//! Differential fuzzing of the hybrid engine against the single-threaded
//! `baseline/` oracles (ISSUE 4; DESIGN.md "Testing: differential fuzz").
//!
//! A seeded sweep samples random engine configurations — workload (R-MAT /
//! uniform) × algorithm × executor mode × partition count × strategy ×
//! [`Placement`] × direction on/off — and checks every run against the
//! baseline: **exact** for the min/max-reduction algorithms (BFS, CC,
//! SSSP, widest-path) and for the integer-accumulating edge-centric
//! family (triangles, k-core, label propagation — DESIGN.md §15), within
//! f32-summation tolerance for the order-sensitive ones (PageRank, BC,
//! personalized PageRank). A second deterministic sweep pins
//! the placement-invariance contract: the same configuration run under
//! every placement must produce bit-identical global outputs. A third
//! property (ISSUE 5) pins the vertex-program driver itself: for every
//! pull-capable program, the derived push and pull kernels must be
//! bit-identical on seeded R-MAT graphs across placements and both
//! executors. A fourth axis (ISSUE 9) fuzzes streaming mutations: a
//! seeded insert/delete batch is applied and the incremental recompute
//! must agree with a from-scratch run on the mutated graph — bit-identical
//! where the warm start claims bit-identity, within engine tolerance for
//! PageRank's residual push.
//!
//! Reproduction: every failure message carries the sweep seed and the full
//! sampled configuration. Re-run just that case with
//! `DIFF_FUZZ_SEED=<seed> cargo test --test differential_fuzz` — the sweep
//! is a pure function of the seed, so iteration k samples the same
//! configuration again. `DIFF_FUZZ_ITERS` widens the sweep (CI uses the
//! committed defaults).

use totem::baseline;
use totem::engine::{Balance, EngineConfig, ExecMode};
use totem::graph::delta::{self, DeltaBatch};
use totem::graph::generator::{rmat, uniform, with_random_weights, RmatParams};
use totem::graph::CsrGraph;
use totem::harness::{
    incremental_rerun, run_alg, AlgKind, FullReason, Recompute, RunSpec, ALL_ALGS,
};
use totem::partition::{Placement, Strategy, ALL_PLACEMENTS};
use totem::util::rng::Rng;

/// Fixed default seed so CI runs are reproducible; override to explore.
const DEFAULT_SEED: u64 = 0xF0221;
const DEFAULT_ITERS: usize = 48;

fn env_u64(name: &str, default: u64) -> u64 {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

/// The sampled graph pool: two scale-free and one uniform graph, all
/// weighted (SSSP and widest-path consume the weights; the rest ignore
/// them). Small enough that the full sweep stays fast in debug builds.
fn graph_pool() -> Vec<(String, CsrGraph)> {
    let mut pool = Vec::new();
    for (name, mut el) in [
        ("rmat7/5".to_string(), rmat(&RmatParams::paper(7, 5))),
        ("rmat6/9".to_string(), rmat(&RmatParams::paper(6, 9))),
        ("uniform6/3".to_string(), uniform(6, 8, 3)),
    ] {
        with_random_weights(&mut el, 64, 0x5eed);
        pool.push((name, CsrGraph::from_edge_list(&el)));
    }
    pool
}

/// One sampled engine configuration plus its human-readable label.
struct Sampled {
    label: String,
    cfg: EngineConfig,
    alg: AlgKind,
    graph_idx: usize,
    source: u32,
    rounds: usize,
}

/// Sample a configuration from the RNG. Every choice is logged into the
/// label so a failure is reproducible by eye as well as by seed.
fn sample(rng: &mut Rng, pool: &[(String, CsrGraph)]) -> Sampled {
    let graph_idx = rng.below(pool.len() as u64) as usize;
    let g = &pool[graph_idx].1;
    let alg = ALL_ALGS[rng.below(ALL_ALGS.len() as u64) as usize];
    let mode = if rng.below(2) == 0 { ExecMode::Synchronous } else { ExecMode::Pipelined };
    let parts = 1 + rng.below(3) as usize;
    let strategy = [Strategy::Rand, Strategy::High, Strategy::Low]
        [rng.below(3) as usize];
    let placement = ALL_PLACEMENTS[rng.below(ALL_PLACEMENTS.len() as u64) as usize];
    let direction = rng.below(2) == 1;
    // Balance mode × worker-thread count (DESIGN.md §11): eligibility is
    // decided centrally in the driver, so every combination must stay
    // baseline-correct regardless of which kernels degrade it.
    let balance = Balance::ALL[rng.below(Balance::ALL.len() as u64) as usize];
    let threads = 1 + rng.below(4) as usize;
    let part_seed = rng.below(1 << 20);
    // shares: random split, normalized
    let mut shares: Vec<f64> = (0..parts).map(|_| 0.2 + rng.next_f64()).collect();
    let total: f64 = shares.iter().sum();
    for s in shares.iter_mut() {
        *s /= total;
    }
    // a source with out-edges (falls back to 0 on pathological graphs)
    let source = (0..64)
        .map(|_| rng.below(g.vertex_count as u64) as u32)
        .find(|&v| g.out_degree(v) > 0)
        .unwrap_or(0);
    let rounds = 2 + rng.below(4) as usize;

    let mut cfg = EngineConfig::cpu_partitions(&shares, strategy)
        .with_mode(mode)
        .with_placement(placement)
        .with_balance(balance)
        .with_threads(threads)
        .with_seed(part_seed);
    if direction {
        cfg = cfg.direction_optimized();
    }
    let label = format!(
        "graph={} alg={} mode={mode:?} parts={parts} strategy={} placement={} \
         balance={} threads={threads} direction={direction} part_seed={part_seed} \
         source={source} rounds={rounds} shares={shares:?}",
        pool[graph_idx].0,
        alg.name(),
        strategy.name(),
        placement.name(),
        balance.name(),
    );
    Sampled { label, cfg, alg, graph_idx, source, rounds }
}

fn check_against_baseline(g: &CsrGraph, s: &Sampled, sweep_seed: u64, iter: usize, iters: usize) {
    // The repro line must carry BOTH env vars: the local default sweep is
    // shorter than CI's, so a failure at iter >= DEFAULT_ITERS would never
    // be reached by `DIFF_FUZZ_SEED=… cargo test` alone.
    let repro = format!("DIFF_FUZZ_SEED={sweep_seed} DIFF_FUZZ_ITERS={iters} iter={iter}");
    let spec = RunSpec::new(s.alg).with_source(s.source).with_rounds(s.rounds);
    let (r, _) = run_alg(g, spec, &s.cfg)
        .unwrap_or_else(|e| panic!("{repro}: {} failed to run: {e:#}", s.label));
    let ctx = |v: usize, a: String, b: String| {
        format!("{repro} [{}] vertex {v}: engine {a} vs baseline {b}", s.label)
    };
    match s.alg {
        AlgKind::Bfs => {
            let want = baseline::bfs(g, s.source);
            for (v, (&a, &b)) in r.output.as_i32().iter().zip(&want).enumerate() {
                assert_eq!(a, b, "{}", ctx(v, a.to_string(), b.to_string()));
            }
        }
        AlgKind::Cc => {
            let want = baseline::cc(g);
            for (v, (&a, &b)) in r.output.as_i32().iter().zip(&want).enumerate() {
                assert_eq!(a, b, "{}", ctx(v, a.to_string(), b.to_string()));
            }
        }
        AlgKind::Sssp => {
            let want = baseline::sssp(g, s.source);
            for (v, (&a, &b)) in r.output.as_f32().iter().zip(&want).enumerate() {
                assert!(
                    a.to_bits() == b.to_bits(),
                    "{}",
                    ctx(v, a.to_string(), b.to_string())
                );
            }
        }
        AlgKind::Widest => {
            // pure selection among edge weights: compared on bits
            let want = baseline::widest(g, s.source);
            for (v, (&a, &b)) in r.output.as_f32().iter().zip(&want).enumerate() {
                assert!(
                    a.to_bits() == b.to_bits(),
                    "{}",
                    ctx(v, a.to_string(), b.to_string())
                );
            }
        }
        AlgKind::Pagerank => {
            let want = baseline::pagerank(g, s.rounds);
            for (v, (&a, &b)) in r.output.as_f32().iter().zip(&want).enumerate() {
                let tol = (1e-4 * b.abs()).max(1e-7);
                assert!((a - b).abs() <= tol, "{}", ctx(v, a.to_string(), b.to_string()));
            }
        }
        AlgKind::Bc => {
            let want = baseline::bc(g, s.source);
            for (v, (&a, &b)) in r.output.as_f32().iter().zip(&want).enumerate() {
                let tol = 1e-3 * b.abs().max(1.0);
                assert!((a - b).abs() <= tol, "{}", ctx(v, a.to_string(), b.to_string()));
            }
        }
        AlgKind::Triangles => {
            // u64 integer accumulation: exact in every configuration
            let want = baseline::triangles(g);
            for (v, (&a, &b)) in r.output.as_u64().iter().zip(&want).enumerate() {
                assert_eq!(a, b, "{}", ctx(v, a.to_string(), b.to_string()));
            }
        }
        AlgKind::Kcore => {
            let want = baseline::kcore(g);
            for (v, (&a, &b)) in r.output.as_i32().iter().zip(&want).enumerate() {
                assert_eq!(a, b, "{}", ctx(v, a.to_string(), b.to_string()));
            }
        }
        AlgKind::Labelprop => {
            let want = baseline::labelprop(g, s.rounds);
            for (v, (&a, &b)) in r.output.as_i32().iter().zip(&want).enumerate() {
                assert_eq!(a, b, "{}", ctx(v, a.to_string(), b.to_string()));
            }
        }
        AlgKind::Ppr => {
            // order-sensitive f32 summation, same slack as PageRank
            let want = baseline::ppr(g, s.source, s.rounds);
            for (v, (&a, &b)) in r.output.as_f32().iter().zip(&want).enumerate() {
                let tol = (1e-4 * b.abs()).max(1e-7);
                assert!((a - b).abs() <= tol, "{}", ctx(v, a.to_string(), b.to_string()));
            }
        }
    }
}

/// The randomized sweep: engine vs baseline across the whole sampled
/// configuration space.
#[test]
fn fuzz_engine_against_baseline() {
    let sweep_seed = env_u64("DIFF_FUZZ_SEED", DEFAULT_SEED);
    let iters = env_u64("DIFF_FUZZ_ITERS", DEFAULT_ITERS as u64) as usize;
    let pool = graph_pool();
    let mut rng = Rng::new(sweep_seed);
    for iter in 0..iters {
        let s = sample(&mut rng, &pool);
        check_against_baseline(&pool[s.graph_idx].1, &s, sweep_seed, iter, iters);
    }
}

/// The mutation axis (ISSUE 9 tentpole contract): after a seeded
/// insert/delete batch, [`incremental_rerun`] must agree with a
/// from-scratch run on the mutated graph under the *same* sampled engine
/// configuration — executor mode × partitions × strategy × placement ×
/// balance × direction all inherited from [`sample`]. Monotone warm
/// starts (BFS/CC/SSSP/widest, insert-only batches) and full fallbacks
/// compare bit-identical; PageRank's residual push compares within the
/// engine's own baseline tolerance. The recompute classification itself
/// is pinned against the batch's delete effect.
#[test]
fn fuzz_incremental_recompute_against_full_rerun() {
    let sweep_seed = env_u64("DIFF_FUZZ_SEED", DEFAULT_SEED);
    let iters = env_u64("DIFF_FUZZ_ITERS", DEFAULT_ITERS as u64) as usize;
    let pool = graph_pool();
    // decorrelated from the baseline sweep so the two tests explore
    // different configurations under the same CI seed
    let mut rng = Rng::new(sweep_seed ^ 0xD317A);
    for iter in 0..iters {
        let s = sample(&mut rng, &pool);
        let g = &pool[s.graph_idx].1;
        // insert-only half the time so the monotone warm-start path runs
        // as often as the effective-delete fallback
        let delete_frac = if rng.below(2) == 0 { 0.0 } else { 0.4 };
        let n_ops = 1 + rng.below(24) as usize;
        let dseed = rng.below(1 << 30);
        let repro = format!(
            "DIFF_FUZZ_SEED={sweep_seed} DIFF_FUZZ_ITERS={iters} iter={iter} \
             n_ops={n_ops} delete_frac={delete_frac} dseed={dseed}"
        );
        let batch = DeltaBatch::seeded(g, n_ops, delete_frac, dseed);
        let applied = delta::apply(g, &batch)
            .unwrap_or_else(|e| panic!("{repro} [{}]: delta apply failed: {e}", s.label));

        let spec = RunSpec::new(s.alg).with_source(s.source).with_rounds(s.rounds);
        let (prior, _) = run_alg(g, spec, &s.cfg)
            .unwrap_or_else(|e| panic!("{repro} [{}]: prior run failed: {e:#}", s.label));
        let inc = incremental_rerun(&applied.graph, spec, &s.cfg, &prior.output, &applied)
            .unwrap_or_else(|e| panic!("{repro} [{}]: incremental failed: {e:#}", s.label));
        let (full, _) = run_alg(&applied.graph, spec, &s.cfg)
            .unwrap_or_else(|e| panic!("{repro} [{}]: full rerun failed: {e:#}", s.label));

        // classification must be a pure function of (alg, delete effect)
        let want_recompute = match s.alg {
            AlgKind::Bc
            | AlgKind::Triangles
            | AlgKind::Kcore
            | AlgKind::Labelprop
            | AlgKind::Ppr => Recompute::Full(FullReason::Unsupported),
            AlgKind::Pagerank => match inc.recompute {
                Recompute::ResidualPush { .. } => inc.recompute,
                other => panic!("{repro} [{}]: pagerank took {other:?}", s.label),
            },
            _ if applied.effective_deletes => Recompute::Full(FullReason::EffectiveDeletes),
            _ => Recompute::WarmStart,
        };
        assert_eq!(
            inc.recompute, want_recompute,
            "{repro} [{}]: recompute classification",
            s.label
        );

        let ctx = |v: usize, a: String, b: String| {
            format!(
                "{repro} [{}] {:?} vertex {v}: incremental {a} vs full {b}",
                s.label, inc.recompute
            )
        };
        match s.alg {
            AlgKind::Pagerank => {
                // residual push vs engine: same tolerance the engine is
                // held to against the sequential baseline
                for (v, (&a, &b)) in
                    inc.output.as_f32().iter().zip(full.output.as_f32()).enumerate()
                {
                    let tol = (1e-4 * b.abs()).max(1e-7);
                    assert!(
                        (a - b).abs() <= tol,
                        "{}",
                        ctx(v, a.to_string(), b.to_string())
                    );
                }
            }
            AlgKind::Bfs | AlgKind::Cc | AlgKind::Kcore | AlgKind::Labelprop => {
                for (v, (&a, &b)) in
                    inc.output.as_i32().iter().zip(full.output.as_i32()).enumerate()
                {
                    assert_eq!(a, b, "{}", ctx(v, a.to_string(), b.to_string()));
                }
            }
            AlgKind::Triangles => {
                for (v, (&a, &b)) in
                    inc.output.as_u64().iter().zip(full.output.as_u64()).enumerate()
                {
                    assert_eq!(a, b, "{}", ctx(v, a.to_string(), b.to_string()));
                }
            }
            // SSSP/widest warm starts and every full fallback (incl. BC
            // and PPR) ran through the same engine: compared on bits
            AlgKind::Sssp | AlgKind::Widest | AlgKind::Bc | AlgKind::Ppr => {
                for (v, (&a, &b)) in
                    inc.output.as_f32().iter().zip(full.output.as_f32()).enumerate()
                {
                    assert!(
                        a.to_bits() == b.to_bits(),
                        "{}",
                        ctx(v, a.to_string(), b.to_string())
                    );
                }
            }
        }
    }
}

/// Deterministic placement-invariance sweep: the same configuration under
/// every [`Placement`] must produce bit-identical global outputs — the
/// tentpole contract of ISSUE 4 (the permutation is invisible after
/// `collect_to_global`), including the order-sensitive f32 algorithms
/// (canonical-order kernels, DESIGN.md §9).
#[test]
fn outputs_bit_identical_across_placements() {
    let pool = graph_pool();
    for (gname, g) in &pool {
        let source = (0..g.vertex_count as u32).find(|&v| g.out_degree(v) > 0).unwrap_or(0);
        for alg in ALL_ALGS {
            for mode in [ExecMode::Synchronous, ExecMode::Pipelined] {
                for parts in [2usize, 3] {
                    let shares = vec![1.0 / parts as f64; parts];
                    let mut reference: Option<(Placement, Vec<u32>)> = None;
                    for placement in ALL_PLACEMENTS {
                        let mut cfg = EngineConfig::cpu_partitions(&shares, Strategy::Rand)
                            .with_mode(mode)
                            .with_seed(13)
                            .with_placement(placement);
                        if alg == AlgKind::Bfs {
                            cfg = cfg.direction_optimized();
                        }
                        let spec = RunSpec::new(alg).with_source(source).with_rounds(3);
                        let (r, _) = run_alg(g, spec, &cfg).unwrap_or_else(|e| {
                            panic!("{gname}/{}/{mode:?}/{parts}p/{}: {e:#}",
                                alg.name(), placement.name())
                        });
                        // compare raw bits regardless of dtype (u64
                        // counts contribute both halves)
                        let bits: Vec<u32> = match &r.output {
                            totem::engine::StateArray::I32(v) => {
                                v.iter().map(|&x| x as u32).collect()
                            }
                            totem::engine::StateArray::F32(v) => {
                                v.iter().map(|x| x.to_bits()).collect()
                            }
                            totem::engine::StateArray::U64(v) => v
                                .iter()
                                .flat_map(|&x| [x as u32, (x >> 32) as u32])
                                .collect(),
                        };
                        match &reference {
                            None => reference = Some((placement, bits)),
                            Some((p0, want)) => assert_eq!(
                                &bits, want,
                                "{gname}/{}/{mode:?}/{parts}p: {} differs from {}",
                                alg.name(),
                                placement.name(),
                                p0.name()
                            ),
                        }
                    }
                }
            }
        }
    }
}

/// Balance-mode invariance (ISSUE 6 tentpole contract, DESIGN.md §11):
/// the same configuration run under {Vertex, Edge, HubSplit} chunking at
/// several worker counts must produce bit-identical global outputs for
/// all ten algorithms, on both executors. CAS-scatter kernels take any
/// mode; the order-sensitive f32 kernels run their canonical sequential
/// path regardless — either way, bits may not move.
#[test]
fn outputs_bit_identical_across_balance_modes() {
    let pool = graph_pool();
    for (gname, g) in &pool {
        let source = (0..g.vertex_count as u32).find(|&v| g.out_degree(v) > 0).unwrap_or(0);
        for alg in ALL_ALGS {
            for mode in [ExecMode::Synchronous, ExecMode::Pipelined] {
                for threads in [2usize, 4] {
                    let mut reference: Option<(Balance, Vec<u32>)> = None;
                    for balance in Balance::ALL {
                        let cfg = EngineConfig::cpu_partitions(&[0.5, 0.5], Strategy::High)
                            .with_mode(mode)
                            .with_seed(13)
                            .with_balance(balance)
                            .with_threads(threads);
                        let spec = RunSpec::new(alg).with_source(source).with_rounds(3);
                        let (r, _) = run_alg(g, spec, &cfg).unwrap_or_else(|e| {
                            panic!("{gname}/{}/{mode:?}/{threads}t/{}: {e:#}",
                                alg.name(), balance.name())
                        });
                        let bits: Vec<u32> = match &r.output {
                            totem::engine::StateArray::I32(v) => {
                                v.iter().map(|&x| x as u32).collect()
                            }
                            totem::engine::StateArray::F32(v) => {
                                v.iter().map(|x| x.to_bits()).collect()
                            }
                            totem::engine::StateArray::U64(v) => v
                                .iter()
                                .flat_map(|&x| [x as u32, (x >> 32) as u32])
                                .collect(),
                        };
                        match &reference {
                            None => reference = Some((balance, bits)),
                            Some((b0, want)) => assert_eq!(
                                &bits, want,
                                "{gname}/{}/{mode:?}/{threads}t: {} differs from {}",
                                alg.name(),
                                balance.name(),
                                b0.name()
                            ),
                        }
                    }
                }
            }
        }
    }
}

/// Push-mode PageRank is the kernel whose scatter order the placement
/// layer made canonical (DESIGN.md §9.2) — pin its bit-identity directly,
/// since the harness only dispatches the pull-mode default.
#[test]
fn push_mode_pagerank_bit_identical_across_placements() {
    let pool = graph_pool();
    for (gname, g) in &pool {
        for parts in [2usize, 3] {
            let shares = vec![1.0 / parts as f64; parts];
            for mode in [ExecMode::Synchronous, ExecMode::Pipelined] {
                let mut reference: Option<Vec<u32>> = None;
                for placement in ALL_PLACEMENTS {
                    let cfg = EngineConfig::cpu_partitions(&shares, Strategy::Rand)
                        .with_mode(mode)
                        .with_seed(13)
                        .with_placement(placement);
                    let mut alg = totem::alg::pagerank::Pagerank::push_mode(4);
                    let r = totem::engine::run(g, &mut alg, &cfg)
                        .unwrap_or_else(|e| panic!("{gname}/{placement:?}: {e:#}"));
                    let bits: Vec<u32> =
                        r.output.as_f32().iter().map(|x| x.to_bits()).collect();
                    match &reference {
                        None => reference = Some(bits),
                        Some(want) => assert_eq!(
                            &bits, want,
                            "{gname}/{mode:?}/{parts}p: push-PR differs under {}",
                            placement.name()
                        ),
                    }
                }
            }
        }
    }
}

/// ISSUE 5 driver property: for every **pull-capable** vertex program,
/// the [`ProgramDriver`]'s derived push and pull kernels must produce
/// bit-identical outputs — and identical superstep counts — on seeded
/// R-MAT graphs across every placement, partition count, and both
/// executors. Push-only programs are asserted to opt out (`supports_pull
/// == false`), so this sweep automatically covers any future program that
/// declares a traversal kernel.
#[test]
fn pull_capable_programs_push_pull_bit_identical() {
    use totem::alg::Algorithm;
    use totem::engine::{self, DirectionConfig};

    /// α/β knobs that flip every CPU element to bottom-up on the first
    /// non-empty frontier and keep it there.
    fn force_pull() -> DirectionConfig {
        DirectionConfig { alpha: 1e12, beta: 1e12 }
    }

    fn graphs() -> Vec<(String, CsrGraph)> {
        [0xA11CEu64, 0xB0B]
            .iter()
            .map(|&seed| {
                let mut el = rmat(&RmatParams::paper(8, seed));
                with_random_weights(&mut el, 32, seed ^ 1);
                (format!("rmat8/{seed:x}"), CsrGraph::from_edge_list(&el))
            })
            .collect()
    }

    fn bits_of(out: &totem::engine::StateArray) -> Vec<u32> {
        match out {
            totem::engine::StateArray::I32(v) => v.iter().map(|&x| x as u32).collect(),
            totem::engine::StateArray::F32(v) => v.iter().map(|x| x.to_bits()).collect(),
            totem::engine::StateArray::U64(v) => {
                v.iter().flat_map(|&x| [x as u32, (x >> 32) as u32]).collect()
            }
        }
    }

    fn check<A: Algorithm>(name: &str, make: &dyn Fn(u32) -> A) -> bool {
        if !make(0).supports_pull() {
            return false;
        }
        for (gname, g) in graphs() {
            // a hub source guarantees a non-empty first frontier, so the
            // forced-pull knobs must engage (asserted below)
            let source = (0..g.vertex_count as u32)
                .max_by_key(|&v| g.out_degree(v))
                .unwrap_or(0);
            for parts in [1usize, 2, 3] {
                let shares = vec![1.0 / parts as f64; parts];
                for mode in [ExecMode::Synchronous, ExecMode::Pipelined] {
                    for placement in ALL_PLACEMENTS {
                        let base = EngineConfig::cpu_partitions(&shares, Strategy::Rand)
                            .with_mode(mode)
                            .with_seed(17)
                            .with_placement(placement);
                        let ctx = format!(
                            "{name}/{gname}/{mode:?}/{parts}p/{}",
                            placement.name()
                        );
                        let mut push_alg = make(source);
                        let rp = engine::run(&g, &mut push_alg, &base).unwrap();
                        let mut pull_alg = make(source);
                        let cfg = base.clone().with_direction(force_pull());
                        let rq = engine::run(&g, &mut pull_alg, &cfg).unwrap();
                        assert!(
                            rq.metrics.pull_steps() >= 1,
                            "{ctx}: forced-pull run never pulled (vacuous test)"
                        );
                        assert_eq!(
                            bits_of(&rp.output),
                            bits_of(&rq.output),
                            "{ctx}: pull kernel diverged from push"
                        );
                        assert_eq!(rp.supersteps, rq.supersteps, "{ctx}: superstep count");
                    }
                }
            }
        }
        true
    }

    let mut any_pull = false;
    any_pull |= check("bfs", &|s| totem::alg::bfs::Bfs::new(s));
    any_pull |= check("pagerank", &|_| totem::alg::pagerank::Pagerank::new(3));
    any_pull |= check("sssp", &|s| totem::alg::sssp::Sssp::new(s));
    any_pull |= check("bc", &|s| totem::alg::bc::Bc::new(s));
    any_pull |= check("cc", &|_| totem::alg::cc::Cc::new());
    any_pull |= check("widest", &|s| totem::alg::widest::Widest::new(s));
    // the edge-centric family (DESIGN.md §15) runs intersection/scan
    // kernels, not traversal — each must opt out rather than derive a
    // bogus pull kernel
    assert!(!check("triangles", &|_| totem::alg::triangles::Triangles::new()));
    assert!(!check("kcore", &|_| totem::alg::kcore::KCore::new()));
    assert!(!check("labelprop", &|_| totem::alg::labelprop::LabelProp::new(3)));
    assert!(!check("ppr", &|s| totem::alg::ppr::Ppr::new(s, 3)));
    assert!(any_pull, "at least one program (BFS) must be pull-capable");
}

/// k-core property sweep (DESIGN.md §15.2): the engine's batch-synchronous
/// peel must agree with an *independently shaped* oracle — the textbook
/// sequential min-degree peel (Matula–Beck) over the same undirected
/// multigraph view. The two peel in different orders (whole frontiers vs
/// one vertex at a time), so an escalation or reactivation bug in the
/// engine cannot be mirrored by the oracle.
#[test]
fn kcore_matches_sequential_min_degree_peel() {
    fn sequential_peel(g: &CsrGraph) -> Vec<i32> {
        let u = g.to_undirected();
        let n = u.vertex_count;
        let mut deg: Vec<i64> = (0..n as u32).map(|v| u.neighbors(v).len() as i64).collect();
        let mut alive = vec![true; n];
        let mut core = vec![0i32; n];
        let mut k = 0i64;
        for _ in 0..n {
            let v = (0..n)
                .filter(|&v| alive[v])
                .min_by_key(|&v| deg[v])
                .expect("one alive vertex per step");
            k = k.max(deg[v]);
            core[v] = k as i32;
            alive[v] = false;
            for &t in u.neighbors(v as u32) {
                if alive[t as usize] {
                    deg[t as usize] -= 1; // multiplicity: one per parallel edge
                }
            }
        }
        core
    }

    for seed in [3u64, 11, 0xC04E] {
        let el = rmat(&RmatParams::paper(7, seed));
        let g = CsrGraph::from_edge_list(&el);
        let want = sequential_peel(&g);
        for mode in [ExecMode::Synchronous, ExecMode::Pipelined] {
            let cfg = EngineConfig::cpu_partitions(&[0.5, 0.5], Strategy::High)
                .with_mode(mode)
                .with_seed(7)
                .with_threads(2);
            let (r, _) = run_alg(&g, RunSpec::new(AlgKind::Kcore), &cfg)
                .unwrap_or_else(|e| panic!("rmat7/{seed:x}/{mode:?}: {e:#}"));
            for (v, (&a, &b)) in r.output.as_i32().iter().zip(&want).enumerate() {
                assert_eq!(
                    a, b,
                    "rmat7/{seed:x}/{mode:?} vertex {v}: engine coreness {a} vs \
                     sequential peel {b}"
                );
            }
        }
    }
}

/// Label propagation's tie-break contract (DESIGN.md §15.3): min-label
/// resolution makes every round a pure function of the previous label
/// array, so the output is **bit-identical** across executors, placements,
/// and partition counts — and equal to the sequential baseline — despite
/// label propagation being chaotic under unspecified tie-breaks.
#[test]
fn labelprop_deterministic_across_executors_and_placements() {
    for seed in [5u64, 0xBEEF] {
        let el = rmat(&RmatParams::paper(7, seed));
        let g = CsrGraph::from_edge_list(&el);
        let rounds = 6;
        let want = baseline::labelprop(&g, rounds);
        for mode in [ExecMode::Synchronous, ExecMode::Pipelined] {
            for parts in [1usize, 2, 3] {
                let shares = vec![1.0 / parts as f64; parts];
                for placement in ALL_PLACEMENTS {
                    let cfg = EngineConfig::cpu_partitions(&shares, Strategy::Rand)
                        .with_mode(mode)
                        .with_seed(7)
                        .with_placement(placement);
                    let spec = RunSpec::new(AlgKind::Labelprop).with_rounds(rounds);
                    let (r, _) = run_alg(&g, spec, &cfg).unwrap_or_else(|e| {
                        panic!("rmat7/{seed:x}/{mode:?}/{parts}p/{}: {e:#}", placement.name())
                    });
                    assert_eq!(
                        r.output.as_i32(),
                        want.as_slice(),
                        "rmat7/{seed:x}/{mode:?}/{parts}p/{}: labels diverged",
                        placement.name()
                    );
                }
            }
        }
    }
}

/// The sweep is a pure function of its seed: same seed, same samples.
#[test]
fn sampling_is_seed_deterministic() {
    let pool = graph_pool();
    let labels = |seed: u64| -> Vec<String> {
        let mut rng = Rng::new(seed);
        (0..8).map(|_| sample(&mut rng, &pool).label).collect()
    };
    assert_eq!(labels(42), labels(42));
    assert_ne!(labels(42), labels(43));
}
