# golden fixture star8 (weighted; see gen_fixtures.py)
p 8 14
0 1 1
0 2 1
0 3 1
0 4 1
0 5 1
0 6 1
0 7 1
1 0 2
2 0 2
3 0 2
4 0 2
5 0 2
6 0 2
7 0 2
