#!/usr/bin/env python3
"""Bootstrap generator for the golden conformance fixtures.

Writes the four fixture graphs (weighted text edge lists) and the expected
per-algorithm outputs as one-value-per-line text files. The *.el files are
the source of truth for the graphs; the expected outputs were computed by
this reference implementation (plain BFS/CC/Dijkstra, float64 PageRank and
Brandes BC mirroring `baseline/`) and cross-checked by the engine itself —
`GOLDEN_REGEN=1 cargo test --test golden_conformance` rewrites the
expected files from the engine's host-only synchronous run (see DESIGN.md
"Testing").

Integer-valued outputs (BFS levels, CC labels, SSSP distances under
integer weights, triangle counts, core numbers, propagation labels) are
exact in f32/u64/i32 and asserted bit-for-bit; PageRank, BC, and
personalized PageRank are asserted within an f32 summation tolerance.

The edge-centric family (DESIGN.md section 15) mirrors baseline/ exactly:
triangles over the undirected deduplicated self-loop-free closure, k-core
and label propagation over the undirected *multigraph* view (parallel
edges keep their multiplicity, self-loops double), PPR as float64 power
iteration with dangling mass dropped.
"""

import heapq
import os

HERE = os.path.dirname(os.path.abspath(__file__))
INF_I32 = 1 << 30
DAMPING = 0.85
PR_ROUNDS = 5


# --- deterministic RNG (xorshift64*, independent of the repo's PRNG) ----
class Rng:
    def __init__(self, seed):
        self.s = seed & 0xFFFFFFFFFFFFFFFF or 0x9E3779B97F4A7C15

    def next(self):
        s = self.s
        s ^= (s >> 12) & 0xFFFFFFFFFFFFFFFF
        s ^= (s << 25) & 0xFFFFFFFFFFFFFFFF
        s ^= (s >> 27) & 0xFFFFFFFFFFFFFFFF
        self.s = s & 0xFFFFFFFFFFFFFFFF
        return (self.s * 0x2545F4914F6CDD1D) & 0xFFFFFFFFFFFFFFFF

    def f64(self):
        return (self.next() >> 11) / float(1 << 53)

    def below(self, n):
        return self.next() % n


# --- fixture graphs -----------------------------------------------------
def chain8():
    edges = [(i, i + 1, float(i + 1)) for i in range(7)]
    return 8, edges


def star8():
    edges = [(0, i, 1.0) for i in range(1, 8)] + [(i, 0, 2.0) for i in range(1, 8)]
    return 8, edges


def twocomm16():
    edges = []
    for i in range(8):  # community A: ring + even chords
        edges.append((i, (i + 1) % 8, 1.0))
    for i in (0, 2, 4, 6):
        edges.append((i, (i + 2) % 8, 3.0))
    for j in range(8):  # community B: ring + sparse chords
        edges.append((8 + j, 8 + (j + 1) % 8, 2.0))
    for j in (0, 3, 6):
        edges.append((8 + j, 8 + (j + 3) % 8, 1.0))
    return 16, edges


def rmat64():
    n, m, scale = 64, 320, 6
    a, b, c = 0.57, 0.19, 0.19
    rng = Rng(0xC0FFEE)
    edges = []
    for _ in range(m):
        x = y = 0
        for level in reversed(range(scale)):
            r = rng.f64()
            bit = 1 << level
            if r < a:
                pass
            elif r < a + b:
                y |= bit
            elif r < a + b + c:
                x |= bit
            else:
                x |= bit
                y |= bit
        w = float(1 + rng.below(8))
        edges.append((x, y, w))
    return n, edges


# --- reference algorithms (mirror baseline/) ----------------------------
def adjacency(n, edges):
    out = [[] for _ in range(n)]
    for s, d, w in edges:
        out[s].append((d, w))
    return out


def bfs(n, edges, src):
    out = adjacency(n, edges)
    lev = [INF_I32] * n
    lev[src] = 0
    q = [src]
    while q:
        nxt = []
        for v in q:
            for d, _ in out[v]:
                if lev[d] == INF_I32:
                    lev[d] = lev[v] + 1
                    nxt.append(d)
        q = nxt
    return lev


def cc(n, edges):
    und = [[] for _ in range(n)]
    for s, d, _ in edges:
        und[s].append(d)
        und[d].append(s)
    label = list(range(n))
    for v in range(n):
        if label[v] != v:
            continue
        stack, comp = [v], [v]
        seen = {v}
        while stack:
            u = stack.pop()
            for w in und[u]:
                if w not in seen:
                    seen.add(w)
                    comp.append(w)
                    stack.append(w)
        m = min(comp)
        for w in comp:
            label[w] = m
    return label


def sssp(n, edges, src):
    out = adjacency(n, edges)
    dist = [float("inf")] * n
    dist[src] = 0.0
    pq = [(0.0, src)]
    while pq:
        d, v = heapq.heappop(pq)
        if d > dist[v]:
            continue
        for t, w in out[v]:
            nd = d + w
            if nd < dist[t]:
                dist[t] = nd
                heapq.heappush(pq, (nd, t))
    return dist


def widest(n, edges, src):
    """Single-source widest path (max-min): width[v] = best bottleneck
    capacity over paths src->v; +inf at the source (empty path), -inf if
    unreachable. Pure selection among edge weights -- exact in f32, so the
    engine is asserted bit-for-bit against these files (like BFS/CC/SSSP).
    Mirrors baseline::widest in rust/src/baseline/."""
    out = adjacency(n, edges)
    width = [-float("inf")] * n
    width[src] = float("inf")
    q = [src]
    queued = [False] * n
    queued[src] = True
    head = 0
    while head < len(q):
        v = q[head]
        head += 1
        queued[v] = False
        for t, w in out[v]:
            cand = min(width[v], w)
            if cand > width[t]:
                width[t] = cand
                if not queued[t]:
                    q.append(t)
                    queued[t] = True
    return width


def pagerank(n, edges, rounds):
    out = adjacency(n, edges)
    outdeg = [len(out[v]) for v in range(n)]
    rev = [[] for _ in range(n)]
    for s, d, _ in edges:
        rev[d].append(s)
    base = (1.0 - DAMPING) / n
    rank = [1.0 / n] * n
    for _ in range(rounds):
        contrib = [rank[v] / outdeg[v] if outdeg[v] > 0 else 0.0 for v in range(n)]
        rank = [base + DAMPING * sum(contrib[u] for u in rev[v]) for v in range(n)]
    return rank


def bc(n, edges, src):
    out = [[] for _ in range(n)]
    for s, d, _ in edges:
        out[s].append(d)
    dist = [-1] * n
    sigma = [0.0] * n
    order = []
    dist[src] = 0
    sigma[src] = 1.0
    q = [src]
    head = 0
    while head < len(q):
        v = q[head]
        head += 1
        order.append(v)
        for w in out[v]:
            if dist[w] < 0:
                dist[w] = dist[v] + 1
                q.append(w)
            if dist[w] == dist[v] + 1:
                sigma[w] += sigma[v]
    delta = [0.0] * n
    scores = [0.0] * n
    for v in reversed(order):
        for w in out[v]:
            if dist[w] == dist[v] + 1 and sigma[w] > 0.0:
                delta[v] += sigma[v] / sigma[w] * (1.0 + delta[w])
        if v != src:
            scores[v] = delta[v]
    return scores


def triangles(n, edges):
    """Per-vertex incident-triangle counts over the undirected,
    deduplicated, self-loop-free closure (mirrors baseline::triangles)."""
    adj = [set() for _ in range(n)]
    for s, d, _ in edges:
        if s != d:
            adj[s].add(d)
            adj[d].add(s)
    srt = [sorted(a) for a in adj]
    tri = [0] * n
    for v in range(n):
        a = srt[v]
        for i, w in enumerate(a):
            for u in a[i + 1:]:
                if u in adj[w]:
                    tri[v] += 1
    return tri


def undirected_multi(n, edges):
    """The engine's to_undirected view: every directed edge contributes
    both endpoints, parallel edges kept, self-loops doubled."""
    und = [[] for _ in range(n)]
    for s, d, _ in edges:
        und[s].append(d)
        und[d].append(s)
    return und


def kcore(n, edges):
    """Coreness by synchronous batch peeling over the undirected
    multigraph (mirrors baseline::kcore): at threshold k remove every
    alive vertex with alive-degree <= k; a quiet round escalates k."""
    und = undirected_multi(n, edges)
    core = [INF_I32] * n
    remaining = n
    k = 0
    while remaining > 0:
        doomed = []
        for v in range(n):
            if core[v] != INF_I32:
                continue
            alive = sum(1 for t in und[v] if core[t] == INF_I32)
            if alive <= k:
                doomed.append(v)
        if not doomed:
            k += 1
        else:
            for v in doomed:
                core[v] = k
                remaining -= 1
    return core


def labelprop(n, edges, rounds):
    """Synchronous label propagation over the undirected multigraph
    (multiplicities weight labels), min-label tie-break, early exit on a
    quiet round (mirrors baseline::labelprop)."""
    und = undirected_multi(n, edges)
    label = list(range(n))
    for _ in range(rounds):
        prev = list(label)
        changed = False
        for v in range(n):
            if not und[v]:
                continue
            freq = {}
            for t in und[v]:
                freq[prev[t]] = freq.get(prev[t], 0) + 1
            best = min(freq.items(), key=lambda kv: (-kv[1], kv[0]))[0]
            if best != label[v]:
                label[v] = best
                changed = True
        if not changed:
            break
    return label


def ppr(n, edges, src, rounds):
    """Personalized PageRank: float64 power iteration from the source
    indicator, fixed rounds, dangling mass dropped (mirrors
    baseline::ppr; the engine's f32 run is asserted within tolerance)."""
    out = adjacency(n, edges)
    outdeg = [len(out[v]) for v in range(n)]
    rev = [[] for _ in range(n)]
    for s, d, _ in edges:
        rev[d].append(s)
    rank = [0.0] * n
    rank[src] = 1.0
    for _ in range(rounds):
        contrib = [rank[v] / outdeg[v] if outdeg[v] > 0 else 0.0 for v in range(n)]
        rank = [
            (1.0 - DAMPING if v == src else 0.0)
            + DAMPING * sum(contrib[u] for u in rev[v])
            for v in range(n)
        ]
    return rank


# --- emit ---------------------------------------------------------------
def fmt(x):
    if x == float("inf"):
        return "inf"
    if x == -float("inf"):
        return "-inf"
    if float(x) == int(x):
        return str(int(x))
    return repr(float(x))


def write_fixture(name, n, edges, src):
    with open(os.path.join(HERE, name + ".el"), "w") as f:
        f.write("# golden fixture %s (weighted; see gen_fixtures.py)\n" % name)
        f.write("p %d %d\n" % (n, len(edges)))
        for s, d, w in edges:
            f.write("%d %d %s\n" % (s, d, fmt(w)))
    results = {
        "bfs": bfs(n, edges, src),
        "cc": cc(n, edges),
        "sssp": sssp(n, edges, src),
        "pagerank": pagerank(n, edges, PR_ROUNDS),
        "bc": bc(n, edges, src),
        "widest": widest(n, edges, src),
        "triangles": triangles(n, edges),
        "kcore": kcore(n, edges),
        "labelprop": labelprop(n, edges, PR_ROUNDS),
        "ppr": ppr(n, edges, src, PR_ROUNDS),
    }
    for alg, vals in results.items():
        with open(os.path.join(HERE, "%s.%s.txt" % (name, alg)), "w") as f:
            for x in vals:
                f.write(fmt(x) + "\n")
    reach = sum(1 for x in results["bfs"] if x != INF_I32)
    print("%s: |V|=%d |E|=%d src=%d reachable=%d" % (name, n, len(edges), src, reach))


def main():
    for name, (n, edges) in (
        ("chain8", chain8()),
        ("star8", star8()),
        ("twocomm16", twocomm16()),
    ):
        write_fixture(name, n, edges, 0)
    n, edges = rmat64()
    outdeg = [0] * n
    for s, _, _ in edges:
        outdeg[s] += 1
    src = max(range(n), key=lambda v: (outdeg[v], -v))
    write_fixture("rmat64", n, edges, src)
    print("rmat64 source =", src, "out-degree", outdeg[src])


if __name__ == "__main__":
    main()
