# golden fixture chain8 (weighted; see gen_fixtures.py)
p 8 7
0 1 1
1 2 2
2 3 3
3 4 4
4 5 5
5 6 6
6 7 7
