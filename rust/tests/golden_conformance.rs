//! Golden conformance suite (DESIGN.md "Testing").
//!
//! Tiny deterministic fixture graphs live in `rust/tests/golden/` as
//! weighted text edge lists, next to the expected output of every
//! algorithm (one value per line). Each test sweeps every engine
//! configuration — {Synchronous, Pipelined} × {1, 2, 3 partitions} ×
//! {RAND, HIGH, LOW} × every vertex [`Placement`] — and checks the run
//! against the fixture:
//!
//! - BFS, CC, SSSP, and widest-path are **bit-exact** against the golden
//!   files in every configuration (min/max reductions are order-free; the
//!   fixtures carry integer weights, so SSSP distances are exact in f32
//!   and widest-path widths are pure selections among weights);
//! - triangle counting, k-core, and label propagation (DESIGN.md §15) are
//!   likewise **bit-exact** everywhere: their per-edge accumulations are
//!   integer adds (u64 counts, i32 degrees/labels), associative and
//!   commutative, so no configuration can perturb them;
//! - direction-optimized BFS must also be bit-exact against the same
//!   push-only golden files (DESIGN.md §8);
//! - PageRank, BC, and personalized PageRank are order-sensitive f32
//!   summations, so their partition-dependent results are checked within
//!   an f32 summation tolerance against the golden files, while
//!   Synchronous vs Pipelined at the *same* partitioning must agree
//!   bit-for-bit (the pipelined executor's contract) — and so must every
//!   placement at the same partitioning (the canonical-order contract,
//!   DESIGN.md §9: a vertex placement is pure layout, invisible after
//!   `collect_to_global`).
//!
//! On mismatch the failing output is dumped under `target/golden-diff/`
//! (CI uploads it as an artifact). Regenerate the expected files
//! deliberately with `GOLDEN_REGEN=1 cargo test --test golden_conformance`
//! — golden files are then rewritten from the host-only synchronous run;
//! inspect the diff before committing (DESIGN.md "Testing").

use std::fmt::Write as _;
use std::path::{Path, PathBuf};
use totem::engine::{Balance, EngineConfig, ExecMode, StateArray};
use totem::graph::{io as gio, CsrGraph};
use totem::harness::{run_alg, AlgKind, RunSpec, ALL_ALGS};
use totem::partition::{Strategy, ALL_PLACEMENTS};

const PR_ROUNDS: usize = 5;

struct Fixture {
    name: &'static str,
    /// BFS/SSSP/BC source (rmat64's is its max-out-degree hub).
    source: u32,
}

const FIXTURES: &[Fixture] = &[
    Fixture { name: "chain8", source: 0 },
    Fixture { name: "star8", source: 0 },
    Fixture { name: "twocomm16", source: 0 },
    Fixture { name: "rmat64", source: 0 },
];

fn golden_dir() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("rust/tests/golden")
}

fn diff_dir() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("target/golden-diff")
}

fn regen() -> bool {
    std::env::var("GOLDEN_REGEN").map_or(false, |v| !v.is_empty() && v != "0")
}

fn load_graph(name: &str) -> CsrGraph {
    let path = golden_dir().join(format!("{name}.el"));
    let el = gio::read_edge_list(&path).unwrap_or_else(|e| panic!("{path:?}: {e:#}"));
    CsrGraph::from_edge_list(&el)
}

fn golden_path(fixture: &str, alg: AlgKind) -> PathBuf {
    golden_dir().join(format!("{fixture}.{}.txt", alg.name()))
}

/// Which [`StateArray`] variant an algorithm's golden file encodes.
/// Exhaustive over [`AlgKind`] so a new algorithm cannot land without a
/// conformance decision.
enum OutKind {
    I32,
    F32,
    U64,
}

fn out_kind(alg: AlgKind) -> OutKind {
    match alg {
        AlgKind::Bfs | AlgKind::Cc | AlgKind::Kcore | AlgKind::Labelprop => OutKind::I32,
        AlgKind::Sssp | AlgKind::Pagerank | AlgKind::Bc | AlgKind::Widest | AlgKind::Ppr => {
            OutKind::F32
        }
        AlgKind::Triangles => OutKind::U64,
    }
}

fn load_golden(fixture: &str, alg: AlgKind) -> StateArray {
    let path = golden_path(fixture, alg);
    let text = std::fs::read_to_string(&path).unwrap_or_else(|e| panic!("{path:?}: {e}"));
    let lines = text.lines().map(str::trim).filter(|l| !l.is_empty());
    match out_kind(alg) {
        OutKind::I32 => StateArray::I32(
            lines
                .map(|l| l.parse::<i32>().unwrap_or_else(|e| panic!("{path:?} '{l}': {e}")))
                .collect(),
        ),
        OutKind::F32 => StateArray::F32(
            lines
                .map(|l| l.parse::<f32>().unwrap_or_else(|e| panic!("{path:?} '{l}': {e}")))
                .collect(),
        ),
        OutKind::U64 => StateArray::U64(
            lines
                .map(|l| l.parse::<u64>().unwrap_or_else(|e| panic!("{path:?} '{l}': {e}")))
                .collect(),
        ),
    }
}

fn render(out: &StateArray) -> String {
    let mut s = String::new();
    match out {
        StateArray::I32(v) => {
            for x in v {
                let _ = writeln!(s, "{x}");
            }
        }
        StateArray::F32(v) => {
            for x in v {
                let _ = writeln!(s, "{x}");
            }
        }
        StateArray::U64(v) => {
            for x in v {
                let _ = writeln!(s, "{x}");
            }
        }
    }
    s
}

/// Dump got-vs-want to `target/golden-diff/` so CI can attach it.
fn dump_diff(fixture: &str, alg: AlgKind, label: &str, got: &StateArray, want: &StateArray) {
    let dir = diff_dir();
    let _ = std::fs::create_dir_all(&dir);
    let fname = format!("{fixture}.{}.{}.diff", alg.name(), label.replace('/', "-"));
    let mut body = format!("# {fixture} {} {label}\n# idx got want\n", alg.name());
    let (gs, ws) = (render(got), render(want));
    for (i, (g, w)) in gs.lines().zip(ws.lines()).enumerate() {
        if g != w {
            let _ = writeln!(body, "{i} {g} {w}");
        }
    }
    let _ = std::fs::write(dir.join(fname), body);
}

/// The full configuration matrix, including the placement axis
/// (DESIGN.md §9).
fn configs() -> Vec<(String, EngineConfig)> {
    let mut out = Vec::new();
    for mode in [ExecMode::Synchronous, ExecMode::Pipelined] {
        for parts in [1usize, 2, 3] {
            for strat in [Strategy::Rand, Strategy::High, Strategy::Low] {
                for placement in ALL_PLACEMENTS {
                    let shares = vec![1.0 / parts as f64; parts];
                    let cfg = EngineConfig::cpu_partitions(&shares, strat)
                        .with_mode(mode)
                        .with_seed(7)
                        .with_placement(placement);
                    out.push((
                        format!("{mode:?}/{parts}p/{}/{}", strat.name(), placement.name()),
                        cfg,
                    ));
                }
            }
        }
    }
    out
}

fn spec_for(alg: AlgKind, fx: &Fixture) -> RunSpec {
    RunSpec::new(alg).with_source(fx.source).with_rounds(PR_ROUNDS)
}

fn assert_bit_exact(
    fixture: &str,
    alg: AlgKind,
    label: &str,
    got: &StateArray,
    want: &StateArray,
) {
    let ok = match (got, want) {
        (StateArray::I32(g), StateArray::I32(w)) => g == w,
        (StateArray::U64(g), StateArray::U64(w)) => g == w,
        (StateArray::F32(g), StateArray::F32(w)) => {
            g.len() == w.len()
                && g.iter().zip(w).all(|(a, b)| a.to_bits() == b.to_bits())
        }
        _ => false,
    };
    if !ok {
        dump_diff(fixture, alg, label, got, want);
        panic!(
            "{fixture}/{}/{label}: output differs from golden (diff in {:?})",
            alg.name(),
            diff_dir()
        );
    }
}

fn assert_within_tolerance(
    fixture: &str,
    alg: AlgKind,
    label: &str,
    got: &StateArray,
    want: &StateArray,
) {
    let (g, w) = (got.as_f32(), want.as_f32());
    assert_eq!(g.len(), w.len(), "{fixture}/{}/{label}: length", alg.name());
    // f32 vs float64-reference summation slack; BC accumulates larger
    // magnitudes than PageRank, so it gets the looser relative term.
    let (abs, rel) = match alg {
        AlgKind::Pagerank | AlgKind::Ppr => (1e-5f32, 1e-4f32),
        _ => (1e-3f32, 1e-3f32),
    };
    for (i, (a, b)) in g.iter().zip(w).enumerate() {
        let tol = abs + rel * b.abs();
        if (a - b).abs() > tol {
            dump_diff(fixture, alg, label, got, want);
            panic!(
                "{fixture}/{}/{label} vertex {i}: {a} vs golden {b} (tol {tol}, diff in {:?})",
                alg.name(),
                diff_dir()
            );
        }
    }
}

/// `GOLDEN_REGEN=1`: rewrite every golden file from the host-only
/// synchronous run — the deliberate-regeneration workflow (DESIGN.md
/// "Testing"). All comparison tests no-op under regen so a stale tree
/// cannot fail mid-rewrite.
#[test]
fn golden_regenerate_if_requested() {
    if !regen() {
        return;
    }
    for fx in FIXTURES {
        let g = load_graph(fx.name);
        for alg in ALL_ALGS {
            let (r, _) = run_alg(&g, spec_for(alg, fx), &EngineConfig::host_only(1))
                .unwrap_or_else(|e| panic!("{}/{}: {e:#}", fx.name, alg.name()));
            std::fs::write(golden_path(fx.name, alg), render(&r.output)).unwrap();
        }
        eprintln!("regenerated golden outputs for {}", fx.name);
    }
}

#[test]
fn golden_bfs_cc_sssp_widest_bit_exact_across_all_configs() {
    if regen() {
        return;
    }
    for fx in FIXTURES {
        let g = load_graph(fx.name);
        for alg in [AlgKind::Bfs, AlgKind::Cc, AlgKind::Sssp, AlgKind::Widest] {
            let want = load_golden(fx.name, alg);
            for (label, cfg) in configs() {
                let (r, _) = run_alg(&g, spec_for(alg, fx), &cfg)
                    .unwrap_or_else(|e| panic!("{}/{}/{label}: {e:#}", fx.name, alg.name()));
                assert_bit_exact(fx.name, alg, &label, &r.output, &want);
            }
        }
    }
}

/// The edge-centric family (DESIGN.md §15): triangle counts, core
/// numbers, and propagation labels are integer-valued and order-free, so
/// like BFS they must be bit-exact against the goldens in **every**
/// engine configuration — executors, partition counts, strategies, and
/// placements included.
#[test]
fn golden_triangles_kcore_labelprop_bit_exact_across_all_configs() {
    if regen() {
        return;
    }
    for fx in FIXTURES {
        let g = load_graph(fx.name);
        for alg in [AlgKind::Triangles, AlgKind::Kcore, AlgKind::Labelprop] {
            let want = load_golden(fx.name, alg);
            for (label, cfg) in configs() {
                let (r, _) = run_alg(&g, spec_for(alg, fx), &cfg)
                    .unwrap_or_else(|e| panic!("{}/{}/{label}: {e:#}", fx.name, alg.name()));
                assert_bit_exact(fx.name, alg, &label, &r.output, &want);
            }
        }
    }
}

#[test]
fn golden_direction_optimized_bfs_bit_exact() {
    if regen() {
        return;
    }
    for fx in FIXTURES {
        let g = load_graph(fx.name);
        let want = load_golden(fx.name, AlgKind::Bfs);
        for (label, cfg) in configs() {
            let cfg = cfg.direction_optimized();
            let label = format!("{label}/dir");
            let (r, _) = run_alg(&g, spec_for(AlgKind::Bfs, fx), &cfg)
                .unwrap_or_else(|e| panic!("{}/bfs/{label}: {e:#}", fx.name));
            assert_bit_exact(fx.name, AlgKind::Bfs, &label, &r.output, &want);
        }
    }
}

#[test]
fn golden_pagerank_bc_ppr_tolerance_and_pipeline_bit_identity() {
    if regen() {
        return;
    }
    for fx in FIXTURES {
        let g = load_graph(fx.name);
        for alg in [AlgKind::Pagerank, AlgKind::Bc, AlgKind::Ppr] {
            let want = load_golden(fx.name, alg);
            for parts in [1usize, 2, 3] {
                for strat in [Strategy::Rand, Strategy::High, Strategy::Low] {
                    // first placement's synchronous output anchors the
                    // cross-placement bit-identity check
                    let mut anchor: Option<StateArray> = None;
                    for placement in ALL_PLACEMENTS {
                        let shares = vec![1.0 / parts as f64; parts];
                        let sync_cfg = EngineConfig::cpu_partitions(&shares, strat)
                            .with_seed(7)
                            .with_placement(placement);
                        let pipe_cfg = sync_cfg.clone().pipelined();
                        let label =
                            format!("{parts}p/{}/{}", strat.name(), placement.name());
                        let (rs, _) = run_alg(&g, spec_for(alg, fx), &sync_cfg)
                            .unwrap_or_else(|e| {
                                panic!("{}/{}/{label}: {e:#}", fx.name, alg.name())
                            });
                        let (rp, _) = run_alg(&g, spec_for(alg, fx), &pipe_cfg)
                            .unwrap_or_else(|e| {
                                panic!("{}/{}/{label}: {e:#}", fx.name, alg.name())
                            });
                        // pipelined executor contract: identical bits
                        assert_bit_exact(
                            fx.name,
                            alg,
                            &format!("{label}/sync-vs-pipe"),
                            &rp.output,
                            &rs.output,
                        );
                        // placement contract (DESIGN.md §9): identical bits
                        // across layouts at the same partitioning
                        match &anchor {
                            None => anchor = Some(rs.output.clone()),
                            Some(a) => assert_bit_exact(
                                fx.name,
                                alg,
                                &format!("{label}/placement-invariance"),
                                &rs.output,
                                a,
                            ),
                        }
                        assert_within_tolerance(fx.name, alg, &label, &rs.output, &want);
                    }
                }
            }
        }
    }
}

/// Balance-mode axis (ISSUE 6; DESIGN.md §11): every algorithm under
/// {Vertex, Edge, HubSplit} chunking at threads = 2, on both executors,
/// against the same golden files. All ten must be **bit-identical across
/// balance modes** (the modes only move chunk boundaries; eligibility for
/// the order-sensitive kernels is decided centrally, forcing their
/// canonical sequential path). The integer- and selection-valued
/// algorithms are additionally bit-exact against the goldens;
/// PageRank/BC/PPR within tolerance, anchored to the Vertex/Synchronous
/// run for the cross-mode bit check.
#[test]
fn golden_all_algs_bit_identical_across_balance_modes() {
    if regen() {
        return;
    }
    for fx in FIXTURES {
        let g = load_graph(fx.name);
        for alg in ALL_ALGS {
            let want = load_golden(fx.name, alg);
            let mut anchor: Option<StateArray> = None;
            for mode in [ExecMode::Synchronous, ExecMode::Pipelined] {
                for balance in Balance::ALL {
                    let cfg = EngineConfig::cpu_partitions(&[0.5, 0.5], Strategy::High)
                        .with_mode(mode)
                        .with_seed(7)
                        .with_balance(balance)
                        .with_threads(2);
                    let label = format!("{mode:?}/2t/{}", balance.name());
                    let (r, _) = run_alg(&g, spec_for(alg, fx), &cfg)
                        .unwrap_or_else(|e| panic!("{}/{}/{label}: {e:#}", fx.name, alg.name()));
                    match &anchor {
                        None => anchor = Some(r.output.clone()),
                        Some(a) => assert_bit_exact(
                            fx.name,
                            alg,
                            &format!("{label}/balance-invariance"),
                            &r.output,
                            a,
                        ),
                    }
                    // only the order-sensitive f32 summations get slack;
                    // every integer-valued or selection-valued algorithm
                    // is bit-exact against its golden here too
                    if matches!(alg, AlgKind::Pagerank | AlgKind::Bc | AlgKind::Ppr) {
                        assert_within_tolerance(fx.name, alg, &label, &r.output, &want);
                    } else {
                        assert_bit_exact(fx.name, alg, &label, &r.output, &want);
                    }
                }
            }
        }
    }
}

/// The committed fixtures themselves stay structurally sane.
#[test]
fn golden_fixtures_are_wellformed() {
    if regen() {
        // the regeneration test rewrites the same files concurrently
        return;
    }
    for fx in FIXTURES {
        let g = load_graph(fx.name);
        g.validate().unwrap_or_else(|e| panic!("{}: {e}", fx.name));
        assert!(g.weights.is_some(), "{}: fixtures carry weights", fx.name);
        assert!((fx.source as usize) < g.vertex_count);
        assert!(g.out_degree(fx.source) > 0, "{}: source must have out-edges", fx.name);
        for alg in ALL_ALGS {
            let want = load_golden(fx.name, alg);
            assert_eq!(
                want.len(),
                g.vertex_count,
                "{}/{}: golden length",
                fx.name,
                alg.name()
            );
        }
    }
}
