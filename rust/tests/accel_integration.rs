//! End-to-end hybrid integration: CPU partition + accelerator partition(s)
//! executing AOT JAX/Pallas programs through PJRT, checked against the
//! whole-graph baseline. Requires `make artifacts`; tests skip (with a
//! loud message) if the manifest is missing so `cargo test` stays green on
//! a fresh checkout.

use std::path::{Path, PathBuf};
use totem::alg::{bc::Bc, bfs::Bfs, cc::Cc, pagerank::Pagerank, sssp::Sssp};
use totem::baseline;
use totem::engine::{self, EngineConfig};
use totem::graph::generator::{rmat, with_random_weights, RmatParams};
use totem::graph::CsrGraph;
use totem::partition::Strategy;

fn artifacts() -> Option<PathBuf> {
    let p = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if p.join("manifest.json").exists() {
        Some(p)
    } else {
        eprintln!("SKIP: no artifacts — run `make artifacts`");
        None
    }
}

fn hybrid_cfg(accels: usize, alpha: f64, strategy: Strategy, dir: &Path) -> EngineConfig {
    EngineConfig::hybrid(accels, alpha, strategy).with_artifacts(dir)
}

#[test]
fn bfs_hybrid_matches_baseline() {
    let Some(dir) = artifacts() else { return };
    let g = CsrGraph::from_edge_list(&rmat(&RmatParams::paper(10, 5)));
    let expect = baseline::bfs(&g, 0);
    for strat in [Strategy::Rand, Strategy::High, Strategy::Low] {
        let mut alg = Bfs::new(0);
        let r = engine::run(&g, &mut alg, &hybrid_cfg(1, 0.7, strat, &dir)).unwrap();
        assert_eq!(r.output.as_i32(), expect.as_slice(), "strategy {strat:?}");
        assert!(r.metrics.accel_transfer_bytes[1] > 0, "accelerator must have run");
    }
}

#[test]
fn sssp_hybrid_matches_baseline() {
    let Some(dir) = artifacts() else { return };
    let mut el = rmat(&RmatParams::paper(10, 7));
    with_random_weights(&mut el, 64, 8);
    let g = CsrGraph::from_edge_list(&el);
    let expect = baseline::sssp(&g, 3);
    let mut alg = Sssp::new(3);
    let r = engine::run(&g, &mut alg, &hybrid_cfg(1, 0.6, Strategy::High, &dir)).unwrap();
    assert_eq!(r.output.as_f32(), expect.as_slice());
}

#[test]
fn cc_hybrid_matches_baseline() {
    let Some(dir) = artifacts() else { return };
    let g = CsrGraph::from_edge_list(&rmat(&RmatParams::paper(9, 9)));
    let expect = baseline::cc(&g);
    let mut alg = Cc::new();
    let r = engine::run(&g, &mut alg, &hybrid_cfg(1, 0.6, Strategy::Rand, &dir)).unwrap();
    assert_eq!(r.output.as_i32(), expect.as_slice());
}

#[test]
fn pagerank_hybrid_matches_baseline() {
    let Some(dir) = artifacts() else { return };
    let g = CsrGraph::from_edge_list(&rmat(&RmatParams::paper(10, 11)));
    let expect = baseline::pagerank(&g, 5);
    for strat in [Strategy::High, Strategy::Low] {
        let mut alg = Pagerank::new(5);
        let r = engine::run(&g, &mut alg, &hybrid_cfg(1, 0.7, strat, &dir)).unwrap();
        for (v, (a, b)) in r.output.as_f32().iter().zip(&expect).enumerate() {
            let tol = 1e-4 * b.abs().max(1e-6);
            assert!((a - b).abs() <= tol.max(1e-7), "{strat:?} v{v}: {a} vs {b}");
        }
    }
}

#[test]
fn bc_hybrid_matches_baseline() {
    let Some(dir) = artifacts() else { return };
    let g = CsrGraph::from_edge_list(&rmat(&RmatParams::paper(9, 13)));
    let expect = baseline::bc(&g, 1);
    let mut alg = Bc::new(1);
    let r = engine::run(&g, &mut alg, &hybrid_cfg(1, 0.6, Strategy::High, &dir)).unwrap();
    for (v, (a, b)) in r.output.as_f32().iter().zip(&expect).enumerate() {
        let tol = 1e-3 * b.abs().max(1.0);
        assert!((a - b).abs() <= tol, "v{v}: {a} vs {b}");
    }
}

#[test]
fn two_accelerators_match() {
    let Some(dir) = artifacts() else { return };
    let g = CsrGraph::from_edge_list(&rmat(&RmatParams::paper(10, 15)));
    let expect = baseline::bfs(&g, 0);
    let mut alg = Bfs::new(0);
    let r = engine::run(&g, &mut alg, &hybrid_cfg(2, 0.5, Strategy::High, &dir)).unwrap();
    assert_eq!(r.output.as_i32(), expect.as_slice());
    assert!(r.metrics.accel_transfer_bytes[1] > 0);
    assert!(r.metrics.accel_transfer_bytes[2] > 0);
}

#[test]
fn memory_budget_rejects_oversized_partition() {
    let Some(dir) = artifacts() else { return };
    let g = CsrGraph::from_edge_list(&rmat(&RmatParams::paper(10, 17)));
    let mut cfg = hybrid_cfg(1, 0.5, Strategy::High, &dir);
    cfg.accel_memory_budget = 1024; // 1KB "GPU"
    let mut alg = Bfs::new(0);
    let err = match engine::run(&g, &mut alg, &cfg) {
        Ok(_) => panic!("1KB accelerator budget must be rejected"),
        Err(e) => e,
    };
    let msg = format!("{err:#}");
    assert!(msg.contains("does not fit"), "unexpected error: {msg}");
}
