//! Direction-optimized BFS integration tests (DESIGN.md §8).
//!
//! The acceptance bar for the traversal subsystem: direction-optimized
//! runs must be **bit-identical** to push-only BFS in every configuration
//! (same levels, same superstep count), and the α/β heuristic must
//! actually choose bottom-up at least once on a seeded R-MAT scale-14
//! graph under Beamer's default knobs.

use totem::baseline;
use totem::engine::{self, Direction, DirectionConfig, EngineConfig, RebalanceConfig};
use totem::alg::bfs::Bfs;
use totem::graph::{CsrGraph, EdgeList, Workload};
use totem::harness::{build_workload, run_alg, AlgKind, RunSpec};
use totem::partition::Strategy;

/// Hub-and-spoke graph: the first direction decision sees
/// `m_f = n - 1 > m_u / α`, so the switch to pull is a deterministic
/// arithmetic fact, not a workload accident.
fn star(n: usize) -> CsrGraph {
    let mut el = EdgeList::new(n);
    for i in 1..n as u32 {
        el.push(0, i);
        el.push(i, 0);
    }
    CsrGraph::from_edge_list(&el)
}

#[test]
fn rmat14_switches_to_pull_and_stays_bit_exact() {
    let g = build_workload(Workload::Rmat(14), 42, AlgKind::Bfs);
    let spec = RunSpec::new(AlgKind::Bfs); // AUTO → the max-degree hub
    let (push, _) = run_alg(&g, spec, &EngineConfig::host_only(1)).unwrap();
    assert_eq!(push.metrics.pull_steps(), 0);

    let cfg = EngineConfig::host_only(1).direction_optimized();
    let (dir, _) = run_alg(&g, spec, &cfg).unwrap();
    assert_eq!(
        push.output.as_i32(),
        dir.output.as_i32(),
        "direction-optimized BFS must be bit-identical to push-only"
    );
    assert_eq!(push.supersteps, dir.supersteps, "superstep counts must agree");
    assert!(
        dir.metrics.pull_steps() >= 1,
        "α/β heuristic (α=15, β=18) never chose pull on R-MAT-14"
    );
    // the heuristic must also switch *back* for the sparse tail: the last
    // compute superstep (empty-frontier quiescence vote) runs push.
    let last = dir.metrics.steps.last().unwrap();
    assert!(
        last.directions.iter().all(|&d| d == Direction::Push),
        "tail superstep should have reverted to push: {:?}",
        last.directions
    );
}

#[test]
fn star_switch_is_deterministic_and_recorded() {
    let g = star(32);
    let mut alg = Bfs::new(0);
    let cfg = EngineConfig::host_only(1).direction_optimized();
    let r = engine::run(&g, &mut alg, &cfg).unwrap();
    // levels match the oracle
    assert_eq!(r.output.as_i32(), baseline::bfs(&g, 0).as_slice());
    // steps[0] is the cycle-initial sync record; steps[1] the first
    // compute superstep, where m_f = 31 > m_u / 15 forces pull.
    let first = &r.metrics.steps[1];
    assert_eq!(first.directions, vec![Direction::Pull]);
    assert_eq!(first.frontier_verts, vec![1], "frontier = the hub");
    assert_eq!(first.frontier_edges, vec![31]);
    assert_eq!(first.unexplored_edges, vec![31]);
    assert!(r.metrics.pull_steps() >= 1);
}

#[test]
fn direction_partitioned_bit_exact_across_modes_and_strategies() {
    let g = build_workload(Workload::Rmat(10), 9, AlgKind::Bfs);
    let src = 3u32;
    let expect = baseline::bfs(&g, src);
    let spec = RunSpec::new(AlgKind::Bfs).with_source(src);
    for shares in [vec![0.5, 0.5], vec![0.4, 0.3, 0.3]] {
        for strat in [Strategy::Rand, Strategy::High, Strategy::Low] {
            for pipelined in [false, true] {
                let mut cfg = EngineConfig::cpu_partitions(&shares, strat)
                    .with_seed(11)
                    .direction_optimized();
                if pipelined {
                    cfg = cfg.pipelined();
                }
                let (r, _) = run_alg(&g, spec, &cfg).unwrap();
                assert_eq!(
                    r.output.as_i32(),
                    expect.as_slice(),
                    "{strat:?} {shares:?} pipelined={pipelined}"
                );
            }
        }
    }
}

#[test]
fn forced_pull_knobs_match_oracle_on_uneven_graphs() {
    // alpha huge → pull from the first non-empty frontier; beta huge →
    // never switch back. The bottom-up kernel alone must still reproduce
    // the oracle exactly.
    let force = DirectionConfig { alpha: 1e12, beta: 1e12 };
    for (scale, seed) in [(9u32, 5u64), (10, 17)] {
        let g = build_workload(Workload::Rmat(scale), seed, AlgKind::Bfs);
        // the max-degree hub: guaranteed out-edges, so the first decision
        // point sees m_f >= 1 and must flip partition 0 (HIGH puts the
        // hub there) to pull immediately.
        let src = totem::harness::resolve_source(&g, &RunSpec::new(AlgKind::Bfs));
        let expect = baseline::bfs(&g, src);
        let cfg = EngineConfig::cpu_partitions(&[0.6, 0.4], Strategy::High)
            .with_direction(force);
        let (r, _) = run_alg(&g, RunSpec::new(AlgKind::Bfs).with_source(src), &cfg).unwrap();
        assert_eq!(r.output.as_i32(), expect.as_slice(), "scale {scale} seed {seed}");
        assert!(r.metrics.pull_steps() >= 1);
    }
}

#[test]
fn direction_composes_with_rebalance_and_pipeline() {
    // the α/β direction policy and the dynamic α controller must not
    // interfere: migrations rebuild partitions (fresh transpose caches,
    // rebuilt bitmaps) mid-run while directions keep flipping.
    let g = build_workload(Workload::Rmat(11), 3, AlgKind::Bfs);
    let src = 1u32;
    let expect = baseline::bfs(&g, src);
    let rb = RebalanceConfig {
        imbalance_threshold: 0.05,
        patience: 1,
        migration_band: 0.15,
        max_migrations: 4,
    };
    let cfg = EngineConfig::cpu_partitions(&[0.9, 0.1], Strategy::High)
        .pipelined()
        .with_rebalance(rb)
        .direction_optimized();
    let (r, _) = run_alg(&g, RunSpec::new(AlgKind::Bfs).with_source(src), &cfg).unwrap();
    assert_eq!(r.output.as_i32(), expect.as_slice());
}

#[test]
fn non_pull_algorithms_ignore_direction_config() {
    // CC never declares supports_pull: a direction-enabled run must be
    // push-only and identical to the plain run.
    let g = build_workload(Workload::Rmat(9), 13, AlgKind::Cc);
    let base = EngineConfig::cpu_partitions(&[0.5, 0.5], Strategy::Rand);
    let (r1, _) = run_alg(&g, RunSpec::new(AlgKind::Cc), &base).unwrap();
    let (r2, _) = run_alg(&g, RunSpec::new(AlgKind::Cc), &base.clone().direction_optimized())
        .unwrap();
    assert_eq!(r1.output.as_i32(), r2.output.as_i32());
    assert_eq!(r2.metrics.pull_steps(), 0, "CC must never pull");
}

#[test]
fn invalid_direction_knobs_fail_loudly() {
    let g = star(8);
    for d in [
        DirectionConfig { alpha: 0.0, beta: 18.0 },
        DirectionConfig { alpha: 15.0, beta: -3.0 },
        DirectionConfig { alpha: f64::NAN, beta: 18.0 },
    ] {
        let cfg = EngineConfig::host_only(1).with_direction(d);
        let mut alg = Bfs::new(0);
        let err = engine::run(&g, &mut alg, &cfg).map(|_| ()).unwrap_err();
        assert!(format!("{err:#}").contains("direction"), "{err:#}");
    }
}
