//! Failure injection: the engine and runtime must fail cleanly (typed
//! errors, actionable messages) rather than panic or silently corrupt,
//! for every operator mistake we could think of.

use std::path::PathBuf;
use totem::alg::program::{
    AccelSpec, CommDecl, CyclePlan, FieldId, FieldSpec, InitRow, Kernel, ProgramDriver,
    ProgramMeta, Role, VertexProgram,
};
use totem::alg::{bfs::Bfs, sssp::Sssp, INF_I32};
use totem::engine::{self, EngineConfig, RebalanceConfig};
use totem::graph::generator::{rmat, RmatParams};
use totem::graph::{io as gio, CsrGraph, EdgeList};
use totem::partition::Strategy;
use totem::runtime::{Manifest, PjrtRuntime};

fn tmpdir(name: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("totem_fail_{}_{name}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

fn small_graph() -> CsrGraph {
    CsrGraph::from_edge_list(&rmat(&RmatParams::paper(8, 1)))
}

#[test]
fn missing_artifacts_directory() {
    let g = small_graph();
    let cfg = EngineConfig::hybrid(1, 0.7, Strategy::High)
        .with_artifacts("/nonexistent/artifacts");
    let mut alg = Bfs::new(0);
    let err = engine::run(&g, &mut alg, &cfg).map(|_| ()).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("make artifacts"), "got: {msg}");
}

#[test]
fn corrupt_manifest_json() {
    let d = tmpdir("badjson");
    std::fs::write(d.join("manifest.json"), "{not json").unwrap();
    assert!(Manifest::load(&d).is_err());
}

#[test]
fn manifest_with_missing_fields() {
    let d = tmpdir("fields");
    std::fs::write(
        d.join("manifest.json"),
        r#"{"programs":[{"name":"bfs"}]}"#,
    )
    .unwrap();
    let err = Manifest::load(&d).unwrap_err();
    assert!(format!("{err:#}").contains("bfs"));
}

#[test]
fn stub_compile_failure_falls_back_to_host_wide() {
    // Everything ahead of compilation passes (manifest, size class,
    // budget, spec); the vendored xla stub then refuses the compile with
    // "PJRT backend unavailable". That exact failure is recoverable: the
    // accelerator partition runs on the HostWide tier (DESIGN.md §11) and
    // the run must succeed with baseline-correct output — this used to be
    // a dead end. Zero accelerator transfer bytes prove no device element
    // was ever bound.
    let d = tmpdir("stubhlo");
    std::fs::write(
        d.join("manifest.json"),
        r#"{"version":1,"programs":[
            {"name":"bfs","n_cap":65536,"e_cap":1048576,"file":"bfs.hlo.txt",
             "arrays":["i32"],"aux":[],"weights":false,"si32":1,"sf32":0,
             "orientation":"fwd"}]}"#,
    )
    .unwrap();
    std::fs::write(d.join("bfs.hlo.txt"), "HloModule stub_refuses_anyway").unwrap();
    let g = small_graph();
    let cfg = EngineConfig::hybrid(1, 0.7, Strategy::High).with_artifacts(&d);
    let mut alg = Bfs::new(0);
    let r = engine::run(&g, &mut alg, &cfg).expect("HostWide fallback must run");
    let mut base = Bfs::new(0);
    let b = engine::run(&g, &mut base, &EngineConfig::host_only(1)).unwrap();
    assert_eq!(r.output.as_i32(), b.output.as_i32(), "fallback output differs");
    assert!(
        r.metrics.accel_transfer_bytes.iter().all(|&b| b == 0),
        "no bytes may cross a device boundary under HostWide"
    );
}

#[test]
fn manifest_spec_mismatch_is_rejected() {
    // declare bfs with f32 state: must be rejected before any execution
    let d = tmpdir("mismatch");
    std::fs::write(
        d.join("manifest.json"),
        r#"{"version":1,"programs":[
            {"name":"bfs","n_cap":65536,"e_cap":1048576,"file":"bfs.hlo.txt",
             "arrays":["f32"],"aux":[],"weights":false,"si32":1,"sf32":0,
             "orientation":"fwd"}]}"#,
    )
    .unwrap();
    std::fs::write(d.join("bfs.hlo.txt"), "unused").unwrap();
    let g = small_graph();
    let cfg = EngineConfig::hybrid(1, 0.7, Strategy::High).with_artifacts(&d);
    let mut alg = Bfs::new(0);
    let err = engine::run(&g, &mut alg, &cfg).map(|_| ()).unwrap_err();
    assert!(format!("{err:#}").contains("dtype mismatch"), "{err:#}");
}

#[test]
fn no_fitting_size_class() {
    let d = tmpdir("tiny");
    std::fs::write(
        d.join("manifest.json"),
        r#"{"version":1,"programs":[
            {"name":"bfs","n_cap":16,"e_cap":16,"file":"bfs.hlo.txt",
             "arrays":["i32"],"aux":[],"weights":false,"si32":1,"sf32":0,
             "orientation":"fwd"}]}"#,
    )
    .unwrap();
    let g = small_graph();
    let cfg = EngineConfig::hybrid(1, 0.5, Strategy::High).with_artifacts(&d);
    let mut alg = Bfs::new(0);
    let err = engine::run(&g, &mut alg, &cfg).map(|_| ()).unwrap_err();
    assert!(format!("{err:#}").contains("size class"), "{err:#}");
}

#[test]
fn weighted_algorithm_on_unweighted_graph() {
    let g = small_graph(); // no weights
    let mut alg = Sssp::new(0);
    let err = engine::run(&g, &mut alg, &EngineConfig::host_only(1))
        .map(|_| ())
        .unwrap_err();
    assert!(format!("{err:#}").contains("weights"));
}

#[test]
fn runtime_rejects_unknown_program() {
    let d = tmpdir("unknown");
    std::fs::write(d.join("manifest.json"), r#"{"version":1,"programs":[]}"#).unwrap();
    let rt = PjrtRuntime::new(&d);
    // empty manifest loads fine; selection must fail with the program name
    let rt = rt.unwrap();
    let err = rt.manifest().select("nope", 10, 10, u64::MAX).unwrap_err();
    assert!(format!("{err:#}").contains("nope"));
}

#[test]
fn graph_io_rejects_out_of_range_vertices() {
    let d = tmpdir("io");
    let p = d.join("bad.el");
    std::fs::write(&p, "p 2 1\n0 5\n").unwrap();
    assert!(gio::read_edge_list(&p).is_err());
}

#[test]
fn engine_source_out_of_partition_is_fine() {
    // a source vertex with zero degree: run must terminate immediately
    let g = small_graph();
    let isolated = (0..g.vertex_count as u32)
        .find(|&v| g.out_degree(v) == 0)
        .unwrap_or(0);
    let mut alg = Bfs::new(isolated);
    let r = engine::run(&g, &mut alg, &EngineConfig::host_only(1)).unwrap();
    assert_eq!(r.output.as_i32()[isolated as usize], 0);
}

#[test]
fn zero_share_partition_is_empty_but_valid() {
    let g = small_graph();
    let cfg = EngineConfig::cpu_partitions(&[1.0, 0.0], Strategy::Rand);
    let mut alg = Bfs::new(0);
    let r = engine::run(&g, &mut alg, &cfg).unwrap();
    assert_eq!(r.output.as_i32().len(), g.vertex_count);
}

#[test]
fn rebalance_rejects_nonpositive_threshold() {
    let g = small_graph();
    for thr in [0.0, -0.5] {
        let cfg = EngineConfig::cpu_partitions(&[0.5, 0.5], Strategy::Rand).with_rebalance(
            RebalanceConfig { imbalance_threshold: thr, ..RebalanceConfig::default() },
        );
        let mut alg = Bfs::new(0);
        let err = engine::run(&g, &mut alg, &cfg).map(|_| ()).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("imbalance_threshold"), "thr={thr}: {msg}");
    }
}

#[test]
fn rebalance_rejects_single_partition_run() {
    let g = small_graph();
    let cfg = EngineConfig::host_only(1).with_rebalance(RebalanceConfig::default());
    let mut alg = Bfs::new(0);
    let err = engine::run(&g, &mut alg, &cfg).map(|_| ()).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("2 partitions"), "{msg}");
}

#[test]
fn rebalance_rejects_bad_patience_and_band() {
    let g = small_graph();
    let base = RebalanceConfig::default();
    let cases = [
        RebalanceConfig { patience: 0, ..base },
        RebalanceConfig { migration_band: 0.0, ..base },
        RebalanceConfig { migration_band: 1.0, ..base },
        RebalanceConfig { imbalance_threshold: 2.0, ..base },
    ];
    for rb in cases {
        let cfg = EngineConfig::cpu_partitions(&[0.5, 0.5], Strategy::Rand).with_rebalance(rb);
        let mut alg = Bfs::new(0);
        assert!(
            engine::run(&g, &mut alg, &cfg).map(|_| ()).is_err(),
            "accepted invalid {rb:?}"
        );
    }
}

#[test]
fn pipelined_with_zero_boundary_edges_is_clean() {
    // edgeless graph: partitions exist but no ghost tables at all — the
    // pipelined scheduler must terminate without exchanges, not panic.
    let g = CsrGraph::from_edge_list(&EdgeList::new(64));
    let cfg = EngineConfig::cpu_partitions(&[0.5, 0.5], Strategy::Rand).pipelined();
    let mut alg = Bfs::new(0);
    let r = engine::run(&g, &mut alg, &cfg).unwrap();
    assert_eq!(r.output.as_i32()[0], 0);
    assert_eq!(r.metrics.total_messages(), 0);
    assert_eq!(r.metrics.overlap_factor(), 0.0);
}

/// A configurable mis-declared vertex program: each knob injects one
/// schema/plan mistake that used to surface as a `panic!("expected i32
/// array")` deep inside a kernel or the comm phase — and must now be a
/// typed `anyhow` error at driver-construction time, before any state is
/// built (ISSUE 5 satellite).
struct Misdeclared {
    /// dist pad that is not the push-min identity
    bad_pad: bool,
    /// put the channel on the aux field instead of the state field
    comm_on_aux: bool,
    /// point the kernel's shadow at an f32 field while value is i32
    shadow_dtype_clash: bool,
    /// point the kernel's shadow at the value field itself
    shadow_is_value: bool,
    /// output field index past the schema
    output_out_of_range: bool,
}

impl Misdeclared {
    fn ok() -> Misdeclared {
        Misdeclared {
            bad_pad: false,
            comm_on_aux: false,
            shadow_dtype_clash: false,
            shadow_is_value: false,
            output_out_of_range: false,
        }
    }
}

const MD_VAL: FieldId = FieldId(0);
const MD_SHADOW_I32: FieldId = FieldId(1);
const MD_SHADOW_F32: FieldId = FieldId(2);
const MD_AUX: FieldId = FieldId(3);

impl VertexProgram for Misdeclared {
    fn meta(&self) -> ProgramMeta {
        ProgramMeta {
            name: "misdeclared",
            needs_weights: false,
            undirected: false,
            reversed: false,
            fixed_rounds: None,
            output: if self.output_out_of_range { FieldId(99) } else { MD_VAL },
        }
    }
    fn schema(&self) -> Vec<FieldSpec> {
        vec![
            FieldSpec::i32("val", Role::Device, if self.bad_pad { 0 } else { INF_I32 }),
            FieldSpec::i32("shadow", Role::Host, INF_I32),
            FieldSpec::f32("shadow_f32", Role::Host, 0.0),
            FieldSpec::f32("aux", Role::Aux, 0.0),
        ]
    }
    fn plan(&self, _cycle: usize) -> CyclePlan {
        CyclePlan {
            kernel: Kernel::MonotoneScatter {
                value: MD_VAL,
                shadow: if self.shadow_is_value {
                    MD_VAL
                } else if self.shadow_dtype_clash {
                    MD_SHADOW_F32
                } else {
                    MD_SHADOW_I32
                },
            },
            comm: vec![if self.comm_on_aux {
                CommDecl::PushMin(MD_AUX)
            } else {
                CommDecl::PushMin(MD_VAL)
            }],
            device: None,
            accel: AccelSpec { name: "misdeclared", n_si32: 0, n_sf32: 0 },
        }
    }
    fn init_vertex(&self, _g: u32, _row: &mut InitRow<'_>) {}
}

#[test]
fn well_formed_program_constructs() {
    assert!(ProgramDriver::build(Misdeclared::ok()).is_ok());
}

#[test]
fn schema_pad_not_reduce_identity_is_typed_error() {
    let err = ProgramDriver::build(Misdeclared { bad_pad: true, ..Misdeclared::ok() })
        .map(|_| ())
        .unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("reduce identity"), "{msg}");
    assert!(msg.contains("'val'"), "{msg}");
}

#[test]
fn channel_on_aux_field_is_typed_error() {
    let err = ProgramDriver::build(Misdeclared { comm_on_aux: true, ..Misdeclared::ok() })
        .map(|_| ())
        .unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("aux"), "{msg}");
    assert!(msg.contains("misdeclared"), "{msg}");
}

#[test]
fn kernel_field_dtype_clash_is_typed_error() {
    let err = ProgramDriver::build(Misdeclared {
        shadow_dtype_clash: true,
        ..Misdeclared::ok()
    })
    .map(|_| ())
    .unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("dtype") || msg.contains("share a dtype"), "{msg}");
}

#[test]
fn shadow_aliasing_value_is_typed_error() {
    // would otherwise pass dtype checks and panic inside the kernel's
    // split-borrow on the first superstep
    let err = ProgramDriver::build(Misdeclared { shadow_is_value: true, ..Misdeclared::ok() })
        .map(|_| ())
        .unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("distinct"), "{msg}");
}

#[test]
fn output_field_out_of_range_is_typed_error() {
    let err = ProgramDriver::build(Misdeclared {
        output_out_of_range: true,
        ..Misdeclared::ok()
    })
    .map(|_| ())
    .unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("4 fields"), "{msg}");
}

#[test]
fn rebalance_with_zero_boundary_edges_is_clean() {
    // migrations on a disconnected graph must not corrupt anything; the
    // run completes with every vertex keeping its own component label.
    let g = CsrGraph::from_edge_list(&EdgeList::new(64));
    let rb = RebalanceConfig {
        imbalance_threshold: 0.01,
        patience: 1,
        migration_band: 0.2,
        max_migrations: 3,
    };
    let cfg = EngineConfig::cpu_partitions(&[0.9, 0.1], Strategy::Rand)
        .pipelined()
        .with_rebalance(rb);
    let mut alg = Bfs::new(5);
    let r = engine::run(&g, &mut alg, &cfg).unwrap();
    assert_eq!(r.output.as_i32()[5], 0);
    assert_eq!(r.output.as_i32().iter().filter(|&&l| l == 0).count(), 1);
}
