//! Concurrency suite for the query-serving layer (ISSUE 8; DESIGN.md §13).
//!
//! Four contracts, each load-bearing for the serving design:
//!
//! 1. **Shared-graph fidelity** — N threads submitting mixed
//!    BFS/SSSP/PageRank queries against one server get answers
//!    bit-identical to solo `engine::run` executions of the same
//!    algorithm on the same graph. Concurrent `run_shared` calls on the
//!    persistent worker pool never bleed state across runs.
//! 2. **Typed saturation** — a stampede of submitters against a tiny
//!    admission limit admits exactly `limit` queries and rejects the rest
//!    with [`AdmissionError::Saturated`], never a panic or silent queue.
//! 3. **64-lane bit identity** — a full-width multi-source BFS matches 64
//!    sequential single-source runs lane-for-lane exactly, and the same
//!    batch stays identical under the pipelined executor.
//! 4. **Batch-width fuzz** — a seeded sweep samples batch widths, source
//!    multisets (repeats included) and engine configurations, checking
//!    every lane against its solo run. Failures carry the sweep seed:
//!    `SERVE_FUZZ_SEED=<seed> cargo test --test serve_concurrency`.

use std::sync::Arc;
use totem::alg::bfs::Bfs;
use totem::alg::msbfs::MsBfs;
use totem::alg::pagerank::Pagerank;
use totem::alg::sssp::Sssp;
use totem::alg::INF_I32;
use totem::engine::{self, EngineConfig, ExecMode};
use totem::graph::generator::{rmat, with_random_weights, RmatParams};
use totem::graph::CsrGraph;
use totem::partition::{Strategy, ALL_PLACEMENTS};
use totem::serve::{AdmissionError, QueryKind, QueryResponse, Server, ServerConfig};
use totem::util::rng::Rng;

fn weighted_rmat(scale: u32, seed: u64) -> CsrGraph {
    let mut el = rmat(&RmatParams::paper(scale, seed));
    with_random_weights(&mut el, 64, seed ^ 0xabcd);
    CsrGraph::from_edge_list(&el)
}

fn env_u64(name: &str, default: u64) -> u64 {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

/// Contract 1: concurrent mixed queries match solo engine runs exactly.
#[test]
fn concurrent_mixed_queries_match_solo_runs_bit_for_bit() {
    let g = weighted_rmat(8, 21);
    let cfg = EngineConfig::host_only(2);

    // Solo expectations, computed up front on the main thread.
    let sources: Vec<u32> = (0..8).map(|i| i * 17 % g.vertex_count as u32).collect();
    let bfs_want: Vec<Vec<i32>> = sources
        .iter()
        .map(|&s| engine::run(&g, &mut Bfs::new(s), &cfg).unwrap().output.as_i32().to_vec())
        .collect();
    let sssp_want: Vec<Vec<f32>> = sources
        .iter()
        .map(|&s| engine::run(&g, &mut Sssp::new(s), &cfg).unwrap().output.as_f32().to_vec())
        .collect();
    let pr_want = engine::run(&g, &mut Pagerank::new(5), &cfg).unwrap().output.as_f32().to_vec();

    let srv = Server::start(
        g.clone(),
        ServerConfig { workers: 4, max_in_flight: 256, ..ServerConfig::new(cfg.clone()) },
    )
    .unwrap();

    std::thread::scope(|scope| {
        for (t, &src) in sources.iter().enumerate() {
            let (srv, bfs_want, sssp_want, pr_want) = (&srv, &bfs_want, &sssp_want, &pr_want);
            scope.spawn(move || {
                for round in 0..3 {
                    let a = srv.submit(QueryKind::Bfs { source: src }).unwrap().wait().unwrap();
                    match a.response {
                        QueryResponse::Levels(got) => {
                            assert_eq!(
                                got.as_slice(),
                                bfs_want[t].as_slice(),
                                "bfs {src} diverged (thread {t}, round {round})"
                            );
                        }
                        other => panic!("bfs answered with {other:?}"),
                    }
                    let a = srv.submit(QueryKind::Sssp { source: src }).unwrap().wait().unwrap();
                    match a.response {
                        QueryResponse::Distances(got) => {
                            assert_eq!(
                                got, sssp_want[t],
                                "sssp {src} diverged (thread {t}, round {round})"
                            );
                        }
                        other => panic!("sssp answered with {other:?}"),
                    }
                    let a = srv.submit(QueryKind::Pagerank).unwrap().wait().unwrap();
                    match a.response {
                        QueryResponse::Ranks(got) => {
                            assert_eq!(
                                got.as_slice(),
                                pr_want.as_slice(),
                                "pagerank diverged (thread {t}, round {round})"
                            );
                        }
                        other => panic!("pagerank answered with {other:?}"),
                    }
                }
            });
        }
    });
    let report = srv.shutdown();
    assert_eq!(report.served, 8 * 3 * 3);
    assert_eq!(report.rejected, 0, "limit 256 never saturates here");
}

/// Contract 2: a submitter stampede against a tiny limit yields exactly
/// `limit` admissions and typed rejections for the rest. No workers, so
/// admitted queries hold their slots for the whole test — deterministic.
#[test]
fn submitter_stampede_saturates_typed() {
    let g = weighted_rmat(6, 5);
    let limit = 3;
    let srv = Server::start(
        g,
        ServerConfig {
            workers: 0,
            max_in_flight: limit,
            ..ServerConfig::new(EngineConfig::host_only(1))
        },
    )
    .unwrap();
    let admitted = std::sync::atomic::AtomicUsize::new(0);
    let rejected = std::sync::atomic::AtomicUsize::new(0);
    std::thread::scope(|scope| {
        for t in 0..16 {
            let (srv, admitted, rejected) = (&srv, &admitted, &rejected);
            scope.spawn(move || match srv.submit(QueryKind::Bfs { source: t }) {
                Ok(_ticket) => {
                    // the slot is held by the queued query (no workers to
                    // drain it), not by the ticket
                    admitted.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                }
                Err(e) => {
                    assert!(matches!(e, AdmissionError::Saturated { .. }));
                    rejected.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                }
            });
        }
    });
    assert_eq!(admitted.load(std::sync::atomic::Ordering::Relaxed), limit);
    assert_eq!(rejected.load(std::sync::atomic::Ordering::Relaxed), 16 - limit);
    assert_eq!(srv.in_flight(), limit);
    let report = srv.shutdown();
    assert_eq!(report.rejected, (16 - limit) as u64);
}

/// Contract 3: full-width (64-lane) MS-BFS equals 64 sequential solo runs
/// lane-for-lane, under both executors.
#[test]
fn sixty_four_lanes_match_sixty_four_solo_runs() {
    let g = CsrGraph::from_edge_list(&rmat(&RmatParams::paper(8, 2)));
    let sources: Vec<u32> = (0..64).map(|i| (i * 37 + 5) % g.vertex_count as u32).collect();
    let solo: Vec<Vec<i32>> = sources
        .iter()
        .map(|&s| {
            engine::run(&g, &mut Bfs::new(s), &EngineConfig::host_only(1))
                .unwrap()
                .output
                .as_i32()
                .to_vec()
        })
        .collect();
    for cfg in [
        EngineConfig::host_only(2),
        EngineConfig::cpu_partitions(&[0.4, 0.6], Strategy::High).with_mode(ExecMode::Pipelined),
    ] {
        let mut alg = MsBfs::new(&sources).unwrap();
        let r = engine::run(&g, &mut alg, &cfg).unwrap();
        assert_eq!(r.extra.len(), 64);
        for (b, want) in solo.iter().enumerate() {
            assert_eq!(
                r.extra[b].as_i32(),
                want.as_slice(),
                "lane {b} (source {}) diverged under {:?}",
                sources[b],
                cfg.mode
            );
        }
        // seen masks agree with the lanes they summarize
        let seen = r.output.as_u64();
        for v in 0..g.vertex_count {
            for b in 0..64 {
                assert_eq!(
                    (seen[v] >> b) & 1 == 1,
                    solo[b][v] != INF_I32,
                    "seen bit {b} of vertex {v} contradicts its lane"
                );
            }
        }
    }
}

/// Contract 3 through the server: 64 distinct sources submitted at once
/// all come back equal to their solo runs, however the batcher slices
/// them.
#[test]
fn server_answers_a_full_width_burst_correctly() {
    let g = CsrGraph::from_edge_list(&rmat(&RmatParams::paper(7, 13)));
    let cfg = EngineConfig::host_only(2);
    let sources: Vec<u32> = (0..64).map(|i| (i * 29 + 1) % g.vertex_count as u32).collect();
    let srv = Server::start(
        g.clone(),
        ServerConfig { workers: 1, max_in_flight: 128, ..ServerConfig::new(cfg.clone()) },
    )
    .unwrap();
    let tickets: Vec<_> =
        sources.iter().map(|&s| srv.submit(QueryKind::Bfs { source: s }).unwrap()).collect();
    for (b, t) in tickets.into_iter().enumerate() {
        let want = engine::run(&g, &mut Bfs::new(sources[b]), &cfg).unwrap();
        match t.wait().unwrap().response {
            QueryResponse::Levels(got) => {
                assert_eq!(got.as_slice(), want.output.as_i32(), "source {} diverged", sources[b])
            }
            other => panic!("bfs answered with {other:?}"),
        }
    }
    srv.shutdown();
}

/// Contract 4: seeded fuzz over batch widths, source multisets, and
/// engine configurations.
#[test]
fn fuzz_batch_widths_against_solo_runs() {
    let seed = env_u64("SERVE_FUZZ_SEED", 0x5E21);
    let iters = env_u64("SERVE_FUZZ_ITERS", 12) as usize;
    let mut rng = Rng::new(seed);
    let pool: Vec<CsrGraph> = vec![
        CsrGraph::from_edge_list(&rmat(&RmatParams::paper(7, 3))),
        CsrGraph::from_edge_list(&rmat(&RmatParams::paper(6, 8))),
    ];
    for iter in 0..iters {
        let g = &pool[rng.below(pool.len() as u64) as usize];
        let width = 1 + rng.below(64) as usize;
        // repeats allowed: duplicate sources must still fill their own
        // lanes with identical answers
        let sources: Vec<u32> =
            (0..width).map(|_| rng.below(g.vertex_count as u64) as u32).collect();
        let parts = 1 + rng.below(3) as usize;
        let mut shares: Vec<f64> = (0..parts).map(|_| 0.2 + rng.next_f64()).collect();
        let total: f64 = shares.iter().sum();
        shares.iter_mut().for_each(|s| *s /= total);
        let mode = if rng.below(2) == 0 { ExecMode::Synchronous } else { ExecMode::Pipelined };
        let strategy = [Strategy::Rand, Strategy::High, Strategy::Low][rng.below(3) as usize];
        let placement = ALL_PLACEMENTS[rng.below(ALL_PLACEMENTS.len() as u64) as usize];
        let cfg = EngineConfig::cpu_partitions(&shares, strategy)
            .with_mode(mode)
            .with_placement(placement)
            .with_threads(1 + rng.below(3) as usize)
            .with_seed(rng.below(1 << 20));
        let label = format!(
            "iter={iter}/{iters} seed={seed:#x} width={width} parts={parts} mode={mode:?} \
             strategy={} placement={} sources={sources:?}",
            strategy.name(),
            placement.name()
        );
        let mut alg = MsBfs::new(&sources).unwrap();
        let r = engine::run(g, &mut alg, &cfg)
            .unwrap_or_else(|e| panic!("engine failed [{label}]: {e:#}"));
        for (b, &s) in sources.iter().enumerate() {
            let want = engine::run(g, &mut Bfs::new(s), &EngineConfig::host_only(1)).unwrap();
            assert_eq!(
                r.extra[b].as_i32(),
                want.output.as_i32(),
                "lane {b} diverged [{label}]"
            );
        }
    }
}

/// The cache answers across submitter threads: after one thread computes
/// a source, other threads' identical queries hit without recompute.
#[test]
fn cache_hits_are_shared_across_threads() {
    let g = weighted_rmat(7, 31);
    let srv = Arc::new(
        Server::start(
            g,
            ServerConfig {
                workers: 2,
                max_in_flight: 64,
                ..ServerConfig::new(EngineConfig::host_only(2))
            },
        )
        .unwrap(),
    );
    // warm one source
    let warm = srv.submit(QueryKind::Bfs { source: 9 }).unwrap().wait().unwrap();
    assert!(!warm.metrics.cache_hit);
    std::thread::scope(|scope| {
        for _ in 0..4 {
            let srv = Arc::clone(&srv);
            scope.spawn(move || {
                let a = srv.submit(QueryKind::Reach { source: 9 }).unwrap().wait().unwrap();
                assert!(a.metrics.cache_hit, "warmed source must hit from every thread");
            });
        }
    });
    let report = Arc::into_inner(srv).unwrap().shutdown();
    assert_eq!(report.cache_hits, 4);
}
