//! Property-based tests over the engine's core invariants, driven by the
//! repo's deterministic PRNG (no external proptest in the offline set —
//! randomized trials with printed seeds serve the same role: any failure
//! message pins the exact reproduction).

use totem::alg::{bfs::Bfs, cc::Cc, sssp::Sssp};
use totem::baseline;
use totem::engine::{self, EngineConfig, StateArray};
use totem::graph::generator::{rmat, uniform, with_random_weights, RmatParams};
use totem::graph::CsrGraph;
use totem::harness::{run_alg, AlgKind, RunSpec, ALL_ALGS};
use totem::partition::{assign, PartitionedGraph, Strategy};
use totem::util::rng::Rng;

fn random_graph(rng: &mut Rng, weighted: bool) -> CsrGraph {
    let scale = 6 + (rng.below(4) as u32); // 64..512 vertices
    let mut el = if rng.below(2) == 0 {
        rmat(&RmatParams::paper(scale, rng.next_u64()))
    } else {
        uniform(scale, 4 + rng.below(12) as u32, rng.next_u64())
    };
    if weighted {
        with_random_weights(&mut el, 32, rng.next_u64());
    }
    CsrGraph::from_edge_list(&el)
}

fn random_shares(rng: &mut Rng) -> Vec<f64> {
    let parts = 2 + rng.below(2) as usize; // 2 or 3
    let mut shares: Vec<f64> = (0..parts).map(|_| 0.1 + rng.next_f64()).collect();
    let total: f64 = shares.iter().sum();
    shares.iter_mut().for_each(|x| *x /= total);
    shares
}

fn random_strategy(rng: &mut Rng) -> Strategy {
    match rng.below(3) {
        0 => Strategy::Rand,
        1 => Strategy::High,
        _ => Strategy::Low,
    }
}

/// Partitioning must preserve the edge multiset for any assignment.
#[test]
fn prop_partition_preserves_edges() {
    let mut rng = Rng::new(0xDEC0DE);
    for trial in 0..25 {
        let g = random_graph(&mut rng, false);
        let shares = random_shares(&mut rng);
        let strat = random_strategy(&mut rng);
        let seed = rng.next_u64();
        let pg = PartitionedGraph::partition(&g, strat, &shares, seed);
        let mut total_edges = 0usize;
        let mut total_vertices = 0usize;
        for p in &pg.parts {
            total_edges += p.edge_count();
            total_vertices += p.nv;
            // every ghost table is sorted and in-range
            for t in &p.ghosts {
                assert!(t.remote_locals.windows(2).all(|w| w[0] < w[1]), "trial {trial}");
                let rp = &pg.parts[t.remote_part];
                assert!(t.remote_locals.iter().all(|&l| (l as usize) < rp.nv));
            }
        }
        assert_eq!(total_edges, g.edge_count(), "trial {trial}");
        assert_eq!(total_vertices, g.vertex_count, "trial {trial}");
        // β invariants: reduction can only shrink the message count
        let b = pg.beta_stats();
        assert!(b.reduced_messages <= b.boundary_edges, "trial {trial}");
        assert!(b.beta_raw() <= 1.0);
    }
}

/// Greedy assignment hits requested shares within one max-degree slack.
#[test]
fn prop_assignment_share_accuracy() {
    let mut rng = Rng::new(0xA55E55);
    for trial in 0..25 {
        let g = random_graph(&mut rng, false);
        let shares = random_shares(&mut rng);
        let strat = random_strategy(&mut rng);
        let a = assign(&g, strat, &shares, rng.next_u64());
        let max_deg = (0..g.vertex_count as u32).map(|v| g.out_degree(v)).max().unwrap_or(0);
        let mut edges = vec![0u64; shares.len()];
        for v in 0..g.vertex_count {
            edges[a[v] as usize] += g.out_degree(v as u32);
        }
        // cumulative prefix property: partition k's cumulative edges is
        // within max_deg of the cumulative target
        let mut cum = 0f64;
        let mut cum_t = 0f64;
        for (k, &e) in edges.iter().enumerate().take(shares.len() - 1) {
            cum += e as f64;
            cum_t += shares[k] * g.edge_count() as f64;
            assert!(
                (cum - cum_t).abs() <= max_deg as f64 + 1.0,
                "trial {trial} part {k}: cum {cum} target {cum_t} maxdeg {max_deg}"
            );
        }
    }
}

/// BFS levels from the hybrid engine must equal the sequential oracle for
/// any graph × partitioning × source.
#[test]
fn prop_bfs_equivalence() {
    let mut rng = Rng::new(0xBF5);
    for trial in 0..15 {
        let g = random_graph(&mut rng, false);
        let src = rng.below(g.vertex_count as u64) as u32;
        let expect = baseline::bfs(&g, src);
        let shares = random_shares(&mut rng);
        let cfg = EngineConfig::cpu_partitions(&shares, random_strategy(&mut rng))
            .with_seed(rng.next_u64());
        let mut alg = Bfs::new(src);
        let r = engine::run(&g, &mut alg, &cfg).unwrap();
        assert_eq!(r.output.as_i32(), expect.as_slice(), "trial {trial} src {src}");
    }
}

/// SSSP distances are exact (min-reduction is order independent).
#[test]
fn prop_sssp_equivalence() {
    let mut rng = Rng::new(0x555);
    for trial in 0..12 {
        let g = random_graph(&mut rng, true);
        let src = rng.below(g.vertex_count as u64) as u32;
        let expect = baseline::sssp(&g, src);
        let shares = random_shares(&mut rng);
        let cfg = EngineConfig::cpu_partitions(&shares, random_strategy(&mut rng))
            .with_seed(rng.next_u64());
        let mut alg = Sssp::new(src);
        let r = engine::run(&g, &mut alg, &cfg).unwrap();
        assert_eq!(r.output.as_f32(), expect.as_slice(), "trial {trial} src {src}");
    }
}

/// CC labels are the component-minimum global id everywhere.
#[test]
fn prop_cc_labels_are_component_minima() {
    let mut rng = Rng::new(0xCC);
    for trial in 0..12 {
        let g = random_graph(&mut rng, false);
        let expect = baseline::cc(&g);
        let shares = random_shares(&mut rng);
        let cfg = EngineConfig::cpu_partitions(&shares, random_strategy(&mut rng))
            .with_seed(rng.next_u64());
        let mut alg = Cc::new();
        let r = engine::run(&g, &mut alg, &cfg).unwrap();
        let got = r.output.as_i32();
        assert_eq!(got, expect.as_slice(), "trial {trial}");
        // label invariant: each vertex's label equals the min vertex id
        // reachable in its undirected component — check label ≤ own id
        for (v, &l) in got.iter().enumerate() {
            assert!(l <= v as i32, "trial {trial} vertex {v}");
        }
    }
}

/// f32 results are compared on bit patterns: tolerance-free equality is
/// the pipelined executor's contract (DESIGN.md §4.2).
fn assert_bit_identical(a: &StateArray, b: &StateArray, ctx: &str) {
    match (a, b) {
        (StateArray::I32(x), StateArray::I32(y)) => assert_eq!(x, y, "{ctx}"),
        (StateArray::F32(x), StateArray::F32(y)) => {
            assert_eq!(x.len(), y.len(), "{ctx}: length");
            for (i, (p, q)) in x.iter().zip(y).enumerate() {
                assert_eq!(p.to_bits(), q.to_bits(), "{ctx} vertex {i}: {p} vs {q}");
            }
        }
        _ => panic!("{ctx}: output dtype mismatch"),
    }
}

/// The pipelined executor must produce bit-identical outputs — and the
/// same superstep count — as the synchronous executor for every
/// algorithm, across random graphs (R-MAT and uniform), seeds, partition
/// counts, and partition strategies.
#[test]
fn prop_pipelined_bit_identical_to_synchronous() {
    let mut rng = Rng::new(0x0E1A);
    for trial in 0..6 {
        let g = random_graph(&mut rng, true); // weighted so SSSP runs too
        let shares = random_shares(&mut rng);
        let strat = random_strategy(&mut rng);
        let seed = rng.next_u64();
        let src = rng.below(g.vertex_count as u64) as u32;
        for alg in ALL_ALGS {
            let spec = RunSpec::new(alg).with_source(src).with_rounds(4);
            let sync_cfg = EngineConfig::cpu_partitions(&shares, strat).with_seed(seed);
            let pipe_cfg = sync_cfg.clone().pipelined();
            let (rs, _) = run_alg(&g, spec, &sync_cfg).unwrap();
            let (rp, _) = run_alg(&g, spec, &pipe_cfg).unwrap();
            let ctx = format!("trial {trial} alg {} src {src}", alg.name());
            assert_bit_identical(&rs.output, &rp.output, &ctx);
            assert_eq!(rs.supersteps, rp.supersteps, "{ctx}: superstep count");
            // overlap accounting invariants
            for (k, s) in rp.metrics.steps.iter().enumerate() {
                assert!(
                    s.comm_overlapped <= s.comm + 1e-12,
                    "{ctx}: step {k} overlapped {} > comm {}",
                    s.comm_overlapped,
                    s.comm
                );
            }
            let of = rp.metrics.overlap_factor();
            assert!((0.0..=1.0).contains(&of), "{ctx}: overlap factor {of}");
        }
    }
}

/// Single-partition runs must be pipelined-safe (no exchanges at all) and
/// equal to the sequential oracle.
#[test]
fn prop_pipelined_single_partition_and_threads() {
    let mut rng = Rng::new(0x51A61E);
    for _ in 0..6 {
        let g = random_graph(&mut rng, false);
        let src = rng.below(g.vertex_count as u64) as u32;
        let expect = baseline::bfs(&g, src);
        for threads in [1usize, 3] {
            let cfg = EngineConfig::host_only(threads).pipelined();
            let mut alg = Bfs::new(src);
            let r = engine::run(&g, &mut alg, &cfg).unwrap();
            assert_eq!(r.output.as_i32(), expect.as_slice(), "threads {threads}");
            assert_eq!(r.metrics.overlap_factor(), 0.0, "nothing to overlap");
        }
    }
}

/// The makespan decomposition must be internally consistent for any run.
#[test]
fn prop_metrics_consistency() {
    let mut rng = Rng::new(0x3E7);
    for _ in 0..10 {
        let g = random_graph(&mut rng, false);
        let shares = random_shares(&mut rng);
        let cfg = EngineConfig::cpu_partitions(&shares, random_strategy(&mut rng));
        let mut alg = Bfs::new(0);
        let r = engine::run(&g, &mut alg, &cfg).unwrap();
        let m = &r.metrics;
        let makespan = m.makespan_secs();
        assert!(makespan >= m.bottleneck_compute_secs());
        assert!((m.bottleneck_compute_secs() + m.comm_secs() - makespan).abs() < 1e-9);
        let per_part_max: f64 = (0..shares.len())
            .map(|p| m.partition_compute_secs(p))
            .fold(0.0, f64::max);
        assert!(m.bottleneck_compute_secs() >= per_part_max / m.supersteps().max(1) as f64);
    }
}
