//! Out-of-core ingest conformance (ISSUE 7 / DESIGN.md §12): the `.tcsr`
//! v2 container round-trips bit-exactly, every single-byte corruption and
//! every truncation is detected, mmap and buffered loads agree on the
//! golden fixtures, the spill-run streaming build is byte-identical to
//! the in-memory build, and a BFS driven through an mmap-backed graph
//! matches the in-memory run exactly.

use totem::engine::{EngineConfig, StateArray};
use totem::graph::generator::{self, RmatParams};
use totem::graph::ingest::{self, SpillBuild};
use totem::graph::store::{self, GraphStore, LoadMode};
use totem::graph::{io as gio, CsrGraph, EdgeList, Workload};
use totem::harness::{build_workload, run_alg, AlgKind, RunSpec};
use std::path::{Path, PathBuf};

const GOLDEN: [&str; 4] = ["chain8", "star8", "twocomm16", "rmat64"];

fn golden_dir() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("rust/tests/golden")
}

fn tmp(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join("totem_ingest_ooc");
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(format!("{}_{}", std::process::id(), name))
}

fn assert_graphs_identical(a: &CsrGraph, b: &CsrGraph, what: &str) {
    assert_eq!(a.vertex_count, b.vertex_count, "{what}: vertex_count");
    assert_eq!(a.row_offsets, b.row_offsets, "{what}: row_offsets");
    assert_eq!(a.col_indices, b.col_indices, "{what}: col_indices");
    assert_eq!(a.weights, b.weights, "{what}: weights");
}

fn sample_graph(weighted: bool) -> CsrGraph {
    let mut el = generator::rmat(&RmatParams::paper(7, 13));
    if weighted {
        generator::with_random_weights(&mut el, 16, 99);
    }
    CsrGraph::from_edge_list(&el)
}

// -- round trip -------------------------------------------------------------

#[test]
fn v2_roundtrip_is_bit_exact_both_modes() {
    for weighted in [false, true] {
        let g = sample_graph(weighted);
        let path = tmp(&format!("rt_{weighted}.tcsr"));
        let bytes = store::write_csr_v2(&g, &path).unwrap();
        assert_eq!(bytes, std::fs::metadata(&path).unwrap().len());
        assert_eq!(store::peek_version(&path).unwrap(), store::VERSION_V2);
        for mode in [LoadMode::Auto, LoadMode::Buffered] {
            let st = GraphStore::open_with(&path, mode, true).unwrap();
            assert_graphs_identical(st.graph(), &g, &format!("{mode:?} weighted={weighted}"));
        }
        // Canonical layout: re-encoding the reloaded graph reproduces the
        // file byte for byte.
        let back = GraphStore::open_with(&path, LoadMode::Buffered, true).unwrap().into_graph();
        let path2 = tmp(&format!("rt2_{weighted}.tcsr"));
        store::write_csr_v2(&back, &path2).unwrap();
        assert_eq!(
            std::fs::read(&path).unwrap(),
            std::fs::read(&path2).unwrap(),
            "canonical re-encode (weighted={weighted})"
        );
    }
}

#[test]
fn v2_roundtrip_zero_edge_graphs() {
    for vcount in [0usize, 5] {
        let g = CsrGraph::from_edge_list(&EdgeList::new(vcount));
        let path = tmp(&format!("empty_{vcount}.tcsr"));
        store::write_csr_v2(&g, &path).unwrap();
        let st = GraphStore::open(&path).unwrap();
        assert_eq!(st.graph().vertex_count, vcount);
        assert_eq!(st.graph().edge_count(), 0);
    }
}

#[test]
fn mmap_and_buffered_agree_on_golden_fixtures() {
    for name in GOLDEN {
        let el = gio::read_edge_list(&golden_dir().join(format!("{name}.el"))).unwrap();
        let g = CsrGraph::from_edge_list(&el);
        let path = tmp(&format!("golden_{name}.tcsr"));
        store::write_csr_v2(&g, &path).unwrap();
        let buffered = GraphStore::open_with(&path, LoadMode::Buffered, true).unwrap();
        assert!(!buffered.is_mapped());
        assert_graphs_identical(buffered.graph(), &g, name);
        if cfg!(all(unix, target_endian = "little")) {
            let mapped = GraphStore::open_with(&path, LoadMode::Mmap, true).unwrap();
            assert!(mapped.is_mapped());
            assert_eq!(mapped.graph().owned_bytes(), 0, "{name}: mmap pins no heap");
            assert_graphs_identical(mapped.graph(), buffered.graph(), name);
        }
    }
}

// -- corruption -------------------------------------------------------------

#[test]
fn truncation_at_every_boundary_is_detected() {
    let g = sample_graph(true);
    let path = tmp("trunc.tcsr");
    store::write_csr_v2(&g, &path).unwrap();
    let bytes = std::fs::read(&path).unwrap();
    let info = store::describe_v2(&path).unwrap();
    let mut cuts = vec![0u64, 4, info.header_bytes - 1, info.header_bytes];
    for s in &info.sections {
        cuts.push(s.offset.saturating_sub(1));
        cuts.push(s.offset);
        cuts.push(s.offset + 1);
        cuts.push(s.offset + s.byte_len - 1);
    }
    cuts.push(info.total_bytes - 1);
    for cut in cuts {
        let cut = cut as usize;
        assert!(cut < bytes.len(), "cut {cut} inside file");
        let p = tmp("trunc_cut.tcsr");
        std::fs::write(&p, &bytes[..cut]).unwrap();
        for mode in [LoadMode::Auto, LoadMode::Buffered] {
            let err = GraphStore::open_with(&p, mode, true)
                .err()
                .unwrap_or_else(|| panic!("cut at {cut} accepted ({mode:?})"));
            let msg = format!("{err:#}").to_lowercase();
            assert!(
                msg.contains("truncated") || msg.contains("not a totem"),
                "cut at {cut} ({mode:?}): {msg}"
            );
        }
    }
    // ...and appending garbage is just as fatal as removing bytes.
    let mut padded = bytes.clone();
    padded.extend_from_slice(&[7u8; 3]);
    let p = tmp("trailing.tcsr");
    std::fs::write(&p, &padded).unwrap();
    let err = GraphStore::open(&p).unwrap_err();
    assert!(format!("{err:#}").contains("trailing"), "{err:#}");
}

#[test]
fn every_single_byte_flip_is_detected() {
    // The container has no unchecked byte: the header FNV covers the
    // fixed fields and table, the stored checksum is compared against a
    // recomputation, padding must be zero, and every section carries its
    // own FNV. Flip each byte in turn and demand a verified open fails.
    // (Small graph: the sweep opens the file twice per byte.)
    let mut el = generator::rmat(&RmatParams::paper(5, 13));
    generator::with_random_weights(&mut el, 16, 99);
    let g = CsrGraph::from_edge_list(&el);
    let path = tmp("flip.tcsr");
    store::write_csr_v2(&g, &path).unwrap();
    let bytes = std::fs::read(&path).unwrap();
    let p = tmp("flip_mut.tcsr");
    for i in 0..bytes.len() {
        let mut m = bytes.clone();
        m[i] ^= 0xff;
        std::fs::write(&p, &m).unwrap();
        assert!(
            GraphStore::open_with(&p, LoadMode::Buffered, true).is_err(),
            "flipped byte {i} of {} accepted",
            bytes.len()
        );
        if cfg!(all(unix, target_endian = "little")) {
            assert!(
                GraphStore::open_with(&p, LoadMode::Mmap, true).is_err(),
                "flipped byte {i} accepted by mmap path"
            );
        }
    }
}

#[test]
fn flipped_section_byte_names_the_section() {
    let g = sample_graph(true);
    let path = tmp("flip_named.tcsr");
    store::write_csr_v2(&g, &path).unwrap();
    let info = store::describe_v2(&path).unwrap();
    let bytes = std::fs::read(&path).unwrap();
    for (s, name) in info.sections.iter().zip(["row-offsets", "col-indices", "weights"]) {
        let mut m = bytes.clone();
        // Flip the high byte of one element so the value stays in range
        // for CsrGraph::validate — only the checksum can catch it.
        m[(s.offset + 1) as usize] ^= 0x01;
        let p = tmp("flip_named_mut.tcsr");
        std::fs::write(&p, &m).unwrap();
        let err = GraphStore::open_with(&p, LoadMode::Buffered, true).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("checksum mismatch"), "{name}: {msg}");
        assert!(msg.contains(name), "error should name the section: {msg}");
    }
}

#[test]
fn unverified_open_still_rejects_structural_corruption() {
    // verify=false skips the per-section FNV pass (the point of lazy
    // mmap loads) but the header checksum and CSR validation still run.
    let g = sample_graph(false);
    let path = tmp("noverify.tcsr");
    store::write_csr_v2(&g, &path).unwrap();
    let info = store::describe_v2(&path).unwrap();
    let mut bytes = std::fs::read(&path).unwrap();
    // Corrupt a column index to an out-of-range vertex id.
    let col = info.sections[1];
    let off = col.offset as usize;
    bytes[off..off + 4].copy_from_slice(&u32::MAX.to_le_bytes());
    let p = tmp("noverify_mut.tcsr");
    std::fs::write(&p, &bytes).unwrap();
    let err = GraphStore::open_with(&p, LoadMode::Buffered, false).unwrap_err();
    assert!(format!("{err:#}").contains("corrupt CSR"), "{err:#}");
}

// -- v1 migration -----------------------------------------------------------

#[test]
fn v1_containers_still_load_and_migrate_to_v2() {
    let g = sample_graph(true);
    let v1 = tmp("legacy.tcsr");
    gio::write_csr_v1(&g, &v1).unwrap();
    assert_eq!(store::peek_version(&v1).unwrap(), store::VERSION_V1);
    let st = GraphStore::open(&v1).unwrap();
    assert!(!st.is_mapped(), "v1 always loads buffered");
    assert_graphs_identical(st.graph(), &g, "v1 load");
    // Migration: re-encode as v2 and verify it matches a direct v2 write.
    let v2 = tmp("migrated.tcsr");
    store::write_csr_v2(st.graph(), &v2).unwrap();
    let direct = tmp("direct.tcsr");
    store::write_csr_v2(&g, &direct).unwrap();
    assert_eq!(
        std::fs::read(&v2).unwrap(),
        std::fs::read(&direct).unwrap(),
        "migrated v1 == direct v2, byte for byte"
    );
}

// -- streaming builds -------------------------------------------------------

#[test]
fn spilled_convert_matches_in_memory_build_byte_for_byte() {
    // The golden rmat64 fixture through the external-sort path (forcing
    // many tiny runs) must produce the same container as the in-memory
    // counting sort + sequential writer.
    let el_path = golden_dir().join("rmat64.el");
    let g = CsrGraph::from_edge_list(&gio::read_edge_list(&el_path).unwrap());
    let direct = tmp("rmat64_direct.tcsr");
    store::write_csr_v2(&g, &direct).unwrap();
    for run_edges in [7usize, 64, 100_000] {
        let out = tmp(&format!("rmat64_spill_{run_edges}.tcsr"));
        let stats =
            ingest::convert_edge_list_to_tcsr(&el_path, &out, run_edges, &std::env::temp_dir())
                .unwrap();
        assert_eq!(stats.edges, 320);
        assert!(stats.peak_staging_bytes <= run_edges as u64 * 12);
        assert_eq!(
            std::fs::read(&direct).unwrap(),
            std::fs::read(&out).unwrap(),
            "run_edges={run_edges}"
        );
    }
}

#[test]
fn streamed_workload_convert_matches_harness_build() {
    // `totem convert rmatN out.tcsr --weights` must reproduce the exact
    // graph the harness builds in memory for SSSP (same weight RNG).
    let seed = 42;
    let out = tmp("wl.tcsr");
    let stats = ingest::convert_workload_to_tcsr(
        &Workload::Rmat(8),
        seed,
        true,
        &out,
        1000, // force several spill runs: 2^8 * 16 = 4096 edges
        &std::env::temp_dir(),
    )
    .unwrap();
    assert_eq!(stats.runs, 5, "4096 edges / 1000 per run");
    let g_mem = build_workload(Workload::Rmat(8), seed, AlgKind::Sssp);
    let st = GraphStore::open(&out).unwrap();
    assert_graphs_identical(st.graph(), &g_mem, "streamed workload");
}

#[test]
fn csr2writer_matches_whole_graph_writer() {
    let g = sample_graph(true);
    let whole = tmp("writer_whole.tcsr");
    store::write_csr_v2(&g, &whole).unwrap();
    let streamed = tmp("writer_streamed.tcsr");
    let ro: Vec<u64> = g.row_offsets.to_vec();
    let mut w = store::Csr2Writer::create(&streamed, &ro, true).unwrap();
    for v in 0..g.vertex_count as u32 {
        for (&d, &wt) in g.neighbors(v).iter().zip(g.edge_weights(v)) {
            w.push_edge(d, wt).unwrap();
        }
    }
    w.finish().unwrap();
    assert_eq!(std::fs::read(&whole).unwrap(), std::fs::read(&streamed).unwrap());
}

#[test]
fn spill_build_rejects_out_of_range_before_writing() {
    let mut b = SpillBuild::new(8, false, 4, &std::env::temp_dir()).unwrap();
    b.push(0, 7, 0.0).unwrap();
    let err = b.push(8, 0, 0.0).unwrap_err();
    assert!(format!("{err:#}").contains("out of declared range"), "{err:#}");
}

// -- edge-list ingest regressions -------------------------------------------

#[test]
fn truncated_edge_list_with_header_is_rejected() {
    // Satellite bug: the `p V E` header's E used to be parsed and thrown
    // away, so a truncated file loaded silently with fewer edges.
    let p = tmp("trunc.el");
    std::fs::write(&p, "p 4 3\n0 1\n1 2\n").unwrap();
    let err = gio::read_edge_list(&p).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("edge count mismatch"), "{msg}");
    assert!(msg.contains("declares 3") && msg.contains("holds 2"), "{msg}");
}

#[test]
fn out_of_range_edge_in_file_names_line_and_edge() {
    let p = tmp("oob.el");
    std::fs::write(&p, "p 4 2\n0 1\n2 9\n").unwrap();
    let err = gio::read_edge_list(&p).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("2 -> 9"), "{msg}");
    assert!(msg.contains("out of declared range"), "{msg}");
}

// -- end to end -------------------------------------------------------------

fn bfs_levels(g: &CsrGraph) -> Vec<i32> {
    let (r, _) = run_alg(
        g,
        RunSpec::new(AlgKind::Bfs).with_source(0),
        &EngineConfig::host_only(1),
    )
    .unwrap();
    match r.output {
        StateArray::I32(v) => v,
        StateArray::F32(_) => panic!("BFS output should be I32"),
    }
}

#[test]
fn bfs_through_mmap_path_matches_in_memory() {
    // The acceptance run: generate → convert (spilled) → load (mmap where
    // supported) → BFS; every level must equal the in-memory pipeline's.
    let out = tmp("e2e.tcsr");
    ingest::convert_workload_to_tcsr(
        &Workload::Rmat(10),
        7,
        false,
        &out,
        5000,
        &std::env::temp_dir(),
    )
    .unwrap();
    let g_mem = build_workload(Workload::Rmat(10), 7, AlgKind::Bfs);
    let st = GraphStore::open(&out).unwrap();
    if cfg!(all(unix, target_endian = "little")) {
        assert!(st.is_mapped(), "Auto should map on this platform");
    }
    assert_eq!(bfs_levels(st.graph()), bfs_levels(&g_mem));
}
