//! Invariants of the dynamic α controller (engine::rebalance): vertex
//! migration must preserve the global vertex/edge sets, keep the
//! `part_of`/`local_of` maps and ghost tables exactly consistent, and
//! leave `RunResult`'s share/footprint/comm-slot accounting exact — while
//! never changing algorithm outputs.

use totem::baseline;
use totem::engine::{self, EngineConfig, RebalanceConfig};
use totem::graph::generator::{rmat, with_random_weights, RmatParams};
use totem::graph::CsrGraph;
use totem::harness::{build_workload, run_alg, AlgKind, RunSpec};
use totem::graph::Workload;
use totem::partition::{
    assign, low_degree_band, PartitionedGraph, Placement, Strategy, ALL_PLACEMENTS,
};

/// A policy aggressive enough that migrations reliably fire on a skewed
/// launch split.
fn aggressive() -> RebalanceConfig {
    RebalanceConfig {
        imbalance_threshold: 0.05,
        patience: 1,
        migration_band: 0.15,
        max_migrations: 4,
    }
}

fn skewed_cfg(strategy: Strategy) -> EngineConfig {
    EngineConfig::cpu_partitions(&[0.9, 0.1], strategy).with_rebalance(aggressive())
}

#[test]
fn migrations_fire_and_accounting_stays_exact() {
    // PageRank with a fixed round count: compute per superstep is
    // edge-proportional, so a 0.9/0.1 split shows ~9x imbalance — far
    // above the 5% threshold on every superstep.
    let g = build_workload(Workload::Rmat(11), 3, AlgKind::Pagerank);
    let spec = RunSpec::new(AlgKind::Pagerank).with_rounds(6);
    let (r, _) = run_alg(&g, spec, &skewed_cfg(Strategy::High)).unwrap();
    assert!(
        r.metrics.migrations >= 1,
        "controller never fired on a 9x-imbalanced run"
    );

    // global vertex set preserved across migrations
    assert_eq!(r.vertices.iter().sum::<usize>(), g.vertex_count);
    // edge accounting: realized shares sum to 1, footprint edges to |E|
    assert!((r.shares.iter().sum::<f64>() - 1.0).abs() < 1e-9, "{:?}", r.shares);
    assert!(r.shares.iter().all(|&s| (0.0..=1.0).contains(&s)));
    let fp_edges: usize = r.footprints.iter().map(|f| f.edges).sum();
    assert_eq!(fp_edges, g.edge_count());
    let fp_vertices: usize = r.footprints.iter().map(|f| f.vertices).sum();
    assert_eq!(fp_vertices, g.vertex_count);
    // footprint totals are the exact sum of their categories
    for f in &r.footprints {
        assert_eq!(
            f.total(),
            f.graph_bytes + f.inbox_bytes + f.outbox_bytes + f.state_bytes
        );
        assert!(f.graph_bytes > 0 && f.state_bytes > 0);
    }
    // comm_slots counts every ghost slot once on each side of its pair
    let slot_sum: u64 = r.comm_slots.iter().sum();
    assert_eq!(slot_sum, 2 * r.beta.reduced_messages);

    // and the output still matches the oracle
    let expect = baseline::pagerank(&g, 6);
    for (v, (a, b)) in r.output.as_f32().iter().zip(&expect).enumerate() {
        let tol = 1e-4 * b.abs().max(1e-6);
        assert!((a - b).abs() <= tol.max(1e-7), "vertex {v}: {a} vs {b}");
    }
}

#[test]
fn band_migration_preserves_partition_maps_and_ghosts() {
    let g = CsrGraph::from_edge_list(&rmat(&RmatParams::paper(10, 7)));
    let pg = PartitionedGraph::partition(&g, Strategy::High, &[0.7, 0.3], 1);
    let donor = &pg.parts[0];
    let band = low_degree_band(
        &g,
        &donor.local_to_global,
        0.1 * donor.edge_count() as f64,
        donor.nv - 1,
    );
    assert!(!band.is_empty());

    let mut assignment = pg.part_of.clone();
    for &v in &band {
        assignment[v as usize] = 1;
    }
    let pg2 = PartitionedGraph::build(&g, &assignment, 2);

    // vertex and edge multisets preserved
    assert_eq!(pg2.parts.iter().map(|p| p.nv).sum::<usize>(), g.vertex_count);
    assert_eq!(
        pg2.parts.iter().map(|p| p.edge_count()).sum::<usize>(),
        g.edge_count()
    );
    assert_eq!(pg2.parts[1].nv, pg.parts[1].nv + band.len());

    // part_of / local_of round-trip is exact for every vertex
    for v in 0..g.vertex_count {
        let p = pg2.part_of[v] as usize;
        let l = pg2.local_of[v] as usize;
        assert_eq!(pg2.parts[p].local_to_global[l], v as u32, "vertex {v}");
    }

    // ghost tables: contiguous slot ranges, sorted, in-range
    for p in &pg2.parts {
        let mut base = p.nv;
        for t in &p.ghosts {
            assert_eq!(t.slot_base, base);
            base += t.len();
            assert!(t.remote_locals.windows(2).all(|w| w[0] < w[1]));
            let rp = &pg2.parts[t.remote_part];
            assert!(t.remote_locals.iter().all(|&l| (l as usize) < rp.nv));
        }
        assert_eq!(base, p.nv + p.n_ghost);
    }
}

#[test]
fn min_reduction_outputs_exact_across_migrations() {
    // BFS / CC / SSSP use min reductions: outputs must be *exactly* the
    // oracle's even when migrations reshuffle partitions mid-run.
    for seed in [5u64, 17, 23] {
        let mut el = rmat(&RmatParams::paper(9, seed));
        with_random_weights(&mut el, 64, seed + 1);
        let g = CsrGraph::from_edge_list(&el);
        let src = 3u32;

        for mode in [false, true] {
            let mut cfg = skewed_cfg(Strategy::Rand).with_seed(seed);
            if mode {
                cfg = cfg.pipelined();
            }
            let (r, _) = run_alg(&g, RunSpec::new(AlgKind::Bfs).with_source(src), &cfg).unwrap();
            assert_eq!(
                r.output.as_i32(),
                baseline::bfs(&g, src).as_slice(),
                "bfs seed {seed} pipelined {mode}"
            );

            let (r, _) = run_alg(&g, RunSpec::new(AlgKind::Cc), &cfg).unwrap();
            assert_eq!(
                r.output.as_i32(),
                baseline::cc(&g).as_slice(),
                "cc seed {seed} pipelined {mode}"
            );

            let (r, _) = run_alg(&g, RunSpec::new(AlgKind::Sssp).with_source(src), &cfg).unwrap();
            assert_eq!(
                r.output.as_f32(),
                baseline::sssp(&g, src).as_slice(),
                "sssp seed {seed} pipelined {mode}"
            );
        }
    }
}

#[test]
fn migrations_respect_the_cap() {
    let g = build_workload(Workload::Rmat(10), 9, AlgKind::Pagerank);
    let rb = RebalanceConfig { max_migrations: 2, ..aggressive() };
    let cfg = EngineConfig::cpu_partitions(&[0.9, 0.1], Strategy::High).with_rebalance(rb);
    let (r, _) = run_alg(&g, RunSpec::new(AlgKind::Pagerank).with_rounds(8), &cfg).unwrap();
    assert!(r.metrics.migrations <= 2, "{} migrations", r.metrics.migrations);
}

/// Structural invariants of one partition's transpose CSR against its
/// forward CSR: edge conservation (every forward edge appears exactly
/// once), in-degree sums, source ranges, and ghost-slot consistency.
fn assert_transpose_invariants(pg: &PartitionedGraph) {
    for p in &pg.parts {
        let tr = p.transpose();
        // edge conservation: |E_p| entries, one per forward edge
        assert_eq!(tr.edge_count(), p.edge_count(), "part {}", p.id);
        assert_eq!(tr.row_offsets.len(), p.state_len() + 1, "part {}", p.id);
        // per-state-index in-degree equals the forward target count
        let mut counts = vec![0u64; p.state_len()];
        let mut fwd: Vec<(u32, u32)> = Vec::new();
        for v in 0..p.nv as u32 {
            for &t in p.targets(v) {
                counts[t as usize] += 1;
                fwd.push((v, t));
            }
        }
        let mut rev: Vec<(u32, u32)> = Vec::new();
        for t in 0..p.state_len() as u32 {
            assert_eq!(tr.in_degree(t), counts[t as usize], "part {} state {t}", p.id);
            for &u in tr.sources_of(t) {
                assert!((u as usize) < p.nv, "part {}: source out of range", p.id);
                rev.push((u, t));
            }
        }
        fwd.sort_unstable();
        rev.sort_unstable();
        assert_eq!(fwd, rev, "part {}: edge multiset mismatch", p.id);
        // ghost-slot consistency: every ghost slot was created by >= 1
        // boundary edge, so its transpose row is non-empty; the dummy
        // sink is never targeted.
        for t in &p.ghosts {
            for s in t.slot_base..t.slot_base + t.len() {
                assert!(tr.in_degree(s as u32) >= 1, "part {} slot {s}", p.id);
            }
        }
        assert_eq!(tr.in_degree(p.dummy_index() as u32), 0, "part {}", p.id);
    }
}

#[test]
fn transpose_conserves_edges_and_degrees() {
    for seed in [2u64, 11, 31] {
        let g = CsrGraph::from_edge_list(&rmat(&RmatParams::paper(9, seed)));
        for strat in [Strategy::Rand, Strategy::High, Strategy::Low] {
            let pg = PartitionedGraph::partition(&g, strat, &[0.4, 0.3, 0.3], seed);
            assert_transpose_invariants(&pg);
        }
    }
}

#[test]
fn transpose_consistent_after_band_migration() {
    let g = CsrGraph::from_edge_list(&rmat(&RmatParams::paper(10, 7)));
    let pg = PartitionedGraph::partition(&g, Strategy::High, &[0.7, 0.3], 1);
    // force-build the pre-migration transposes, then migrate a band: the
    // rebuilt partitions must come with *fresh* (empty) caches whose lazy
    // build is again exact — a stale transpose would break every
    // invariant below.
    assert_transpose_invariants(&pg);
    let donor = &pg.parts[0];
    let band = low_degree_band(
        &g,
        &donor.local_to_global,
        0.1 * donor.edge_count() as f64,
        donor.nv - 1,
    );
    assert!(!band.is_empty());
    let mut assignment = pg.part_of.clone();
    for &v in &band {
        assignment[v as usize] = 1;
    }
    let pg2 = PartitionedGraph::build(&g, &assignment, 2);
    assert_transpose_invariants(&pg2);
    // the transpose sees the migrated vertices on their new side: the
    // recipient's local edge count grew by exactly what the donor lost
    assert_eq!(
        pg2.parts[0].transpose().edge_count() + pg2.parts[1].transpose().edge_count(),
        g.edge_count()
    );
}

/// Does a partition's member order satisfy `placement`'s layout contract?
fn assert_placement_layout(g: &CsrGraph, pg: &PartitionedGraph, placement: Placement) {
    for p in &pg.parts {
        match placement {
            Placement::AssignmentOrder => {
                assert!(
                    p.local_to_global.windows(2).all(|w| w[0] < w[1]),
                    "part {}: not in assignment order",
                    p.id
                );
            }
            Placement::DegreeDesc => assert!(
                p.local_to_global
                    .windows(2)
                    .all(|w| g.out_degree(w[0]) >= g.out_degree(w[1])),
                "part {}: not degree-descending",
                p.id
            ),
            Placement::DegreeAsc => assert!(
                p.local_to_global
                    .windows(2)
                    .all(|w| g.out_degree(w[0]) <= g.out_degree(w[1])),
                "part {}: not degree-ascending",
                p.id
            ),
            Placement::BfsOrder => {
                if p.nv > 0 {
                    let max = p.local_to_global.iter().map(|&v| g.out_degree(v)).max().unwrap();
                    assert_eq!(
                        g.out_degree(p.local_to_global[0]),
                        max,
                        "part {}: BFS order must seed at a max-degree member",
                        p.id
                    );
                }
            }
        }
    }
}

#[test]
fn placement_permutation_is_a_bijection_preserving_structure() {
    // The placement permutes each partition's local id space: member sets,
    // edge/weight multisets and the part_of/local_of round-trip must be
    // exactly those of the assignment-order build.
    let mut el = rmat(&RmatParams::paper(9, 21));
    with_random_weights(&mut el, 64, 22);
    let g = CsrGraph::from_edge_list(&el);
    for strat in [Strategy::Rand, Strategy::High, Strategy::Low] {
        let a = assign(&g, strat, &[0.5, 0.3, 0.2], 7);
        let base = PartitionedGraph::build_placed(&g, &a, 3, Placement::AssignmentOrder);
        for placement in ALL_PLACEMENTS {
            let pg = PartitionedGraph::build_placed(&g, &a, 3, placement);
            assert_placement_layout(&g, &pg, placement);
            // bijection: every vertex round-trips through the maps
            for v in 0..g.vertex_count {
                let p = pg.part_of[v] as usize;
                let l = pg.local_of[v] as usize;
                assert_eq!(pg.parts[p].local_to_global[l], v as u32, "{placement:?} v={v}");
            }
            for (p, b) in pg.parts.iter().zip(&base.parts) {
                // member sets identical
                let mut m = p.local_to_global.clone();
                m.sort_unstable();
                assert_eq!(m, b.local_to_global, "{placement:?}");
                // edge count and total weight conserved
                assert_eq!(p.edge_count(), b.edge_count(), "{placement:?}");
                let wsum = |x: &totem::partition::Partition| -> f64 {
                    x.csr.weights.as_ref().unwrap().iter().map(|&w| w as f64).sum()
                };
                assert!((wsum(p) - wsum(b)).abs() < 1e-6, "{placement:?}");
                // ghost tables still sorted, contiguous, in-range
                let mut next_base = p.nv;
                for t in &p.ghosts {
                    assert_eq!(t.slot_base, next_base, "{placement:?}");
                    next_base += t.len();
                    assert!(t.remote_locals.windows(2).all(|w| w[0] < w[1]), "{placement:?}");
                    let rp = &pg.parts[t.remote_part];
                    assert!(t.remote_locals.iter().all(|&l| (l as usize) < rp.nv));
                }
                assert_eq!(next_base, p.nv + p.n_ghost, "{placement:?}");
            }
        }
    }
}

#[test]
fn transpose_in_degrees_are_placement_invariant() {
    // Per *global* vertex, the local in-degree inside its partition is a
    // structural quantity — relabeling local ids cannot change it; the
    // ghost rows' total in-degree is likewise fixed by the assignment.
    let g = CsrGraph::from_edge_list(&rmat(&RmatParams::paper(9, 25)));
    let a = assign(&g, Strategy::Rand, &[0.6, 0.4], 3);
    let base = PartitionedGraph::build_placed(&g, &a, 2, Placement::AssignmentOrder);
    let base_ghost_in: Vec<u64> = base
        .parts
        .iter()
        .map(|p| {
            let tr = p.transpose();
            (p.nv..p.nv + p.n_ghost).map(|s| tr.in_degree(s as u32)).sum()
        })
        .collect();
    for placement in ALL_PLACEMENTS {
        let pg = PartitionedGraph::build_placed(&g, &a, 2, placement);
        for v in 0..g.vertex_count as u32 {
            let (bp, bl) = (base.part_of[v as usize] as usize, base.local_of[v as usize]);
            let (pp, pl) = (pg.part_of[v as usize] as usize, pg.local_of[v as usize]);
            assert_eq!(bp, pp);
            assert_eq!(
                base.parts[bp].transpose().in_degree(bl),
                pg.parts[pp].transpose().in_degree(pl),
                "{placement:?} vertex {v}"
            );
        }
        for (p, &want) in pg.parts.iter().zip(&base_ghost_in) {
            let tr = p.transpose();
            let got: u64 = (p.nv..p.nv + p.n_ghost).map(|s| tr.in_degree(s as u32)).sum();
            assert_eq!(got, want, "{placement:?} part {}", p.id);
        }
        // the structural transpose invariants hold for every layout
        assert_transpose_invariants(&pg);
    }
}

#[test]
fn collect_after_map_is_identity_for_every_placement() {
    let g = CsrGraph::from_edge_list(&rmat(&RmatParams::paper(8, 27)));
    let a = assign(&g, Strategy::High, &[0.7, 0.3], 1);
    let global: Vec<i32> = (0..g.vertex_count as i32).map(|v| 3 * v - 7).collect();
    for placement in ALL_PLACEMENTS {
        let pg = PartitionedGraph::build_placed(&g, &a, 2, placement);
        let locals: Vec<Vec<i32>> =
            pg.parts.iter().map(|p| p.map_vertex_array(&global, i32::MIN)).collect();
        assert_eq!(pg.collect_to_global(&locals), global, "{placement:?}");
    }
}

#[test]
fn post_migration_reassignment_keeps_placement_layout_fresh() {
    // After a migration-shaped reassignment, a rebuild under the graph's
    // placement must still satisfy the layout contract — i.e. the moved
    // low-degree band is *re-placed* into position, not appended (an
    // appended band would break the ordering of every ordered placement
    // and the ascending-global property of AssignmentOrder, since band
    // vertices have arbitrary ids). The engine-internal migration path
    // (`migrate_band` re-placing through `pg.placement` and remapping
    // state exactly) is unit-tested in `engine/rebalance.rs`.
    let g = CsrGraph::from_edge_list(&rmat(&RmatParams::paper(10, 7)));
    for placement in ALL_PLACEMENTS {
        let pg = PartitionedGraph::partition_placed(&g, Strategy::High, &[0.7, 0.3], 1, placement);
        assert_eq!(pg.placement, placement);
        let mut members_desc = pg.parts[0].local_to_global.clone();
        members_desc.sort_by_key(|&v| (std::cmp::Reverse(g.out_degree(v)), v));
        let band = low_degree_band(&g, &members_desc, 0.1 * pg.parts[0].edge_count() as f64, 64);
        assert!(!band.is_empty());
        let mut assignment = pg.part_of.clone();
        for &v in &band {
            assignment[v as usize] = 1;
        }
        let pg2 = PartitionedGraph::build_placed(&g, &assignment, 2, pg.placement);
        assert_eq!(pg2.parts[1].nv, pg.parts[1].nv + band.len());
        assert_placement_layout(&g, &pg2, placement);
        // canonical order inverts the rebuilt permutation too
        for p in &pg2.parts {
            let seq: Vec<u32> =
                p.canonical_order.iter().map(|&l| p.local_to_global[l as usize]).collect();
            assert!(seq.windows(2).all(|w| w[0] < w[1]), "{placement:?}");
        }
    }
}

#[test]
fn rebalanced_runs_stay_exact_under_every_placement() {
    // The dynamic α controller composes with the placement layer: BFS
    // stays bit-exact vs the oracle through migrations, whatever layout
    // the partitions use (migrate_band rebuilds via pg.placement).
    let g = build_workload(Workload::Rmat(9), 5, AlgKind::Bfs);
    let expect = baseline::bfs(&g, 3);
    for placement in ALL_PLACEMENTS {
        let cfg = skewed_cfg(Strategy::Rand).with_placement(placement);
        let (r, _) = run_alg(&g, RunSpec::new(AlgKind::Bfs).with_source(3), &cfg).unwrap();
        assert_eq!(r.output.as_i32(), expect.as_slice(), "{placement:?}");
    }
}

#[test]
fn bc_two_cycle_run_survives_migrations() {
    // BC spans two BSP cycles with different channel sets (the paired
    // dist+σ push, then pulls); migrations must be safe in both.
    let g = build_workload(Workload::Rmat(9), 13, AlgKind::Bc);
    let (r, _) = run_alg(&g, RunSpec::new(AlgKind::Bc).with_source(1), &skewed_cfg(Strategy::Rand))
        .unwrap();
    let expect = baseline::bc(&g, 1);
    for (v, (a, b)) in r.output.as_f32().iter().zip(&expect).enumerate() {
        let tol = 1e-3 * b.abs().max(1.0);
        assert!((a - b).abs() <= tol, "vertex {v}: {a} vs {b}");
    }
}
