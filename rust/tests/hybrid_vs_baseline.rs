//! Integration: every engine configuration must reproduce the whole-graph
//! baseline on every algorithm — the core correctness contract of the
//! partitioned BSP engine (CPU-only element mixes; the accelerator path is
//! covered by `accel_integration.rs` once artifacts are built).

use totem::alg::{bc::Bc, bfs::Bfs, cc::Cc, pagerank::Pagerank, sssp::Sssp, widest::Widest};
use totem::baseline;
use totem::engine::{self, EngineConfig, RebalanceConfig};
use totem::graph::generator::{rmat, with_random_weights, RmatParams};
use totem::graph::CsrGraph;
use totem::partition::Strategy;

fn workload(scale: u32, seed: u64, weighted: bool) -> CsrGraph {
    let mut el = rmat(&RmatParams::paper(scale, seed));
    if weighted {
        with_random_weights(&mut el, 64, seed + 1);
    }
    CsrGraph::from_edge_list(&el)
}

fn configs() -> Vec<(String, EngineConfig)> {
    let mut out = Vec::new();
    out.push(("host".into(), EngineConfig::host_only(1)));
    out.push(("host4t".into(), EngineConfig::host_only(4)));
    for strat in [Strategy::Rand, Strategy::High, Strategy::Low] {
        out.push((
            format!("2p-{}", strat.name()),
            EngineConfig::cpu_partitions(&[0.6, 0.4], strat),
        ));
    }
    out.push((
        "3p-RAND".into(),
        EngineConfig::cpu_partitions(&[0.5, 0.25, 0.25], Strategy::Rand),
    ));
    // pipelined executor: must reproduce every output exactly
    out.push((
        "2p-HIGH-pipelined".into(),
        EngineConfig::cpu_partitions(&[0.6, 0.4], Strategy::High).pipelined(),
    ));
    out.push((
        "3p-RAND-pipelined".into(),
        EngineConfig::cpu_partitions(&[0.5, 0.25, 0.25], Strategy::Rand).pipelined(),
    ));
    // dynamic α re-balancing on a deliberately skewed launch split, with
    // an aggressive policy so migrations actually fire mid-run
    let aggressive = RebalanceConfig {
        imbalance_threshold: 0.05,
        patience: 1,
        migration_band: 0.15,
        max_migrations: 4,
    };
    out.push((
        "2p-HIGH-rebalance".into(),
        EngineConfig::cpu_partitions(&[0.85, 0.15], Strategy::High).with_rebalance(aggressive),
    ));
    out.push((
        "2p-RAND-pipelined-rebalance".into(),
        EngineConfig::cpu_partitions(&[0.85, 0.15], Strategy::Rand)
            .pipelined()
            .with_rebalance(aggressive),
    ));
    out
}

#[test]
fn bfs_matches_baseline() {
    let g = workload(9, 11, false);
    let expect = baseline::bfs(&g, 3);
    for (name, cfg) in configs() {
        let mut alg = Bfs::new(3);
        let r = engine::run(&g, &mut alg, &cfg).unwrap();
        assert_eq!(r.output.as_i32(), expect.as_slice(), "config {name}");
    }
}

#[test]
fn sssp_matches_baseline() {
    let g = workload(9, 13, true);
    let expect = baseline::sssp(&g, 5);
    for (name, cfg) in configs() {
        let mut alg = Sssp::new(5);
        let r = engine::run(&g, &mut alg, &cfg).unwrap();
        assert_eq!(r.output.as_f32(), expect.as_slice(), "config {name}");
    }
}

#[test]
fn widest_matches_baseline() {
    // max-min relaxation is pure selection among edge weights: the hybrid
    // engine must reproduce the oracle bit-for-bit in every configuration
    // (the new vertex program riding the driver's MonotoneScatter family).
    let g = workload(9, 43, true);
    let expect = baseline::widest(&g, 5);
    for (name, cfg) in configs() {
        let mut alg = Widest::new(5);
        let r = engine::run(&g, &mut alg, &cfg).unwrap();
        for (v, (a, b)) in r.output.as_f32().iter().zip(&expect).enumerate() {
            assert!(
                a.to_bits() == b.to_bits(),
                "config {name} vertex {v}: {a} vs {b}"
            );
        }
    }
}

#[test]
fn cc_matches_baseline() {
    let g = workload(9, 17, false);
    let expect = baseline::cc(&g);
    for (name, cfg) in configs() {
        let mut alg = Cc::new();
        let r = engine::run(&g, &mut alg, &cfg).unwrap();
        assert_eq!(r.output.as_i32(), expect.as_slice(), "config {name}");
    }
}

#[test]
fn pagerank_matches_baseline() {
    let g = workload(9, 19, false);
    let expect = baseline::pagerank(&g, 5);
    for (name, cfg) in configs() {
        let mut alg = Pagerank::new(5);
        let r = engine::run(&g, &mut alg, &cfg).unwrap();
        let got = r.output.as_f32();
        for (v, (a, b)) in got.iter().zip(&expect).enumerate() {
            let tol = 1e-4 * b.abs().max(1e-6);
            assert!(
                (a - b).abs() <= tol.max(1e-7),
                "config {name} vertex {v}: {a} vs {b}"
            );
        }
    }
}

#[test]
fn bc_matches_baseline() {
    let g = workload(8, 23, false);
    let expect = baseline::bc(&g, 1);
    for (name, cfg) in configs() {
        let mut alg = Bc::new(1);
        let r = engine::run(&g, &mut alg, &cfg).unwrap();
        let got = r.output.as_f32();
        for (v, (a, b)) in got.iter().zip(&expect).enumerate() {
            let tol = 1e-3 * b.abs().max(1.0);
            assert!(
                (a - b).abs() <= tol,
                "config {name} vertex {v}: {a} vs {b}"
            );
        }
    }
}

#[test]
fn bfs_many_sources_two_partitions() {
    let g = workload(8, 29, false);
    let cfg = EngineConfig::cpu_partitions(&[0.7, 0.3], Strategy::High);
    for src in [0u32, 7, 63, 200] {
        let expect = baseline::bfs(&g, src);
        let mut alg = Bfs::new(src);
        let r = engine::run(&g, &mut alg, &cfg).unwrap();
        assert_eq!(r.output.as_i32(), expect.as_slice(), "src {src}");
    }
}

#[test]
fn metrics_are_consistent() {
    let g = workload(9, 31, false);
    let cfg = EngineConfig::cpu_partitions(&[0.5, 0.5], Strategy::Rand);
    let mut alg = Bfs::new(0);
    let r = engine::run(&g, &mut alg, &cfg).unwrap();
    let m = &r.metrics;
    assert!(m.supersteps() >= 2);
    assert!(m.makespan_secs() >= m.bottleneck_compute_secs());
    assert!(m.total_messages() > 0, "partitions must communicate");
    // β stats: RAND two-way on a scale-free graph must show reduction wins
    assert!(r.beta.beta_reduced() < r.beta.beta_raw());
    // realized α close to request
    assert!((r.shares[0] - 0.5).abs() < 0.05);
}

#[test]
fn instrumented_counts_populate() {
    let g = workload(8, 37, false);
    let cfg = EngineConfig::cpu_partitions(&[0.5, 0.5], Strategy::Rand).with_instrument(true);
    let mut alg = Bfs::new(0);
    let r = engine::run(&g, &mut alg, &cfg).unwrap();
    assert!(r.metrics.mem[0].reads > 0);
    assert!(r.metrics.mem[0].writes > 0);
    // HIGH should generate far fewer CPU writes than LOW for PageRank
    // (Figure 17's effect) — checked at the bench level; here we only
    // verify the counters move.
}

#[test]
fn footprints_reported() {
    let g = workload(9, 41, false);
    let cfg = EngineConfig::cpu_partitions(&[0.6, 0.4], Strategy::High);
    let mut alg = Pagerank::new(2);
    let r = engine::run(&g, &mut alg, &cfg).unwrap();
    for fp in &r.footprints {
        assert!(fp.graph_bytes > 0);
        assert!(fp.state_bytes > 0);
        assert!(fp.total() >= fp.graph_bytes + fp.state_bytes);
    }
    // vertex counts: HIGH gives partition 0 far fewer vertices (Fig 13)
    assert!(r.vertices[0] * 4 < r.vertices[1]);
}
