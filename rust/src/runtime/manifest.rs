//! AOT artifact manifest.
//!
//! `python/compile/aot.py` lowers every (program × size-class) pair to
//! `artifacts/<name>_n<N>_e<E>.hlo.txt` and records the marshaling contract
//! in `artifacts/manifest.json`. This module parses and validates that
//! contract; `runtime::PjrtRuntime` compiles entries on demand.
//!
//! A manifest entry must agree exactly with the Rust-side
//! [`crate::alg::ProgramSpec`] — array dtypes and order, aux arrays,
//! weights, scalar counts, and edge orientation — otherwise instantiation
//! fails loudly rather than feeding a program garbage.

use crate::alg::{EdgeOrientation, ProgramSpec};
use crate::util::json::{parse_str, JsonValue};
use anyhow::{anyhow, bail, Context, Result};
use std::path::{Path, PathBuf};

/// Element type of a device array.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    I32,
    F32,
}

impl DType {
    fn parse(s: &str) -> Result<DType> {
        match s {
            "i32" => Ok(DType::I32),
            "f32" => Ok(DType::F32),
            _ => bail!("bad dtype '{s}'"),
        }
    }
}

/// One AOT-compiled program at one size class.
#[derive(Debug, Clone)]
pub struct ManifestEntry {
    pub name: String,
    /// Device array length for per-vertex state (includes ghost slots,
    /// padding, and the dummy sink at `n_cap - 1`).
    pub n_cap: usize,
    /// Device edge capacity.
    pub e_cap: usize,
    /// HLO text file, relative to the artifacts dir.
    pub file: String,
    /// Dtypes of the mutable state arrays, in program input order.
    pub arrays: Vec<DType>,
    /// Dtypes of the constant aux vertex arrays.
    pub aux: Vec<DType>,
    pub weights: bool,
    pub n_si32: usize,
    pub n_sf32: usize,
    pub orientation: EdgeOrientation,
}

impl ManifestEntry {
    fn from_json(v: &JsonValue) -> Result<ManifestEntry> {
        let name = v
            .get("name")
            .and_then(|x| x.as_str())
            .ok_or_else(|| anyhow!("entry missing name"))?
            .to_string();
        let get_usize = |k: &str| -> Result<usize> {
            v.get(k)
                .and_then(|x| x.as_usize())
                .ok_or_else(|| anyhow!("{name}: missing field {k}"))
        };
        let dtypes = |k: &str| -> Result<Vec<DType>> {
            v.get(k)
                .and_then(|x| x.as_arr())
                .ok_or_else(|| anyhow!("{name}: missing array {k}"))?
                .iter()
                .map(|x| {
                    DType::parse(x.as_str().ok_or_else(|| anyhow!("{name}: bad {k}"))?)
                })
                .collect()
        };
        let orientation = match v.get("orientation").and_then(|x| x.as_str()) {
            Some("fwd") | None => EdgeOrientation::Forward,
            Some("rev") => EdgeOrientation::Reversed,
            Some(o) => bail!("{name}: bad orientation '{o}'"),
        };
        Ok(ManifestEntry {
            n_cap: get_usize("n_cap")?,
            e_cap: get_usize("e_cap")?,
            file: v
                .get("file")
                .and_then(|x| x.as_str())
                .ok_or_else(|| anyhow!("{name}: missing file"))?
                .to_string(),
            arrays: dtypes("arrays")?,
            aux: dtypes("aux")?,
            weights: v.get("weights").map(|x| x == &JsonValue::Bool(true)).unwrap_or(false),
            n_si32: get_usize("si32")?,
            n_sf32: get_usize("sf32")?,
            orientation,
            name,
        })
    }

    /// Device memory this entry allocates (Table 5 accounting): state +
    /// aux arrays at `n_cap`, edge arrays at `e_cap`.
    pub fn device_bytes(&self) -> u64 {
        let state = 4 * (self.arrays.len() + self.aux.len()) as u64 * self.n_cap as u64;
        let edges = 4 * (2 + self.weights as usize) as u64 * self.e_cap as u64;
        state + edges
    }
}

/// Parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub entries: Vec<ManifestEntry>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).with_context(|| {
            format!("reading {path:?} — run `make artifacts` first")
        })?;
        let v = parse_str(&text).map_err(|e| anyhow!("{path:?}: {e}"))?;
        let entries = v
            .get("programs")
            .and_then(|x| x.as_arr())
            .ok_or_else(|| anyhow!("{path:?}: missing 'programs'"))?
            .iter()
            .map(ManifestEntry::from_json)
            .collect::<Result<Vec<_>>>()?;
        Ok(Manifest { dir: dir.to_path_buf(), entries })
    }

    /// Smallest size class of `name` fitting `(n_needed, e_needed)` and the
    /// memory budget. Mirrors the paper's GPU-memory constraint: if
    /// nothing fits, the partition cannot be offloaded.
    pub fn select(
        &self,
        name: &str,
        n_needed: usize,
        e_needed: usize,
        budget_bytes: u64,
    ) -> Result<&ManifestEntry> {
        let mut candidates: Vec<&ManifestEntry> = self
            .entries
            .iter()
            // strict `<` on n: slot n_cap-1 is the dummy sink
            .filter(|e| e.name == name && e.n_cap > n_needed && e.e_cap >= e_needed)
            .collect();
        candidates.sort_by_key(|e| (e.n_cap, e.e_cap));
        let fitting = candidates.iter().find(|e| e.device_bytes() <= budget_bytes);
        match fitting {
            Some(e) => Ok(e),
            None if candidates.is_empty() => bail!(
                "no AOT size class for program '{name}' covers n={n_needed}, e={e_needed} \
                 (available: {:?})",
                self.entries
                    .iter()
                    .filter(|e| e.name == name)
                    .map(|e| (e.n_cap, e.e_cap))
                    .collect::<Vec<_>>()
            ),
            None => bail!(
                "program '{name}' at n={n_needed}, e={e_needed} needs {} bytes, over the \
                 accelerator budget of {budget_bytes}",
                candidates[0].device_bytes()
            ),
        }
    }

    /// Validate a Rust-side spec against a manifest entry.
    pub fn check_spec(entry: &ManifestEntry, spec: &ProgramSpec, arrays: &[DType]) -> Result<()> {
        if entry.arrays != arrays {
            bail!(
                "program '{}': state dtype mismatch rust={arrays:?} manifest={:?}",
                entry.name,
                entry.arrays
            );
        }
        if entry.weights != spec.needs_weights {
            bail!("program '{}': weights mismatch", entry.name);
        }
        if entry.n_si32 != spec.n_si32 || entry.n_sf32 != spec.n_sf32 {
            bail!(
                "program '{}': scalar count mismatch rust=({}, {}) manifest=({}, {})",
                entry.name,
                spec.n_si32,
                spec.n_sf32,
                entry.n_si32,
                entry.n_sf32
            );
        }
        if entry.orientation != spec.orientation {
            bail!("program '{}': edge orientation mismatch", entry.name);
        }
        if entry.aux.len() != spec.aux.len() {
            bail!("program '{}': aux count mismatch", entry.name);
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::alg::Pad;

    fn write_manifest(json: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "totem_manifest_{}",
            std::process::id()
        ));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), json).unwrap();
        dir
    }

    const SAMPLE: &str = r#"{"version":1,"programs":[
      {"name":"bfs","n_cap":4096,"e_cap":32768,"file":"bfs_n4096.hlo.txt",
       "arrays":["i32"],"aux":[],"weights":false,"si32":1,"sf32":0,"orientation":"fwd"},
      {"name":"bfs","n_cap":16384,"e_cap":131072,"file":"bfs_n16384.hlo.txt",
       "arrays":["i32"],"aux":[],"weights":false,"si32":1,"sf32":0,"orientation":"fwd"},
      {"name":"pagerank","n_cap":4096,"e_cap":32768,"file":"pr.hlo.txt",
       "arrays":["f32","f32"],"aux":["f32","f32"],"weights":false,"si32":0,"sf32":2,
       "orientation":"rev"}
    ]}"#;

    #[test]
    fn load_and_select() {
        let dir = write_manifest(SAMPLE);
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.entries.len(), 3);
        let e = m.select("bfs", 4000, 30000, u64::MAX).unwrap();
        assert_eq!(e.n_cap, 4096);
        // n == n_cap must NOT fit (dummy slot)
        let e = m.select("bfs", 4096, 100, u64::MAX).unwrap();
        assert_eq!(e.n_cap, 16384);
        assert!(m.select("bfs", 100_000, 1, u64::MAX).is_err());
        assert!(m.select("nope", 1, 1, u64::MAX).is_err());
    }

    #[test]
    fn budget_respected() {
        let dir = write_manifest(SAMPLE);
        let m = Manifest::load(&dir).unwrap();
        // tiny budget: nothing fits
        assert!(m.select("bfs", 100, 100, 1024).is_err());
    }

    #[test]
    fn device_bytes_formula() {
        let dir = write_manifest(SAMPLE);
        let m = Manifest::load(&dir).unwrap();
        let e = &m.entries[0];
        assert_eq!(e.device_bytes(), (4 * 4096 + 2 * 4 * 32768) as u64);
    }

    #[test]
    fn spec_validation() {
        let dir = write_manifest(SAMPLE);
        let m = Manifest::load(&dir).unwrap();
        let spec = ProgramSpec {
            name: "bfs",
            arrays: vec![0],
            pads: vec![Pad::I32(0)],
            aux: vec![],
            needs_weights: false,
            n_si32: 1,
            n_sf32: 0,
            orientation: EdgeOrientation::Forward,
        };
        Manifest::check_spec(&m.entries[0], &spec, &[DType::I32]).unwrap();
        assert!(Manifest::check_spec(&m.entries[0], &spec, &[DType::F32]).is_err());
        let mut bad = spec.clone();
        bad.n_si32 = 0;
        assert!(Manifest::check_spec(&m.entries[0], &bad, &[DType::I32]).is_err());
    }

    #[test]
    fn missing_manifest_message() {
        let err = Manifest::load(Path::new("/nonexistent")).unwrap_err();
        assert!(format!("{err:#}").contains("make artifacts"));
    }
}
