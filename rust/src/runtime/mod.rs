//! PJRT runtime — the accelerator processing element.
//!
//! Loads AOT artifacts (HLO text lowered by `python/compile/aot.py` from
//! JAX/Pallas step functions), compiles them once per size class on the
//! PJRT CPU client, and executes them against partition state.
//!
//! Data movement model (mirrors a discrete GPU; DESIGN.md §2/§6):
//! - **edge arrays and aux vertex arrays are device-resident** — uploaded
//!   once at instantiation, like the paper's GPU-resident CSR;
//! - **state arrays cross the boundary every superstep** (upload before
//!   execute, readback after) — this measured copy is the PCIe-transfer
//!   analogue and is attributed to the communication phase;
//! - scalars (the BSP round counter etc.) are tiny per-step uploads.
//!
//! Python never runs here: after `make artifacts` the binary is
//! self-contained.

pub mod manifest;

pub use manifest::{DType, Manifest, ManifestEntry};

use crate::alg::{EdgeOrientation, Pad, ProgramSpec};
use crate::engine::state::{AlgState, StateArray};
use crate::partition::Partition;
use anyhow::{anyhow, bail, Result};
use std::collections::HashMap;
use std::path::Path;
use std::rc::Rc;
use std::time::Instant;

/// Does this error chain mean "the PJRT backend itself is unavailable"
/// (the vendored offline xla stub refusing to compile), as opposed to a
/// real per-partition failure (missing artifacts, no fitting size class,
/// budget exceeded, spec mismatch)? The engine treats exactly this case
/// as recoverable and falls back to the `HostWide` element tier
/// (DESIGN.md §11); everything else stays a hard error.
pub fn backend_unavailable(e: &anyhow::Error) -> bool {
    format!("{e:#}").contains("PJRT backend unavailable")
}

/// Shared PJRT client + compiled-program cache.
pub struct PjrtRuntime {
    client: xla::PjRtClient,
    manifest: Manifest,
    cache: HashMap<String, Rc<xla::PjRtLoadedExecutable>>,
}

impl PjrtRuntime {
    pub fn new(artifacts_dir: &Path) -> Result<PjrtRuntime> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT client: {e}"))?;
        Ok(PjrtRuntime { client, manifest, cache: HashMap::new() })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    fn compile(&mut self, entry: &ManifestEntry) -> Result<Rc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self.cache.get(&entry.file) {
            return Ok(exe.clone());
        }
        let path = self.manifest.dir.join(&entry.file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .map_err(|e| anyhow!("parsing {path:?}: {e}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = Rc::new(
            self.client
                .compile(&comp)
                .map_err(|e| anyhow!("compiling {}: {e}", entry.file))?,
        );
        self.cache.insert(entry.file.clone(), exe.clone());
        Ok(exe)
    }

    /// Bind a partition to an accelerator program: select the size class,
    /// compile, and upload the device-resident arrays.
    pub fn instantiate(
        &mut self,
        prog: &ProgramSpec,
        part: &Partition,
        state: &AlgState,
        budget_bytes: u64,
    ) -> Result<AccelPartition> {
        let dtypes: Vec<DType> = prog
            .arrays
            .iter()
            .map(|&i| match &state.arrays[i] {
                StateArray::I32(_) => Ok(DType::I32),
                StateArray::F32(_) => Ok(DType::F32),
                // The driver keeps u64 fields host-role, so a u64 here
                // means a program listed one in `arrays` — a bug upstream.
                StateArray::U64(_) => Err(anyhow!(
                    "program '{}': u64 state arrays are host-only and cannot ship to the \
                     accelerator",
                    prog.name
                )),
            })
            .collect::<Result<_>>()?;
        let entry = self
            .manifest
            .select(prog.name, part.state_len(), part.edge_count(), budget_bytes)?
            .clone();
        Manifest::check_spec(&entry, prog, &dtypes)?;
        let exe = self.compile(&entry)?;

        let n_cap = entry.n_cap;
        let e_cap = entry.e_cap;
        let dummy = (n_cap - 1) as i32;

        // --- COO edge arrays, padded with dummy self-edges -----------------
        let ne = part.edge_count();
        if ne > e_cap {
            bail!("partition edges {ne} exceed class e_cap {e_cap}");
        }
        let mut src = vec![dummy; e_cap];
        let mut dst = vec![dummy; e_cap];
        let mut wgt = if entry.weights { Some(vec![0f32; e_cap]) } else { None };
        let mut k = 0usize;
        for v in 0..part.nv as u32 {
            let ts = part.targets(v);
            let lo = part.csr.row_offsets[v as usize] as usize;
            for (j, &t) in ts.iter().enumerate() {
                match prog.orientation {
                    EdgeOrientation::Forward => {
                        src[k] = v as i32;
                        dst[k] = t as i32;
                    }
                    EdgeOrientation::Reversed => {
                        src[k] = t as i32;
                        dst[k] = v as i32;
                    }
                }
                if let Some(wv) = &mut wgt {
                    wv[k] = part.csr.weights.as_ref().expect("weighted program")[lo + j];
                }
                k += 1;
            }
        }

        let src_buf = self
            .client
            .buffer_from_host_buffer(&src, &[e_cap], None)
            .map_err(|e| anyhow!("edge upload: {e}"))?;
        let dst_buf = self
            .client
            .buffer_from_host_buffer(&dst, &[e_cap], None)
            .map_err(|e| anyhow!("edge upload: {e}"))?;
        let wgt_buf = match &wgt {
            Some(w) => Some(
                self.client
                    .buffer_from_host_buffer(w, &[e_cap], None)
                    .map_err(|e| anyhow!("weight upload: {e}"))?,
            ),
            None => None,
        };

        // --- aux vertex arrays (constant), padded to n_cap -----------------
        let mut aux_bufs = Vec::with_capacity(prog.aux.len());
        for (&ai, &adt) in prog.aux.iter().zip(&entry.aux) {
            let buf = match (&state.aux[ai], adt) {
                (StateArray::I32(v), DType::I32) => {
                    let mut p = vec![0i32; n_cap];
                    p[..v.len()].copy_from_slice(v);
                    self.client
                        .buffer_from_host_buffer(&p, &[n_cap], None)
                        .map_err(|e| anyhow!("aux upload: {e}"))?
                }
                (StateArray::F32(v), DType::F32) => {
                    let mut p = vec![0f32; n_cap];
                    p[..v.len()].copy_from_slice(v);
                    self.client
                        .buffer_from_host_buffer(&p, &[n_cap], None)
                        .map_err(|e| anyhow!("aux upload: {e}"))?
                }
                _ => bail!("aux dtype mismatch for program '{}'", entry.name),
            };
            aux_bufs.push(buf);
        }

        let graph_bytes = (2 + entry.weights as usize) as u64 * 4 * e_cap as u64
            + 4 * aux_bufs.len() as u64 * n_cap as u64;
        let state_bytes = 4 * prog.arrays.len() as u64 * n_cap as u64;

        // Per-dtype pad values must be uniform within a program so the
        // upload scratch's padding region can be written once and reused
        // across supersteps (perf pass §Perf-L3-2). This holds for every
        // algorithm here; assert it to keep future programs honest.
        let mut pad_i32 = 0i32;
        let mut pad_f32 = 0f32;
        for (k, &ai) in prog.arrays.iter().enumerate() {
            match (&state.arrays[ai], prog.pads[k]) {
                (StateArray::I32(_), Pad::I32(p)) => pad_i32 = p,
                (StateArray::F32(_), Pad::F32(p)) => pad_f32 = p,
                _ => bail!("pad/dtype mismatch in '{}' array {k}", prog.name),
            }
        }
        for (k, &ai) in prog.arrays.iter().enumerate() {
            match (&state.arrays[ai], prog.pads[k]) {
                (StateArray::I32(_), Pad::I32(p)) if p != pad_i32 => {
                    bail!("'{}': non-uniform i32 pads", prog.name)
                }
                (StateArray::F32(_), Pad::F32(p)) if p != pad_f32 => {
                    bail!("'{}': non-uniform f32 pads", prog.name)
                }
                _ => {}
            }
        }

        Ok(AccelPartition {
            client: self.client.clone(),
            exe,
            spec: prog.clone(),
            n_cap,
            state_len: part.state_len(),
            src_buf,
            dst_buf,
            wgt_buf,
            aux_bufs,
            graph_bytes,
            state_bytes,
            scratch_i32: vec![pad_i32; n_cap],
            scratch_f32: vec![pad_f32; n_cap],
        })
    }

    /// Re-bind a partition to its program after the dynamic α controller
    /// re-shaped the partitioning (`engine`'s vertex migration) or a BSP
    /// cycle switched programs. Functionally a fresh [`Self::instantiate`]
    /// against the new geometry; the compiled executable comes from the
    /// per-file cache, so the cost is re-uploading the device-resident
    /// edge/aux arrays — the incremental part of migration on the
    /// accelerator side.
    pub fn rebind(
        &mut self,
        prog: &ProgramSpec,
        part: &Partition,
        state: &AlgState,
        budget_bytes: u64,
    ) -> Result<AccelPartition> {
        self.instantiate(prog, part, state, budget_bytes)
    }
}

/// Outcome of one accelerator superstep.
#[derive(Debug, Clone, Copy, Default)]
pub struct AccelStepOut {
    pub changed: bool,
    pub upload_secs: f64,
    pub exec_secs: f64,
    pub readback_secs: f64,
    pub transfer_bytes: u64,
}

/// A partition bound to an accelerator program with device-resident edges.
pub struct AccelPartition {
    client: xla::PjRtClient,
    exe: Rc<xla::PjRtLoadedExecutable>,
    spec: ProgramSpec,
    n_cap: usize,
    state_len: usize,
    src_buf: xla::PjRtBuffer,
    dst_buf: xla::PjRtBuffer,
    wgt_buf: Option<xla::PjRtBuffer>,
    aux_bufs: Vec<xla::PjRtBuffer>,
    graph_bytes: u64,
    state_bytes: u64,
    scratch_i32: Vec<i32>,
    scratch_f32: Vec<f32>,
}

impl AccelPartition {
    pub fn graph_bytes(&self) -> u64 {
        self.graph_bytes
    }
    pub fn state_bytes(&self) -> u64 {
        self.state_bytes
    }
    pub fn n_cap(&self) -> usize {
        self.n_cap
    }

    /// Execute one superstep: upload state, run the AOT program, read the
    /// new state back into `state`.
    pub fn step(
        &mut self,
        state: &mut AlgState,
        si32: &[i32],
        sf32: &[f32],
    ) -> Result<AccelStepOut> {
        if si32.len() != self.spec.n_si32 || sf32.len() != self.spec.n_sf32 {
            bail!("scalar count mismatch for '{}'", self.spec.name);
        }
        let n_cap = self.n_cap;
        let mut out = AccelStepOut::default();

        // --- upload state arrays -------------------------------------------
        let t0 = Instant::now();
        let mut state_bufs: Vec<xla::PjRtBuffer> = Vec::with_capacity(self.spec.arrays.len());
        for (k, &ai) in self.spec.arrays.iter().enumerate() {
            // scratch padding region is prefilled at instantiation and
            // preserved by readback (kernels keep padding inert), so only
            // the live prefix is copied per superstep.
            let buf = match &state.arrays[ai] {
                StateArray::I32(v) => {
                    self.scratch_i32[..v.len()].copy_from_slice(v);
                    self.client
                        .buffer_from_host_buffer(&self.scratch_i32, &[n_cap], None)
                        .map_err(|e| anyhow!("state upload: {e}"))?
                }
                StateArray::F32(v) => {
                    self.scratch_f32[..v.len()].copy_from_slice(v);
                    self.client
                        .buffer_from_host_buffer(&self.scratch_f32, &[n_cap], None)
                        .map_err(|e| anyhow!("state upload: {e}"))?
                }
                // unreachable in practice: instantiate rejects u64 arrays
                StateArray::U64(_) => bail!("u64 state arrays cannot ship to the accelerator"),
            };
            let _ = k;
            state_bufs.push(buf);
            out.transfer_bytes += 4 * n_cap as u64;
        }
        let mut scalar_bufs: Vec<xla::PjRtBuffer> = Vec::new();
        if self.spec.n_si32 > 0 {
            scalar_bufs.push(
                self.client
                    .buffer_from_host_buffer(si32, &[si32.len()], None)
                    .map_err(|e| anyhow!("scalar upload: {e}"))?,
            );
            out.transfer_bytes += 4 * si32.len() as u64;
        }
        if self.spec.n_sf32 > 0 {
            scalar_bufs.push(
                self.client
                    .buffer_from_host_buffer(sf32, &[sf32.len()], None)
                    .map_err(|e| anyhow!("scalar upload: {e}"))?,
            );
            out.transfer_bytes += 4 * sf32.len() as u64;
        }
        out.upload_secs = t0.elapsed().as_secs_f64();

        // --- execute --------------------------------------------------------
        let t1 = Instant::now();
        let mut args: Vec<&xla::PjRtBuffer> = Vec::new();
        args.extend(state_bufs.iter());
        args.extend(self.aux_bufs.iter());
        args.push(&self.src_buf);
        args.push(&self.dst_buf);
        if let Some(w) = &self.wgt_buf {
            args.push(w);
        }
        args.extend(scalar_bufs.iter());
        let results = self
            .exe
            .execute_b::<&xla::PjRtBuffer>(&args)
            .map_err(|e| anyhow!("executing '{}': {e}", self.spec.name))?;
        out.exec_secs = t1.elapsed().as_secs_f64();

        // --- readback -------------------------------------------------------
        let t2 = Instant::now();
        let mut tuple = results[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("readback: {e}"))?;
        let parts = tuple
            .decompose_tuple()
            .map_err(|e| anyhow!("decompose: {e}"))?;
        if parts.len() != self.spec.arrays.len() + 1 {
            bail!(
                "program '{}' returned {} outputs, expected {}",
                self.spec.name,
                parts.len(),
                self.spec.arrays.len() + 1
            );
        }
        for (k, &ai) in self.spec.arrays.iter().enumerate() {
            // copy_raw_to into the persistent scratch: no per-step Vec
            // allocation (perf pass §Perf-L3-2).
            match &mut state.arrays[ai] {
                StateArray::I32(v) => {
                    parts[k]
                        .copy_raw_to(&mut self.scratch_i32)
                        .map_err(|e| anyhow!("readback array {k}: {e}"))?;
                    v.copy_from_slice(&self.scratch_i32[..self.state_len]);
                }
                StateArray::F32(v) => {
                    parts[k]
                        .copy_raw_to(&mut self.scratch_f32)
                        .map_err(|e| anyhow!("readback array {k}: {e}"))?;
                    v.copy_from_slice(&self.scratch_f32[..self.state_len]);
                }
                // unreachable in practice: instantiate rejects u64 arrays
                StateArray::U64(_) => bail!("u64 state arrays cannot ship to the accelerator"),
            }
            out.transfer_bytes += 4 * n_cap as u64;
        }
        let changed: i32 = parts[self.spec.arrays.len()]
            .to_vec::<i32>()
            .map_err(|e| anyhow!("changed flag: {e}"))?
            .first()
            .copied()
            .unwrap_or(0);
        out.changed = changed != 0;
        out.readback_secs = t2.elapsed().as_secs_f64();
        Ok(out)
    }
}
