//! The hybrid-platform performance model (paper §3, Equations 1–4).
//!
//! Predicts the speedup of processing a graph on `{cpu, accelerator}`
//! versus the host alone, from five parameters:
//!
//! - `r_cpu`, `r_acc` — processing rates in edges/second;
//! - `c` — communication rate over the host↔accelerator link (edges/s,
//!   i.e. link bandwidth ÷ bytes per edge message);
//! - `α` — share of edges that stay on the host;
//! - `β` — share of edges that cross the partition (after reduction).
//!
//! Eq. 1: `t(G_p) = |E_p^b| / c + |E_p| / r_p`
//! Eq. 2: `makespan = max_p t(G_p)`
//! Eq. 3/4: `speedup = (1/r_cpu) / (β/c + α/r_cpu)` assuming the CPU
//! partition dominates (the paper's assumption ii, validated in §5.2).
//!
//! [`calibrate`] measures the parameters on this testbed so the model can
//! be compared with achieved speedups (Figure 7 / Table 3).

pub mod calibrate;
pub mod direction;
pub mod locality;

/// Model parameters.
#[derive(Debug, Clone, Copy)]
pub struct ModelParams {
    /// CPU processing rate, edges/s.
    pub r_cpu: f64,
    /// Accelerator processing rate, edges/s.
    pub r_acc: f64,
    /// Host↔accelerator communication rate, edges/s (bandwidth ÷ message
    /// bytes).
    pub c: f64,
}

impl ModelParams {
    /// The paper's Figure 1 reference values for 2013 commodity parts:
    /// r_cpu = 1 BE/s, r_acc = 2 BE/s (assumption ii: the GPU is faster),
    /// c = 3 BE/s (PCI-E 3.0 at 12 GB/s, 4-byte messages).
    pub fn paper_reference() -> ModelParams {
        ModelParams { r_cpu: 1e9, r_acc: 2e9, c: 3e9 }
    }
}

/// A partition's workload in model terms.
#[derive(Debug, Clone, Copy)]
pub struct PartitionLoad {
    /// Share of |E| processed by this partition.
    pub edge_share: f64,
    /// Share of |E| that this partition communicates (boundary messages
    /// after reduction, normalized by |E|).
    pub boundary_share: f64,
}

/// Eq. 1: time to process one partition, normalized to |E| = 1.
pub fn partition_time(load: &PartitionLoad, rate: f64, c: f64) -> f64 {
    load.boundary_share / c + load.edge_share / rate
}

/// Eq. 2: makespan of a two-element platform, normalized to |E| = 1.
pub fn makespan(cpu: &PartitionLoad, acc: &PartitionLoad, p: &ModelParams) -> f64 {
    partition_time(cpu, p.r_cpu, p.c).max(partition_time(acc, p.r_acc, p.c))
}

/// Eq. 4: predicted speedup vs host-only processing.
///
/// `alpha` = CPU edge share, `beta` = boundary share (after reduction).
/// Uses the general Eq. 2 form (max over both elements), which reduces to
/// the paper's Eq. 4 whenever the CPU partition dominates.
pub fn speedup(alpha: f64, beta: f64, p: &ModelParams) -> f64 {
    let host_only = 1.0 / p.r_cpu;
    let cpu = PartitionLoad { edge_share: alpha, boundary_share: beta };
    let acc = PartitionLoad { edge_share: 1.0 - alpha, boundary_share: beta };
    host_only / makespan(&cpu, &acc, p)
}

/// Eq. 4 exactly as printed (CPU-dominant assumption): `c / (β·r_cpu + α·c)`.
pub fn speedup_eq4(alpha: f64, beta: f64, p: &ModelParams) -> f64 {
    p.c / (beta * p.r_cpu + alpha * p.c)
}

/// Figure 3's x-axis: scale the communication rate by the per-edge message
/// volume. `c_base` is the rate at 4 bytes/edge.
pub fn comm_rate_for_message_bytes(c_base: f64, msg_bytes: f64) -> f64 {
    c_base * 4.0 / msg_bytes
}

/// Eq. 1 extended with an overlap factor ω ∈ [0, 1]: the fraction of the
/// partition's communication hidden behind computation by the pipelined
/// executor (DESIGN.md §4.2). ω = 0 degenerates to the paper's Eq. 1;
/// ω = 1 is perfect hiding (the §3 model's implicit assumption). The
/// realized counterpart is `Metrics::overlap_factor`.
pub fn partition_time_overlapped(load: &PartitionLoad, rate: f64, c: f64, omega: f64) -> f64 {
    debug_assert!((0.0..=1.0).contains(&omega));
    (1.0 - omega) * load.boundary_share / c + load.edge_share / rate
}

/// Eq. 2 with overlap: makespan of a two-element platform at overlap ω.
pub fn makespan_overlapped(
    cpu: &PartitionLoad,
    acc: &PartitionLoad,
    p: &ModelParams,
    omega: f64,
) -> f64 {
    partition_time_overlapped(cpu, p.r_cpu, p.c, omega)
        .max(partition_time_overlapped(acc, p.r_acc, p.c, omega))
}

/// Eq. 4 with overlap: predicted speedup vs host-only processing when a
/// fraction ω of communication is hidden behind compute.
pub fn speedup_overlapped(alpha: f64, beta: f64, p: &ModelParams, omega: f64) -> f64 {
    let host_only = 1.0 / p.r_cpu;
    let cpu = PartitionLoad { edge_share: alpha, boundary_share: beta };
    let acc = PartitionLoad { edge_share: 1.0 - alpha, boundary_share: beta };
    host_only / makespan_overlapped(&cpu, &acc, p, omega)
}

/// Predicted speedup series over a range of α values (a figure column).
pub fn speedup_series(alphas: &[f64], beta: f64, p: &ModelParams) -> Vec<f64> {
    alphas.iter().map(|&a| speedup(a, beta, p)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn infinite_bandwidth_gives_one_over_alpha() {
        // §3.2: "if c is set to infinity, the speedup ≈ 1/α"
        let p = ModelParams { r_cpu: 1e9, r_acc: 1e12, c: f64::INFINITY };
        for alpha in [0.3, 0.5, 0.8] {
            let s = speedup(alpha, 0.5, &p);
            assert!((s - 1.0 / alpha).abs() < 1e-9, "alpha={alpha} s={s}");
        }
    }

    #[test]
    fn eq4_matches_general_when_cpu_dominates() {
        let p = ModelParams::paper_reference();
        // large α → CPU partition dominates
        for alpha in [0.6, 0.8, 0.95] {
            let a = speedup(alpha, 0.05, &p);
            let b = speedup_eq4(alpha, 0.05, &p);
            assert!((a - b).abs() < 1e-12, "alpha={alpha}: {a} vs {b}");
        }
    }

    #[test]
    fn higher_beta_lower_speedup() {
        let p = ModelParams::paper_reference();
        let s1 = speedup(0.6, 0.05, &p);
        let s2 = speedup(0.6, 0.40, &p);
        assert!(s1 > s2);
    }

    #[test]
    fn figure2_worst_case_slowdown_threshold() {
        // Fig 2 right: with β=100% (bipartite worst case), slowdown is
        // predicted only for α > ~0.7 at r_cpu=1, c=3 BE/s... the paper
        // phrases it as: slowdown predicted only for α *below* 0.7 — i.e.
        // speedup < 1 exactly when α + β·r/c > 1 ⇒ α > 1 - 1/3.
        let p = ModelParams::paper_reference();
        assert!(speedup_eq4(0.75, 1.0, &p) < 1.0);
        assert!(speedup_eq4(0.60, 1.0, &p) > 1.0);
    }

    #[test]
    fn figure3_message_volume() {
        // doubling message bytes halves c and lowers speedup
        let p = ModelParams::paper_reference();
        let c8 = comm_rate_for_message_bytes(p.c, 8.0);
        assert!((c8 - 1.5e9).abs() < 1.0);
        let p8 = ModelParams { c: c8, ..p };
        assert!(speedup(0.6, 0.2, &p8) < speedup(0.6, 0.2, &p));
    }

    #[test]
    fn speedup_monotone_in_alpha() {
        let p = ModelParams::paper_reference();
        let s = speedup_series(&[0.9, 0.7, 0.5], 0.05, &p);
        assert!(s[0] < s[1] && s[1] < s[2]);
    }

    #[test]
    fn zero_overlap_degenerates_to_base_model() {
        let p = ModelParams::paper_reference();
        for (alpha, beta) in [(0.6, 0.05), (0.8, 0.4)] {
            let a = speedup(alpha, beta, &p);
            let b = speedup_overlapped(alpha, beta, &p, 0.0);
            assert!((a - b).abs() < 1e-12, "{a} vs {b}");
        }
    }

    #[test]
    fn overlap_monotonically_raises_speedup() {
        let p = ModelParams::paper_reference();
        let s0 = speedup_overlapped(0.6, 0.4, &p, 0.0);
        let s5 = speedup_overlapped(0.6, 0.4, &p, 0.5);
        let s1 = speedup_overlapped(0.6, 0.4, &p, 1.0);
        assert!(s0 < s5 && s5 < s1, "{s0} {s5} {s1}");
    }

    #[test]
    fn full_overlap_hides_all_communication() {
        // at ω = 1 the boundary term vanishes: speedup = 1/α when the CPU
        // partition dominates
        let p = ModelParams { r_cpu: 1e9, r_acc: 1e12, c: 3e9 };
        let s = speedup_overlapped(0.7, 0.9, &p, 1.0);
        assert!((s - 1.0 / 0.7).abs() < 1e-9, "s={s}");
    }
}
