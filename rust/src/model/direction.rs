//! Direction-aware per-step cost term (DESIGN.md §8), extending the §3
//! model to direction-optimized traversal.
//!
//! The base model prices a partition's whole workload at `|E_p| / r_p`.
//! For level-synchronous traversal that over-charges the dense middle
//! supersteps: a bottom-up (pull) step does not expand the frontier's
//! `m_f` out-edges — it scans the unexplored vertices' in-edges (≤ `m_u`)
//! and early-exits on the first frontier parent. Per superstep:
//!
//! ```text
//! cost_push = m_f · s_e             (s_e = seconds/edge = 1 / r_p)
//! cost_pull = m_u · φ · s_e         (φ = expected scanned fraction)
//! ```
//!
//! Summing `min(cost_push, cost_pull)` over a run's recorded
//! `(m_f, m_u)` series gives the model-side counterpart of the engine's
//! α/β switch — comparable against the measured per-step compute times in
//! [`StepMetrics`](crate::engine::StepMetrics), whose `frontier_edges` /
//! `unexplored_edges` columns are exactly this module's inputs.

use crate::engine::Direction;

/// Per-edge cost parameters of one processing element.
#[derive(Debug, Clone, Copy)]
pub struct DirectionCost {
    /// Seconds per expanded edge in top-down mode (`1 / r_p`).
    pub push_edge_secs: f64,
    /// Seconds per probed in-edge in bottom-up mode (usually ≈ the push
    /// cost; bottom-up wins by probing fewer edges, not cheaper ones).
    pub pull_edge_secs: f64,
    /// Expected fraction of a vertex's in-edges probed before the early
    /// exit hits. Beamer reports the sweep typically touches well under
    /// half the candidate edges on scale-free graphs; 0.5 is conservative.
    pub scan_fraction: f64,
}

impl DirectionCost {
    /// Reference element at rate `r` edges/second.
    pub fn from_rate(r: f64) -> DirectionCost {
        DirectionCost { push_edge_secs: 1.0 / r, pull_edge_secs: 1.0 / r, scan_fraction: 0.5 }
    }

    /// Cost of one superstep executed in `dir`, given the frontier's
    /// out-edge count `m_f` and the unexplored out-edge count `m_u`.
    pub fn step_cost(&self, dir: Direction, m_f: u64, m_u: u64) -> f64 {
        match dir {
            Direction::Push => m_f as f64 * self.push_edge_secs,
            Direction::Pull => m_u as f64 * self.scan_fraction * self.pull_edge_secs,
        }
    }

    /// The cheaper direction for one superstep and its cost. Ties go to
    /// push (no transpose traffic).
    pub fn best(&self, m_f: u64, m_u: u64) -> (Direction, f64) {
        let push = self.step_cost(Direction::Push, m_f, m_u);
        let pull = self.step_cost(Direction::Pull, m_f, m_u);
        if pull < push {
            (Direction::Pull, pull)
        } else {
            (Direction::Push, push)
        }
    }

    /// Predicted compute cost of a whole traversal under a fixed
    /// direction, from a per-step `(m_f, m_u)` series.
    pub fn traversal_cost_fixed(&self, dir: Direction, steps: &[(u64, u64)]) -> f64 {
        steps.iter().map(|&(mf, mu)| self.step_cost(dir, mf, mu)).sum()
    }

    /// Predicted compute cost with the optimal per-step direction — the
    /// lower bound the engine's α/β heuristic approximates.
    pub fn traversal_cost_optimized(&self, steps: &[(u64, u64)]) -> f64 {
        steps.iter().map(|&(mf, mu)| self.best(mf, mu).1).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn c() -> DirectionCost {
        DirectionCost { push_edge_secs: 1.0, pull_edge_secs: 1.0, scan_fraction: 0.5 }
    }

    #[test]
    fn pull_wins_on_dense_frontier() {
        // m_f = 1000 out-edges to expand, only 100 unexplored edges left
        let (dir, cost) = c().best(1000, 100);
        assert_eq!(dir, Direction::Pull);
        assert!((cost - 50.0).abs() < 1e-12);
    }

    #[test]
    fn push_wins_on_sparse_frontier() {
        let (dir, cost) = c().best(10, 10_000);
        assert_eq!(dir, Direction::Push);
        assert!((cost - 10.0).abs() < 1e-12);
    }

    #[test]
    fn optimized_never_exceeds_fixed() {
        // a BFS-like profile: tiny frontier, explosive middle, tiny tail
        let steps = [(5u64, 10_000u64), (4_000, 6_000), (9_000, 900), (50, 20)];
        let m = c();
        let opt = m.traversal_cost_optimized(&steps);
        let push = m.traversal_cost_fixed(Direction::Push, &steps);
        let pull = m.traversal_cost_fixed(Direction::Pull, &steps);
        assert!(opt <= push + 1e-12, "opt {opt} push {push}");
        assert!(opt <= pull + 1e-12, "opt {opt} pull {pull}");
        // and on this profile it strictly beats both fixed policies
        assert!(opt < push && opt < pull);
    }

    #[test]
    fn from_rate_inverts() {
        let m = DirectionCost::from_rate(2.0);
        assert!((m.step_cost(Direction::Push, 4, 0) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn ties_go_to_push() {
        // m_f = m_u * φ → equal costs → push
        let (dir, _) = c().best(50, 100);
        assert_eq!(dir, Direction::Push);
    }
}
