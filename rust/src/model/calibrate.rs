//! Model calibration (paper §3.3) and prediction-vs-achievement machinery
//! (Figure 7 / Table 3).
//!
//! The paper sets `r_cpu` by running the CPU-only implementation — "a
//! reasonable assumption as one typically starts off by implementing a CPU
//! version" — and takes `c` from measured PCI-E bandwidth. We do the same
//! on this testbed: `r_cpu` from a host-only engine run, `r_acc` from the
//! accelerator's kernel-execution rate in a hybrid probe run, and `c` from
//! the measured transfer+scatter rate of the communication phase.

use super::ModelParams;
use crate::alg::Algorithm;
use crate::engine::{self, EngineConfig, RunResult};
use crate::graph::CsrGraph;
use crate::partition::Strategy;
use anyhow::Result;
use std::path::Path;

/// Calibrated parameters plus the probe measurements behind them.
#[derive(Debug, Clone, Copy)]
pub struct Calibration {
    pub params: ModelParams,
    /// Host-only makespan on the calibration workload (the speedup
    /// denominator's baseline).
    pub host_secs: f64,
    /// Traversed edges of the calibration run.
    pub traversed: u64,
}

/// Number of PageRank-style rounds assumed when converting outputs to
/// traversed edges (ignored by traversal algorithms).
fn rounds_of(r: &RunResult) -> usize {
    r.supersteps.max(1)
}

/// Measure `r_cpu` from a host-only run: traversed edges per second of
/// bottleneck compute time.
pub fn measure_host<A: Algorithm>(g: &CsrGraph, alg: &mut A) -> Result<(f64, f64, u64)> {
    let cfg = EngineConfig::host_only(1);
    let r = engine::run(g, alg, &cfg)?;
    // TEPS accounting lives on the trait: each program owns its formula.
    let traversed = alg.traversed_edges(&r.output, g, rounds_of(&r));
    let compute = r.metrics.bottleneck_compute_secs().max(1e-9);
    Ok((traversed as f64 / compute, r.makespan_secs(), traversed))
}

/// Calibrate all three parameters for an algorithm on a workload.
///
/// `alpha_probe` sets the hybrid probe's CPU share (something comfortably
/// within the accelerator's size classes, e.g. 0.6).
pub fn calibrate<A: Algorithm>(
    g: &CsrGraph,
    host_alg: &mut A,
    probe_alg: &mut A,
    artifacts: &Path,
    alpha_probe: f64,
) -> Result<Calibration> {
    calibrate_with(g, host_alg, probe_alg, artifacts, alpha_probe, Strategy::High)
}

/// Like [`calibrate`] but with an explicit probe partitioning strategy —
/// the probe should match the configuration the predictions will be
/// compared against (the accelerator's effective rate depends on the
/// partition geometry through the AOT size-class padding).
pub fn calibrate_with<A: Algorithm>(
    g: &CsrGraph,
    host_alg: &mut A,
    probe_alg: &mut A,
    artifacts: &Path,
    alpha_probe: f64,
    strategy: Strategy,
) -> Result<Calibration> {
    let (r_cpu, host_secs, traversed) = measure_host(g, host_alg)?;

    let cfg = EngineConfig::hybrid(1, alpha_probe, strategy).with_artifacts(artifacts);
    let r = engine::run(g, probe_alg, &cfg)?;
    // accelerator rate: its edge share of the traversed work per second of
    // kernel execution.
    let acc_share: f64 = r.shares[1..].iter().sum();
    let acc_compute: f64 = (1..r.shares.len())
        .map(|p| r.metrics.partition_compute_secs(p))
        .sum();
    let r_acc = traversed as f64 * acc_share / acc_compute.max(1e-9);
    // channel rate: messages per second of communication time (transfer +
    // scatter-apply + accelerator state movement).
    let comm = r.metrics.comm_secs().max(1e-9);
    let c = r.metrics.total_messages() as f64 / comm;

    Ok(Calibration {
        params: ModelParams { r_cpu, r_acc, c },
        host_secs,
        traversed,
    })
}

/// One Figure-7 data point: model prediction vs achieved speedup at a
/// given α.
#[derive(Debug, Clone, Copy)]
pub struct SpeedupPoint {
    pub alpha: f64,
    pub predicted: f64,
    pub achieved: f64,
}

/// Compute the model's β for a hybrid run: the CPU partition's
/// communicated slots (after reduction) per total edge.
pub fn beta_of(run: &RunResult, total_edges: usize) -> f64 {
    run.comm_slots.first().copied().unwrap_or(0) as f64 / total_edges.max(1) as f64
}

/// Evaluate prediction vs achievement for one hybrid run.
pub fn speedup_point(
    cal: &Calibration,
    run: &RunResult,
    total_edges: usize,
) -> SpeedupPoint {
    let alpha = run.shares.first().copied().unwrap_or(1.0);
    let beta = beta_of(run, total_edges);
    SpeedupPoint {
        alpha,
        predicted: super::speedup(alpha, beta, &cal.params),
        achieved: cal.host_secs / run.makespan_secs().max(1e-12),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::alg::bfs::Bfs;
    use crate::graph::generator::{rmat, RmatParams};

    #[test]
    fn host_measurement_positive() {
        let g = CsrGraph::from_edge_list(&rmat(&RmatParams::paper(10, 3)));
        let mut alg = Bfs::new(0);
        let (r_cpu, secs, traversed) = measure_host(&g, &mut alg).unwrap();
        assert!(r_cpu > 0.0);
        assert!(secs > 0.0);
        assert!(traversed > 0);
    }

    #[test]
    fn calibrate_with_artifacts_if_present() {
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            eprintln!("SKIP: no artifacts");
            return;
        }
        let g = CsrGraph::from_edge_list(&rmat(&RmatParams::paper(10, 5)));
        let mut host = Bfs::new(0);
        let mut probe = Bfs::new(0);
        let cal = calibrate(&g, &mut host, &mut probe, &dir, 0.6).unwrap();
        assert!(cal.params.r_cpu > 0.0);
        assert!(cal.params.r_acc > 0.0);
        assert!(cal.params.c > 0.0);
    }
}
