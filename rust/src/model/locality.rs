//! Locality extension of the CPU per-step cost term (DESIGN.md §9).
//!
//! The paper attributes the CPU side's super-linear speedup under HIGH
//! partitioning to cache residency (§6.3.2, Figures 12–13): the BFS
//! summary structure is `|V_cpu|` bits, and once it fits the LLC the
//! miss rate collapses. Equations 1–4 model the CPU as a flat rate
//! `r_cpu`; this module adds the working-set dependence as a **locality
//! factor** `λ ≥ 1` multiplying the CPU's per-edge cost:
//!
//! ```text
//! t_cpu(G_p) = |E_p^b| / c + λ(w) · |E_p| / r_cpu        (Eq. 1′)
//! ```
//!
//! where `w` is the CPU partition's state working set and `λ` ramps
//! linearly from 1 (resident) to `miss_penalty` (working set ≥ 2× LLC),
//! the simplest shape consistent with the Fig-12 proxy: the instrumented
//! state-reference counts are layout-independent, so the *cost per
//! reference* is what the working-set ratio scales.
//!
//! The calibration anchor is the paper's own numbers: at `|V|` vertices
//! the full-graph bitmap stands at 32 MB against a 40 MB LLC (ratio 0.8),
//! and the observed CPU-side BFS speedup of HIGH over the vertex-share
//! expectation is ≈ 2× — the default `miss_penalty`.
//! [`LocalityParams::fit_miss_penalty`] recalibrates from two measured
//! (working-set ratio, per-edge time) points, e.g. a host-only run vs a
//! HIGH-partitioned CPU element from `benches/fig12_13_cache.rs`.

use super::PartitionLoad;

/// Locality model parameters for one CPU element.
#[derive(Debug, Clone, Copy)]
pub struct LocalityParams {
    /// Vertices whose per-vertex state fits the last-level cache.
    pub llc_vertices: f64,
    /// Cost multiplier once the working set is far (≥ 2×) beyond the LLC.
    pub miss_penalty: f64,
}

impl LocalityParams {
    /// The Fig-12 proxy anchor: the paper's full graph puts the bitmap at
    /// 0.8× the LLC, and the miss-rate gap is ≈ 2×.
    pub fn fig12_reference(total_vertices: usize) -> LocalityParams {
        LocalityParams {
            llc_vertices: total_vertices as f64 / 0.8,
            miss_penalty: 2.0,
        }
    }

    /// Fit `miss_penalty` from two measured per-edge times at different
    /// working-set ratios (`t` in seconds/edge, `ws` in units of
    /// `llc_vertices`). Point order is irrelevant; degenerate inputs
    /// (equal ratios, non-positive times) fall back to penalty 1.
    pub fn fit_miss_penalty(&mut self, ws_a: f64, t_a: f64, ws_b: f64, t_b: f64) {
        let (small, big) =
            if ws_a <= ws_b { ((ws_a, t_a), (ws_b, t_b)) } else { ((ws_b, t_b), (ws_a, t_a)) };
        if small.1 <= 0.0 || big.1 <= 0.0 {
            self.miss_penalty = 1.0;
            return;
        }
        // t = t0 · λ(ws) with λ(ws) = 1 + (p − 1)·g(ws), so
        // t_big/t_small = (1 + (p−1)·g_big) / (1 + (p−1)·g_small):
        //   p = 1 + (ratio − 1) / (g_big − ratio·g_small).
        let ratio = big.1 / small.1;
        let (ga, gb) = (ramp(small.0), ramp(big.0));
        let denom = gb - ratio * ga;
        let p = if denom <= 1e-12 { 1.0 } else { 1.0 + (ratio - 1.0) / denom };
        self.miss_penalty = p.clamp(1.0, 16.0);
    }
}

/// Ramp position in `[0, 1]`: 0 while the working set is LLC-resident,
/// 1 at twice the LLC and beyond.
fn ramp(ws_ratio: f64) -> f64 {
    (ws_ratio - 1.0).clamp(0.0, 1.0)
}

/// λ on the ramp at a given working-set ratio.
fn lambda_at(ws_ratio: f64, penalty: f64) -> f64 {
    1.0 + (penalty - 1.0) * ramp(ws_ratio)
}

/// Locality factor λ ∈ [1, miss_penalty] for a CPU element holding
/// `cpu_vertices` of per-vertex state. λ = 1 while the working set is
/// LLC-resident — exactly the regime HIGH partitioning buys (Fig 13) —
/// and ramps to `miss_penalty` as it spills.
pub fn locality_factor(cpu_vertices: f64, p: &LocalityParams) -> f64 {
    debug_assert!(p.llc_vertices > 0.0 && p.miss_penalty >= 1.0);
    lambda_at(cpu_vertices / p.llc_vertices, p.miss_penalty)
}

/// Eq. 1′: per-partition time with the CPU locality factor applied to the
/// compute term only (communication is bandwidth-bound, not cache-bound).
pub fn partition_time_localized(load: &PartitionLoad, rate: f64, c: f64, lambda: f64) -> f64 {
    debug_assert!(lambda >= 1.0);
    load.boundary_share / c + lambda * load.edge_share / rate
}

/// Eq. 4 with locality: predicted hybrid speedup when the CPU element
/// keeps `cpu_vertices` of state (the accelerator is modeled flat — its
/// scratchpad kernels are insensitive to vertex layout, paper §6.3.2).
pub fn speedup_localized(
    alpha: f64,
    beta: f64,
    m: &super::ModelParams,
    cpu_vertices: f64,
    total_vertices: f64,
    p: &LocalityParams,
) -> f64 {
    let host_lambda = locality_factor(total_vertices, p);
    let cpu_lambda = locality_factor(cpu_vertices, p);
    let host_only = host_lambda / m.r_cpu;
    let cpu = PartitionLoad { edge_share: alpha, boundary_share: beta };
    let acc = PartitionLoad { edge_share: 1.0 - alpha, boundary_share: beta };
    let t = partition_time_localized(&cpu, m.r_cpu, m.c, cpu_lambda)
        .max(partition_time_localized(&acc, m.r_acc, m.c, 1.0));
    host_only / t
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ModelParams;

    fn params() -> LocalityParams {
        LocalityParams { llc_vertices: 1000.0, miss_penalty: 2.0 }
    }

    #[test]
    fn resident_working_set_has_unit_factor() {
        let p = params();
        assert_eq!(locality_factor(0.0, &p), 1.0);
        assert_eq!(locality_factor(500.0, &p), 1.0);
        assert_eq!(locality_factor(1000.0, &p), 1.0);
    }

    #[test]
    fn factor_ramps_and_saturates() {
        let p = params();
        let mid = locality_factor(1500.0, &p);
        assert!((mid - 1.5).abs() < 1e-12, "mid={mid}");
        assert_eq!(locality_factor(2000.0, &p), 2.0);
        assert_eq!(locality_factor(1_000_000.0, &p), 2.0, "saturates at the penalty");
        // monotone in the working set
        let mut prev = 0.0;
        for v in [0.0, 800.0, 1200.0, 1600.0, 2400.0] {
            let l = locality_factor(v, &p);
            assert!(l >= prev);
            prev = l;
        }
    }

    #[test]
    fn unit_lambda_degenerates_to_eq1() {
        let load = PartitionLoad { edge_share: 0.7, boundary_share: 0.1 };
        let base = crate::model::partition_time(&load, 1e9, 3e9);
        let loc = partition_time_localized(&load, 1e9, 3e9, 1.0);
        assert!((base - loc).abs() < 1e-18);
    }

    #[test]
    fn fig12_reference_anchor() {
        // full graph: bitmap / LLC = 0.8 → resident → λ = 1
        let p = LocalityParams::fig12_reference(1 << 20);
        assert_eq!(locality_factor((1 << 20) as f64, &p), 1.0);
        // 4× the graph spills → penalized
        assert!(locality_factor(4.0 * (1 << 20) as f64, &p) > 1.0);
        assert!(p.miss_penalty >= 2.0 - 1e-12);
    }

    #[test]
    fn localized_speedup_superlinear_when_cpu_fits() {
        // HIGH partitioning's Fig-12 effect: host-only spills (λ = 2), the
        // hybrid CPU element is resident (λ = 1) → speedup beats the flat
        // model's prediction.
        let m = ModelParams::paper_reference();
        let p = LocalityParams { llc_vertices: 1000.0, miss_penalty: 2.0 };
        let flat = crate::model::speedup(0.6, 0.05, &m);
        let loc = speedup_localized(0.6, 0.05, &m, 100.0, 4000.0, &p);
        assert!(loc > flat, "localized {loc} must beat flat {flat}");
        // with everything resident the two models agree
        let same = speedup_localized(0.6, 0.05, &m, 100.0, 900.0, &p);
        assert!((same - flat).abs() < 1e-12, "{same} vs {flat}");
    }

    #[test]
    fn fit_penalty_recovers_ramp() {
        let mut p = params();
        // synthetic measurements on a λ-with-penalty-3 ramp: t = t0·λ
        let t0 = 2e-9;
        let lam = |ws: f64| 1.0 + (3.0 - 1.0) * (ws - 1.0).clamp(0.0, 1.0);
        p.fit_miss_penalty(0.5, t0 * lam(0.5), 2.0, t0 * lam(2.0));
        assert!((p.miss_penalty - 3.0).abs() < 1e-9, "got {}", p.miss_penalty);
        // degenerate input falls back to 1
        let mut q = params();
        q.fit_miss_penalty(1.0, 0.0, 1.0, 0.0);
        assert_eq!(q.miss_penalty, 1.0);
    }
}
