//! Concurrent query serving over one partitioned graph, with streaming
//! mutations between queries (DESIGN.md §13, §14).
//!
//! The offline engine answers one algorithm per process run; this layer
//! turns the same engine into a **server**: one graph is partitioned once
//! ([`ServeGraph`]), then many queries execute against it concurrently via
//! [`crate::engine::run_shared`] on the persistent worker pool. The moving
//! parts, each its own submodule with an isolated contract:
//!
//! - [`admission`] — bounded in-flight queries, typed rejection when
//!   saturated;
//! - [`workload`] — the query vocabulary
//!   (`bfs`/`reach`/`sssp`/`pagerank`/`ppr`) and replayable query files;
//! - [`batch`] — the pure lane-packing policy that folds compatible
//!   queued traversals into one bit-parallel multi-source BFS
//!   ([`crate::alg::msbfs::MsBfs`], up to 64 sources per run);
//! - [`cache`] — per-source result caches keyed by source + graph
//!   version: [`LaneCache`] for BFS lanes, [`PprCache`] for
//!   personalized-PageRank ranks (DESIGN.md §15.4);
//! - [`metrics`] — per-query latency split and the server-level report.
//!
//! Worker threads pop the FIFO queue; a lane-batchable head drags every
//! compatible queued query into its batch (the batching win the serving
//! benchmark measures), a non-batchable head runs solo. Because
//! `Reduce::OrU64` is order-free, batched traversals stay bit-identical
//! lane-for-lane to solo runs under every executor and partitioning —
//! the serving layer never trades answer fidelity for throughput.
//! Personalized PageRank (`ppr V`) is the deliberately *non*-batchable
//! per-source query: it carries a source but its f32 ranks cannot ride a
//! bit lane, so the batcher must skip it **without reordering** (tested
//! in [`batch`]); it runs solo over the epoch's lazily built reversed
//! view like global PageRank and caches per `(version, source)`.
//!
//! ## Graph epochs (DESIGN.md §14.3)
//!
//! [`Server::submit_mutation`] enqueues a [`DeltaBatch`] as a queue entry
//! like any query, so mutations are **linearized in FIFO order** with the
//! reads around them. Applying one takes the graph's write lock — every
//! in-flight engine run holds the read lock, so the commit naturally
//! *drains* dispatched work — then rebuilds the partitioning through
//! [`delta::rebuild_partitions`] (the α controller's commit-time tier:
//! mutation-induced load skew past the threshold triggers reassignment),
//! swaps the [`ServeGraph`], invalidates the lane cache via
//! [`LaneCache::commit`], and only then publishes the new epoch. Queries
//! carry the epoch they were admitted under; at dispatch, a query whose
//! epoch was retired is answered against the current graph under
//! [`MutationPolicy::Drain`] (the default) or bounced with a typed
//! [`ServeError::StaleEpoch`] under [`MutationPolicy::Reject`]. Batches
//! never span a mutation entry: lane-packing stops at the first mutation
//! in the queue, so one engine run never mixes pre- and post-commit
//! answers.

pub mod admission;
pub mod batch;
pub mod cache;
pub mod metrics;
pub mod workload;

pub use admission::{Admission, AdmissionError, AdmissionGuard};
pub use batch::{select_batch, BatchSelection};
pub use cache::{graph_fingerprint, GraphVersion, LaneCache, PprCache, ResultCache};
pub use metrics::{LatencyHistogram, QueryMetrics, ServeMetrics, ServeReport};
pub use workload::{
    arrival_delay_secs, parse_query, parse_query_file, synthetic_mix, QueryKind, QueryParseError,
};

use crate::alg::msbfs::MsBfs;
use crate::alg::pagerank::Pagerank;
use crate::alg::ppr::Ppr;
use crate::alg::sssp::Sssp;
use crate::alg::{Algorithm, INF_I32};
use crate::engine::{self, EngineConfig, StateArray};
use crate::graph::delta::{self, DeltaBatch, DEFAULT_SKEW_THRESHOLD};
use crate::graph::CsrGraph;
use crate::partition::PartitionedGraph;
use anyhow::{bail, Result};
use std::collections::VecDeque;
use std::fmt;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex, OnceLock, RwLock};
use std::thread;
use std::time::Instant;

// ---------------------------------------------------------------------------
// Shared graph
// ---------------------------------------------------------------------------

/// One graph, partitioned once, served by any number of concurrent runs.
///
/// The value itself is immutable — a mutation commit builds a *successor*
/// `ServeGraph` and swaps it under the server's write lock, so each value
/// describes exactly one graph epoch. The forward partitioning answers
/// traversals (BFS / reach / SSSP); the reversed view pull-mode PageRank
/// needs is built **lazily** on the first PageRank query of the epoch (a
/// `OnceLock` — pure traversal servers never pay the doubled footprint,
/// and a commit drops the stale reversed view with the epoch).
pub struct ServeGraph {
    graph: CsrGraph,
    forward_pg: PartitionedGraph,
    reversed: OnceLock<(CsrGraph, PartitionedGraph)>,
    engine: EngineConfig,
    fingerprint: u64,
}

impl ServeGraph {
    /// Partition `graph` per `engine` for serving. Rejects configurations
    /// [`engine::run_shared`] would reject per query (dynamic
    /// re-balancing mutates the partitioning and cannot share it).
    pub fn build(graph: CsrGraph, engine: EngineConfig) -> Result<ServeGraph> {
        engine.validate()?;
        if engine.rebalance.is_some() {
            bail!("serve: dynamic re-balancing would mutate the shared partitioned graph");
        }
        let forward_pg = PartitionedGraph::partition_placed(
            &graph,
            engine.strategy,
            &engine.shares,
            engine.seed,
            engine.placement,
        );
        let fingerprint = graph_fingerprint(&graph);
        Ok(ServeGraph {
            graph,
            forward_pg,
            reversed: OnceLock::new(),
            engine,
            fingerprint,
        })
    }

    pub fn graph(&self) -> &CsrGraph {
        &self.graph
    }

    pub fn fingerprint(&self) -> u64 {
        self.fingerprint
    }

    fn reversed(&self) -> &(CsrGraph, PartitionedGraph) {
        self.reversed.get_or_init(|| {
            let rg = self.graph.reverse();
            let rpg = PartitionedGraph::partition_placed(
                &rg,
                self.engine.strategy,
                &self.engine.shares,
                self.engine.seed,
                self.engine.placement,
            );
            (rg, rpg)
        })
    }
}

// ---------------------------------------------------------------------------
// Queries and answers
// ---------------------------------------------------------------------------

/// Per-kind answer payloads. Level arrays are `Arc`-shared with the lane
/// cache — a batched BFS answering 30 queries clones 30 handles, not 30
/// |V|-sized vectors.
#[derive(Debug, Clone, PartialEq)]
pub enum QueryResponse {
    /// BFS levels per vertex ([`crate::alg::INF_I32`] = unreached).
    Levels(Arc<Vec<i32>>),
    /// Reachability per vertex.
    Reachable(Vec<bool>),
    /// SSSP distances per vertex.
    Distances(Vec<f32>),
    /// PageRank / personalized-PageRank scores per vertex. `Arc`-shared
    /// with the [`PprCache`]: a cache hit clones a handle, not |V| f32s.
    Ranks(Arc<Vec<f32>>),
}

/// Typed post-admission failure (admission failures are rejected at
/// [`Server::submit`] with [`AdmissionError`] before a ticket exists).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ServeError {
    /// The query cannot run on this graph (e.g. SSSP without weights).
    Unsupported(String),
    /// The engine run failed.
    Engine(String),
    /// The query's admission epoch was retired by a mutation commit before
    /// it dispatched ([`MutationPolicy::Reject`] only — under
    /// [`MutationPolicy::Drain`] the query is answered against the current
    /// graph instead).
    StaleEpoch { submitted: u64, current: u64 },
    /// A mutation batch failed to apply; the graph is unchanged and the
    /// epoch did not advance.
    Mutation(String),
    /// The server shut down before answering.
    Disconnected,
}

impl fmt::Display for ServeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ServeError::Unsupported(why) => write!(f, "unsupported query: {why}"),
            ServeError::Engine(why) => write!(f, "engine failure: {why}"),
            ServeError::StaleEpoch { submitted, current } => write!(
                f,
                "query admitted at graph epoch {submitted} retired by commit (current epoch {current})"
            ),
            ServeError::Mutation(why) => write!(f, "mutation rejected: {why}"),
            ServeError::Disconnected => write!(f, "server shut down before answering"),
        }
    }
}

impl std::error::Error for ServeError {}

/// An answered query: the payload plus where its latency went.
#[derive(Debug, Clone, PartialEq)]
pub struct QueryAnswer {
    pub response: QueryResponse,
    pub metrics: QueryMetrics,
}

/// Handle to an admitted query; blocks until a worker answers.
pub struct Ticket {
    rx: mpsc::Receiver<Result<QueryAnswer, ServeError>>,
}

impl Ticket {
    pub fn wait(self) -> Result<QueryAnswer, ServeError> {
        self.rx.recv().unwrap_or(Err(ServeError::Disconnected))
    }
}

// ---------------------------------------------------------------------------
// Mutations
// ---------------------------------------------------------------------------

/// What happens to queries whose admission epoch a mutation commit retires
/// before they dispatch (DESIGN.md §14.3).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MutationPolicy {
    /// Answer them against the current (post-commit) graph. The default:
    /// every admitted query gets an answer, linearized after the commit.
    Drain,
    /// Bounce them with [`ServeError::StaleEpoch`] — for clients that must
    /// know their answer describes the graph they submitted against.
    Reject,
}

/// What one committed mutation batch did to the served graph.
#[derive(Debug, Clone, PartialEq)]
pub struct MutationReport {
    /// The epoch this commit published (first commit publishes 1).
    pub epoch: u64,
    /// Edge insertions applied / edge copies removed.
    pub inserted: u64,
    pub deleted: u64,
    /// Deletes that matched no edge (counted no-ops).
    pub delete_misses: u64,
    /// Vertices the batch grew the graph by.
    pub new_vertices: usize,
    /// Did commit-time load skew trigger a from-scratch reassignment?
    pub reassigned: bool,
    /// Realized edge-share skew after the rebuild.
    pub skew: f64,
}

/// Handle to an enqueued mutation; blocks until its commit (or failure).
pub struct MutationTicket {
    rx: mpsc::Receiver<Result<MutationReport, ServeError>>,
}

impl MutationTicket {
    pub fn wait(self) -> Result<MutationReport, ServeError> {
        self.rx.recv().unwrap_or(Err(ServeError::Disconnected))
    }
}

/// One enqueued, not-yet-applied mutation batch.
struct MutationJob {
    batch: DeltaBatch,
    tx: mpsc::Sender<Result<MutationReport, ServeError>>,
}

// ---------------------------------------------------------------------------
// Server
// ---------------------------------------------------------------------------

/// Serving-layer knobs on top of the engine configuration.
#[derive(Debug, Clone)]
pub struct ServerConfig {
    /// Dispatcher threads (each runs whole engine jobs; `0` = accept
    /// submissions but never dispatch — used by saturation tests).
    pub workers: usize,
    /// Admission limit: queries admitted but not yet answered.
    pub max_in_flight: usize,
    /// Lane budget per batched traversal (capped at 64 bit lanes).
    pub max_batch: usize,
    /// Rounds for PageRank and personalized-PageRank queries.
    pub pagerank_rounds: usize,
    /// Cache entries per result cache — the lane cache and the PPR cache
    /// each get this many (0 disables caching).
    pub cache_capacity: usize,
    /// What to do with admitted queries a mutation commit strands on a
    /// retired epoch (DESIGN.md §14.3).
    pub mutation_policy: MutationPolicy,
    /// Commit-time load-skew threshold above which
    /// [`delta::rebuild_partitions`] abandons the extended assignment and
    /// reassigns from scratch.
    pub skew_threshold: f64,
    /// Engine configuration every query runs under (re-balancing
    /// rejected — see [`ServeGraph::build`]).
    pub engine: EngineConfig,
}

impl ServerConfig {
    pub fn new(engine: EngineConfig) -> ServerConfig {
        ServerConfig {
            workers: 2,
            max_in_flight: 64,
            max_batch: 64,
            pagerank_rounds: 5,
            cache_capacity: 1024,
            mutation_policy: MutationPolicy::Drain,
            skew_threshold: DEFAULT_SKEW_THRESHOLD,
            engine,
        }
    }
}

/// One admitted, not-yet-dispatched query. Dropping it (answered or not)
/// releases its admission slot via the RAII guard.
struct Pending {
    kind: QueryKind,
    /// Graph epoch this query was admitted under; compared against the
    /// current epoch at dispatch (see [`MutationPolicy`]).
    epoch: u64,
    _guard: AdmissionGuard,
    enqueued_at: Instant,
    tx: mpsc::Sender<Result<QueryAnswer, ServeError>>,
}

/// FIFO queue entry: queries and mutations share one queue so mutations
/// are linearized with the reads around them.
enum Entry {
    Query(Pending),
    Mutation(MutationJob),
}

struct Shared {
    /// The served graph of the current epoch. Queries hold the read lock
    /// for the duration of their engine run; a mutation commit takes the
    /// write lock, which drains every dispatched run before it applies.
    graph: RwLock<ServeGraph>,
    /// Published graph epoch (0 at start). Bumped under the write lock,
    /// after the cache commit — a reader holding the graph read lock
    /// always observes an epoch consistent with the graph it sees.
    epoch: AtomicU64,
    cfg: ServerConfig,
    queue: Mutex<VecDeque<Entry>>,
    ready: Condvar,
    admission: Arc<Admission>,
    cache: LaneCache,
    /// Personalized-PageRank answers, same version/epoch policy as the
    /// lane cache (DESIGN.md §15.4).
    ppr_cache: PprCache,
    metrics: ServeMetrics,
    shutdown: AtomicBool,
}

/// The query server: admission → FIFO queue → worker threads dispatching
/// batched or solo engine runs over one [`ServeGraph`].
pub struct Server {
    shared: Arc<Shared>,
    workers: Vec<thread::JoinHandle<()>>,
}

impl Server {
    pub fn start(graph: CsrGraph, cfg: ServerConfig) -> Result<Server> {
        let sg = ServeGraph::build(graph, cfg.engine.clone())?;
        let cache = LaneCache::new(&sg.graph, cfg.cache_capacity);
        let ppr_cache = PprCache::new(&sg.graph, cfg.cache_capacity);
        let shared = Arc::new(Shared {
            graph: RwLock::new(sg),
            epoch: AtomicU64::new(0),
            admission: Admission::new(cfg.max_in_flight),
            cfg,
            queue: Mutex::new(VecDeque::new()),
            ready: Condvar::new(),
            cache,
            ppr_cache,
            metrics: ServeMetrics::new(),
            shutdown: AtomicBool::new(false),
        });
        let workers = (0..shared.cfg.workers)
            .map(|i| {
                let sh = Arc::clone(&shared);
                thread::Builder::new()
                    .name(format!("serve-worker-{i}"))
                    .spawn(move || worker_loop(&sh))
                    .expect("spawn serve worker")
            })
            .collect();
        Ok(Server { shared, workers })
    }

    /// Submit one query. Cache hits (lane or PPR) answer immediately
    /// without consuming an admission slot; otherwise the query takes a
    /// slot (or is rejected typed) and queues for a worker.
    pub fn submit(&self, kind: QueryKind) -> Result<Ticket, AdmissionError> {
        let (tx, rx) = mpsc::channel();
        let hit = QueryMetrics {
            queue_wait_secs: 0.0,
            compute_secs: 0.0,
            supersteps: 0,
            teps: 0.0,
            batch_width: 1,
            cache_hit: true,
        };
        if let Some(src) = kind.lane_source() {
            if let Some(levels) = self.shared.cache.get(src) {
                self.shared.metrics.record_query(hit);
                let _ =
                    tx.send(Ok(QueryAnswer { response: respond(kind, &levels), metrics: hit }));
                return Ok(Ticket { rx });
            }
        }
        if let QueryKind::Ppr { source } = kind {
            if let Some(ranks) = self.shared.ppr_cache.get(source) {
                self.shared.metrics.record_query(hit);
                let _ = tx
                    .send(Ok(QueryAnswer { response: QueryResponse::Ranks(ranks), metrics: hit }));
                return Ok(Ticket { rx });
            }
        }
        let guard = match self.shared.admission.try_admit() {
            Ok(g) => g,
            Err(e) => {
                self.shared.metrics.record_rejection();
                return Err(e);
            }
        };
        let pending = Pending {
            kind,
            epoch: self.shared.epoch.load(Ordering::Acquire),
            _guard: guard,
            enqueued_at: Instant::now(),
            tx,
        };
        self.shared.queue.lock().unwrap().push_back(Entry::Query(pending));
        self.shared.ready.notify_one();
        Ok(Ticket { rx })
    }

    /// Enqueue one mutation batch. It is applied in FIFO position — every
    /// query submitted before it is answered against the pre-commit graph,
    /// every query after it against the post-commit graph. Mutations do
    /// not consume admission slots (they are control-plane, not load).
    pub fn submit_mutation(&self, batch: DeltaBatch) -> MutationTicket {
        let (tx, rx) = mpsc::channel();
        self.shared.queue.lock().unwrap().push_back(Entry::Mutation(MutationJob { batch, tx }));
        self.shared.ready.notify_one();
        MutationTicket { rx }
    }

    pub fn in_flight(&self) -> usize {
        self.shared.admission.in_flight()
    }

    /// The published graph epoch (0 until the first mutation commits).
    pub fn epoch(&self) -> u64 {
        self.shared.epoch.load(Ordering::Acquire)
    }

    pub fn fingerprint(&self) -> u64 {
        self.shared.graph.read().unwrap().fingerprint()
    }

    pub fn report(&self) -> ServeReport {
        self.shared.metrics.report()
    }

    /// Drain the queue, stop the workers, and return the final report.
    pub fn shutdown(mut self) -> ServeReport {
        self.stop_workers();
        self.report()
    }

    fn stop_workers(&mut self) {
        self.shared.shutdown.store(true, Ordering::Release);
        self.shared.ready.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.stop_workers();
    }
}

/// Shape one answer from a lane's level array.
fn respond(kind: QueryKind, levels: &Arc<Vec<i32>>) -> QueryResponse {
    match kind {
        QueryKind::Bfs { .. } => QueryResponse::Levels(Arc::clone(levels)),
        QueryKind::Reach { .. } => {
            QueryResponse::Reachable(levels.iter().map(|&l| l != INF_I32).collect())
        }
        other => unreachable!("{} queries do not ride lanes", other.name()),
    }
}

/// One unit of dispatched work.
enum Work {
    /// Lane-batched traversal: the pendings in pick order, one source per
    /// lane, and each pending's lane.
    Batch { pendings: Vec<Pending>, lane_sources: Vec<u32>, lane_of: Vec<usize> },
    Solo(Pending),
    /// A mutation batch to commit.
    Mutate(MutationJob),
}

/// Pop the next unit of work (caller holds the queue non-empty).
fn take_work(q: &mut VecDeque<Entry>, max_batch: usize) -> Work {
    match q.front().expect("caller checked non-empty") {
        Entry::Mutation(_) => {
            return match q.pop_front().expect("checked above") {
                Entry::Mutation(job) => Work::Mutate(job),
                Entry::Query(_) => unreachable!("front was a mutation"),
            };
        }
        Entry::Query(p) if !p.kind.batchable() => {
            return match q.pop_front().expect("checked above") {
                Entry::Query(p) => Work::Solo(p),
                Entry::Mutation(_) => unreachable!("front was a query"),
            };
        }
        Entry::Query(_) => {}
    }
    // Lane-pack over the prefix of queries ahead of the first queued
    // mutation: a batch must never span an epoch boundary, or one engine
    // run would mix pre- and post-commit answers.
    let kinds: Vec<QueryKind> = q
        .iter()
        .map_while(|e| match e {
            Entry::Query(p) => Some(p.kind),
            Entry::Mutation(_) => None,
        })
        .collect();
    let sel = select_batch(&kinds, max_batch);
    let mut pendings = Vec::with_capacity(sel.picked.len());
    for &i in sel.picked.iter().rev() {
        match q.remove(i).expect("selected index in range") {
            Entry::Query(p) => pendings.push(p),
            Entry::Mutation(_) => unreachable!("selection restricted to the query prefix"),
        }
    }
    pendings.reverse(); // back to pick (FIFO) order, aligned with lane_of
    Work::Batch { pendings, lane_sources: sel.lane_sources, lane_of: sel.lane_of }
}

fn worker_loop(shared: &Shared) {
    loop {
        let work = {
            let mut q = shared.queue.lock().unwrap();
            loop {
                if q.is_empty() {
                    // graceful drain: exit only once the queue is empty
                    if shared.shutdown.load(Ordering::Acquire) {
                        return;
                    }
                    q = shared.ready.wait(q).unwrap();
                    continue;
                }
                break take_work(&mut q, shared.cfg.max_batch);
            }
        };
        match work {
            Work::Batch { pendings, lane_sources, lane_of } => {
                run_batch(shared, pendings, &lane_sources, &lane_of)
            }
            Work::Solo(p) => run_solo(shared, p),
            Work::Mutate(job) => apply_mutation(shared, job),
        }
    }
}

/// Apply one mutation batch under the graph write lock: delta-apply,
/// rebuild the partitioning (reassigning from scratch when commit-time
/// load skew exceeds the threshold — the α controller's commit-time
/// tier), swap the [`ServeGraph`], invalidate the lane cache, and only
/// then publish the new epoch. Acquiring the write lock drains every
/// dispatched engine run; a failed apply leaves graph and epoch untouched.
fn apply_mutation(shared: &Shared, job: MutationJob) {
    let outcome = {
        let mut sg = shared.graph.write().unwrap();
        match delta::apply(&sg.graph, &job.batch) {
            Err(e) => Err(ServeError::Mutation(e.to_string())),
            Ok(applied) => {
                let ecfg = &shared.cfg.engine;
                let rb = delta::rebuild_partitions(
                    &applied.graph,
                    &sg.forward_pg,
                    ecfg.strategy,
                    &ecfg.shares,
                    ecfg.seed,
                    shared.cfg.skew_threshold,
                );
                let epoch = shared.epoch.load(Ordering::Relaxed) + 1;
                let report = MutationReport {
                    epoch,
                    inserted: applied.inserted,
                    deleted: applied.deleted,
                    delete_misses: applied.delete_misses,
                    new_vertices: applied.new_vertices,
                    reassigned: rb.reassigned,
                    skew: rb.skew,
                };
                let engine = sg.engine.clone();
                let fingerprint = graph_fingerprint(&applied.graph);
                *sg = ServeGraph {
                    graph: applied.graph,
                    forward_pg: rb.pg,
                    reversed: OnceLock::new(),
                    engine,
                    fingerprint,
                };
                shared.cache.commit(&sg.graph, epoch);
                shared.ppr_cache.commit(&sg.graph, epoch);
                shared.epoch.store(epoch, Ordering::Release);
                shared.metrics.record_mutation(report.inserted, report.deleted, report.reassigned);
                Ok(report)
            }
        }
    };
    let _ = job.tx.send(outcome);
}

/// Dispatch one bit-parallel multi-source traversal and fan its lanes
/// back out to the queries that rode them.
fn run_batch(shared: &Shared, pendings: Vec<Pending>, lane_sources: &[u32], lane_of: &[usize]) {
    let dispatched = Instant::now();
    // held for the whole run: this is what a mutation commit drains on
    let sg = shared.graph.read().unwrap();
    let current = shared.epoch.load(Ordering::Acquire);
    let mut live: Vec<(Pending, usize)> = Vec::with_capacity(pendings.len());
    for (j, p) in pendings.into_iter().enumerate() {
        if shared.cfg.mutation_policy == MutationPolicy::Reject && p.epoch != current {
            shared.metrics.record_stale_epoch_reject();
            let _ = p.tx.send(Err(ServeError::StaleEpoch { submitted: p.epoch, current }));
        } else {
            live.push((p, lane_of[j]));
        }
    }
    if live.is_empty() {
        return;
    }
    let fail_all = |live: Vec<(Pending, usize)>, err: ServeError| {
        for (p, _) in live {
            let _ = p.tx.send(Err(err.clone()));
        }
    };
    let mut alg = match MsBfs::new(lane_sources) {
        Ok(a) => a,
        Err(e) => return fail_all(live, ServeError::Engine(format!("{e:#}"))),
    };
    let r = match engine::run_shared(&sg.graph, &sg.graph, &sg.forward_pg, &mut alg, &shared.cfg.engine)
    {
        Ok(r) => r,
        Err(e) => return fail_all(live, ServeError::Engine(format!("{e:#}"))),
    };
    let compute = dispatched.elapsed().as_secs_f64();
    let traversed = alg.traversed_edges(&r.output, &sg.graph, 1);
    let teps = if compute > 0.0 { traversed as f64 / compute } else { 0.0 };
    let width = lane_sources.len();
    let lane_levels: Vec<Arc<Vec<i32>>> = r
        .extra
        .into_iter()
        .map(|a| match a {
            StateArray::I32(v) => Arc::new(v),
            _ => unreachable!("msbfs lane outputs are i32 level arrays"),
        })
        .collect();
    debug_assert_eq!(lane_levels.len(), width, "one collected level array per lane");
    // insert at the version the lanes were computed against (read under
    // the graph read lock, so it cannot move mid-capture): even if a
    // commit lands between dropping the lock and these inserts, insert_at
    // drops the stale answers instead of poisoning the new epoch
    let version = shared.cache.version();
    for (b, &src) in lane_sources.iter().enumerate() {
        shared.cache.insert_at(version, src, Arc::clone(&lane_levels[b]));
    }
    shared.metrics.record_batch(live.len());
    for (p, lane) in live {
        let m = QueryMetrics {
            queue_wait_secs: dispatched.saturating_duration_since(p.enqueued_at).as_secs_f64(),
            compute_secs: compute,
            supersteps: r.supersteps,
            teps,
            batch_width: width,
            cache_hit: false,
        };
        shared.metrics.record_query(m);
        let response = respond(p.kind, &lane_levels[lane]);
        let _ = p.tx.send(Ok(QueryAnswer { response, metrics: m }));
    }
}

/// Dispatch one non-batchable query (SSSP / PageRank / PPR) solo.
fn run_solo(shared: &Shared, p: Pending) {
    let dispatched = Instant::now();
    let sg = shared.graph.read().unwrap();
    let current = shared.epoch.load(Ordering::Acquire);
    if shared.cfg.mutation_policy == MutationPolicy::Reject && p.epoch != current {
        shared.metrics.record_stale_epoch_reject();
        let _ = p.tx.send(Err(ServeError::StaleEpoch { submitted: p.epoch, current }));
        return;
    }
    let g = &sg.graph;
    let cfg = &shared.cfg.engine;
    let outcome: Result<(Vec<f32>, usize, u64)> = match p.kind {
        QueryKind::Sssp { source } => {
            if g.weights.is_none() {
                let _ = p.tx.send(Err(ServeError::Unsupported(
                    "sssp requires a weighted graph".into(),
                )));
                return;
            }
            let mut alg = Sssp::new(source);
            engine::run_shared(g, g, &sg.forward_pg, &mut alg, cfg).map(|r| {
                let traversed = alg.traversed_edges(&r.output, g, 1);
                (take_f32(r.output), r.supersteps, traversed)
            })
        }
        QueryKind::Pagerank => {
            let (rg, rpg) = sg.reversed();
            let rounds = shared.cfg.pagerank_rounds;
            let mut alg = Pagerank::new(rounds);
            engine::run_shared(g, rg, rpg, &mut alg, cfg).map(|r| {
                let traversed = alg.traversed_edges(&r.output, g, rounds);
                (take_f32(r.output), r.supersteps, traversed)
            })
        }
        QueryKind::Ppr { source } => {
            // same reversed view and round budget as global PageRank —
            // the first pagerank-family query of an epoch pays the build
            let (rg, rpg) = sg.reversed();
            let rounds = shared.cfg.pagerank_rounds;
            let mut alg = Ppr::new(source, rounds);
            engine::run_shared(g, rg, rpg, &mut alg, cfg).map(|r| {
                let traversed = alg.traversed_edges(&r.output, g, rounds);
                (take_f32(r.output), r.supersteps, traversed)
            })
        }
        other => unreachable!("{} heads dispatch as batches", other.name()),
    };
    match outcome {
        Err(e) => {
            let _ = p.tx.send(Err(ServeError::Engine(format!("{e:#}"))));
        }
        Ok((values, supersteps, traversed)) => {
            let compute = dispatched.elapsed().as_secs_f64();
            let m = QueryMetrics {
                queue_wait_secs: dispatched.saturating_duration_since(p.enqueued_at).as_secs_f64(),
                compute_secs: compute,
                supersteps,
                teps: if compute > 0.0 { traversed as f64 / compute } else { 0.0 },
                batch_width: 1,
                cache_hit: false,
            };
            shared.metrics.record_query(m);
            let response = match p.kind {
                QueryKind::Sssp { .. } => QueryResponse::Distances(values),
                QueryKind::Pagerank => QueryResponse::Ranks(Arc::new(values)),
                QueryKind::Ppr { source } => {
                    let ranks = Arc::new(values);
                    // still under the graph read lock (`sg` is live), so
                    // the cache version cannot move mid-capture; a racing
                    // commit makes insert_at drop the stale answer
                    shared.ppr_cache.insert_at(
                        shared.ppr_cache.version(),
                        source,
                        Arc::clone(&ranks),
                    );
                    QueryResponse::Ranks(ranks)
                }
                other => unreachable!("{} heads dispatch as batches", other.name()),
            };
            let _ = p.tx.send(Ok(QueryAnswer { response, metrics: m }));
        }
    }
}

fn take_f32(a: StateArray) -> Vec<f32> {
    match a {
        StateArray::F32(v) => v,
        _ => unreachable!("solo outputs are f32 arrays"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::alg::bfs::Bfs;
    use crate::graph::delta::MutationOp;
    use crate::graph::{rmat, with_random_weights, EdgeList, RmatParams};

    fn weighted_rmat(scale: u32, seed: u64) -> CsrGraph {
        let mut el = rmat(&RmatParams::paper(scale, seed));
        with_random_weights(&mut el, 64, seed ^ 0x9e37);
        CsrGraph::from_edge_list(&el)
    }

    fn server(g: &CsrGraph, workers: usize, limit: usize) -> Server {
        let cfg = ServerConfig {
            workers,
            max_in_flight: limit,
            ..ServerConfig::new(EngineConfig::host_only(2))
        };
        Server::start(g.clone(), cfg).unwrap()
    }

    #[test]
    fn mixed_queries_match_solo_engine_runs() {
        let g = weighted_rmat(7, 42);
        let srv = server(&g, 2, 64);
        let tickets: Vec<(QueryKind, Ticket)> = [
            QueryKind::Bfs { source: 0 },
            QueryKind::Reach { source: 3 },
            QueryKind::Sssp { source: 0 },
            QueryKind::Pagerank,
            QueryKind::Ppr { source: 0 },
        ]
        .into_iter()
        .map(|k| (k, srv.submit(k).unwrap()))
        .collect();
        for (kind, t) in tickets {
            let a = t.wait().unwrap();
            let cfg = EngineConfig::host_only(2);
            match (kind, a.response) {
                (QueryKind::Bfs { source }, QueryResponse::Levels(got)) => {
                    let want = engine::run(&g, &mut Bfs::new(source), &cfg).unwrap();
                    assert_eq!(got.as_slice(), want.output.as_i32());
                }
                (QueryKind::Reach { source }, QueryResponse::Reachable(got)) => {
                    let want = engine::run(&g, &mut Bfs::new(source), &cfg).unwrap();
                    let want: Vec<bool> =
                        want.output.as_i32().iter().map(|&l| l != INF_I32).collect();
                    assert_eq!(got, want);
                }
                (QueryKind::Sssp { source }, QueryResponse::Distances(got)) => {
                    let want = engine::run(&g, &mut Sssp::new(source), &cfg).unwrap();
                    assert_eq!(got.as_slice(), want.output.as_f32());
                }
                (QueryKind::Pagerank, QueryResponse::Ranks(got)) => {
                    let want = engine::run(&g, &mut Pagerank::new(5), &cfg).unwrap();
                    assert_eq!(got.as_slice(), want.output.as_f32());
                }
                (QueryKind::Ppr { source }, QueryResponse::Ranks(got)) => {
                    let want = engine::run(&g, &mut Ppr::new(source, 5), &cfg).unwrap();
                    assert_eq!(got.as_slice(), want.output.as_f32());
                }
                (kind, other) => panic!("{} answered with {other:?}", kind.name()),
            }
        }
        let report = srv.shutdown();
        assert_eq!(report.served, 5);
        assert_eq!(report.rejected, 0);
    }

    #[test]
    fn repeated_ppr_sources_hit_the_ppr_cache() {
        let g = weighted_rmat(6, 13);
        let srv = server(&g, 1, 16);
        let a1 = srv.submit(QueryKind::Ppr { source: 4 }).unwrap().wait().unwrap();
        assert!(!a1.metrics.cache_hit);
        let a2 = srv.submit(QueryKind::Ppr { source: 4 }).unwrap().wait().unwrap();
        assert!(a2.metrics.cache_hit, "second identical ppr query is a cache hit");
        assert_eq!(a1.response, a2.response);
        // a different source misses (keyed per source)
        let a3 = srv.submit(QueryKind::Ppr { source: 5 }).unwrap().wait().unwrap();
        assert!(!a3.metrics.cache_hit);
        let report = srv.shutdown();
        assert_eq!(report.cache_hits, 1);
        assert_eq!(report.served, 3);
    }

    #[test]
    fn mutation_commit_invalidates_cached_ppr_answers() {
        let g = path_graph(4);
        let srv = server(&g, 1, 16);
        let a1 = srv.submit(QueryKind::Ppr { source: 0 }).unwrap().wait().unwrap();
        let a2 = srv.submit(QueryKind::Ppr { source: 0 }).unwrap().wait().unwrap();
        assert!(a2.metrics.cache_hit);
        srv.submit_mutation(DeltaBatch {
            ops: vec![MutationOp::Insert { src: 0, dst: 3, weight: None }],
        })
        .wait()
        .unwrap();
        let a3 = srv.submit(QueryKind::Ppr { source: 0 }).unwrap().wait().unwrap();
        assert!(!a3.metrics.cache_hit, "commit must invalidate cached ranks");
        // the inserted 0->3 edge redirects mass: the answer really changed
        assert_ne!(a1.response, a3.response);
        srv.shutdown();
    }

    #[test]
    fn saturation_rejects_typed_and_drains_on_shutdown() {
        let g = weighted_rmat(6, 7);
        // no workers: admitted queries stay queued, so saturation is
        // deterministic
        let srv = server(&g, 0, 2);
        let t1 = srv.submit(QueryKind::Bfs { source: 0 }).unwrap();
        let _t2 = srv.submit(QueryKind::Bfs { source: 1 }).unwrap();
        let err = srv.submit(QueryKind::Bfs { source: 2 }).unwrap_err();
        assert!(matches!(err, AdmissionError::Saturated { in_flight: 2, limit: 2 }));
        assert_eq!(srv.in_flight(), 2);
        let report = srv.shutdown();
        assert_eq!(report.rejected, 1);
        // with no workers the pending tickets resolve to Disconnected
        assert_eq!(t1.wait().unwrap_err(), ServeError::Disconnected);
    }

    #[test]
    fn repeated_sources_hit_the_lane_cache() {
        let g = weighted_rmat(6, 11);
        let srv = server(&g, 1, 16);
        let a1 = srv.submit(QueryKind::Bfs { source: 5 }).unwrap().wait().unwrap();
        assert!(!a1.metrics.cache_hit);
        let a2 = srv.submit(QueryKind::Bfs { source: 5 }).unwrap().wait().unwrap();
        assert!(a2.metrics.cache_hit, "second identical query is a cache hit");
        assert_eq!(a1.response, a2.response);
        // reach shares the cached lane
        let a3 = srv.submit(QueryKind::Reach { source: 5 }).unwrap().wait().unwrap();
        assert!(a3.metrics.cache_hit);
        let report = srv.shutdown();
        assert_eq!(report.cache_hits, 2);
        assert_eq!(report.served, 3);
    }

    #[test]
    fn sssp_on_unweighted_graph_is_a_typed_unsupported_error() {
        let el = rmat(&RmatParams::paper(6, 3));
        let g = CsrGraph::from_edge_list(&el);
        let srv = server(&g, 1, 16);
        let err = srv.submit(QueryKind::Sssp { source: 0 }).unwrap().wait().unwrap_err();
        assert!(matches!(err, ServeError::Unsupported(_)));
        assert!(format!("{err}").contains("weighted"));
    }

    /// 0 → 1 → … → n-1 (unweighted): BFS levels from 0 are the vertex ids.
    fn path_graph(n: u32) -> CsrGraph {
        let mut el = EdgeList::new(n as usize);
        for v in 0..n - 1 {
            el.push(v, v + 1);
        }
        CsrGraph::from_edge_list(&el)
    }

    #[test]
    fn post_mutation_query_never_sees_pre_mutation_cache() {
        // ISSUE 9 acceptance: a post-mutation serve query must provably
        // never be answered from a pre-mutation cached lane.
        let g = path_graph(4);
        let srv = server(&g, 1, 16);
        let a1 = srv.submit(QueryKind::Bfs { source: 0 }).unwrap().wait().unwrap();
        assert_eq!(levels(&a1), &[0, 1, 2, 3]);
        let a2 = srv.submit(QueryKind::Bfs { source: 0 }).unwrap().wait().unwrap();
        assert!(a2.metrics.cache_hit, "identical pre-mutation query hits the cache");

        let batch =
            DeltaBatch { ops: vec![MutationOp::Insert { src: 0, dst: 3, weight: None }] };
        let report = srv.submit_mutation(batch).wait().unwrap();
        assert_eq!(report.epoch, 1);
        assert_eq!(report.inserted, 1);
        assert_eq!(srv.epoch(), 1);

        let a3 = srv.submit(QueryKind::Bfs { source: 0 }).unwrap().wait().unwrap();
        assert!(!a3.metrics.cache_hit, "commit must invalidate the cached lane");
        assert_eq!(levels(&a3), &[0, 1, 2, 1], "answer reflects the inserted shortcut");
        // and the new epoch caches normally
        let a4 = srv.submit(QueryKind::Bfs { source: 0 }).unwrap().wait().unwrap();
        assert!(a4.metrics.cache_hit);
        let r = srv.shutdown();
        assert_eq!(r.mutations, 1);
        assert_eq!(r.edges_inserted, 1);
    }

    #[test]
    fn mutations_linearize_with_queries_in_fifo_order() {
        let g = path_graph(4);
        let srv = server(&g, 1, 16);
        // pre-mutation query is ahead of the mutation in the FIFO, so it is
        // answered against the pre-commit graph even if still queued when
        // the mutation is submitted
        let pre = srv.submit(QueryKind::Bfs { source: 1 }).unwrap();
        let mt = srv.submit_mutation(DeltaBatch {
            ops: vec![MutationOp::Insert { src: 1, dst: 3, weight: None }],
        });
        // the commit implies the pre query already dispatched (FIFO ahead
        // of the mutation), so its answer describes the pre-commit graph
        mt.wait().unwrap();
        assert_eq!(levels(&pre.wait().unwrap()), &[INF_I32, 0, 1, 2]);
        let post = srv.submit(QueryKind::Bfs { source: 1 }).unwrap();
        let post = post.wait().unwrap();
        assert!(!post.metrics.cache_hit, "pre-commit lane cannot answer post-commit");
        assert_eq!(levels(&post), &[INF_I32, 0, 1, 1]);
        srv.shutdown();
    }

    #[test]
    fn reject_policy_bounces_stale_epoch_queries() {
        let g = path_graph(4);
        let cfg = ServerConfig {
            workers: 0, // dispatch by hand for determinism
            mutation_policy: MutationPolicy::Reject,
            ..ServerConfig::new(EngineConfig::host_only(2))
        };
        let srv = Server::start(g, cfg).unwrap();
        let t = srv.submit(QueryKind::Bfs { source: 0 }).unwrap(); // epoch 0
        // a commit lands while the query is still queued
        let (mtx, mrx) = mpsc::channel();
        apply_mutation(
            &srv.shared,
            MutationJob {
                batch: DeltaBatch {
                    ops: vec![MutationOp::Insert { src: 0, dst: 3, weight: None }],
                },
                tx: mtx,
            },
        );
        assert_eq!(mrx.recv().unwrap().unwrap().epoch, 1);
        // dispatch the stranded query
        let work = {
            let mut q = srv.shared.queue.lock().unwrap();
            take_work(&mut q, 64)
        };
        match work {
            Work::Batch { pendings, lane_sources, lane_of } => {
                run_batch(&srv.shared, pendings, &lane_sources, &lane_of)
            }
            _ => panic!("a queued bfs dispatches as a batch"),
        }
        assert_eq!(t.wait().unwrap_err(), ServeError::StaleEpoch { submitted: 0, current: 1 });
        let r = srv.shutdown();
        assert_eq!(r.stale_epoch_rejects, 1);
        assert_eq!(r.served, 0, "a bounced query is not an answer");
    }

    #[test]
    fn take_work_never_batches_across_a_mutation() {
        let adm = Admission::new(16);
        let mut pend = |kind: QueryKind| {
            let (tx, _rx) = mpsc::channel();
            // receiver dropped: sends become no-ops, fine for a queue test
            Entry::Query(Pending {
                kind,
                epoch: 0,
                _guard: adm.try_admit().unwrap(),
                enqueued_at: Instant::now(),
                tx,
            })
        };
        let (mtx, _mrx) = mpsc::channel();
        let mut q = VecDeque::new();
        q.push_back(pend(QueryKind::Bfs { source: 0 }));
        q.push_back(pend(QueryKind::Bfs { source: 1 }));
        q.push_back(Entry::Mutation(MutationJob { batch: DeltaBatch { ops: vec![] }, tx: mtx }));
        q.push_back(pend(QueryKind::Bfs { source: 2 }));
        match take_work(&mut q, 64) {
            Work::Batch { lane_sources, .. } => {
                assert_eq!(lane_sources, vec![0, 1], "batching stops at the mutation")
            }
            _ => panic!("batchable head dispatches as a batch"),
        }
        assert!(matches!(take_work(&mut q, 64), Work::Mutate(_)));
        match take_work(&mut q, 64) {
            Work::Batch { lane_sources, .. } => assert_eq!(lane_sources, vec![2]),
            _ => panic!("post-mutation query dispatches on its own"),
        }
        assert!(q.is_empty());
    }

    #[test]
    fn failed_mutation_leaves_graph_and_epoch_untouched() {
        let g = path_graph(3);
        let srv = server(&g, 1, 16);
        let before = srv.fingerprint();
        // weight on an unweighted graph is a typed arity error
        let err = srv
            .submit_mutation(DeltaBatch {
                ops: vec![MutationOp::Insert { src: 0, dst: 2, weight: Some(1.0) }],
            })
            .wait()
            .unwrap_err();
        assert!(matches!(err, ServeError::Mutation(_)));
        assert_eq!(srv.epoch(), 0, "failed apply publishes no epoch");
        assert_eq!(srv.fingerprint(), before, "graph is unchanged");
        let a = srv.submit(QueryKind::Bfs { source: 0 }).unwrap().wait().unwrap();
        assert_eq!(levels(&a), &[0, 1, 2]);
        let r = srv.shutdown();
        assert_eq!(r.mutations, 0);
    }

    fn levels(a: &QueryAnswer) -> &[i32] {
        match &a.response {
            QueryResponse::Levels(l) => l.as_slice(),
            other => panic!("expected levels, got {other:?}"),
        }
    }

    #[test]
    fn a_burst_of_batchable_queries_answers_in_few_batches() {
        let g = weighted_rmat(7, 19);
        // single worker: the first query dispatches solo-ish, the rest
        // pile up and must leave in (at most a few) batched runs
        let srv = server(&g, 1, 64);
        let tickets: Vec<Ticket> = (0..24)
            .map(|s| srv.submit(QueryKind::Bfs { source: s % 12 }).unwrap())
            .collect();
        let cfg = EngineConfig::host_only(2);
        for (s, t) in tickets.into_iter().enumerate() {
            let a = t.wait().unwrap();
            let want = engine::run(&g, &mut Bfs::new((s % 12) as u32), &cfg).unwrap();
            match a.response {
                QueryResponse::Levels(got) => assert_eq!(got.as_slice(), want.output.as_i32()),
                other => panic!("bfs answered with {other:?}"),
            }
        }
        let report = srv.shutdown();
        // cache hits + batching: far fewer engine runs than queries
        assert!(
            report.batches + report.cache_hits < 24,
            "24 queries should not take 24 runs (batches {}, cache hits {})",
            report.batches,
            report.cache_hits
        );
        assert_eq!(report.served, 24);
    }
}
