//! Lane-packing policy: which queued queries share one bit-parallel
//! traversal, and which u64 bit lane each rides (DESIGN.md §13.3).
//!
//! The policy is deliberately a **pure function** over the queued query
//! kinds — no clocks, no server state — so the contract is testable in
//! isolation and cross-checked offline by `tools/cross_check_serving.py`:
//!
//! 1. the head query anchors the batch (FIFO: the oldest admitted query
//!    never waits for younger ones);
//! 2. every lane-batchable query (`bfs`/`reach`) whose source already has
//!    a lane **joins** it (dedup — repeated hot sources cost one lane);
//! 3. a new source opens the next lane while fewer than
//!    `min(max_batch, 64)` lanes are open;
//! 4. non-batchable queries are never reordered into a batch, and
//!    batchable queries beyond the lane budget stay queued in order.
//!
//! Lane order is first-seen query order, so lane `b` of the resulting
//! [`crate::alg::msbfs::MsBfs`] run is BFS from `lane_sources[b]` and the
//! engine's lane-for-lane bit-identity contract maps each query straight
//! to its solo-run answer.

use super::workload::QueryKind;
use crate::alg::msbfs::MAX_LANES;

/// Outcome of batch selection over a queue snapshot.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct BatchSelection {
    /// Indices (into the snapshot) of the queries taken, head first.
    pub picked: Vec<usize>,
    /// One traversal source per lane, in lane order.
    pub lane_sources: Vec<u32>,
    /// `lane_of[j]` is the lane serving `picked[j]`.
    pub lane_of: Vec<usize>,
}

impl BatchSelection {
    pub fn width(&self) -> usize {
        self.lane_sources.len()
    }
}

/// Select the batch anchored at `kinds[0]` (which must be lane-batchable;
/// callers dispatch non-batchable heads solo). `max_batch` caps the lane
/// budget and is itself capped by the 64 bit lanes of a u64.
pub fn select_batch(kinds: &[QueryKind], max_batch: usize) -> BatchSelection {
    let budget = max_batch.clamp(1, MAX_LANES);
    debug_assert!(kinds[0].batchable(), "head must be lane-batchable");
    let mut picked = Vec::new();
    let mut lane_sources: Vec<u32> = Vec::new();
    let mut lane_of = Vec::new();
    for (i, k) in kinds.iter().enumerate() {
        let Some(src) = k.lane_source() else { continue };
        if let Some(lane) = lane_sources.iter().position(|&s| s == src) {
            picked.push(i);
            lane_of.push(lane);
        } else if lane_sources.len() < budget {
            picked.push(i);
            lane_of.push(lane_sources.len());
            lane_sources.push(src);
        }
        // else: lane budget full and this source is new — stays queued
    }
    BatchSelection { picked, lane_sources, lane_of }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn bfs(s: u32) -> QueryKind {
        QueryKind::Bfs { source: s }
    }

    #[test]
    fn batches_compatible_queries_in_fifo_order() {
        let kinds = [bfs(5), QueryKind::Reach { source: 7 }, bfs(9)];
        let b = select_batch(&kinds, 64);
        assert_eq!(b.picked, vec![0, 1, 2]);
        assert_eq!(b.lane_sources, vec![5, 7, 9]);
        assert_eq!(b.lane_of, vec![0, 1, 2]);
        assert_eq!(b.width(), 3);
    }

    #[test]
    fn repeated_sources_share_a_lane() {
        let kinds = [bfs(5), QueryKind::Reach { source: 5 }, bfs(5), bfs(8)];
        let b = select_batch(&kinds, 64);
        assert_eq!(b.picked, vec![0, 1, 2, 3]);
        assert_eq!(b.lane_sources, vec![5, 8], "dedup: hot source costs one lane");
        assert_eq!(b.lane_of, vec![0, 0, 0, 1]);
    }

    #[test]
    fn non_batchable_queries_are_left_in_place() {
        let kinds = [bfs(1), QueryKind::Pagerank, QueryKind::Sssp { source: 2 }, bfs(3)];
        let b = select_batch(&kinds, 64);
        assert_eq!(b.picked, vec![0, 3]);
        assert_eq!(b.lane_sources, vec![1, 3]);
    }

    #[test]
    fn ppr_is_skipped_without_reordering() {
        // PPR carries a source but must NOT be folded into a lane: its
        // f32 ranks cannot ride a bit lane. It stays queued, in place,
        // and the batchable queries around it keep their FIFO order.
        let kinds = [bfs(1), QueryKind::Ppr { source: 1 }, bfs(2), QueryKind::Ppr { source: 9 }];
        let b = select_batch(&kinds, 64);
        assert_eq!(b.picked, vec![0, 2], "ppr never picked, order preserved");
        assert_eq!(b.lane_sources, vec![1, 2]);
        assert_eq!(b.lane_of, vec![0, 1]);
    }

    #[test]
    fn lane_budget_caps_new_sources_but_not_joins() {
        let kinds = [bfs(1), bfs(2), bfs(3), bfs(1)];
        let b = select_batch(&kinds, 2);
        // sources 1 and 2 open the two lanes; 3 is over budget; the
        // second source-1 query still joins lane 0
        assert_eq!(b.picked, vec![0, 1, 3]);
        assert_eq!(b.lane_sources, vec![1, 2]);
        assert_eq!(b.lane_of, vec![0, 1, 0]);
    }

    #[test]
    fn budget_is_clamped_to_u64_lanes() {
        let kinds: Vec<QueryKind> = (0..100).map(|s| bfs(s as u32)).collect();
        let b = select_batch(&kinds, 1000);
        assert_eq!(b.width(), MAX_LANES);
        assert_eq!(b.picked.len(), MAX_LANES);
    }
}
