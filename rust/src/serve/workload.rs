//! Query vocabulary and replayable query files for `totem serve`
//! (DESIGN.md §13.5).
//!
//! A query file is one query per line, `#` comments and blank lines
//! ignored:
//!
//! ```text
//! bfs 17        # full level array from source 17
//! reach 17      # reachable-set bit from source 17 (batches with bfs)
//! sssp 42       # weighted distances (requires a weighted graph)
//! pagerank      # fixed-round ranks
//! ppr 17        # personalized PageRank from source 17 (DESIGN.md §15.4)
//! ```
//!
//! Replay paces submissions at a configured arrival rate
//! (queries/second; `0` = submit as fast as possible), which is how the
//! serving benchmarks model open-loop load. File parsing reports a
//! typed [`QueryParseError`] carrying the 1-based line number, so a bad
//! line in a 10k-query replay names itself instead of failing wholesale.

use anyhow::{bail, Result};
use std::fmt;

/// One query. `Bfs` and `Reach` are **lane-compatible**: both are
/// answered by one bit lane of a multi-source traversal, so the batcher
/// may pack them into the same run.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum QueryKind {
    /// Per-vertex BFS levels from `source`.
    Bfs { source: u32 },
    /// Per-vertex reachability from `source` (a BFS that only keeps the
    /// seen bit — served from the same lane as [`QueryKind::Bfs`]).
    Reach { source: u32 },
    /// Weighted single-source shortest paths from `source`.
    Sssp { source: u32 },
    /// Fixed-round PageRank over the whole graph.
    Pagerank,
    /// Personalized PageRank from `source` (DESIGN.md §15.4). Not
    /// lane-batchable — its f32 ranks cannot ride a bit lane — but
    /// cacheable per `(version, source)` like a lane answer.
    Ppr { source: u32 },
}

impl QueryKind {
    /// Can this query ride a bit lane of a batched traversal?
    pub fn batchable(&self) -> bool {
        matches!(self, QueryKind::Bfs { .. } | QueryKind::Reach { .. })
    }

    /// The traversal source for lane-batchable kinds.
    pub fn lane_source(&self) -> Option<u32> {
        match *self {
            QueryKind::Bfs { source } | QueryKind::Reach { source } => Some(source),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            QueryKind::Bfs { .. } => "bfs",
            QueryKind::Reach { .. } => "reach",
            QueryKind::Sssp { .. } => "sssp",
            QueryKind::Pagerank => "pagerank",
            QueryKind::Ppr { .. } => "ppr",
        }
    }
}

/// A query-file line that failed to parse: the 1-based line number plus
/// the per-line reason (which names an unknown kind when that is the
/// failure). Typed so callers can point at the exact line of a large
/// replay file rather than re-scanning it.
#[derive(Debug)]
pub struct QueryParseError {
    /// 1-based line number in the query file.
    pub line: usize,
    pub reason: String,
}

impl fmt::Display for QueryParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "query file line {}: {}", self.line, self.reason)
    }
}

impl std::error::Error for QueryParseError {}

/// Parse one query line (already comment/blank-filtered).
pub fn parse_query(line: &str) -> Result<QueryKind> {
    let mut it = line.split_whitespace();
    let head = it.next().expect("caller filters blank lines");
    let arg = it.next();
    if it.next().is_some() {
        bail!("query '{line}': trailing tokens");
    }
    let source = |what: &str| -> Result<u32> {
        let Some(a) = arg else { bail!("query '{line}': {what} needs a source vertex") };
        a.parse::<u32>()
            .map_err(|_| anyhow::anyhow!("query '{line}': bad source '{a}'"))
    };
    match head.to_ascii_lowercase().as_str() {
        "bfs" => Ok(QueryKind::Bfs { source: source("bfs")? }),
        "reach" => Ok(QueryKind::Reach { source: source("reach")? }),
        "sssp" => Ok(QueryKind::Sssp { source: source("sssp")? }),
        "pagerank" | "pr" => {
            if arg.is_some() {
                bail!("query '{line}': pagerank takes no source");
            }
            Ok(QueryKind::Pagerank)
        }
        "ppr" => Ok(QueryKind::Ppr { source: source("ppr")? }),
        other => bail!("query '{line}': unknown kind '{other}' (bfs|reach|sssp|pagerank|ppr)"),
    }
}

/// Parse a whole query file (one query per line; `#` comments). The
/// first bad line aborts with a [`QueryParseError`] naming its 1-based
/// line number.
pub fn parse_query_file(text: &str) -> Result<Vec<QueryKind>> {
    let mut out = Vec::new();
    for (i, raw) in text.lines().enumerate() {
        let line = raw.split('#').next().unwrap_or("").trim();
        if line.is_empty() {
            continue;
        }
        match parse_query(line) {
            Ok(q) => out.push(q),
            Err(e) => {
                return Err(QueryParseError { line: i + 1, reason: format!("{e}") }.into());
            }
        }
    }
    Ok(out)
}

/// Seeded synthetic load for `totem serve` without `--queries`: a
/// deterministic bfs/reach/ppr mix over xorshift sources (repeats occur
/// by design — they exercise lane dedup, the lane cache, and the PPR
/// result cache). Half the stream is lane-batchable bfs, a quarter
/// reach (dedups against the bfs lanes), a quarter ppr (must be skipped
/// by the lane batcher without reordering).
pub fn synthetic_mix(n: usize, seed: u64, vertex_count: u32) -> Vec<QueryKind> {
    let mut x = seed | 1;
    (0..n)
        .map(|i| {
            x ^= x << 13;
            x ^= x >> 7;
            x ^= x << 17;
            let source = (x % vertex_count.max(1) as u64) as u32;
            match i % 4 {
                0 | 2 => QueryKind::Bfs { source },
                1 => QueryKind::Reach { source },
                _ => QueryKind::Ppr { source },
            }
        })
        .collect()
}

/// Inter-arrival pacing for replay: at `rate_qps == 0` every delay is
/// zero (closed-loop, as fast as the server admits); otherwise queries
/// arrive uniformly spaced at the configured open-loop rate.
pub fn arrival_delay_secs(rate_qps: f64) -> f64 {
    if rate_qps <= 0.0 {
        0.0
    } else {
        1.0 / rate_qps
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_the_query_vocabulary() {
        assert_eq!(parse_query("bfs 17").unwrap(), QueryKind::Bfs { source: 17 });
        assert_eq!(parse_query("REACH 4").unwrap(), QueryKind::Reach { source: 4 });
        assert_eq!(parse_query("sssp 42").unwrap(), QueryKind::Sssp { source: 42 });
        assert_eq!(parse_query("pagerank").unwrap(), QueryKind::Pagerank);
        assert_eq!(parse_query("pr").unwrap(), QueryKind::Pagerank);
        assert_eq!(parse_query("ppr 7").unwrap(), QueryKind::Ppr { source: 7 });
        assert_eq!(parse_query("PPR 7").unwrap(), QueryKind::Ppr { source: 7 });
    }

    #[test]
    fn rejects_malformed_queries() {
        assert!(parse_query("bfs").is_err(), "missing source");
        assert!(parse_query("bfs x").is_err(), "non-numeric source");
        assert!(parse_query("bfs 1 2").is_err(), "trailing tokens");
        assert!(parse_query("pagerank 3").is_err(), "pagerank takes no source");
        assert!(parse_query("ppr").is_err(), "ppr needs a source");
        assert!(parse_query("dijkstra 1").is_err(), "unknown kind");
    }

    #[test]
    fn file_errors_carry_the_line_number_and_kind() {
        // line 4 (1-based, counting the comment and blank) is the bad one
        let err = parse_query_file("# header\nbfs 1\n\ndijkstra 9\n").unwrap_err();
        let typed = err.downcast_ref::<QueryParseError>().expect("typed error");
        assert_eq!(typed.line, 4);
        assert!(typed.reason.contains("dijkstra"), "{}", typed.reason);
        assert!(format!("{typed}").contains("line 4"));
        // a malformed-but-known kind also names its line
        let err = parse_query_file("bfs 1\nppr\n").unwrap_err();
        assert_eq!(err.downcast_ref::<QueryParseError>().unwrap().line, 2);
    }

    #[test]
    fn file_parsing_skips_comments_and_blanks() {
        let qs = parse_query_file("# header\nbfs 1\n\n  reach 2 # inline\npagerank\n").unwrap();
        assert_eq!(
            qs,
            vec![
                QueryKind::Bfs { source: 1 },
                QueryKind::Reach { source: 2 },
                QueryKind::Pagerank
            ]
        );
    }

    #[test]
    fn batchability_and_lane_sources() {
        assert!(QueryKind::Bfs { source: 1 }.batchable());
        assert!(QueryKind::Reach { source: 1 }.batchable());
        assert!(!QueryKind::Sssp { source: 1 }.batchable());
        assert!(!QueryKind::Pagerank.batchable());
        assert!(!QueryKind::Ppr { source: 1 }.batchable(), "f32 ranks cannot ride a bit lane");
        assert_eq!(QueryKind::Reach { source: 9 }.lane_source(), Some(9));
        assert_eq!(QueryKind::Pagerank.lane_source(), None);
        assert_eq!(QueryKind::Ppr { source: 9 }.lane_source(), None);
    }

    #[test]
    fn synthetic_mix_is_seeded_and_mixed() {
        let a = synthetic_mix(64, 42, 1000);
        let b = synthetic_mix(64, 42, 1000);
        assert_eq!(a, b, "same seed, same stream");
        let c = synthetic_mix(64, 43, 1000);
        assert_ne!(a, c, "different seed, different sources");
        let ppr = a.iter().filter(|q| matches!(q, QueryKind::Ppr { .. })).count();
        let lane = a.iter().filter(|q| q.batchable()).count();
        assert_eq!(ppr, 16, "a quarter of the stream is ppr");
        assert_eq!(lane, 48, "the rest is lane-batchable bfs/reach");
        for q in &a {
            assert!(q.lane_source().unwrap_or_else(|| match q {
                QueryKind::Ppr { source } => *source,
                _ => unreachable!(),
            }) < 1000);
        }
    }

    #[test]
    fn arrival_pacing() {
        assert_eq!(arrival_delay_secs(0.0), 0.0);
        assert!((arrival_delay_secs(200.0) - 0.005).abs() < 1e-12);
    }
}
