//! Query vocabulary and replayable query files for `totem serve`
//! (DESIGN.md §13.5).
//!
//! A query file is one query per line, `#` comments and blank lines
//! ignored:
//!
//! ```text
//! bfs 17        # full level array from source 17
//! reach 17      # reachable-set bit from source 17 (batches with bfs)
//! sssp 42       # weighted distances (requires a weighted graph)
//! pagerank      # fixed-round ranks
//! ```
//!
//! Replay paces submissions at a configured arrival rate
//! (queries/second; `0` = submit as fast as possible), which is how the
//! serving benchmarks model open-loop load.

use anyhow::{bail, Result};

/// One query. `Bfs` and `Reach` are **lane-compatible**: both are
/// answered by one bit lane of a multi-source traversal, so the batcher
/// may pack them into the same run.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum QueryKind {
    /// Per-vertex BFS levels from `source`.
    Bfs { source: u32 },
    /// Per-vertex reachability from `source` (a BFS that only keeps the
    /// seen bit — served from the same lane as [`QueryKind::Bfs`]).
    Reach { source: u32 },
    /// Weighted single-source shortest paths from `source`.
    Sssp { source: u32 },
    /// Fixed-round PageRank over the whole graph.
    Pagerank,
}

impl QueryKind {
    /// Can this query ride a bit lane of a batched traversal?
    pub fn batchable(&self) -> bool {
        matches!(self, QueryKind::Bfs { .. } | QueryKind::Reach { .. })
    }

    /// The traversal source for lane-batchable kinds.
    pub fn lane_source(&self) -> Option<u32> {
        match *self {
            QueryKind::Bfs { source } | QueryKind::Reach { source } => Some(source),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            QueryKind::Bfs { .. } => "bfs",
            QueryKind::Reach { .. } => "reach",
            QueryKind::Sssp { .. } => "sssp",
            QueryKind::Pagerank => "pagerank",
        }
    }
}

/// Parse one query line (already comment/blank-filtered).
pub fn parse_query(line: &str) -> Result<QueryKind> {
    let mut it = line.split_whitespace();
    let head = it.next().expect("caller filters blank lines");
    let arg = it.next();
    if it.next().is_some() {
        bail!("query '{line}': trailing tokens");
    }
    let source = |what: &str| -> Result<u32> {
        let Some(a) = arg else { bail!("query '{line}': {what} needs a source vertex") };
        a.parse::<u32>()
            .map_err(|_| anyhow::anyhow!("query '{line}': bad source '{a}'"))
    };
    match head.to_ascii_lowercase().as_str() {
        "bfs" => Ok(QueryKind::Bfs { source: source("bfs")? }),
        "reach" => Ok(QueryKind::Reach { source: source("reach")? }),
        "sssp" => Ok(QueryKind::Sssp { source: source("sssp")? }),
        "pagerank" | "pr" => {
            if arg.is_some() {
                bail!("query '{line}': pagerank takes no source");
            }
            Ok(QueryKind::Pagerank)
        }
        other => bail!("query '{line}': unknown kind '{other}' (bfs|reach|sssp|pagerank)"),
    }
}

/// Parse a whole query file (one query per line; `#` comments).
pub fn parse_query_file(text: &str) -> Result<Vec<QueryKind>> {
    text.lines()
        .map(|l| l.split('#').next().unwrap_or("").trim())
        .filter(|l| !l.is_empty())
        .map(parse_query)
        .collect()
}

/// Inter-arrival pacing for replay: at `rate_qps == 0` every delay is
/// zero (closed-loop, as fast as the server admits); otherwise queries
/// arrive uniformly spaced at the configured open-loop rate.
pub fn arrival_delay_secs(rate_qps: f64) -> f64 {
    if rate_qps <= 0.0 {
        0.0
    } else {
        1.0 / rate_qps
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_the_query_vocabulary() {
        assert_eq!(parse_query("bfs 17").unwrap(), QueryKind::Bfs { source: 17 });
        assert_eq!(parse_query("REACH 4").unwrap(), QueryKind::Reach { source: 4 });
        assert_eq!(parse_query("sssp 42").unwrap(), QueryKind::Sssp { source: 42 });
        assert_eq!(parse_query("pagerank").unwrap(), QueryKind::Pagerank);
        assert_eq!(parse_query("pr").unwrap(), QueryKind::Pagerank);
    }

    #[test]
    fn rejects_malformed_queries() {
        assert!(parse_query("bfs").is_err(), "missing source");
        assert!(parse_query("bfs x").is_err(), "non-numeric source");
        assert!(parse_query("bfs 1 2").is_err(), "trailing tokens");
        assert!(parse_query("pagerank 3").is_err(), "pagerank takes no source");
        assert!(parse_query("dijkstra 1").is_err(), "unknown kind");
    }

    #[test]
    fn file_parsing_skips_comments_and_blanks() {
        let qs = parse_query_file("# header\nbfs 1\n\n  reach 2 # inline\npagerank\n").unwrap();
        assert_eq!(
            qs,
            vec![
                QueryKind::Bfs { source: 1 },
                QueryKind::Reach { source: 2 },
                QueryKind::Pagerank
            ]
        );
    }

    #[test]
    fn batchability_and_lane_sources() {
        assert!(QueryKind::Bfs { source: 1 }.batchable());
        assert!(QueryKind::Reach { source: 1 }.batchable());
        assert!(!QueryKind::Sssp { source: 1 }.batchable());
        assert!(!QueryKind::Pagerank.batchable());
        assert_eq!(QueryKind::Reach { source: 9 }.lane_source(), Some(9));
        assert_eq!(QueryKind::Pagerank.lane_source(), None);
    }

    #[test]
    fn arrival_pacing() {
        assert_eq!(arrival_delay_secs(0.0), 0.0);
        assert!((arrival_delay_secs(200.0) - 0.005).abs() < 1e-12);
    }
}
