//! Per-query metrics and the server-level aggregate report
//! (DESIGN.md §13.6).
//!
//! Every answered query records where its latency went — queue wait
//! versus compute — plus the superstep count and traversal rate of the
//! run that answered it. The server aggregates these into counters,
//! means, and a **log2-bucket latency histogram** (microsecond-indexed,
//! so one histogram spans cache hits in the tens of microseconds and
//! billion-edge traversals in the tens of seconds without tuning bucket
//! edges).

use std::fmt;
use std::sync::Mutex;

/// What one answered query cost.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct QueryMetrics {
    /// Admission to dispatch: time spent queued behind other queries.
    pub queue_wait_secs: f64,
    /// Dispatch to answer: the engine run (amortized share for batched
    /// queries is NOT taken — each rider records the full batch compute
    /// time, because that is the latency it observed).
    pub compute_secs: f64,
    /// Supersteps of the run that answered this query (0 for cache hits).
    pub supersteps: usize,
    /// Traversed edges / compute_secs of the answering run, in edges/sec
    /// (0.0 for cache hits and non-traversal queries).
    pub teps: f64,
    /// Lanes of the batch that answered this query (1 = solo).
    pub batch_width: usize,
    /// Answered from the lane cache without touching the engine.
    pub cache_hit: bool,
}

/// Log2-bucket latency histogram. Bucket `b` holds latencies in
/// `[2^b, 2^(b+1))` microseconds; bucket 0 also absorbs sub-microsecond
/// samples. 40 buckets cover ~12 days — effectively unbounded.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LatencyHistogram {
    buckets: [u64; Self::BUCKETS],
}

impl LatencyHistogram {
    pub const BUCKETS: usize = 40;

    pub fn new() -> LatencyHistogram {
        LatencyHistogram { buckets: [0; Self::BUCKETS] }
    }

    pub fn record(&mut self, secs: f64) {
        let us = (secs * 1e6).max(0.0) as u64;
        let b = (64 - us.max(1).leading_zeros() as usize - 1).min(Self::BUCKETS - 1);
        self.buckets[b] += 1;
    }

    pub fn count(&self) -> u64 {
        self.buckets.iter().sum()
    }

    /// Latency below which `q` (0..=1) of samples fall, reported as the
    /// upper edge of the containing bucket (conservative). Exception:
    /// `q == 0.0` asks for the *minimum*-latency estimate, so it reports
    /// the first non-empty bucket's **lower** edge — the upper edge would
    /// overstate p0 by up to 2× (ISSUE 9 satellite bug).
    pub fn quantile_secs(&self, q: f64) -> f64 {
        let total = self.count();
        if total == 0 {
            return 0.0;
        }
        let q = q.clamp(0.0, 1.0);
        let target = (q * total as f64).ceil().max(1.0) as u64;
        let mut seen = 0;
        for (b, &n) in self.buckets.iter().enumerate() {
            seen += n;
            if seen >= target {
                return if q == 0.0 {
                    2f64.powi(b as i32) / 1e6
                } else {
                    2f64.powi(b as i32 + 1) / 1e6
                };
            }
        }
        2f64.powi(Self::BUCKETS as i32) / 1e6
    }

    /// Non-empty buckets as `(lower_us, upper_us, count)` rows.
    pub fn rows(&self) -> Vec<(u64, u64, u64)> {
        self.buckets
            .iter()
            .enumerate()
            .filter(|(_, &n)| n > 0)
            .map(|(b, &n)| (1u64 << b, 1u64 << (b + 1), n))
            .collect()
    }
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        LatencyHistogram::new()
    }
}

/// Aggregate snapshot of a serving session.
#[derive(Debug, Clone, PartialEq)]
pub struct ServeReport {
    /// Queries answered (cache hits included).
    pub served: u64,
    /// Typed admission rejections at submit time.
    pub rejected: u64,
    /// Queries answered from the lane cache.
    pub cache_hits: u64,
    /// Multi-source traversal runs dispatched (width ≥ 1).
    pub batches: u64,
    /// Queries answered by those runs (≥ batches; the surplus is the
    /// batching win).
    pub batched_queries: u64,
    pub mean_queue_wait_secs: f64,
    pub mean_compute_secs: f64,
    /// Mean TEPS over traversal-answering runs (cache hits excluded).
    pub mean_teps: f64,
    /// Mutation batches committed (graph epochs past the initial one).
    pub mutations: u64,
    /// Edges inserted / removed across all committed batches.
    pub edges_inserted: u64,
    pub edges_deleted: u64,
    /// Commits whose load skew triggered a from-scratch reassignment
    /// (the α controller's commit-time tier, DESIGN.md §14.4).
    pub reassignments: u64,
    /// Queries rejected because their admission epoch was retired by a
    /// mutation commit before dispatch (reject policy only).
    pub stale_epoch_rejects: u64,
    /// End-to-end latency (queue wait + compute) distribution.
    pub histogram: LatencyHistogram,
}

impl fmt::Display for ServeReport {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(
            f,
            "served {} (cache hits {}), rejected {}, {} traversal batches answering {} queries",
            self.served, self.cache_hits, self.rejected, self.batches, self.batched_queries
        )?;
        writeln!(
            f,
            "mean queue wait {:.3} ms, mean compute {:.3} ms, mean {:.2} MTEPS, p50 {:.3} ms, p99 {:.3} ms",
            self.mean_queue_wait_secs * 1e3,
            self.mean_compute_secs * 1e3,
            self.mean_teps / 1e6,
            self.histogram.quantile_secs(0.50) * 1e3,
            self.histogram.quantile_secs(0.99) * 1e3,
        )?;
        if self.mutations > 0 || self.stale_epoch_rejects > 0 {
            writeln!(
                f,
                "{} mutation batches (+{} / -{} edges, {} reassignments), {} stale-epoch rejects",
                self.mutations,
                self.edges_inserted,
                self.edges_deleted,
                self.reassignments,
                self.stale_epoch_rejects,
            )?;
        }
        for (lo, hi, n) in self.histogram.rows() {
            writeln!(f, "  [{lo:>9} us, {hi:>9} us)  {n}")?;
        }
        Ok(())
    }
}

/// Thread-safe accumulator behind one mutex — contention is per answered
/// query, negligible next to the engine runs it is measuring.
pub struct ServeMetrics {
    inner: Mutex<Accum>,
}

#[derive(Default)]
struct Accum {
    served: u64,
    rejected: u64,
    cache_hits: u64,
    batches: u64,
    batched_queries: u64,
    queue_wait_sum: f64,
    compute_sum: f64,
    teps_sum: f64,
    teps_samples: u64,
    mutations: u64,
    edges_inserted: u64,
    edges_deleted: u64,
    reassignments: u64,
    stale_epoch_rejects: u64,
    histogram: LatencyHistogram,
}

impl ServeMetrics {
    pub fn new() -> ServeMetrics {
        ServeMetrics { inner: Mutex::new(Accum::default()) }
    }

    pub fn record_query(&self, m: QueryMetrics) {
        let mut a = self.inner.lock().unwrap();
        a.served += 1;
        a.queue_wait_sum += m.queue_wait_secs;
        a.compute_sum += m.compute_secs;
        a.histogram.record(m.queue_wait_secs + m.compute_secs);
        if m.cache_hit {
            a.cache_hits += 1;
        } else if m.teps > 0.0 {
            a.teps_sum += m.teps;
            a.teps_samples += 1;
        }
    }

    pub fn record_rejection(&self) {
        self.inner.lock().unwrap().rejected += 1;
    }

    /// One multi-source run dispatched, answering `queries` queries.
    pub fn record_batch(&self, queries: usize) {
        let mut a = self.inner.lock().unwrap();
        a.batches += 1;
        a.batched_queries += queries as u64;
    }

    /// One mutation batch committed (DESIGN.md §14).
    pub fn record_mutation(&self, inserted: u64, deleted: u64, reassigned: bool) {
        let mut a = self.inner.lock().unwrap();
        a.mutations += 1;
        a.edges_inserted += inserted;
        a.edges_deleted += deleted;
        if reassigned {
            a.reassignments += 1;
        }
    }

    /// One query bounced at an epoch boundary under the reject policy.
    pub fn record_stale_epoch_reject(&self) {
        self.inner.lock().unwrap().stale_epoch_rejects += 1;
    }

    pub fn report(&self) -> ServeReport {
        let a = self.inner.lock().unwrap();
        let served = a.served.max(1) as f64;
        ServeReport {
            served: a.served,
            rejected: a.rejected,
            cache_hits: a.cache_hits,
            batches: a.batches,
            batched_queries: a.batched_queries,
            mean_queue_wait_secs: a.queue_wait_sum / served,
            mean_compute_secs: a.compute_sum / served,
            mean_teps: if a.teps_samples > 0 { a.teps_sum / a.teps_samples as f64 } else { 0.0 },
            mutations: a.mutations,
            edges_inserted: a.edges_inserted,
            edges_deleted: a.edges_deleted,
            reassignments: a.reassignments,
            stale_epoch_rejects: a.stale_epoch_rejects,
            histogram: a.histogram.clone(),
        }
    }
}

impl Default for ServeMetrics {
    fn default() -> Self {
        ServeMetrics::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn q(queue: f64, compute: f64, hit: bool) -> QueryMetrics {
        QueryMetrics {
            queue_wait_secs: queue,
            compute_secs: compute,
            supersteps: if hit { 0 } else { 3 },
            teps: if hit { 0.0 } else { 1e6 },
            batch_width: 1,
            cache_hit: hit,
        }
    }

    #[test]
    fn histogram_buckets_by_log2_microseconds() {
        let mut h = LatencyHistogram::new();
        h.record(3e-6); // 3 us -> bucket [2,4)
        h.record(3e-6);
        h.record(1.0); // 1 s -> bucket [524288, 1048576) us
        assert_eq!(h.count(), 3);
        let rows = h.rows();
        assert_eq!(rows[0], (2, 4, 2));
        assert_eq!(rows[1], (524288, 1048576, 1));
        assert!(h.quantile_secs(0.5) <= 8e-6);
        assert!(h.quantile_secs(1.0) >= 1.0);
    }

    #[test]
    fn quantile_boundaries_use_lower_edge_at_p0_and_upper_at_p100() {
        let mut h = LatencyHistogram::new();
        h.record(3e-6); // bucket [2, 4) us
        h.record(1.0); // bucket [524288, 1048576) us
        // p0: minimum estimate = lower edge of the first non-empty bucket.
        // The pre-fix code returned the upper edge (4 us) here.
        assert_eq!(h.quantile_secs(0.0), 2e-6);
        // p100: conservative maximum = upper edge of the last non-empty bucket.
        assert_eq!(h.quantile_secs(1.0), 1048576e-6);
        // Out-of-range q clamps to the boundaries rather than misbehaving.
        assert_eq!(h.quantile_secs(-1.0), h.quantile_secs(0.0));
        assert_eq!(h.quantile_secs(2.0), h.quantile_secs(1.0));
        // A single-sample histogram: p0 and p100 are the same bucket's
        // opposite edges.
        let mut one = LatencyHistogram::new();
        one.record(3e-6);
        assert_eq!(one.quantile_secs(0.0), 2e-6);
        assert_eq!(one.quantile_secs(1.0), 4e-6);
    }

    #[test]
    fn mutation_counters_aggregate_and_render() {
        let m = ServeMetrics::new();
        m.record_mutation(12, 3, false);
        m.record_mutation(5, 0, true);
        m.record_stale_epoch_reject();
        let r = m.report();
        assert_eq!(r.mutations, 2);
        assert_eq!(r.edges_inserted, 17);
        assert_eq!(r.edges_deleted, 3);
        assert_eq!(r.reassignments, 1);
        assert_eq!(r.stale_epoch_rejects, 1);
        let text = format!("{r}");
        assert!(text.contains("2 mutation batches (+17 / -3 edges, 1 reassignments)"));
        assert!(text.contains("1 stale-epoch rejects"));
        // The mutation line is suppressed for a mutation-free session.
        let quiet = format!("{}", ServeMetrics::new().report());
        assert!(!quiet.contains("mutation batches"));
    }

    #[test]
    fn sub_microsecond_and_huge_samples_stay_in_range() {
        let mut h = LatencyHistogram::new();
        h.record(0.0);
        h.record(1e9);
        assert_eq!(h.count(), 2);
    }

    #[test]
    fn report_aggregates_counters_and_means() {
        let m = ServeMetrics::new();
        m.record_query(q(0.010, 0.090, false));
        m.record_query(q(0.030, 0.000, true));
        m.record_rejection();
        m.record_batch(2);
        let r = m.report();
        assert_eq!(r.served, 2);
        assert_eq!(r.rejected, 1);
        assert_eq!(r.cache_hits, 1);
        assert_eq!(r.batches, 1);
        assert_eq!(r.batched_queries, 2);
        assert!((r.mean_queue_wait_secs - 0.020).abs() < 1e-12);
        assert!((r.mean_compute_secs - 0.045).abs() < 1e-12);
        assert!((r.mean_teps - 1e6).abs() < 1.0, "cache hits excluded from TEPS mean");
        let text = format!("{r}");
        assert!(text.contains("served 2"));
        assert!(text.contains("p99"));
    }

    #[test]
    fn empty_report_is_all_zeros() {
        let r = ServeMetrics::new().report();
        assert_eq!(r.served, 0);
        assert_eq!(r.mean_teps, 0.0);
        assert_eq!(r.histogram.quantile_secs(0.99), 0.0);
    }
}
