//! Per-lane result cache keyed by source **and graph identity**
//! (DESIGN.md §13.4).
//!
//! A lane answer (the i32 level array of one BFS source) is immutable
//! once computed — the served graph is immutable by construction — so
//! repeats of a hot source are cache hits that bypass admission-queue
//! compute entirely. Keys embed a **graph fingerprint**: an FNV-1a hash
//! over the vertex/edge counts and a bounded sample of CSR offsets and
//! column indices. Serving a different graph (even one with identical
//! n/m) changes the fingerprint, so a stale cache can never answer for
//! the wrong graph; reloading the same file reproduces the same
//! fingerprint, so warm caches survive server restarts by design.
//! Invalidation is therefore structural — there is no TTL to tune and no
//! explicit flush: entries are evicted FIFO only to bound memory.

use crate::graph::store::Fnv64;
use crate::graph::CsrGraph;
use std::collections::{HashMap, VecDeque};
use std::sync::{Arc, Mutex};

/// Offsets/columns sampled per array — enough to distinguish graphs that
/// agree on n and m, cheap enough to run at server start on billion-edge
/// inputs (the sample stride adapts to the array length).
const FINGERPRINT_SAMPLES: usize = 1024;

/// FNV-1a fingerprint of a CSR graph: n, m, weightedness, and a strided
/// sample of row offsets and column indices. Reuses the `.tcsr` checksum
/// primitive so `tools/cross_check_serving.py` can mirror it exactly.
pub fn graph_fingerprint(g: &CsrGraph) -> u64 {
    let mut h = Fnv64::new();
    h.update(&(g.vertex_count as u64).to_le_bytes());
    h.update(&(g.edge_count() as u64).to_le_bytes());
    h.update(&(g.weights.is_some() as u64).to_le_bytes());
    let ro = &g.row_offsets[..];
    let stride = (ro.len() / FINGERPRINT_SAMPLES).max(1);
    for i in (0..ro.len()).step_by(stride) {
        h.update(&ro[i].to_le_bytes());
    }
    let cols = &g.col_indices[..];
    let stride = (cols.len() / FINGERPRINT_SAMPLES).max(1);
    for i in (0..cols.len()).step_by(stride) {
        h.update(&(cols[i] as u64).to_le_bytes());
    }
    h.finish()
}

/// Cache key: one lane answer of one graph.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
struct LaneKey {
    fingerprint: u64,
    source: u32,
}

/// Bounded FIFO cache of lane level arrays. Values are `Arc`ed: a hit
/// hands the caller a shared handle, never a copy of an |V|-sized array.
pub struct LaneCache {
    fingerprint: u64,
    capacity: usize,
    inner: Mutex<CacheInner>,
}

struct CacheInner {
    map: HashMap<LaneKey, Arc<Vec<i32>>>,
    fifo: VecDeque<LaneKey>,
}

impl LaneCache {
    /// A cache bound to one served graph. `capacity` 0 disables caching.
    pub fn new(g: &CsrGraph, capacity: usize) -> LaneCache {
        LaneCache {
            fingerprint: graph_fingerprint(g),
            capacity,
            inner: Mutex::new(CacheInner { map: HashMap::new(), fifo: VecDeque::new() }),
        }
    }

    pub fn fingerprint(&self) -> u64 {
        self.fingerprint
    }

    pub fn get(&self, source: u32) -> Option<Arc<Vec<i32>>> {
        let key = LaneKey { fingerprint: self.fingerprint, source };
        self.inner.lock().unwrap().map.get(&key).cloned()
    }

    pub fn insert(&self, source: u32, levels: Arc<Vec<i32>>) {
        if self.capacity == 0 {
            return;
        }
        let key = LaneKey { fingerprint: self.fingerprint, source };
        let mut inner = self.inner.lock().unwrap();
        if inner.map.insert(key, levels).is_none() {
            inner.fifo.push_back(key);
            while inner.fifo.len() > self.capacity {
                let evict = inner.fifo.pop_front().expect("len checked");
                inner.map.remove(&evict);
            }
        }
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::EdgeList;

    fn graph(edges: &[(u32, u32)], n: usize) -> CsrGraph {
        let mut el = EdgeList::new(n);
        for &(u, v) in edges {
            el.push(u, v);
        }
        CsrGraph::from_edge_list(&el)
    }

    #[test]
    fn fingerprint_distinguishes_graphs_and_reproduces() {
        let g1 = graph(&[(0, 1), (1, 2)], 3);
        let g2 = graph(&[(0, 1), (0, 2)], 3); // same n, same m
        assert_ne!(graph_fingerprint(&g1), graph_fingerprint(&g2));
        let g1b = graph(&[(0, 1), (1, 2)], 3);
        assert_eq!(graph_fingerprint(&g1), graph_fingerprint(&g1b), "identity is structural");
    }

    #[test]
    fn hit_returns_the_shared_answer() {
        let g = graph(&[(0, 1)], 2);
        let c = LaneCache::new(&g, 8);
        assert!(c.get(0).is_none());
        c.insert(0, Arc::new(vec![0, 1]));
        assert_eq!(c.get(0).unwrap().as_slice(), &[0, 1]);
        assert!(c.get(1).is_none(), "keyed by source");
    }

    #[test]
    fn fifo_eviction_bounds_memory() {
        let g = graph(&[(0, 1)], 2);
        let c = LaneCache::new(&g, 2);
        c.insert(0, Arc::new(vec![0]));
        c.insert(1, Arc::new(vec![1]));
        c.insert(2, Arc::new(vec![2]));
        assert_eq!(c.len(), 2);
        assert!(c.get(0).is_none(), "oldest evicted");
        assert!(c.get(2).is_some());
    }

    #[test]
    fn zero_capacity_disables_caching() {
        let g = graph(&[(0, 1)], 2);
        let c = LaneCache::new(&g, 0);
        c.insert(0, Arc::new(vec![0]));
        assert!(c.is_empty());
    }
}
