//! Per-source result cache keyed by source **and graph version**
//! (DESIGN.md §13.4, §14.2, §15.4).
//!
//! A per-source answer (the i32 level array of one BFS lane, or the f32
//! rank vector of one personalized-PageRank source) is immutable for as
//! long as the served graph is — which, since streaming mutations
//! landed (DESIGN.md §14), is one *graph epoch*, not the server's
//! lifetime. Keys therefore embed a [`GraphVersion`]: the structural
//! **fingerprint** (an FNV-1a hash over the vertex/edge counts and a
//! bounded sample of CSR offsets and column indices) *and* the mutation
//! **epoch**. [`ResultCache::commit`] moves the cache to the
//! post-mutation version and drops every older entry, and
//! [`ResultCache::insert_at`] refuses answers computed against a retired
//! version (a worker racing a commit must not poison the new epoch) — so
//! a post-mutation query can never be answered from a pre-mutation
//! answer, even in the (fingerprint-collision) case where the mutated
//! graph samples identically.
//!
//! The cache is generic over the answer payload: [`LaneCache`] holds
//! level arrays, [`PprCache`] rank vectors — one eviction/invalidation
//! policy, two payloads, zero duplicated epoch logic.
//!
//! The original version of this cache froze the fingerprint once in
//! `new` and keyed on it forever — correct for an immutable graph,
//! silently stale the moment mutations landed (ISSUE 9 satellite bug).
//! Reloading the same file still reproduces fingerprints across restarts;
//! epochs restart at 0 with the server, which is safe because the cache
//! restarts empty with it.

use crate::graph::store::Fnv64;
use crate::graph::CsrGraph;
use std::collections::{HashMap, VecDeque};
use std::sync::{Arc, Mutex};

/// Offsets/columns sampled per array — enough to distinguish graphs that
/// agree on n and m, cheap enough to run at server start on billion-edge
/// inputs (the sample stride adapts to the array length).
const FINGERPRINT_SAMPLES: usize = 1024;

/// FNV-1a fingerprint of a CSR graph: n, m, weightedness, and a strided
/// sample of row offsets and column indices. Reuses the `.tcsr` checksum
/// primitive so `tools/cross_check_serving.py` can mirror it exactly.
pub fn graph_fingerprint(g: &CsrGraph) -> u64 {
    let mut h = Fnv64::new();
    h.update(&(g.vertex_count as u64).to_le_bytes());
    h.update(&(g.edge_count() as u64).to_le_bytes());
    h.update(&(g.weights.is_some() as u64).to_le_bytes());
    let ro = &g.row_offsets[..];
    let stride = (ro.len() / FINGERPRINT_SAMPLES).max(1);
    for i in (0..ro.len()).step_by(stride) {
        h.update(&ro[i].to_le_bytes());
    }
    let cols = &g.col_indices[..];
    let stride = (cols.len() / FINGERPRINT_SAMPLES).max(1);
    for i in (0..cols.len()).step_by(stride) {
        h.update(&(cols[i] as u64).to_le_bytes());
    }
    h.finish()
}

/// One committed state of the served graph: structural fingerprint plus
/// the monotonically increasing mutation epoch (0 at server start).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct GraphVersion {
    pub fingerprint: u64,
    pub epoch: u64,
}

/// Cache key: one per-source answer of one graph version.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
struct SourceKey {
    version: GraphVersion,
    source: u32,
}

/// Bounded FIFO cache of per-source answers. Values are `Arc`ed: a hit
/// hands the caller a shared handle, never a copy of an |V|-sized array.
pub struct ResultCache<T> {
    capacity: usize,
    inner: Mutex<CacheInner<T>>,
}

/// BFS lane answers (i32 level arrays), shared by `reach` bit queries.
pub type LaneCache = ResultCache<Vec<i32>>;

/// Personalized-PageRank answers (f32 rank vectors), keyed by the query
/// source (DESIGN.md §15.4).
pub type PprCache = ResultCache<Vec<f32>>;

struct CacheInner<T> {
    version: GraphVersion,
    map: HashMap<SourceKey, Arc<T>>,
    fifo: VecDeque<SourceKey>,
}

impl<T> ResultCache<T> {
    /// A cache bound to one served graph at epoch 0. `capacity` 0
    /// disables caching.
    pub fn new(g: &CsrGraph, capacity: usize) -> ResultCache<T> {
        ResultCache {
            capacity,
            inner: Mutex::new(CacheInner {
                version: GraphVersion { fingerprint: graph_fingerprint(g), epoch: 0 },
                map: HashMap::new(),
                fifo: VecDeque::new(),
            }),
        }
    }

    /// The version current entries are keyed under.
    pub fn version(&self) -> GraphVersion {
        self.inner.lock().unwrap().version
    }

    /// Current structural fingerprint (report/display convenience).
    pub fn fingerprint(&self) -> u64 {
        self.version().fingerprint
    }

    /// Move the cache to the post-mutation graph at `epoch`: recompute the
    /// fingerprint and drop every entry of every older version. Called
    /// under the server's graph write lock, so no reader observes the new
    /// graph with the old cache.
    pub fn commit(&self, g: &CsrGraph, epoch: u64) {
        let mut inner = self.inner.lock().unwrap();
        inner.version = GraphVersion { fingerprint: graph_fingerprint(g), epoch };
        inner.map.clear();
        inner.fifo.clear();
    }

    /// Look up a per-source answer for the **current** version.
    pub fn get(&self, source: u32) -> Option<Arc<T>> {
        let inner = self.inner.lock().unwrap();
        let key = SourceKey { version: inner.version, source };
        inner.map.get(&key).cloned()
    }

    /// Insert an answer computed against `version`. Silently dropped when
    /// `version` is no longer current — the answer was computed against a
    /// retired epoch and must not survive the commit that retired it.
    pub fn insert_at(&self, version: GraphVersion, source: u32, answer: Arc<T>) {
        if self.capacity == 0 {
            return;
        }
        let mut inner = self.inner.lock().unwrap();
        if version != inner.version {
            return;
        }
        let key = SourceKey { version, source };
        if inner.map.insert(key, answer).is_none() {
            inner.fifo.push_back(key);
            while inner.fifo.len() > self.capacity {
                let evict = inner.fifo.pop_front().expect("len checked");
                inner.map.remove(&evict);
            }
        }
    }

    /// Insert at the current version (single-epoch callers and tests).
    pub fn insert(&self, source: u32, answer: Arc<T>) {
        let version = self.version();
        self.insert_at(version, source, answer);
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::EdgeList;

    fn graph(edges: &[(u32, u32)], n: usize) -> CsrGraph {
        let mut el = EdgeList::new(n);
        for &(u, v) in edges {
            el.push(u, v);
        }
        CsrGraph::from_edge_list(&el)
    }

    #[test]
    fn fingerprint_distinguishes_graphs_and_reproduces() {
        let g1 = graph(&[(0, 1), (1, 2)], 3);
        let g2 = graph(&[(0, 1), (0, 2)], 3); // same n, same m
        assert_ne!(graph_fingerprint(&g1), graph_fingerprint(&g2));
        let g1b = graph(&[(0, 1), (1, 2)], 3);
        assert_eq!(graph_fingerprint(&g1), graph_fingerprint(&g1b), "identity is structural");
    }

    #[test]
    fn hit_returns_the_shared_answer() {
        let g = graph(&[(0, 1)], 2);
        let c = LaneCache::new(&g, 8);
        assert!(c.get(0).is_none());
        c.insert(0, Arc::new(vec![0, 1]));
        assert_eq!(c.get(0).unwrap().as_slice(), &[0, 1]);
        assert!(c.get(1).is_none(), "keyed by source");
    }

    #[test]
    fn fifo_eviction_bounds_memory() {
        let g = graph(&[(0, 1)], 2);
        let c = LaneCache::new(&g, 2);
        c.insert(0, Arc::new(vec![0]));
        c.insert(1, Arc::new(vec![1]));
        c.insert(2, Arc::new(vec![2]));
        assert_eq!(c.len(), 2);
        assert!(c.get(0).is_none(), "oldest evicted");
        assert!(c.get(2).is_some());
    }

    #[test]
    fn zero_capacity_disables_caching() {
        let g = graph(&[(0, 1)], 2);
        let c = LaneCache::new(&g, 0);
        c.insert(0, Arc::new(vec![0]));
        assert!(c.is_empty());
    }

    #[test]
    fn commit_invalidates_prior_epoch_entries() {
        // regression: the pre-ISSUE-9 cache froze its fingerprint in `new`
        // and would keep answering for a graph that no longer exists
        let g = graph(&[(0, 1)], 2);
        let c = LaneCache::new(&g, 8);
        c.insert(0, Arc::new(vec![0, 1]));
        assert!(c.get(0).is_some());

        let mutated = graph(&[(0, 1), (1, 0)], 2);
        c.commit(&mutated, 1);
        assert!(c.get(0).is_none(), "post-mutation query must miss");
        assert!(c.is_empty(), "retired entries are dropped, not shadowed");
        assert_eq!(c.version().epoch, 1);

        c.insert(0, Arc::new(vec![0, 1]));
        assert!(c.get(0).is_some(), "new epoch caches normally");
    }

    #[test]
    fn epoch_distinguishes_identical_structures() {
        // same structure re-committed at a later epoch: even a fingerprint
        // match cannot resurrect old entries (epoch is part of the key)
        let g = graph(&[(0, 1)], 2);
        let c = LaneCache::new(&g, 8);
        c.insert(0, Arc::new(vec![0, 1]));
        c.commit(&g, 1); // e.g. del + add of the same edge
        assert_eq!(c.fingerprint(), graph_fingerprint(&g));
        assert!(c.get(0).is_none());
    }

    #[test]
    fn insert_at_retired_version_is_dropped() {
        let g = graph(&[(0, 1)], 2);
        let c = LaneCache::new(&g, 8);
        let old = c.version();
        let mutated = graph(&[(0, 1), (1, 0)], 2);
        c.commit(&mutated, 1);
        // a worker that computed against epoch 0 finishes late
        c.insert_at(old, 0, Arc::new(vec![0, 1]));
        assert!(c.is_empty(), "stale compute must not poison the new epoch");
    }

    #[test]
    fn ppr_cache_shares_the_epoch_policy() {
        // the f32 instantiation gets the identical version/eviction logic
        let g = graph(&[(0, 1)], 2);
        let c = PprCache::new(&g, 2);
        c.insert(0, Arc::new(vec![0.85f32, 0.15]));
        assert_eq!(c.get(0).unwrap().as_slice(), &[0.85, 0.15]);
        let mutated = graph(&[(0, 1), (1, 0)], 2);
        c.commit(&mutated, 1);
        assert!(c.get(0).is_none(), "ranks from a retired epoch never serve");
    }
}
