//! Admission control for the query-serving layer (DESIGN.md §13.1).
//!
//! The server bounds **in-flight** queries — admitted but not yet
//! answered, whether queued, batched, or computing — with a single atomic
//! counter. Saturation is a *typed, immediate* rejection at submit time
//! ([`AdmissionError::Saturated`]), never silent queueing without bound:
//! a serving layer that buffers arbitrarily converts overload into
//! unbounded latency and memory, while a typed rejection lets callers
//! shed load or retry with backoff. Admission is released by an RAII
//! guard, so every exit path (answered, failed, worker panic unwinding a
//! batch) gives the slot back.

use std::fmt;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

/// Typed admission failure.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AdmissionError {
    /// The server already holds `limit` in-flight queries; the observed
    /// count at rejection rides along for operator-facing logs.
    Saturated { in_flight: usize, limit: usize },
}

impl fmt::Display for AdmissionError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            AdmissionError::Saturated { in_flight, limit } => write!(
                f,
                "server saturated: {in_flight} queries in flight (admission limit {limit})"
            ),
        }
    }
}

impl std::error::Error for AdmissionError {}

/// Bounded in-flight counter shared by submitters and workers.
#[derive(Debug)]
pub struct Admission {
    limit: usize,
    in_flight: AtomicUsize,
}

impl Admission {
    /// `limit` is clamped to at least 1 — an admission controller that
    /// can never admit is a misconfiguration, not a policy.
    pub fn new(limit: usize) -> Arc<Admission> {
        Arc::new(Admission { limit: limit.max(1), in_flight: AtomicUsize::new(0) })
    }

    /// Try to take one in-flight slot. CAS loop (not `fetch_add` +
    /// correction) so the counter never overshoots the limit even under a
    /// submitter stampede.
    pub fn try_admit(self: &Arc<Admission>) -> Result<AdmissionGuard, AdmissionError> {
        let mut cur = self.in_flight.load(Ordering::Relaxed);
        loop {
            if cur >= self.limit {
                return Err(AdmissionError::Saturated { in_flight: cur, limit: self.limit });
            }
            match self.in_flight.compare_exchange_weak(
                cur,
                cur + 1,
                Ordering::AcqRel,
                Ordering::Relaxed,
            ) {
                Ok(_) => return Ok(AdmissionGuard { admission: Arc::clone(self) }),
                Err(seen) => cur = seen,
            }
        }
    }

    /// Queries currently holding a slot.
    pub fn in_flight(&self) -> usize {
        self.in_flight.load(Ordering::Relaxed)
    }

    pub fn limit(&self) -> usize {
        self.limit
    }
}

/// RAII in-flight slot: dropping it (result delivered, query failed, or a
/// worker unwound) releases admission.
#[derive(Debug)]
pub struct AdmissionGuard {
    admission: Arc<Admission>,
}

impl Drop for AdmissionGuard {
    fn drop(&mut self) {
        let prev = self.admission.in_flight.fetch_sub(1, Ordering::AcqRel);
        debug_assert!(prev > 0, "admission guard double-release");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn admits_up_to_the_limit_then_rejects_typed() {
        let a = Admission::new(2);
        let g1 = a.try_admit().unwrap();
        let _g2 = a.try_admit().unwrap();
        let err = a.try_admit().unwrap_err();
        assert_eq!(err, AdmissionError::Saturated { in_flight: 2, limit: 2 });
        assert!(format!("{err}").contains("saturated"));
        drop(g1);
        assert!(a.try_admit().is_ok(), "released slot is reusable");
    }

    #[test]
    fn zero_limit_is_clamped_to_one() {
        let a = Admission::new(0);
        assert_eq!(a.limit(), 1);
        let _g = a.try_admit().unwrap();
        assert!(a.try_admit().is_err());
    }

    #[test]
    fn concurrent_stampede_never_exceeds_limit() {
        let a = Admission::new(8);
        let admitted = std::sync::atomic::AtomicUsize::new(0);
        std::thread::scope(|s| {
            for _ in 0..32 {
                s.spawn(|| {
                    for _ in 0..100 {
                        if let Ok(g) = a.try_admit() {
                            admitted.fetch_add(1, Ordering::Relaxed);
                            assert!(a.in_flight() <= 8, "overshoot");
                            drop(g);
                        }
                    }
                });
            }
        });
        assert_eq!(a.in_flight(), 0, "all slots released");
        assert!(admitted.load(Ordering::Relaxed) > 0);
    }
}
