//! Statistics used by the evaluation harness.
//!
//! The paper reports: averages over 64 runs with 95% confidence intervals
//! (§5 "Data Collection"), Pearson's correlation coefficient between
//! model-predicted and achieved speedups (Figure 7), and average error
//! (Table 3). This module implements exactly those.

/// Arithmetic mean. Empty input returns 0.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Sample standard deviation (n-1 denominator).
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    let var = xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64;
    var.sqrt()
}

/// Half-width of the 95% confidence interval of the mean, using the normal
/// approximation (z = 1.96). The paper plots these as error bars; with 64
/// samples the normal approximation matches Student-t to <2%.
pub fn ci95(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    1.96 * stddev(xs) / (xs.len() as f64).sqrt()
}

/// Pearson's correlation coefficient between paired samples.
///
/// Returns 0 when either side has zero variance (degenerate but defined —
/// the paper's Figure 7 reports r in [-1, 1]).
pub fn pearson(xs: &[f64], ys: &[f64]) -> f64 {
    assert_eq!(xs.len(), ys.len(), "pearson: length mismatch");
    let n = xs.len();
    if n < 2 {
        return 0.0;
    }
    let mx = mean(xs);
    let my = mean(ys);
    let mut sxy = 0.0;
    let mut sxx = 0.0;
    let mut syy = 0.0;
    for i in 0..n {
        let dx = xs[i] - mx;
        let dy = ys[i] - my;
        sxy += dx * dy;
        sxx += dx * dx;
        syy += dy * dy;
    }
    if sxx == 0.0 || syy == 0.0 {
        return 0.0;
    }
    sxy / (sxx.sqrt() * syy.sqrt())
}

/// Average signed relative error of `predicted` w.r.t. `achieved`, in
/// percent — Table 3's "Avg. Err." column. Positive means the model
/// under-predicts (achieved > predicted), matching the paper's sign
/// convention (BFS rows are positive because offloading also improves the
/// CPU's cache behaviour, which the model misses).
pub fn avg_error_pct(predicted: &[f64], achieved: &[f64]) -> f64 {
    assert_eq!(predicted.len(), achieved.len());
    if predicted.is_empty() {
        return 0.0;
    }
    let errs: Vec<f64> = predicted
        .iter()
        .zip(achieved)
        .map(|(p, a)| if *p != 0.0 { (a - p) / p * 100.0 } else { 0.0 })
        .collect();
    mean(&errs)
}

/// Simple linear regression y = a + b x; returns (a, b).
pub fn linear_fit(xs: &[f64], ys: &[f64]) -> (f64, f64) {
    assert_eq!(xs.len(), ys.len());
    let n = xs.len();
    if n < 2 {
        return (mean(ys), 0.0);
    }
    let mx = mean(xs);
    let my = mean(ys);
    let mut sxy = 0.0;
    let mut sxx = 0.0;
    for i in 0..n {
        sxy += (xs[i] - mx) * (ys[i] - my);
        sxx += (xs[i] - mx) * (xs[i] - mx);
    }
    if sxx == 0.0 {
        return (my, 0.0);
    }
    let b = sxy / sxx;
    (my - b * mx, b)
}

/// Median (copies and sorts; fine at harness scale).
pub fn median(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = v.len();
    if n % 2 == 1 {
        v[n / 2]
    } else {
        0.5 * (v[n / 2 - 1] + v[n / 2])
    }
}

/// Geometric mean (used when summarizing speedups across workloads).
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let s: f64 = xs.iter().map(|x| x.max(1e-300).ln()).sum();
    (s / xs.len() as f64).exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_stddev_basic() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((stddev(&xs) - 2.138089935).abs() < 1e-6);
    }

    #[test]
    fn pearson_perfect_correlation() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        let ys = [2.0, 4.0, 6.0, 8.0];
        assert!((pearson(&xs, &ys) - 1.0).abs() < 1e-12);
        let neg: Vec<f64> = ys.iter().map(|y| -y).collect();
        assert!((pearson(&xs, &neg) + 1.0).abs() < 1e-12);
    }

    #[test]
    fn pearson_no_correlation_degenerate() {
        let xs = [1.0, 1.0, 1.0];
        let ys = [2.0, 5.0, 9.0];
        assert_eq!(pearson(&xs, &ys), 0.0);
    }

    #[test]
    fn pearson_known_value() {
        let xs = [1.0, 2.0, 3.0, 5.0, 8.0];
        let ys = [0.11, 0.12, 0.13, 0.15, 0.18];
        assert!((pearson(&xs, &ys) - 1.0).abs() < 1e-9); // exactly linear
    }

    #[test]
    fn avg_error_sign_convention() {
        // model predicts 1.0, we achieve 1.1 => +10% (under-prediction)
        assert!((avg_error_pct(&[1.0], &[1.1]) - 10.0).abs() < 1e-9);
        assert!((avg_error_pct(&[2.0], &[1.5]) + 25.0).abs() < 1e-9);
    }

    #[test]
    fn ci_shrinks_with_n() {
        let a: Vec<f64> = (0..16).map(|i| (i % 4) as f64).collect();
        let b: Vec<f64> = (0..64).map(|i| (i % 4) as f64).collect();
        assert!(ci95(&b) < ci95(&a));
    }

    #[test]
    fn linear_fit_recovers_line() {
        let xs: Vec<f64> = (0..10).map(|i| i as f64).collect();
        let ys: Vec<f64> = xs.iter().map(|x| 3.0 + 2.0 * x).collect();
        let (a, b) = linear_fit(&xs, &ys);
        assert!((a - 3.0).abs() < 1e-9 && (b - 2.0).abs() < 1e-9);
    }

    #[test]
    fn median_even_odd() {
        assert_eq!(median(&[3.0, 1.0, 2.0]), 2.0);
        assert_eq!(median(&[4.0, 1.0, 2.0, 3.0]), 2.5);
    }

    #[test]
    fn geomean_of_speedups() {
        assert!((geomean(&[2.0, 8.0]) - 4.0).abs() < 1e-12);
    }
}
