//! Reporting: markdown tables, ASCII series plots, and JSON result dumps
//! for the benchmark harness (one emitter per paper table/figure).

use crate::util::json::{arr, num, obj, s, JsonValue};
use std::fmt::Write as _;
use std::path::Path;

/// A markdown table.
#[derive(Debug, Clone, Default)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|h| h.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "row arity");
        self.rows.push(cells);
    }

    pub fn markdown(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let _ = writeln!(out, "### {}\n", self.title);
        let fmt_row = |cells: &[String]| -> String {
            let mut line = String::from("|");
            for (i, c) in cells.iter().enumerate() {
                let _ = write!(line, " {:<w$} |", c, w = widths[i]);
            }
            line
        };
        let _ = writeln!(out, "{}", fmt_row(&self.headers));
        let mut sep = String::from("|");
        for w in &widths {
            let _ = write!(sep, "{}|", "-".repeat(w + 2));
        }
        let _ = writeln!(out, "{sep}");
        for r in &self.rows {
            let _ = writeln!(out, "{}", fmt_row(r));
        }
        out
    }
}

/// A named data series for a figure.
#[derive(Debug, Clone)]
pub struct Series {
    pub name: String,
    pub points: Vec<(f64, f64)>,
}

impl Series {
    pub fn new(name: &str) -> Series {
        Series { name: name.to_string(), points: Vec::new() }
    }
    pub fn push(&mut self, x: f64, y: f64) {
        self.points.push((x, y));
    }
}

/// Terminal-friendly figure: a set of series rendered as a data table plus
/// an ASCII plot — the harness's stand-in for the paper's figures.
#[derive(Debug, Clone)]
pub struct Figure {
    pub title: String,
    pub x_label: String,
    pub y_label: String,
    pub series: Vec<Series>,
}

impl Figure {
    pub fn new(title: &str, x_label: &str, y_label: &str) -> Figure {
        Figure {
            title: title.to_string(),
            x_label: x_label.to_string(),
            y_label: y_label.to_string(),
            series: Vec::new(),
        }
    }

    pub fn markdown(&self) -> String {
        let mut out = String::new();
        let _ = writeln!(out, "### {}\n", self.title);
        // data table: x column + one column per series
        let mut xs: Vec<f64> = self
            .series
            .iter()
            .flat_map(|s| s.points.iter().map(|p| p.0))
            .collect();
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        xs.dedup();
        let mut headers = vec![self.x_label.clone()];
        headers.extend(self.series.iter().map(|s| s.name.clone()));
        let _ = writeln!(out, "| {} |", headers.join(" | "));
        let _ = writeln!(out, "|{}|", headers.iter().map(|_| "---").collect::<Vec<_>>().join("|"));
        for &x in &xs {
            let mut cells = vec![trim_num(x)];
            for s in &self.series {
                let y = s
                    .points
                    .iter()
                    .find(|p| (p.0 - x).abs() < 1e-9)
                    .map(|p| trim_num(p.1))
                    .unwrap_or_else(|| "-".into());
                cells.push(y);
            }
            let _ = writeln!(out, "| {} |", cells.join(" | "));
        }
        let _ = writeln!(out, "\n```\n{}```", self.ascii_plot(64, 16));
        out
    }

    /// Simple multi-series scatter/line plot in a character grid.
    pub fn ascii_plot(&self, width: usize, height: usize) -> String {
        let pts: Vec<(f64, f64)> = self.series.iter().flat_map(|s| s.points.clone()).collect();
        if pts.is_empty() {
            return String::from("(no data)\n");
        }
        let (mut x0, mut x1) = (f64::INFINITY, f64::NEG_INFINITY);
        let (mut y0, mut y1) = (f64::INFINITY, f64::NEG_INFINITY);
        for &(x, y) in &pts {
            x0 = x0.min(x);
            x1 = x1.max(x);
            y0 = y0.min(y);
            y1 = y1.max(y);
        }
        y0 = y0.min(0.0_f64.max(y0 - 0.05 * (y1 - y0).abs()));
        if (x1 - x0).abs() < 1e-12 {
            x1 = x0 + 1.0;
        }
        if (y1 - y0).abs() < 1e-12 {
            y1 = y0 + 1.0;
        }
        let marks = ['*', 'o', '+', 'x', '#', '@', '%', '&'];
        let mut grid = vec![vec![' '; width]; height];
        for (si, series) in self.series.iter().enumerate() {
            for &(x, y) in &series.points {
                let cx = ((x - x0) / (x1 - x0) * (width - 1) as f64).round() as usize;
                let cy = ((y - y0) / (y1 - y0) * (height - 1) as f64).round() as usize;
                let row = height - 1 - cy.min(height - 1);
                grid[row][cx.min(width - 1)] = marks[si % marks.len()];
            }
        }
        let mut out = String::new();
        let _ = writeln!(out, "{} ({})", self.y_label, self.title);
        for (i, row) in grid.iter().enumerate() {
            let label = if i == 0 {
                format!("{y1:>9.3}")
            } else if i == height - 1 {
                format!("{y0:>9.3}")
            } else {
                " ".repeat(9)
            };
            let _ = writeln!(out, "{label} |{}", row.iter().collect::<String>());
        }
        let _ = writeln!(out, "{} +{}", " ".repeat(9), "-".repeat(width));
        let _ = writeln!(
            out,
            "{} {:<w$}{}",
            " ".repeat(9),
            format!("{x0:.2}"),
            format!("{x1:.2}"),
            w = width.saturating_sub(6)
        );
        let legend: Vec<String> = self
            .series
            .iter()
            .enumerate()
            .map(|(i, s)| format!("{} {}", marks[i % marks.len()], s.name))
            .collect();
        let _ = writeln!(out, "{} x: {}   [{}]", " ".repeat(9), self.x_label, legend.join(", "));
        out
    }

    pub fn to_json(&self) -> JsonValue {
        obj(vec![
            ("title", s(&self.title)),
            ("x_label", s(&self.x_label)),
            ("y_label", s(&self.y_label)),
            (
                "series",
                arr(self
                    .series
                    .iter()
                    .map(|sr| {
                        obj(vec![
                            ("name", s(&sr.name)),
                            (
                                "points",
                                arr(sr
                                    .points
                                    .iter()
                                    .map(|&(x, y)| arr(vec![num(x), num(y)]))
                                    .collect()),
                            ),
                        ])
                    })
                    .collect()),
            ),
        ])
    }
}

fn trim_num(x: f64) -> String {
    if x.abs() >= 1000.0 {
        format!("{x:.0}")
    } else if (x - x.round()).abs() < 1e-9 {
        format!("{x:.0}")
    } else {
        format!("{x:.3}")
    }
}

/// Write markdown + JSON result files under `results/`.
pub fn save(name: &str, markdown: &str, json: &JsonValue) -> std::io::Result<()> {
    let dir = Path::new("results");
    std::fs::create_dir_all(dir)?;
    std::fs::write(dir.join(format!("{name}.md")), markdown)?;
    std::fs::write(dir.join(format!("{name}.json")), json.render())?;
    Ok(())
}

/// Format seconds compactly.
pub fn fmt_secs(x: f64) -> String {
    if x >= 1.0 {
        format!("{x:.2}s")
    } else if x >= 1e-3 {
        format!("{:.2}ms", x * 1e3)
    } else {
        format!("{:.0}µs", x * 1e6)
    }
}

/// Format a TEPS rate.
pub fn fmt_teps(x: f64) -> String {
    if x >= 1e9 {
        format!("{:.2} BTEPS", x / 1e9)
    } else if x >= 1e6 {
        format!("{:.1} MTEPS", x / 1e6)
    } else {
        format!("{:.0} KTEPS", x / 1e3)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_markdown_shape() {
        let mut t = Table::new("Demo", &["a", "b"]);
        t.row(vec!["1".into(), "22".into()]);
        let md = t.markdown();
        assert!(md.contains("### Demo"));
        assert!(md.contains("| a"));
        assert!(md.contains("| 1"));
        assert_eq!(md.lines().filter(|l| l.starts_with('|')).count(), 3);
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn table_arity_checked() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["1".into()]);
    }

    #[test]
    fn figure_renders() {
        let mut f = Figure::new("Speedup", "alpha", "speedup");
        let mut s1 = Series::new("model");
        s1.push(0.5, 2.0);
        s1.push(1.0, 1.0);
        f.series.push(s1);
        let md = f.markdown();
        assert!(md.contains("| alpha | model |"));
        assert!(md.contains("```"));
        let j = f.to_json();
        assert!(j.get("series").is_some());
    }

    #[test]
    fn formatting_helpers() {
        assert_eq!(fmt_secs(2.5), "2.50s");
        assert_eq!(fmt_secs(0.002), "2.00ms");
        assert!(fmt_teps(2.5e9).contains("BTEPS"));
        assert!(fmt_teps(3.0e6).contains("MTEPS"));
    }
}
