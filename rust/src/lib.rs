//! # TOTEM — hybrid CPU + accelerator graph processing
//!
//! A reproduction of *"Efficient Large-Scale Graph Processing on Hybrid CPU
//! and GPU Systems"* (Gharaibeh et al., 2013) on a Rust + JAX/Pallas stack:
//! the Rust coordinator owns partitioning, the BSP engine and the CPU
//! processing element; accelerator partitions execute AOT-compiled
//! JAX/Pallas step programs through the PJRT C API (`xla` crate).
//!
//! See `DESIGN.md` for the architecture and `EXPERIMENTS.md` for the
//! paper-vs-measured results.

pub mod alg;
pub mod baseline;
pub mod engine;
pub mod graph;
pub mod harness;
pub mod model;
pub mod partition;
pub mod report;
pub mod runtime;
pub mod serve;
pub mod stats;
pub mod util;
