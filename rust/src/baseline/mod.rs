//! Whole-graph shared-memory reference implementations.
//!
//! These play two roles:
//! 1. the **comparator framework** for Table 4 — a clean, Galois/Ligra-
//!    style single-machine implementation of each algorithm with no
//!    partitioning overhead (the paper's 2S baseline);
//! 2. the **correctness oracle** for the hybrid engine's integration
//!    tests: every engine configuration must reproduce these outputs.
//!
//! They intentionally share no code with the engine kernels so that a bug
//! can't cancel itself out across both sides.

use crate::alg::INF_I32;
use crate::graph::CsrGraph;
use std::collections::VecDeque;

/// Queue-based sequential BFS. Returns per-vertex levels (INF_I32 if
/// unreachable).
pub fn bfs(g: &CsrGraph, source: u32) -> Vec<i32> {
    let mut levels = vec![INF_I32; g.vertex_count];
    if g.vertex_count == 0 {
        return levels;
    }
    levels[source as usize] = 0;
    let mut queue = VecDeque::new();
    queue.push_back(source);
    while let Some(v) = queue.pop_front() {
        let next = levels[v as usize] + 1;
        for &d in g.neighbors(v) {
            if levels[d as usize] == INF_I32 {
                levels[d as usize] = next;
                queue.push_back(d);
            }
        }
    }
    levels
}

/// Direction-optimized BFS (Beamer et al. 2013; paper §10): switches to a
/// bottom-up sweep when the frontier covers more than `threshold` of the
/// vertices. Needs the reversed adjacency for the bottom-up step.
pub fn bfs_direction_optimized(g: &CsrGraph, source: u32, threshold: f64) -> Vec<i32> {
    let rev = g.reverse();
    let mut levels = vec![INF_I32; g.vertex_count];
    if g.vertex_count == 0 {
        return levels;
    }
    levels[source as usize] = 0;
    let mut frontier: Vec<u32> = vec![source];
    let mut cur = 0i32;
    while !frontier.is_empty() {
        let mut next_frontier = Vec::new();
        if (frontier.len() as f64) < threshold * g.vertex_count as f64 {
            // top-down
            for &v in &frontier {
                for &d in g.neighbors(v) {
                    if levels[d as usize] == INF_I32 {
                        levels[d as usize] = cur + 1;
                        next_frontier.push(d);
                    }
                }
            }
        } else {
            // bottom-up: every unvisited vertex probes its in-neighbors
            for v in 0..g.vertex_count as u32 {
                if levels[v as usize] != INF_I32 {
                    continue;
                }
                for &u in rev.neighbors(v) {
                    if levels[u as usize] == cur {
                        levels[v as usize] = cur + 1;
                        next_frontier.push(v);
                        break;
                    }
                }
            }
        }
        frontier = next_frontier;
        cur += 1;
    }
    levels
}

/// Pull-based PageRank, fixed rounds, d = 0.85 — mirrors the paper's
/// Figure 14 kernel exactly (no dangling-mass redistribution).
pub fn pagerank(g: &CsrGraph, rounds: usize) -> Vec<f32> {
    let n = g.vertex_count;
    if n == 0 {
        return Vec::new();
    }
    let rev = g.reverse();
    let d = crate::alg::pagerank::DAMPING;
    let base = (1.0 - d) / n as f32;
    let outdeg = g.out_degrees();
    let mut rank = vec![1.0f32 / n as f32; n];
    let mut contrib = vec![0f32; n];
    for _ in 0..rounds {
        for v in 0..n {
            contrib[v] = if outdeg[v] > 0 {
                rank[v] / outdeg[v] as f32
            } else {
                0.0
            };
        }
        for v in 0..n as u32 {
            let mut sum = 0f32;
            for &u in rev.neighbors(v) {
                sum += contrib[u as usize];
            }
            rank[v as usize] = base + d * sum;
        }
    }
    rank
}

/// Sequential Bellman-Ford with a worklist. Returns f32 distances
/// (INFINITY if unreachable).
pub fn sssp(g: &CsrGraph, source: u32) -> Vec<f32> {
    let mut dist = vec![f32::INFINITY; g.vertex_count];
    if g.vertex_count == 0 {
        return dist;
    }
    dist[source as usize] = 0.0;
    let mut queue = VecDeque::new();
    let mut queued = vec![false; g.vertex_count];
    queue.push_back(source);
    queued[source as usize] = true;
    while let Some(v) = queue.pop_front() {
        queued[v as usize] = false;
        let dv = dist[v as usize];
        let ws = g.edge_weights(v);
        for (k, &dn) in g.neighbors(v).iter().enumerate() {
            let nd = dv + ws[k];
            if nd < dist[dn as usize] {
                dist[dn as usize] = nd;
                if !queued[dn as usize] {
                    queue.push_back(dn);
                    queued[dn as usize] = true;
                }
            }
        }
    }
    dist
}

/// Sequential single-source widest path (maximum-bottleneck path) with a
/// worklist. Returns per-vertex path widths: `+inf` at the source (the
/// empty path has no bottleneck), `-inf` if unreachable. Widths are pure
/// selections among edge weights (no arithmetic), so the hybrid engine
/// must reproduce them bit-for-bit — the differential-fuzz oracle for the
/// `widest` vertex program.
pub fn widest(g: &CsrGraph, source: u32) -> Vec<f32> {
    let mut width = vec![f32::NEG_INFINITY; g.vertex_count];
    if g.vertex_count == 0 {
        return width;
    }
    width[source as usize] = f32::INFINITY;
    let mut queue = VecDeque::new();
    let mut queued = vec![false; g.vertex_count];
    queue.push_back(source);
    queued[source as usize] = true;
    while let Some(v) = queue.pop_front() {
        queued[v as usize] = false;
        let wv = width[v as usize];
        let ws = g.edge_weights(v);
        for (k, &dn) in g.neighbors(v).iter().enumerate() {
            let cand = wv.min(ws[k]);
            if cand > width[dn as usize] {
                width[dn as usize] = cand;
                if !queued[dn as usize] {
                    queue.push_back(dn);
                    queued[dn as usize] = true;
                }
            }
        }
    }
    width
}

/// Brandes' single-source betweenness centrality (f32 accumulation, like
/// the GPU kernels). Returns per-vertex dependency scores.
pub fn bc(g: &CsrGraph, source: u32) -> Vec<f32> {
    let n = g.vertex_count;
    let mut bc = vec![0f32; n];
    if n == 0 {
        return bc;
    }
    let mut dist = vec![-1i64; n];
    let mut sigma = vec![0f32; n];
    let mut order: Vec<u32> = Vec::with_capacity(n);
    dist[source as usize] = 0;
    sigma[source as usize] = 1.0;
    let mut queue = VecDeque::new();
    queue.push_back(source);
    while let Some(v) = queue.pop_front() {
        order.push(v);
        for &w in g.neighbors(v) {
            if dist[w as usize] < 0 {
                dist[w as usize] = dist[v as usize] + 1;
                queue.push_back(w);
            }
            if dist[w as usize] == dist[v as usize] + 1 {
                sigma[w as usize] += sigma[v as usize];
            }
        }
    }
    let mut delta = vec![0f32; n];
    for &v in order.iter().rev() {
        for &w in g.neighbors(v) {
            if dist[w as usize] == dist[v as usize] + 1 && sigma[w as usize] > 0.0 {
                delta[v as usize] +=
                    sigma[v as usize] / sigma[w as usize] * (1.0 + delta[w as usize]);
            }
        }
        if v != source {
            bc[v as usize] += delta[v as usize];
        }
    }
    bc
}

/// Per-vertex incident-triangle counts over the undirected, deduplicated,
/// self-loop-free closure of `g`. Hash-set membership probes instead of
/// the engine's sorted-merge orientation, so a bug can't cancel out.
pub fn triangles(g: &CsrGraph) -> Vec<u64> {
    let n = g.vertex_count;
    let mut adj: Vec<Vec<u32>> = vec![Vec::new(); n];
    for v in 0..n as u32 {
        for &t in g.neighbors(v) {
            if t != v {
                adj[v as usize].push(t);
                adj[t as usize].push(v);
            }
        }
    }
    for a in &mut adj {
        a.sort_unstable();
        a.dedup();
    }
    let sets: Vec<std::collections::HashSet<u32>> =
        adj.iter().map(|a| a.iter().copied().collect()).collect();
    let mut tri = vec![0u64; n];
    for v in 0..n {
        // for each neighbor pair (w, u) with w < u, probe the edge w-u
        let a = &adj[v];
        for (i, &w) in a.iter().enumerate() {
            for &u in &a[i + 1..] {
                if sets[w as usize].contains(&u) {
                    tri[v] += 1;
                }
            }
        }
    }
    tri
}

/// k-core decomposition (coreness) over the undirected **multigraph**
/// view — `to_undirected` keeps parallel edges and doubles self-loops,
/// and degrees count multiplicity, exactly like the engine's view.
/// Synchronous batch peeling: at threshold `k`, repeatedly remove every
/// alive vertex whose alive-degree is ≤ `k` (coreness = `k`); when a
/// round removes nobody, escalate `k`.
pub fn kcore(g: &CsrGraph) -> Vec<i32> {
    let u = g.to_undirected();
    let n = u.vertex_count;
    let mut core = vec![INF_I32; n];
    let mut remaining = n;
    let mut k = 0i32;
    while remaining > 0 {
        let mut doomed = Vec::new();
        for v in 0..n as u32 {
            if core[v as usize] != INF_I32 {
                continue;
            }
            let alive =
                u.neighbors(v).iter().filter(|&&t| core[t as usize] == INF_I32).count() as i64;
            if alive <= k as i64 {
                doomed.push(v);
            }
        }
        if doomed.is_empty() {
            k += 1;
        } else {
            for v in doomed {
                core[v as usize] = k;
                remaining -= 1;
            }
        }
    }
    core
}

/// Synchronous label propagation over the undirected multigraph view
/// (multiplicities weight labels), min-label tie-break, fixed `rounds`
/// with early exit on a quiet round — the engine's exact semantics,
/// reimplemented with a frequency map instead of a sorted-run scan.
pub fn labelprop(g: &CsrGraph, rounds: usize) -> Vec<i32> {
    let u = g.to_undirected();
    let n = u.vertex_count;
    let mut label: Vec<i32> = (0..n as i32).collect();
    for _ in 0..rounds {
        let prev = label.clone();
        let mut changed = false;
        for v in 0..n as u32 {
            let ns = u.neighbors(v);
            if ns.is_empty() {
                continue;
            }
            let mut freq = std::collections::HashMap::new();
            for &t in ns {
                *freq.entry(prev[t as usize]).or_insert(0usize) += 1;
            }
            // max count, ties toward the smaller label
            let best = freq
                .into_iter()
                .min_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(&b.0)))
                .map(|(l, _)| l)
                .unwrap();
            if best != label[v as usize] {
                label[v as usize] = best;
                changed = true;
            }
        }
        if !changed {
            break;
        }
    }
    label
}

/// Personalized PageRank: power iteration from the source indicator,
/// fixed rounds, d = 0.85, dangling mass dropped (same contract as
/// [`pagerank`]).
pub fn ppr(g: &CsrGraph, source: u32, rounds: usize) -> Vec<f32> {
    let n = g.vertex_count;
    if n == 0 {
        return Vec::new();
    }
    let rev = g.reverse();
    let d = crate::alg::pagerank::DAMPING;
    let outdeg = g.out_degrees();
    let mut rank = vec![0f32; n];
    rank[source as usize] = 1.0;
    let mut contrib = vec![0f32; n];
    for _ in 0..rounds {
        for v in 0..n {
            contrib[v] = if outdeg[v] > 0 {
                rank[v] / outdeg[v] as f32
            } else {
                0.0
            };
        }
        for v in 0..n as u32 {
            let mut sum = 0f32;
            for &u in rev.neighbors(v) {
                sum += contrib[u as usize];
            }
            let teleport = if v == source { 1.0 - d } else { 0.0 };
            rank[v as usize] = teleport + d * sum;
        }
    }
    rank
}

/// Connected components on the undirected view via label propagation.
pub fn cc(g: &CsrGraph) -> Vec<i32> {
    let u = g.to_undirected();
    let n = u.vertex_count;
    let mut label: Vec<i32> = (0..n as i32).collect();
    let mut changed = true;
    while changed {
        changed = false;
        for v in 0..n as u32 {
            let lv = label[v as usize];
            for &w in u.neighbors(v) {
                if lv < label[w as usize] {
                    label[w as usize] = lv;
                    changed = true;
                }
            }
        }
    }
    label
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generator::{rmat, with_random_weights, RmatParams};
    use crate::graph::EdgeList;

    fn small() -> CsrGraph {
        // 0->1->2->3 and 0->2 shortcut
        let mut el = EdgeList::new(4);
        el.push(0, 1);
        el.push(1, 2);
        el.push(2, 3);
        el.push(0, 2);
        CsrGraph::from_edge_list(&el)
    }

    #[test]
    fn bfs_shortcut() {
        assert_eq!(bfs(&small(), 0), vec![0, 1, 1, 2]);
    }

    #[test]
    fn dobfs_matches_bfs() {
        let g = CsrGraph::from_edge_list(&rmat(&RmatParams::paper(10, 3)));
        let a = bfs(&g, 0);
        for thr in [0.0, 0.05, 1.1] {
            assert_eq!(a, bfs_direction_optimized(&g, 0, thr), "thr={thr}");
        }
    }

    #[test]
    fn sssp_uses_weights() {
        let mut el = EdgeList::new(3);
        el.push(0, 1);
        el.push(0, 2);
        el.push(2, 1);
        el.weights = Some(vec![10.0, 1.0, 2.0]);
        let g = CsrGraph::from_edge_list(&el);
        assert_eq!(sssp(&g, 0), vec![0.0, 3.0, 1.0]);
    }

    #[test]
    fn sssp_random_matches_dijkstra_property() {
        // Bellman-Ford worklist vs brute-force floyd-warshall row on a tiny graph
        let mut el = rmat(&RmatParams::paper(6, 9));
        with_random_weights(&mut el, 8, 3);
        let g = CsrGraph::from_edge_list(&el);
        let dist = sssp(&g, 0);
        // triangle inequality check: for each edge (u,v,w): dist[v] <= dist[u]+w
        for u in 0..g.vertex_count as u32 {
            let ws = g.edge_weights(u);
            for (k, &v) in g.neighbors(u).iter().enumerate() {
                assert!(dist[v as usize] <= dist[u as usize] + ws[k] + 1e-3);
            }
        }
    }

    #[test]
    fn widest_bottleneck_diamond() {
        // 0 -1-> 1 -4-> 3 ; 0 -3-> 2 -2-> 3 : widest 0->3 = min(3,2) = 2
        let mut el = EdgeList::new(5);
        el.push(0, 1);
        el.push(0, 2);
        el.push(1, 3);
        el.push(2, 3);
        el.weights = Some(vec![1.0, 3.0, 4.0, 2.0]);
        let g = CsrGraph::from_edge_list(&el);
        let w = widest(&g, 0);
        assert_eq!(w, vec![f32::INFINITY, 1.0, 3.0, 2.0, f32::NEG_INFINITY]);
    }

    #[test]
    fn widest_is_monotone_under_relaxation() {
        // for each edge (u,v,w): width[v] >= min(width[u], w)
        let mut el = rmat(&RmatParams::paper(7, 5));
        with_random_weights(&mut el, 16, 11);
        let g = CsrGraph::from_edge_list(&el);
        let w = widest(&g, 0);
        for u in 0..g.vertex_count as u32 {
            let ws = g.edge_weights(u);
            for (k, &v) in g.neighbors(u).iter().enumerate() {
                assert!(w[v as usize] >= w[u as usize].min(ws[k]));
            }
        }
    }

    #[test]
    fn pagerank_sums_near_one_without_dangling() {
        // cycle: no dangling mass loss
        let mut el = EdgeList::new(3);
        el.push(0, 1);
        el.push(1, 2);
        el.push(2, 0);
        let g = CsrGraph::from_edge_list(&el);
        let pr = pagerank(&g, 50);
        let total: f32 = pr.iter().sum();
        assert!((total - 1.0).abs() < 1e-3, "total={total}");
        // symmetric cycle → equal ranks
        assert!((pr[0] - pr[1]).abs() < 1e-5);
    }

    #[test]
    fn bc_path() {
        let mut el = EdgeList::new(4);
        el.push(0, 1);
        el.push(1, 2);
        el.push(2, 3);
        let g = CsrGraph::from_edge_list(&el);
        assert_eq!(bc(&g, 0), vec![0.0, 2.0, 1.0, 0.0]);
    }

    #[test]
    fn cc_components() {
        let mut el = EdgeList::new(5);
        el.push(0, 1);
        el.push(1, 2);
        el.push(3, 4);
        let g = CsrGraph::from_edge_list(&el);
        assert_eq!(cc(&g), vec![0, 0, 0, 3, 3]);
    }

    #[test]
    fn triangles_bowtie_ignores_duplicates_and_self_loops() {
        let mut el = EdgeList::new(5);
        for (s, d) in [(0, 1), (1, 2), (2, 0), (1, 3), (3, 2), (2, 1), (4, 4)] {
            el.push(s, d);
        }
        let g = CsrGraph::from_edge_list(&el);
        assert_eq!(triangles(&g), vec![1, 2, 2, 1, 0]);
    }

    #[test]
    fn kcore_k4_with_tail() {
        let mut el = EdgeList::new(7);
        for (s, d) in [(0, 1), (0, 2), (0, 3), (1, 2), (1, 3), (2, 3), (3, 4), (4, 5)] {
            el.push(s, d);
        }
        let g = CsrGraph::from_edge_list(&el);
        assert_eq!(kcore(&g), vec![3, 3, 3, 3, 1, 1, 0]);
    }

    #[test]
    fn kcore_never_exceeds_multigraph_degree() {
        let g = CsrGraph::from_edge_list(&rmat(&RmatParams::paper(7, 6)));
        let u = g.to_undirected();
        let core = kcore(&g);
        for v in 0..g.vertex_count as u32 {
            assert!(core[v as usize] as u64 <= u.out_degree(v));
        }
    }

    #[test]
    fn labelprop_two_triangles() {
        let mut el = EdgeList::new(6);
        for (s, d) in [(0, 1), (1, 2), (0, 2), (3, 4), (4, 5), (3, 5)] {
            el.push(s, d);
        }
        let g = CsrGraph::from_edge_list(&el);
        assert_eq!(labelprop(&g, 5), vec![0, 0, 0, 3, 3, 3]);
    }

    #[test]
    fn ppr_mass_is_bounded_and_source_heavy() {
        let mut el = EdgeList::new(3);
        el.push(0, 1);
        el.push(1, 2);
        el.push(2, 0);
        let g = CsrGraph::from_edge_list(&el);
        let r = ppr(&g, 0, 30);
        assert!((r.iter().sum::<f32>() - 1.0).abs() < 1e-3, "cycle conserves mass");
        assert!(r[0] > r[1] && r[0] > r[2]);
    }
}
