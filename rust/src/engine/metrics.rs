//! Execution metrics (paper §5.2 and all breakdown figures).
//!
//! Every superstep records: per-partition compute time, communication time
//! (transfer + scatter), bytes moved across the element boundary, and
//! message counts. The headline numbers derive from these:
//!
//! - **makespan** (Eq. 2): `Σ_steps (max_p compute_p + comm)` — the time a
//!   truly concurrent hybrid platform would take, since partitions compute
//!   in parallel within a BSP superstep but communication is serialized.
//! - **bottleneck compute**: `Σ_steps max_p compute_p` (the "Computation"
//!   bar in Figures 8/10/16/19/21).
//! - **per-element compute**: `Σ_steps compute_p` (the "GPU" bar).
//!
//! On this single-core container the raw wall time is close to the *sum*
//! over partitions; the makespan is the faithful concurrent-platform
//! number (DESIGN.md §2).

/// Metrics for one BSP superstep.
#[derive(Debug, Clone, Default)]
pub struct StepMetrics {
    /// Compute seconds per partition.
    pub compute: Vec<f64>,
    /// Communication seconds (all pairs, transfer + scatter-apply).
    pub comm: f64,
    /// Bytes that crossed a partition boundary this step.
    pub bytes: u64,
    /// Messages (ghost-slot values) delivered this step.
    pub messages: u64,
}

/// Memory-access counters per partition (instrumented CPU kernels;
/// Figures 12/17/22 proxies).
#[derive(Debug, Clone, Copy, Default)]
pub struct MemCounters {
    pub reads: u64,
    pub writes: u64,
}

/// Full run metrics.
#[derive(Debug, Clone, Default)]
pub struct Metrics {
    pub steps: Vec<StepMetrics>,
    pub partitions: usize,
    /// Wall-clock of the whole run (includes engine overhead).
    pub wall_secs: f64,
    /// Per-partition memory access counters (only filled when
    /// `EngineConfig::instrument` is set).
    pub mem: Vec<MemCounters>,
    /// Per-partition accelerator transfer bytes (state upload + readback),
    /// part of the comm story for hybrid configs.
    pub accel_transfer_bytes: Vec<u64>,
}

impl Metrics {
    pub fn new(partitions: usize) -> Self {
        Metrics {
            steps: Vec::new(),
            partitions,
            wall_secs: 0.0,
            mem: vec![MemCounters::default(); partitions],
            accel_transfer_bytes: vec![0; partitions],
        }
    }

    pub fn supersteps(&self) -> usize {
        self.steps.len()
    }

    /// Eq. 2 makespan in seconds.
    pub fn makespan_secs(&self) -> f64 {
        self.steps
            .iter()
            .map(|s| {
                s.compute.iter().copied().fold(0.0, f64::max) + s.comm
            })
            .sum()
    }

    /// Σ max_p compute — the "Computation" (bottleneck processor) bar.
    pub fn bottleneck_compute_secs(&self) -> f64 {
        self.steps
            .iter()
            .map(|s| s.compute.iter().copied().fold(0.0, f64::max))
            .sum()
    }

    /// Σ compute for one partition (e.g. the "GPU" bar in Fig 8/10).
    pub fn partition_compute_secs(&self, p: usize) -> f64 {
        self.steps.iter().map(|s| s.compute.get(p).copied().unwrap_or(0.0)).sum()
    }

    /// Total communication seconds.
    pub fn comm_secs(&self) -> f64 {
        self.steps.iter().map(|s| s.comm).sum()
    }

    pub fn total_bytes(&self) -> u64 {
        self.steps.iter().map(|s| s.bytes).sum()
    }

    pub fn total_messages(&self) -> u64 {
        self.steps.iter().map(|s| s.messages).sum()
    }

    /// Index of the slowest partition by total compute time — the paper's
    /// "bottleneck processor" (always the CPU in their experiments).
    pub fn bottleneck_partition(&self) -> usize {
        (0..self.partitions)
            .max_by(|&a, &b| {
                self.partition_compute_secs(a)
                    .total_cmp(&self.partition_compute_secs(b))
            })
            .unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Metrics {
        let mut m = Metrics::new(2);
        m.steps.push(StepMetrics {
            compute: vec![2.0, 1.0],
            comm: 0.5,
            bytes: 100,
            messages: 10,
        });
        m.steps.push(StepMetrics {
            compute: vec![1.0, 3.0],
            comm: 0.5,
            bytes: 50,
            messages: 5,
        });
        m
    }

    #[test]
    fn makespan_is_sum_of_max_plus_comm() {
        let m = sample();
        assert!((m.makespan_secs() - (2.5 + 3.5)).abs() < 1e-12);
        assert!((m.bottleneck_compute_secs() - 5.0).abs() < 1e-12);
        assert!((m.comm_secs() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn per_partition_totals() {
        let m = sample();
        assert_eq!(m.partition_compute_secs(0), 3.0);
        assert_eq!(m.partition_compute_secs(1), 4.0);
        assert_eq!(m.bottleneck_partition(), 1);
        assert_eq!(m.total_bytes(), 150);
        assert_eq!(m.total_messages(), 15);
    }
}
