//! Execution metrics (paper §5.2 and all breakdown figures).
//!
//! Every superstep records: per-partition compute time, communication time
//! (transfer + scatter), bytes moved across the element boundary, and
//! message counts. The headline numbers derive from these:
//!
//! - **makespan** (Eq. 2): `Σ_steps (max_p compute_p + comm)` — the time a
//!   truly concurrent hybrid platform would take, since partitions compute
//!   in parallel within a BSP superstep but communication is serialized.
//! - **bottleneck compute**: `Σ_steps max_p compute_p` (the "Computation"
//!   bar in Figures 8/10/16/19/21).
//! - **per-element compute**: `Σ_steps compute_p` (the "GPU" bar).
//!
//! On this single-core container the raw wall time is close to the *sum*
//! over partitions; the makespan is the faithful concurrent-platform
//! number (DESIGN.md §2).

use super::direction::Direction;

/// Metrics for one BSP superstep.
#[derive(Debug, Clone, Default)]
pub struct StepMetrics {
    /// Compute seconds per partition.
    pub compute: Vec<f64>,
    /// Communication seconds (all pairs, transfer + scatter-apply).
    pub comm: f64,
    /// Seconds of `comm` that executed while at least one partition was
    /// still computing — communication hidden behind computation by the
    /// pipelined executor (always 0 in synchronous mode). Invariant:
    /// `comm_overlapped <= comm`.
    pub comm_overlapped: f64,
    /// Bytes that crossed a partition boundary this step.
    pub bytes: u64,
    /// Messages (ghost-slot values) delivered this step.
    pub messages: u64,
    /// Traversal direction each partition computed with this step
    /// (DESIGN.md §8). Push-only runs record `Push` everywhere.
    pub directions: Vec<Direction>,
    /// Per-partition frontier-size estimate at the start of the step —
    /// populated only when direction optimization is enabled and the
    /// algorithm reports frontier stats (zeros otherwise).
    pub frontier_verts: Vec<u64>,
    /// Per-partition Σ out-degree over the frontier (`m_f`).
    pub frontier_edges: Vec<u64>,
    /// Per-partition Σ out-degree over unexplored vertices (`m_u` proxy).
    pub unexplored_edges: Vec<u64>,
    /// Per-partition wall time of the *slowest* worker chunk in the
    /// compute phase (DESIGN.md §11) — with `chunk_min`, the observable
    /// intra-partition load-imbalance spread. Zero when the kernel ran as
    /// a single chunk (threads = 1, tiny partitions, or the deterministic
    /// order-sensitive path).
    pub chunk_max: Vec<f64>,
    /// Per-partition wall time of the fastest worker chunk.
    pub chunk_min: Vec<f64>,
}

impl StepMetrics {
    /// Empty record for a step over `partitions` elements.
    pub fn empty(partitions: usize) -> StepMetrics {
        StepMetrics {
            compute: vec![0.0; partitions],
            directions: vec![Direction::Push; partitions],
            frontier_verts: vec![0; partitions],
            frontier_edges: vec![0; partitions],
            unexplored_edges: vec![0; partitions],
            chunk_max: vec![0.0; partitions],
            chunk_min: vec![0.0; partitions],
            ..Default::default()
        }
    }

    /// Did any partition run bottom-up this step?
    pub fn any_pull(&self) -> bool {
        self.directions.iter().any(|&d| d == Direction::Pull)
    }

    /// Communication seconds on the critical path (not hidden by compute).
    pub fn comm_exposed(&self) -> f64 {
        (self.comm - self.comm_overlapped).max(0.0)
    }
}

/// Memory-access counters per partition (instrumented CPU kernels;
/// Figures 12/17/22 proxies).
#[derive(Debug, Clone, Copy, Default)]
pub struct MemCounters {
    pub reads: u64,
    pub writes: u64,
}

/// Full run metrics.
#[derive(Debug, Clone, Default)]
pub struct Metrics {
    pub steps: Vec<StepMetrics>,
    pub partitions: usize,
    /// Wall-clock of the whole run (includes engine overhead).
    pub wall_secs: f64,
    /// Per-partition memory access counters (only filled when
    /// `EngineConfig::instrument` is set).
    pub mem: Vec<MemCounters>,
    /// Per-partition accelerator transfer bytes (state upload + readback),
    /// part of the comm story for hybrid configs.
    pub accel_transfer_bytes: Vec<u64>,
    /// Vertex migrations performed by the dynamic α controller.
    pub migrations: usize,
    /// Controller firings that selected an empty band (the donor could not
    /// shed a vertex, e.g. a single-vertex partition). Counted distinctly:
    /// no rebuild happened, no budget was consumed, and the controller
    /// stops observing that donor until a real migration reshapes the
    /// partitions.
    pub noop_migrations: usize,
}

impl Metrics {
    pub fn new(partitions: usize) -> Self {
        Metrics {
            steps: Vec::new(),
            partitions,
            wall_secs: 0.0,
            mem: vec![MemCounters::default(); partitions],
            accel_transfer_bytes: vec![0; partitions],
            migrations: 0,
            noop_migrations: 0,
        }
    }

    pub fn supersteps(&self) -> usize {
        self.steps.len()
    }

    /// Eq. 2 makespan in seconds, extended for overlap: per step, the
    /// bottleneck element's compute plus the communication that was *not*
    /// hidden behind compute. With `comm_overlapped == 0` (synchronous
    /// mode) this is exactly the paper's Eq. 2.
    pub fn makespan_secs(&self) -> f64 {
        self.steps
            .iter()
            .map(|s| {
                s.compute.iter().copied().fold(0.0, f64::max) + s.comm_exposed()
            })
            .sum()
    }

    /// Σ max_p compute — the "Computation" (bottleneck processor) bar.
    pub fn bottleneck_compute_secs(&self) -> f64 {
        self.steps
            .iter()
            .map(|s| s.compute.iter().copied().fold(0.0, f64::max))
            .sum()
    }

    /// Σ compute for one partition (e.g. the "GPU" bar in Fig 8/10).
    pub fn partition_compute_secs(&self, p: usize) -> f64 {
        self.steps.iter().map(|s| s.compute.get(p).copied().unwrap_or(0.0)).sum()
    }

    /// Total communication seconds.
    pub fn comm_secs(&self) -> f64 {
        self.steps.iter().map(|s| s.comm).sum()
    }

    /// Communication seconds hidden behind compute by the pipeline.
    pub fn overlapped_comm_secs(&self) -> f64 {
        self.steps.iter().map(|s| s.comm_overlapped).sum()
    }

    /// Realized overlap factor in `[0, 1]`: fraction of communication
    /// time hidden behind compute (0 for the synchronous engine). This is
    /// the measured counterpart of `model::overlap`'s ω parameter.
    pub fn overlap_factor(&self) -> f64 {
        let comm = self.comm_secs();
        if comm <= 0.0 {
            0.0
        } else {
            (self.overlapped_comm_secs() / comm).clamp(0.0, 1.0)
        }
    }

    /// Supersteps in which at least one partition ran bottom-up — the
    /// run-level summary of the §8 direction policy (0 for push-only
    /// runs). Surfaced by the harness and the CLI.
    pub fn pull_steps(&self) -> usize {
        self.steps.iter().filter(|s| s.any_pull()).count()
    }

    pub fn total_bytes(&self) -> u64 {
        self.steps.iter().map(|s| s.bytes).sum()
    }

    pub fn total_messages(&self) -> u64 {
        self.steps.iter().map(|s| s.messages).sum()
    }

    /// Intra-partition load-imbalance for partition `p`:
    /// `Σ_steps (chunk_max - chunk_min)` — seconds the partition's fastest
    /// worker spent idle waiting on its slowest sibling. The balance-mode
    /// signal (DESIGN.md §11); ~0 under `Edge`/`HubSplit` on skewed graphs
    /// and for single-chunk kernels.
    pub fn chunk_spread_secs(&self, p: usize) -> f64 {
        self.steps
            .iter()
            .map(|s| {
                (s.chunk_max.get(p).copied().unwrap_or(0.0)
                    - s.chunk_min.get(p).copied().unwrap_or(0.0))
                .max(0.0)
            })
            .sum()
    }

    /// Index of the slowest partition by total compute time — the paper's
    /// "bottleneck processor" (always the CPU in their experiments).
    pub fn bottleneck_partition(&self) -> usize {
        (0..self.partitions)
            .max_by(|&a, &b| {
                self.partition_compute_secs(a)
                    .total_cmp(&self.partition_compute_secs(b))
            })
            .unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Metrics {
        let mut m = Metrics::new(2);
        m.steps.push(StepMetrics {
            compute: vec![2.0, 1.0],
            comm: 0.5,
            bytes: 100,
            messages: 10,
            ..StepMetrics::empty(2)
        });
        m.steps.push(StepMetrics {
            compute: vec![1.0, 3.0],
            comm: 0.5,
            bytes: 50,
            messages: 5,
            ..StepMetrics::empty(2)
        });
        m
    }

    #[test]
    fn makespan_is_sum_of_max_plus_comm() {
        let m = sample();
        assert!((m.makespan_secs() - (2.5 + 3.5)).abs() < 1e-12);
        assert!((m.bottleneck_compute_secs() - 5.0).abs() < 1e-12);
        assert!((m.comm_secs() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn overlap_shortens_makespan() {
        let mut m = sample();
        // hide 0.3s of the second step's comm behind compute
        m.steps[1].comm_overlapped = 0.3;
        assert!((m.makespan_secs() - (2.5 + 3.2)).abs() < 1e-12);
        assert!((m.overlapped_comm_secs() - 0.3).abs() < 1e-12);
        assert!((m.overlap_factor() - 0.3).abs() < 1e-12);
        assert!((m.steps[1].comm_exposed() - 0.2).abs() < 1e-12);
        // fully synchronous metrics report zero overlap
        assert_eq!(sample().overlap_factor(), 0.0);
    }

    #[test]
    fn empty_step_record() {
        let s = StepMetrics::empty(3);
        assert_eq!(s.compute, vec![0.0; 3]);
        assert_eq!(s.comm, 0.0);
        assert_eq!(s.comm_exposed(), 0.0);
        assert_eq!(s.directions, vec![Direction::Push; 3]);
        assert_eq!(s.frontier_verts, vec![0; 3]);
        assert!(!s.any_pull());
    }

    #[test]
    fn pull_step_counting() {
        let mut m = sample();
        assert_eq!(m.pull_steps(), 0);
        m.steps[1].directions = vec![Direction::Push, Direction::Pull];
        assert!(m.steps[1].any_pull());
        assert_eq!(m.pull_steps(), 1);
    }

    #[test]
    fn per_partition_totals() {
        let m = sample();
        assert_eq!(m.partition_compute_secs(0), 3.0);
        assert_eq!(m.partition_compute_secs(1), 4.0);
        assert_eq!(m.bottleneck_partition(), 1);
        assert_eq!(m.total_bytes(), 150);
        assert_eq!(m.total_messages(), 15);
    }

    #[test]
    fn chunk_spread_accumulates_per_partition() {
        let mut m = sample();
        assert_eq!(m.chunk_spread_secs(0), 0.0, "single-chunk steps report zero");
        m.steps[0].chunk_max = vec![0.5, 0.2];
        m.steps[0].chunk_min = vec![0.1, 0.2];
        m.steps[1].chunk_max = vec![0.3, 0.0];
        m.steps[1].chunk_min = vec![0.2, 0.0];
        assert!((m.chunk_spread_secs(0) - 0.5).abs() < 1e-12);
        assert_eq!(m.chunk_spread_secs(1), 0.0, "balanced chunks: no spread");
        assert_eq!(m.chunk_spread_secs(9), 0.0, "out of range is zero");
    }
}
