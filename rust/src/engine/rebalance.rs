//! Dynamic α re-balancing (DESIGN.md §5).
//!
//! The launch-time edge shares (α) come from the performance model, but
//! realized per-element rates drift with the workload phase (frontier
//! shape, cache residency, accelerator padding). The controller watches
//! per-element busy time from [`StepMetrics`] and, when the slowest
//! element has been `imbalance_threshold` busier than the fastest for
//! `patience` consecutive supersteps, migrates a **band** of the donor's
//! lowest-degree vertices to the recipient — the same degree-ordered
//! machinery the HIGH/LOW assignment strategies use (`partition::assign`):
//! partitions keep their members sorted by descending degree, so the band
//! is cut from the tail of `local_to_global`.
//!
//! A migration rebuilds the partitioned graph for the new assignment and
//! remaps all per-partition state:
//!
//! - **real vertices** carry their values over through the global id maps
//!   (`part_of` / `local_of` round-trip);
//! - **ghost and dummy slots** are re-initialized to each array's
//!   background value (the dummy slot's value — kernels never write it),
//!   which is the reduce identity for every push channel, so re-sent
//!   `min` messages are idempotent and `add` outboxes restart from zero;
//! - **pull channels** are refreshed with a pull-only exchange (the same
//!   machinery as the cycle-initial synchronization), so the next compute
//!   sees exactly the remote values it would have seen without migration;
//! - **algorithm scratch** (e.g. the BFS visited bitmap) is rebuilt via
//!   [`Algorithm::rebuild_scratch`].
//!
//! Migration points sit *between* supersteps (after the communication
//! phase), where every outbox is clean — that is what makes the remap
//! exact rather than approximate.

use super::comm_phase;
use super::config::RebalanceConfig;
use super::state::{AlgState, CommOp, StateArray};
use crate::alg::Algorithm;
use crate::graph::CsrGraph;
use crate::partition::{low_degree_band, Partition, PartitionedGraph};

/// Imbalance tracker: decides *when* to migrate and between whom.
pub(crate) struct Controller {
    cfg: RebalanceConfig,
    streak: usize,
    migrations: usize,
    /// Donors whose band came back empty (e.g. single-vertex partitions):
    /// observing them again would no-op forever, so they are skipped until
    /// a committed migration reshapes the partitions.
    noop_donors: Vec<usize>,
}

impl Controller {
    pub(crate) fn new(cfg: RebalanceConfig) -> Controller {
        Controller { cfg, streak: 0, migrations: 0, noop_donors: Vec::new() }
    }

    /// Edge-share band moved per migration.
    pub(crate) fn band(&self) -> f64 {
        self.cfg.migration_band
    }

    /// Feed one superstep's per-partition busy seconds; returns
    /// `Some((donor, recipient))` when a migration should fire.
    pub(crate) fn observe(&mut self, busy: &[f64]) -> Option<(usize, usize)> {
        if self.migrations >= self.cfg.max_migrations || busy.len() < 2 {
            return None;
        }
        let mut slow = 0usize;
        let mut fast = 0usize;
        for (p, &b) in busy.iter().enumerate() {
            if b > busy[slow] {
                slow = p;
            }
            if b < busy[fast] {
                fast = p;
            }
        }
        let (hi, lo) = (busy[slow], busy[fast]);
        if self.noop_donors.contains(&slow) {
            // This donor already proved it cannot shed a band; firing again
            // would no-op every window (the PR 8 pinned-partition loop).
            self.streak = 0;
            return None;
        }
        if hi <= 0.0 {
            self.streak = 0;
            return None;
        }
        let imbalance = (hi - lo) / hi;
        if imbalance <= self.cfg.imbalance_threshold {
            self.streak = 0;
            return None;
        }
        self.streak += 1;
        if self.streak < self.cfg.patience {
            return None;
        }
        self.streak = 0;
        self.migrations += 1;
        Some((slow, fast))
    }

    /// The migration fired by the last `observe` selected an empty band:
    /// refund the budget (no rebuild happened) and stop observing the
    /// donor — it cannot shed a vertex until a committed migration
    /// reshapes the partitions.
    pub(crate) fn mark_noop(&mut self, donor: usize) {
        self.migrations = self.migrations.saturating_sub(1);
        if !self.noop_donors.contains(&donor) {
            self.noop_donors.push(donor);
        }
    }

    /// A migration was committed: partition shapes changed, so previously
    /// pinned donors may have grown — clear the no-op blacklist.
    pub(crate) fn committed(&mut self) {
        self.noop_donors.clear();
    }
}

/// A fully prepared migration, not yet committed: the engine installs
/// `pg`/`states` only after re-binding accelerator partitions against the
/// candidate succeeds, so a band that no longer fits the device skips the
/// migration instead of aborting a healthy run.
pub(crate) struct Migration {
    pub pg: PartitionedGraph,
    pub states: Vec<AlgState>,
    /// (bytes, messages) of the post-migration pull refresh.
    pub refresh: (u64, u64),
}

/// Prepare the migration of a band of `donor`'s lowest-degree vertices to
/// `recipient`: rebuild the partitioned graph and remap all state exactly.
/// Returns `None` when there is nothing to move (donor too small).
#[allow(clippy::too_many_arguments)]
pub(crate) fn migrate_band<A: Algorithm>(
    alg: &A,
    graph: &CsrGraph,
    pg: &PartitionedGraph,
    states: &[AlgState],
    channels: &[CommOp],
    donor: usize,
    recipient: usize,
    band: f64,
) -> Option<Migration> {
    debug_assert_ne!(donor, recipient);
    let moved = select_band(graph, &pg.parts[donor], band);
    if moved.is_empty() {
        return None;
    }

    let nparts = pg.parts.len();
    let mut assignment = pg.part_of.clone();
    for &gv in &moved {
        assignment[gv as usize] = recipient as u8;
    }
    // Rebuild re-places every partition with the run's placement policy
    // (DESIGN.md §9): migrated vertices land where the layout says, not
    // appended — the post-migration layout is indistinguishable from a
    // fresh build of the new assignment.
    let new_pg = PartitionedGraph::build_placed(graph, &assignment, nparts, pg.placement);
    let mut new_states = remap_states(pg, states, &new_pg);

    // Algorithm-private scratch is partition-shaped; rebuild it.
    for (part, st) in new_pg.parts.iter().zip(new_states.iter_mut()) {
        alg.rebuild_scratch(part, st);
    }

    // Refresh pull channels so the next compute sees the same remote
    // values it would have without the migration.
    let refresh = comm_phase(&new_pg, &mut new_states, channels, true);
    Some(Migration { pg: new_pg, states: new_states, refresh })
}

/// Pick the band: walk the donor's members from the low-degree tail until
/// the band's edge share is covered, bounded by a proportional vertex cap
/// so zero-degree tails can't drain the partition. Never empties the
/// donor. Returns global vertex ids.
///
/// Placement-agnostic: `local_to_global` is only degree-ordered under the
/// default [`Placement`](crate::partition::Placement), so the degree-
/// descending view is rebuilt here explicitly (stable by local id, which
/// reproduces the historical band byte-for-byte under `DegreeDesc`).
pub(crate) fn select_band(g: &CsrGraph, donor: &Partition, band: f64) -> Vec<u32> {
    if donor.nv <= 1 {
        return Vec::new();
    }
    let target_edges = (band * donor.edge_count() as f64).max(1.0);
    let max_vertices =
        ((band * donor.nv as f64).ceil() as usize).clamp(1, donor.nv - 1);
    let mut members_desc = donor.local_to_global.clone();
    // Tie-break by global id: a stable sort alone would inherit the
    // placement's tie order (BFS-order layouts shuffle the equal-degree
    // tail), and the band must not depend on layout. The (degree, id) key
    // also reproduces the historical DegreeDesc band byte-for-byte.
    members_desc.sort_by_key(|&v| (std::cmp::Reverse(g.out_degree(v)), v));
    low_degree_band(g, &members_desc, target_edges, max_vertices)
}

/// Remap every partition's state arrays onto the freshly built
/// partitioning: real vertices carry over via global ids; ghost and dummy
/// slots take the array's background value (read from the old dummy slot,
/// which kernels never touch).
fn remap_states(
    old_pg: &PartitionedGraph,
    old_states: &[AlgState],
    new_pg: &PartitionedGraph,
) -> Vec<AlgState> {
    new_pg
        .parts
        .iter()
        .map(|part| {
            let template = &old_states[part.id];
            let arrays = template
                .arrays
                .iter()
                .enumerate()
                .map(|(k, arr)| remap_array(old_pg, old_states, part, k, arr, false))
                .collect();
            let aux = template
                .aux
                .iter()
                .enumerate()
                .map(|(k, arr)| remap_array(old_pg, old_states, part, k, arr, true))
                .collect();
            AlgState { arrays, aux, scratch: Vec::new() }
        })
        .collect()
}

fn remap_array(
    old_pg: &PartitionedGraph,
    old_states: &[AlgState],
    part: &Partition,
    k: usize,
    template: &StateArray,
    aux: bool,
) -> StateArray {
    let n = part.state_len();
    match template {
        StateArray::I32(old) => {
            let fill = *old.last().expect("state arrays are never empty");
            let mut out = vec![fill; n];
            for (l, &gv) in part.local_to_global.iter().enumerate() {
                let op = old_pg.part_of[gv as usize] as usize;
                let ol = old_pg.local_of[gv as usize] as usize;
                let src = if aux { &old_states[op].aux[k] } else { &old_states[op].arrays[k] };
                out[l] = src.as_i32()[ol];
            }
            StateArray::I32(out)
        }
        StateArray::F32(old) => {
            let fill = *old.last().expect("state arrays are never empty");
            let mut out = vec![fill; n];
            for (l, &gv) in part.local_to_global.iter().enumerate() {
                let op = old_pg.part_of[gv as usize] as usize;
                let ol = old_pg.local_of[gv as usize] as usize;
                let src = if aux { &old_states[op].aux[k] } else { &old_states[op].arrays[k] };
                out[l] = src.as_f32()[ol];
            }
            StateArray::F32(out)
        }
        StateArray::U64(old) => {
            let fill = *old.last().expect("state arrays are never empty");
            let mut out = vec![fill; n];
            for (l, &gv) in part.local_to_global.iter().enumerate() {
                let op = old_pg.part_of[gv as usize] as usize;
                let ol = old_pg.local_of[gv as usize] as usize;
                let src = if aux { &old_states[op].aux[k] } else { &old_states[op].arrays[k] };
                out[l] = src.as_u64()[ol];
            }
            StateArray::U64(out)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generator::{rmat, RmatParams};
    use crate::graph::CsrGraph;
    use crate::partition::Strategy;

    fn controller(threshold: f64, patience: usize, max: usize) -> Controller {
        Controller::new(RebalanceConfig {
            imbalance_threshold: threshold,
            patience,
            migration_band: 0.1,
            max_migrations: max,
        })
    }

    #[test]
    fn controller_waits_for_patience() {
        let mut c = controller(0.3, 2, 10);
        assert_eq!(c.observe(&[1.0, 0.5]), None); // streak 1
        assert_eq!(c.observe(&[1.0, 0.5]), Some((0, 1))); // streak 2 fires
        // streak resets after firing
        assert_eq!(c.observe(&[1.0, 0.5]), None);
    }

    #[test]
    fn controller_resets_on_balance() {
        let mut c = controller(0.3, 2, 10);
        assert_eq!(c.observe(&[1.0, 0.5]), None);
        assert_eq!(c.observe(&[1.0, 0.95]), None); // balanced: reset
        assert_eq!(c.observe(&[1.0, 0.5]), None); // streak restarts at 1
    }

    #[test]
    fn controller_respects_max_migrations_and_direction() {
        let mut c = controller(0.3, 1, 1);
        assert_eq!(c.observe(&[0.2, 1.0]), Some((1, 0))); // donor = slowest
        assert_eq!(c.observe(&[0.2, 1.0]), None); // cap reached
        let mut c = controller(0.3, 1, 5);
        assert_eq!(c.observe(&[0.0, 0.0]), None); // no busy time: no signal
        assert_eq!(c.observe(&[1.0]), None); // single partition
    }

    #[test]
    fn noop_donor_is_blacklisted_until_a_commit() {
        // Regression (PR 8): a pinned one-vertex donor used to re-fire the
        // controller every `patience` window, silently draining the
        // migration budget on no-ops.
        let mut c = controller(0.3, 1, 3);
        assert_eq!(c.observe(&[1.0, 0.1]), Some((0, 1)));
        // the migration came back empty: refund + blacklist donor 0
        c.mark_noop(0);
        for _ in 0..32 {
            assert_eq!(c.observe(&[1.0, 0.1]), None, "blacklisted donor must not re-fire");
        }
        // the budget was refunded, so a *different* donor still has all 3
        assert_eq!(c.observe(&[0.1, 1.0]), Some((1, 0)));
        // a committed migration reshapes partitions: blacklist clears
        c.committed();
        assert_eq!(c.observe(&[1.0, 0.1]), Some((0, 1)));
        // mark_noop is idempotent
        c.mark_noop(0);
        c.mark_noop(0);
        assert_eq!(c.observe(&[1.0, 0.1]), None);
    }

    #[test]
    fn band_respects_caps_and_degree_order() {
        let g = CsrGraph::from_edge_list(&rmat(&RmatParams::paper(10, 3)));
        let pg = PartitionedGraph::partition(&g, Strategy::High, &[0.5, 0.5], 1);
        let donor = &pg.parts[0];
        let moved = select_band(&g, donor, 0.1);
        assert!(!moved.is_empty());
        assert!(moved.len() < donor.nv);
        // the band comes from the low-degree tail: every moved vertex has
        // degree <= every kept vertex's degree
        let max_moved = moved.iter().map(|&v| g.out_degree(v)).max().unwrap();
        let kept_min = donor
            .local_to_global
            .iter()
            .take(donor.nv - moved.len())
            .map(|&v| g.out_degree(v))
            .min()
            .unwrap();
        assert!(max_moved <= kept_min, "moved max {max_moved} kept min {kept_min}");
        // tiny partitions refuse to move anything
        let single = Partition {
            id: 0,
            nv: 1,
            local_to_global: vec![0],
            csr: crate::partition::LocalCsr {
                row_offsets: vec![0, 0],
                targets: vec![],
                weights: None,
                local_counts: vec![0],
            },
            ghosts: vec![],
            n_ghost: 0,
            canonical_order: vec![0],
            transpose_cache: std::sync::OnceLock::new(),
        };
        assert!(select_band(&g, &single, 0.5).is_empty());
    }

    #[test]
    fn migrate_band_rebuilds_with_the_graphs_placement() {
        // The engine-internal migration path must re-place through
        // `pg.placement` — migrated vertices land where the layout policy
        // says, not appended — and remap real-vertex state exactly.
        use crate::alg::cc::Cc;
        use crate::partition::{Placement, ALL_PLACEMENTS};
        let g = CsrGraph::from_edge_list(&rmat(&RmatParams::paper(9, 15)));
        for placement in ALL_PLACEMENTS {
            let pg = PartitionedGraph::partition_placed(
                &g,
                Strategy::Rand,
                &[0.7, 0.3],
                2,
                placement,
            );
            let mut alg = Cc::new();
            let states: Vec<AlgState> =
                pg.parts.iter().map(|p| alg.init_state(&pg, p)).collect();
            let channels = alg.channels(0);
            let labels_of = |pg: &PartitionedGraph, states: &[AlgState]| -> Vec<i32> {
                let locals: Vec<Vec<i32>> =
                    states.iter().map(|s| s.arrays[0].as_i32().to_vec()).collect();
                pg.collect_to_global(&locals)
            };
            let before = labels_of(&pg, &states);
            let mig = migrate_band(&alg, &g, &pg, &states, &channels, 0, 1, 0.2)
                .expect("band must move on a 0.7/0.3 split");
            assert_eq!(mig.pg.placement, placement, "placement must survive migration");
            assert!(mig.pg.parts[1].nv > pg.parts[1].nv, "recipient must grow");
            // layout contract holds in the rebuilt partitions (an appended
            // band would violate every ordered placement)
            for p in &mig.pg.parts {
                match placement {
                    Placement::AssignmentOrder => {
                        assert!(p.local_to_global.windows(2).all(|w| w[0] < w[1]))
                    }
                    Placement::DegreeDesc => assert!(p
                        .local_to_global
                        .windows(2)
                        .all(|w| g.out_degree(w[0]) >= g.out_degree(w[1]))),
                    Placement::DegreeAsc => assert!(p
                        .local_to_global
                        .windows(2)
                        .all(|w| g.out_degree(w[0]) <= g.out_degree(w[1]))),
                    Placement::BfsOrder => {
                        let max =
                            p.local_to_global.iter().map(|&v| g.out_degree(v)).max().unwrap();
                        assert_eq!(g.out_degree(p.local_to_global[0]), max);
                    }
                }
                // canonical order still inverts the new permutation
                let seq: Vec<u32> = p
                    .canonical_order
                    .iter()
                    .map(|&l| p.local_to_global[l as usize])
                    .collect();
                assert!(seq.windows(2).all(|w| w[0] < w[1]), "{placement:?}");
            }
            // real-vertex state carried over exactly through the remap
            assert_eq!(labels_of(&mig.pg, &mig.states), before, "{placement:?}");
        }
    }

    #[test]
    fn band_selection_is_placement_invariant() {
        // The degree-descending view is rebuilt from the member set, so
        // the chosen band cannot depend on the partition's layout.
        use crate::partition::ALL_PLACEMENTS;
        let g = CsrGraph::from_edge_list(&rmat(&RmatParams::paper(10, 3)));
        let a = crate::partition::assign(&g, Strategy::High, &[0.5, 0.5], 1);
        let base = {
            let pg = PartitionedGraph::build(&g, &a, 2);
            select_band(&g, &pg.parts[0], 0.1)
        };
        assert!(!base.is_empty());
        for placement in ALL_PLACEMENTS {
            let pg = PartitionedGraph::build_placed(&g, &a, 2, placement);
            assert_eq!(select_band(&g, &pg.parts[0], 0.1), base, "{placement:?}");
        }
    }
}
