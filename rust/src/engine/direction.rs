//! Direction-optimized traversal policy (DESIGN.md §8).
//!
//! Beamer et al. 2012 ("Direction-Optimizing Breadth-First Search") showed
//! that on scale-free graphs the middle BFS supersteps — where the frontier
//! covers most of the graph — are far cheaper bottom-up (every unexplored
//! vertex probes its *in*-edges and early-exits on the first frontier
//! parent) than top-down (the frontier expands every out-edge). Sallinen
//! et al. 2015 carried the idea to the hybrid CPU+GPU setting: the switch
//! is decided **per processing element**, so a CPU partition can sweep
//! bottom-up while an accelerator partition stays top-down (its bulk model
//! has no early exit to exploit).
//!
//! This module holds the policy only; the mechanism lives in
//! `partition::TransposeCsr` (the in-edge CSR) and in each algorithm's
//! pull kernel (`StepCtx::direction`). The engine evaluates the policy
//! before every superstep for every CPU partition of an algorithm that
//! reports [`Algorithm::frontier_stats`](crate::alg::Algorithm); chosen
//! directions and the frontier estimates they were based on are recorded
//! in [`StepMetrics`](super::StepMetrics).

/// Traversal direction of one partition's compute phase for one superstep.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Direction {
    /// Top-down: frontier vertices expand their out-edges.
    #[default]
    Push,
    /// Bottom-up: unexplored vertices probe their in-edges through the
    /// partition's transpose CSR.
    Pull,
}

impl Direction {
    pub fn name(&self) -> &'static str {
        match self {
            Direction::Push => "push",
            Direction::Pull => "pull",
        }
    }
}

/// Frontier-shape estimate for one partition at one superstep boundary,
/// reported by the algorithm (BFS scans its levels array). Edge counts are
/// out-degree sums over the partition's local CSR — the `m_f` / `m_u`
/// quantities of Beamer's heuristic, restricted to this element.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct FrontierStats {
    /// Vertices active in the coming superstep (`n_f`).
    pub frontier_verts: u64,
    /// Σ out-degree over the frontier (`m_f`).
    pub frontier_edges: u64,
    /// Vertices not yet explored (`n_u`).
    pub unexplored_verts: u64,
    /// Σ out-degree over unexplored vertices (`m_u` proxy).
    pub unexplored_edges: u64,
    /// Real local vertices in the partition (`n`).
    pub total_verts: u64,
}

/// Beamer α/β switch heuristic knobs.
///
/// - Push→Pull when `m_f > m_u / alpha` — the frontier is about to touch
///   more edges than a bottom-up sweep would scan.
/// - Pull→Push when `n_f < n / beta` — the frontier shrank enough that
///   scanning all unexplored vertices is wasteful again.
///
/// Defaults are Beamer's published `α = 15`, `β = 18`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DirectionConfig {
    pub alpha: f64,
    pub beta: f64,
}

impl Default for DirectionConfig {
    fn default() -> DirectionConfig {
        DirectionConfig { alpha: 15.0, beta: 18.0 }
    }
}

impl DirectionConfig {
    /// Validate the knobs; the engine calls this before the first
    /// superstep so operator mistakes fail loudly, not mid-run.
    pub fn validate(&self) -> Result<(), String> {
        if !(self.alpha.is_finite() && self.alpha > 0.0) {
            return Err(format!(
                "direction: alpha must be finite and > 0, got {}",
                self.alpha
            ));
        }
        if !(self.beta.is_finite() && self.beta > 0.0) {
            return Err(format!(
                "direction: beta must be finite and > 0, got {}",
                self.beta
            ));
        }
        Ok(())
    }

    /// Per-element decision for the coming superstep, given the previous
    /// direction and the partition's frontier estimate. Hysteresis comes
    /// from conditioning on `prev` — exactly Beamer's two-threshold form.
    pub fn next(&self, prev: Direction, s: &FrontierStats) -> Direction {
        match prev {
            Direction::Push
                if (s.frontier_edges as f64) > s.unexplored_edges as f64 / self.alpha =>
            {
                Direction::Pull
            }
            Direction::Pull
                if (s.frontier_verts as f64) < s.total_verts as f64 / self.beta =>
            {
                Direction::Push
            }
            _ => prev,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn stats(nf: u64, mf: u64, nu: u64, mu: u64, n: u64) -> FrontierStats {
        FrontierStats {
            frontier_verts: nf,
            frontier_edges: mf,
            unexplored_verts: nu,
            unexplored_edges: mu,
            total_verts: n,
        }
    }

    #[test]
    fn defaults_are_beamers() {
        let d = DirectionConfig::default();
        assert_eq!(d.alpha, 15.0);
        assert_eq!(d.beta, 18.0);
        assert!(d.validate().is_ok());
    }

    #[test]
    fn validation_rejects_bad_knobs() {
        assert!(DirectionConfig { alpha: 0.0, beta: 18.0 }.validate().is_err());
        assert!(DirectionConfig { alpha: -1.0, beta: 18.0 }.validate().is_err());
        assert!(DirectionConfig { alpha: 15.0, beta: 0.0 }.validate().is_err());
        assert!(DirectionConfig { alpha: f64::NAN, beta: 18.0 }.validate().is_err());
        assert!(DirectionConfig { alpha: 15.0, beta: f64::INFINITY }
            .validate()
            .is_err());
    }

    #[test]
    fn push_switches_to_pull_on_heavy_frontier() {
        let d = DirectionConfig::default();
        // m_f = 200 > m_u / 15 = 100: switch
        assert_eq!(
            d.next(Direction::Push, &stats(50, 200, 500, 1500, 1000)),
            Direction::Pull
        );
        // m_f = 50 <= 100: stay
        assert_eq!(
            d.next(Direction::Push, &stats(50, 50, 500, 1500, 1000)),
            Direction::Push
        );
    }

    #[test]
    fn pull_switches_back_on_small_frontier() {
        let d = DirectionConfig::default();
        // n_f = 10 < n / 18 = 55.5: switch back
        assert_eq!(
            d.next(Direction::Pull, &stats(10, 20, 100, 400, 1000)),
            Direction::Push
        );
        // n_f = 100 >= 55.5: stay bottom-up
        assert_eq!(
            d.next(Direction::Pull, &stats(100, 300, 100, 400, 1000)),
            Direction::Pull
        );
    }

    #[test]
    fn empty_frontier_always_lands_push() {
        let d = DirectionConfig::default();
        assert_eq!(d.next(Direction::Push, &stats(0, 0, 0, 0, 8)), Direction::Push);
        assert_eq!(d.next(Direction::Pull, &stats(0, 0, 0, 0, 8)), Direction::Push);
    }

    #[test]
    fn hysteresis_holds_between_thresholds() {
        // A frontier in the dead band keeps whatever direction it had.
        let d = DirectionConfig { alpha: 2.0, beta: 2.0 };
        let s = stats(600, 300, 400, 1000, 1000); // m_f < m_u/2, n_f > n/2
        assert_eq!(d.next(Direction::Push, &s), Direction::Push);
        assert_eq!(d.next(Direction::Pull, &s), Direction::Pull);
    }

    #[test]
    fn direction_names() {
        assert_eq!(Direction::Push.name(), "push");
        assert_eq!(Direction::Pull.name(), "pull");
        assert_eq!(Direction::default(), Direction::Push);
    }
}
