//! The BSP engine (paper §4): partition → per-superstep
//! compute / communicate / synchronize → terminate on quiescence.
//!
//! Each partition is executed by a processing element: the native Rust CPU
//! element, or the accelerator element (AOT JAX/Pallas programs via PJRT).
//! The communication phase exchanges ghost-slot values between partitions
//! with the algorithm's reduction operator — the paper's inbox/outbox
//! machinery with message aggregation (§4.3.2) — and is identical code for
//! every element pairing.
//!
//! Two superstep executors share that machinery (DESIGN.md §4):
//!
//! - [`ExecMode::Synchronous`]: the paper's lockstep loop — all partitions
//!   compute, then all pairwise exchanges run, then the quiescence vote.
//! - [`ExecMode::Pipelined`]: partitions compute concurrently on their own
//!   threads and each pairwise exchange starts as soon as both endpoints
//!   finished computing, overlapping communication with the compute of
//!   still-running partitions (the `pipeline` module). Output is
//!   bit-identical to the synchronous executor.
//!
//! On top of either executor, an optional dynamic α controller
//! (the `rebalance` module, [`RebalanceConfig`]) watches per-element busy time and
//! migrates bands of boundary vertices from the slowest to the fastest
//! element when imbalance persists (DESIGN.md §5).

pub mod config;
pub mod direction;
pub mod metrics;
mod pipeline;
mod rebalance;
pub mod state;

pub use crate::alg::INF_I32;
pub use crate::partition::Placement;
pub use crate::util::threadpool::Balance;
pub use config::{
    default_threads, detected_threads, ConfigError, ElementKind, EngineConfig, ExecMode,
    RebalanceConfig,
};
pub use direction::{Direction, DirectionConfig, FrontierStats};
pub use metrics::{MemCounters, Metrics, StepMetrics};
pub use state::{AlgState, Channel, ChannelKind, CommOp, FieldType, Reduce, StateArray, TypeMismatch};

use crate::alg::{Algorithm, StepCtx};
use crate::graph::CsrGraph;
use crate::partition::{BetaStats, GhostTable, PartitionedGraph};
use crate::runtime::{backend_unavailable, AccelPartition, PjrtRuntime};
use crate::util::threadpool::ensure_workers;
use crate::util::timer::{timed, Stopwatch};
use anyhow::{bail, Context, Result};
use std::time::Instant;

/// Result of a hybrid run.
pub struct RunResult {
    /// Global per-vertex result (e.g. BFS levels, PageRank ranks).
    pub output: StateArray,
    /// Additional collected arrays declared by
    /// [`Algorithm::extra_outputs`] (multi-source BFS collects one level
    /// array per lane on top of the `seen` word in `output`). Empty for
    /// every single-output algorithm.
    pub extra: Vec<StateArray>,
    pub metrics: Metrics,
    pub supersteps: usize,
    /// Realized per-partition edge shares (α = shares[0]); reflects the
    /// *final* partitioning after any dynamic re-balancing.
    pub shares: Vec<f64>,
    /// Per-partition vertex counts (Figure 13), final partitioning.
    pub vertices: Vec<usize>,
    /// Boundary-edge statistics (Figure 4), final partitioning.
    pub beta: BetaStats,
    /// Per-partition memory footprints (Table 5), final partitioning.
    pub footprints: Vec<PartitionFootprint>,
    /// Per-partition communicated slots per superstep (outbox + inbox
    /// ghost entries) — the model's per-partition |E_p^b| after reduction.
    pub comm_slots: Vec<u64>,
}

impl RunResult {
    pub fn makespan_secs(&self) -> f64 {
        self.metrics.makespan_secs()
    }
}

/// Memory footprint of one partition, in the paper's Table 5 categories.
#[derive(Debug, Clone, Default)]
pub struct PartitionFootprint {
    pub vertices: usize,
    pub edges: usize,
    /// Graph structure (CSR / COO + weights).
    pub graph_bytes: u64,
    /// Inbox: ghost slots other partitions keep *of our* vertices.
    pub inbox_bytes: u64,
    /// Outbox: our ghost slots for remote vertices.
    pub outbox_bytes: u64,
    /// Algorithm state arrays.
    pub state_bytes: u64,
}

impl PartitionFootprint {
    pub fn total(&self) -> u64 {
        self.graph_bytes + self.inbox_bytes + self.outbox_bytes + self.state_bytes
    }
}

pub(crate) enum Element {
    Cpu { threads: usize },
    Accel(Box<AccelPartition>),
    /// Wide-parallel host fallback for an `ElementKind::Accelerator`
    /// partition whose PJRT program could not be *compiled* (the vendored
    /// stub's only failure point). Runs the same derived CPU kernels with
    /// full-machine, edge-balanced parallelism — a measured execution path
    /// with real per-partition busy time, instead of a dead end
    /// (DESIGN.md §11). Everything ahead of compilation (manifest, size
    /// class, memory budget, spec checks) must still have passed: those
    /// failures stay hard errors.
    HostWide { threads: usize },
}

/// Outcome of one executed superstep (either executor).
pub(crate) struct SuperstepOutcome {
    pub step: StepMetrics,
    pub any_changed: bool,
}

/// Run `alg` on `g` under `cfg`. The graph is partitioned per the config,
/// each partition is bound to its element, and BSP cycles execute until
/// the algorithm quiesces (or its fixed round count elapses).
pub fn run<A: Algorithm>(g: &CsrGraph, alg: &mut A, cfg: &EngineConfig) -> Result<RunResult> {
    let spec = alg.spec();
    if spec.needs_weights && g.weights.is_none() {
        bail!("{} requires edge weights", spec.name);
    }
    cfg.validate()?;
    let nparts = cfg.num_partitions();
    if let Some(rb) = &cfg.rebalance {
        rb.validate(nparts).map_err(anyhow::Error::msg)?;
    }
    if let Some(d) = &cfg.direction {
        d.validate().map_err(anyhow::Error::msg)?;
    }

    // --- graph preparation (§4.2: the engine owns the data layout) -------
    let mut prepared: Option<CsrGraph> = None;
    if spec.undirected {
        prepared = Some(g.to_undirected());
    }
    if spec.reversed {
        let base = prepared.as_ref().unwrap_or(g);
        prepared = Some(base.reverse());
    }
    let pg_graph: &CsrGraph = prepared.as_ref().unwrap_or(g);
    alg.prepare(g, pg_graph);

    // --- partition --------------------------------------------------------
    let pg = PartitionedGraph::partition_placed(
        pg_graph,
        cfg.strategy,
        &cfg.shares,
        cfg.seed,
        cfg.placement,
    );
    run_inner(pg_graph, PgRef::Owned(pg), alg, cfg)
}

/// Run `alg` over a pre-partitioned **shared** graph — the serving layer's
/// path (DESIGN.md §13). The engine borrows `pg` immutably, so any number
/// of concurrent `run_shared` calls may execute against one
/// `Arc<PartitionedGraph>`: each run owns its per-partition `AlgState`s,
/// and the worker pool accepts concurrent submitters (see
/// `util::threadpool`'s concurrent-caller contract).
///
/// The caller owns graph preparation: `prepared` must already be the
/// undirected/reversed view the algorithm's spec asks for, and `pg` must
/// be a partitioning *of `prepared`* matching `cfg`'s element count.
/// `original` is the untransformed graph handed to [`Algorithm::prepare`]
/// (pass the same reference as `prepared` when the spec needs no
/// transform). Dynamic re-balancing is rejected: it would mutate the
/// shared partitioning mid-flight.
pub fn run_shared<A: Algorithm>(
    original: &CsrGraph,
    prepared: &CsrGraph,
    pg: &PartitionedGraph,
    alg: &mut A,
    cfg: &EngineConfig,
) -> Result<RunResult> {
    let spec = alg.spec();
    if spec.needs_weights && prepared.weights.is_none() {
        bail!("{} requires edge weights", spec.name);
    }
    cfg.validate()?;
    if cfg.rebalance.is_some() {
        bail!("run_shared: dynamic re-balancing would mutate the shared partitioned graph");
    }
    if let Some(d) = &cfg.direction {
        d.validate().map_err(anyhow::Error::msg)?;
    }
    if cfg.num_partitions() != pg.parts.len() {
        bail!(
            "run_shared: config has {} elements but the shared graph has {} partitions",
            cfg.num_partitions(),
            pg.parts.len()
        );
    }
    alg.prepare(original, prepared);
    run_inner(prepared, PgRef::Shared(pg), alg, cfg)
}

/// Owned-vs-borrowed partitioned graph for [`run_inner`]: the classic path
/// owns its partitioning (the α controller may rebuild it mid-run); the
/// serving path borrows an immutable shared one (re-balancing rejected up
/// front by [`run_shared`]).
enum PgRef<'a> {
    Owned(PartitionedGraph),
    Shared(&'a PartitionedGraph),
}

impl PgRef<'_> {
    fn get(&self) -> &PartitionedGraph {
        match self {
            PgRef::Owned(p) => p,
            PgRef::Shared(p) => p,
        }
    }
}

/// Outcome of one α-controller migration attempt (see the controller block
/// in [`run_inner`]).
enum MigrationAttempt {
    /// Candidate built and accelerators re-bound: ready to commit.
    Ready(rebalance::Migration, Vec<(usize, AccelPartition)>),
    /// The donor had no movable band — a distinct no-op (nothing was
    /// rebuilt; counted in `Metrics::noop_migrations`).
    Noop,
    /// The candidate no longer fits the accelerator — migration skipped,
    /// run continues on the current partitioning.
    DeviceSkip,
}

/// Shared BSP core behind [`run`] and [`run_shared`]; `pg_graph` is the
/// (prepared) graph `pg` partitions.
fn run_inner<A: Algorithm>(
    pg_graph: &CsrGraph,
    mut pg: PgRef<'_>,
    alg: &mut A,
    cfg: &EngineConfig,
) -> Result<RunResult> {
    let spec = alg.spec();
    let nparts = cfg.num_partitions();

    // --- state + elements --------------------------------------------------
    let mut states: Vec<AlgState> = pg
        .get()
        .parts
        .iter()
        .map(|p| alg.init_state(pg.get(), p))
        .collect();

    let mut runtime: Option<PjrtRuntime> = None;
    if cfg.has_accelerator() {
        runtime = Some(PjrtRuntime::new(&cfg.artifacts_dir)?);
    }

    let mut elements: Vec<Element> = Vec::with_capacity(nparts);
    for (pid, kind) in cfg.elements.iter().enumerate() {
        match kind {
            ElementKind::Cpu { threads } => elements.push(Element::Cpu { threads: *threads }),
            ElementKind::Accelerator => {
                let rt = runtime.as_mut().expect("runtime initialized above");
                let prog = alg.program(0);
                match rt.instantiate(
                    &prog,
                    &pg.get().parts[pid],
                    &states[pid],
                    cfg.accel_memory_budget,
                ) {
                    Ok(accel) => elements.push(Element::Accel(Box::new(accel))),
                    // The backend itself is unavailable (the vendored PJRT
                    // stub refuses every compile): fall back to the wide-
                    // parallel host tier instead of failing the run. Every
                    // check ahead of compilation — manifest, size class,
                    // memory budget, spec — already passed, so the program
                    // is valid; only the device is missing.
                    Err(e) if backend_unavailable(&e) => {
                        elements.push(Element::HostWide { threads: default_threads() });
                    }
                    Err(e) => {
                        return Err(e.context(format!(
                            "partition {pid} ({} vertices, {} edges) does not fit the accelerator",
                            pg.get().parts[pid].nv,
                            pg.get().parts[pid].edge_count()
                        )));
                    }
                }
            }
        }
    }

    // Warm the persistent worker pool once per run, sized for the widest
    // element (DESIGN.md §11): supersteps then dispatch chunks to parked
    // workers instead of spawning threads.
    let pool_threads = elements
        .iter()
        .map(|el| match el {
            Element::Cpu { threads } | Element::HostWide { threads } => *threads,
            Element::Accel(_) => 1,
        })
        .max()
        .unwrap_or(1);
    ensure_workers(pool_threads);

    // --- BSP cycles --------------------------------------------------------
    let wall0 = Instant::now();
    let mut metrics = Metrics::new(nparts);
    let mut total_steps = 0usize;
    let mut controller = cfg.rebalance.map(rebalance::Controller::new);
    // Per-element traversal directions (DESIGN.md §8), carried across
    // supersteps so the α/β policy has hysteresis.
    let mut directions = vec![Direction::Push; nparts];

    for cycle in 0..alg.cycles() {
        alg.begin_cycle(cycle, pg.get(), &mut states);
        let channels = alg.channels(cycle);

        // Re-bind accelerator partitions to this cycle's program.
        if cycle > 0 {
            let rebinds = build_accel_rebinds(
                alg, cycle, pg.get(), &states, &elements, runtime.as_mut(), cfg,
            )?;
            commit_accel_rebinds(&mut elements, rebinds);
        }

        // Initial synchronization: pull channels must see remote values
        // before the first compute (PageRank contributions, BC ratios).
        {
            let mut sw = Stopwatch::new();
            let (bytes, msgs) = sw.time(|| comm_phase(pg.get(), &mut states, &channels, true));
            let mut step = StepMetrics::empty(nparts);
            step.comm = sw.secs();
            step.bytes = bytes;
            step.messages = msgs;
            metrics.steps.push(step);
        }

        let mut superstep = 0usize;
        loop {
            // -- per-element direction decision (DESIGN.md §8) --------------
            // Accelerator partitions always stay top-down: their bulk
            // kernels have no early exit for a bottom-up sweep to exploit,
            // and the AOT programs are push-oriented. CPU partitions of a
            // pull-capable algorithm consult the α/β policy against their
            // own frontier shape — directions are per element, so the CPU
            // can sweep bottom-up while an accelerator keeps pushing.
            let mut dir_stats: Vec<Option<FrontierStats>> = vec![None; nparts];
            if let Some(dc) = &cfg.direction {
                if alg.supports_pull() {
                    for pid in 0..nparts {
                        if matches!(elements[pid], Element::Cpu { .. }) {
                            if let Some(fs) =
                                alg.frontier_stats(&pg.get().parts[pid], &states[pid], superstep)
                            {
                                directions[pid] = dc.next(directions[pid], &fs);
                                dir_stats[pid] = Some(fs);
                            }
                        } else {
                            directions[pid] = Direction::Push;
                        }
                    }
                }
            }

            let mut outcome = match cfg.mode {
                ExecMode::Synchronous => run_superstep_sync(
                    &*alg, pg.get(), &mut states, &mut elements, &channels, &directions, cycle,
                    superstep, cfg.instrument, cfg.balance, &mut metrics,
                )?,
                ExecMode::Pipelined => pipeline::run_superstep(
                    &*alg, pg.get(), &mut states, &mut elements, &channels, &directions, cycle,
                    superstep, cfg.instrument, cfg.balance, &mut metrics,
                )?,
            };
            outcome.step.directions.copy_from_slice(&directions);
            for (pid, fs) in dir_stats.iter().enumerate() {
                if let Some(fs) = fs {
                    outcome.step.frontier_verts[pid] = fs.frontier_verts;
                    outcome.step.frontier_edges[pid] = fs.frontier_edges;
                    outcome.step.unexplored_edges[pid] = fs.unexplored_edges;
                }
            }
            let any_changed = outcome.any_changed;
            metrics.steps.push(outcome.step);
            superstep += 1;
            total_steps += 1;

            if alg.cycle_done(cycle, superstep, any_changed) {
                break;
            }
            if superstep >= cfg.max_supersteps {
                bail!(
                    "{}: exceeded max_supersteps={} in cycle {cycle}",
                    spec.name,
                    cfg.max_supersteps
                );
            }

            // -- dynamic α controller (DESIGN.md §5) ------------------------
            if let Some(ctrl) = controller.as_mut() {
                let busy = metrics.steps.last().expect("step just pushed").compute.clone();
                if let Some((donor, recipient)) = ctrl.observe(&busy) {
                    let (attempt, secs) = timed(|| {
                        let Some(candidate) = rebalance::migrate_band(
                            &*alg,
                            pg_graph,
                            pg.get(),
                            &states,
                            &channels,
                            donor,
                            recipient,
                            ctrl.band(),
                        ) else {
                            return MigrationAttempt::Noop;
                        };
                        // Re-bind accelerators against the candidate BEFORE
                        // committing: a band that no longer fits the device
                        // skips this migration instead of aborting the run.
                        match build_accel_rebinds(
                            &*alg, cycle, &candidate.pg, &candidate.states, &elements,
                            runtime.as_mut(), cfg,
                        ) {
                            Ok(rebinds) => MigrationAttempt::Ready(candidate, rebinds),
                            Err(_) => MigrationAttempt::DeviceSkip,
                        }
                    });
                    match attempt {
                        MigrationAttempt::Ready(candidate, rebinds) => {
                            let rebalance::Migration { pg: new_pg, states: new_states, refresh } =
                                candidate;
                            match &mut pg {
                                PgRef::Owned(p) => *p = new_pg,
                                // run_shared rejects rebalance up front.
                                PgRef::Shared(_) => {
                                    unreachable!("rebalance on a shared graph is rejected")
                                }
                            }
                            states = new_states;
                            commit_accel_rebinds(&mut elements, rebinds);
                            metrics.migrations += 1;
                            ctrl.committed();
                            // migration (rebuild + remap + pull refresh) is
                            // engine overhead on the critical path: charge it
                            // as exposed communication of the step just run.
                            let last = metrics.steps.last_mut().expect("step just pushed");
                            last.comm += secs;
                            last.bytes += refresh.0;
                            last.messages += refresh.1;
                        }
                        // Empty band: nothing was rebuilt. Count the no-op
                        // distinctly and stop observing this donor — a
                        // pinned single-vertex partition used to re-fire
                        // the controller every window (PR 8 bugfix).
                        MigrationAttempt::Noop => {
                            metrics.noop_migrations += 1;
                            ctrl.mark_noop(donor);
                        }
                        MigrationAttempt::DeviceSkip => {}
                    }
                }
            }
        }
    }
    metrics.wall_secs = wall0.elapsed().as_secs_f64();

    // --- collect (paper: alg_collect via local→global maps) ----------------
    let pgr = pg.get();
    let out_idx = alg.output_array();
    let output = collect_output(pgr, &states, out_idx);
    let extra: Vec<StateArray> = alg
        .extra_outputs()
        .into_iter()
        .map(|idx| collect_output(pgr, &states, idx))
        .collect();

    let footprints = footprints_of(&*alg, pgr, &states, &elements);

    let mut comm_slots = vec![0u64; nparts];
    for p in &pgr.parts {
        for t in &p.ghosts {
            comm_slots[p.id] += t.len() as u64;
            comm_slots[t.remote_part] += t.len() as u64;
        }
    }

    Ok(RunResult {
        output,
        extra,
        metrics,
        supersteps: total_steps,
        shares: pgr.edge_shares(),
        vertices: pgr.parts.iter().map(|p| p.nv).collect(),
        beta: pgr.beta_stats(),
        footprints,
        comm_slots,
    })
}

/// One lockstep superstep: all elements compute (timed separately, Eq. 2),
/// then all communication runs serialized.
#[allow(clippy::too_many_arguments)]
fn run_superstep_sync<A: Algorithm>(
    alg: &A,
    pg: &PartitionedGraph,
    states: &mut [AlgState],
    elements: &mut [Element],
    channels: &[CommOp],
    directions: &[Direction],
    cycle: usize,
    superstep: usize,
    instrument: bool,
    balance: Balance,
    metrics: &mut Metrics,
) -> Result<SuperstepOutcome> {
    let nparts = pg.parts.len();
    let mut step = StepMetrics::empty(nparts);
    let mut any_changed = false;

    // -- compute phase (elements run concurrently on real hardware; here
    //    each is timed separately and the metrics take the max — Eq. 2).
    for (pid, el) in elements.iter_mut().enumerate() {
        let part = &pg.parts[pid];
        match el {
            Element::Cpu { threads } => {
                let ctx = StepCtx {
                    cycle,
                    superstep,
                    threads: *threads,
                    instrument,
                    direction: directions[pid],
                    balance,
                };
                let (out, secs) = timed(|| alg.compute_cpu(part, &mut states[pid], &ctx));
                step.compute[pid] = secs;
                step.chunk_max[pid] = out.chunk_max_secs;
                step.chunk_min[pid] = out.chunk_min_secs;
                any_changed |= out.changed;
                metrics.mem[pid].reads += out.reads;
                metrics.mem[pid].writes += out.writes;
            }
            Element::HostWide { threads } => {
                // Wide-parallel host tier: the same derived kernels, but
                // always push-direction, edge-balanced, and uninstrumented
                // (it stands in for an accelerator, which records neither
                // direction decisions nor memory counters).
                let ctx = StepCtx {
                    cycle,
                    superstep,
                    threads: *threads,
                    instrument: false,
                    direction: Direction::Push,
                    balance: Balance::Edge,
                };
                let (out, secs) = timed(|| alg.compute_cpu(part, &mut states[pid], &ctx));
                step.compute[pid] = secs;
                step.chunk_max[pid] = out.chunk_max_secs;
                step.chunk_min[pid] = out.chunk_min_secs;
                any_changed |= out.changed;
            }
            Element::Accel(acc) => {
                let ctx = StepCtx {
                    cycle,
                    superstep,
                    threads: 1,
                    instrument: false,
                    direction: Direction::Push,
                    balance: Balance::Vertex,
                };
                let si32 = alg.scalars_i32(&ctx);
                let sf32 = alg.scalars_f32(&ctx);
                let out = acc.step(&mut states[pid], &si32, &sf32)?;
                // paper attribution: kernel execution = compute,
                // host<->device transfer = communication.
                step.compute[pid] = out.exec_secs;
                step.comm += out.upload_secs + out.readback_secs;
                step.bytes += out.transfer_bytes;
                metrics.accel_transfer_bytes[pid] += out.transfer_bytes;
                any_changed |= out.changed;
            }
        }
    }

    // -- communication phase ---------------------------------------
    let mut sw = Stopwatch::new();
    let (bytes, msgs) = sw.time(|| comm_phase(pg, states, channels, false));
    step.comm += sw.secs();
    step.bytes += bytes;
    step.messages += msgs;

    Ok(SuperstepOutcome { step, any_changed })
}

/// Build fresh accelerator bindings for every accelerator element against
/// a (possibly candidate) partitioning and cycle program — without
/// touching the live elements, so callers can abandon the batch if any
/// partition fails to map (used for BC's cycle switch, where failure is a
/// hard error, and for vertex migrations, where failure skips the
/// migration instead of aborting the run).
fn build_accel_rebinds<A: Algorithm>(
    alg: &A,
    cycle: usize,
    pg: &PartitionedGraph,
    states: &[AlgState],
    elements: &[Element],
    runtime: Option<&mut PjrtRuntime>,
    cfg: &EngineConfig,
) -> Result<Vec<(usize, AccelPartition)>> {
    let mut out = Vec::new();
    let Some(rt) = runtime else { return Ok(out) };
    let prog = alg.program(cycle);
    for (pid, el) in elements.iter().enumerate() {
        if matches!(el, Element::Accel(_)) {
            let acc = rt
                .rebind(&prog, &pg.parts[pid], &states[pid], cfg.accel_memory_budget)
                .with_context(|| format!("re-binding accelerator partition {pid}"))?;
            out.push((pid, acc));
        }
    }
    Ok(out)
}

/// Install bindings produced by [`build_accel_rebinds`].
fn commit_accel_rebinds(elements: &mut [Element], rebinds: Vec<(usize, AccelPartition)>) {
    for (pid, acc) in rebinds {
        if let Element::Accel(slot) = &mut elements[pid] {
            **slot = acc;
        }
    }
}

/// Table 5 footprint accounting over the current partitioning; accelerator
/// partitions report their device-side graph/state bytes.
fn footprints_of<A: Algorithm>(
    alg: &A,
    pg: &PartitionedGraph,
    states: &[AlgState],
    elements: &[Element],
) -> Vec<PartitionFootprint> {
    let msg_bytes: u64 = alg.channels(0).iter().map(|op| op.bytes_per_slot()).sum();
    let mut out = Vec::with_capacity(pg.parts.len());
    for (pid, part) in pg.parts.iter().enumerate() {
        let inbox: u64 = pg
            .parts
            .iter()
            .flat_map(|q| q.ghosts.iter())
            .filter(|t| t.remote_part == pid)
            .map(|t| (4 + msg_bytes) * t.len() as u64)
            .sum();
        let mut fp = PartitionFootprint {
            vertices: part.nv,
            edges: part.edge_count(),
            graph_bytes: part.graph_bytes(),
            inbox_bytes: inbox,
            outbox_bytes: part.comm_bytes(msg_bytes),
            state_bytes: states[pid].state_bytes(),
        };
        if let Element::Accel(acc) = &elements[pid] {
            // device-side footprint supersedes the host estimate
            fp.graph_bytes = acc.graph_bytes();
            fp.state_bytes = acc.state_bytes();
        }
        out.push(fp);
    }
    out
}

/// Exchange all communication ops between all partition pairs, in the
/// canonical order (op-major, then owner partition, then table). Returns
/// (bytes, messages) moved. `pull_only` is the cycle-initial sync: only
/// pull channels run, so pull algorithms see remote values before their
/// first compute.
pub(crate) fn comm_phase(
    pg: &PartitionedGraph,
    states: &mut [AlgState],
    ops: &[CommOp],
    pull_only: bool,
) -> (u64, u64) {
    let mut bytes = 0u64;
    let mut msgs = 0u64;
    for op in ops {
        for pid in 0..pg.parts.len() {
            for t in &pg.parts[pid].ghosts {
                let (owner, remote) = two_states(states, pid, t.remote_part);
                let (b, m) = comm_op_table(op, pull_only, t, owner, remote);
                bytes += b;
                msgs += m;
            }
        }
    }
    (bytes, msgs)
}

/// Split-borrow two distinct partitions' states. Zero-copy — the comm
/// phase's hot path (perf pass §Perf-L3-1: removed the per-table message
/// `Vec` allocations). The disjoint-split arithmetic lives in
/// [`crate::util::split_two_mut`], shared with the vertex-program driver.
fn two_states(states: &mut [AlgState], a: usize, b: usize) -> (&mut AlgState, &mut AlgState) {
    crate::util::split_two_mut(states, a, b)
}

/// Apply one communication op across one ghost table. `owner` is the
/// partition owning the table (the outbox side); `remote` is the
/// partition `t` points at. Both executors and the post-migration refresh
/// funnel through this one function, which is what keeps the pipelined
/// engine bit-identical to the synchronous one (DESIGN.md §4.2).
pub(crate) fn comm_op_table(
    op: &CommOp,
    pull_only: bool,
    t: &GhostTable,
    owner: &mut AlgState,
    remote: &mut AlgState,
) -> (u64, u64) {
    let n = t.len();
    if n == 0 {
        return (0, 0);
    }
    match *op {
        CommOp::Single(ch) => {
            if pull_only && ch.kind == ChannelKind::Push {
                return (0, 0);
            }
            match ch.kind {
                ChannelKind::Push => {
                    // outbox slice of owner → reduce into remote's real slots
                    match (&owner.arrays[ch.array], &mut remote.arrays[ch.array]) {
                        (StateArray::I32(v), StateArray::I32(dv)) => {
                            for (i, &m) in v[t.slot_base..t.slot_base + n].iter().enumerate() {
                                state::apply_i32(
                                    ch.reduce,
                                    &mut dv[t.remote_locals[i] as usize],
                                    m,
                                );
                            }
                        }
                        (StateArray::F32(v), StateArray::F32(dv)) => {
                            for (i, &m) in v[t.slot_base..t.slot_base + n].iter().enumerate() {
                                state::apply_f32(
                                    ch.reduce,
                                    &mut dv[t.remote_locals[i] as usize],
                                    m,
                                );
                            }
                        }
                        (StateArray::U64(v), StateArray::U64(dv)) => {
                            for (i, &m) in v[t.slot_base..t.slot_base + n].iter().enumerate() {
                                state::apply_u64(
                                    ch.reduce,
                                    &mut dv[t.remote_locals[i] as usize],
                                    m,
                                );
                            }
                        }
                        _ => unreachable!("channel dtype mismatch"),
                    }
                    if ch.reset_after_send {
                        match &mut owner.arrays[ch.array] {
                            StateArray::I32(v) => v[t.slot_base..t.slot_base + n]
                                .fill(ch.reduce.identity_i32()),
                            StateArray::F32(v) => v[t.slot_base..t.slot_base + n]
                                .fill(ch.reduce.identity_f32()),
                            StateArray::U64(v) => v[t.slot_base..t.slot_base + n]
                                .fill(ch.reduce.identity_u64()),
                        }
                    }
                }
                ChannelKind::Pull => {
                    // gather remote's real values → overwrite owner's ghost slots
                    match (&remote.arrays[ch.array], &mut owner.arrays[ch.array]) {
                        (StateArray::I32(v), StateArray::I32(dv)) => {
                            for (i, &l) in t.remote_locals.iter().enumerate() {
                                dv[t.slot_base + i] = v[l as usize];
                            }
                        }
                        (StateArray::F32(v), StateArray::F32(dv)) => {
                            for (i, &l) in t.remote_locals.iter().enumerate() {
                                dv[t.slot_base + i] = v[l as usize];
                            }
                        }
                        (StateArray::U64(v), StateArray::U64(dv)) => {
                            for (i, &l) in t.remote_locals.iter().enumerate() {
                                dv[t.slot_base + i] = v[l as usize];
                            }
                        }
                        _ => unreachable!("channel dtype mismatch"),
                    }
                }
            }
            let width: u64 = if ch.reduce.is_u64() { 8 } else { 4 };
            (width * n as u64, n as u64)
        }
        CommOp::DistSigma { dist, sigma } => {
            if pull_only {
                return (0, 0);
            }
            comm_dist_sigma_table(t, owner, remote, dist, sigma)
        }
    }
}

/// BC forward paired scatter for one table: a σ contribution is valid only
/// for the level it was generated at. `msg_dist < dist[w]` means w was
/// just discovered through this boundary → σ replaces (w had none); `==`
/// means another shortest path of the same length → σ adds; `>` means a
/// stale candidate (w is actually closer) → both are dropped.
fn comm_dist_sigma_table(
    t: &GhostTable,
    owner: &mut AlgState,
    remote: &mut AlgState,
    dist_idx: usize,
    sigma_idx: usize,
) -> (u64, u64) {
    let n = t.len();
    let dist_out: Vec<i32> = {
        let v = owner.arrays[dist_idx].as_i32();
        v[t.slot_base..t.slot_base + n].to_vec()
    };
    let sigma_out: Vec<f32> = {
        let v = owner.arrays[sigma_idx].as_f32();
        v[t.slot_base..t.slot_base + n].to_vec()
    };
    {
        // two disjoint arrays of the remote state
        let (dist_arr, sigma_arr) =
            crate::util::split_two_mut(&mut remote.arrays, dist_idx, sigma_idx);
        let dv = dist_arr.as_i32_mut();
        let sv = sigma_arr.as_f32_mut();
        for i in 0..n {
            let w = t.remote_locals[i] as usize;
            let (md, ms) = (dist_out[i], sigma_out[i]);
            if md < dv[w] {
                dv[w] = md;
                sv[w] = ms;
            } else if md == dv[w] && md != crate::alg::INF_I32 {
                sv[w] += ms;
            }
        }
    }
    // reset σ slots (add semantics); dist slots stay (min).
    let sv = owner.arrays[sigma_idx].as_f32_mut();
    sv[t.slot_base..t.slot_base + n].fill(0.0);
    (8 * n as u64, n as u64)
}

/// Gather the `idx`-th state array of every partition into a global array.
fn collect_output(pg: &PartitionedGraph, states: &[AlgState], idx: usize) -> StateArray {
    match &states.first().map(|s| &s.arrays[idx]) {
        Some(StateArray::I32(_)) => {
            let locals: Vec<Vec<i32>> = states
                .iter()
                .map(|s| s.arrays[idx].as_i32().to_vec())
                .collect();
            StateArray::I32(pg.collect_to_global(&locals))
        }
        Some(StateArray::F32(_)) => {
            let locals: Vec<Vec<f32>> = states
                .iter()
                .map(|s| s.arrays[idx].as_f32().to_vec())
                .collect();
            StateArray::F32(pg.collect_to_global(&locals))
        }
        Some(StateArray::U64(_)) => {
            let locals: Vec<Vec<u64>> = states
                .iter()
                .map(|s| s.arrays[idx].as_u64().to_vec())
                .collect();
            StateArray::U64(pg.collect_to_global(&locals))
        }
        None => StateArray::I32(Vec::new()),
    }
}
