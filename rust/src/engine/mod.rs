//! The BSP engine (paper §4): partition → per-superstep
//! compute / communicate / synchronize → terminate on quiescence.
//!
//! Each partition is executed by a processing element: the native Rust CPU
//! element, or the accelerator element (AOT JAX/Pallas programs via PJRT).
//! The communication phase exchanges ghost-slot values between partitions
//! with the algorithm's reduction operator — the paper's inbox/outbox
//! machinery with message aggregation (§4.3.2) — and is identical code for
//! every element pairing.

pub mod config;
pub mod metrics;
pub mod state;

pub use crate::alg::INF_I32;
pub use config::{ElementKind, EngineConfig};
pub use metrics::{MemCounters, Metrics, StepMetrics};
pub use state::{AlgState, Channel, ChannelKind, CommOp, Reduce, StateArray};

use crate::alg::{Algorithm, StepCtx};
use crate::graph::CsrGraph;
use crate::partition::{BetaStats, PartitionedGraph};
use crate::runtime::{AccelPartition, PjrtRuntime};
use crate::util::timer::{timed, Stopwatch};
use anyhow::{bail, Context, Result};
use std::time::Instant;

/// Result of a hybrid run.
pub struct RunResult {
    /// Global per-vertex result (e.g. BFS levels, PageRank ranks).
    pub output: StateArray,
    pub metrics: Metrics,
    pub supersteps: usize,
    /// Realized per-partition edge shares (α = shares[0]).
    pub shares: Vec<f64>,
    /// Per-partition vertex counts (Figure 13).
    pub vertices: Vec<usize>,
    /// Boundary-edge statistics (Figure 4).
    pub beta: BetaStats,
    /// Per-partition memory footprints (Table 5).
    pub footprints: Vec<PartitionFootprint>,
    /// Per-partition communicated slots per superstep (outbox + inbox
    /// ghost entries) — the model's per-partition |E_p^b| after reduction.
    pub comm_slots: Vec<u64>,
}

impl RunResult {
    pub fn makespan_secs(&self) -> f64 {
        self.metrics.makespan_secs()
    }
}

/// Memory footprint of one partition, in the paper's Table 5 categories.
#[derive(Debug, Clone, Default)]
pub struct PartitionFootprint {
    pub vertices: usize,
    pub edges: usize,
    /// Graph structure (CSR / COO + weights).
    pub graph_bytes: u64,
    /// Inbox: ghost slots other partitions keep *of our* vertices.
    pub inbox_bytes: u64,
    /// Outbox: our ghost slots for remote vertices.
    pub outbox_bytes: u64,
    /// Algorithm state arrays.
    pub state_bytes: u64,
}

impl PartitionFootprint {
    pub fn total(&self) -> u64 {
        self.graph_bytes + self.inbox_bytes + self.outbox_bytes + self.state_bytes
    }
}

enum Element {
    Cpu { threads: usize },
    Accel(Box<AccelPartition>),
}

/// Run `alg` on `g` under `cfg`. The graph is partitioned per the config,
/// each partition is bound to its element, and BSP cycles execute until
/// the algorithm quiesces (or its fixed round count elapses).
pub fn run<A: Algorithm>(g: &CsrGraph, alg: &mut A, cfg: &EngineConfig) -> Result<RunResult> {
    let spec = alg.spec();
    if spec.needs_weights && g.weights.is_none() {
        bail!("{} requires edge weights", spec.name);
    }

    // --- graph preparation (§4.2: the engine owns the data layout) -------
    let mut prepared: Option<CsrGraph> = None;
    if spec.undirected {
        prepared = Some(g.to_undirected());
    }
    if spec.reversed {
        let base = prepared.as_ref().unwrap_or(g);
        prepared = Some(base.reverse());
    }
    let pg_graph: &CsrGraph = prepared.as_ref().unwrap_or(g);
    alg.prepare(g, pg_graph);

    // --- partition --------------------------------------------------------
    let nparts = cfg.num_partitions();
    let pg = PartitionedGraph::partition(pg_graph, cfg.strategy, &cfg.shares, cfg.seed);

    // --- state + elements --------------------------------------------------
    let mut states: Vec<AlgState> = pg
        .parts
        .iter()
        .map(|p| alg.init_state(&pg, p))
        .collect();

    let mut runtime: Option<PjrtRuntime> = None;
    if cfg.has_accelerator() {
        runtime = Some(PjrtRuntime::new(&cfg.artifacts_dir)?);
    }

    let mut footprints: Vec<PartitionFootprint> = Vec::with_capacity(nparts);
    for (pid, part) in pg.parts.iter().enumerate() {
        let msg_bytes: u64 = alg.channels(0).iter().map(|op| op.bytes_per_slot()).sum();
        let inbox: u64 = pg
            .parts
            .iter()
            .flat_map(|q| q.ghosts.iter())
            .filter(|t| t.remote_part == pid)
            .map(|t| (4 + msg_bytes) * t.len() as u64)
            .sum();
        footprints.push(PartitionFootprint {
            vertices: part.nv,
            edges: part.edge_count(),
            graph_bytes: part.graph_bytes(),
            inbox_bytes: inbox,
            outbox_bytes: part.comm_bytes(msg_bytes),
            state_bytes: states[pid].state_bytes(),
        });
    }

    let mut elements: Vec<Element> = Vec::with_capacity(nparts);
    for (pid, kind) in cfg.elements.iter().enumerate() {
        match kind {
            ElementKind::Cpu { threads } => elements.push(Element::Cpu { threads: *threads }),
            ElementKind::Accelerator => {
                let rt = runtime.as_mut().expect("runtime initialized above");
                let prog = alg.program(0);
                let accel = rt
                    .instantiate(&prog, &pg.parts[pid], &states[pid], cfg.accel_memory_budget)
                    .with_context(|| {
                        format!(
                            "partition {pid} ({} vertices, {} edges) does not fit the accelerator",
                            pg.parts[pid].nv,
                            pg.parts[pid].edge_count()
                        )
                    })?;
                // device-side footprint supersedes the host estimate
                footprints[pid].graph_bytes = accel.graph_bytes();
                footprints[pid].state_bytes = accel.state_bytes();
                elements.push(Element::Accel(Box::new(accel)));
            }
        }
    }

    // --- BSP cycles --------------------------------------------------------
    let wall0 = Instant::now();
    let mut metrics = Metrics::new(nparts);
    let mut total_steps = 0usize;

    for cycle in 0..alg.cycles() {
        alg.begin_cycle(cycle, &pg, &mut states);
        let channels = alg.channels(cycle);

        // Re-bind accelerator partitions to this cycle's program.
        if cycle > 0 {
            let prog = alg.program(cycle);
            for (pid, el) in elements.iter_mut().enumerate() {
                if let Element::Accel(acc) = el {
                    let rt = runtime.as_mut().unwrap();
                    **acc = rt.instantiate(&prog, &pg.parts[pid], &states[pid], cfg.accel_memory_budget)?;
                }
            }
        }

        // Initial synchronization: pull channels must see remote values
        // before the first compute (PageRank contributions, BC ratios).
        {
            let mut sw = Stopwatch::new();
            let (bytes, msgs) = sw.time(|| comm_phase(&pg, &mut states, &channels, true));
            metrics.steps.push(StepMetrics {
                compute: vec![0.0; nparts],
                comm: sw.secs(),
                bytes,
                messages: msgs,
            });
        }

        let mut superstep = 0usize;
        loop {
            let mut step = StepMetrics {
                compute: vec![0.0; nparts],
                comm: 0.0,
                bytes: 0,
                messages: 0,
            };
            let mut any_changed = false;

            // -- compute phase (elements run concurrently on real hardware;
            //    we time each separately and take the max — Eq. 2).
            for (pid, el) in elements.iter_mut().enumerate() {
                let part = &pg.parts[pid];
                match el {
                    Element::Cpu { threads } => {
                        let ctx = StepCtx {
                            cycle,
                            superstep,
                            threads: *threads,
                            instrument: cfg.instrument,
                        };
                        let (out, secs) = timed(|| alg.compute_cpu(part, &mut states[pid], &ctx));
                        step.compute[pid] = secs;
                        any_changed |= out.changed;
                        metrics.mem[pid].reads += out.reads;
                        metrics.mem[pid].writes += out.writes;
                    }
                    Element::Accel(acc) => {
                        let ctx = StepCtx { cycle, superstep, threads: 1, instrument: false };
                        let si32 = alg.scalars_i32(&ctx);
                        let sf32 = alg.scalars_f32(&ctx);
                        let out = acc.step(&mut states[pid], &si32, &sf32)?;
                        // paper attribution: kernel execution = compute,
                        // host<->device transfer = communication.
                        step.compute[pid] = out.exec_secs;
                        step.comm += out.upload_secs + out.readback_secs;
                        step.bytes += out.transfer_bytes;
                        metrics.accel_transfer_bytes[pid] += out.transfer_bytes;
                        any_changed |= out.changed;
                    }
                }
            }

            // -- communication phase ---------------------------------------
            let mut sw = Stopwatch::new();
            let (bytes, msgs) = sw.time(|| comm_phase(&pg, &mut states, &channels, false));
            step.comm += sw.secs();
            step.bytes += bytes;
            step.messages += msgs;

            metrics.steps.push(step);
            superstep += 1;
            total_steps += 1;

            if alg.cycle_done(cycle, superstep, any_changed) {
                break;
            }
            if superstep >= cfg.max_supersteps {
                bail!(
                    "{}: exceeded max_supersteps={} in cycle {cycle}",
                    spec.name,
                    cfg.max_supersteps
                );
            }
        }
    }
    metrics.wall_secs = wall0.elapsed().as_secs_f64();

    // --- collect (paper: alg_collect via local→global maps) ----------------
    let out_idx = alg.output_array();
    let output = collect_output(&pg, &states, out_idx);

    let mut comm_slots = vec![0u64; nparts];
    for p in &pg.parts {
        for t in &p.ghosts {
            comm_slots[p.id] += t.len() as u64;
            comm_slots[t.remote_part] += t.len() as u64;
        }
    }

    Ok(RunResult {
        output,
        metrics,
        supersteps: total_steps,
        shares: pg.edge_shares(),
        vertices: pg.parts.iter().map(|p| p.nv).collect(),
        beta: pg.beta_stats(),
        footprints,
        comm_slots,
    })
}

/// Exchange all communication ops between all partition pairs. Returns
/// (bytes, messages) moved. `pull_only` is the cycle-initial sync: only
/// pull channels run, so pull algorithms see remote values before their
/// first compute.
fn comm_phase(
    pg: &PartitionedGraph,
    states: &mut [AlgState],
    ops: &[CommOp],
    pull_only: bool,
) -> (u64, u64) {
    let mut bytes = 0u64;
    let mut msgs = 0u64;
    for op in ops {
        match *op {
            CommOp::Single(ch) => {
                if pull_only && ch.kind == ChannelKind::Push {
                    continue;
                }
                let (b, m) = comm_single(pg, states, ch);
                bytes += b;
                msgs += m;
            }
            CommOp::DistSigma { dist, sigma } => {
                if pull_only {
                    continue;
                }
                let (b, m) = comm_dist_sigma(pg, states, dist, sigma);
                bytes += b;
                msgs += m;
            }
        }
    }
    (bytes, msgs)
}

/// Split-borrow two distinct partitions' states: `(read &states[a], write
/// &mut states[b])`. Zero-copy — the comm phase's hot path (perf pass
/// §Perf-L3-1: removed the per-table message `Vec` allocations).
fn two_states(states: &mut [AlgState], a: usize, b: usize) -> (&AlgState, &mut AlgState) {
    debug_assert_ne!(a, b);
    if a < b {
        let (x, y) = states.split_at_mut(b);
        (&x[a], &mut y[0])
    } else {
        let (x, y) = states.split_at_mut(a);
        (&y[0], &mut x[b])
    }
}

fn comm_single(pg: &PartitionedGraph, states: &mut [AlgState], ch: Channel) -> (u64, u64) {
    let mut bytes = 0u64;
    let mut msgs = 0u64;
    for pid in 0..pg.parts.len() {
        let p = &pg.parts[pid];
        for t in &p.ghosts {
            let n = t.len();
            if n == 0 {
                continue;
            }
            let q = t.remote_part;
            debug_assert_ne!(q, pid);
            match ch.kind {
                ChannelKind::Push => {
                    // outbox slice of p → reduce into q's real slots
                    let (src, dst) = two_states(states, pid, q);
                    match (&src.arrays[ch.array], &mut dst.arrays[ch.array]) {
                        (StateArray::I32(v), StateArray::I32(dv)) => {
                            for (i, &m) in v[t.slot_base..t.slot_base + n].iter().enumerate() {
                                state::apply_i32(
                                    ch.reduce,
                                    &mut dv[t.remote_locals[i] as usize],
                                    m,
                                );
                            }
                        }
                        (StateArray::F32(v), StateArray::F32(dv)) => {
                            for (i, &m) in v[t.slot_base..t.slot_base + n].iter().enumerate() {
                                state::apply_f32(
                                    ch.reduce,
                                    &mut dv[t.remote_locals[i] as usize],
                                    m,
                                );
                            }
                        }
                        _ => unreachable!("channel dtype mismatch"),
                    }
                    if ch.reset_after_send {
                        match &mut states[pid].arrays[ch.array] {
                            StateArray::I32(v) => v[t.slot_base..t.slot_base + n]
                                .fill(ch.reduce.identity_i32()),
                            StateArray::F32(v) => v[t.slot_base..t.slot_base + n]
                                .fill(ch.reduce.identity_f32()),
                        }
                    }
                }
                ChannelKind::Pull => {
                    // gather q's real values → overwrite p's ghost slots
                    let (src, dst) = two_states(states, q, pid);
                    match (&src.arrays[ch.array], &mut dst.arrays[ch.array]) {
                        (StateArray::I32(v), StateArray::I32(dv)) => {
                            for (i, &l) in t.remote_locals.iter().enumerate() {
                                dv[t.slot_base + i] = v[l as usize];
                            }
                        }
                        (StateArray::F32(v), StateArray::F32(dv)) => {
                            for (i, &l) in t.remote_locals.iter().enumerate() {
                                dv[t.slot_base + i] = v[l as usize];
                            }
                        }
                        _ => unreachable!("channel dtype mismatch"),
                    }
                }
            }
            bytes += 4 * n as u64;
            msgs += n as u64;
        }
    }
    (bytes, msgs)
}

/// BC forward paired scatter: a σ contribution is valid only for the level
/// it was generated at. `msg_dist < dist[w]` means w was just discovered
/// through this boundary → σ replaces (w had none); `==` means another
/// shortest path of the same length → σ adds; `>` means a stale candidate
/// (w is actually closer) → both are dropped.
fn comm_dist_sigma(
    pg: &PartitionedGraph,
    states: &mut [AlgState],
    dist_idx: usize,
    sigma_idx: usize,
) -> (u64, u64) {
    let mut bytes = 0u64;
    let mut msgs = 0u64;
    for pid in 0..pg.parts.len() {
        let p = &pg.parts[pid];
        for t in &p.ghosts {
            let n = t.len();
            if n == 0 {
                continue;
            }
            let q = t.remote_part;
            let dist_out: Vec<i32> = {
                let v = states[pid].arrays[dist_idx].as_i32();
                v[t.slot_base..t.slot_base + n].to_vec()
            };
            let sigma_out: Vec<f32> = {
                let v = states[pid].arrays[sigma_idx].as_f32();
                v[t.slot_base..t.slot_base + n].to_vec()
            };
            {
                let (dst_state, _) = {
                    // two disjoint arrays of the remote state
                    let st = &mut states[q];
                    let (a, b) = if dist_idx < sigma_idx {
                        let (x, y) = st.arrays.split_at_mut(sigma_idx);
                        (&mut x[dist_idx], &mut y[0])
                    } else {
                        let (x, y) = st.arrays.split_at_mut(dist_idx);
                        (&mut y[0], &mut x[sigma_idx])
                    };
                    ((a, b), ())
                };
                let (dist_arr, sigma_arr) = dst_state;
                let dv = dist_arr.as_i32_mut();
                let sv = sigma_arr.as_f32_mut();
                for i in 0..n {
                    let w = t.remote_locals[i] as usize;
                    let (md, ms) = (dist_out[i], sigma_out[i]);
                    if md < dv[w] {
                        dv[w] = md;
                        sv[w] = ms;
                    } else if md == dv[w] && md != crate::alg::INF_I32 {
                        sv[w] += ms;
                    }
                }
            }
            // reset σ slots (add semantics); dist slots stay (min).
            let sv = states[pid].arrays[sigma_idx].as_f32_mut();
            sv[t.slot_base..t.slot_base + n].fill(0.0);
            bytes += 8 * n as u64;
            msgs += n as u64;
        }
    }
    (bytes, msgs)
}

/// Gather the `idx`-th state array of every partition into a global array.
fn collect_output(pg: &PartitionedGraph, states: &[AlgState], idx: usize) -> StateArray {
    match &states.first().map(|s| &s.arrays[idx]) {
        Some(StateArray::I32(_)) => {
            let locals: Vec<Vec<i32>> = states
                .iter()
                .map(|s| s.arrays[idx].as_i32().to_vec())
                .collect();
            StateArray::I32(pg.collect_to_global(&locals))
        }
        Some(StateArray::F32(_)) => {
            let locals: Vec<Vec<f32>> = states
                .iter()
                .map(|s| s.arrays[idx].as_f32().to_vec())
                .collect();
            StateArray::F32(pg.collect_to_global(&locals))
        }
        None => StateArray::I32(Vec::new()),
    }
}
