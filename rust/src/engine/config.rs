//! Engine configuration — the paper's `totem_attr_t` (§4.2) plus the
//! hardware-configuration notation `xSyG` (§5: x CPU sockets, y GPUs).

use super::direction::DirectionConfig;
use crate::partition::{Placement, Strategy};
use crate::util::threadpool::{Balance, MAX_POOL_WORKERS};
use std::path::PathBuf;

/// Raw machine parallelism as detected, unclamped. The run banner compares
/// this against [`default_threads`] to surface worker-pool-cap clamping.
pub fn detected_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Detected machine parallelism clamped to the worker-pool cap
/// ([`MAX_POOL_WORKERS`]) — the default CPU-element thread count for
/// `host_auto`, `hybrid`, and the CLI (`totem run --threads N` overrides;
/// explicit values above the cap are rejected by
/// [`EngineConfig::validate`] instead of clamped).
pub fn default_threads() -> usize {
    detected_threads().min(MAX_POOL_WORKERS)
}

/// Typed engine-configuration errors, surfaced by
/// [`EngineConfig::validate`] before any state is built.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ConfigError {
    /// A CPU element requests more threads than the worker pool can hold:
    /// `ChunkPlan` would cut `requested` chunks against a pool silently
    /// capped at `cap` workers — quiet oversubscription. Explicit
    /// `--threads` values above the cap are rejected; auto-detected
    /// parallelism is clamped in [`default_threads`] instead.
    ThreadsExceedPoolCap { requested: usize, cap: usize },
}

impl std::fmt::Display for ConfigError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ConfigError::ThreadsExceedPoolCap { requested, cap } => write!(
                f,
                "--threads {requested} exceeds the worker-pool cap of {cap} \
                 (the pool would silently run {cap} workers against {requested} chunks); \
                 use --threads <= {cap}"
            ),
        }
    }
}

impl std::error::Error for ConfigError {}

/// What kind of processing element executes a partition.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ElementKind {
    /// Native Rust element with a bounded worker count. `threads` models
    /// the paper's socket count (1S/2S).
    Cpu { threads: usize },
    /// AOT-compiled JAX/Pallas programs executed through PJRT — the
    /// accelerator ("GPU") element.
    Accelerator,
}

/// How the engine schedules a BSP superstep (DESIGN.md §4).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ExecMode {
    /// The paper's lockstep loop: all partitions compute, then all
    /// communication, then the quiescence vote.
    #[default]
    Synchronous,
    /// Pipelined executor: partitions compute concurrently on their own
    /// threads, and each pairwise ghost exchange starts as soon as both
    /// endpoints finished computing — communication overlaps the compute
    /// of still-running partitions. Output is bit-identical to
    /// [`ExecMode::Synchronous`] (DESIGN.md §4.2).
    Pipelined,
}

/// Dynamic α re-balancing policy (DESIGN.md §5): watch per-element busy
/// time each superstep and migrate a band of boundary vertices from the
/// slowest to the fastest partition when imbalance persists.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RebalanceConfig {
    /// Trigger when `(max_p busy - min_p busy) / max_p busy` exceeds this
    /// (must be in `(0, 1]`; e.g. 0.3 = slowest element 30% busier).
    pub imbalance_threshold: f64,
    /// Consecutive over-threshold supersteps required before migrating
    /// (must be ≥ 1; absorbs per-step noise).
    pub patience: usize,
    /// Edge share of the donor partition moved per migration (must be in
    /// `(0, 1)`; the band is cut from the donor's lowest-degree tail —
    /// the same degree-ordered machinery as `partition::assign`).
    pub migration_band: f64,
    /// Hard cap on migrations per run (0 disables re-balancing).
    pub max_migrations: usize,
}

impl Default for RebalanceConfig {
    fn default() -> RebalanceConfig {
        RebalanceConfig {
            imbalance_threshold: 0.25,
            patience: 2,
            migration_band: 0.10,
            max_migrations: 8,
        }
    }
}

impl RebalanceConfig {
    /// Validate the knobs; the engine calls this before the first
    /// superstep so operator mistakes fail loudly, not mid-run.
    pub fn validate(&self, num_partitions: usize) -> Result<(), String> {
        if !(self.imbalance_threshold > 0.0 && self.imbalance_threshold <= 1.0) {
            return Err(format!(
                "rebalance: imbalance_threshold must be in (0, 1], got {}",
                self.imbalance_threshold
            ));
        }
        if self.patience == 0 {
            return Err("rebalance: patience must be >= 1".into());
        }
        if !(self.migration_band > 0.0 && self.migration_band < 1.0) {
            return Err(format!(
                "rebalance: migration_band must be in (0, 1), got {}",
                self.migration_band
            ));
        }
        if num_partitions < 2 {
            return Err(format!(
                "rebalance: needs >= 2 partitions to migrate between, got {num_partitions}"
            ));
        }
        Ok(())
    }
}

/// Engine attributes.
#[derive(Debug, Clone)]
pub struct EngineConfig {
    /// One element per partition; index = partition id. Partition 0 is the
    /// host/CPU by the paper's convention.
    pub elements: Vec<ElementKind>,
    /// Edge share per partition (α = shares[0]).
    pub shares: Vec<f64>,
    pub strategy: Strategy,
    /// Intra-partition vertex placement (DESIGN.md §9). Pure layout
    /// choice: global outputs are bit-identical across placements.
    pub placement: Placement,
    /// Seed for RAND partitioning and any tie-breaking.
    pub seed: u64,
    /// Safety bound on supersteps per BSP cycle.
    pub max_supersteps: usize,
    /// Fixed round count override (PageRank; paper uses 5 in Fig 16 and 1
    /// in Table 4).
    pub rounds: Option<usize>,
    /// Enable memory-access counters in the CPU kernels (Fig 12/17/22).
    pub instrument: bool,
    /// Where the AOT artifacts live (manifest.json + *.hlo.txt).
    pub artifacts_dir: PathBuf,
    /// Emulated accelerator memory capacity in bytes (paper: 6 GB Titans).
    /// A partition whose footprint exceeds this fails to map, reproducing
    /// the "minimum α" structure of Figures 7/9/15.
    pub accel_memory_budget: u64,
    /// Superstep scheduling: lockstep or pipelined (DESIGN.md §4).
    pub mode: ExecMode,
    /// Dynamic α re-balancing; `None` keeps launch-time shares fixed.
    pub rebalance: Option<RebalanceConfig>,
    /// Beamer-style direction optimization (DESIGN.md §8); `None` keeps
    /// every compute phase top-down (push). Only algorithms that declare
    /// `supports_pull` react; CPU partitions may switch to bottom-up
    /// sweeps per superstep, accelerator partitions always stay top-down.
    pub direction: Option<DirectionConfig>,
    /// Intra-partition load-balance mode for parallel kernels
    /// (DESIGN.md §11). Pure scheduling choice: global outputs are
    /// bit-identical across modes; eligibility per kernel family is
    /// decided centrally in `ProgramDriver`.
    pub balance: Balance,
}

impl EngineConfig {
    fn base() -> EngineConfig {
        EngineConfig {
            elements: vec![ElementKind::Cpu { threads: 1 }],
            shares: vec![1.0],
            strategy: Strategy::Rand,
            placement: Placement::default(),
            seed: 1,
            max_supersteps: 100_000,
            rounds: None,
            instrument: false,
            artifacts_dir: PathBuf::from("artifacts"),
            accel_memory_budget: 256 << 20, // 256 MB default "device"
            mode: ExecMode::Synchronous,
            rebalance: None,
            direction: None,
            balance: Balance::Vertex,
        }
    }

    /// Host-only (`xS`) configuration.
    pub fn host_only(threads: usize) -> EngineConfig {
        EngineConfig {
            elements: vec![ElementKind::Cpu { threads }],
            ..Self::base()
        }
    }

    /// Host-only configuration sized to the machine
    /// (`available_parallelism`) — the CLI default.
    pub fn host_auto() -> EngineConfig {
        Self::host_only(default_threads())
    }

    /// Hybrid `2SyG`-style configuration: one CPU partition holding an
    /// `alpha` share of the edges plus `accels` accelerator partitions
    /// splitting the rest evenly. The CPU element is sized to the machine;
    /// override with `from_notation` or by editing `elements[0]`.
    pub fn hybrid(accels: usize, alpha: f64, strategy: Strategy) -> EngineConfig {
        assert!(accels >= 1, "hybrid needs at least one accelerator");
        assert!((0.0..=1.0).contains(&alpha));
        let mut elements = vec![ElementKind::Cpu { threads: default_threads() }];
        let mut shares = vec![alpha];
        for _ in 0..accels {
            elements.push(ElementKind::Accelerator);
            shares.push((1.0 - alpha) / accels as f64);
        }
        EngineConfig { elements, shares, strategy, ..Self::base() }
    }

    /// Multi-partition CPU-only configuration — exercises the full BSP +
    /// communication machinery without PJRT (used heavily by tests).
    /// Deliberately `threads: 1` per element: test infrastructure defaults
    /// to the fully deterministic single-chunk path; tests that exercise
    /// intra-partition parallelism raise it explicitly.
    pub fn cpu_partitions(shares: &[f64], strategy: Strategy) -> EngineConfig {
        EngineConfig {
            elements: shares.iter().map(|_| ElementKind::Cpu { threads: 1 }).collect(),
            shares: shares.to_vec(),
            strategy,
            ..Self::base()
        }
    }

    /// Parse the paper's `xSyG` notation into a config: `x` sockets →
    /// CPU threads, `y` GPUs → accelerator partitions.
    pub fn from_notation(
        notation: &str,
        alpha: f64,
        strategy: Strategy,
        threads_per_socket: usize,
    ) -> Result<EngineConfig, String> {
        let s = notation.to_ascii_uppercase();
        let parts: Vec<&str> = s.split(['S', 'G']).collect();
        let (x, y) = match parts.as_slice() {
            [x, ""] => (x.parse::<usize>().map_err(|e| e.to_string())?, 0),
            [x, y, ""] => (
                x.parse::<usize>().map_err(|e| e.to_string())?,
                y.parse::<usize>().map_err(|e| e.to_string())?,
            ),
            _ => return Err(format!("bad hardware notation '{notation}' (e.g. 2S1G)")),
        };
        if x == 0 {
            return Err("need at least one CPU socket".into());
        }
        let threads = x * threads_per_socket;
        let mut cfg = if y == 0 {
            Self::host_only(threads)
        } else {
            let mut c = Self::hybrid(y, alpha, strategy);
            c.elements[0] = ElementKind::Cpu { threads };
            c
        };
        cfg.strategy = strategy;
        Ok(cfg)
    }

    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Select the intra-partition vertex placement (DESIGN.md §9).
    pub fn with_placement(mut self, placement: Placement) -> Self {
        self.placement = placement;
        self
    }

    pub fn with_rounds(mut self, rounds: usize) -> Self {
        self.rounds = Some(rounds);
        self
    }

    pub fn with_instrument(mut self, on: bool) -> Self {
        self.instrument = on;
        self
    }

    pub fn with_artifacts(mut self, dir: impl Into<PathBuf>) -> Self {
        self.artifacts_dir = dir.into();
        self
    }

    /// Switch to the pipelined executor (DESIGN.md §4.2).
    pub fn pipelined(mut self) -> Self {
        self.mode = ExecMode::Pipelined;
        self
    }

    pub fn with_mode(mut self, mode: ExecMode) -> Self {
        self.mode = mode;
        self
    }

    /// Enable dynamic α re-balancing with the given policy.
    pub fn with_rebalance(mut self, rb: RebalanceConfig) -> Self {
        self.rebalance = Some(rb);
        self
    }

    /// Enable direction optimization with the given α/β policy
    /// (DESIGN.md §8).
    pub fn with_direction(mut self, d: DirectionConfig) -> Self {
        self.direction = Some(d);
        self
    }

    /// Enable direction optimization with Beamer's published defaults.
    pub fn direction_optimized(self) -> Self {
        self.with_direction(DirectionConfig::default())
    }

    /// Select the intra-partition balance mode (DESIGN.md §11).
    pub fn with_balance(mut self, balance: Balance) -> Self {
        self.balance = balance;
        self
    }

    /// Set every CPU element's thread count (the `--threads` override).
    pub fn with_threads(mut self, threads: usize) -> Self {
        for el in &mut self.elements {
            if let ElementKind::Cpu { threads: t } = el {
                *t = threads;
            }
        }
        self
    }

    /// Validate element-level limits. `engine::run`/`run_shared` call this
    /// before any state is built; the CLI and harness surface the typed
    /// error directly.
    pub fn validate(&self) -> Result<(), ConfigError> {
        for el in &self.elements {
            if let ElementKind::Cpu { threads } = el {
                if *threads > MAX_POOL_WORKERS {
                    return Err(ConfigError::ThreadsExceedPoolCap {
                        requested: *threads,
                        cap: MAX_POOL_WORKERS,
                    });
                }
            }
        }
        Ok(())
    }

    pub fn num_partitions(&self) -> usize {
        self.elements.len()
    }

    pub fn has_accelerator(&self) -> bool {
        self.elements.iter().any(|e| *e == ElementKind::Accelerator)
    }

    /// Widest CPU element — the worker-pool size for this run and the
    /// `threads` figure reported by `harness::Measured`.
    pub fn max_cpu_threads(&self) -> usize {
        self.elements
            .iter()
            .map(|e| match e {
                ElementKind::Cpu { threads } => *threads,
                ElementKind::Accelerator => 0,
            })
            .max()
            .unwrap_or(1)
            .max(1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hybrid_shares_sum_to_one() {
        let c = EngineConfig::hybrid(2, 0.5, Strategy::High);
        assert_eq!(c.elements.len(), 3);
        assert!((c.shares.iter().sum::<f64>() - 1.0).abs() < 1e-12);
        assert_eq!(c.shares[1], 0.25);
        assert!(c.has_accelerator());
    }

    #[test]
    fn notation_parsing() {
        let c = EngineConfig::from_notation("2S", 0.7, Strategy::High, 8).unwrap();
        assert_eq!(c.elements, vec![ElementKind::Cpu { threads: 16 }]);

        let c = EngineConfig::from_notation("2S1G", 0.7, Strategy::High, 8).unwrap();
        assert_eq!(c.elements.len(), 2);
        assert_eq!(c.elements[0], ElementKind::Cpu { threads: 16 });
        assert_eq!(c.elements[1], ElementKind::Accelerator);
        assert!((c.shares[0] - 0.7).abs() < 1e-12);

        let c = EngineConfig::from_notation("1s2g", 0.6, Strategy::Low, 4).unwrap();
        assert_eq!(c.elements.len(), 3);
        assert_eq!(c.elements[0], ElementKind::Cpu { threads: 4 });

        assert!(EngineConfig::from_notation("0S1G", 0.5, Strategy::Rand, 4).is_err());
        assert!(EngineConfig::from_notation("XYZ", 0.5, Strategy::Rand, 4).is_err());
    }

    #[test]
    fn cpu_partitions_config() {
        let c = EngineConfig::cpu_partitions(&[0.6, 0.4], Strategy::Rand);
        assert_eq!(c.num_partitions(), 2);
        assert!(!c.has_accelerator());
    }

    #[test]
    fn placement_default_and_builder() {
        let c = EngineConfig::host_only(1);
        assert_eq!(c.placement, Placement::DegreeDesc, "historical layout");
        let c = c.with_placement(Placement::BfsOrder);
        assert_eq!(c.placement, Placement::BfsOrder);
    }

    #[test]
    fn mode_defaults_and_builders() {
        let c = EngineConfig::host_only(1);
        assert_eq!(c.mode, ExecMode::Synchronous);
        assert!(c.rebalance.is_none());
        assert!(c.direction.is_none(), "push-only by default");
        let c = c.pipelined().with_rebalance(RebalanceConfig::default());
        assert_eq!(c.mode, ExecMode::Pipelined);
        assert!(c.rebalance.is_some());
        let c = c.direction_optimized();
        assert_eq!(c.direction, Some(DirectionConfig::default()));
        let c = c.with_direction(DirectionConfig { alpha: 4.0, beta: 8.0 });
        assert_eq!(c.direction.unwrap().alpha, 4.0);
    }

    #[test]
    fn balance_and_threads_builders() {
        let c = EngineConfig::host_only(1);
        assert_eq!(c.balance, Balance::Vertex, "historical chunking is the default");
        let c = c.with_balance(Balance::HubSplit).with_threads(4);
        assert_eq!(c.balance, Balance::HubSplit);
        assert_eq!(c.elements, vec![ElementKind::Cpu { threads: 4 }]);
        assert_eq!(c.max_cpu_threads(), 4);

        let auto = EngineConfig::host_auto();
        assert!(auto.max_cpu_threads() >= 1);
        let h = EngineConfig::hybrid(1, 0.5, Strategy::High).with_threads(3);
        assert_eq!(h.elements[0], ElementKind::Cpu { threads: 3 });
        assert_eq!(h.elements[1], ElementKind::Accelerator, "accels untouched");
        assert_eq!(h.max_cpu_threads(), 3);
    }

    #[test]
    fn threads_above_pool_cap_are_a_typed_error() {
        assert!(EngineConfig::host_only(MAX_POOL_WORKERS).validate().is_ok());
        let err = EngineConfig::host_only(MAX_POOL_WORKERS + 1).validate().unwrap_err();
        assert_eq!(
            err,
            ConfigError::ThreadsExceedPoolCap {
                requested: MAX_POOL_WORKERS + 1,
                cap: MAX_POOL_WORKERS
            }
        );
        assert!(err.to_string().contains("worker-pool cap"));
        // auto-detection clamps instead of erroring
        assert!(default_threads() <= MAX_POOL_WORKERS);
        assert!(EngineConfig::host_auto().validate().is_ok());
    }

    #[test]
    fn rebalance_validation() {
        let ok = RebalanceConfig::default();
        assert!(ok.validate(2).is_ok());
        assert!(ok.validate(1).is_err());
        assert!(RebalanceConfig { imbalance_threshold: 0.0, ..ok }.validate(2).is_err());
        assert!(RebalanceConfig { imbalance_threshold: -1.0, ..ok }.validate(2).is_err());
        assert!(RebalanceConfig { imbalance_threshold: 1.5, ..ok }.validate(2).is_err());
        assert!(RebalanceConfig { patience: 0, ..ok }.validate(2).is_err());
        assert!(RebalanceConfig { migration_band: 0.0, ..ok }.validate(2).is_err());
        assert!(RebalanceConfig { migration_band: 1.0, ..ok }.validate(2).is_err());
    }
}
