//! Unified per-partition algorithm state (DESIGN.md §3).
//!
//! Every partition — CPU- or accelerator-resident — owns the same dense
//! state representation: a list of typed arrays of length
//! `Partition::state_len()` (real vertices, then ghost slots, then the
//! dummy sink). The engine's communication phase, the CPU kernels, and the
//! accelerator marshaling all operate on this one layout, which is what
//! makes the hybrid engine algorithm-agnostic.

/// Element type of a [`StateArray`]. `i32`/`f32` exist on both sides of
/// the PJRT boundary; `u64` (the bit-parallel MS-BFS lane words) is
/// host-only — the driver validates that u64 fields are never marked
/// `Role::Device`, so they never reach the accelerator marshaling layer.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FieldType {
    I32,
    F32,
    U64,
}

impl FieldType {
    pub fn name(&self) -> &'static str {
        match self {
            FieldType::I32 => "i32",
            FieldType::F32 => "f32",
            FieldType::U64 => "u64",
        }
    }
}

/// A dtype mismatch between what a caller expected of a [`StateArray`] and
/// what it holds. The vertex-program layer (`alg::program`) validates every
/// declared field/channel dtype at driver-construction time, so this error
/// surfaces through `anyhow` *before* any state is built — the panicking
/// `as_i32`/`as_f32` accessors are then provably unreachable in kernels.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TypeMismatch {
    pub expected: FieldType,
    pub actual: FieldType,
}

impl std::fmt::Display for TypeMismatch {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "state-array dtype mismatch: expected {}, found {}",
            self.expected.name(),
            self.actual.name()
        )
    }
}

impl std::error::Error for TypeMismatch {}

/// A single state array. `i32` and `f32` exist on both sides of the PJRT
/// boundary; `u64` is host-only (see [`FieldType`]).
#[derive(Debug, Clone)]
pub enum StateArray {
    I32(Vec<i32>),
    F32(Vec<f32>),
    U64(Vec<u64>),
}

impl StateArray {
    pub fn len(&self) -> usize {
        match self {
            StateArray::I32(v) => v.len(),
            StateArray::F32(v) => v.len(),
            StateArray::U64(v) => v.len(),
        }
    }
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
    pub fn field_type(&self) -> FieldType {
        match self {
            StateArray::I32(_) => FieldType::I32,
            StateArray::F32(_) => FieldType::F32,
            StateArray::U64(_) => FieldType::U64,
        }
    }
    /// Typed (non-panicking) accessor — see [`TypeMismatch`].
    pub fn try_as_i32(&self) -> Result<&[i32], TypeMismatch> {
        match self {
            StateArray::I32(v) => Ok(v),
            _ => Err(TypeMismatch { expected: FieldType::I32, actual: self.field_type() }),
        }
    }
    /// Typed (non-panicking) accessor — see [`TypeMismatch`].
    pub fn try_as_f32(&self) -> Result<&[f32], TypeMismatch> {
        match self {
            StateArray::F32(v) => Ok(v),
            _ => Err(TypeMismatch { expected: FieldType::F32, actual: self.field_type() }),
        }
    }
    pub fn as_i32(&self) -> &[i32] {
        match self {
            StateArray::I32(v) => v,
            _ => panic!("expected i32 array"),
        }
    }
    pub fn as_i32_mut(&mut self) -> &mut Vec<i32> {
        match self {
            StateArray::I32(v) => v,
            _ => panic!("expected i32 array"),
        }
    }
    pub fn as_f32(&self) -> &[f32] {
        match self {
            StateArray::F32(v) => v,
            _ => panic!("expected f32 array"),
        }
    }
    pub fn as_f32_mut(&mut self) -> &mut Vec<f32> {
        match self {
            StateArray::F32(v) => v,
            _ => panic!("expected f32 array"),
        }
    }
    pub fn as_u64(&self) -> &[u64] {
        match self {
            StateArray::U64(v) => v,
            _ => panic!("expected u64 array"),
        }
    }
    pub fn as_u64_mut(&mut self) -> &mut Vec<u64> {
        match self {
            StateArray::U64(v) => v,
            _ => panic!("expected u64 array"),
        }
    }
    pub fn bytes(&self) -> u64 {
        let elem = match self {
            StateArray::I32(_) | StateArray::F32(_) => 4,
            StateArray::U64(_) => 8,
        };
        elem * self.len() as u64
    }
}

/// Per-partition algorithm state.
#[derive(Debug, Clone)]
pub struct AlgState {
    /// Mutable arrays — communicated, computed on, and (for accelerator
    /// partitions) shipped across the PJRT boundary every superstep.
    pub arrays: Vec<StateArray>,
    /// Constant per-vertex arrays (e.g. PageRank's 1/outdeg), uploaded to
    /// the accelerator once alongside the edge arrays.
    pub aux: Vec<StateArray>,
    /// CPU-only scratch (e.g. the BFS visited bitmap, paper §5 / Fig 12).
    pub scratch: Vec<u64>,
}

impl AlgState {
    pub fn new(arrays: Vec<StateArray>) -> Self {
        AlgState { arrays, aux: Vec::new(), scratch: Vec::new() }
    }

    pub fn state_bytes(&self) -> u64 {
        self.arrays.iter().map(|a| a.bytes()).sum()
    }
}

/// Message reduction operator (paper §3.4: min for BFS/SSSP/CC, max for
/// widest-path's bottleneck relaxation, sum for PageRank-style rank
/// aggregation, set for pull channels).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Reduce {
    MinI32,
    MinF32,
    /// Max-reduce (widest path). Like `min`, idempotent and commutative
    /// even in f32, so never order-sensitive.
    MaxF32,
    AddF32,
    SetI32,
    SetF32,
    /// Bitwise-OR reduce over u64 lane words (multi-source BFS frontiers).
    /// Idempotent and commutative on exact integer bits, so never
    /// order-sensitive — pipelined deliveries stay bit-identical.
    OrU64,
}

impl Reduce {
    /// The identity element used to (re)initialize ghost slots.
    pub fn identity_i32(&self) -> i32 {
        match self {
            Reduce::MinI32 => super::INF_I32,
            Reduce::SetI32 => 0,
            _ => panic!("not an i32 reduce"),
        }
    }
    pub fn identity_f32(&self) -> f32 {
        match self {
            Reduce::MinF32 => f32::INFINITY,
            Reduce::MaxF32 => f32::NEG_INFINITY,
            Reduce::AddF32 => 0.0,
            Reduce::SetF32 => 0.0,
            _ => panic!("not an f32 reduce"),
        }
    }
    pub fn identity_u64(&self) -> u64 {
        match self {
            Reduce::OrU64 => 0,
            _ => panic!("not a u64 reduce"),
        }
    }
    pub fn is_f32(&self) -> bool {
        matches!(
            self,
            Reduce::MinF32 | Reduce::MaxF32 | Reduce::AddF32 | Reduce::SetF32
        )
    }
    pub fn is_u64(&self) -> bool {
        matches!(self, Reduce::OrU64)
    }
}

/// Communication direction of a channel.
///
/// `Push`: ghost slots accumulate updates for remote vertices during
/// compute; the comm phase sends slot values and reduces them into the
/// remote partition's real slots (BFS levels, SSSP distances, BC σ).
///
/// `Pull`: the comm phase gathers remote *real* values and overwrites the
/// local ghost slots (PageRank contributions, BC dependency ratios) before
/// the next compute.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ChannelKind {
    Push,
    Pull,
}

/// One communicated state array.
#[derive(Debug, Clone, Copy)]
pub struct Channel {
    /// Index into `AlgState::arrays`.
    pub array: usize,
    pub reduce: Reduce,
    pub kind: ChannelKind,
    /// Reset ghost slots to the reduce identity after sending. Required
    /// for `Add` channels (a re-send would double-count); unnecessary for
    /// idempotent `Min` channels.
    pub reset_after_send: bool,
}

impl Channel {
    pub fn push_min_i32(array: usize) -> Channel {
        Channel { array, reduce: Reduce::MinI32, kind: ChannelKind::Push, reset_after_send: false }
    }
    pub fn push_min_f32(array: usize) -> Channel {
        Channel { array, reduce: Reduce::MinF32, kind: ChannelKind::Push, reset_after_send: false }
    }
    pub fn push_max_f32(array: usize) -> Channel {
        Channel { array, reduce: Reduce::MaxF32, kind: ChannelKind::Push, reset_after_send: false }
    }
    pub fn push_add_f32(array: usize) -> Channel {
        Channel { array, reduce: Reduce::AddF32, kind: ChannelKind::Push, reset_after_send: true }
    }
    pub fn pull_f32(array: usize) -> Channel {
        Channel { array, reduce: Reduce::SetF32, kind: ChannelKind::Pull, reset_after_send: false }
    }
    pub fn pull_i32(array: usize) -> Channel {
        Channel { array, reduce: Reduce::SetI32, kind: ChannelKind::Pull, reset_after_send: false }
    }
    /// OR is idempotent, so stale re-delivery would be *correct* — but
    /// resetting after send keeps each superstep's traffic to fresh bits
    /// only (a hub's ghost word would otherwise re-ship every superstep
    /// until quiescence).
    pub fn push_or_u64(array: usize) -> Channel {
        Channel { array, reduce: Reduce::OrU64, kind: ChannelKind::Push, reset_after_send: true }
    }
}

/// A communication-phase operation. Most algorithms use independent
/// [`Channel`]s; Betweenness Centrality's forward sweep needs the paired
/// distance + σ scatter (a σ contribution may only be applied when the
/// accompanying BFS level agrees with the receiver's — otherwise a stale
/// candidate level would corrupt shortest-path counts).
#[derive(Debug, Clone, Copy)]
pub enum CommOp {
    Single(Channel),
    /// BC forward: `dist` is an i32 min-channel, `sigma` an f32 add-channel
    /// applied only where the delivered distance equals (or improves) the
    /// receiver's. Sigma ghost slots are reset after sending.
    DistSigma { dist: usize, sigma: usize },
}

impl CommOp {
    /// Bytes per ghost slot this op moves.
    pub fn bytes_per_slot(&self) -> u64 {
        match self {
            CommOp::Single(ch) if ch.reduce.is_u64() => 8,
            CommOp::Single(_) => 4,
            CommOp::DistSigma { .. } => 8,
        }
    }

    /// Whether the bitwise result depends on the order in which different
    /// senders' deliveries reach a receiver cell. `min` is idempotent and
    /// commutative even in f32; pull/`set` slots have exactly one writer;
    /// but f32 *additions* from multiple senders (push-add channels and
    /// the BC dist+σ pair) only reproduce the synchronous engine bit-for-
    /// bit when applied in the same sender order. The pipelined executor
    /// serializes deliveries of such ops per receiver (DESIGN.md §4.2).
    pub fn order_sensitive(&self) -> bool {
        match self {
            CommOp::Single(ch) => ch.reduce == Reduce::AddF32 && ch.kind == ChannelKind::Push,
            CommOp::DistSigma { .. } => true,
        }
    }
}

/// Apply `reduce(dst, msg)` to one i32 cell; returns true if it changed.
#[inline]
pub fn apply_i32(reduce: Reduce, dst: &mut i32, msg: i32) -> bool {
    match reduce {
        Reduce::MinI32 => {
            if msg < *dst {
                *dst = msg;
                true
            } else {
                false
            }
        }
        Reduce::SetI32 => {
            let ch = *dst != msg;
            *dst = msg;
            ch
        }
        _ => panic!("i32 apply with f32 reduce"),
    }
}

/// Apply `reduce(dst, msg)` to one u64 cell; returns true if it changed.
#[inline]
pub fn apply_u64(reduce: Reduce, dst: &mut u64, msg: u64) -> bool {
    match reduce {
        Reduce::OrU64 => {
            let new = msg & !*dst;
            *dst |= msg;
            new != 0
        }
        _ => panic!("u64 apply with non-u64 reduce"),
    }
}

/// Apply `reduce(dst, msg)` to one f32 cell; returns true if it changed.
#[inline]
pub fn apply_f32(reduce: Reduce, dst: &mut f32, msg: f32) -> bool {
    match reduce {
        Reduce::MinF32 => {
            if msg < *dst {
                *dst = msg;
                true
            } else {
                false
            }
        }
        Reduce::MaxF32 => {
            if msg > *dst {
                *dst = msg;
                true
            } else {
                false
            }
        }
        Reduce::AddF32 => {
            if msg != 0.0 {
                *dst += msg;
                true
            } else {
                false
            }
        }
        Reduce::SetF32 => {
            let ch = *dst != msg;
            *dst = msg;
            ch
        }
        _ => panic!("f32 apply with i32 reduce"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reduce_apply_semantics() {
        let mut x = 10i32;
        assert!(apply_i32(Reduce::MinI32, &mut x, 3));
        assert_eq!(x, 3);
        assert!(!apply_i32(Reduce::MinI32, &mut x, 5));
        assert_eq!(x, 3);

        let mut y = 1.5f32;
        assert!(apply_f32(Reduce::AddF32, &mut y, 0.5));
        assert_eq!(y, 2.0);
        assert!(!apply_f32(Reduce::AddF32, &mut y, 0.0));

        let mut z = 0.0f32;
        assert!(apply_f32(Reduce::SetF32, &mut z, 4.0));
        assert!(!apply_f32(Reduce::SetF32, &mut z, 4.0));
    }

    #[test]
    fn identities() {
        assert_eq!(Reduce::MinI32.identity_i32(), super::super::INF_I32);
        assert_eq!(Reduce::AddF32.identity_f32(), 0.0);
        assert_eq!(Reduce::MinF32.identity_f32(), f32::INFINITY);
        assert_eq!(Reduce::MaxF32.identity_f32(), f32::NEG_INFINITY);
        assert!(Reduce::MaxF32.is_f32());
    }

    #[test]
    fn max_reduce_apply_semantics() {
        let mut x = f32::NEG_INFINITY;
        assert!(apply_f32(Reduce::MaxF32, &mut x, 2.0));
        assert_eq!(x, 2.0);
        assert!(!apply_f32(Reduce::MaxF32, &mut x, 1.0));
        assert_eq!(x, 2.0);
        assert!(apply_f32(Reduce::MaxF32, &mut x, f32::INFINITY));
        assert_eq!(x, f32::INFINITY);
    }

    #[test]
    fn typed_accessors_report_mismatch() {
        let a = StateArray::I32(vec![1]);
        assert_eq!(a.field_type(), FieldType::I32);
        assert!(a.try_as_i32().is_ok());
        let err = a.try_as_f32().unwrap_err();
        assert_eq!(err.expected, FieldType::F32);
        assert_eq!(err.actual, FieldType::I32);
        assert!(err.to_string().contains("expected f32"));
        let b = StateArray::F32(vec![0.5]);
        assert!(b.try_as_f32().is_ok());
        assert!(b.try_as_i32().is_err());
    }

    #[test]
    fn array_accessors() {
        let mut a = StateArray::I32(vec![1, 2, 3]);
        a.as_i32_mut()[0] = 9;
        assert_eq!(a.as_i32(), &[9, 2, 3]);
        assert_eq!(a.bytes(), 12);
        let s = AlgState::new(vec![a, StateArray::F32(vec![0.0; 5])]);
        assert_eq!(s.state_bytes(), 12 + 20);
    }

    #[test]
    #[should_panic(expected = "expected f32")]
    fn wrong_type_panics() {
        StateArray::I32(vec![1]).as_f32();
    }

    #[test]
    fn order_sensitivity_classification() {
        assert!(!CommOp::Single(Channel::push_min_i32(0)).order_sensitive());
        assert!(!CommOp::Single(Channel::push_min_f32(0)).order_sensitive());
        assert!(!CommOp::Single(Channel::push_max_f32(0)).order_sensitive());
        assert!(!CommOp::Single(Channel::pull_f32(0)).order_sensitive());
        assert!(!CommOp::Single(Channel::pull_i32(0)).order_sensitive());
        assert!(CommOp::Single(Channel::push_add_f32(0)).order_sensitive());
        assert!(CommOp::DistSigma { dist: 0, sigma: 1 }.order_sensitive());
        // OR over integer bits is exact/commutative/idempotent: the MS-BFS
        // channel must pipeline freely.
        assert!(!CommOp::Single(Channel::push_or_u64(0)).order_sensitive());
    }

    #[test]
    fn or_u64_reduce_semantics() {
        assert_eq!(Reduce::OrU64.identity_u64(), 0);
        assert!(Reduce::OrU64.is_u64());
        assert!(!Reduce::OrU64.is_f32());
        let mut w = 0b0011u64;
        assert!(apply_u64(Reduce::OrU64, &mut w, 0b0110));
        assert_eq!(w, 0b0111);
        // already-subsumed message: no change reported
        assert!(!apply_u64(Reduce::OrU64, &mut w, 0b0101));
        assert_eq!(w, 0b0111);
        let ch = Channel::push_or_u64(3);
        assert_eq!(ch.array, 3);
        assert!(ch.reset_after_send, "fresh-bits-only traffic contract");
        assert_eq!(CommOp::Single(ch).bytes_per_slot(), 8);
    }

    #[test]
    fn u64_array_accessors() {
        let mut a = StateArray::U64(vec![1, 2]);
        a.as_u64_mut()[1] = 0xff;
        assert_eq!(a.as_u64(), &[1, 0xff]);
        assert_eq!(a.bytes(), 16, "u64 arrays are 8 bytes/element");
        assert_eq!(a.field_type(), FieldType::U64);
        assert_eq!(FieldType::U64.name(), "u64");
        assert!(a.try_as_i32().is_err());
        assert!(a.try_as_f32().is_err());
    }
}
