//! Pipelined superstep executor (DESIGN.md §4.2): overlap communication
//! with computation inside a BSP superstep.
//!
//! The synchronous executor serializes `compute(all) → communicate(all)`.
//! This executor splits the superstep into per-partition tasks:
//!
//! - every CPU partition computes on its own scoped thread;
//! - accelerator partitions (and `HostWide` fallback partitions, which
//!   fan out across the whole machine themselves) step on the coordinator
//!   thread while the CPU threads run;
//! - the coordinator drains compute completions and, as soon as **both**
//!   endpoints of a ghost-table exchange have finished computing, runs
//!   that exchange — while other partitions are still computing.
//!
//! Communication executed before the last compute completion is *hidden*
//! behind computation; [`StepMetrics::comm_overlapped`] records it and
//! `Metrics::makespan_secs` subtracts it from the critical path.
//!
//! ## Bit-identical outputs
//!
//! The exchange itself is the same [`comm_op_table`] code the synchronous
//! engine runs; what could differ is only *ordering*. Three cases:
//!
//! 1. `min`/`max` reductions are commutative and idempotent (also in f32,
//!    since no NaNs occur) — any delivery order yields the same bits.
//! 2. pull (`set`) ghost slots have exactly one writer each — order-free.
//! 3. f32 *additive* deliveries (push-add channels, the BC dist+σ pair)
//!    are order-sensitive ([`CommOp::order_sensitive`]), as are op lists
//!    sharing a state array. For those the scheduler falls back to strict
//!    canonical order (op, then owner partition, then table index — the
//!    synchronous engine's exact order), releasing each exchange only
//!    when every earlier exchange has run. Overlap still happens whenever
//!    the canonical prefix is ready early.
//!
//! Double buffering: each partition's inbox writes land in its state
//! arrays only after its own compute finished (readiness condition), so a
//! partition's superstep-`s` kernel never races its superstep-`s` inbox —
//! the sealed-inbox invariant that makes the overlap safe.
//!
//! Each partition still gets one fresh scoped *task* thread per superstep
//! (scoped threads keep the borrow story trivially sound, and the
//! coordinator needs per-partition completion events anyway), but the
//! kernels inside those tasks no longer spawn: chunk work is dispatched
//! to the persistent parked worker pool (`util::threadpool`, DESIGN.md
//! §11), created once per engine run. Hoisting the exchange plan — which
//! must currently be re-derived because a migration can reshape `pg`
//! between supersteps — remains deliberate future work.

use super::direction::Direction;
use super::state::{AlgState, CommOp};
use super::{comm_op_table, Element, Metrics, StepMetrics, SuperstepOutcome};
use crate::alg::{Algorithm, ComputeOut, StepCtx};
use crate::partition::PartitionedGraph;
use crate::util::threadpool::Balance;
use crate::util::timer::timed;
use anyhow::{anyhow, Result};
use std::sync::mpsc;
use std::time::Instant;

/// One scheduled exchange: communication op `op` applied over ghost table
/// `ti` of partition `p`, pointing at partition `q`. Ready once `p` and
/// `q` both finished computing. Units are ordered op-major, then owner,
/// then table — the synchronous engine's exact order — so strict-mode
/// release reproduces it verbatim.
struct Unit {
    op: usize,
    p: usize,
    ti: usize,
    q: usize,
    ran: bool,
}

/// Conservative strictness: fall back to canonical-order release when any
/// op is order-sensitive, or when two ops touch the same state array (in
/// that case even op-insensitive reductions could observe each other's
/// intermediate values in a schedule-dependent way).
fn needs_strict_order(ops: &[CommOp]) -> bool {
    if ops.iter().any(|op| op.order_sensitive()) {
        return true;
    }
    let mut seen: Vec<usize> = Vec::new();
    for op in ops {
        let mut arrs = [0usize; 2];
        let k = match *op {
            CommOp::Single(ch) => {
                arrs[0] = ch.array;
                1
            }
            CommOp::DistSigma { dist, sigma } => {
                arrs[0] = dist;
                arrs[1] = sigma;
                2
            }
        };
        for &a in &arrs[..k] {
            if seen.contains(&a) {
                return true;
            }
            seen.push(a);
        }
    }
    false
}

/// Execute one pipelined superstep. Semantics (outputs, `any_changed`)
/// are identical to `run_superstep_sync`; only the schedule differs.
#[allow(clippy::too_many_arguments)]
pub(crate) fn run_superstep<A: Algorithm>(
    alg: &A,
    pg: &PartitionedGraph,
    states: &mut Vec<AlgState>,
    elements: &mut [Element],
    ops: &[CommOp],
    directions: &[Direction],
    cycle: usize,
    superstep: usize,
    instrument: bool,
    balance: Balance,
    metrics: &mut Metrics,
) -> Result<SuperstepOutcome> {
    let nparts = pg.parts.len();
    let mut step = StepMetrics::empty(nparts);
    let mut any_changed = false;

    // Plan the exchanges in canonical (op, owner, table) order.
    let mut units: Vec<Unit> = Vec::new();
    for (op, _) in ops.iter().enumerate() {
        for (p, part) in pg.parts.iter().enumerate() {
            for (ti, t) in part.ghosts.iter().enumerate() {
                if !t.is_empty() {
                    units.push(Unit { op, p, ti, q: t.remote_part, ran: false });
                }
            }
        }
    }
    let strict = needs_strict_order(ops);

    // Each partition's state is moved into its compute task and moved back
    // on completion; `done[p]` marks both "compute finished" and "state
    // returned" (the inbox is sealed until then).
    let mut slots: Vec<Option<AlgState>> = states.drain(..).map(Some).collect();
    let mut done = vec![false; nparts];

    let (tx, rx) = mpsc::channel::<(usize, AlgState, ComputeOut, f64)>();
    let mut live = 0usize;

    std::thread::scope(|scope| -> Result<()> {
        // -- spawn CPU compute tasks ---------------------------------------
        for (pid, el) in elements.iter_mut().enumerate() {
            if let Element::Cpu { threads } = el {
                let threads = *threads;
                let direction = directions[pid];
                let mut st = slots[pid].take().expect("state present at superstep start");
                let tx = tx.clone();
                let part = &pg.parts[pid];
                live += 1;
                scope.spawn(move || {
                    let ctx =
                        StepCtx { cycle, superstep, threads, instrument, direction, balance };
                    let (out, secs) = timed(|| alg.compute_cpu(part, &mut st, &ctx));
                    // Receiver dropping early (accelerator error) is fine.
                    let _ = tx.send((pid, st, out, secs));
                });
            }
        }
        drop(tx);

        // -- accelerator + host-wide steps on the coordinator, overlapping
        //    the CPUs (a HostWide element spreads across the whole machine
        //    via the shared worker pool, so it gets no scoped thread of its
        //    own — it IS the wide element).
        for pid in 0..elements.len() {
            if let Element::HostWide { threads } = &elements[pid] {
                let ctx = StepCtx {
                    cycle,
                    superstep,
                    threads: *threads,
                    instrument: false,
                    direction: Direction::Push,
                    balance: Balance::Edge,
                };
                let st = slots[pid].as_mut().expect("host-wide state is never moved");
                let (out, secs) = timed(|| alg.compute_cpu(&pg.parts[pid], st, &ctx));
                step.compute[pid] = secs;
                step.chunk_max[pid] = out.chunk_max_secs;
                step.chunk_min[pid] = out.chunk_min_secs;
                any_changed |= out.changed;
                done[pid] = true;
                run_ready_units(
                    &mut units, strict, &done, &mut slots, pg, ops, &mut step, live > 0,
                );
                continue;
            }
            if !matches!(elements[pid], Element::Accel(_)) {
                continue;
            }
            let ctx = StepCtx {
                cycle,
                superstep,
                threads: 1,
                instrument: false,
                direction: Direction::Push,
                balance: Balance::Vertex,
            };
            let si32 = alg.scalars_i32(&ctx);
            let sf32 = alg.scalars_f32(&ctx);
            if let Element::Accel(acc) = &mut elements[pid] {
                let st = slots[pid].as_mut().expect("accelerator state is never moved");
                let out = acc.step(st, &si32, &sf32)?;
                step.compute[pid] = out.exec_secs;
                let transfer = out.upload_secs + out.readback_secs;
                step.comm += transfer;
                if live > 0 {
                    // host↔device transfer runs while CPU elements compute
                    // — the paper's PCIe-hiding overlap.
                    step.comm_overlapped += transfer;
                }
                step.bytes += out.transfer_bytes;
                metrics.accel_transfer_bytes[pid] += out.transfer_bytes;
                any_changed |= out.changed;
                done[pid] = true;
                run_ready_units(
                    &mut units, strict, &done, &mut slots, pg, ops, &mut step, live > 0,
                );
            }
        }

        // -- drain completions; exchanges fire as endpoints finish ----------
        let mut remaining = live;
        while remaining > 0 {
            let (pid, st, out, secs) = rx
                .recv()
                .map_err(|_| anyhow!("pipelined compute worker disappeared"))?;
            slots[pid] = Some(st);
            step.compute[pid] = secs;
            step.chunk_max[pid] = out.chunk_max_secs;
            step.chunk_min[pid] = out.chunk_min_secs;
            any_changed |= out.changed;
            metrics.mem[pid].reads += out.reads;
            metrics.mem[pid].writes += out.writes;
            done[pid] = true;
            remaining -= 1;
            run_ready_units(
                &mut units, strict, &done, &mut slots, pg, ops, &mut step, remaining > 0,
            );
        }
        Ok(())
    })?;

    // Everything is done; sweep any exchange still pending (possible only
    // if the loop above never ran, e.g. an all-accelerator configuration).
    run_ready_units(&mut units, strict, &done, &mut slots, pg, ops, &mut step, false);
    debug_assert!(units.iter().all(|u| u.ran));

    // Move the states back into the engine's dense vector.
    states.extend(slots.into_iter().map(|s| s.expect("all states returned")));

    Ok(SuperstepOutcome { step, any_changed })
}

/// Run every not-yet-run exchange whose endpoints both finished computing.
/// In `strict` mode (order-sensitive f32 additions present) exchanges are
/// released only in canonical order. `overlapping` marks the executed
/// seconds as hidden behind still-running compute.
#[allow(clippy::too_many_arguments)]
fn run_ready_units(
    units: &mut [Unit],
    strict: bool,
    done: &[bool],
    slots: &mut [Option<AlgState>],
    pg: &PartitionedGraph,
    ops: &[CommOp],
    step: &mut StepMetrics,
    overlapping: bool,
) {
    for i in 0..units.len() {
        if units[i].ran {
            continue;
        }
        let (p, q, ti, op) = (units[i].p, units[i].q, units[i].ti, units[i].op);
        if !(done[p] && done[q]) {
            if strict {
                // canonical-order barrier: nothing later may jump the queue
                break;
            }
            continue;
        }
        let t = &pg.parts[p].ghosts[ti];
        let (owner, remote) = two_slots(slots, p, q);
        let t0 = Instant::now();
        let (bytes, msgs) = comm_op_table(&ops[op], false, t, owner, remote);
        let secs = t0.elapsed().as_secs_f64();
        step.comm += secs;
        if overlapping {
            step.comm_overlapped += secs;
        }
        step.bytes += bytes;
        step.messages += msgs;
        units[i].ran = true;
    }
}

/// Split-borrow two distinct partitions' returned states.
fn two_slots(
    slots: &mut [Option<AlgState>],
    a: usize,
    b: usize,
) -> (&mut AlgState, &mut AlgState) {
    debug_assert_ne!(a, b);
    if a < b {
        let (x, y) = slots.split_at_mut(b);
        (
            x[a].as_mut().expect("owner state returned"),
            y[0].as_mut().expect("remote state returned"),
        )
    } else {
        let (x, y) = slots.split_at_mut(a);
        (
            y[0].as_mut().expect("owner state returned"),
            x[b].as_mut().expect("remote state returned"),
        )
    }
}
