//! The typed vertex-program layer — TOTEM's declarative programming
//! surface (paper §4.2, Fig. 5; DESIGN.md §10).
//!
//! An algorithm is written once as a [`VertexProgram`]: a **typed state
//! schema** (named fields with dtype, pad/identity value, and role), a
//! per-cycle **plan** (which fields are communicated and with which
//! reduction, and which generic **kernel family** drives the superstep),
//! and a handful of small typed callbacks (`edge_update`, `gather_apply`,
//! …). The generic [`ProgramDriver`] then implements the engine-facing
//! [`Algorithm`] trait *once* for every program:
//!
//! - it builds per-partition [`AlgState`] from the schema (locals
//!   initialized by [`VertexProgram::init_vertex`], ghost/dummy slots at
//!   the field's pad value — which the driver validates to be the reduce
//!   identity of the field's channel);
//! - it derives the **push kernel**, and for traversal programs the
//!   transpose **pull kernel** with early exit, from the declared kernel
//!   family — including the visited-bitmap claim protocol, canonical-order
//!   iteration whenever the cycle's communication is order-sensitive
//!   (DESIGN.md §9), and instrumentation read/write counting;
//! - it marshals the [`ProgramSpec`] for the accelerator element, the
//!   engine [`CommOp`] list, `frontier_stats` for the α/β direction
//!   policy, and `rebuild_scratch` after α-controller migrations —
//!   so both executors, the re-balancer, and the harness run unmodified.
//!
//! Schema/plan mistakes (dtype mismatches, aux fields on channels, pads
//! that are not reduce identities) are **typed errors at construction
//! time** ([`ProgramDriver::build`]), not panics deep inside a kernel.
//!
//! See `alg/widest.rs` for the canonical "add an algorithm in well under
//! 100 lines" example, and DESIGN.md §10 for the walkthrough.

use super::{AlgSpec, Algorithm, ComputeOut, EdgeOrientation, Pad, ProgramSpec, StepCtx, INF_I32};
use crate::engine::direction::{Direction, FrontierStats};
use crate::engine::state::{AlgState, Channel, CommOp, FieldType, StateArray};
use crate::graph::CsrGraph;
use crate::partition::{Partition, PartitionedGraph};
use crate::util::atomic::{
    as_atomic_f32_cells, as_atomic_i32_cells, as_atomic_u64_cells, atomic_add_f32, atomic_max_f32,
    atomic_min_f32,
};
use crate::util::split_two_mut;
use crate::util::threadpool::{
    parallel_reduce, parallel_reduce_plan, Balance, Chunk, ChunkPlan,
};
use anyhow::{bail, Result};
use std::sync::atomic::{AtomicI32, AtomicU32, AtomicU64, Ordering};

/// Handle to a schema field: its position in [`VertexProgram::schema`].
/// Programs define these as `const` alongside the schema.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FieldId(pub usize);

/// A typed scalar — the value vocabulary of the schema layer.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Value {
    I32(i32),
    F32(f32),
    /// Bit-lane word (multi-source BFS frontiers). Host-only: u64 fields
    /// never cross the PJRT boundary, so [`Role::Device`] u64 fields are a
    /// construction-time error.
    U64(u64),
}

impl Value {
    pub fn field_type(&self) -> FieldType {
        match self {
            Value::I32(_) => FieldType::I32,
            Value::F32(_) => FieldType::F32,
            Value::U64(_) => FieldType::U64,
        }
    }
    /// Extract the i32 payload. Only called by driver kernels after the
    /// schema validated the field dtype, so a mismatch is a program bug.
    pub fn expect_i32(self) -> i32 {
        match self {
            Value::I32(x) => x,
            Value::F32(x) => panic!("expected i32 update, program produced f32 {x}"),
            Value::U64(x) => panic!("expected i32 update, program produced u64 {x}"),
        }
    }
    pub fn expect_f32(self) -> f32 {
        match self {
            Value::F32(x) => x,
            Value::I32(x) => panic!("expected f32 update, program produced i32 {x}"),
            Value::U64(x) => panic!("expected f32 update, program produced u64 {x}"),
        }
    }
    fn to_pad(self) -> Pad {
        match self {
            Value::I32(x) => Pad::I32(x),
            Value::F32(x) => Pad::F32(x),
            Value::U64(x) => Pad::U64(x),
        }
    }
}

/// Where a schema field lives and who sees it.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Role {
    /// Mutable per-vertex state, marshaled across the PJRT boundary every
    /// superstep (unless the cycle's [`CyclePlan::device`] narrows the
    /// set). Stored in [`AlgState::arrays`].
    Device,
    /// Mutable per-vertex state the accelerator never sees — activation
    /// shadows like SSSP's `relaxed_at`. Stored in [`AlgState::arrays`]
    /// (after the device fields), so α-controller migrations remap it
    /// exactly like any other state.
    Host,
    /// Constant per-vertex input uploaded to the accelerator once
    /// (PageRank's `1/outdeg`). Stored in [`AlgState::aux`]; read-only to
    /// kernels.
    Aux,
}

/// One named field of a program's typed state schema.
#[derive(Debug, Clone, Copy)]
pub struct FieldSpec {
    pub name: &'static str,
    pub ty: FieldType,
    pub role: Role,
    /// The field's background value: ghost slots, the dummy sink, every
    /// local vertex [`VertexProgram::init_vertex`] leaves untouched, and
    /// the accelerator's `[state_len, n_cap)` pad region. For fields on a
    /// push channel the driver validates this to be the channel's reduce
    /// identity (re-sent `min`/`max` messages stay idempotent, `add`
    /// outboxes restart from zero).
    pub pad: Value,
}

impl FieldSpec {
    pub fn i32(name: &'static str, role: Role, pad: i32) -> FieldSpec {
        FieldSpec { name, ty: FieldType::I32, role, pad: Value::I32(pad) }
    }
    pub fn f32(name: &'static str, role: Role, pad: f32) -> FieldSpec {
        FieldSpec { name, ty: FieldType::F32, role, pad: Value::F32(pad) }
    }
    pub fn u64(name: &'static str, role: Role, pad: u64) -> FieldSpec {
        FieldSpec { name, ty: FieldType::U64, role, pad: Value::U64(pad) }
    }
}

/// Declarative communication op over schema fields. The driver resolves
/// these to engine [`CommOp`]s with array indices and dtype-checked
/// reductions.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CommDecl {
    /// Push channel with a `min` reduction (dtype from the field).
    PushMin(FieldId),
    /// Push channel with a `max` reduction (f32 — widest path).
    PushMax(FieldId),
    /// Push channel with an f32 `add` reduction. Order-sensitive: the
    /// engine falls back to canonical-order release (DESIGN.md §4.2) and
    /// the driver's scatter kernels iterate in canonical vertex order
    /// (DESIGN.md §9).
    PushAdd(FieldId),
    /// Push channel with a bitwise-`or` reduction over u64 lane words
    /// (multi-source BFS frontiers). Order-free: `a | b | c` is the same
    /// word in any arrival order, so the pipelined executor never needs
    /// the strict-order fallback. The channel resets outbox slots to the
    /// identity (0) after each send — only fresh bits travel.
    PushOr(FieldId),
    /// Pull channel: ghost slots are overwritten with remote real values
    /// before each compute.
    Pull(FieldId),
    /// BC's paired level+σ scatter ([`CommOp::DistSigma`]).
    DistSigma { dist: FieldId, sigma: FieldId },
}

/// Which vertices a kernel visits in a superstep.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Activation {
    /// Every vertex, every superstep (fixed-round programs).
    Always,
    /// Vertices whose i32 field equals [`VertexProgram::current_level`]
    /// (level-synchronous programs).
    LevelEquals(FieldId),
}

/// The kernel family the driver derives a cycle's compute phase from.
/// Families cover the paper's algorithm classes; adding a family extends
/// every program at once.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Kernel {
    /// Monotone value propagation (SSSP, CC, widest path): a vertex whose
    /// `value` improved past its `shadow` since it last relaxed scatters
    /// [`VertexProgram::edge_update`] along its out-edges with the
    /// channel's `min`/`max` reduction. Activation is the monotone trick
    /// of paper Fig. 20: inbox improvements re-activate without flags.
    MonotoneScatter { value: FieldId, shadow: FieldId },
    /// Level-synchronous traversal (BFS): frontier vertices (`level ==
    /// current_level`) expand out-edges, claiming unvisited local targets
    /// through the cache-resident visited bitmap (paper Fig. 11/12). The
    /// driver also derives the bottom-up **pull** kernel over the
    /// partition transpose with early exit (DESIGN.md §8), frontier
    /// stats for the α/β policy, and bitmap rebuilds after migrations.
    ///
    /// Contract: a traversal program's [`VertexProgram::edge_update`] must
    /// be **edge-uniform** — `Some`, weight-independent, and a function of
    /// the frontier level only (BFS: `cur + 1`). The claim protocol and
    /// the derived pull kernel apply one update value per superstep; the
    /// driver evaluates `edge_update` once per superstep with weight 0.
    Traversal { level: FieldId },
    /// Bit-parallel multi-source traversal (MS-BFS; DESIGN.md §13): up to
    /// 64 BFS instances share one cache line per vertex, each owning one
    /// bit lane of three u64 words. A superstep runs in two pool-barriered
    /// phases:
    ///
    /// - **Phase A (settle, vertex-parallel)**: `new = next[v] & !seen[v]`;
    ///   if nonzero the vertex folds `new` into `seen`, records
    ///   `current_level` into the per-lane i32 level field of every bit in
    ///   `new`, publishes `frontier[v] = new`, and votes changed. `next`
    ///   resets to 0 either way. Per-vertex writes are disjoint, so any
    ///   interleaving yields the same words.
    /// - **Phase B (expand, requested balance plan incl. `HubSplit`)**:
    ///   every vertex with a nonzero frontier word `fetch_or`s it into all
    ///   out-neighbors' `next` cells (ghost slots included). `or` is
    ///   idempotent and commutative, so sharded hub adjacencies and any
    ///   chunk schedule produce identical bits.
    ///
    /// The per-lane level fields are the `lanes` consecutive schema fields
    /// starting at `levels_base` (contiguity keeps `Kernel: Copy`). Push
    /// only: the derived pull kernel and the α/β direction policy do not
    /// apply (`supports_pull` is false for bit-traversal programs).
    BitTraversal {
        next: FieldId,
        seen: FieldId,
        frontier: FieldId,
        levels_base: FieldId,
        lanes: usize,
    },
    /// BC's forward sweep: traversal that additionally accumulates
    /// shortest-path counts (σ) into targets settled exactly one level
    /// deeper, iterated in canonical order (the σ adds are f32). The
    /// per-edge behavior is fixed by the paired [`CommDecl::DistSigma`].
    TraversalSigma { dist: FieldId, sigma: FieldId },
    /// Gather (pull-based PageRank, BC backward): each active vertex sums
    /// `src` over its adjacency and applies the result via
    /// [`VertexProgram::gather_apply`]; afterwards every vertex runs
    /// [`VertexProgram::publish`] (contribution/ratio refresh).
    Gather { src: FieldId, active: Activation },
    /// Push-mode PageRank: fold the accumulated sums into the value
    /// ([`VertexProgram::fold`]), then scatter
    /// [`VertexProgram::scatter_value`] into `accum` along out-edges in
    /// canonical order. The final fixed superstep is fold-only (the last
    /// round's remote partial sums land during communication).
    FoldScatter { accum: FieldId },
    /// Edge-centric sorted-adjacency intersection (triangle counting, the
    /// motif family's showcase; DESIGN.md §15). A single fixed superstep:
    /// for every local vertex `v` with global id `g`, the driver merges
    /// [`VertexProgram::neighbors`]`(g)` against the neighbor list of each
    /// of its neighbors `w`, counting common vertices **strictly greater
    /// than `w`** (`count_common_above`), and stores the u64 total into
    /// `count`. Counting only above `w` orients each triangle so it is
    /// charged to `v` exactly once per incident triangle — no divide-by-2,
    /// and the per-vertex totals are shard-safe. The adjacency is the
    /// *program's* (sorted, deduplicated, global-id) view captured in
    /// `prepare`, not the partition CSR, so every merge is exact
    /// regardless of partitioning. Per-vertex u64 stores are disjoint —
    /// order-free under the §9 contract, so the pipelined executor and
    /// every balance plan stay bit-identical. No communication: the plan
    /// must declare an empty channel list and `fixed_rounds == Some(1)`.
    /// `Balance::HubSplit` degrades to `Edge` (a merge must see the whole
    /// adjacency; partition-row shards do not index the program's view).
    NeighborIntersect { count: FieldId },
    /// Synchronous double-buffered neighborhood scan (k-core peeling,
    /// label propagation; DESIGN.md §15). A superstep runs in two
    /// pool-barriered phases:
    ///
    /// - **Phase A (snapshot, vertex-parallel)**: copy `cur → prev` for
    ///   every local vertex. The pool barrier between the phases makes
    ///   `prev` a consistent previous-round snapshot.
    /// - **Phase B (scan, requested balance plan capped at `Edge`)**: each
    ///   vertex computes its next value via
    ///   [`VertexProgram::scan_vertex`], reading neighbors' previous-round
    ///   values through a [`NeighborView`] — local targets from the `prev`
    ///   snapshot, ghost targets from `cur`, whose ghost slots the **pull
    ///   channel** (required on `cur`) filled with the remote reals'
    ///   end-of-previous-superstep values. The driver stores the result
    ///   and votes changed only on difference.
    ///
    /// Reads are snapshot-isolated and each vertex writes only its own
    /// i32 cell, so the scan is order-free: bit-identical across
    /// executors, placements, and balance plans.
    NeighborScan { cur: FieldId, prev: FieldId },
}

/// Accelerator program binding for one cycle.
#[derive(Debug, Clone, Copy)]
pub struct AccelSpec {
    /// Program name in the AOT manifest (`python/compile/model.py`).
    /// Naming a program that is not lowered (e.g. `pagerank_push`) keeps
    /// the algorithm CPU-only: accelerator runs fail at manifest lookup
    /// with an actionable message.
    pub name: &'static str,
    pub n_si32: usize,
    pub n_sf32: usize,
}

/// One BSP cycle's declarative plan.
#[derive(Debug, Clone)]
pub struct CyclePlan {
    pub kernel: Kernel,
    pub comm: Vec<CommDecl>,
    /// Fields shipped to the accelerator this cycle, in program order.
    /// `None` = every [`Role::Device`] field in schema order (BC's forward
    /// cycle narrows this to `[dist, numsp]`).
    pub device: Option<Vec<FieldId>>,
    pub accel: AccelSpec,
}

/// Static program description — the typed counterpart of [`AlgSpec`].
#[derive(Debug, Clone, Copy)]
pub struct ProgramMeta {
    pub name: &'static str,
    /// Requires edge weights (SSSP, widest path).
    pub needs_weights: bool,
    /// Operates on the undirected view (CC).
    pub undirected: bool,
    /// Operates on the reversed graph (pull-based PageRank); also selects
    /// the accelerator's [`EdgeOrientation`].
    pub reversed: bool,
    /// Fixed superstep count per cycle; `None` → run to quiescence.
    pub fixed_rounds: Option<usize>,
    /// Which field carries the per-vertex result.
    pub output: FieldId,
}

/// The typed vertex-program interface. Implementations declare *what* the
/// algorithm is; the [`ProgramDriver`] owns *how* it executes.
pub trait VertexProgram: Sync {
    fn meta(&self) -> ProgramMeta;
    fn schema(&self) -> Vec<FieldSpec>;
    fn plan(&self, cycle: usize) -> CyclePlan;

    /// BSP cycles (1 for everything except BC's forward+backward).
    fn cycles(&self) -> usize {
        1
    }

    /// One-time hook before partitioning (PageRank captures |V| and the
    /// original out-degrees here).
    fn prepare(&mut self, _original: &CsrGraph, _prepared: &CsrGraph) {}

    /// Initialize one local vertex's fields. The driver pre-fills every
    /// array with the field pads, so programs only write what differs
    /// (the source's level/distance, a vertex's own label, …).
    fn init_vertex(&self, global_id: u32, row: &mut InitRow<'_>);

    /// Hook at the start of each cycle (BC computes the max level and
    /// seeds the deepest ratios here). `states` follows the schema layout:
    /// `arrays[i]` is state field `i` (device fields first — schema order
    /// restricted to [`Role::Device`]/[`Role::Host`]).
    fn begin_cycle(&mut self, _cycle: usize, _pg: &PartitionedGraph, _states: &mut [AlgState]) {}

    /// The level that [`Activation::LevelEquals`] and the traversal
    /// kernels compare against (BC's backward sweep counts down).
    fn current_level(&self, ctx: &StepCtx) -> i32 {
        ctx.superstep as i32
    }

    /// Per-edge update for the scatter families: given the source vertex's
    /// value (of the kernel's `value`/`level` field) and the edge weight,
    /// produce the value delivered to the target — applied with the
    /// field's declared reduction. `None` skips the edge.
    fn edge_update(&self, _ctx: &StepCtx, _src: Value, _w: f32) -> Option<Value> {
        None
    }

    /// [`Kernel::Gather`]: apply the adjacency sum to vertex `v`; returns
    /// the number of state writes performed (instrumentation).
    fn gather_apply(&self, _ctx: &StepCtx, _v: usize, _f: &Fields<'_>, _sum: f32) -> u64 {
        panic!("program declared Kernel::Gather but does not implement gather_apply")
    }

    /// [`Kernel::Gather`]: per-vertex publish sweep after the gather
    /// (PageRank refreshes contributions, BC publishes ratios).
    fn publish(&self, _ctx: &StepCtx, _v: usize, _f: &Fields<'_>) {}

    /// [`Kernel::FoldScatter`]: fold the accumulator into the value for
    /// vertex `v` (runs for supersteps ≥ 1); returns writes performed.
    fn fold(&self, _ctx: &StepCtx, _v: usize, _f: &Fields<'_>) -> u64 {
        panic!("program declared Kernel::FoldScatter but does not implement fold")
    }

    /// [`Kernel::FoldScatter`]: the value vertex `v` scatters along its
    /// out-edges this superstep (`0.0` skips the vertex).
    fn scatter_value(&self, _ctx: &StepCtx, _v: usize, _f: &Fields<'_>) -> f32 {
        panic!("program declared Kernel::FoldScatter but does not implement scatter_value")
    }

    /// [`Kernel::NeighborIntersect`]: the sorted, **deduplicated**
    /// adjacency of global vertex `g` in the program's own view of the
    /// graph (captured in `prepare`; triangle counting uses the
    /// undirected, self-loop-free closure). Must be sorted ascending —
    /// the driver's merge intersections rely on it.
    fn neighbors(&self, _g: u32) -> &[u32] {
        panic!("program declared Kernel::NeighborIntersect but does not implement neighbors")
    }

    /// [`Kernel::NeighborScan`]: compute local vertex `v`'s next value
    /// from its own fields and its neighbors' previous-round values
    /// (`nb`, one entry per adjacency slot of the partitioned view —
    /// multigraph multiplicities included). The driver stores the return
    /// value into `cur` and votes changed only if it differs.
    fn scan_vertex(&self, _ctx: &StepCtx, _v: usize, _f: &Fields<'_>, _nb: &NeighborView<'_, '_>) -> i32 {
        panic!("program declared Kernel::NeighborScan but does not implement scan_vertex")
    }

    /// Skip this superstep's compute entirely (BC's backward cycle guards
    /// `current_level < 1`: the source must never accumulate dependency).
    /// Skipped supersteps report `changed = true` so fixed-length cycles
    /// keep their superstep count.
    fn skip_superstep(&self, _ctx: &StepCtx) -> bool {
        false
    }

    /// Custom cycle termination; `None` uses the default (fixed rounds, or
    /// quiescence). BC overrides both cycles.
    fn cycle_done(&self, _cycle: usize, _next_superstep: usize, _any_changed: bool) -> Option<bool> {
        None
    }

    /// Scalar inputs for the accelerator program (lengths must match the
    /// plan's [`AccelSpec`]).
    fn scalars_i32(&self, _ctx: &StepCtx) -> Vec<i32> {
        vec![]
    }
    fn scalars_f32(&self, _ctx: &StepCtx) -> Vec<f32> {
        vec![]
    }

    /// Traversed-edges accounting for TEPS (paper §5) — each program owns
    /// its own formula instead of a stringly-typed dispatch.
    fn traversed_edges(&self, _output: &StateArray, g: &CsrGraph, rounds: usize) -> u64 {
        g.edge_count() as u64 * rounds.max(1) as u64
    }
}

// ---------------------------------------------------------------------------
// Typed state access
// ---------------------------------------------------------------------------

/// Where a schema field resolved to in the built [`AlgState`].
#[derive(Debug, Clone, Copy)]
enum Slot {
    State(usize),
    Aux(usize),
}

/// Typed per-vertex writer handed to [`VertexProgram::init_vertex`].
pub struct InitRow<'a> {
    arrays: &'a mut [StateArray],
    aux: &'a mut [StateArray],
    slots: &'a [Slot],
    v: usize,
}

impl InitRow<'_> {
    fn slot_mut(&mut self, f: FieldId) -> &mut StateArray {
        match self.slots[f.0] {
            Slot::State(i) => &mut self.arrays[i],
            Slot::Aux(i) => &mut self.aux[i],
        }
    }
    pub fn set_i32(&mut self, f: FieldId, x: i32) {
        let v = self.v;
        self.slot_mut(f).as_i32_mut()[v] = x;
    }
    pub fn set_f32(&mut self, f: FieldId, x: f32) {
        let v = self.v;
        self.slot_mut(f).as_f32_mut()[v] = x;
    }
    pub fn set_u64(&mut self, f: FieldId, x: u64) {
        let v = self.v;
        self.slot_mut(f).as_u64_mut()[v] = x;
    }
}

/// Typed view over one partition's state during a superstep, indexed by
/// schema [`FieldId`]. State fields are atomic cells (relaxed ordering —
/// the BSP barrier provides synchronization); aux fields are read-only.
pub struct Fields<'a> {
    cells: Vec<StateCells<'a>>,
    aux: Vec<AuxSlice<'a>>,
    slots: &'a [Slot],
}

enum StateCells<'a> {
    I32(&'a [AtomicI32]),
    F32(&'a [AtomicU32]),
    U64(&'a [AtomicU64]),
}

enum AuxSlice<'a> {
    I32(&'a [i32]),
    F32(&'a [f32]),
    U64(&'a [u64]),
}

impl<'a> Fields<'a> {
    fn new(state: &'a mut AlgState, slots: &'a [Slot]) -> Fields<'a> {
        let AlgState { arrays, aux, .. } = state;
        let cells = arrays
            .iter_mut()
            .map(|a| match a {
                StateArray::I32(v) => StateCells::I32(as_atomic_i32_cells(v)),
                StateArray::F32(v) => StateCells::F32(as_atomic_f32_cells(v)),
                StateArray::U64(v) => StateCells::U64(as_atomic_u64_cells(v)),
            })
            .collect();
        let aux = aux
            .iter()
            .map(|a| match a {
                StateArray::I32(v) => AuxSlice::I32(v),
                StateArray::F32(v) => AuxSlice::F32(v),
                StateArray::U64(v) => AuxSlice::U64(v),
            })
            .collect();
        Fields { cells, aux, slots }
    }

    fn state_cells(&self, f: FieldId) -> &StateCells<'a> {
        match self.slots[f.0] {
            Slot::State(i) => &self.cells[i],
            Slot::Aux(_) => panic!("field {} is aux (read via aux accessors)", f.0),
        }
    }

    pub fn i32(&self, f: FieldId, v: usize) -> i32 {
        match self.slots[f.0] {
            Slot::State(i) => match &self.cells[i] {
                StateCells::I32(c) => c[v].load(Ordering::Relaxed),
                _ => panic!("field {} is not i32", f.0),
            },
            Slot::Aux(i) => match &self.aux[i] {
                AuxSlice::I32(s) => s[v],
                _ => panic!("field {} is not i32", f.0),
            },
        }
    }

    pub fn f32(&self, f: FieldId, v: usize) -> f32 {
        match self.slots[f.0] {
            Slot::State(i) => match &self.cells[i] {
                StateCells::F32(c) => f32::from_bits(c[v].load(Ordering::Relaxed)),
                _ => panic!("field {} is not f32", f.0),
            },
            Slot::Aux(i) => match &self.aux[i] {
                AuxSlice::F32(s) => s[v],
                _ => panic!("field {} is not f32", f.0),
            },
        }
    }

    pub fn u64(&self, f: FieldId, v: usize) -> u64 {
        match self.slots[f.0] {
            Slot::State(i) => match &self.cells[i] {
                StateCells::U64(c) => c[v].load(Ordering::Relaxed),
                _ => panic!("field {} is not u64", f.0),
            },
            Slot::Aux(i) => match &self.aux[i] {
                AuxSlice::U64(s) => s[v],
                _ => panic!("field {} is not u64", f.0),
            },
        }
    }

    pub fn set_i32(&self, f: FieldId, v: usize, x: i32) {
        match self.state_cells(f) {
            StateCells::I32(c) => c[v].store(x, Ordering::Relaxed),
            _ => panic!("field {} is not i32", f.0),
        }
    }

    pub fn set_f32(&self, f: FieldId, v: usize, x: f32) {
        match self.state_cells(f) {
            StateCells::F32(c) => c[v].store(x.to_bits(), Ordering::Relaxed),
            _ => panic!("field {} is not f32", f.0),
        }
    }

    pub fn set_u64(&self, f: FieldId, v: usize, x: u64) {
        match self.state_cells(f) {
            StateCells::U64(c) => c[v].store(x, Ordering::Relaxed),
            _ => panic!("field {} is not u64", f.0),
        }
    }

    /// Atomic `fetch_or` into a u64 cell; returns the previous word.
    pub fn or_u64(&self, f: FieldId, v: usize, x: u64) -> u64 {
        match self.state_cells(f) {
            StateCells::U64(c) => c[v].fetch_or(x, Ordering::Relaxed),
            _ => panic!("field {} is not u64", f.0),
        }
    }

    pub fn add_f32(&self, f: FieldId, v: usize, x: f32) {
        match self.state_cells(f) {
            StateCells::F32(c) => {
                atomic_add_f32(&c[v], x);
            }
            _ => panic!("field {} is not f32", f.0),
        }
    }
}

/// Read-only view of one vertex's neighbors' previous-round values during
/// a [`Kernel::NeighborScan`] superstep (see the kernel docs for the
/// local-prev / ghost-cur split that makes the snapshot consistent).
pub struct NeighborView<'a, 'b> {
    targets: &'a [u32],
    fields: &'a Fields<'b>,
    cur: FieldId,
    prev: FieldId,
    /// Local (real) vertex count: targets `>= nv` are ghost slots.
    nv: usize,
}

impl NeighborView<'_, '_> {
    pub fn len(&self) -> usize {
        self.targets.len()
    }

    pub fn is_empty(&self) -> bool {
        self.targets.is_empty()
    }

    /// Previous-round value of the `k`-th adjacency target: locals read
    /// the Phase-A `prev` snapshot; ghosts read `cur`, whose ghost slots
    /// the pull channel filled with the remote reals' end-of-previous-
    /// superstep values (nobody writes ghosts during compute).
    pub fn value(&self, k: usize) -> i32 {
        let t = self.targets[k] as usize;
        if t < self.nv {
            self.fields.i32(self.prev, t)
        } else {
            self.fields.i32(self.cur, t)
        }
    }
}

/// Count elements common to two **sorted ascending, deduplicated** slices
/// that are strictly greater than `above` — the oriented merge step of
/// [`Kernel::NeighborIntersect`] (each triangle `{v, w, u}` with `w < u`
/// is charged to `v` exactly once, at neighbor `w` via common vertex `u`).
pub fn count_common_above(a: &[u32], b: &[u32], above: u32) -> u64 {
    let mut i = a.partition_point(|&x| x <= above);
    let mut j = b.partition_point(|&x| x <= above);
    let mut n = 0u64;
    while i < a.len() && j < b.len() {
        match a[i].cmp(&b[j]) {
            std::cmp::Ordering::Less => i += 1,
            std::cmp::Ordering::Greater => j += 1,
            std::cmp::Ordering::Equal => {
                n += 1;
                i += 1;
                j += 1;
            }
        }
    }
    n
}

// ---------------------------------------------------------------------------
// Driver
// ---------------------------------------------------------------------------

/// Prior-state injection for incremental recompute (DESIGN.md §14.3).
///
/// `prior` holds the converged output of a previous run of the *same*
/// program on the *pre-mutation* graph, indexed by global vertex id;
/// `seeds` are the mutation-touched endpoints whose out-edges must
/// re-relax. Valid only for single-cycle [`Kernel::MonotoneScatter`]
/// programs and only when every prior value is still an over-approximation
/// of the new fixed point — i.e. after **insert-only** batches (the caller
/// enforces the delete fallback; `alg::incremental` is that caller).
#[derive(Debug, Clone)]
pub struct WarmStart {
    /// Prior converged output values by global id. Vertices at or beyond
    /// `prior.len()` (grown by the mutation) keep their fresh init.
    pub prior: StateArray,
    /// Global ids to re-activate (their shadow resets to the pad, so they
    /// re-scatter their current value on the first superstep).
    pub seeds: Vec<u32>,
}

/// The generic adapter that runs any [`VertexProgram`] through the engine's
/// [`Algorithm`] interface. Construct with [`ProgramDriver::build`] — schema
/// and plan validation happens there, once, with typed errors.
pub struct ProgramDriver<P: VertexProgram> {
    program: P,
    schema: Vec<FieldSpec>,
    /// Schema index → storage slot.
    slots: Vec<Slot>,
    n_state: usize,
    /// Per-cycle kernel, cached at construction so the per-superstep
    /// dispatch never re-derives the plan.
    kernels: Vec<Kernel>,
    /// Per-cycle monotone improvement direction (`Some(upward)` for
    /// [`Kernel::MonotoneScatter`] cycles), cached at construction.
    monotone_upward: Vec<Option<bool>>,
    /// Optional warm start, validated in [`ProgramDriver::with_warm_start`].
    warm: Option<WarmStart>,
}

impl<P: VertexProgram> ProgramDriver<P> {
    /// Validate the program's schema and every cycle plan; a mis-declared
    /// program fails here — before any graph is partitioned or state
    /// built — with an error naming the offending field.
    pub fn build(program: P) -> Result<ProgramDriver<P>> {
        let schema = program.schema();
        let meta = program.meta();
        if schema.is_empty() {
            bail!("program '{}': empty schema", meta.name);
        }
        for (i, f) in schema.iter().enumerate() {
            if f.pad.field_type() != f.ty {
                bail!(
                    "program '{}': field '{}' is {} but its pad is {}",
                    meta.name,
                    f.name,
                    f.ty.name(),
                    f.pad.field_type().name()
                );
            }
            if schema[..i].iter().any(|g| g.name == f.name) {
                bail!("program '{}': duplicate field name '{}'", meta.name, f.name);
            }
            if f.ty == FieldType::U64 && f.role != Role::Host {
                bail!(
                    "program '{}': u64 field '{}' must be Role::Host — u64 state never \
                     crosses the accelerator boundary",
                    meta.name,
                    f.name
                );
            }
        }
        let mut slots = Vec::with_capacity(schema.len());
        let (mut n_state, mut n_aux) = (0usize, 0usize);
        for f in &schema {
            match f.role {
                Role::Device | Role::Host => {
                    slots.push(Slot::State(n_state));
                    n_state += 1;
                }
                Role::Aux => {
                    slots.push(Slot::Aux(n_aux));
                    n_aux += 1;
                }
            }
        }
        let mut driver = ProgramDriver {
            program,
            schema,
            slots,
            n_state,
            kernels: Vec::new(),
            monotone_upward: Vec::new(),
            warm: None,
        };
        for cycle in 0..driver.program.cycles() {
            driver.validate_plan(cycle)?;
            let plan = driver.program.plan(cycle);
            let upward = match plan.kernel {
                Kernel::MonotoneScatter { value, .. } => {
                    Some(driver.monotone_direction(&plan, value)?)
                }
                _ => None,
            };
            driver.monotone_upward.push(upward);
            driver.kernels.push(plan.kernel);
        }
        let out = meta.output;
        driver.check_field(out, "output", None)?;
        if !matches!(driver.slots.get(out.0), Some(Slot::State(_))) {
            bail!(
                "program '{}': output field '{}' must be state, not aux",
                meta.name,
                driver.field_name(out)
            );
        }
        Ok(driver)
    }

    /// The wrapped program (read access for tests and tools). Named
    /// `inner` so it cannot shadow [`Algorithm::program`] on concrete
    /// driver types.
    pub fn inner(&self) -> &P {
        &self.program
    }

    /// Arm a warm start (see [`WarmStart`]): `init_state` will overwrite
    /// the fresh per-vertex init with the prior converged values — shadow
    /// included, so un-seeded vertices start quiescent — then reset the
    /// shadow of every seed to the field pad so seeds re-scatter on the
    /// first superstep. Chaotic monotone relaxation started from any state
    /// ≥ the least fixed point converges to that same fixed point, and the
    /// per-edge candidates are computed by the identical binary ops — so a
    /// warm run's output is **bit-identical** to a cold run's (asserted by
    /// the differential-fuzz mutation axis).
    ///
    /// Typed rejections: any program that is not single-cycle
    /// [`Kernel::MonotoneScatter`] (a level-synchronous traversal's
    /// `level == superstep` activation cannot resume mid-wave), or a
    /// `prior` dtype that does not match the value field.
    pub fn with_warm_start(mut self, warm: WarmStart) -> Result<Self> {
        let meta = self.program.meta();
        let value = match (self.kernels.as_slice(), self.program.cycles()) {
            ([Kernel::MonotoneScatter { value, .. }], 1) => *value,
            _ => bail!(
                "program '{}': warm start requires a single-cycle MonotoneScatter kernel",
                meta.name
            ),
        };
        let want = self.schema[value.0].ty;
        let got = warm.prior.field_type();
        if want != got {
            bail!(
                "program '{}': warm-start prior is {} but value field '{}' is {}",
                meta.name,
                got.name(),
                self.field_name(value),
                want.name()
            );
        }
        self.warm = Some(warm);
        Ok(self)
    }

    fn field_name(&self, f: FieldId) -> &'static str {
        self.schema.get(f.0).map_or("<out of range>", |s| s.name)
    }

    fn check_field(&self, f: FieldId, what: &str, want: Option<FieldType>) -> Result<()> {
        let meta = self.program.meta();
        let Some(spec) = self.schema.get(f.0) else {
            bail!(
                "program '{}': {what} references field {} but the schema has {} fields",
                meta.name,
                f.0,
                self.schema.len()
            );
        };
        if let Some(ty) = want {
            if spec.ty != ty {
                bail!(
                    "program '{}': {what} needs a {} field, but '{}' is {}",
                    meta.name,
                    ty.name(),
                    spec.name,
                    spec.ty.name()
                );
            }
        }
        Ok(())
    }

    fn check_state_field(&self, f: FieldId, what: &str, want: Option<FieldType>) -> Result<()> {
        self.check_field(f, what, want)?;
        if self.schema[f.0].role == Role::Aux {
            bail!(
                "program '{}': {what} may not use aux field '{}' (aux is constant)",
                self.program.meta().name,
                self.field_name(f)
            );
        }
        Ok(())
    }

    /// Pad must be the push reduction's identity: ghost slots are
    /// initialized from it and re-sent messages must be no-ops.
    fn check_identity(&self, f: FieldId, want: Value, chan: &str) -> Result<()> {
        let spec = &self.schema[f.0];
        let ok = match (spec.pad, want) {
            (Value::I32(a), Value::I32(b)) => a == b,
            (Value::F32(a), Value::F32(b)) => a.to_bits() == b.to_bits(),
            (Value::U64(a), Value::U64(b)) => a == b,
            _ => false,
        };
        if !ok {
            bail!(
                "program '{}': field '{}' is on a {chan} channel, so its pad must be the \
                 reduce identity {want:?}, got {:?}",
                self.program.meta().name,
                spec.name,
                spec.pad
            );
        }
        Ok(())
    }

    fn validate_plan(&self, cycle: usize) -> Result<()> {
        let meta = self.program.meta();
        let plan = self.program.plan(cycle);
        for decl in &plan.comm {
            match *decl {
                CommDecl::PushMin(f) => {
                    self.check_state_field(f, "PushMin", None)?;
                    let id = match self.schema[f.0].ty {
                        FieldType::I32 => Value::I32(INF_I32),
                        FieldType::F32 => Value::F32(f32::INFINITY),
                        FieldType::U64 => bail!(
                            "program '{}': PushMin is not defined for u64 field '{}' \
                             (u64 travels on PushOr)",
                            meta.name,
                            self.field_name(f)
                        ),
                    };
                    self.check_identity(f, id, "push-min")?;
                }
                CommDecl::PushMax(f) => {
                    self.check_state_field(f, "PushMax", Some(FieldType::F32))?;
                    self.check_identity(f, Value::F32(f32::NEG_INFINITY), "push-max")?;
                }
                CommDecl::PushAdd(f) => {
                    self.check_state_field(f, "PushAdd", Some(FieldType::F32))?;
                    self.check_identity(f, Value::F32(0.0), "push-add")?;
                }
                CommDecl::PushOr(f) => {
                    self.check_state_field(f, "PushOr", Some(FieldType::U64))?;
                    self.check_identity(f, Value::U64(0), "push-or")?;
                }
                CommDecl::Pull(f) => {
                    self.check_state_field(f, "Pull", None)?;
                    if self.schema[f.0].ty == FieldType::U64 {
                        bail!(
                            "program '{}': Pull is not defined for u64 field '{}' \
                             (u64 travels on PushOr)",
                            meta.name,
                            self.field_name(f)
                        );
                    }
                }
                CommDecl::DistSigma { dist, sigma } => {
                    self.check_state_field(dist, "DistSigma.dist", Some(FieldType::I32))?;
                    self.check_state_field(sigma, "DistSigma.sigma", Some(FieldType::F32))?;
                    self.check_identity(dist, Value::I32(INF_I32), "dist-sigma")?;
                    self.check_identity(sigma, Value::F32(0.0), "dist-sigma")?;
                }
            }
        }
        match plan.kernel {
            Kernel::MonotoneScatter { value, shadow } => {
                self.check_state_field(value, "MonotoneScatter.value", None)?;
                self.check_state_field(shadow, "MonotoneScatter.shadow", None)?;
                if value == shadow {
                    bail!(
                        "program '{}': MonotoneScatter value and shadow must be distinct \
                         fields (both are '{}')",
                        meta.name,
                        self.field_name(value)
                    );
                }
                if self.schema[value.0].ty != self.schema[shadow.0].ty {
                    bail!(
                        "program '{}': MonotoneScatter value '{}' and shadow '{}' must share a dtype",
                        meta.name,
                        self.field_name(value),
                        self.field_name(shadow)
                    );
                }
                // direction comes from the value field's push channel
                self.monotone_direction(&plan, value)?;
            }
            Kernel::Traversal { level } => {
                self.check_state_field(level, "Traversal.level", Some(FieldType::I32))?;
                if !plan.comm.contains(&CommDecl::PushMin(level)) {
                    bail!(
                        "program '{}': Traversal level '{}' must travel on a PushMin channel",
                        meta.name,
                        self.field_name(level)
                    );
                }
            }
            Kernel::BitTraversal { next, seen, frontier, levels_base, lanes } => {
                if lanes == 0 || lanes > 64 {
                    bail!(
                        "program '{}': BitTraversal lanes must be 1..=64, got {lanes}",
                        meta.name
                    );
                }
                for (f, what) in [
                    (next, "BitTraversal.next"),
                    (seen, "BitTraversal.seen"),
                    (frontier, "BitTraversal.frontier"),
                ] {
                    self.check_state_field(f, what, Some(FieldType::U64))?;
                    self.check_identity(f, Value::U64(0), "bit-traversal")?;
                }
                if next == seen || next == frontier || seen == frontier {
                    bail!(
                        "program '{}': BitTraversal next/seen/frontier must be three \
                         distinct fields",
                        meta.name
                    );
                }
                if !plan.comm.contains(&CommDecl::PushOr(next)) {
                    bail!(
                        "program '{}': BitTraversal next word '{}' must travel on a \
                         PushOr channel",
                        meta.name,
                        self.field_name(next)
                    );
                }
                for b in 0..lanes {
                    let f = FieldId(levels_base.0 + b);
                    self.check_state_field(f, "BitTraversal lane level", Some(FieldType::I32))?;
                    self.check_identity(f, Value::I32(INF_I32), "bit-traversal lane")?;
                }
            }
            Kernel::TraversalSigma { dist, sigma } => {
                if !plan.comm.iter().any(|d| *d == CommDecl::DistSigma { dist, sigma }) {
                    bail!(
                        "program '{}': TraversalSigma must pair with a DistSigma channel",
                        meta.name
                    );
                }
            }
            Kernel::Gather { src, active } => {
                self.check_state_field(src, "Gather.src", Some(FieldType::F32))?;
                if let Activation::LevelEquals(f) = active {
                    self.check_state_field(f, "Gather activation", Some(FieldType::I32))?;
                }
            }
            Kernel::FoldScatter { accum } => {
                self.check_state_field(accum, "FoldScatter.accum", Some(FieldType::F32))?;
                if !plan.comm.contains(&CommDecl::PushAdd(accum)) {
                    bail!(
                        "program '{}': FoldScatter accumulator '{}' must travel on a PushAdd channel",
                        meta.name,
                        self.field_name(accum)
                    );
                }
                if meta.fixed_rounds.is_none() {
                    bail!(
                        "program '{}': FoldScatter requires fixed_rounds (the trailing \
                         superstep is fold-only)",
                        meta.name
                    );
                }
            }
            Kernel::NeighborIntersect { count } => {
                self.check_state_field(count, "NeighborIntersect.count", Some(FieldType::U64))?;
                if !matches!(self.schema[count.0].pad, Value::U64(0)) {
                    bail!(
                        "program '{}': NeighborIntersect count '{}' must pad with 0 \
                         (ghost/dummy slots carry no triangles)",
                        meta.name,
                        self.field_name(count)
                    );
                }
                if !plan.comm.is_empty() {
                    bail!(
                        "program '{}': NeighborIntersect declares no communication \
                         (per-vertex counts are store-only over the program's own \
                         adjacency), got {} channel(s)",
                        meta.name,
                        plan.comm.len()
                    );
                }
                if meta.fixed_rounds != Some(1) {
                    bail!(
                        "program '{}': NeighborIntersect is a single fixed superstep \
                         (fixed_rounds must be Some(1))",
                        meta.name
                    );
                }
            }
            Kernel::NeighborScan { cur, prev } => {
                self.check_state_field(cur, "NeighborScan.cur", Some(FieldType::I32))?;
                self.check_state_field(prev, "NeighborScan.prev", Some(FieldType::I32))?;
                if cur == prev {
                    bail!(
                        "program '{}': NeighborScan cur and prev must be distinct fields \
                         (both are '{}')",
                        meta.name,
                        self.field_name(cur)
                    );
                }
                if !plan.comm.contains(&CommDecl::Pull(cur)) {
                    bail!(
                        "program '{}': NeighborScan cur '{}' must travel on a Pull channel \
                         (ghost slots carry the previous round's remote values)",
                        meta.name,
                        self.field_name(cur)
                    );
                }
            }
        }
        if let Some(device) = &plan.device {
            for &f in device {
                self.check_field(f, "device list", None)?;
                if self.schema[f.0].role != Role::Device {
                    bail!(
                        "program '{}': device list includes '{}' whose role is {:?}",
                        meta.name,
                        self.field_name(f),
                        self.schema[f.0].role
                    );
                }
            }
        }
        Ok(())
    }

    /// Which way a monotone value improves, derived from its push channel.
    fn monotone_direction(&self, plan: &CyclePlan, value: FieldId) -> Result<bool> {
        for decl in &plan.comm {
            match *decl {
                CommDecl::PushMin(f) if f == value => return Ok(false), // improves downward
                CommDecl::PushMax(f) if f == value => return Ok(true),  // improves upward
                _ => {}
            }
        }
        bail!(
            "program '{}': MonotoneScatter value '{}' needs a PushMin or PushMax channel \
             to derive its improvement direction",
            self.program.meta().name,
            self.field_name(value)
        )
    }

    fn state_index(&self, f: FieldId) -> usize {
        match self.slots[f.0] {
            Slot::State(i) => i,
            Slot::Aux(_) => unreachable!("validated as state field"),
        }
    }

    fn aux_index(&self, f: FieldId) -> usize {
        match self.slots[f.0] {
            Slot::Aux(i) => i,
            Slot::State(_) => unreachable!("validated as aux field"),
        }
    }

    /// Does any cycle derive a pull kernel? (Traversal programs only.)
    fn is_traversal(&self) -> Option<FieldId> {
        match self.kernels.first() {
            Some(&Kernel::Traversal { level }) if self.kernels.len() == 1 => Some(level),
            _ => None,
        }
    }

    /// Rebuild the visited bitmap from the level field: a bit is set iff
    /// the vertex already holds a level (claims only ever accompany a
    /// settle to a finite level, so bit ⊆ finite always holds).
    fn build_bitmap(&self, level: FieldId, part: &Partition, state: &mut AlgState) {
        let mut bitmap = vec![0u64; part.nv.div_ceil(64).max(1)];
        let levels = state.arrays[self.state_index(level)].as_i32();
        for (v, &l) in levels.iter().take(part.nv).enumerate() {
            if l != INF_I32 {
                bitmap[v / 64] |= 1 << (v % 64);
            }
        }
        state.scratch = bitmap;
    }
}

impl<P: VertexProgram> Algorithm for ProgramDriver<P> {
    fn spec(&self) -> AlgSpec {
        let m = self.program.meta();
        AlgSpec {
            name: m.name,
            needs_weights: m.needs_weights,
            undirected: m.undirected,
            reversed: m.reversed,
            fixed_rounds: m.fixed_rounds,
        }
    }

    fn cycles(&self) -> usize {
        self.program.cycles()
    }

    fn prepare(&mut self, original: &CsrGraph, prepared: &CsrGraph) {
        self.program.prepare(original, prepared);
    }

    fn init_state(&mut self, pg: &PartitionedGraph, part: &Partition) -> AlgState {
        let n = part.state_len();
        let mut arrays = vec![StateArray::I32(Vec::new()); self.n_state];
        let mut aux: Vec<StateArray> = Vec::new();
        for (f, &slot) in self.schema.iter().zip(&self.slots) {
            let arr = match f.pad {
                Value::I32(x) => StateArray::I32(vec![x; n]),
                Value::F32(x) => StateArray::F32(vec![x; n]),
                Value::U64(x) => StateArray::U64(vec![x; n]),
            };
            match slot {
                Slot::State(i) => arrays[i] = arr,
                Slot::Aux(_) => aux.push(arr),
            }
        }
        let mut st = AlgState { arrays, aux, scratch: Vec::new() };
        for (l, &g) in part.local_to_global.iter().enumerate() {
            let mut row = InitRow {
                arrays: &mut st.arrays,
                aux: &mut st.aux,
                slots: &self.slots,
                v: l,
            };
            self.program.init_vertex(g, &mut row);
        }
        if let Some(warm) = &self.warm {
            // validated in with_warm_start: single-cycle MonotoneScatter
            let (value, shadow) = match self.kernels[0] {
                Kernel::MonotoneScatter { value, shadow } => (value, shadow),
                _ => unreachable!("validated in with_warm_start"),
            };
            let (vi, si) = (self.state_index(value), self.state_index(shadow));
            // prior values land in value AND shadow (quiescent); ghost and
            // dummy slots keep the pad — the push-reduce identity.
            match &warm.prior {
                StateArray::I32(prior) => {
                    for (l, &g) in part.local_to_global.iter().enumerate() {
                        if let Some(&p) = prior.get(g as usize) {
                            st.arrays[vi].as_i32_mut()[l] = p;
                            st.arrays[si].as_i32_mut()[l] = p;
                        }
                    }
                }
                StateArray::F32(prior) => {
                    for (l, &g) in part.local_to_global.iter().enumerate() {
                        if let Some(&p) = prior.get(g as usize) {
                            st.arrays[vi].as_f32_mut()[l] = p;
                            st.arrays[si].as_f32_mut()[l] = p;
                        }
                    }
                }
                StateArray::U64(_) => unreachable!("rejected in with_warm_start"),
            }
            // seeds re-activate: shadow back to the pad means "has never
            // scattered", so the monotone gate fires for any finite value.
            let pad = self.schema[shadow.0].pad;
            for &gid in &warm.seeds {
                let g = gid as usize;
                if g < pg.part_of.len()
                    && pg.part_of[g] as usize == part.id
                    && pg.local_of[g] != u32::MAX
                {
                    let l = pg.local_of[g] as usize;
                    match pad {
                        Value::I32(x) => st.arrays[si].as_i32_mut()[l] = x,
                        Value::F32(x) => st.arrays[si].as_f32_mut()[l] = x,
                        Value::U64(x) => st.arrays[si].as_u64_mut()[l] = x,
                    }
                }
            }
        }
        if let Some(level) = self.is_traversal() {
            self.build_bitmap(level, part, &mut st);
        }
        st
    }

    fn begin_cycle(&mut self, cycle: usize, pg: &PartitionedGraph, states: &mut [AlgState]) {
        self.program.begin_cycle(cycle, pg, states);
    }

    fn channels(&self, cycle: usize) -> Vec<CommOp> {
        self.program
            .plan(cycle)
            .comm
            .iter()
            .map(|decl| match *decl {
                CommDecl::PushMin(f) => {
                    let i = self.state_index(f);
                    CommOp::Single(match self.schema[f.0].ty {
                        FieldType::I32 => Channel::push_min_i32(i),
                        FieldType::F32 => Channel::push_min_f32(i),
                        FieldType::U64 => unreachable!("rejected at construction"),
                    })
                }
                CommDecl::PushMax(f) => CommOp::Single(Channel::push_max_f32(self.state_index(f))),
                CommDecl::PushAdd(f) => CommOp::Single(Channel::push_add_f32(self.state_index(f))),
                CommDecl::PushOr(f) => CommOp::Single(Channel::push_or_u64(self.state_index(f))),
                CommDecl::Pull(f) => {
                    let i = self.state_index(f);
                    CommOp::Single(match self.schema[f.0].ty {
                        FieldType::I32 => Channel::pull_i32(i),
                        FieldType::F32 => Channel::pull_f32(i),
                        FieldType::U64 => unreachable!("rejected at construction"),
                    })
                }
                CommDecl::DistSigma { dist, sigma } => CommOp::DistSigma {
                    dist: self.state_index(dist),
                    sigma: self.state_index(sigma),
                },
            })
            .collect()
    }

    fn program(&self, cycle: usize) -> ProgramSpec {
        let plan = self.program.plan(cycle);
        let meta = self.program.meta();
        let device: Vec<FieldId> = plan.device.clone().unwrap_or_else(|| {
            self.schema
                .iter()
                .enumerate()
                .filter(|(_, f)| f.role == Role::Device)
                .map(|(i, _)| FieldId(i))
                .collect()
        });
        ProgramSpec {
            name: plan.accel.name,
            arrays: device.iter().map(|&f| self.state_index(f)).collect(),
            pads: device.iter().map(|&f| self.schema[f.0].pad.to_pad()).collect(),
            aux: self
                .schema
                .iter()
                .enumerate()
                .filter(|(_, f)| f.role == Role::Aux)
                .map(|(i, _)| self.aux_index(FieldId(i)))
                .collect(),
            needs_weights: meta.needs_weights,
            n_si32: plan.accel.n_si32,
            n_sf32: plan.accel.n_sf32,
            orientation: if meta.reversed {
                EdgeOrientation::Reversed
            } else {
                EdgeOrientation::Forward
            },
        }
    }

    fn scalars_i32(&self, ctx: &StepCtx) -> Vec<i32> {
        self.program.scalars_i32(ctx)
    }

    fn scalars_f32(&self, ctx: &StepCtx) -> Vec<f32> {
        self.program.scalars_f32(ctx)
    }

    fn supports_pull(&self) -> bool {
        self.is_traversal().is_some()
    }

    /// Frontier shape ahead of superstep `next_superstep` for traversal
    /// programs: one scan of the local levels counting the frontier
    /// (`level == next`) and unexplored (`level == INF`) vertices with
    /// their out-degree sums — the `m_f` / `m_u` inputs of the α/β policy.
    fn frontier_stats(
        &self,
        part: &Partition,
        state: &AlgState,
        next_superstep: usize,
    ) -> Option<FrontierStats> {
        let level = self.is_traversal()?;
        // classify against the same level the kernels will compare with
        // (current_level of the coming superstep), not the raw counter —
        // keeps custom level mappings consistent with their kernels.
        let probe = StepCtx {
            cycle: 0,
            superstep: next_superstep,
            threads: 1,
            instrument: false,
            direction: Direction::Push,
            balance: Balance::Vertex,
        };
        let cur = self.program.current_level(&probe);
        let levels = state.arrays[self.state_index(level)].as_i32();
        let ro = &part.csr.row_offsets;
        let mut s = FrontierStats { total_verts: part.nv as u64, ..Default::default() };
        for (v, &l) in levels.iter().take(part.nv).enumerate() {
            let deg = ro[v + 1] - ro[v];
            if l == cur {
                s.frontier_verts += 1;
                s.frontier_edges += deg;
            } else if l == INF_I32 {
                s.unexplored_verts += 1;
                s.unexplored_edges += deg;
            }
        }
        Some(s)
    }

    fn compute_cpu(&self, part: &Partition, state: &mut AlgState, ctx: &StepCtx) -> ComputeOut {
        if self.program.skip_superstep(ctx) {
            return ComputeOut { changed: true, ..Default::default() };
        }
        match self.kernels[ctx.cycle] {
            Kernel::MonotoneScatter { value, shadow } => {
                self.monotone_scatter(part, state, ctx, value, shadow)
            }
            Kernel::Traversal { level } => match ctx.direction {
                Direction::Push => self.traversal_push(part, state, ctx, level),
                Direction::Pull => self.traversal_pull(part, state, ctx, level),
            },
            Kernel::BitTraversal { next, seen, frontier, levels_base, lanes } => {
                self.bit_traversal(part, state, ctx, next, seen, frontier, levels_base, lanes)
            }
            Kernel::TraversalSigma { dist, sigma } => {
                self.traversal_sigma(part, state, ctx, dist, sigma)
            }
            Kernel::Gather { src, active } => self.gather(part, state, ctx, src, active),
            Kernel::FoldScatter { accum } => self.fold_scatter(part, state, ctx, accum),
            Kernel::NeighborIntersect { count } => {
                self.neighbor_intersect(part, state, ctx, count)
            }
            Kernel::NeighborScan { cur, prev } => {
                self.neighbor_scan(part, state, ctx, cur, prev)
            }
        }
    }

    fn cycle_done(&self, cycle: usize, next_superstep: usize, any_changed: bool) -> bool {
        if let Some(done) = self.program.cycle_done(cycle, next_superstep, any_changed) {
            return done;
        }
        if let Some(r) = self.program.meta().fixed_rounds {
            next_superstep >= r
        } else {
            !any_changed
        }
    }

    fn output_array(&self) -> usize {
        self.state_index(self.program.meta().output)
    }

    /// Bit-traversal programs additionally expose every per-lane level
    /// field, in lane order, so callers (the serving layer) can unpack one
    /// full i32 level array per batched source.
    fn extra_outputs(&self) -> Vec<usize> {
        match self.kernels.first() {
            Some(&Kernel::BitTraversal { levels_base, lanes, .. }) if self.kernels.len() == 1 => {
                (0..lanes).map(|b| self.state_index(FieldId(levels_base.0 + b))).collect()
            }
            _ => vec![],
        }
    }

    fn rebuild_scratch(&self, part: &Partition, state: &mut AlgState) {
        if let Some(level) = self.is_traversal() {
            self.build_bitmap(level, part, state);
        }
    }

    fn traversed_edges(&self, output: &StateArray, g: &CsrGraph, rounds: usize) -> u64 {
        self.program.traversed_edges(output, g, rounds)
    }
}

// ---------------------------------------------------------------------------
// Derived kernels
// ---------------------------------------------------------------------------

type Acc = (bool, u64, u64);

fn merge(a: Acc, b: Acc) -> Acc {
    (a.0 || b.0, a.1 + b.1, a.2 + b.2)
}

impl<P: VertexProgram> ProgramDriver<P> {
    /// Central balance-mode eligibility (DESIGN.md §11). The requested
    /// `ctx.balance` is granted, degraded, or ignored **here**, by kernel
    /// family, from the §9 order-sensitivity contract — never at call
    /// sites:
    ///
    /// - `MonotoneScatter`, `Traversal` (push): CAS scatters
    ///   (`fetch_min`/`fetch_max`/`fetch_or`) are idempotent, commutative
    ///   and NaN-free → any mode, including `HubSplit`.
    /// - `Traversal` (pull), `Gather`: per-vertex work must stay whole (a
    ///   pull probe early-exits; a gather's f32 sum must run in adjacency
    ///   order) → `HubSplit` degrades to `Edge`.
    /// - `TraversalSigma`, `FoldScatter`: canonical-order f32 scatters are
    ///   order-*sensitive* → forced single-chunk (see those kernels).
    /// - `NeighborIntersect`, `NeighborScan` (DESIGN.md §15): per-edge
    ///   **integer** accumulation into the owning vertex's own cell only —
    ///   order-free, but a vertex's merge/scan must stay whole →
    ///   `HubSplit` degrades to `Edge` (edge-capped plan).
    fn scatter_plan(&self, part: &Partition, ctx: &StepCtx) -> ChunkPlan {
        ChunkPlan::for_balance(ctx.balance, &part.csr.row_offsets, ctx.threads)
    }

    /// Edge-capped plan (`HubSplit` → `Edge`) over the given row offsets:
    /// pull kernels balance on in-degree (transpose rows), gather on
    /// out-degree, but neither may shard a single vertex's adjacency.
    fn edge_capped_plan(row_offsets: &[u64], ctx: &StepCtx) -> ChunkPlan {
        let b = match ctx.balance {
            Balance::HubSplit => Balance::Edge,
            b => b,
        };
        ChunkPlan::for_balance(b, row_offsets, ctx.threads)
    }

    /// Monotone relaxation (paper Fig. 20's `active` pattern): a vertex
    /// relaxes its out-edges when its value improved past the shadow —
    /// which covers both local and inbox updates without explicit flags.
    fn monotone_scatter(
        &self,
        part: &Partition,
        state: &mut AlgState,
        ctx: &StepCtx,
        value: FieldId,
        shadow: FieldId,
    ) -> ComputeOut {
        let upward = self.monotone_upward[ctx.cycle].expect("cached at construction");
        let (vi, si) = (self.state_index(value), self.state_index(shadow));
        let needs_w = self.program.meta().needs_weights;
        match self.schema[value.0].ty {
            // u64 monotone values are impossible: the value needs a
            // PushMin/PushMax channel and both reject u64 at construction.
            FieldType::U64 => unreachable!("rejected at construction"),
            FieldType::I32 => {
                let plan = self.scatter_plan(part, ctx);
                let (lo_arr, hi_arr) = split_two_mut(&mut state.arrays, vi, si);
                let cells = as_atomic_i32_cells(lo_arr.as_i32_mut());
                let shadow_cells = as_atomic_i32_cells(hi_arr.as_i32_mut());
                // Hub gate (DESIGN.md §11): with a split hub the gate runs
                // once, *before* the fan-out, so every adjacency shard
                // scatters the same settled value and the shadow advances
                // exactly once per superstep.
                let hub_val: Option<i32> = plan.hub.and_then(|h| {
                    let dv = cells[h].load(Ordering::Relaxed);
                    let sh = shadow_cells[h].load(Ordering::Relaxed);
                    if (!upward && dv >= sh) || (upward && dv <= sh) {
                        return None;
                    }
                    shadow_cells[h].store(dv, Ordering::Relaxed);
                    Some(dv)
                });
                let hub = plan.hub;
                let scatter = |v: usize,
                               dv: i32,
                               span: Option<(usize, usize)>,
                               changed: &mut bool,
                               reads: &mut u64,
                               writes: &mut u64| {
                    let ts_all = part.targets(v as u32);
                    let ws_all = if needs_w { part.weights(v as u32) } else { &[] };
                    let (ts, base) = match span {
                        Some((e0, e1)) => (&ts_all[e0..e1], e0),
                        None => (ts_all, 0),
                    };
                    for (k, &t) in ts.iter().enumerate() {
                        let w = if needs_w { ws_all[base + k] } else { 0.0 };
                        let Some(up) = self.program.edge_update(ctx, Value::I32(dv), w) else {
                            continue;
                        };
                        let msg = up.expect_i32();
                        // only min-reduce exists for i32 values
                        let old = cells[t as usize].fetch_min(msg, Ordering::Relaxed);
                        if ctx.instrument {
                            *reads += 1;
                        }
                        if msg < old {
                            *changed = true;
                            if ctx.instrument {
                                *writes += 1;
                            }
                        }
                    }
                };
                let fold = |c: &Chunk, acc: Acc| {
                    let (mut changed, mut reads, mut writes) = acc;
                    for v in c.lo..c.hi {
                        if hub == Some(v) {
                            continue;
                        }
                        let dv = cells[v].load(Ordering::Relaxed);
                        if ctx.instrument {
                            reads += 2; // value[v], shadow[v]
                        }
                        let sh = shadow_cells[v].load(Ordering::Relaxed);
                        if (!upward && dv >= sh) || (upward && dv <= sh) {
                            continue;
                        }
                        shadow_cells[v].store(dv, Ordering::Relaxed);
                        scatter(v, dv, None, &mut changed, &mut reads, &mut writes);
                    }
                    if let (Some(span), Some(h), Some(dv)) = (c.split, hub, hub_val) {
                        scatter(h, dv, Some(span), &mut changed, &mut reads, &mut writes);
                    }
                    (changed, reads, writes)
                };
                let ((changed, mut reads, writes), spread) =
                    parallel_reduce_plan(&plan, (false, 0, 0), fold, merge);
                if ctx.instrument && hub.is_some() {
                    reads += 2; // hub gate: value[h], shadow[h]
                }
                ComputeOut {
                    changed,
                    reads,
                    writes,
                    chunk_max_secs: spread.max_secs,
                    chunk_min_secs: spread.min_secs,
                }
            }
            FieldType::F32 => {
                let plan = self.scatter_plan(part, ctx);
                let (lo_arr, hi_arr) = split_two_mut(&mut state.arrays, vi, si);
                let cells = as_atomic_f32_cells(lo_arr.as_f32_mut());
                let shadow_cells = as_atomic_f32_cells(hi_arr.as_f32_mut());
                // Hub gate: see the I32 arm.
                let hub_val: Option<f32> = plan.hub.and_then(|h| {
                    let dv = f32::from_bits(cells[h].load(Ordering::Relaxed));
                    let sh = f32::from_bits(shadow_cells[h].load(Ordering::Relaxed));
                    if (!upward && dv >= sh) || (upward && dv <= sh) {
                        return None;
                    }
                    shadow_cells[h].store(dv.to_bits(), Ordering::Relaxed);
                    Some(dv)
                });
                let hub = plan.hub;
                let scatter = |v: usize,
                               dv: f32,
                               span: Option<(usize, usize)>,
                               changed: &mut bool,
                               reads: &mut u64,
                               writes: &mut u64| {
                    let ts_all = part.targets(v as u32);
                    let ws_all = if needs_w { part.weights(v as u32) } else { &[] };
                    let (ts, base) = match span {
                        Some((e0, e1)) => (&ts_all[e0..e1], e0),
                        None => (ts_all, 0),
                    };
                    for (k, &t) in ts.iter().enumerate() {
                        let w = if needs_w { ws_all[base + k] } else { 0.0 };
                        let Some(up) = self.program.edge_update(ctx, Value::F32(dv), w) else {
                            continue;
                        };
                        let msg = up.expect_f32();
                        let old = if upward {
                            atomic_max_f32(&cells[t as usize], msg)
                        } else {
                            atomic_min_f32(&cells[t as usize], msg)
                        };
                        if ctx.instrument {
                            *reads += 1;
                        }
                        if (upward && msg > old) || (!upward && msg < old) {
                            *changed = true;
                            if ctx.instrument {
                                *writes += 1;
                            }
                        }
                    }
                };
                let fold = |c: &Chunk, acc: Acc| {
                    let (mut changed, mut reads, mut writes) = acc;
                    for v in c.lo..c.hi {
                        if hub == Some(v) {
                            continue;
                        }
                        let dv = f32::from_bits(cells[v].load(Ordering::Relaxed));
                        if ctx.instrument {
                            reads += 2; // value[v], shadow[v]
                        }
                        let sh = f32::from_bits(shadow_cells[v].load(Ordering::Relaxed));
                        if (!upward && dv >= sh) || (upward && dv <= sh) {
                            continue;
                        }
                        shadow_cells[v].store(dv.to_bits(), Ordering::Relaxed);
                        scatter(v, dv, None, &mut changed, &mut reads, &mut writes);
                    }
                    if let (Some(span), Some(h), Some(dv)) = (c.split, hub, hub_val) {
                        scatter(h, dv, Some(span), &mut changed, &mut reads, &mut writes);
                    }
                    (changed, reads, writes)
                };
                let ((changed, mut reads, writes), spread) =
                    parallel_reduce_plan(&plan, (false, 0, 0), fold, merge);
                if ctx.instrument && hub.is_some() {
                    reads += 2; // hub gate: value[h], shadow[h]
                }
                ComputeOut {
                    changed,
                    reads,
                    writes,
                    chunk_max_secs: spread.max_secs,
                    chunk_min_secs: spread.min_secs,
                }
            }
        }
    }

    /// Top-down traversal (paper Figure 11): the frontier expands its
    /// out-edges; local targets go through the cache-resident visited
    /// bitmap's claim protocol, boundary targets reduce into ghost slots.
    fn traversal_push(
        &self,
        part: &Partition,
        state: &mut AlgState,
        ctx: &StepCtx,
        level: FieldId,
    ) -> ComputeOut {
        let cur = self.program.current_level(ctx);
        let up = self
            .program
            .edge_update(ctx, Value::I32(cur), 0.0)
            .expect("traversal programs must produce a frontier update")
            .expect_i32();
        let nv = part.nv;
        let li = self.state_index(level);
        let (arrays, scratch) = (&mut state.arrays, &mut state.scratch);
        let cells = as_atomic_i32_cells(arrays[li].as_i32_mut());
        // SAFETY: scratch is exclusively borrowed; AtomicU64 has the same
        // layout as u64.
        let bitmap: &[AtomicU64] = unsafe {
            std::slice::from_raw_parts(scratch.as_ptr() as *const AtomicU64, scratch.len())
        };

        let plan = self.scatter_plan(part, ctx);
        // Hub gate: the frontier test is read-only, but snapshotting it
        // once keeps every adjacency shard's decision identical (the level
        // of an already-frontier vertex cannot drop mid-superstep — all
        // writers write `cur + 1`).
        let hub = plan.hub;
        let hub_on_frontier =
            hub.is_some_and(|h| cells[h].load(Ordering::Relaxed) == cur);
        let expand = |v: usize,
                      span: Option<(usize, usize)>,
                      changed: &mut bool,
                      reads: &mut u64,
                      writes: &mut u64| {
            let ts_all = part.targets(v as u32);
            let ts = match span {
                Some((e0, e1)) => &ts_all[e0..e1],
                None => ts_all,
            };
            for &t in ts {
                let t = t as usize;
                if t < nv {
                    // visited-bitmap fast path (Fig 11 lines 6-7)
                    if ctx.instrument {
                        *reads += 1;
                    }
                    let bit = 1u64 << (t % 64);
                    if bitmap[t / 64].load(Ordering::Relaxed) & bit != 0 {
                        continue;
                    }
                    // claim the bit; the level write races benignly
                    // (all writers this superstep write the same value).
                    let prev = bitmap[t / 64].fetch_or(bit, Ordering::Relaxed);
                    if prev & bit == 0 {
                        // might already hold a level delivered by the
                        // inbox (stale bitmap) — min keeps it correct.
                        cells[t].fetch_min(up, Ordering::Relaxed);
                        if ctx.instrument {
                            *writes += 1;
                        }
                        *changed = true;
                    }
                } else {
                    // boundary edge: reduce into the ghost slot
                    let prev = cells[t].fetch_min(up, Ordering::Relaxed);
                    if ctx.instrument {
                        *reads += 1;
                    }
                    if prev > up {
                        if ctx.instrument {
                            *writes += 1;
                        }
                        *changed = true;
                    }
                }
            }
        };
        let fold = |c: &Chunk, acc: Acc| {
            let (mut changed, mut reads, mut writes) = acc;
            for v in c.lo..c.hi {
                if hub == Some(v) {
                    continue;
                }
                if ctx.instrument {
                    reads += 1; // level[v]
                }
                if cells[v].load(Ordering::Relaxed) != cur {
                    continue;
                }
                expand(v, None, &mut changed, &mut reads, &mut writes);
            }
            if let (Some(span), Some(h)) = (c.split, hub) {
                if hub_on_frontier {
                    expand(h, Some(span), &mut changed, &mut reads, &mut writes);
                }
            }
            (changed, reads, writes)
        };
        let ((changed, mut reads, writes), spread) =
            parallel_reduce_plan(&plan, (false, 0, 0), fold, merge);
        if ctx.instrument && hub.is_some() {
            reads += 1; // hub gate: level[h]
        }
        ComputeOut {
            changed,
            reads,
            writes,
            chunk_max_secs: spread.max_secs,
            chunk_min_secs: spread.min_secs,
        }
    }

    /// Bottom-up traversal (DESIGN.md §8), derived from the same program:
    ///
    /// - a **frontier** vertex relaxes only its boundary tail (ghost
    ///   slots) — its local out-neighbors are discovered from the probe
    ///   side instead;
    /// - an **unexplored** vertex probes its in-neighbors through the
    ///   transpose CSR and claims the frontier update on the first parent
    ///   at `current_level`, then stops probing (the early exit that makes
    ///   bottom-up win on dense frontiers).
    ///
    /// Discoveries, ghost-slot writes, and the `changed` vote are exactly
    /// the push kernel's — levels are identical bits either way, which is
    /// what lets the golden conformance suite compare the two
    /// byte-for-byte.
    fn traversal_pull(
        &self,
        part: &Partition,
        state: &mut AlgState,
        ctx: &StepCtx,
        level: FieldId,
    ) -> ComputeOut {
        let cur = self.program.current_level(ctx);
        let up = self
            .program
            .edge_update(ctx, Value::I32(cur), 0.0)
            .expect("traversal programs must produce a frontier update")
            .expect_i32();
        let nv = part.nv;
        let tr = part.transpose();
        let li = self.state_index(level);
        let (arrays, scratch) = (&mut state.arrays, &mut state.scratch);
        let cells = as_atomic_i32_cells(arrays[li].as_i32_mut());
        // SAFETY: scratch is exclusively borrowed; AtomicU64 has the same
        // layout as u64.
        let bitmap: &[AtomicU64] = unsafe {
            std::slice::from_raw_parts(scratch.as_ptr() as *const AtomicU64, scratch.len())
        };

        // Balance on in-degree (the probe cost); a vertex's probe must stay
        // whole (early exit + claim), so HubSplit caps at Edge.
        let plan = Self::edge_capped_plan(&tr.row_offsets[..nv + 1], ctx);
        let fold = |c: &Chunk, acc: Acc| {
            let (mut changed, mut reads, mut writes) = acc;
            for v in c.lo..c.hi {
                let lv = cells[v].load(Ordering::Relaxed);
                if ctx.instrument {
                    reads += 1; // level[v]
                }
                if lv == cur {
                    // frontier vertex: boundary edges keep push semantics
                    // (remote partitions cannot probe our levels).
                    let nl = part.csr.local_counts[v] as usize;
                    for &t in &part.targets(v as u32)[nl..] {
                        let prev = cells[t as usize].fetch_min(up, Ordering::Relaxed);
                        if ctx.instrument {
                            reads += 1;
                        }
                        if prev > up {
                            if ctx.instrument {
                                writes += 1;
                            }
                            changed = true;
                        }
                    }
                    continue;
                }
                // unexplored vertex: probe in-neighbors, early-exit on the
                // first frontier parent. The bitmap check mirrors the push
                // kernel's claim protocol: a bit-set vertex is never
                // re-discovered, a bit-unset vertex with an inbox-delivered
                // level still gets the idempotent `min`.
                //
                // Deliberate trade-off (DESIGN.md §8): an inbox-discovered
                // vertex keeps its bit unset until a local parent aligns
                // with `cur`, so sustained pull mode may re-scan its
                // transpose row across supersteps — the price of keeping
                // the `changed` vote (and therefore superstep counts)
                // bit-identical to push mode.
                let bit = 1u64 << (v % 64);
                if ctx.instrument {
                    reads += 1; // bitmap word
                }
                if bitmap[v / 64].load(Ordering::Relaxed) & bit != 0 {
                    continue;
                }
                for &u in tr.sources_of(v as u32) {
                    if ctx.instrument {
                        reads += 1; // level[u]
                    }
                    if cells[u as usize].load(Ordering::Relaxed) == cur {
                        bitmap[v / 64].fetch_or(bit, Ordering::Relaxed);
                        cells[v].fetch_min(up, Ordering::Relaxed);
                        if ctx.instrument {
                            writes += 1;
                        }
                        changed = true;
                        break;
                    }
                }
            }
            (changed, reads, writes)
        };
        let ((changed, reads, writes), spread) =
            parallel_reduce_plan(&plan, (false, 0, 0), fold, merge);
        ComputeOut {
            changed,
            reads,
            writes,
            chunk_max_secs: spread.max_secs,
            chunk_min_secs: spread.min_secs,
        }
    }

    /// Bit-parallel multi-source traversal (DESIGN.md §13). Two
    /// pool-barriered phases per superstep — `parallel_reduce_plan`
    /// returns only after every chunk finished, which IS the barrier:
    ///
    /// - **Phase A (settle)**: vertex-parallel, per-vertex writes disjoint.
    ///   `new = next[v] & !seen[v]`; a nonzero `new` folds into `seen`,
    ///   stamps `current_level` into each new bit's lane level field, and
    ///   publishes `frontier[v] = new`. `next` and `frontier` reset
    ///   otherwise, so stale words never re-expand.
    /// - **Phase B (expand)**: the requested balance plan (`HubSplit`
    ///   included — `fetch_or` is idempotent and commutative, and
    ///   `frontier` settled in Phase A, so adjacency shards all scatter
    ///   the same word). Each frontier word ORs into every out-neighbor's
    ///   `next` cell; boundary targets land in ghost slots for the PushOr
    ///   channel to carry.
    ///
    /// Every cross-vertex interaction is an OR-reduction of u64 words, so
    /// the result is bit-identical for any thread count, chunk schedule,
    /// executor, partition count, or placement.
    #[allow(clippy::too_many_arguments)]
    fn bit_traversal(
        &self,
        part: &Partition,
        state: &mut AlgState,
        ctx: &StepCtx,
        next: FieldId,
        seen: FieldId,
        frontier: FieldId,
        levels_base: FieldId,
        lanes: usize,
    ) -> ComputeOut {
        let cur = self.program.current_level(ctx);
        let fields = Fields::new(state, &self.slots);

        // Phase A: settle — vertex plan regardless of the requested
        // balance (per-vertex work is O(1); splitting a vertex would
        // double-settle it).
        let plan_a = ChunkPlan::for_balance(Balance::Vertex, &part.csr.row_offsets, ctx.threads);
        let ((a_changed, a_reads, a_writes), _) = parallel_reduce_plan(
            &plan_a,
            (false, 0u64, 0u64),
            |c: &Chunk, acc: Acc| {
                let (mut changed, mut reads, mut writes) = acc;
                for v in c.lo..c.hi {
                    let nx = fields.u64(next, v);
                    let sn = fields.u64(seen, v);
                    if ctx.instrument {
                        reads += 2;
                    }
                    let new = nx & !sn;
                    if new != 0 {
                        changed = true;
                        fields.set_u64(seen, v, sn | new);
                        let mut bits = new;
                        while bits != 0 {
                            let b = bits.trailing_zeros() as usize;
                            fields.set_i32(FieldId(levels_base.0 + b), v, cur);
                            bits &= bits - 1;
                        }
                        if ctx.instrument {
                            writes += 2 + new.count_ones() as u64;
                        }
                    }
                    fields.set_u64(frontier, v, new);
                    if nx != 0 {
                        fields.set_u64(next, v, 0);
                    }
                }
                (changed, reads, writes)
            },
            merge,
        );

        // Phase B: expand the settled frontier along out-edges.
        let plan_b = self.scatter_plan(part, ctx);
        let hub = plan_b.hub;
        // Snapshot once so every adjacency shard scatters the same word
        // (stable anyway — nobody writes `frontier` in this phase).
        let hub_word = hub.map(|h| fields.u64(frontier, h)).unwrap_or(0);
        let expand = |v: usize,
                      word: u64,
                      span: Option<(usize, usize)>,
                      changed: &mut bool,
                      reads: &mut u64,
                      writes: &mut u64| {
            let ts_all = part.targets(v as u32);
            let ts = match span {
                Some((e0, e1)) => &ts_all[e0..e1],
                None => ts_all,
            };
            for &t in ts {
                let prev = fields.or_u64(next, t as usize, word);
                if ctx.instrument {
                    *reads += 1;
                }
                if word & !prev != 0 {
                    *changed = true;
                    if ctx.instrument {
                        *writes += 1;
                    }
                }
            }
        };
        let ((b_changed, b_reads, b_writes), spread) = parallel_reduce_plan(
            &plan_b,
            (false, 0u64, 0u64),
            |c: &Chunk, acc: Acc| {
                let (mut changed, mut reads, mut writes) = acc;
                for v in c.lo..c.hi {
                    if hub == Some(v) {
                        continue;
                    }
                    let word = fields.u64(frontier, v);
                    if ctx.instrument {
                        reads += 1;
                    }
                    if word == 0 {
                        continue;
                    }
                    expand(v, word, None, &mut changed, &mut reads, &mut writes);
                }
                if let (Some(span), true) = (c.split, hub_word != 0) {
                    expand(
                        hub.expect("split implies hub"),
                        hub_word,
                        Some(span),
                        &mut changed,
                        &mut reads,
                        &mut writes,
                    );
                }
                (changed, reads, writes)
            },
            merge,
        );
        let hub_read = if ctx.instrument && hub.is_some() { 1 } else { 0 };
        ComputeOut {
            changed: a_changed || b_changed,
            reads: a_reads + b_reads + hub_read,
            writes: a_writes + b_writes,
            chunk_max_secs: spread.max_secs,
            chunk_min_secs: spread.min_secs,
        }
    }

    /// BC forward (paper Figure 18 forwardPropagation): settle levels with
    /// `min`, then accumulate σ into targets settled exactly one level
    /// deeper. Frontier scan in canonical (ascending global id) order: the
    /// scan order is observable *only* through the f32 add order into each
    /// target — canonical iteration makes that order placement-invariant
    /// (DESIGN.md §9).
    fn traversal_sigma(
        &self,
        part: &Partition,
        state: &mut AlgState,
        ctx: &StepCtx,
        dist: FieldId,
        sigma: FieldId,
    ) -> ComputeOut {
        let cur = self.program.current_level(ctx);
        let (di, si) = (self.state_index(dist), self.state_index(sigma));
        let (d_arr, s_arr) = split_two_mut(&mut state.arrays, di, si);
        let dist_cells = as_atomic_i32_cells(d_arr.as_i32_mut());
        let numsp_cells = as_atomic_f32_cells(s_arr.as_f32_mut());

        let canon = &part.canonical_order;
        let fold = |lo: usize, hi: usize, acc: Acc| {
            let (mut changed, mut reads, mut writes) = acc;
            for i in lo..hi {
                let v = canon[i] as usize;
                if ctx.instrument {
                    reads += 1;
                }
                if dist_cells[v].load(Ordering::Relaxed) != cur {
                    continue;
                }
                let v_numsp = f32::from_bits(numsp_cells[v].load(Ordering::Relaxed));
                if ctx.instrument {
                    reads += 1;
                }
                for &t in part.targets(v as u32) {
                    let t = t as usize;
                    // discover (Fig 18 lines 7-9): settle the level
                    let prev = dist_cells[t].fetch_min(cur + 1, Ordering::Relaxed);
                    if prev > cur + 1 {
                        changed = true;
                        if ctx.instrument {
                            writes += 1;
                        }
                    }
                    if ctx.instrument {
                        reads += 1;
                    }
                    // accumulate σ (Fig 18 lines 11-12): only into
                    // vertices/slots settled exactly one level deeper.
                    // Within a superstep all writers write cur+1, so the
                    // re-read is stable.
                    if dist_cells[t].load(Ordering::Relaxed) == cur + 1 {
                        atomic_add_f32(&numsp_cells[t], v_numsp);
                        changed = true;
                        if ctx.instrument {
                            writes += 1;
                        }
                    }
                }
            }
            (changed, reads, writes)
        };
        // Deterministic path (DESIGN.md §9, §11): the f32 σ-adds into a
        // shared target are order-sensitive, so the canonical sweep must
        // run start-to-finish as ONE chunk — parallel chunking (any
        // balance mode, any thread count) would make the add order
        // timing-dependent. `threads = 1` is the central eligibility
        // decision, not a call-site accident.
        let (changed, reads, writes) =
            parallel_reduce(part.nv, 1, (false, 0, 0), fold, merge);
        ComputeOut { changed, reads, writes, ..Default::default() }
    }

    /// Gather: each active vertex sums `src` over its adjacency (local CSR
    /// order, so f32 sums are placement-invariant per vertex) and applies
    /// it; then every vertex runs the publish sweep. Per-vertex writes are
    /// disjoint, so the parallel phase is bit-identical at any thread
    /// count. Always reports `changed` (gather programs terminate by
    /// fixed rounds or a custom `cycle_done`).
    fn gather(
        &self,
        part: &Partition,
        state: &mut AlgState,
        ctx: &StepCtx,
        src: FieldId,
        active: Activation,
    ) -> ComputeOut {
        let nv = part.nv;
        let lvl = self.program.current_level(ctx);
        let fields = Fields::new(state, &self.slots);
        let program = &self.program;
        // Balance on out-degree (the sum cost); a vertex's f32 sum must run
        // in adjacency order (§9), so HubSplit caps at Edge.
        let plan = Self::edge_capped_plan(&part.csr.row_offsets, ctx);
        let ((reads, writes), spread) = parallel_reduce_plan(
            &plan,
            (0u64, 0u64),
            |c: &Chunk, acc| {
                let (mut reads, mut writes) = acc;
                for v in c.lo..c.hi {
                    match active {
                        Activation::Always => {}
                        Activation::LevelEquals(f) => {
                            if ctx.instrument {
                                reads += 1;
                            }
                            if fields.i32(f, v) != lvl {
                                continue;
                            }
                        }
                    }
                    let ts = part.targets(v as u32);
                    let mut sum = 0f32;
                    for &t in ts {
                        sum += fields.f32(src, t as usize);
                    }
                    let w = program.gather_apply(ctx, v, &fields, sum);
                    if ctx.instrument {
                        reads += ts.len() as u64;
                        writes += w;
                    }
                }
                (reads, writes)
            },
            |a, b| (a.0 + b.0, a.1 + b.1),
        );
        // publish sweep (sequential: per-vertex, order-free)
        for v in 0..nv {
            program.publish(ctx, v, &fields);
        }
        let publish_writes = if ctx.instrument { nv as u64 } else { 0 };
        ComputeOut {
            changed: true,
            reads,
            writes: writes + publish_writes,
            chunk_max_secs: spread.max_secs,
            chunk_min_secs: spread.min_secs,
        }
    }

    /// Fold-then-scatter (push-mode PageRank): fold the previous round's
    /// accumulated sums (local scatters + the remote partial sums the
    /// communication phase delivered), then scatter this round's values in
    /// canonical (ascending global id) order — the f32 adds into shared
    /// accumulator cells then arrive in a placement-invariant sender order
    /// (DESIGN.md §9). The trailing fixed superstep is fold-only.
    fn fold_scatter(
        &self,
        part: &Partition,
        state: &mut AlgState,
        ctx: &StepCtx,
        accum: FieldId,
    ) -> ComputeOut {
        let nv = part.nv;
        let rounds = self
            .program
            .meta()
            .fixed_rounds
            .expect("validated at construction")
            .saturating_sub(1);
        let fields = Fields::new(state, &self.slots);
        let program = &self.program;

        let mut writes_seq = 0u64;
        if ctx.superstep > 0 {
            for v in 0..nv {
                writes_seq += program.fold(ctx, v, &fields);
            }
        }
        if ctx.superstep >= rounds {
            return ComputeOut { changed: true, writes: writes_seq, ..Default::default() };
        }

        let canon = &part.canonical_order;
        // Deterministic path (DESIGN.md §9, §11): rank mass is f32-added
        // into shared accumulator cells in canonical sender order; that
        // order is observable, so the sweep runs as ONE chunk regardless
        // of `ctx.threads` / `ctx.balance` — the driver's central
        // eligibility decision for order-sensitive kernels.
        let (reads, writes) = parallel_reduce(
            nv,
            1,
            (0u64, 0u64),
            |lo, hi, acc| {
                let (mut reads, mut writes) = acc;
                for i in lo..hi {
                    let v = canon[i] as usize;
                    let c = program.scatter_value(ctx, v, &fields);
                    if c == 0.0 {
                        continue;
                    }
                    for &t in part.targets(v as u32) {
                        fields.add_f32(accum, t as usize, c);
                    }
                    if ctx.instrument {
                        let deg = part.targets(v as u32).len() as u64;
                        reads += 1 + deg;
                        writes += deg;
                    }
                }
                (reads, writes)
            },
            |a, b| (a.0 + b.0, a.1 + b.1),
        );
        ComputeOut { changed: true, reads, writes: writes + writes_seq, ..Default::default() }
    }

    /// Neighbor intersection (DESIGN.md §15.1): per local vertex, merge
    /// the program's sorted dedup adjacency against each neighbor's,
    /// counting common vertices strictly above the neighbor
    /// ([`count_common_above`]) — each incident triangle is charged
    /// exactly once. Disjoint per-vertex u64 stores → order-free at any
    /// thread count / balance plan; a vertex's merges must stay whole, so
    /// `HubSplit` caps at `Edge` (the partition-row shards would not index
    /// the program's own adjacency anyway).
    fn neighbor_intersect(
        &self,
        part: &Partition,
        state: &mut AlgState,
        ctx: &StepCtx,
        count: FieldId,
    ) -> ComputeOut {
        let fields = Fields::new(state, &self.slots);
        let program = &self.program;
        let plan = Self::edge_capped_plan(&part.csr.row_offsets, ctx);
        let ((reads, writes), spread) = parallel_reduce_plan(
            &plan,
            (0u64, 0u64),
            |c: &Chunk, acc| {
                let (mut reads, mut writes) = acc;
                for v in c.lo..c.hi {
                    let g = part.local_to_global[v];
                    let adj = program.neighbors(g);
                    let mut cnt = 0u64;
                    for &w in adj {
                        cnt += count_common_above(adj, program.neighbors(w), w);
                    }
                    fields.set_u64(count, v, cnt);
                    if ctx.instrument {
                        // adjacency cells fetched; merge comparisons are
                        // register traffic, not state memory
                        reads += 2 * adj.len() as u64;
                        writes += 1;
                    }
                }
                (reads, writes)
            },
            |a, b| (a.0 + b.0, a.1 + b.1),
        );
        // single fixed superstep: termination comes from fixed_rounds(1)
        ComputeOut {
            changed: true,
            reads,
            writes,
            chunk_max_secs: spread.max_secs,
            chunk_min_secs: spread.min_secs,
        }
    }

    /// Synchronous neighborhood scan (DESIGN.md §15.2) in two
    /// pool-barriered phases: Phase A snapshots `cur → prev` for every
    /// local (vertex plan — O(1)/vertex); Phase B (edge-capped plan)
    /// computes each vertex's next value from neighbors' previous-round
    /// values through a [`NeighborView`] and votes changed only on
    /// difference. Snapshot reads + own-cell i32 writes → order-free.
    fn neighbor_scan(
        &self,
        part: &Partition,
        state: &mut AlgState,
        ctx: &StepCtx,
        cur: FieldId,
        prev: FieldId,
    ) -> ComputeOut {
        let nv = part.nv;
        let fields = Fields::new(state, &self.slots);
        let program = &self.program;

        let plan_a = ChunkPlan::for_balance(Balance::Vertex, &part.csr.row_offsets, ctx.threads);
        let _ = parallel_reduce_plan(
            &plan_a,
            (),
            |c: &Chunk, ()| {
                for v in c.lo..c.hi {
                    fields.set_i32(prev, v, fields.i32(cur, v));
                }
            },
            |(), ()| (),
        );

        let plan_b = Self::edge_capped_plan(&part.csr.row_offsets, ctx);
        let ((changed, reads, writes), spread) = parallel_reduce_plan(
            &plan_b,
            (false, 0u64, 0u64),
            |c: &Chunk, acc: Acc| {
                let (mut changed, mut reads, mut writes) = acc;
                for v in c.lo..c.hi {
                    let view = NeighborView {
                        targets: part.targets(v as u32),
                        fields: &fields,
                        cur,
                        prev,
                        nv,
                    };
                    let old = fields.i32(cur, v);
                    let new = program.scan_vertex(ctx, v, &fields, &view);
                    if ctx.instrument {
                        reads += 1 + view.len() as u64;
                    }
                    if new != old {
                        fields.set_i32(cur, v, new);
                        changed = true;
                        if ctx.instrument {
                            writes += 1;
                        }
                    }
                }
                (changed, reads, writes)
            },
            merge,
        );
        ComputeOut {
            changed,
            reads: reads + if ctx.instrument { nv as u64 } else { 0 },
            writes: writes + if ctx.instrument { nv as u64 } else { 0 },
            chunk_max_secs: spread.max_secs,
            chunk_min_secs: spread.min_secs,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Minimal well-formed program for driver-level tests: single i32
    /// min-field monotone propagation (a degenerate CC).
    struct MiniProgram;

    const VAL: FieldId = FieldId(0);
    const SHADOW: FieldId = FieldId(1);

    impl VertexProgram for MiniProgram {
        fn meta(&self) -> ProgramMeta {
            ProgramMeta {
                name: "mini",
                needs_weights: false,
                undirected: false,
                reversed: false,
                fixed_rounds: None,
                output: VAL,
            }
        }
        fn schema(&self) -> Vec<FieldSpec> {
            vec![
                FieldSpec::i32("val", Role::Device, INF_I32),
                FieldSpec::i32("shadow", Role::Host, INF_I32),
            ]
        }
        fn plan(&self, _cycle: usize) -> CyclePlan {
            CyclePlan {
                kernel: Kernel::MonotoneScatter { value: VAL, shadow: SHADOW },
                comm: vec![CommDecl::PushMin(VAL)],
                device: None,
                accel: AccelSpec { name: "mini", n_si32: 0, n_sf32: 0 },
            }
        }
        fn init_vertex(&self, g: u32, row: &mut InitRow<'_>) {
            row.set_i32(VAL, g as i32);
        }
        fn edge_update(&self, _ctx: &StepCtx, src: Value, _w: f32) -> Option<Value> {
            Some(src)
        }
    }

    #[test]
    fn valid_program_constructs_and_derives_spec() {
        let d = ProgramDriver::build(MiniProgram).unwrap();
        assert_eq!(d.spec().name, "mini");
        assert!(!d.supports_pull());
        let ops = d.channels(0);
        assert_eq!(ops.len(), 1);
        assert!(!ops[0].order_sensitive());
        let prog = Algorithm::program(&d, 0);
        assert_eq!(prog.arrays, vec![0], "host shadow must not ship");
        assert_eq!(prog.name, "mini");
        assert_eq!(d.output_array(), 0);
    }

    #[test]
    fn mini_program_propagates_minima_end_to_end() {
        use crate::engine::{self, EngineConfig};
        use crate::graph::{CsrGraph, EdgeList};
        let mut el = EdgeList::new(4);
        el.push(3, 2);
        el.push(2, 1);
        el.push(1, 0);
        let g = CsrGraph::from_edge_list(&el);
        let mut d = ProgramDriver::build(MiniProgram).unwrap();
        let r = engine::run(&g, &mut d, &EngineConfig::host_only(1)).unwrap();
        // edges point toward smaller ids, so every delivered label is
        // larger than the receiver's own: the min-propagation quiesces
        // after one superstep with each vertex keeping its own id
        assert_eq!(r.output.as_i32(), &[0, 1, 2, 3]);
    }

    /// A program whose pad is not the channel's reduce identity.
    struct BadPad;
    impl VertexProgram for BadPad {
        fn meta(&self) -> ProgramMeta {
            ProgramMeta {
                name: "badpad",
                needs_weights: false,
                undirected: false,
                reversed: false,
                fixed_rounds: None,
                output: FieldId(0),
            }
        }
        fn schema(&self) -> Vec<FieldSpec> {
            vec![
                FieldSpec::i32("val", Role::Device, 0), // must be INF_I32
                FieldSpec::i32("shadow", Role::Host, INF_I32),
            ]
        }
        fn plan(&self, _c: usize) -> CyclePlan {
            CyclePlan {
                kernel: Kernel::MonotoneScatter { value: FieldId(0), shadow: FieldId(1) },
                comm: vec![CommDecl::PushMin(FieldId(0))],
                device: None,
                accel: AccelSpec { name: "badpad", n_si32: 0, n_sf32: 0 },
            }
        }
        fn init_vertex(&self, _g: u32, _row: &mut InitRow<'_>) {}
    }

    #[test]
    fn pad_identity_mismatch_is_a_typed_error() {
        let err = ProgramDriver::build(BadPad).map(|_| ()).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("reduce identity"), "{msg}");
        assert!(msg.contains("val"), "{msg}");
    }

    /// Comm channel on an aux (constant) field.
    struct AuxComm;
    impl VertexProgram for AuxComm {
        fn meta(&self) -> ProgramMeta {
            ProgramMeta {
                name: "auxcomm",
                needs_weights: false,
                undirected: false,
                reversed: false,
                fixed_rounds: Some(1),
                output: FieldId(0),
            }
        }
        fn schema(&self) -> Vec<FieldSpec> {
            vec![
                FieldSpec::f32("rank", Role::Device, 0.0),
                FieldSpec::f32("inv", Role::Aux, 0.0),
            ]
        }
        fn plan(&self, _c: usize) -> CyclePlan {
            CyclePlan {
                kernel: Kernel::Gather { src: FieldId(0), active: Activation::Always },
                comm: vec![CommDecl::Pull(FieldId(1))], // aux on a channel!
                device: None,
                accel: AccelSpec { name: "auxcomm", n_si32: 0, n_sf32: 0 },
            }
        }
        fn init_vertex(&self, _g: u32, _row: &mut InitRow<'_>) {}
    }

    #[test]
    fn aux_field_on_channel_is_a_typed_error() {
        let err = ProgramDriver::build(AuxComm).map(|_| ()).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("aux"), "{msg}");
    }

    /// f32 add channel on an i32 field (the old `as_f32` panic scenario).
    struct DtypeClash;
    impl VertexProgram for DtypeClash {
        fn meta(&self) -> ProgramMeta {
            ProgramMeta {
                name: "clash",
                needs_weights: false,
                undirected: false,
                reversed: false,
                fixed_rounds: Some(2),
                output: FieldId(0),
            }
        }
        fn schema(&self) -> Vec<FieldSpec> {
            vec![FieldSpec::i32("acc", Role::Device, 0)]
        }
        fn plan(&self, _c: usize) -> CyclePlan {
            CyclePlan {
                kernel: Kernel::FoldScatter { accum: FieldId(0) },
                comm: vec![CommDecl::PushAdd(FieldId(0))],
                device: None,
                accel: AccelSpec { name: "clash", n_si32: 0, n_sf32: 0 },
            }
        }
        fn init_vertex(&self, _g: u32, _row: &mut InitRow<'_>) {}
    }

    #[test]
    fn add_channel_on_i32_field_is_a_typed_error() {
        let err = ProgramDriver::build(DtypeClash).map(|_| ()).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("f32"), "{msg}");
        assert!(msg.contains("acc"), "{msg}");
    }

    #[test]
    fn out_of_range_field_is_a_typed_error() {
        struct OutOfRange;
        impl VertexProgram for OutOfRange {
            fn meta(&self) -> ProgramMeta {
                ProgramMeta {
                    name: "oor",
                    needs_weights: false,
                    undirected: false,
                    reversed: false,
                    fixed_rounds: None,
                    output: FieldId(7),
                }
            }
            fn schema(&self) -> Vec<FieldSpec> {
                vec![
                    FieldSpec::i32("val", Role::Device, INF_I32),
                    FieldSpec::i32("shadow", Role::Host, INF_I32),
                ]
            }
            fn plan(&self, _c: usize) -> CyclePlan {
                CyclePlan {
                    kernel: Kernel::MonotoneScatter { value: FieldId(0), shadow: FieldId(1) },
                    comm: vec![CommDecl::PushMin(FieldId(0))],
                    device: None,
                    accel: AccelSpec { name: "oor", n_si32: 0, n_sf32: 0 },
                }
            }
            fn init_vertex(&self, _g: u32, _row: &mut InitRow<'_>) {}
        }
        let err = ProgramDriver::build(OutOfRange).map(|_| ()).unwrap_err();
        assert!(format!("{err:#}").contains("2 fields"), "{err:#}");
    }

    #[test]
    fn count_common_above_is_an_oriented_merge() {
        let a = [1u32, 3, 5, 7, 9];
        let b = [3u32, 4, 5, 9, 11];
        assert_eq!(count_common_above(&a, &b, 0), 3); // 3, 5, 9
        assert_eq!(count_common_above(&a, &b, 3), 2); // 5, 9
        assert_eq!(count_common_above(&a, &b, 5), 1); // 9
        assert_eq!(count_common_above(&a, &b, 9), 0);
        assert_eq!(count_common_above(&a, &[], 0), 0);
        assert_eq!(count_common_above(&[], &b, 0), 0);
    }

    /// Minimal intersect program: undirected dedup adjacency captured in
    /// `prepare`, u64 triangle counts.
    struct MiniIntersect {
        offsets: Vec<usize>,
        nbrs: Vec<u32>,
        comm: Vec<CommDecl>,
        fixed_rounds: Option<usize>,
    }
    impl MiniIntersect {
        fn well_formed() -> MiniIntersect {
            MiniIntersect {
                offsets: vec![0],
                nbrs: Vec::new(),
                comm: vec![],
                fixed_rounds: Some(1),
            }
        }
    }
    impl VertexProgram for MiniIntersect {
        fn meta(&self) -> ProgramMeta {
            ProgramMeta {
                name: "mini_intersect",
                needs_weights: false,
                undirected: false,
                reversed: false,
                fixed_rounds: self.fixed_rounds,
                output: FieldId(0),
            }
        }
        fn schema(&self) -> Vec<FieldSpec> {
            vec![FieldSpec::u64("tri", Role::Host, 0)]
        }
        fn plan(&self, _c: usize) -> CyclePlan {
            CyclePlan {
                kernel: Kernel::NeighborIntersect { count: FieldId(0) },
                comm: self.comm.clone(),
                device: None,
                accel: AccelSpec { name: "mini_intersect", n_si32: 0, n_sf32: 0 },
            }
        }
        fn prepare(&mut self, original: &crate::graph::CsrGraph, _p: &crate::graph::CsrGraph) {
            let n = original.vertex_count;
            let mut adj: Vec<Vec<u32>> = vec![Vec::new(); n];
            for v in 0..n as u32 {
                for &t in original.neighbors(v) {
                    if t != v {
                        adj[v as usize].push(t);
                        adj[t as usize].push(v);
                    }
                }
            }
            self.offsets = vec![0];
            self.nbrs.clear();
            for mut a in adj {
                a.sort_unstable();
                a.dedup();
                self.nbrs.extend_from_slice(&a);
                self.offsets.push(self.nbrs.len());
            }
        }
        fn init_vertex(&self, _g: u32, _row: &mut InitRow<'_>) {}
        fn neighbors(&self, g: u32) -> &[u32] {
            &self.nbrs[self.offsets[g as usize]..self.offsets[g as usize + 1]]
        }
    }

    #[test]
    fn neighbor_intersect_counts_triangles_end_to_end() {
        use crate::engine::{self, EngineConfig};
        use crate::graph::{CsrGraph, EdgeList};
        use crate::partition::Strategy;
        // triangle 0-1-2 plus a sink 3
        let mut el = EdgeList::new(4);
        el.push(0, 1);
        el.push(1, 2);
        el.push(0, 2);
        el.push(2, 3);
        let g = CsrGraph::from_edge_list(&el);
        let mut d = ProgramDriver::build(MiniIntersect::well_formed()).unwrap();
        let r = engine::run(&g, &mut d, &EngineConfig::host_only(1)).unwrap();
        assert_eq!(r.output.as_u64(), &[1, 1, 1, 0]);
        // partitioned: the program's global adjacency makes merges exact
        let mut d2 = ProgramDriver::build(MiniIntersect::well_formed()).unwrap();
        let cfg = EngineConfig::cpu_partitions(&[0.5, 0.5], Strategy::Rand);
        let r2 = engine::run(&g, &mut d2, &cfg).unwrap();
        assert_eq!(r2.output.as_u64(), &[1, 1, 1, 0]);
    }

    #[test]
    fn neighbor_intersect_rejects_comm_channels() {
        let mut p = MiniIntersect::well_formed();
        p.comm = vec![CommDecl::Pull(FieldId(0))];
        let err = ProgramDriver::build(p).map(|_| ()).unwrap_err();
        // the u64-on-Pull check fires first; both are typed construction errors
        let msg = format!("{err:#}");
        assert!(msg.contains("u64") || msg.contains("no communication"), "{msg}");
    }

    #[test]
    fn neighbor_intersect_requires_single_fixed_round() {
        let mut p = MiniIntersect::well_formed();
        p.fixed_rounds = None;
        let err = ProgramDriver::build(p).map(|_| ()).unwrap_err();
        assert!(format!("{err:#}").contains("fixed_rounds"), "{err:#}");
    }

    /// Minimal scan program: min-label diffusion over out-neighbors.
    struct MiniScan {
        comm: Vec<CommDecl>,
    }
    const SCUR: FieldId = FieldId(0);
    const SPREV: FieldId = FieldId(1);
    impl VertexProgram for MiniScan {
        fn meta(&self) -> ProgramMeta {
            ProgramMeta {
                name: "mini_scan",
                needs_weights: false,
                undirected: false,
                reversed: false,
                fixed_rounds: None,
                output: SCUR,
            }
        }
        fn schema(&self) -> Vec<FieldSpec> {
            vec![
                FieldSpec::i32("cur", Role::Host, 0),
                FieldSpec::i32("prev", Role::Host, 0),
            ]
        }
        fn plan(&self, _c: usize) -> CyclePlan {
            CyclePlan {
                kernel: Kernel::NeighborScan { cur: SCUR, prev: SPREV },
                comm: self.comm.clone(),
                device: None,
                accel: AccelSpec { name: "mini_scan", n_si32: 0, n_sf32: 0 },
            }
        }
        fn init_vertex(&self, g: u32, row: &mut InitRow<'_>) {
            row.set_i32(SCUR, g as i32);
        }
        fn scan_vertex(
            &self,
            _ctx: &StepCtx,
            v: usize,
            f: &Fields<'_>,
            nb: &NeighborView<'_, '_>,
        ) -> i32 {
            let mut m = f.i32(SPREV, v);
            for k in 0..nb.len() {
                m = m.min(nb.value(k));
            }
            m
        }
    }

    #[test]
    fn neighbor_scan_diffuses_minima_end_to_end() {
        use crate::engine::{self, EngineConfig};
        use crate::graph::{CsrGraph, EdgeList};
        use crate::partition::Strategy;
        // edges point toward smaller ids: each vertex adopts its
        // out-neighbor's previous label, one hop per superstep
        let mut el = EdgeList::new(4);
        el.push(3, 2);
        el.push(2, 1);
        el.push(1, 0);
        let g = CsrGraph::from_edge_list(&el);
        let mut d = ProgramDriver::build(MiniScan { comm: vec![CommDecl::Pull(SCUR)] }).unwrap();
        let r = engine::run(&g, &mut d, &EngineConfig::host_only(1)).unwrap();
        assert_eq!(r.output.as_i32(), &[0, 0, 0, 0]);
        // quiescence: 3 diffusion supersteps + 1 no-change superstep
        assert_eq!(r.supersteps, 4);
        // partitioned: ghost slots of `cur` carry remote prev-round values
        for shares in [[0.5, 0.5], [0.3, 0.7]] {
            let mut d2 =
                ProgramDriver::build(MiniScan { comm: vec![CommDecl::Pull(SCUR)] }).unwrap();
            let cfg = EngineConfig::cpu_partitions(&shares, Strategy::Rand);
            let r2 = engine::run(&g, &mut d2, &cfg).unwrap();
            assert_eq!(r2.output.as_i32(), &[0, 0, 0, 0]);
        }
    }

    #[test]
    fn neighbor_scan_requires_pull_channel_on_cur() {
        let err = ProgramDriver::build(MiniScan { comm: vec![] }).map(|_| ()).unwrap_err();
        assert!(format!("{err:#}").contains("Pull channel"), "{err:#}");
    }
}
