//! Bit-parallel multi-source BFS (MS-BFS) on the typed vertex-program
//! surface (DESIGN.md §13; Then et al. 2014's lane-packing idea on the
//! engine's BSP substrate).
//!
//! Up to 64 BFS instances run as **bit lanes of shared u64 words**: one
//! `next`/`seen`/`frontier` word per vertex plus one i32 level field per
//! lane. A single graph sweep advances every lane at once — the frontier
//! union is one OR, the settle test one AND-NOT — so b batched traversals
//! cost one traversal's memory traffic instead of b. Every cross-vertex
//! interaction is an OR-reduction ([`CommDecl::PushOr`], which is
//! order-free), so batched results are bit-identical to solo runs in every
//! engine configuration; the serving layer (`serve/`) leans on exactly
//! that equivalence to auto-batch queued reachability/BFS queries.
//!
//! The program declares the three words, the per-lane level fields, and
//! the source→lane assignment; the [`Kernel::BitTraversal`] family in the
//! driver owns the two-phase race-free superstep.

use super::program::{
    AccelSpec, CommDecl, CyclePlan, FieldId, FieldSpec, InitRow, Kernel, ProgramDriver,
    ProgramMeta, Role, VertexProgram,
};
use super::INF_I32;
use crate::engine::state::StateArray;
use crate::graph::CsrGraph;
use anyhow::{bail, Result};

/// Maximum batch width: one bit lane per u64 bit.
pub const MAX_LANES: usize = 64;

const NEXT: FieldId = FieldId(0);
const SEEN: FieldId = FieldId(1);
const FRONTIER: FieldId = FieldId(2);
/// Lane level fields occupy the contiguous schema range
/// `[LEVELS_BASE, LEVELS_BASE + lanes)` — the layout [`Kernel::BitTraversal`]
/// encodes as `levels_base`/`lanes` (keeps `Kernel: Copy`).
const LEVELS_BASE: FieldId = FieldId(3);

/// Static lane field names ([`FieldSpec::name`] is `&'static str`).
static LANE_NAMES: [&str; MAX_LANES] = [
    "lane00", "lane01", "lane02", "lane03", "lane04", "lane05", "lane06", "lane07", "lane08",
    "lane09", "lane10", "lane11", "lane12", "lane13", "lane14", "lane15", "lane16", "lane17",
    "lane18", "lane19", "lane20", "lane21", "lane22", "lane23", "lane24", "lane25", "lane26",
    "lane27", "lane28", "lane29", "lane30", "lane31", "lane32", "lane33", "lane34", "lane35",
    "lane36", "lane37", "lane38", "lane39", "lane40", "lane41", "lane42", "lane43", "lane44",
    "lane45", "lane46", "lane47", "lane48", "lane49", "lane50", "lane51", "lane52", "lane53",
    "lane54", "lane55", "lane56", "lane57", "lane58", "lane59", "lane60", "lane61", "lane62",
    "lane63",
];

/// Multi-source BFS: lane `b` runs BFS from `sources[b]`. Repeated
/// sources are legal — the vertex simply carries several bits from
/// superstep 0, and the repeated lanes stay bit-identical forever.
pub struct MsBfsProgram {
    pub sources: Vec<u32>,
}

impl MsBfsProgram {
    fn lanes(&self) -> usize {
        self.sources.len()
    }
}

impl VertexProgram for MsBfsProgram {
    fn meta(&self) -> ProgramMeta {
        ProgramMeta {
            name: "msbfs",
            needs_weights: false,
            undirected: false,
            reversed: false,
            fixed_rounds: None,
            // the seen word is the per-vertex reachability mask; lane
            // levels ride along via `extra_outputs`
            output: SEEN,
        }
    }

    /// All fields are [`Role::Host`]: u64 words never cross the PJRT
    /// boundary, and the lane levels stay host-side with them (one
    /// program, one placement story).
    fn schema(&self) -> Vec<FieldSpec> {
        let mut s = vec![
            FieldSpec::u64("next", Role::Host, 0),
            FieldSpec::u64("seen", Role::Host, 0),
            FieldSpec::u64("frontier", Role::Host, 0),
        ];
        for b in 0..self.lanes() {
            s.push(FieldSpec::i32(LANE_NAMES[b], Role::Host, INF_I32));
        }
        s
    }

    fn plan(&self, _cycle: usize) -> CyclePlan {
        CyclePlan {
            kernel: Kernel::BitTraversal {
                next: NEXT,
                seen: SEEN,
                frontier: FRONTIER,
                levels_base: LEVELS_BASE,
                lanes: self.lanes(),
            },
            comm: vec![CommDecl::PushOr(NEXT)],
            // not lowered for the accelerator: an accelerator placement
            // fails at manifest lookup with an actionable message
            accel: AccelSpec { name: "msbfs", n_si32: 0, n_sf32: 0 },
            device: None,
        }
    }

    /// Sources enter through `next`: Phase A of superstep 0 settles them
    /// at level 0, exactly like a delivered frontier bit.
    fn init_vertex(&self, global_id: u32, row: &mut InitRow<'_>) {
        let mut mask = 0u64;
        for (b, &s) in self.sources.iter().enumerate() {
            if s == global_id {
                mask |= 1 << b;
            }
        }
        if mask != 0 {
            row.set_u64(NEXT, mask);
        }
    }

    /// Σ over vertices of out-degree × |lanes that reached the vertex| —
    /// each lane is a full BFS, so edges count once per lane that
    /// traversed them (paper §5 accounting, summed over the batch).
    fn traversed_edges(&self, output: &StateArray, g: &CsrGraph, _rounds: usize) -> u64 {
        output
            .as_u64()
            .iter()
            .enumerate()
            .map(|(v, &w)| g.out_degree(v as u32) * w.count_ones() as u64)
            .sum()
    }
}

/// The engine-facing multi-source BFS algorithm.
pub type MsBfs = ProgramDriver<MsBfsProgram>;

impl MsBfs {
    /// Batch `sources` (1..=64, repeats allowed) into one bit-parallel
    /// traversal; lane `b` computes BFS from `sources[b]`.
    pub fn new(sources: &[u32]) -> Result<MsBfs> {
        if sources.is_empty() || sources.len() > MAX_LANES {
            bail!(
                "multi-source BFS batches 1..={MAX_LANES} sources per run, got {}",
                sources.len()
            );
        }
        ProgramDriver::build(MsBfsProgram { sources: sources.to_vec() })
    }

    /// Batch width of this instance.
    pub fn lane_count(&self) -> usize {
        self.inner().lanes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::alg::{bfs::Bfs, Algorithm};
    use crate::engine::{self, EngineConfig, ExecMode};
    use crate::graph::generator::{rmat, RmatParams};
    use crate::graph::{CsrGraph, EdgeList};
    use crate::partition::Strategy;

    fn chain(n: usize) -> CsrGraph {
        let mut el = EdgeList::new(n);
        for i in 0..n - 1 {
            el.push(i as u32, i as u32 + 1);
        }
        CsrGraph::from_edge_list(&el)
    }

    #[test]
    fn batch_width_is_validated() {
        assert!(MsBfs::new(&[]).is_err());
        assert!(MsBfs::new(&vec![0; 65]).is_err());
        assert_eq!(MsBfs::new(&vec![0; 64]).unwrap().lane_count(), 64);
    }

    #[test]
    fn driver_derives_the_msbfs_contract() {
        let alg = MsBfs::new(&[0, 1, 2]).unwrap();
        assert!(!alg.supports_pull(), "bit traversal is push-only");
        let ops = alg.channels(0);
        assert_eq!(ops.len(), 1);
        assert!(
            !ops[0].order_sensitive(),
            "OR lanes are order-free — pipelining must stay eligible"
        );
        let spec = Algorithm::program(&alg, 0);
        assert!(spec.arrays.is_empty(), "host-only program ships nothing");
        assert_eq!(alg.extra_outputs().len(), 3, "one level array per lane");
    }

    #[test]
    fn two_lane_chain_levels_and_masks() {
        let g = chain(6);
        let mut alg = MsBfs::new(&[0, 3]).unwrap();
        let r = engine::run(&g, &mut alg, &EngineConfig::host_only(1)).unwrap();
        // lane 0 reaches everything, lane 1 only vertices >= 3
        let seen = r.output.as_u64();
        assert_eq!(seen, &[0b01, 0b01, 0b01, 0b11, 0b11, 0b11]);
        let lane0 = r.extra[0].as_i32();
        let lane1 = r.extra[1].as_i32();
        for v in 0..6 {
            assert_eq!(lane0[v], v as i32);
            let want = if v >= 3 { v as i32 - 3 } else { INF_I32 };
            assert_eq!(lane1[v], want);
        }
    }

    #[test]
    fn repeated_sources_share_lane_results() {
        let g = chain(5);
        let mut alg = MsBfs::new(&[2, 2]).unwrap();
        let r = engine::run(&g, &mut alg, &EngineConfig::host_only(1)).unwrap();
        assert_eq!(r.extra[0].as_i32(), r.extra[1].as_i32());
    }

    /// Each lane of a batched run must equal the corresponding solo BFS
    /// bit-for-bit — the contract the serving layer's auto-batching
    /// depends on. Checked across partitioning and both executors.
    #[test]
    fn lanes_match_solo_bfs_across_configs() {
        let g = CsrGraph::from_edge_list(&rmat(&RmatParams::paper(8, 5)));
        let sources = [0u32, 3, 17, 42, 100, 200];
        let solo: Vec<Vec<i32>> = sources
            .iter()
            .map(|&s| {
                let mut b = Bfs::new(s);
                engine::run(&g, &mut b, &EngineConfig::host_only(1))
                    .unwrap()
                    .output
                    .as_i32()
                    .to_vec()
            })
            .collect();
        let configs = [
            EngineConfig::host_only(1),
            EngineConfig::host_only(3),
            EngineConfig::cpu_partitions(&[0.5, 0.5], Strategy::Rand),
            EngineConfig::cpu_partitions(&[0.3, 0.7], Strategy::High)
                .with_mode(ExecMode::Pipelined),
        ];
        for cfg in configs {
            let mut alg = MsBfs::new(&sources).unwrap();
            let r = engine::run(&g, &mut alg, &cfg).unwrap();
            for (b, want) in solo.iter().enumerate() {
                assert_eq!(
                    r.extra[b].as_i32(),
                    want.as_slice(),
                    "lane {b} diverged from solo BFS"
                );
            }
            // seen mask must agree with the lane levels
            let seen = r.output.as_u64();
            for (v, &w) in seen.iter().enumerate() {
                for (b, want) in solo.iter().enumerate() {
                    assert_eq!(w >> b & 1 == 1, want[v] != INF_I32, "mask/level clash at {v}");
                }
            }
        }
    }

    #[test]
    fn traversed_edges_counts_per_lane() {
        let g = chain(4);
        let mut alg = MsBfs::new(&[0, 2]).unwrap();
        let r = engine::run(&g, &mut alg, &EngineConfig::host_only(1)).unwrap();
        // lane 0 visits all 4 vertices (deg 1,1,1,0), lane 1 visits {2,3}
        // (deg 1,0): 3 + 1 edges
        let te = alg.traversed_edges(&r.output, &g, r.supersteps);
        assert_eq!(te, 4);
    }
}
