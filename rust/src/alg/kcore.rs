//! k-core decomposition — synchronous batch peeling on the
//! [`Kernel::NeighborScan`] family (DESIGN.md §15).
//!
//! Coreness of `v` = the largest `k` such that `v` survives in the
//! `k`-core (the maximal subgraph where every vertex has degree ≥ `k`).
//! The program peels in rounds over the engine's **undirected view**
//! (the doubled multigraph — parallel edges and self-loops count with
//! multiplicity, exactly like CC's view): at the current threshold `k`,
//! every still-alive vertex counts its alive neighbors in the previous
//! round's snapshot; a count ≤ `k` assigns coreness `k` and kills the
//! vertex. A round that kills nobody either terminates (no one left
//! alive) or **escalates** `k` by one in `cycle_done` and reactivates
//! the peel — the quiescence override is the reactivation mechanism.
//! Batch-synchronous peeling removes any subset of sub-threshold
//! vertices per round, which converges to the same unique k-core as
//! sequential peeling; determinism comes from the snapshot reads and
//! own-cell integer writes (§9 order-free), so every executor,
//! placement, and balance plan is bit-identical. CPU-only ("kcore" is
//! not in the AOT manifest).

use super::program::{
    AccelSpec, CommDecl, CyclePlan, FieldId, Fields, FieldSpec, InitRow, Kernel, NeighborView,
    ProgramDriver, ProgramMeta, Role, VertexProgram,
};
use super::{StepCtx, INF_I32};
use crate::engine::state::{AlgState, StateArray};
use crate::graph::CsrGraph;
use crate::partition::PartitionedGraph;
use std::sync::atomic::{AtomicI32, AtomicU32, Ordering};

/// Alive marker: a vertex whose `core` is still `INF_I32` has not been
/// peeled yet.
const CORE: FieldId = FieldId(0);
const CORE_PREV: FieldId = FieldId(1);

/// k-core decomposition as a vertex program.
pub struct KCoreProgram {
    /// Global vertex count (set in `prepare`).
    n_global: u32,
    /// Current peeling threshold. Escalated in `cycle_done` when a round
    /// kills nobody — interior mutability because the hook takes `&self`
    /// (it runs once per superstep, single-threaded, after the barrier).
    k: AtomicI32,
    /// Vertices still alive, decremented once per death in `scan_vertex`
    /// (each real vertex is local to exactly one partition).
    remaining: AtomicU32,
}

impl VertexProgram for KCoreProgram {
    fn meta(&self) -> ProgramMeta {
        ProgramMeta {
            name: "kcore",
            needs_weights: false,
            undirected: true,
            reversed: false,
            fixed_rounds: None,
            output: CORE,
        }
    }

    fn schema(&self) -> Vec<FieldSpec> {
        vec![
            FieldSpec::i32("core", Role::Host, INF_I32),
            FieldSpec::i32("core_prev", Role::Host, INF_I32),
        ]
    }

    fn plan(&self, _cycle: usize) -> CyclePlan {
        CyclePlan {
            kernel: Kernel::NeighborScan { cur: CORE, prev: CORE_PREV },
            comm: vec![CommDecl::Pull(CORE)],
            device: None,
            accel: AccelSpec { name: "kcore", n_si32: 0, n_sf32: 0 },
        }
    }

    fn prepare(&mut self, original: &CsrGraph, _prepared: &CsrGraph) {
        self.n_global = original.vertex_count as u32;
    }

    fn begin_cycle(&mut self, _cycle: usize, _pg: &PartitionedGraph, _states: &mut [AlgState]) {
        self.k.store(0, Ordering::Relaxed);
        self.remaining.store(self.n_global, Ordering::Relaxed);
    }

    fn init_vertex(&self, _global_id: u32, _row: &mut InitRow<'_>) {}

    fn scan_vertex(&self, _ctx: &StepCtx, v: usize, f: &Fields<'_>, nb: &NeighborView<'_, '_>) -> i32 {
        let own = f.i32(CORE_PREV, v);
        if own != INF_I32 {
            return own; // already peeled: coreness is settled
        }
        let k = self.k.load(Ordering::Relaxed);
        let mut alive = 0i64;
        for i in 0..nb.len() {
            if nb.value(i) == INF_I32 {
                alive += 1;
            }
        }
        if alive <= k as i64 {
            self.remaining.fetch_sub(1, Ordering::Relaxed);
            k
        } else {
            INF_I32
        }
    }

    /// The reactivation mechanism: a changed round keeps peeling at the
    /// same threshold; a quiet round with survivors escalates `k` and
    /// continues; a quiet round with no survivors terminates.
    fn cycle_done(&self, _cycle: usize, _next_superstep: usize, any_changed: bool) -> Option<bool> {
        if any_changed {
            return Some(false);
        }
        if self.remaining.load(Ordering::Relaxed) == 0 {
            return Some(true);
        }
        self.k.fetch_add(1, Ordering::Relaxed);
        Some(false)
    }

    /// Each peel round scans every adjacency cell of the doubled view.
    fn traversed_edges(&self, _output: &StateArray, g: &CsrGraph, rounds: usize) -> u64 {
        2 * g.edge_count() as u64 * rounds.max(1) as u64
    }
}

/// The engine-facing k-core algorithm.
pub type KCore = ProgramDriver<KCoreProgram>;

impl KCore {
    #[allow(clippy::new_without_default)]
    pub fn new() -> KCore {
        ProgramDriver::build(KCoreProgram {
            n_global: 0,
            k: AtomicI32::new(0),
            remaining: AtomicU32::new(0),
        })
        .expect("static schema is valid")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::{self, EngineConfig};
    use crate::graph::EdgeList;
    use crate::partition::Strategy;

    /// K4 (coreness 3) with a pendant path 4-5 (coreness 1) and an
    /// isolated vertex 6 (coreness 0).
    fn k4_tail() -> CsrGraph {
        let mut el = EdgeList::new(7);
        for (s, d) in [(0, 1), (0, 2), (0, 3), (1, 2), (1, 3), (2, 3), (3, 4), (4, 5)] {
            el.push(s, d);
        }
        CsrGraph::from_edge_list(&el)
    }

    #[test]
    fn coreness_k4_tail() {
        let g = k4_tail();
        let mut alg = KCore::new();
        let r = engine::run(&g, &mut alg, &EngineConfig::host_only(1)).unwrap();
        assert_eq!(r.output.as_i32(), &[3, 3, 3, 3, 1, 1, 0]);
    }

    #[test]
    fn partitioned_matches_host_bitwise() {
        let g = k4_tail();
        let mut a = KCore::new();
        let r1 = engine::run(&g, &mut a, &EngineConfig::host_only(1)).unwrap();
        for shares in [[0.5, 0.5], [0.3, 0.7]] {
            let mut b = KCore::new();
            let cfg = EngineConfig::cpu_partitions(&shares, Strategy::Rand);
            let r2 = engine::run(&g, &mut b, &cfg).unwrap();
            assert_eq!(r1.output.as_i32(), r2.output.as_i32());
        }
    }

    #[test]
    fn matches_baseline_on_rmat() {
        use crate::graph::generator::{rmat, RmatParams};
        let g = CsrGraph::from_edge_list(&rmat(&RmatParams::paper(7, 6)));
        let mut alg = KCore::new();
        let r = engine::run(&g, &mut alg, &EngineConfig::host_only(2)).unwrap();
        assert_eq!(r.output.as_i32(), crate::baseline::kcore(&g).as_slice());
    }
}
