//! Triangle counting — the intersection family's showcase (DESIGN.md
//! §15; Rossi & Zhou's hybrid CPU-GPU network-motifs framework motivates
//! the edge-centric iteration pattern).
//!
//! The program captures its own **undirected, deduplicated,
//! self-loop-free** sorted adjacency from the original graph in
//! `prepare` and declares [`Kernel::NeighborIntersect`]: one fixed
//! superstep in which every vertex merges its neighbor list against each
//! neighbor's, counting common vertices strictly above the neighbor —
//! each incident triangle charged exactly once, so `tri[v]` is the exact
//! per-vertex incident-triangle count and `Σ tri[v] / 3` the global
//! count (every triangle is incident to three vertices). Per-vertex u64
//! stores are order-free (§9), so the pipelined executor and every
//! balance plan stay bit-identical. CPU-only: no AOT program is shipped
//! ("triangles" is not in the manifest), so accelerator placements fail
//! at manifest lookup with an actionable message.

use super::program::{
    AccelSpec, CommDecl, CyclePlan, FieldId, FieldSpec, InitRow, Kernel, ProgramDriver,
    ProgramMeta, Role, VertexProgram,
};
use crate::engine::state::StateArray;
use crate::graph::CsrGraph;

const TRI: FieldId = FieldId(0);

/// Triangle counting as a vertex program.
pub struct TrianglesProgram {
    /// Flat CSR of the sorted dedup undirected adjacency (global ids),
    /// built in `prepare`.
    offsets: Vec<usize>,
    nbrs: Vec<u32>,
}

impl VertexProgram for TrianglesProgram {
    fn meta(&self) -> ProgramMeta {
        ProgramMeta {
            name: "triangles",
            needs_weights: false,
            // the program builds its own undirected closure; the engine
            // keeps the forward graph (doubling it would only inflate the
            // chunking row offsets, never the merge inputs)
            undirected: false,
            reversed: false,
            fixed_rounds: Some(1),
            output: TRI,
        }
    }

    fn schema(&self) -> Vec<FieldSpec> {
        vec![FieldSpec::u64("tri", Role::Host, 0)]
    }

    fn plan(&self, _cycle: usize) -> CyclePlan {
        CyclePlan {
            kernel: Kernel::NeighborIntersect { count: TRI },
            comm: Vec::<CommDecl>::new(),
            device: None,
            accel: AccelSpec { name: "triangles", n_si32: 0, n_sf32: 0 },
        }
    }

    fn prepare(&mut self, original: &CsrGraph, _prepared: &CsrGraph) {
        let n = original.vertex_count;
        let mut adj: Vec<Vec<u32>> = vec![Vec::new(); n];
        for v in 0..n as u32 {
            for &t in original.neighbors(v) {
                if t != v {
                    adj[v as usize].push(t);
                    adj[t as usize].push(v);
                }
            }
        }
        self.offsets = Vec::with_capacity(n + 1);
        self.offsets.push(0);
        self.nbrs.clear();
        for mut a in adj {
            a.sort_unstable();
            a.dedup();
            self.nbrs.extend_from_slice(&a);
            self.offsets.push(self.nbrs.len());
        }
    }

    fn init_vertex(&self, _global_id: u32, _row: &mut InitRow<'_>) {}

    fn neighbors(&self, g: u32) -> &[u32] {
        &self.nbrs[self.offsets[g as usize]..self.offsets[g as usize + 1]]
    }

    /// Intersection work is bounded below by the adjacency cells fetched:
    /// every merge touches two neighbor lists once each.
    fn traversed_edges(&self, _output: &StateArray, _g: &CsrGraph, _rounds: usize) -> u64 {
        2 * self.nbrs.len() as u64
    }
}

/// The engine-facing triangle-counting algorithm.
pub type Triangles = ProgramDriver<TrianglesProgram>;

impl Triangles {
    #[allow(clippy::new_without_default)]
    pub fn new() -> Triangles {
        ProgramDriver::build(TrianglesProgram { offsets: vec![0], nbrs: Vec::new() })
            .expect("static schema is valid")
    }
}

/// Global triangle count from the per-vertex output: each triangle is
/// incident to exactly three vertices.
pub fn global_count(per_vertex: &[u64]) -> u64 {
    per_vertex.iter().sum::<u64>() / 3
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::{self, EngineConfig};
    use crate::graph::EdgeList;
    use crate::partition::Strategy;

    /// Two triangles sharing edge 1-2, plus duplicate and self-loop noise
    /// that the dedup closure must ignore.
    fn bowtie() -> CsrGraph {
        let mut el = EdgeList::new(5);
        el.push(0, 1);
        el.push(1, 2);
        el.push(2, 0);
        el.push(1, 3);
        el.push(3, 2);
        el.push(2, 1); // duplicate of 1-2, reversed
        el.push(4, 4); // self-loop
        CsrGraph::from_edge_list(&el)
    }

    #[test]
    fn bowtie_counts() {
        let g = bowtie();
        let mut alg = Triangles::new();
        let r = engine::run(&g, &mut alg, &EngineConfig::host_only(1)).unwrap();
        assert_eq!(r.output.as_u64(), &[1, 2, 2, 1, 0]);
        assert_eq!(global_count(r.output.as_u64()), 2);
        assert_eq!(r.supersteps, 1);
    }

    #[test]
    fn partitioned_matches_host_bitwise() {
        let g = bowtie();
        let mut a = Triangles::new();
        let r1 = engine::run(&g, &mut a, &EngineConfig::host_only(1)).unwrap();
        for shares in [[0.5, 0.5], [0.3, 0.7]] {
            let mut b = Triangles::new();
            let cfg = EngineConfig::cpu_partitions(&shares, Strategy::Rand);
            let r2 = engine::run(&g, &mut b, &cfg).unwrap();
            assert_eq!(r1.output.as_u64(), r2.output.as_u64());
        }
    }

    #[test]
    fn matches_baseline_on_rmat() {
        use crate::graph::generator::{rmat, RmatParams};
        let g = CsrGraph::from_edge_list(&rmat(&RmatParams::paper(7, 6)));
        let mut alg = Triangles::new();
        let r = engine::run(&g, &mut alg, &EngineConfig::host_only(2)).unwrap();
        assert_eq!(r.output.as_u64(), crate::baseline::triangles(&g).as_slice());
    }
}
