//! Incremental recompute after streaming mutations (DESIGN.md §14.3).
//!
//! Three strategies, picked by `harness::incremental_rerun` per algorithm
//! and batch shape:
//!
//! - **Monotone warm start** (SSSP / CC / widest — and BFS via
//!   [`BfsRelax`]): re-run the engine on the post-batch graph, but seed
//!   every vertex with its prior converged value and re-activate only the
//!   mutation-touched endpoints ([`super::program::WarmStart`]). After an
//!   insert-only batch the old fixed point still over-approximates the new
//!   one, so chaotic min/max relaxation re-converges to the *same* least
//!   fixed point a cold run finds, computing candidates with the identical
//!   binary ops — **bit-identical** output, touching only the affected
//!   cone.
//! - **Residual push** (PageRank): Gauss–Seidel push of the residual
//!   `r = F(p_prior) − p_prior` on the new graph until quiescence
//!   ([`pagerank_residual_push`]) — within the established f32 tolerance
//!   of a converged from-scratch run.
//! - **Full fallback**: any *effective* delete breaks the monotone
//!   over-approximation invariant (a shortened distance may need to grow
//!   back, which min-relaxation cannot do), so the caller falls back to a
//!   cold run. Same for programs with no incremental form (BC's two-cycle
//!   forward/backward sweeps).
//!
//! BFS needs its own program here because the level-synchronous
//! [`Kernel::Traversal`] activation (`level == superstep`) cannot resume
//! mid-wave: [`BfsRelax`] recasts BFS as unit-weight SSSP on the i32
//! monotone-scatter family. Integer unit-distance relaxation has the same
//! unique fixed point as wavefront BFS, so its levels are exactly the
//! `Bfs` levels in every configuration (asserted by this module's tests
//! and the differential-fuzz mutation axis).

use super::program::{
    AccelSpec, CommDecl, CyclePlan, FieldId, FieldSpec, InitRow, Kernel, ProgramDriver,
    ProgramMeta, Role, Value, VertexProgram,
};
use super::{StepCtx, INF_I32};
use crate::alg::pagerank::DAMPING;
use crate::graph::CsrGraph;

/// BFS as unit-distance monotone relaxation (module docs): warm-startable
/// where [`crate::alg::bfs::Bfs`]'s level-synchronous kernel is not.
pub struct BfsRelaxProgram {
    pub source: u32,
}

const DIST: FieldId = FieldId(0);
/// CPU-only shadow: distance at which the vertex last relaxed its edges.
const RELAXED_AT: FieldId = FieldId(1);

impl VertexProgram for BfsRelaxProgram {
    fn meta(&self) -> ProgramMeta {
        ProgramMeta {
            name: "bfs_relax",
            needs_weights: false,
            undirected: false,
            reversed: false,
            fixed_rounds: None,
            output: DIST,
        }
    }

    fn schema(&self) -> Vec<FieldSpec> {
        vec![
            FieldSpec::i32("dist", Role::Device, INF_I32),
            FieldSpec::i32("relaxed_at", Role::Host, INF_I32),
        ]
    }

    fn plan(&self, _cycle: usize) -> CyclePlan {
        CyclePlan {
            kernel: Kernel::MonotoneScatter { value: DIST, shadow: RELAXED_AT },
            comm: vec![CommDecl::PushMin(DIST)],
            device: None,
            accel: AccelSpec { name: "bfs_relax", n_si32: 0, n_sf32: 0 },
        }
    }

    fn init_vertex(&self, global_id: u32, row: &mut InitRow<'_>) {
        if global_id == self.source {
            row.set_i32(DIST, 0);
        }
    }

    /// Unit-weight relaxation: the whole of BFS, minus the wavefront.
    fn edge_update(&self, _ctx: &StepCtx, src: Value, _w: f32) -> Option<Value> {
        Some(Value::I32(src.expect_i32() + 1))
    }
}

/// The engine-facing warm-startable BFS.
pub type BfsRelax = ProgramDriver<BfsRelaxProgram>;

impl BfsRelax {
    pub fn new(source: u32) -> BfsRelax {
        ProgramDriver::build(BfsRelaxProgram { source }).expect("static schema is valid")
    }
}

/// Residual-push budget guard; hit only by a diverging bug, never by the
/// geometric contraction (rate [`DAMPING`]) of a healthy run.
pub const MAX_RESIDUAL_SWEEPS: usize = 10_000;

/// Per-vertex residual quiescence threshold. The remaining error is
/// bounded by `‖r‖₁ / (1 − d)`, so `1e-12` per vertex sits orders of
/// magnitude under the fuzz suite's f32 tolerance (`1e-4·|x|` floored at
/// `1e-7`).
pub const RESIDUAL_EPS: f64 = 1e-12;

/// Incremental PageRank by residual push (module docs; DESIGN.md §14.3).
///
/// `prior` is the previous rank vector by global id (any length: vertices
/// the mutation grew start at the fresh-init `1/n`). One pull-free
/// application of the PageRank operator on the *new* graph computes the
/// initial residual, then deterministic ascending-id Gauss–Seidel sweeps
/// push residual mass (`r[t] += d·r[v]/outdeg(v)`) until every vertex is
/// quiescent. Dangling vertices drop their mass, matching the engine's
/// semantics (`inv_outdeg = 0`). Returns the new ranks and the sweep
/// count. Internally f64 so the comparison slack vs the engine's f32 run
/// is the engine's own rounding, not ours.
pub fn pagerank_residual_push(g: &CsrGraph, prior: &[f32]) -> (Vec<f32>, usize) {
    let n = g.vertex_count;
    if n == 0 {
        return (Vec::new(), 0);
    }
    let d = DAMPING as f64;
    let base = (1.0 - d) / n as f64;
    let fresh = 1.0 / n as f64;
    let mut p: Vec<f64> =
        (0..n).map(|v| prior.get(v).map_or(fresh, |&x| x as f64)).collect();

    // r = F(p) − p via one forward scatter of the operator
    let mut r = vec![base; n];
    for v in 0..n as u32 {
        let nbrs = g.neighbors(v);
        if nbrs.is_empty() {
            continue;
        }
        let contrib = d * p[v as usize] / nbrs.len() as f64;
        for &t in nbrs {
            r[t as usize] += contrib;
        }
    }
    for v in 0..n {
        r[v] -= p[v];
    }

    let mut sweeps = 0;
    while sweeps < MAX_RESIDUAL_SWEEPS {
        sweeps += 1;
        let mut any = false;
        for v in 0..n {
            let rv = r[v];
            if rv.abs() <= RESIDUAL_EPS {
                continue;
            }
            any = true;
            p[v] += rv;
            r[v] = 0.0;
            let nbrs = g.neighbors(v as u32);
            if nbrs.is_empty() {
                continue;
            }
            let push = d * rv / nbrs.len() as f64;
            for &t in nbrs {
                r[t as usize] += push;
            }
        }
        if !any {
            break;
        }
    }
    (p.into_iter().map(|x| x as f32).collect(), sweeps)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::alg::bfs::Bfs;
    use crate::alg::pagerank::Pagerank;
    use crate::alg::program::WarmStart;
    use crate::alg::sssp::Sssp;
    use crate::engine::{self, EngineConfig};
    use crate::engine::state::StateArray;
    use crate::graph::delta::{apply, DeltaBatch, MutationOp};
    use crate::graph::{generator, CsrGraph, EdgeList};
    use crate::partition::Strategy;

    fn rmat(scale: u32, seed: u64) -> CsrGraph {
        let el = generator::rmat(&generator::RmatParams::paper(scale, 6 + seed));
        CsrGraph::from_edge_list(&el)
    }

    #[test]
    fn bfs_relax_matches_wavefront_bfs() {
        let g = rmat(7, 0);
        for cfg in [
            EngineConfig::host_only(1),
            EngineConfig::cpu_partitions(&[0.5, 0.5], Strategy::High),
        ] {
            let mut a = Bfs::new(0);
            let r1 = engine::run(&g, &mut a, &cfg).unwrap();
            let mut b = BfsRelax::new(0);
            let r2 = engine::run(&g, &mut b, &cfg).unwrap();
            assert_eq!(r1.output.as_i32(), r2.output.as_i32());
        }
    }

    #[test]
    fn warm_start_rejects_traversal_and_dtype_mismatch() {
        let warm = WarmStart { prior: StateArray::I32(vec![0; 4]), seeds: vec![] };
        assert!(Bfs::new(0).with_warm_start(warm.clone()).is_err());
        // SSSP's value field is f32; an i32 prior must be rejected
        assert!(Sssp::new(0).with_warm_start(warm).is_err());
    }

    #[test]
    fn warm_started_bfs_bit_identical_after_inserts() {
        let g = rmat(7, 1);
        let cfg = EngineConfig::cpu_partitions(&[0.4, 0.6], Strategy::Rand);
        let mut cold = BfsRelax::new(0);
        let prior = engine::run(&g, &mut cold, &cfg).unwrap().output;

        let batch = DeltaBatch::seeded(&g, 24, 0.0, 0xD311A);
        let a = apply(&g, &batch).unwrap();
        assert!(!a.effective_deletes);

        let mut warm = BfsRelax::new(0)
            .with_warm_start(WarmStart { prior: prior.clone(), seeds: a.touched.clone() })
            .unwrap();
        let warm_out = engine::run(&a.graph, &mut warm, &cfg).unwrap().output;

        let mut scratch = BfsRelax::new(0);
        let cold_out = engine::run(&a.graph, &mut scratch, &cfg).unwrap().output;
        assert_eq!(warm_out.as_i32(), cold_out.as_i32());
    }

    #[test]
    fn warm_started_sssp_bit_identical_after_inserts() {
        let mut el = generator::rmat(&generator::RmatParams::paper(6, 8));
        generator::with_random_weights(&mut el, 64, 0x5eed);
        let g = CsrGraph::from_edge_list(&el);
        let cfg = EngineConfig::cpu_partitions(&[0.5, 0.5], Strategy::Low).pipelined();

        let mut cold = Sssp::new(1);
        let prior = engine::run(&g, &mut cold, &cfg).unwrap().output;

        let batch = DeltaBatch::seeded(&g, 16, 0.0, 77);
        let a = apply(&g, &batch).unwrap();

        let mut warm = Sssp::new(1)
            .with_warm_start(WarmStart { prior, seeds: a.touched.clone() })
            .unwrap();
        let warm_out = engine::run(&a.graph, &mut warm, &cfg).unwrap().output;
        let mut scratch = Sssp::new(1);
        let cold_out = engine::run(&a.graph, &mut scratch, &cfg).unwrap().output;
        for (x, y) in warm_out.as_f32().iter().zip(cold_out.as_f32()) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }

    #[test]
    fn warm_start_with_empty_seeds_is_a_no_op_run() {
        let g = rmat(6, 2);
        let cfg = EngineConfig::host_only(1);
        let mut cold = BfsRelax::new(3);
        let prior = engine::run(&g, &mut cold, &cfg).unwrap().output;
        let mut warm = BfsRelax::new(3)
            .with_warm_start(WarmStart { prior: prior.clone(), seeds: vec![] })
            .unwrap();
        let r = engine::run(&g, &mut warm, &cfg).unwrap();
        assert_eq!(r.output.as_i32(), prior.as_i32());
        // quiesces immediately: nothing was re-activated
        assert!(r.supersteps <= 1, "supersteps = {}", r.supersteps);
    }

    #[test]
    fn residual_push_matches_converged_engine_run() {
        let g = rmat(6, 3);
        // enough rounds that the fixed iteration converged below tolerance
        let mut full = Pagerank::new(100);
        let want = engine::run(&g, &mut full, &EngineConfig::host_only(1)).unwrap().output;

        // start the push from a deliberately different prior (uniform)
        let uniform = vec![1.0 / g.vertex_count as f32; g.vertex_count];
        let (got, sweeps) = pagerank_residual_push(&g, &uniform);
        assert!(sweeps < MAX_RESIDUAL_SWEEPS);
        for (v, (a, b)) in got.iter().zip(want.as_f32()).enumerate() {
            let tol = (1e-4 * b.abs()).max(1e-7);
            assert!((a - b).abs() <= tol, "vertex {v}: {a} vs {b}");
        }
    }

    #[test]
    fn residual_push_after_mutation_matches_full_recompute() {
        let g = rmat(6, 4);
        let mut before = Pagerank::new(100);
        let prior = engine::run(&g, &mut before, &EngineConfig::host_only(1)).unwrap().output;

        let first_nbr = g.neighbors(0).first().copied().unwrap_or(1);
        let batch = DeltaBatch {
            ops: vec![
                MutationOp::Insert { src: 0, dst: 5, weight: None },
                MutationOp::Delete { src: 0, dst: first_nbr },
            ],
        };
        let a = apply(&g, &batch).unwrap();

        let (got, _) = pagerank_residual_push(&a.graph, prior.as_f32());
        let mut full = Pagerank::new(100);
        let want = engine::run(&a.graph, &mut full, &EngineConfig::host_only(1)).unwrap().output;
        for (v, (x, y)) in got.iter().zip(want.as_f32()).enumerate() {
            let tol = (1e-4 * y.abs()).max(1e-7);
            assert!((x - y).abs() <= tol, "vertex {v}: {x} vs {y}");
        }
    }

    #[test]
    fn residual_push_handles_grown_and_empty_graphs() {
        let (out, _) = pagerank_residual_push(&CsrGraph::from_edge_list(&EdgeList::new(0)), &[]);
        assert!(out.is_empty());
        // prior shorter than the graph: new vertices start at fresh init
        let mut el = EdgeList::new(3);
        el.push(0, 1);
        el.push(1, 2);
        let g = CsrGraph::from_edge_list(&el);
        let (out, _) = pagerank_residual_push(&g, &[0.5]);
        assert_eq!(out.len(), 3);
        assert!(out.iter().all(|x| x.is_finite() && *x > 0.0));
    }
}
