//! Single-Source Shortest Path — Bellman-Ford (paper §7.3, Figure 20).
//!
//! The paper picks Bellman-Ford over Dijkstra/Δ-stepping because every
//! active vertex can relax its edges in parallel — a good fit for the
//! accelerator's bulk model. The CPU kernel keeps the paper's `active`
//! optimization (a vertex relaxes only when its distance improved); the
//! accelerator program relaxes **all** edges each superstep (Harish et al.
//! 2007 style), which is exactly how the original CUDA kernels behave.
//!
//! Remote activation falls out of monotonicity: instead of explicit active
//! flags that the communication phase would have to maintain, each vertex
//! remembers the distance it last relaxed at (`relaxed_at`); any vertex
//! whose current distance is lower — whether improved locally or by an
//! inbox message — is active.

use super::{AlgSpec, Algorithm, ComputeOut, EdgeOrientation, Pad, ProgramSpec, StepCtx};
use crate::engine::state::{AlgState, Channel, CommOp, StateArray};
use crate::partition::{Partition, PartitionedGraph};
use crate::util::atomic::{as_atomic_f32_cells, atomic_min_f32};
use crate::util::threadpool::parallel_reduce;
use std::sync::atomic::Ordering;

pub struct Sssp {
    pub source: u32,
}

impl Sssp {
    pub fn new(source: u32) -> Sssp {
        Sssp { source }
    }
}

const DIST: usize = 0;
/// CPU-only: distance at which the vertex last relaxed its edges.
const RELAXED_AT: usize = 1;

impl Algorithm for Sssp {
    fn spec(&self) -> AlgSpec {
        AlgSpec {
            name: "sssp",
            needs_weights: true,
            undirected: false,
            reversed: false,
            fixed_rounds: None,
        }
    }

    fn init_state(&mut self, pg: &PartitionedGraph, part: &Partition) -> AlgState {
        let n = part.state_len();
        let mut dist = vec![f32::INFINITY; n];
        if pg.part_of[self.source as usize] as usize == part.id {
            dist[pg.local_of[self.source as usize] as usize] = 0.0;
        }
        AlgState::new(vec![
            StateArray::F32(dist),
            StateArray::F32(vec![f32::INFINITY; n]),
        ])
    }

    fn channels(&self, _cycle: usize) -> Vec<CommOp> {
        vec![CommOp::Single(Channel::push_min_f32(DIST))]
    }

    fn program(&self, _cycle: usize) -> ProgramSpec {
        ProgramSpec {
            name: "sssp",
            arrays: vec![DIST],
            pads: vec![Pad::F32(f32::INFINITY)],
            aux: vec![],
            needs_weights: true,
            n_si32: 0,
            n_sf32: 0,
            orientation: EdgeOrientation::Forward,
        }
    }

    fn compute_cpu(&self, part: &Partition, state: &mut AlgState, ctx: &StepCtx) -> ComputeOut {
        let nv = part.nv;
        let (dist_arr, rest) = state.arrays.split_at_mut(RELAXED_AT);
        let dist = dist_arr[DIST].as_f32_mut();
        let dist_cells = as_atomic_f32_cells(dist);
        // per-vertex, written only by the owning chunk — atomic view just
        // satisfies the shared-closure borrow.
        let relaxed_cells = as_atomic_f32_cells(rest[0].as_f32_mut());

        let fold = |lo: usize, hi: usize, acc: (bool, u64, u64)| {
            let (mut changed, mut reads, mut writes) = acc;
            for v in lo..hi {
                let dv = f32::from_bits(dist_cells[v].load(Ordering::Relaxed));
                if ctx.instrument {
                    reads += 2; // dist[v], relaxed_at[v]
                }
                // active test (Fig 20 line 4): distance improved since the
                // last relaxation — covers both local and inbox updates.
                if dv >= f32::from_bits(relaxed_cells[v].load(Ordering::Relaxed)) {
                    continue;
                }
                relaxed_cells[v].store(dv.to_bits(), Ordering::Relaxed);
                let ts = part.targets(v as u32);
                let ws = part.weights(v as u32);
                for (k, &t) in ts.iter().enumerate() {
                    let nd = dv + ws[k];
                    let old = atomic_min_f32(&dist_cells[t as usize], nd);
                    if ctx.instrument {
                        reads += 1;
                    }
                    if nd < old {
                        changed = true;
                        if ctx.instrument {
                            writes += 1;
                        }
                    }
                }
            }
            (changed, reads, writes)
        };
        let (changed, reads, writes) = parallel_reduce(
            nv,
            ctx.threads,
            (false, 0u64, 0u64),
            fold,
            |a, b| (a.0 || b.0, a.1 + b.1, a.2 + b.2),
        );
        ComputeOut { changed, reads, writes }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::{self, EngineConfig};
    use crate::graph::{CsrGraph, EdgeList};
    use crate::partition::Strategy;

    fn weighted_diamond() -> CsrGraph {
        // 0 -1-> 1 -1-> 3 ; 0 -5-> 2 -1-> 3 ; shortest 0->3 = 2
        let mut el = EdgeList::new(4);
        el.push(0, 1);
        el.push(0, 2);
        el.push(1, 3);
        el.push(2, 3);
        el.weights = Some(vec![1.0, 5.0, 1.0, 1.0]);
        CsrGraph::from_edge_list(&el)
    }

    #[test]
    fn shortest_paths_host_only() {
        let g = weighted_diamond();
        let mut alg = Sssp::new(0);
        let r = engine::run(&g, &mut alg, &EngineConfig::host_only(1)).unwrap();
        assert_eq!(r.output.as_f32(), &[0.0, 1.0, 5.0, 2.0]);
    }

    #[test]
    fn partitioned_matches_host() {
        let g = weighted_diamond();
        let mut a = Sssp::new(0);
        let r1 = engine::run(&g, &mut a, &EngineConfig::host_only(1)).unwrap();
        let mut b = Sssp::new(0);
        let cfg = EngineConfig::cpu_partitions(&[0.5, 0.5], Strategy::Low);
        let r2 = engine::run(&g, &mut b, &cfg).unwrap();
        assert_eq!(r1.output.as_f32(), r2.output.as_f32());
    }

    #[test]
    fn requires_weights() {
        let mut el = EdgeList::new(2);
        el.push(0, 1);
        let g = CsrGraph::from_edge_list(&el);
        let mut alg = Sssp::new(0);
        assert!(engine::run(&g, &mut alg, &EngineConfig::host_only(1)).is_err());
    }
}
