//! Single-Source Shortest Path — Bellman-Ford (paper §7.3, Figure 20) on
//! the typed vertex-program surface.
//!
//! The paper picks Bellman-Ford over Dijkstra/Δ-stepping because every
//! active vertex can relax its edges in parallel — a good fit for the
//! accelerator's bulk model. The program declares a `dist` field on a
//! push-min channel plus a host-only `relaxed_at` shadow and the
//! [`Kernel::MonotoneScatter`] family; the driver derives the paper's
//! `active` optimization from the shadow (a vertex relaxes only when its
//! distance improved — locally or via the inbox — since it last relaxed:
//! remote activation falls out of monotonicity, no explicit flags). The
//! per-edge rule is one line: offer `dist[v] + w`.
//!
//! The accelerator program relaxes **all** edges each superstep (Harish et
//! al. 2007 style), which is exactly how the original CUDA kernels behave.

use super::program::{
    AccelSpec, CommDecl, CyclePlan, FieldId, FieldSpec, InitRow, Kernel, ProgramDriver,
    ProgramMeta, Role, Value, VertexProgram,
};
use super::StepCtx;
use crate::engine::state::StateArray;
use crate::graph::CsrGraph;

/// SSSP from a single source vertex (global id), as a vertex program.
pub struct SsspProgram {
    pub source: u32,
}

const DIST: FieldId = FieldId(0);
/// CPU-only shadow: distance at which the vertex last relaxed its edges.
const RELAXED_AT: FieldId = FieldId(1);

impl VertexProgram for SsspProgram {
    fn meta(&self) -> ProgramMeta {
        ProgramMeta {
            name: "sssp",
            needs_weights: true,
            undirected: false,
            reversed: false,
            fixed_rounds: None,
            output: DIST,
        }
    }

    fn schema(&self) -> Vec<FieldSpec> {
        vec![
            FieldSpec::f32("dist", Role::Device, f32::INFINITY),
            FieldSpec::f32("relaxed_at", Role::Host, f32::INFINITY),
        ]
    }

    fn plan(&self, _cycle: usize) -> CyclePlan {
        CyclePlan {
            kernel: Kernel::MonotoneScatter { value: DIST, shadow: RELAXED_AT },
            comm: vec![CommDecl::PushMin(DIST)],
            device: None,
            accel: AccelSpec { name: "sssp", n_si32: 0, n_sf32: 0 },
        }
    }

    fn init_vertex(&self, global_id: u32, row: &mut InitRow<'_>) {
        if global_id == self.source {
            row.set_f32(DIST, 0.0);
        }
    }

    /// Relaxation (Fig 20 line 6): offer `dist[v] + w` to the target.
    fn edge_update(&self, _ctx: &StepCtx, src: Value, w: f32) -> Option<Value> {
        Some(Value::F32(src.expect_f32() + w))
    }

    /// Σ degree(v) over vertices with finite distance (paper §5).
    fn traversed_edges(&self, output: &StateArray, g: &CsrGraph, _rounds: usize) -> u64 {
        output
            .as_f32()
            .iter()
            .enumerate()
            .filter(|(_, &d)| d.is_finite())
            .map(|(v, _)| g.out_degree(v as u32))
            .sum()
    }
}

/// The engine-facing SSSP algorithm.
pub type Sssp = ProgramDriver<SsspProgram>;

impl Sssp {
    pub fn new(source: u32) -> Sssp {
        ProgramDriver::build(SsspProgram { source }).expect("static schema is valid")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::{self, EngineConfig};
    use crate::graph::{CsrGraph, EdgeList};
    use crate::partition::Strategy;

    fn weighted_diamond() -> CsrGraph {
        // 0 -1-> 1 -1-> 3 ; 0 -5-> 2 -1-> 3 ; shortest 0->3 = 2
        let mut el = EdgeList::new(4);
        el.push(0, 1);
        el.push(0, 2);
        el.push(1, 3);
        el.push(2, 3);
        el.weights = Some(vec![1.0, 5.0, 1.0, 1.0]);
        CsrGraph::from_edge_list(&el)
    }

    #[test]
    fn shortest_paths_host_only() {
        let g = weighted_diamond();
        let mut alg = Sssp::new(0);
        let r = engine::run(&g, &mut alg, &EngineConfig::host_only(1)).unwrap();
        assert_eq!(r.output.as_f32(), &[0.0, 1.0, 5.0, 2.0]);
    }

    #[test]
    fn partitioned_matches_host() {
        let g = weighted_diamond();
        let mut a = Sssp::new(0);
        let r1 = engine::run(&g, &mut a, &EngineConfig::host_only(1)).unwrap();
        let mut b = Sssp::new(0);
        let cfg = EngineConfig::cpu_partitions(&[0.5, 0.5], Strategy::Low);
        let r2 = engine::run(&g, &mut b, &cfg).unwrap();
        assert_eq!(r1.output.as_f32(), r2.output.as_f32());
    }

    #[test]
    fn requires_weights() {
        let mut el = EdgeList::new(2);
        el.push(0, 1);
        let g = CsrGraph::from_edge_list(&el);
        let mut alg = Sssp::new(0);
        assert!(engine::run(&g, &mut alg, &EngineConfig::host_only(1)).is_err());
    }

    #[test]
    fn shadow_field_stays_host_side() {
        use crate::alg::Algorithm;
        let alg = Sssp::new(0);
        let spec = Algorithm::program(&alg, 0);
        assert_eq!(spec.arrays, vec![0], "relaxed_at must not ship");
        assert!(spec.needs_weights);
    }
}
