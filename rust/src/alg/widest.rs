//! Single-source widest path (maximum-bottleneck path) — the sixth
//! algorithm, written to prove the typed vertex-program API (ISSUE 5).
//!
//! `width[v]` is the best bottleneck capacity of any path from the source:
//! the maximum over paths of the minimum edge weight along the path. The
//! source has width `+inf` (the empty path has no bottleneck); unreachable
//! vertices stay at the max-reduce identity `-inf`. With the repo's
//! positive integer weight fixtures every width is an exact copy of some
//! edge weight (or ±inf) — pure selection, no arithmetic — so outputs are
//! **bit-exact** in f32 and the golden/differential suites compare them
//! like BFS/CC/SSSP.
//!
//! The entire algorithm is this file: a two-field schema (`width` on a
//! push-**max** channel plus the monotone-activation shadow) and a
//! one-line `edge_update` (`min(width[v], w)`), riding the driver's
//! [`Kernel::MonotoneScatter`] family — the same derived kernel, comm,
//! instrumentation, and migration machinery SSSP and CC use. The AOT
//! side ships too: `python/compile/model.py` registers a `widest` step
//! (the max dual of the SSSP relaxation), so `make artifacts` lowers it;
//! on a checkout without built artifacts, accelerator runs fail at
//! manifest lookup with an actionable message.

use super::program::{
    AccelSpec, CommDecl, CyclePlan, FieldId, FieldSpec, InitRow, Kernel, ProgramDriver,
    ProgramMeta, Role, Value, VertexProgram,
};
use super::StepCtx;
use crate::engine::state::StateArray;
use crate::graph::CsrGraph;

/// Widest path from a single source vertex (global id).
pub struct WidestProgram {
    pub source: u32,
}

const WIDTH: FieldId = FieldId(0);
/// CPU-only shadow: width at which the vertex last relaxed its edges.
const RELAXED_AT: FieldId = FieldId(1);

impl VertexProgram for WidestProgram {
    fn meta(&self) -> ProgramMeta {
        ProgramMeta {
            name: "widest",
            needs_weights: true,
            undirected: false,
            reversed: false,
            fixed_rounds: None,
            output: WIDTH,
        }
    }

    fn schema(&self) -> Vec<FieldSpec> {
        vec![
            FieldSpec::f32("width", Role::Device, f32::NEG_INFINITY),
            FieldSpec::f32("relaxed_at", Role::Host, f32::NEG_INFINITY),
        ]
    }

    fn plan(&self, _cycle: usize) -> CyclePlan {
        CyclePlan {
            kernel: Kernel::MonotoneScatter { value: WIDTH, shadow: RELAXED_AT },
            comm: vec![CommDecl::PushMax(WIDTH)],
            device: None,
            accel: AccelSpec { name: "widest", n_si32: 0, n_sf32: 0 },
        }
    }

    fn init_vertex(&self, global_id: u32, row: &mut InitRow<'_>) {
        if global_id == self.source {
            row.set_f32(WIDTH, f32::INFINITY);
        }
    }

    /// Bottleneck relaxation: a path through `v` over this edge has
    /// capacity `min(width[v], w)`; the channel's `max` keeps the best.
    fn edge_update(&self, _ctx: &StepCtx, src: Value, w: f32) -> Option<Value> {
        Some(Value::F32(src.expect_f32().min(w)))
    }

    /// Σ degree(v) over reached vertices (width above the identity).
    fn traversed_edges(&self, output: &StateArray, g: &CsrGraph, _rounds: usize) -> u64 {
        output
            .as_f32()
            .iter()
            .enumerate()
            .filter(|(_, &d)| d > f32::NEG_INFINITY)
            .map(|(v, _)| g.out_degree(v as u32))
            .sum()
    }
}

/// The engine-facing widest-path algorithm.
pub type Widest = ProgramDriver<WidestProgram>;

impl Widest {
    pub fn new(source: u32) -> Widest {
        ProgramDriver::build(WidestProgram { source }).expect("static schema is valid")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::{self, EngineConfig};
    use crate::graph::{CsrGraph, EdgeList};
    use crate::partition::Strategy;

    fn weighted_diamond() -> CsrGraph {
        // 0 -1-> 1 -4-> 3 ; 0 -3-> 2 -2-> 3
        // widest 0->3: via 1 = min(1,4)=1, via 2 = min(3,2)=2 → 2
        let mut el = EdgeList::new(4);
        el.push(0, 1);
        el.push(0, 2);
        el.push(1, 3);
        el.push(2, 3);
        el.weights = Some(vec![1.0, 3.0, 4.0, 2.0]);
        CsrGraph::from_edge_list(&el)
    }

    #[test]
    fn widest_paths_host_only() {
        let g = weighted_diamond();
        let mut alg = Widest::new(0);
        let r = engine::run(&g, &mut alg, &EngineConfig::host_only(1)).unwrap();
        assert_eq!(r.output.as_f32(), &[f32::INFINITY, 1.0, 3.0, 2.0]);
    }

    #[test]
    fn unreachable_stays_neg_inf() {
        let mut el = EdgeList::new(3);
        el.push(0, 1);
        el.weights = Some(vec![7.0]);
        let g = CsrGraph::from_edge_list(&el);
        let mut alg = Widest::new(0);
        let r = engine::run(&g, &mut alg, &EngineConfig::host_only(1)).unwrap();
        let out = r.output.as_f32();
        assert_eq!(out[0], f32::INFINITY);
        assert_eq!(out[1], 7.0);
        assert_eq!(out[2], f32::NEG_INFINITY);
    }

    #[test]
    fn partitioned_matches_host_bitwise() {
        let mut el = crate::graph::generator::rmat(&crate::graph::generator::RmatParams::paper(
            7, 3,
        ));
        crate::graph::generator::with_random_weights(&mut el, 64, 9);
        let g = CsrGraph::from_edge_list(&el);
        let mut a = Widest::new(0);
        let r1 = engine::run(&g, &mut a, &EngineConfig::host_only(1)).unwrap();
        for strat in [Strategy::Rand, Strategy::High, Strategy::Low] {
            for mode_pipelined in [false, true] {
                let mut cfg = EngineConfig::cpu_partitions(&[0.6, 0.4], strat);
                if mode_pipelined {
                    cfg = cfg.pipelined();
                }
                let mut b = Widest::new(0);
                let r2 = engine::run(&g, &mut b, &cfg).unwrap();
                for (x, y) in r1.output.as_f32().iter().zip(r2.output.as_f32()) {
                    assert_eq!(x.to_bits(), y.to_bits(), "{strat:?}/{mode_pipelined}");
                }
            }
        }
    }

    #[test]
    fn requires_weights() {
        let mut el = EdgeList::new(2);
        el.push(0, 1);
        let g = CsrGraph::from_edge_list(&el);
        let mut alg = Widest::new(0);
        assert!(engine::run(&g, &mut alg, &EngineConfig::host_only(1)).is_err());
    }
}
