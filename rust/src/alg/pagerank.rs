//! PageRank — pull-based by default (paper §7.1, Figure 14), with a
//! push-mode comparison variant (DESIGN.md §8) — on the typed
//! vertex-program surface.
//!
//! **Pull mode** ([`PrMode::Pull`], the default): each vertex *pulls* its
//! in-neighbors' rank contributions (faster than push: no atomics — the
//! paper cites Nguyen et al. 2013 for this), so the engine partitions the
//! **reversed** graph. The program declares [`Kernel::Gather`] over the
//! `contrib` field on a **pull channel**: pull slots have exactly one
//! writer, so the op list is never order-sensitive and the pipelined
//! executor keeps full exchange freedom while staying bit-identical to
//! the synchronous engine.
//!
//! **Push mode** ([`PrMode::Push`]): [`Kernel::FoldScatter`] over the
//! forward graph — every vertex scatters `rank/outdeg` along its
//! out-edges; remote partial sums travel on a **push-add channel**, which
//! is order-sensitive and forces canonical-order iteration and exchange
//! release. Kept as the measurable counterexample that motivates the pull
//! gather; CPU-only (no AOT program is shipped for it, so accelerator
//! runs fail at manifest lookup with an actionable message).
//!
//! `rank_{t+1}[v] = (1-d)/|V| + d · Σ_{u→v} contrib_t[u]`, d = 0.85, run
//! for a fixed number of rounds (paper: 5 in Figure 16, 1 in Table 4).
//! Push mode pays one extra trailing fold-only superstep (the last
//! round's remote partial sums land during communication).

use super::program::{
    AccelSpec, Activation, CommDecl, CyclePlan, FieldId, Fields, FieldSpec, InitRow, Kernel,
    ProgramDriver, ProgramMeta, Role, VertexProgram,
};
use super::StepCtx;
use crate::engine::state::StateArray;
use crate::graph::CsrGraph;

pub const DAMPING: f32 = 0.85;
pub const DEFAULT_ROUNDS: usize = 5;

/// Communication mode (module docs; DESIGN.md §8).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PrMode {
    /// Gather over the reversed graph's local CSR; contributions travel on
    /// a pull channel. Default, fully pipelinable.
    Pull,
    /// Scatter over the forward graph; partial sums travel on a push-add
    /// channel (order-sensitive). CPU-only comparison variant.
    Push,
}

/// PageRank as a vertex program.
pub struct PagerankProgram {
    pub rounds: usize,
    pub mode: PrMode,
    /// Global vertex count (set in `prepare`).
    n_global: usize,
    /// Original out-degrees, indexed by global id (set in `prepare`).
    outdeg: Vec<u64>,
}

impl PagerankProgram {
    fn base(&self) -> f32 {
        (1.0 - DAMPING) / self.n_global.max(1) as f32
    }
}

const RANK: FieldId = FieldId(0);
/// Pull mode: published contribution. Push mode: incoming-sum accumulator.
const CONTRIB: FieldId = FieldId(1);
const INV_OUTDEG: FieldId = FieldId(2);
const MASK: FieldId = FieldId(3);

impl VertexProgram for PagerankProgram {
    fn meta(&self) -> ProgramMeta {
        ProgramMeta {
            name: "pagerank",
            needs_weights: false,
            undirected: false,
            // pull gathers over in-edges → partition the reversed graph;
            // push scatters over out-edges → keep the forward graph.
            reversed: self.mode == PrMode::Pull,
            // push mode needs one extra superstep: the final round's remote
            // partial sums land during communication and are folded into
            // ranks by a trailing fold-only compute (driver rule).
            fixed_rounds: Some(match self.mode {
                PrMode::Pull => self.rounds,
                PrMode::Push => self.rounds + 1,
            }),
            output: RANK,
        }
    }

    fn schema(&self) -> Vec<FieldSpec> {
        vec![
            FieldSpec::f32("rank", Role::Device, 0.0),
            FieldSpec::f32("contrib", Role::Device, 0.0),
            FieldSpec::f32("inv_outdeg", Role::Aux, 0.0),
            FieldSpec::f32("mask", Role::Aux, 0.0),
        ]
    }

    fn plan(&self, _cycle: usize) -> CyclePlan {
        match self.mode {
            // single writer per ghost slot → never order-sensitive: the
            // pipelined executor keeps full exchange freedom.
            PrMode::Pull => CyclePlan {
                kernel: Kernel::Gather { src: CONTRIB, active: Activation::Always },
                comm: vec![CommDecl::Pull(CONTRIB)],
                device: None,
                accel: AccelSpec { name: "pagerank", n_si32: 0, n_sf32: 2 },
            },
            // remote partial sums: order-sensitive push-add, the pipelined
            // executor falls back to canonical-order release.
            PrMode::Push => CyclePlan {
                kernel: Kernel::FoldScatter { accum: CONTRIB },
                comm: vec![CommDecl::PushAdd(CONTRIB)],
                device: None,
                accel: AccelSpec { name: "pagerank_push", n_si32: 0, n_sf32: 2 },
            },
        }
    }

    fn prepare(&mut self, original: &CsrGraph, _prepared: &CsrGraph) {
        self.n_global = original.vertex_count;
        self.outdeg = original.out_degrees();
    }

    fn init_vertex(&self, global_id: u32, row: &mut InitRow<'_>) {
        let r0 = 1.0f32 / self.n_global.max(1) as f32;
        let d = self.outdeg[global_id as usize];
        let inv = if d > 0 { 1.0 / d as f32 } else { 0.0 };
        row.set_f32(RANK, r0);
        row.set_f32(INV_OUTDEG, inv);
        // pull: publish the initial contribution; push: CONTRIB is the
        // incoming-sum accumulator and must start at the add identity
        // (its pad, 0), ghost slots included.
        if self.mode == PrMode::Pull {
            row.set_f32(CONTRIB, r0 * inv);
        }
        row.set_f32(MASK, 1.0);
    }

    /// Pull phase apply (Fig 14): no atomics needed — each `v` writes only
    /// `rank[v]`, which is the whole point of pull-based PageRank.
    fn gather_apply(&self, _ctx: &StepCtx, v: usize, f: &Fields<'_>, sum: f32) -> u64 {
        f.set_f32(RANK, v, self.base() + DAMPING * sum);
        1
    }

    /// Refresh contributions for the next superstep.
    fn publish(&self, _ctx: &StepCtx, v: usize, f: &Fields<'_>) {
        f.set_f32(CONTRIB, v, f.f32(RANK, v) * f.f32(INV_OUTDEG, v));
    }

    /// Push-mode fold: the accumulator holds every local scatter from the
    /// previous superstep plus the remote partial sums the communication
    /// phase added — fold it into ranks and reset.
    fn fold(&self, _ctx: &StepCtx, v: usize, f: &Fields<'_>) -> u64 {
        f.set_f32(RANK, v, self.base() + DAMPING * f.f32(CONTRIB, v));
        f.set_f32(CONTRIB, v, 0.0);
        2
    }

    /// Push-mode scatter value: `rank/outdeg` into every out-target.
    fn scatter_value(&self, _ctx: &StepCtx, v: usize, f: &Fields<'_>) -> f32 {
        f.f32(RANK, v) * f.f32(INV_OUTDEG, v)
    }

    fn scalars_f32(&self, _ctx: &StepCtx) -> Vec<f32> {
        vec![self.base(), DAMPING]
    }

    /// |E| per iteration (paper §5).
    fn traversed_edges(&self, _output: &StateArray, g: &CsrGraph, rounds: usize) -> u64 {
        g.edge_count() as u64 * rounds.max(1) as u64
    }
}

/// The engine-facing PageRank algorithm.
pub type Pagerank = ProgramDriver<PagerankProgram>;

impl Pagerank {
    /// Pull-mode PageRank (the default used by the harness).
    pub fn new(rounds: usize) -> Pagerank {
        ProgramDriver::build(PagerankProgram {
            rounds,
            mode: PrMode::Pull,
            n_global: 0,
            outdeg: Vec::new(),
        })
        .expect("static schema is valid")
    }

    /// Push-mode comparison variant (module docs).
    pub fn push_mode(rounds: usize) -> Pagerank {
        ProgramDriver::build(PagerankProgram {
            rounds,
            mode: PrMode::Push,
            n_global: 0,
            outdeg: Vec::new(),
        })
        .expect("static schema is valid")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::alg::Algorithm;
    use crate::engine::{self, EngineConfig};
    use crate::graph::{CsrGraph, EdgeList};
    use crate::partition::Strategy;

    fn triangle_plus_sink() -> CsrGraph {
        // 0->1, 1->2, 2->0 (cycle) and 0->3 (sink)
        let mut el = EdgeList::new(4);
        el.push(0, 1);
        el.push(1, 2);
        el.push(2, 0);
        el.push(0, 3);
        CsrGraph::from_edge_list(&el)
    }

    #[test]
    fn ranks_sum_reasonably() {
        let g = triangle_plus_sink();
        let mut alg = Pagerank::new(20);
        let r = engine::run(&g, &mut alg, &EngineConfig::host_only(1)).unwrap();
        let ranks = r.output.as_f32();
        assert_eq!(ranks.len(), 4);
        assert!(ranks.iter().all(|&x| x > 0.0));
        // vertex 1 has one in-link from 0 which splits rank two ways;
        // vertex 2 gets all of 1's rank — so rank(2) > rank(1).
        assert!(ranks[2] > ranks[1]);
    }

    #[test]
    fn partitioned_matches_host() {
        let g = triangle_plus_sink();
        let mut a = Pagerank::new(5);
        let r1 = engine::run(&g, &mut a, &EngineConfig::host_only(1)).unwrap();
        let mut b = Pagerank::new(5);
        let cfg = EngineConfig::cpu_partitions(&[0.5, 0.5], Strategy::Rand);
        let r2 = engine::run(&g, &mut b, &cfg).unwrap();
        for (x, y) in r1.output.as_f32().iter().zip(r2.output.as_f32()) {
            assert!((x - y).abs() < 1e-6, "{x} vs {y}");
        }
    }

    #[test]
    fn fixed_round_count() {
        let g = triangle_plus_sink();
        let mut alg = Pagerank::new(3);
        let r = engine::run(&g, &mut alg, &EngineConfig::host_only(1)).unwrap();
        // 3 compute supersteps + 1 initial sync step record
        assert_eq!(r.metrics.supersteps(), 4);
        assert_eq!(r.supersteps, 3);
    }

    #[test]
    fn push_mode_matches_pull_mode() {
        let g = triangle_plus_sink();
        let mut pull = Pagerank::new(5);
        let r1 = engine::run(&g, &mut pull, &EngineConfig::host_only(1)).unwrap();
        let mut push = Pagerank::push_mode(5);
        let r2 = engine::run(&g, &mut push, &EngineConfig::host_only(1)).unwrap();
        for (v, (a, b)) in r1.output.as_f32().iter().zip(r2.output.as_f32()).enumerate() {
            assert!((a - b).abs() < 1e-6, "vertex {v}: pull {a} vs push {b}");
        }
        // push mode pays one extra (fold-only) superstep
        assert_eq!(r2.supersteps, r1.supersteps + 1);
    }

    #[test]
    fn push_mode_partitioned_matches_host() {
        let g = triangle_plus_sink();
        let mut a = Pagerank::push_mode(4);
        let r1 = engine::run(&g, &mut a, &EngineConfig::host_only(1)).unwrap();
        for shares in [[0.5, 0.5], [0.3, 0.7]] {
            let mut b = Pagerank::push_mode(4);
            let cfg = EngineConfig::cpu_partitions(&shares, Strategy::Rand);
            let r2 = engine::run(&g, &mut b, &cfg).unwrap();
            for (v, (x, y)) in r1.output.as_f32().iter().zip(r2.output.as_f32()).enumerate() {
                assert!((x - y).abs() < 1e-6, "vertex {v}: {x} vs {y}");
            }
        }
    }

    #[test]
    fn channel_order_sensitivity_by_mode() {
        // The whole point of the pull gather: its op list is never
        // order-sensitive, so pipelined PageRank needs no canonical-order
        // fallback; the push variant is the counterexample.
        let pull = Pagerank::new(5);
        assert!(pull.channels(0).iter().all(|op| !op.order_sensitive()));
        let push = Pagerank::push_mode(5);
        assert!(push.channels(0).iter().any(|op| op.order_sensitive()));
        // and the derived accelerator specs keep their historical shapes
        let spec = Algorithm::program(&pull, 0);
        assert_eq!(spec.name, "pagerank");
        assert_eq!(spec.arrays, vec![0, 1]);
        assert_eq!(spec.aux, vec![0, 1]);
        assert_eq!(spec.n_sf32, 2);
        assert_eq!(Algorithm::program(&push, 0).name, "pagerank_push");
    }
}
