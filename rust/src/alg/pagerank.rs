//! PageRank — pull-based (paper §7.1, Figure 14).
//!
//! Each vertex *pulls* its in-neighbors' rank contributions (faster than
//! push: no atomics — the paper cites Nguyen et al. 2013 for this), so the
//! engine partitions the **reversed** graph: a partition's local CSR lists
//! each vertex's in-neighbors, remote in-neighbors become ghost-in slots.
//!
//! The communicated quantity is `contrib[u] = rank[u] / outdeg(u)` — a
//! single value per unique remote source vertex per superstep (a pull
//! channel), matching the paper's observation that PageRank communicates
//! via every boundary edge every round.
//!
//! `rank_{t+1}[v] = (1-d)/|V| + d · Σ_{u→v} contrib_t[u]`, d = 0.85, run
//! for a fixed number of rounds (paper: 5 in Figure 16, 1 in Table 4).

use super::{AlgSpec, Algorithm, ComputeOut, EdgeOrientation, Pad, ProgramSpec, StepCtx};
use crate::engine::state::{AlgState, Channel, CommOp, StateArray};
use crate::graph::CsrGraph;
use crate::partition::{Partition, PartitionedGraph};
use crate::util::threadpool::parallel_reduce;

pub const DAMPING: f32 = 0.85;
pub const DEFAULT_ROUNDS: usize = 5;

pub struct Pagerank {
    pub rounds: usize,
    /// Global vertex count (set in `prepare`).
    n_global: usize,
    /// Original out-degrees, indexed by global id (set in `prepare`).
    outdeg: Vec<u64>,
}

impl Pagerank {
    pub fn new(rounds: usize) -> Pagerank {
        Pagerank { rounds, n_global: 0, outdeg: Vec::new() }
    }

    fn base(&self) -> f32 {
        (1.0 - DAMPING) / self.n_global.max(1) as f32
    }
}

const RANK: usize = 0;
const CONTRIB: usize = 1;
const AUX_INV_OUTDEG: usize = 0;
const AUX_MASK: usize = 1;

impl Algorithm for Pagerank {
    fn spec(&self) -> AlgSpec {
        AlgSpec {
            name: "pagerank",
            needs_weights: false,
            undirected: false,
            reversed: true,
            fixed_rounds: Some(self.rounds),
        }
    }

    fn prepare(&mut self, original: &CsrGraph, _prepared: &CsrGraph) {
        self.n_global = original.vertex_count;
        self.outdeg = original.out_degrees();
    }

    fn init_state(&mut self, _pg: &PartitionedGraph, part: &Partition) -> AlgState {
        let n = part.state_len();
        let r0 = 1.0f32 / self.n_global.max(1) as f32;
        let mut rank = vec![0f32; n];
        let mut contrib = vec![0f32; n];
        let mut inv_outdeg = vec![0f32; n];
        let mut mask = vec![0f32; n];
        for (l, &g) in part.local_to_global.iter().enumerate() {
            let d = self.outdeg[g as usize];
            rank[l] = r0;
            inv_outdeg[l] = if d > 0 { 1.0 / d as f32 } else { 0.0 };
            contrib[l] = rank[l] * inv_outdeg[l];
            mask[l] = 1.0;
        }
        let mut st = AlgState::new(vec![StateArray::F32(rank), StateArray::F32(contrib)]);
        st.aux = vec![StateArray::F32(inv_outdeg), StateArray::F32(mask)];
        st
    }

    fn channels(&self, _cycle: usize) -> Vec<CommOp> {
        vec![CommOp::Single(Channel::pull_f32(CONTRIB))]
    }

    fn program(&self, _cycle: usize) -> ProgramSpec {
        ProgramSpec {
            name: "pagerank",
            arrays: vec![RANK, CONTRIB],
            pads: vec![Pad::F32(0.0), Pad::F32(0.0)],
            aux: vec![AUX_INV_OUTDEG, AUX_MASK],
            needs_weights: false,
            n_si32: 0,
            n_sf32: 2,
            orientation: EdgeOrientation::Reversed,
        }
    }

    fn scalars_f32(&self, _ctx: &StepCtx) -> Vec<f32> {
        vec![self.base(), DAMPING]
    }

    fn compute_cpu(&self, part: &Partition, state: &mut AlgState, ctx: &StepCtx) -> ComputeOut {
        let nv = part.nv;
        let base = self.base();
        // split: contrib is read (including ghost slots), rank written,
        // then contrib refreshed for the next round.
        let (rank_arr, contrib_arr) = state.arrays.split_at_mut(CONTRIB);
        let rank = rank_arr[RANK].as_f32_mut();
        let contrib = contrib_arr[0].as_f32_mut();
        let inv_outdeg = state.aux[AUX_INV_OUTDEG].as_f32();

        // Pull phase: no atomics needed — each v writes only rank[v]
        // (Fig 14; this is the whole point of pull-based PageRank).
        let rank_ptr = SendPtr(rank.as_mut_ptr());
        let (reads, writes) = parallel_reduce(
            nv,
            ctx.threads,
            (0u64, 0u64),
            |lo, hi, acc| {
                let (mut reads, mut writes) = acc;
                let rank = rank_ptr;
                for v in lo..hi {
                    let mut sum = 0f32;
                    for &t in part.targets(v as u32) {
                        sum += contrib[t as usize];
                    }
                    if ctx.instrument {
                        reads += part.targets(v as u32).len() as u64;
                        writes += 1;
                    }
                    // SAFETY: disjoint v per chunk.
                    unsafe { *rank.0.add(v) = base + DAMPING * sum };
                }
                (reads, writes)
            },
            |a, b| (a.0 + b.0, a.1 + b.1),
        );
        // refresh contributions for the next superstep
        for v in 0..nv {
            contrib[v] = rank[v] * inv_outdeg[v];
        }
        ComputeOut { changed: true, reads, writes: writes + nv as u64 }
    }

    fn output_array(&self) -> usize {
        RANK
    }
}

/// Tiny Send wrapper for the disjoint-chunk write pattern above.
#[derive(Clone, Copy)]
struct SendPtr(*mut f32);
unsafe impl Send for SendPtr {}
unsafe impl Sync for SendPtr {}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::{self, EngineConfig};
    use crate::graph::{CsrGraph, EdgeList};
    use crate::partition::Strategy;

    fn triangle_plus_sink() -> CsrGraph {
        // 0->1, 1->2, 2->0 (cycle) and 0->3 (sink)
        let mut el = EdgeList::new(4);
        el.push(0, 1);
        el.push(1, 2);
        el.push(2, 0);
        el.push(0, 3);
        CsrGraph::from_edge_list(&el)
    }

    #[test]
    fn ranks_sum_reasonably() {
        let g = triangle_plus_sink();
        let mut alg = Pagerank::new(20);
        let r = engine::run(&g, &mut alg, &EngineConfig::host_only(1)).unwrap();
        let ranks = r.output.as_f32();
        assert_eq!(ranks.len(), 4);
        assert!(ranks.iter().all(|&x| x > 0.0));
        // vertex 1 has one in-link from 0 which splits rank two ways;
        // vertex 2 gets all of 1's rank — so rank(2) > rank(1).
        assert!(ranks[2] > ranks[1]);
    }

    #[test]
    fn partitioned_matches_host() {
        let g = triangle_plus_sink();
        let mut a = Pagerank::new(5);
        let r1 = engine::run(&g, &mut a, &EngineConfig::host_only(1)).unwrap();
        let mut b = Pagerank::new(5);
        let cfg = EngineConfig::cpu_partitions(&[0.5, 0.5], Strategy::Rand);
        let r2 = engine::run(&g, &mut b, &cfg).unwrap();
        for (x, y) in r1.output.as_f32().iter().zip(r2.output.as_f32()) {
            assert!((x - y).abs() < 1e-6, "{x} vs {y}");
        }
    }

    #[test]
    fn fixed_round_count() {
        let g = triangle_plus_sink();
        let mut alg = Pagerank::new(3);
        let r = engine::run(&g, &mut alg, &EngineConfig::host_only(1)).unwrap();
        // 3 compute supersteps + 1 initial sync step record
        assert_eq!(r.metrics.supersteps(), 4);
        assert_eq!(r.supersteps, 3);
    }
}
