//! PageRank — pull-based by default (paper §7.1, Figure 14), with a
//! push-mode comparison variant (DESIGN.md §8).
//!
//! **Pull mode** ([`PrMode::Pull`], the default): each vertex *pulls* its
//! in-neighbors' rank contributions (faster than push: no atomics — the
//! paper cites Nguyen et al. 2013 for this), so the engine partitions the
//! **reversed** graph: a partition's local CSR lists each vertex's
//! in-neighbors, remote in-neighbors become ghost-in slots. The
//! communicated quantity is `contrib[u] = rank[u] / outdeg(u)` — a single
//! value per unique remote source vertex per superstep on a **pull
//! channel**. Pull slots have exactly one writer, so the op list is never
//! order-sensitive and the pipelined executor keeps full exchange freedom
//! (no canonical-order fallback) while staying bit-identical to the
//! synchronous engine.
//!
//! **Push mode** ([`PrMode::Push`]): the forward graph is partitioned and
//! every vertex scatters `rank/outdeg` along its out-edges; remote partial
//! sums travel on a **push-add channel**, which is order-sensitive
//! (`CommOp::order_sensitive`) and forces the pipelined executor into
//! canonical-order release. Kept as the measurable counterexample that
//! motivates the pull gather; CPU-only (no AOT program is shipped for it).
//!
//! `rank_{t+1}[v] = (1-d)/|V| + d · Σ_{u→v} contrib_t[u]`, d = 0.85, run
//! for a fixed number of rounds (paper: 5 in Figure 16, 1 in Table 4).

use super::{AlgSpec, Algorithm, ComputeOut, EdgeOrientation, Pad, ProgramSpec, StepCtx};
use crate::engine::state::{AlgState, Channel, CommOp, StateArray};
use crate::graph::CsrGraph;
use crate::partition::{Partition, PartitionedGraph};
use crate::util::atomic::{as_atomic_f32_cells, atomic_add_f32};
use crate::util::threadpool::parallel_reduce;

pub const DAMPING: f32 = 0.85;
pub const DEFAULT_ROUNDS: usize = 5;

/// Communication mode (module docs; DESIGN.md §8).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PrMode {
    /// Gather over the reversed graph's local CSR; contributions travel on
    /// a pull channel. Default, fully pipelinable.
    Pull,
    /// Scatter over the forward graph; partial sums travel on a push-add
    /// channel (order-sensitive). CPU-only comparison variant.
    Push,
}

pub struct Pagerank {
    pub rounds: usize,
    pub mode: PrMode,
    /// Global vertex count (set in `prepare`).
    n_global: usize,
    /// Original out-degrees, indexed by global id (set in `prepare`).
    outdeg: Vec<u64>,
}

impl Pagerank {
    /// Pull-mode PageRank (the default used by the harness).
    pub fn new(rounds: usize) -> Pagerank {
        Pagerank { rounds, mode: PrMode::Pull, n_global: 0, outdeg: Vec::new() }
    }

    /// Push-mode comparison variant (module docs).
    pub fn push_mode(rounds: usize) -> Pagerank {
        Pagerank { rounds, mode: PrMode::Push, n_global: 0, outdeg: Vec::new() }
    }

    fn base(&self) -> f32 {
        (1.0 - DAMPING) / self.n_global.max(1) as f32
    }
}

const RANK: usize = 0;
/// Pull mode: published contribution. Push mode: incoming-sum accumulator.
const CONTRIB: usize = 1;
const AUX_INV_OUTDEG: usize = 0;
const AUX_MASK: usize = 1;

impl Algorithm for Pagerank {
    fn spec(&self) -> AlgSpec {
        AlgSpec {
            name: "pagerank",
            needs_weights: false,
            undirected: false,
            // pull gathers over in-edges → partition the reversed graph;
            // push scatters over out-edges → keep the forward graph.
            reversed: self.mode == PrMode::Pull,
            // push mode needs one extra superstep: the final round's remote
            // partial sums land during communication and are folded into
            // ranks by a trailing fold-only compute.
            fixed_rounds: Some(match self.mode {
                PrMode::Pull => self.rounds,
                PrMode::Push => self.rounds + 1,
            }),
        }
    }

    fn prepare(&mut self, original: &CsrGraph, _prepared: &CsrGraph) {
        self.n_global = original.vertex_count;
        self.outdeg = original.out_degrees();
    }

    fn init_state(&mut self, _pg: &PartitionedGraph, part: &Partition) -> AlgState {
        let n = part.state_len();
        let r0 = 1.0f32 / self.n_global.max(1) as f32;
        let mut rank = vec![0f32; n];
        let mut contrib = vec![0f32; n];
        let mut inv_outdeg = vec![0f32; n];
        let mut mask = vec![0f32; n];
        for (l, &g) in part.local_to_global.iter().enumerate() {
            let d = self.outdeg[g as usize];
            rank[l] = r0;
            inv_outdeg[l] = if d > 0 { 1.0 / d as f32 } else { 0.0 };
            // pull: publish the initial contribution; push: CONTRIB is the
            // incoming-sum accumulator and must start at the add identity
            // (0), ghost slots included.
            if self.mode == PrMode::Pull {
                contrib[l] = rank[l] * inv_outdeg[l];
            }
            mask[l] = 1.0;
        }
        let mut st = AlgState::new(vec![StateArray::F32(rank), StateArray::F32(contrib)]);
        st.aux = vec![StateArray::F32(inv_outdeg), StateArray::F32(mask)];
        st
    }

    fn channels(&self, _cycle: usize) -> Vec<CommOp> {
        match self.mode {
            // single writer per ghost slot → never order-sensitive: the
            // pipelined executor keeps full exchange freedom.
            PrMode::Pull => vec![CommOp::Single(Channel::pull_f32(CONTRIB))],
            // remote partial sums: order-sensitive push-add, the pipelined
            // executor falls back to canonical-order release.
            PrMode::Push => vec![CommOp::Single(Channel::push_add_f32(CONTRIB))],
        }
    }

    fn program(&self, _cycle: usize) -> ProgramSpec {
        ProgramSpec {
            // push mode is a CPU-only comparison variant: no AOT program is
            // shipped for it, so an accelerator run fails at manifest
            // lookup with an actionable message.
            name: match self.mode {
                PrMode::Pull => "pagerank",
                PrMode::Push => "pagerank_push",
            },
            arrays: vec![RANK, CONTRIB],
            pads: vec![Pad::F32(0.0), Pad::F32(0.0)],
            aux: vec![AUX_INV_OUTDEG, AUX_MASK],
            needs_weights: false,
            n_si32: 0,
            n_sf32: 2,
            orientation: match self.mode {
                PrMode::Pull => EdgeOrientation::Reversed,
                PrMode::Push => EdgeOrientation::Forward,
            },
        }
    }

    fn scalars_f32(&self, _ctx: &StepCtx) -> Vec<f32> {
        vec![self.base(), DAMPING]
    }

    fn compute_cpu(&self, part: &Partition, state: &mut AlgState, ctx: &StepCtx) -> ComputeOut {
        match self.mode {
            PrMode::Pull => self.compute_pull(part, state, ctx),
            PrMode::Push => self.compute_push(part, state, ctx),
        }
    }

    fn output_array(&self) -> usize {
        RANK
    }
}

impl Pagerank {
    fn compute_pull(&self, part: &Partition, state: &mut AlgState, ctx: &StepCtx) -> ComputeOut {
        let nv = part.nv;
        let base = self.base();
        // split: contrib is read (including ghost slots), rank written,
        // then contrib refreshed for the next round.
        let (rank_arr, contrib_arr) = state.arrays.split_at_mut(CONTRIB);
        let rank = rank_arr[RANK].as_f32_mut();
        let contrib = contrib_arr[0].as_f32_mut();
        let inv_outdeg = state.aux[AUX_INV_OUTDEG].as_f32();

        // Pull phase: no atomics needed — each v writes only rank[v]
        // (Fig 14; this is the whole point of pull-based PageRank).
        let rank_ptr = SendPtr(rank.as_mut_ptr());
        let (reads, writes) = parallel_reduce(
            nv,
            ctx.threads,
            (0u64, 0u64),
            |lo, hi, acc| {
                let (mut reads, mut writes) = acc;
                let rank = rank_ptr;
                for v in lo..hi {
                    let mut sum = 0f32;
                    for &t in part.targets(v as u32) {
                        sum += contrib[t as usize];
                    }
                    if ctx.instrument {
                        reads += part.targets(v as u32).len() as u64;
                        writes += 1;
                    }
                    // SAFETY: disjoint v per chunk.
                    unsafe { *rank.0.add(v) = base + DAMPING * sum };
                }
                (reads, writes)
            },
            |a, b| (a.0 + b.0, a.1 + b.1),
        );
        // refresh contributions for the next superstep
        for v in 0..nv {
            contrib[v] = rank[v] * inv_outdeg[v];
        }
        ComputeOut { changed: true, reads, writes: writes + nv as u64 }
    }

    /// Push-mode superstep over the forward graph:
    ///
    /// - **fold** (supersteps ≥ 1): the accumulator now holds every local
    ///   scatter from the previous superstep plus the remote partial sums
    ///   the communication phase added — fold it into ranks and reset;
    /// - **scatter** (supersteps < rounds): add `rank/outdeg` into each
    ///   out-target — local targets via an f32 CAS-add, ghost slots
    ///   likewise (the outbox the push-add channel flushes).
    ///
    /// The trailing superstep (`== rounds`) is fold-only, which is why
    /// `spec()` reports `rounds + 1` fixed rounds for push mode.
    fn compute_push(&self, part: &Partition, state: &mut AlgState, ctx: &StepCtx) -> ComputeOut {
        let nv = part.nv;
        let base = self.base();
        let (rank_arr, accum_arr) = state.arrays.split_at_mut(CONTRIB);
        let rank = rank_arr[RANK].as_f32_mut();
        let accum = accum_arr[0].as_f32_mut();
        let inv_outdeg = state.aux[AUX_INV_OUTDEG].as_f32();

        let mut writes_seq = 0u64;
        if ctx.superstep > 0 {
            for v in 0..nv {
                rank[v] = base + DAMPING * accum[v];
                accum[v] = 0.0;
            }
            writes_seq += 2 * nv as u64;
        }
        if ctx.superstep >= self.rounds {
            return ComputeOut { changed: true, reads: 0, writes: writes_seq };
        }

        let rank_ro: &[f32] = rank;
        let cells = as_atomic_f32_cells(accum);
        // Scatter in canonical (ascending global id) order: the f32 adds
        // into shared accumulator cells — local targets and ghost slots
        // alike — then arrive in a placement-invariant sender order, which
        // keeps push-mode outputs bit-identical across placements
        // (DESIGN.md §9; with one worker the order is exact, with more the
        // chunk boundaries are placement-invariant too).
        let canon = &part.canonical_order;
        let (reads, writes) = parallel_reduce(
            nv,
            ctx.threads,
            (0u64, 0u64),
            |lo, hi, acc| {
                let (mut reads, mut writes) = acc;
                for i in lo..hi {
                    let v = canon[i] as usize;
                    let c = rank_ro[v] * inv_outdeg[v];
                    if c == 0.0 {
                        continue;
                    }
                    for &t in part.targets(v as u32) {
                        atomic_add_f32(&cells[t as usize], c);
                    }
                    if ctx.instrument {
                        let deg = part.targets(v as u32).len() as u64;
                        reads += 1 + deg;
                        writes += deg;
                    }
                }
                (reads, writes)
            },
            |a, b| (a.0 + b.0, a.1 + b.1),
        );
        ComputeOut { changed: true, reads, writes: writes + writes_seq }
    }
}

/// Tiny Send wrapper for the disjoint-chunk write pattern above.
#[derive(Clone, Copy)]
struct SendPtr(*mut f32);
unsafe impl Send for SendPtr {}
unsafe impl Sync for SendPtr {}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::{self, EngineConfig};
    use crate::graph::{CsrGraph, EdgeList};
    use crate::partition::Strategy;

    fn triangle_plus_sink() -> CsrGraph {
        // 0->1, 1->2, 2->0 (cycle) and 0->3 (sink)
        let mut el = EdgeList::new(4);
        el.push(0, 1);
        el.push(1, 2);
        el.push(2, 0);
        el.push(0, 3);
        CsrGraph::from_edge_list(&el)
    }

    #[test]
    fn ranks_sum_reasonably() {
        let g = triangle_plus_sink();
        let mut alg = Pagerank::new(20);
        let r = engine::run(&g, &mut alg, &EngineConfig::host_only(1)).unwrap();
        let ranks = r.output.as_f32();
        assert_eq!(ranks.len(), 4);
        assert!(ranks.iter().all(|&x| x > 0.0));
        // vertex 1 has one in-link from 0 which splits rank two ways;
        // vertex 2 gets all of 1's rank — so rank(2) > rank(1).
        assert!(ranks[2] > ranks[1]);
    }

    #[test]
    fn partitioned_matches_host() {
        let g = triangle_plus_sink();
        let mut a = Pagerank::new(5);
        let r1 = engine::run(&g, &mut a, &EngineConfig::host_only(1)).unwrap();
        let mut b = Pagerank::new(5);
        let cfg = EngineConfig::cpu_partitions(&[0.5, 0.5], Strategy::Rand);
        let r2 = engine::run(&g, &mut b, &cfg).unwrap();
        for (x, y) in r1.output.as_f32().iter().zip(r2.output.as_f32()) {
            assert!((x - y).abs() < 1e-6, "{x} vs {y}");
        }
    }

    #[test]
    fn fixed_round_count() {
        let g = triangle_plus_sink();
        let mut alg = Pagerank::new(3);
        let r = engine::run(&g, &mut alg, &EngineConfig::host_only(1)).unwrap();
        // 3 compute supersteps + 1 initial sync step record
        assert_eq!(r.metrics.supersteps(), 4);
        assert_eq!(r.supersteps, 3);
    }

    #[test]
    fn push_mode_matches_pull_mode() {
        let g = triangle_plus_sink();
        let mut pull = Pagerank::new(5);
        let r1 = engine::run(&g, &mut pull, &EngineConfig::host_only(1)).unwrap();
        let mut push = Pagerank::push_mode(5);
        let r2 = engine::run(&g, &mut push, &EngineConfig::host_only(1)).unwrap();
        for (v, (a, b)) in r1.output.as_f32().iter().zip(r2.output.as_f32()).enumerate() {
            assert!((a - b).abs() < 1e-6, "vertex {v}: pull {a} vs push {b}");
        }
        // push mode pays one extra (fold-only) superstep
        assert_eq!(r2.supersteps, r1.supersteps + 1);
    }

    #[test]
    fn push_mode_partitioned_matches_host() {
        let g = triangle_plus_sink();
        let mut a = Pagerank::push_mode(4);
        let r1 = engine::run(&g, &mut a, &EngineConfig::host_only(1)).unwrap();
        for shares in [[0.5, 0.5], [0.3, 0.7]] {
            let mut b = Pagerank::push_mode(4);
            let cfg = EngineConfig::cpu_partitions(&shares, Strategy::Rand);
            let r2 = engine::run(&g, &mut b, &cfg).unwrap();
            for (v, (x, y)) in r1.output.as_f32().iter().zip(r2.output.as_f32()).enumerate() {
                assert!((x - y).abs() < 1e-6, "vertex {v}: {x} vs {y}");
            }
        }
    }

    #[test]
    fn channel_order_sensitivity_by_mode() {
        // The whole point of the pull gather: its op list is never
        // order-sensitive, so pipelined PageRank needs no canonical-order
        // fallback; the push variant is the counterexample.
        let pull = Pagerank::new(5);
        assert!(pull.channels(0).iter().all(|op| !op.order_sensitive()));
        let push = Pagerank::push_mode(5);
        assert!(push.channels(0).iter().any(|op| op.order_sensitive()));
    }
}
