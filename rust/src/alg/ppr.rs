//! Personalized PageRank — the serving layer's natural per-user query
//! (DESIGN.md §15.4), on the same pull-gather family as global PageRank.
//!
//! Standard power iteration from the source indicator: rank starts as
//! `1{v == source}` and each round applies
//! `rank_{t+1}[v] = (1-d)·1{v == source} + d · Σ_{u→v} rank_t[u]/outdeg(u)`
//! for a fixed number of rounds (d = 0.85, same damping as global
//! PageRank; dangling mass is dropped, same as the Figure 14 kernel).
//! The only differences from [`super::pagerank`] are the personalized
//! teleport vector (an aux source-mask field set in `init_vertex`, since
//! `gather_apply` sees local indices) and the indicator initialization —
//! the gather over the reversed graph, the pull channel, and therefore
//! full pipelining eligibility are identical. Tolerances follow the
//! established PageRank tiers. CPU-only ("ppr" is not in the AOT
//! manifest).

use super::pagerank::DAMPING;
use super::program::{
    AccelSpec, Activation, CommDecl, CyclePlan, FieldId, Fields, FieldSpec, InitRow, Kernel,
    ProgramDriver, ProgramMeta, Role, VertexProgram,
};
use super::StepCtx;
use crate::engine::state::StateArray;
use crate::graph::CsrGraph;

pub const DEFAULT_ROUNDS: usize = 5;

const RANK: FieldId = FieldId(0);
const CONTRIB: FieldId = FieldId(1);
const INV_OUTDEG: FieldId = FieldId(2);
/// Personalized teleport vector: 1.0 at the source, 0.0 elsewhere.
const SRC_MASK: FieldId = FieldId(3);

/// Personalized PageRank as a vertex program.
pub struct PprProgram {
    pub source: u32,
    pub rounds: usize,
    /// Original out-degrees, indexed by global id (set in `prepare`).
    outdeg: Vec<u64>,
}

impl VertexProgram for PprProgram {
    fn meta(&self) -> ProgramMeta {
        ProgramMeta {
            name: "ppr",
            needs_weights: false,
            undirected: false,
            // pull gathers over in-edges → partition the reversed graph
            reversed: true,
            fixed_rounds: Some(self.rounds),
            output: RANK,
        }
    }

    fn schema(&self) -> Vec<FieldSpec> {
        vec![
            FieldSpec::f32("rank", Role::Device, 0.0),
            FieldSpec::f32("contrib", Role::Device, 0.0),
            FieldSpec::f32("inv_outdeg", Role::Aux, 0.0),
            FieldSpec::f32("src_mask", Role::Aux, 0.0),
        ]
    }

    fn plan(&self, _cycle: usize) -> CyclePlan {
        CyclePlan {
            // single writer per pull slot → never order-sensitive: the
            // pipelined executor keeps full exchange freedom (§9)
            kernel: Kernel::Gather { src: CONTRIB, active: Activation::Always },
            comm: vec![CommDecl::Pull(CONTRIB)],
            device: None,
            accel: AccelSpec { name: "ppr", n_si32: 0, n_sf32: 2 },
        }
    }

    fn prepare(&mut self, original: &CsrGraph, _prepared: &CsrGraph) {
        self.outdeg = original.out_degrees();
    }

    fn init_vertex(&self, global_id: u32, row: &mut InitRow<'_>) {
        let d = self.outdeg[global_id as usize];
        let inv = if d > 0 { 1.0 / d as f32 } else { 0.0 };
        row.set_f32(INV_OUTDEG, inv);
        if global_id == self.source {
            row.set_f32(RANK, 1.0);
            row.set_f32(CONTRIB, inv);
            row.set_f32(SRC_MASK, 1.0);
        }
    }

    /// Pull apply: personalized teleport instead of the uniform base.
    fn gather_apply(&self, _ctx: &StepCtx, v: usize, f: &Fields<'_>, sum: f32) -> u64 {
        f.set_f32(RANK, v, (1.0 - DAMPING) * f.f32(SRC_MASK, v) + DAMPING * sum);
        1
    }

    /// Refresh contributions for the next superstep.
    fn publish(&self, _ctx: &StepCtx, v: usize, f: &Fields<'_>) {
        f.set_f32(CONTRIB, v, f.f32(RANK, v) * f.f32(INV_OUTDEG, v));
    }

    fn scalars_f32(&self, _ctx: &StepCtx) -> Vec<f32> {
        vec![1.0 - DAMPING, DAMPING]
    }

    /// |E| per iteration, like global PageRank.
    fn traversed_edges(&self, _output: &StateArray, g: &CsrGraph, rounds: usize) -> u64 {
        g.edge_count() as u64 * rounds.max(1) as u64
    }
}

/// The engine-facing personalized-PageRank algorithm.
pub type Ppr = ProgramDriver<PprProgram>;

impl Ppr {
    pub fn new(source: u32, rounds: usize) -> Ppr {
        ProgramDriver::build(PprProgram { source, rounds, outdeg: Vec::new() })
            .expect("static schema is valid")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::{self, EngineConfig};
    use crate::graph::EdgeList;
    use crate::partition::Strategy;

    fn cycle_with_spur() -> CsrGraph {
        // 0->1->2->0 cycle, plus 0->3 spur
        let mut el = EdgeList::new(4);
        el.push(0, 1);
        el.push(1, 2);
        el.push(2, 0);
        el.push(0, 3);
        CsrGraph::from_edge_list(&el)
    }

    #[test]
    fn mass_concentrates_near_the_source() {
        let g = cycle_with_spur();
        let mut alg = Ppr::new(0, 20);
        let r = engine::run(&g, &mut alg, &EngineConfig::host_only(1)).unwrap();
        let ranks = r.output.as_f32();
        // teleport restarts at 0: it keeps the largest rank, and 3 (a
        // dead end fed only by 0) stays below 1 and 2 on the cycle path
        assert!(ranks[0] > ranks[1] && ranks[1] > ranks[2]);
        assert!(ranks.iter().all(|&x| x >= 0.0));
        // total mass is bounded by 1 (dangling mass drops out via 3)
        assert!(ranks.iter().sum::<f32>() <= 1.0 + 1e-5);
    }

    #[test]
    fn source_locality_differs_by_source() {
        let g = cycle_with_spur();
        let mut a = Ppr::new(0, 10);
        let r0 = engine::run(&g, &mut a, &EngineConfig::host_only(1)).unwrap();
        let mut b = Ppr::new(1, 10);
        let r1 = engine::run(&g, &mut b, &EngineConfig::host_only(1)).unwrap();
        assert!(r0.output.as_f32()[0] > r1.output.as_f32()[0]);
        assert!(r1.output.as_f32()[1] > r0.output.as_f32()[1]);
    }

    #[test]
    fn partitioned_matches_host() {
        let g = cycle_with_spur();
        let mut a = Ppr::new(0, 5);
        let r1 = engine::run(&g, &mut a, &EngineConfig::host_only(1)).unwrap();
        for shares in [[0.5, 0.5], [0.3, 0.7]] {
            let mut b = Ppr::new(0, 5);
            let cfg = EngineConfig::cpu_partitions(&shares, Strategy::Rand);
            let r2 = engine::run(&g, &mut b, &cfg).unwrap();
            for (v, (x, y)) in r1.output.as_f32().iter().zip(r2.output.as_f32()).enumerate() {
                assert!((x - y).abs() < 1e-6, "vertex {v}: {x} vs {y}");
            }
        }
    }

    #[test]
    fn matches_baseline_on_rmat() {
        use crate::graph::generator::{rmat, RmatParams};
        let g = CsrGraph::from_edge_list(&rmat(&RmatParams::paper(7, 6)));
        let mut alg = Ppr::new(3, DEFAULT_ROUNDS);
        let r = engine::run(&g, &mut alg, &EngineConfig::host_only(2)).unwrap();
        let want = crate::baseline::ppr(&g, 3, DEFAULT_ROUNDS);
        for (v, (x, y)) in r.output.as_f32().iter().zip(&want).enumerate() {
            let tol = (1e-4 * y.abs()).max(1e-7);
            assert!((x - y).abs() <= tol, "vertex {v}: engine {x} vs baseline {y}");
        }
    }
}
