//! Label propagation community detection — synchronous, with a
//! deterministic **min-label** tie-break, on the [`Kernel::NeighborScan`]
//! family (DESIGN.md §15).
//!
//! Every vertex starts labeled with its own global id. Each round, every
//! vertex simultaneously adopts the most frequent label among its
//! neighbors' previous-round labels — over the engine's **undirected
//! doubled multigraph**, so parallel edges weight their endpoint's label
//! with multiplicity — breaking frequency ties toward the smallest
//! label. A vertex with no neighbors keeps its own label. The scan is a
//! pure function of the previous round's snapshot and integer-only, so
//! runs are bit-identical across executors, placements, and balance
//! plans — the determinism contract satellite-tested in
//! `differential_fuzz`. Synchronous LPA can oscillate (e.g. on bipartite
//! structures), so the cycle runs a fixed number of rounds
//! ([`DEFAULT_ROUNDS`], `--rounds` on the CLI) with early exit on a
//! fully quiet round. CPU-only ("labelprop" is not in the AOT manifest).

use super::program::{
    AccelSpec, CommDecl, CyclePlan, FieldId, Fields, FieldSpec, InitRow, Kernel, NeighborView,
    ProgramDriver, ProgramMeta, Role, VertexProgram,
};
use super::StepCtx;
use crate::engine::state::StateArray;
use crate::graph::CsrGraph;

pub const DEFAULT_ROUNDS: usize = 5;

const LABEL: FieldId = FieldId(0);
const LABEL_PREV: FieldId = FieldId(1);

/// Label propagation as a vertex program.
pub struct LabelPropProgram {
    pub rounds: usize,
}

impl VertexProgram for LabelPropProgram {
    fn meta(&self) -> ProgramMeta {
        ProgramMeta {
            name: "labelprop",
            needs_weights: false,
            undirected: true,
            reversed: false,
            fixed_rounds: Some(self.rounds),
            output: LABEL,
        }
    }

    fn schema(&self) -> Vec<FieldSpec> {
        vec![
            FieldSpec::i32("label", Role::Host, 0),
            FieldSpec::i32("label_prev", Role::Host, 0),
        ]
    }

    fn plan(&self, _cycle: usize) -> CyclePlan {
        CyclePlan {
            kernel: Kernel::NeighborScan { cur: LABEL, prev: LABEL_PREV },
            comm: vec![CommDecl::Pull(LABEL)],
            device: None,
            accel: AccelSpec { name: "labelprop", n_si32: 0, n_sf32: 0 },
        }
    }

    fn init_vertex(&self, global_id: u32, row: &mut InitRow<'_>) {
        row.set_i32(LABEL, global_id as i32);
    }

    fn scan_vertex(&self, _ctx: &StepCtx, v: usize, f: &Fields<'_>, nb: &NeighborView<'_, '_>) -> i32 {
        if nb.is_empty() {
            return f.i32(LABEL_PREV, v);
        }
        let mut labels: Vec<i32> = (0..nb.len()).map(|k| nb.value(k)).collect();
        labels.sort_unstable();
        // ascending scan: the first maximal run wins, which IS the
        // min-label tie-break (only strictly longer runs replace it)
        let mut best = labels[0];
        let mut best_count = 0usize;
        let mut run = labels[0];
        let mut run_count = 0usize;
        for &l in &labels {
            if l == run {
                run_count += 1;
            } else {
                run = l;
                run_count = 1;
            }
            if run_count > best_count {
                best = run;
                best_count = run_count;
            }
        }
        best
    }

    /// A quiet round is a fixed point: every later round would repeat it.
    fn cycle_done(&self, _cycle: usize, _next_superstep: usize, any_changed: bool) -> Option<bool> {
        if any_changed {
            None // fall through to the fixed-rounds cap
        } else {
            Some(true)
        }
    }

    /// Every round scans every adjacency cell of the doubled view.
    fn traversed_edges(&self, _output: &StateArray, g: &CsrGraph, rounds: usize) -> u64 {
        2 * g.edge_count() as u64 * rounds.max(1) as u64
    }
}

/// The engine-facing label-propagation algorithm.
pub type LabelProp = ProgramDriver<LabelPropProgram>;

impl LabelProp {
    pub fn new(rounds: usize) -> LabelProp {
        ProgramDriver::build(LabelPropProgram { rounds }).expect("static schema is valid")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::{self, EngineConfig};
    use crate::graph::EdgeList;
    use crate::partition::Strategy;

    /// Two dense communities {0,1,2} and {3,4,5} joined by one bridge.
    fn two_communities() -> CsrGraph {
        let mut el = EdgeList::new(6);
        for (s, d) in [(0, 1), (1, 2), (0, 2), (3, 4), (4, 5), (3, 5), (2, 3)] {
            el.push(s, d);
        }
        CsrGraph::from_edge_list(&el)
    }

    #[test]
    fn communities_converge_to_min_labels() {
        let g = two_communities();
        let mut alg = LabelProp::new(DEFAULT_ROUNDS);
        let r = engine::run(&g, &mut alg, &EngineConfig::host_only(1)).unwrap();
        let labels = r.output.as_i32();
        // each triangle is internally uniform, and they stay distinct
        assert_eq!(labels[0], labels[1]);
        assert_eq!(labels[1], labels[2]);
        assert_eq!(labels[3], labels[4]);
        assert_eq!(labels[4], labels[5]);
        assert_ne!(labels[2], labels[3]);
    }

    #[test]
    fn min_label_tie_break_is_deterministic() {
        // a single undirected edge 0-1: each adopts the other's label and
        // oscillates; the fixed round cap terminates and every config
        // must land on the identical oscillation phase
        let mut el = EdgeList::new(2);
        el.push(0, 1);
        let g = CsrGraph::from_edge_list(&el);
        let mut a = LabelProp::new(3);
        let r1 = engine::run(&g, &mut a, &EngineConfig::host_only(1)).unwrap();
        let mut b = LabelProp::new(3);
        let cfg = EngineConfig::cpu_partitions(&[0.5, 0.5], Strategy::Rand);
        let r2 = engine::run(&g, &mut b, &cfg).unwrap();
        assert_eq!(r1.output.as_i32(), r2.output.as_i32());
        // 3 rounds: [1,0] -> [0,1] -> [1,0]
        assert_eq!(r1.output.as_i32(), &[1, 0]);
    }

    #[test]
    fn matches_baseline_on_rmat() {
        use crate::graph::generator::{rmat, RmatParams};
        let g = CsrGraph::from_edge_list(&rmat(&RmatParams::paper(7, 6)));
        let mut alg = LabelProp::new(DEFAULT_ROUNDS);
        let r = engine::run(&g, &mut alg, &EngineConfig::host_only(2)).unwrap();
        assert_eq!(r.output.as_i32(), crate::baseline::labelprop(&g, DEFAULT_ROUNDS).as_slice());
    }
}
