//! Breadth-First Search on the typed vertex-program surface (paper
//! Figure 11; DESIGN.md §8/§10).
//!
//! The program declares a single `levels` field on a push-min channel and
//! the [`Kernel::Traversal`] family; everything else — the top-down kernel
//! with the cache-resident visited bitmap (Chhugani et al. 2012; paper
//! §6.3.2), the bottom-up transpose sweep with early exit (Beamer et al.
//! 2012; Sallinen et al. 2015), frontier statistics for the α/β policy,
//! and bitmap rebuilds after α-controller migrations — is derived by the
//! [`ProgramDriver`]. The per-edge rule is one line: a frontier vertex at
//! level `cur` offers `cur + 1`.
//!
//! Bottom-up and top-down produce bit-identical levels, `changed` votes,
//! and superstep counts in every configuration (asserted by the golden
//! conformance suite); see the driver's kernel docs for the argument.

use super::program::{
    AccelSpec, CommDecl, CyclePlan, FieldId, FieldSpec, InitRow, Kernel, ProgramDriver,
    ProgramMeta, Role, Value, VertexProgram,
};
use super::{StepCtx, INF_I32};
use crate::engine::state::StateArray;
use crate::graph::CsrGraph;

/// BFS from a single source vertex (global id), as a vertex program.
pub struct BfsProgram {
    pub source: u32,
}

const LEVELS: FieldId = FieldId(0);

impl VertexProgram for BfsProgram {
    fn meta(&self) -> ProgramMeta {
        ProgramMeta {
            name: "bfs",
            needs_weights: false,
            undirected: false,
            reversed: false,
            fixed_rounds: None,
            output: LEVELS,
        }
    }

    fn schema(&self) -> Vec<FieldSpec> {
        vec![FieldSpec::i32("levels", Role::Device, INF_I32)]
    }

    fn plan(&self, _cycle: usize) -> CyclePlan {
        CyclePlan {
            kernel: Kernel::Traversal { level: LEVELS },
            comm: vec![CommDecl::PushMin(LEVELS)],
            device: None,
            accel: AccelSpec { name: "bfs", n_si32: 1, n_sf32: 0 },
        }
    }

    fn init_vertex(&self, global_id: u32, row: &mut InitRow<'_>) {
        if global_id == self.source {
            row.set_i32(LEVELS, 0);
        }
    }

    /// A frontier vertex at level `cur` offers `cur + 1` along every
    /// out-edge — the whole of BFS.
    fn edge_update(&self, _ctx: &StepCtx, src: Value, _w: f32) -> Option<Value> {
        Some(Value::I32(src.expect_i32() + 1))
    }

    fn scalars_i32(&self, ctx: &StepCtx) -> Vec<i32> {
        vec![ctx.superstep as i32]
    }

    /// Σ degree(v) over visited vertices (paper §5).
    fn traversed_edges(&self, output: &StateArray, g: &CsrGraph, _rounds: usize) -> u64 {
        output
            .as_i32()
            .iter()
            .enumerate()
            .filter(|(_, &l)| l != INF_I32)
            .map(|(v, _)| g.out_degree(v as u32))
            .sum()
    }
}

/// The engine-facing BFS algorithm: the program above behind the generic
/// driver. Every historical constructor and `Algorithm` behavior is
/// preserved.
pub type Bfs = ProgramDriver<BfsProgram>;

impl Bfs {
    pub fn new(source: u32) -> Bfs {
        ProgramDriver::build(BfsProgram { source }).expect("static schema is valid")
    }
}

/// Frontier density of a levels array — the whole-graph threshold form of
/// the per-element α/β policy (`engine::direction`); kept for the
/// `baseline::bfs_direction_optimized` comparison path and the ablation
/// bench.
pub fn frontier_density(levels: &[i32], cur: i32) -> f64 {
    let total = levels.len().max(1);
    let in_frontier = levels.iter().filter(|&&l| l == cur).count();
    in_frontier as f64 / total as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::alg::Algorithm;
    use crate::engine::{self, DirectionConfig, EngineConfig};
    use crate::graph::{CsrGraph, EdgeList};
    use crate::partition::Strategy;

    fn chain(n: usize) -> CsrGraph {
        let mut el = EdgeList::new(n);
        for i in 0..n - 1 {
            el.push(i as u32, i as u32 + 1);
        }
        CsrGraph::from_edge_list(&el)
    }

    /// α/β knobs that flip every CPU element to bottom-up on the first
    /// non-empty frontier and keep it there.
    fn force_pull() -> DirectionConfig {
        DirectionConfig { alpha: 1e12, beta: 1e12 }
    }

    #[test]
    fn single_partition_chain() {
        let g = chain(10);
        let mut alg = Bfs::new(0);
        let r = engine::run(&g, &mut alg, &EngineConfig::host_only(1)).unwrap();
        let levels = r.output.as_i32();
        for (v, &l) in levels.iter().enumerate() {
            assert_eq!(l, v as i32);
        }
    }

    #[test]
    fn two_cpu_partitions_match() {
        let g = chain(32);
        let mut a = Bfs::new(0);
        let r1 = engine::run(&g, &mut a, &EngineConfig::host_only(1)).unwrap();
        let mut b = Bfs::new(0);
        let cfg = EngineConfig::cpu_partitions(&[0.5, 0.5], Strategy::Rand);
        let r2 = engine::run(&g, &mut b, &cfg).unwrap();
        assert_eq!(r1.output.as_i32(), r2.output.as_i32());
    }

    #[test]
    fn unreachable_stays_inf() {
        let mut el = EdgeList::new(4);
        el.push(0, 1);
        // 2, 3 disconnected
        let g = CsrGraph::from_edge_list(&el);
        let mut alg = Bfs::new(0);
        let r = engine::run(&g, &mut alg, &EngineConfig::host_only(1)).unwrap();
        assert_eq!(r.output.as_i32(), &[0, 1, INF_I32, INF_I32]);
    }

    #[test]
    fn frontier_density_counts() {
        assert!((frontier_density(&[0, 1, 1, INF_I32], 1) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn pull_mode_chain_matches_push() {
        let g = chain(16);
        let mut push = Bfs::new(0);
        let r1 = engine::run(&g, &mut push, &EngineConfig::host_only(1)).unwrap();
        let mut pull = Bfs::new(0);
        let cfg = EngineConfig::host_only(1).with_direction(force_pull());
        let r2 = engine::run(&g, &mut pull, &cfg).unwrap();
        assert_eq!(r1.output.as_i32(), r2.output.as_i32());
        assert_eq!(r1.supersteps, r2.supersteps);
        assert!(r2.metrics.pull_steps() >= 1, "forced-pull run never pulled");
        assert_eq!(r1.metrics.pull_steps(), 0, "push-only run recorded a pull");
    }

    #[test]
    fn pull_mode_partitioned_bit_identical() {
        let g = crate::graph::generator::rmat(&crate::graph::generator::RmatParams::paper(8, 5));
        let g = CsrGraph::from_edge_list(&g);
        for strat in [Strategy::Rand, Strategy::High, Strategy::Low] {
            let mut push = Bfs::new(0);
            let base = EngineConfig::cpu_partitions(&[0.5, 0.5], strat);
            let r1 = engine::run(&g, &mut push, &base).unwrap();
            let mut pull = Bfs::new(0);
            let cfg = EngineConfig::cpu_partitions(&[0.5, 0.5], strat)
                .with_direction(force_pull());
            let r2 = engine::run(&g, &mut pull, &cfg).unwrap();
            assert_eq!(r1.output.as_i32(), r2.output.as_i32(), "{strat:?}");
            assert_eq!(r1.supersteps, r2.supersteps, "{strat:?}");
        }
    }

    #[test]
    fn frontier_stats_report_shape() {
        let g = chain(8);
        let mut alg = Bfs::new(0);
        // hand-build the single-partition state to probe stats directly
        let pg = crate::partition::PartitionedGraph::partition(
            &g,
            Strategy::Rand,
            &[1.0],
            1,
        );
        let st = alg.init_state(&pg, &pg.parts[0]);
        let s = alg.frontier_stats(&pg.parts[0], &st, 0).unwrap();
        assert_eq!(s.total_verts, 8);
        assert_eq!(s.frontier_verts, 1); // the source
        // the source's out-degree (local ids are degree-ordered, but
        // out-degree of the level-0 vertex is 1 in a chain)
        assert_eq!(s.frontier_edges, 1);
        assert_eq!(s.unexplored_verts, 7);
        assert_eq!(s.unexplored_edges, 6); // tail vertex has out-degree 0
    }

    #[test]
    fn driver_derives_the_bfs_contract() {
        let alg = Bfs::new(0);
        assert!(alg.supports_pull(), "Traversal programs derive a pull kernel");
        let spec = Algorithm::program(&alg, 0);
        assert_eq!(spec.name, "bfs");
        assert_eq!(spec.arrays, vec![0]);
        assert_eq!(spec.n_si32, 1);
        let ops = alg.channels(0);
        assert_eq!(ops.len(), 1);
        assert!(!ops[0].order_sensitive());
    }
}
