//! Breadth-First Search — the paper's level-synchronous kernel (Figure 11)
//! plus a direction-optimized bottom-up variant (DESIGN.md §8).
//!
//! **Top-down (push)**: per superstep `cur`, every vertex at level `cur`
//! relaxes its edges: unvisited local neighbors get level `cur+1`; remote
//! neighbors get a `min` into their ghost slot, which the communication
//! phase reduces into the owning partition (one message per unique remote
//! neighbor — §3.4).
//!
//! **Bottom-up (pull)**: when the engine's α/β policy flips this element
//! to `Direction::Pull` (Beamer et al. 2012; Sallinen et al. 2015 for the
//! hybrid setting), each *unexplored* local vertex probes its in-neighbors
//! through the partition's transpose CSR and adopts `cur+1` on the first
//! frontier parent — early exit instead of frontier expansion. Frontier
//! vertices still `min` `cur+1` into their boundary ghost slots (the tail
//! of their forward adjacency): remote partitions cannot probe this
//! element's levels, so cross-partition edges keep push semantics in both
//! directions. Discoveries, ghost-slot writes, and the `changed` vote are
//! exactly the push kernel's — levels are identical bits either way, which
//! is what lets the golden conformance suite compare the two byte-for-byte.
//!
//! The CPU kernel uses the cache-resident **visited bitmap** (Chhugani et
//! al. 2012; paper §6.3.2): a bit per local vertex answers "already has a
//! level?" without touching the 4-byte level entry. The bitmap is exactly
//! why the HIGH partitioning strategy super-linearly accelerates the CPU
//! side — fewer CPU vertices → the bitmap fits in LLC (Figure 12). The
//! bottom-up sweep reuses it as its frontier-membership filter.

use super::{AlgSpec, Algorithm, ComputeOut, EdgeOrientation, Pad, ProgramSpec, StepCtx, INF_I32};
use crate::engine::direction::{Direction, FrontierStats};
use crate::engine::state::{AlgState, Channel, CommOp, StateArray};
use crate::partition::{Partition, PartitionedGraph};
use crate::util::atomic::as_atomic_i32_cells;
use crate::util::threadpool::parallel_reduce;
use std::sync::atomic::{AtomicU64, Ordering};

/// BFS from a single source vertex (global id).
pub struct Bfs {
    pub source: u32,
}

impl Bfs {
    pub fn new(source: u32) -> Bfs {
        Bfs { source }
    }
}

const LEVELS: usize = 0;

impl Algorithm for Bfs {
    fn spec(&self) -> AlgSpec {
        AlgSpec {
            name: "bfs",
            needs_weights: false,
            undirected: false,
            reversed: false,
            fixed_rounds: None,
        }
    }

    fn init_state(&mut self, pg: &PartitionedGraph, part: &Partition) -> AlgState {
        let n = part.state_len();
        let mut levels = vec![INF_I32; n];
        if pg.part_of[self.source as usize] as usize == part.id {
            levels[pg.local_of[self.source as usize] as usize] = 0;
        }
        let mut st = AlgState::new(vec![StateArray::I32(levels)]);
        // visited bitmap over local vertices (the paper's summary structure)
        st.scratch = vec![0u64; part.nv.div_ceil(64).max(1)];
        if pg.part_of[self.source as usize] as usize == part.id {
            let l = pg.local_of[self.source as usize] as usize;
            st.scratch[l / 64] |= 1 << (l % 64);
        }
        st
    }

    fn channels(&self, _cycle: usize) -> Vec<CommOp> {
        vec![CommOp::Single(Channel::push_min_i32(LEVELS))]
    }

    fn program(&self, _cycle: usize) -> ProgramSpec {
        ProgramSpec {
            name: "bfs",
            arrays: vec![LEVELS],
            pads: vec![Pad::I32(INF_I32)],
            aux: vec![],
            needs_weights: false,
            n_si32: 1,
            n_sf32: 0,
            orientation: EdgeOrientation::Forward,
        }
    }

    fn scalars_i32(&self, ctx: &StepCtx) -> Vec<i32> {
        vec![ctx.superstep as i32]
    }

    /// After a migration the engine remapped `levels` onto the new
    /// partition; the visited bitmap is derived state — a bit is set iff
    /// the vertex already holds a level (claims only ever accompany a
    /// `fetch_min` to a finite level, so bit ⊆ finite always holds).
    fn rebuild_scratch(&self, part: &Partition, state: &mut AlgState) {
        let mut bitmap = vec![0u64; part.nv.div_ceil(64).max(1)];
        let levels = state.arrays[LEVELS].as_i32();
        for (v, &l) in levels.iter().take(part.nv).enumerate() {
            if l != INF_I32 {
                bitmap[v / 64] |= 1 << (v % 64);
            }
        }
        state.scratch = bitmap;
    }

    fn supports_pull(&self) -> bool {
        true
    }

    /// Frontier shape ahead of superstep `next_superstep`: one scan of the
    /// local levels counting the frontier (`level == cur`) and unexplored
    /// (`level == INF`) vertices with their out-degree sums — the `m_f` /
    /// `m_u` inputs of the α/β policy. `O(nv)` per superstep, dwarfed by
    /// the edge work it steers.
    fn frontier_stats(
        &self,
        part: &Partition,
        state: &AlgState,
        next_superstep: usize,
    ) -> Option<FrontierStats> {
        let cur = next_superstep as i32;
        let levels = state.arrays[LEVELS].as_i32();
        let ro = &part.csr.row_offsets;
        let mut s = FrontierStats { total_verts: part.nv as u64, ..Default::default() };
        for (v, &l) in levels.iter().take(part.nv).enumerate() {
            let deg = ro[v + 1] - ro[v];
            if l == cur {
                s.frontier_verts += 1;
                s.frontier_edges += deg;
            } else if l == INF_I32 {
                s.unexplored_verts += 1;
                s.unexplored_edges += deg;
            }
        }
        Some(s)
    }

    fn compute_cpu(&self, part: &Partition, state: &mut AlgState, ctx: &StepCtx) -> ComputeOut {
        match ctx.direction {
            Direction::Push => self.compute_push(part, state, ctx),
            Direction::Pull => self.compute_pull(part, state, ctx),
        }
    }
}

impl Bfs {
    /// Top-down kernel (Figure 11): the frontier expands its out-edges.
    fn compute_push(&self, part: &Partition, state: &mut AlgState, ctx: &StepCtx) -> ComputeOut {
        let cur = ctx.superstep as i32;
        let nv = part.nv;
        let (arrays, scratch) = (&mut state.arrays, &mut state.scratch);
        let levels = arrays[LEVELS].as_i32_mut();
        let cells = as_atomic_i32_cells(levels);
        // SAFETY: scratch is exclusively borrowed; AtomicU64 has the same
        // layout as u64.
        let bitmap: &[AtomicU64] = unsafe {
            std::slice::from_raw_parts(scratch.as_ptr() as *const AtomicU64, scratch.len())
        };

        let fold = |lo: usize, hi: usize, acc: (bool, u64, u64)| {
            let (mut changed, mut reads, mut writes) = acc;
            for v in lo..hi {
                if ctx.instrument {
                    reads += 1; // level[v]
                }
                if cells[v].load(Ordering::Relaxed) != cur {
                    continue;
                }
                for &t in part.targets(v as u32) {
                    let t = t as usize;
                    if t < nv {
                        // visited-bitmap fast path (Fig 11 lines 6-7)
                        if ctx.instrument {
                            reads += 1;
                        }
                        let bit = 1u64 << (t % 64);
                        if bitmap[t / 64].load(Ordering::Relaxed) & bit != 0 {
                            continue;
                        }
                        // claim the bit; the level write races benignly
                        // (all writers this superstep write cur+1).
                        let prev = bitmap[t / 64].fetch_or(bit, Ordering::Relaxed);
                        if prev & bit == 0 {
                            // might already hold a level delivered by the
                            // inbox (stale bitmap) — min keeps it correct.
                            cells[t].fetch_min(cur + 1, Ordering::Relaxed);
                            if ctx.instrument {
                                writes += 1;
                            }
                            changed = true;
                        }
                    } else {
                        // boundary edge: reduce into the ghost slot
                        let prev = cells[t].fetch_min(cur + 1, Ordering::Relaxed);
                        if ctx.instrument {
                            reads += 1;
                        }
                        if prev > cur + 1 {
                            if ctx.instrument {
                                writes += 1;
                            }
                            changed = true;
                        }
                    }
                }
            }
            (changed, reads, writes)
        };
        let (changed, reads, writes) = parallel_reduce(
            nv,
            ctx.threads,
            (false, 0u64, 0u64),
            fold,
            |a, b| (a.0 || b.0, a.1 + b.1, a.2 + b.2),
        );
        ComputeOut { changed, reads, writes }
    }

    /// Bottom-up kernel (DESIGN.md §8). One pass over the local vertices:
    ///
    /// - a **frontier** vertex (`level == cur`) relaxes only its boundary
    ///   tail (ghost slots) — its local out-neighbors are discovered from
    ///   the probe side instead;
    /// - an **unexplored** vertex probes its in-neighbors through the
    ///   transpose CSR and claims `cur + 1` on the first parent at `cur`,
    ///   then stops probing (the early exit that makes bottom-up win on
    ///   dense frontiers).
    ///
    /// A vertex is discovered here iff it has a frontier in-neighbor —
    /// exactly the push kernel's local-discovery set — and ghost slots
    /// receive the same `min(cur + 1)` writes, so levels, the `changed`
    /// vote, and the superstep count are bit-identical to push mode.
    fn compute_pull(&self, part: &Partition, state: &mut AlgState, ctx: &StepCtx) -> ComputeOut {
        let cur = ctx.superstep as i32;
        let nv = part.nv;
        let tr = part.transpose();
        let (arrays, scratch) = (&mut state.arrays, &mut state.scratch);
        let levels = arrays[LEVELS].as_i32_mut();
        let cells = as_atomic_i32_cells(levels);
        // SAFETY: scratch is exclusively borrowed; AtomicU64 has the same
        // layout as u64.
        let bitmap: &[AtomicU64] = unsafe {
            std::slice::from_raw_parts(scratch.as_ptr() as *const AtomicU64, scratch.len())
        };

        let fold = |lo: usize, hi: usize, acc: (bool, u64, u64)| {
            let (mut changed, mut reads, mut writes) = acc;
            for v in lo..hi {
                let lv = cells[v].load(Ordering::Relaxed);
                if ctx.instrument {
                    reads += 1; // level[v]
                }
                if lv == cur {
                    // frontier vertex: boundary edges keep push semantics
                    // (remote partitions cannot probe our levels).
                    let nl = part.csr.local_counts[v] as usize;
                    for &t in &part.targets(v as u32)[nl..] {
                        let prev = cells[t as usize].fetch_min(cur + 1, Ordering::Relaxed);
                        if ctx.instrument {
                            reads += 1;
                        }
                        if prev > cur + 1 {
                            if ctx.instrument {
                                writes += 1;
                            }
                            changed = true;
                        }
                    }
                    continue;
                }
                // unexplored vertex: probe in-neighbors, early-exit on the
                // first frontier parent. The bitmap check mirrors the push
                // kernel's claim protocol: a bit-set vertex is never
                // re-discovered, a bit-unset vertex with an inbox-delivered
                // level still gets the idempotent `min(cur + 1)`.
                //
                // Deliberate trade-off: an inbox-discovered vertex keeps
                // its bit unset until a local parent aligns with `cur`, so
                // sustained pull mode may re-scan its transpose row across
                // supersteps — the price of keeping the `changed` vote (and
                // therefore superstep counts) bit-identical to push mode,
                // whose claim protocol emits the same spurious first-claim
                // event. Marking bits on inbox delivery would need the comm
                // phase to know about algorithm-private scratch.
                let bit = 1u64 << (v % 64);
                if ctx.instrument {
                    reads += 1; // bitmap word
                }
                if bitmap[v / 64].load(Ordering::Relaxed) & bit != 0 {
                    continue;
                }
                for &u in tr.sources_of(v as u32) {
                    if ctx.instrument {
                        reads += 1; // level[u]
                    }
                    if cells[u as usize].load(Ordering::Relaxed) == cur {
                        bitmap[v / 64].fetch_or(bit, Ordering::Relaxed);
                        cells[v].fetch_min(cur + 1, Ordering::Relaxed);
                        if ctx.instrument {
                            writes += 1;
                        }
                        changed = true;
                        break;
                    }
                }
            }
            (changed, reads, writes)
        };
        let (changed, reads, writes) = parallel_reduce(
            nv,
            ctx.threads,
            (false, 0u64, 0u64),
            fold,
            |a, b| (a.0 || b.0, a.1 + b.1, a.2 + b.2),
        );
        ComputeOut { changed, reads, writes }
    }
}

/// Frontier density of a levels array — the whole-graph threshold form of
/// the per-element α/β policy (`engine::direction`); kept for the
/// `baseline::bfs_direction_optimized` comparison path and the ablation
/// bench.
pub fn frontier_density(levels: &[i32], cur: i32) -> f64 {
    let total = levels.len().max(1);
    let in_frontier = levels.iter().filter(|&&l| l == cur).count();
    in_frontier as f64 / total as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::{self, DirectionConfig, EngineConfig};
    use crate::graph::{CsrGraph, EdgeList};
    use crate::partition::Strategy;

    fn chain(n: usize) -> CsrGraph {
        let mut el = EdgeList::new(n);
        for i in 0..n - 1 {
            el.push(i as u32, i as u32 + 1);
        }
        CsrGraph::from_edge_list(&el)
    }

    /// α/β knobs that flip every CPU element to bottom-up on the first
    /// non-empty frontier and keep it there.
    fn force_pull() -> DirectionConfig {
        DirectionConfig { alpha: 1e12, beta: 1e12 }
    }

    #[test]
    fn single_partition_chain() {
        let g = chain(10);
        let mut alg = Bfs::new(0);
        let r = engine::run(&g, &mut alg, &EngineConfig::host_only(1)).unwrap();
        let levels = r.output.as_i32();
        for (v, &l) in levels.iter().enumerate() {
            assert_eq!(l, v as i32);
        }
    }

    #[test]
    fn two_cpu_partitions_match() {
        let g = chain(32);
        let mut a = Bfs::new(0);
        let r1 = engine::run(&g, &mut a, &EngineConfig::host_only(1)).unwrap();
        let mut b = Bfs::new(0);
        let cfg = EngineConfig::cpu_partitions(&[0.5, 0.5], Strategy::Rand);
        let r2 = engine::run(&g, &mut b, &cfg).unwrap();
        assert_eq!(r1.output.as_i32(), r2.output.as_i32());
    }

    #[test]
    fn unreachable_stays_inf() {
        let mut el = EdgeList::new(4);
        el.push(0, 1);
        // 2, 3 disconnected
        let g = CsrGraph::from_edge_list(&el);
        let mut alg = Bfs::new(0);
        let r = engine::run(&g, &mut alg, &EngineConfig::host_only(1)).unwrap();
        assert_eq!(r.output.as_i32(), &[0, 1, INF_I32, INF_I32]);
    }

    #[test]
    fn frontier_density_counts() {
        assert!((frontier_density(&[0, 1, 1, INF_I32], 1) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn pull_mode_chain_matches_push() {
        let g = chain(16);
        let mut push = Bfs::new(0);
        let r1 = engine::run(&g, &mut push, &EngineConfig::host_only(1)).unwrap();
        let mut pull = Bfs::new(0);
        let cfg = EngineConfig::host_only(1).with_direction(force_pull());
        let r2 = engine::run(&g, &mut pull, &cfg).unwrap();
        assert_eq!(r1.output.as_i32(), r2.output.as_i32());
        assert_eq!(r1.supersteps, r2.supersteps);
        assert!(r2.metrics.pull_steps() >= 1, "forced-pull run never pulled");
        assert_eq!(r1.metrics.pull_steps(), 0, "push-only run recorded a pull");
    }

    #[test]
    fn pull_mode_partitioned_bit_identical() {
        let g = crate::graph::generator::rmat(&crate::graph::generator::RmatParams::paper(8, 5));
        let g = CsrGraph::from_edge_list(&g);
        for strat in [Strategy::Rand, Strategy::High, Strategy::Low] {
            let mut push = Bfs::new(0);
            let base = EngineConfig::cpu_partitions(&[0.5, 0.5], strat);
            let r1 = engine::run(&g, &mut push, &base).unwrap();
            let mut pull = Bfs::new(0);
            let cfg = EngineConfig::cpu_partitions(&[0.5, 0.5], strat)
                .with_direction(force_pull());
            let r2 = engine::run(&g, &mut pull, &cfg).unwrap();
            assert_eq!(r1.output.as_i32(), r2.output.as_i32(), "{strat:?}");
            assert_eq!(r1.supersteps, r2.supersteps, "{strat:?}");
        }
    }

    #[test]
    fn frontier_stats_report_shape() {
        let g = chain(8);
        let mut alg = Bfs::new(0);
        // hand-build the single-partition state to probe stats directly
        let pg = crate::partition::PartitionedGraph::partition(
            &g,
            Strategy::Rand,
            &[1.0],
            1,
        );
        let st = alg.init_state(&pg, &pg.parts[0]);
        let s = alg.frontier_stats(&pg.parts[0], &st, 0).unwrap();
        assert_eq!(s.total_verts, 8);
        assert_eq!(s.frontier_verts, 1); // the source
        // the source's out-degree (local ids are degree-ordered, but
        // out-degree of the level-0 vertex is 1 in a chain)
        assert_eq!(s.frontier_edges, 1);
        assert_eq!(s.unexplored_verts, 7);
        assert_eq!(s.unexplored_edges, 6); // tail vertex has out-degree 0
    }
}
