//! Breadth-First Search — the paper's level-synchronous kernel (Figure 11).
//!
//! Per superstep `cur`, every vertex at level `cur` relaxes its edges:
//! unvisited local neighbors get level `cur+1`; remote neighbors get a
//! `min` into their ghost slot, which the communication phase reduces into
//! the owning partition (one message per unique remote neighbor — §3.4).
//!
//! The CPU kernel uses the cache-resident **visited bitmap** (Chhugani et
//! al. 2012; paper §6.3.2): a bit per local vertex answers "already has a
//! level?" without touching the 4-byte level entry. The bitmap is exactly
//! why the HIGH partitioning strategy super-linearly accelerates the CPU
//! side — fewer CPU vertices → the bitmap fits in LLC (Figure 12).

use super::{AlgSpec, Algorithm, ComputeOut, EdgeOrientation, Pad, ProgramSpec, StepCtx, INF_I32};
use crate::engine::state::{AlgState, Channel, CommOp, StateArray};
use crate::partition::{Partition, PartitionedGraph};
use crate::util::atomic::as_atomic_i32_cells;
use crate::util::threadpool::parallel_reduce;
use std::sync::atomic::{AtomicU64, Ordering};

/// BFS from a single source vertex (global id).
pub struct Bfs {
    pub source: u32,
}

impl Bfs {
    pub fn new(source: u32) -> Bfs {
        Bfs { source }
    }
}

const LEVELS: usize = 0;

impl Algorithm for Bfs {
    fn spec(&self) -> AlgSpec {
        AlgSpec {
            name: "bfs",
            needs_weights: false,
            undirected: false,
            reversed: false,
            fixed_rounds: None,
        }
    }

    fn init_state(&mut self, pg: &PartitionedGraph, part: &Partition) -> AlgState {
        let n = part.state_len();
        let mut levels = vec![INF_I32; n];
        if pg.part_of[self.source as usize] as usize == part.id {
            levels[pg.local_of[self.source as usize] as usize] = 0;
        }
        let mut st = AlgState::new(vec![StateArray::I32(levels)]);
        // visited bitmap over local vertices (the paper's summary structure)
        st.scratch = vec![0u64; part.nv.div_ceil(64).max(1)];
        if pg.part_of[self.source as usize] as usize == part.id {
            let l = pg.local_of[self.source as usize] as usize;
            st.scratch[l / 64] |= 1 << (l % 64);
        }
        st
    }

    fn channels(&self, _cycle: usize) -> Vec<CommOp> {
        vec![CommOp::Single(Channel::push_min_i32(LEVELS))]
    }

    fn program(&self, _cycle: usize) -> ProgramSpec {
        ProgramSpec {
            name: "bfs",
            arrays: vec![LEVELS],
            pads: vec![Pad::I32(INF_I32)],
            aux: vec![],
            needs_weights: false,
            n_si32: 1,
            n_sf32: 0,
            orientation: EdgeOrientation::Forward,
        }
    }

    fn scalars_i32(&self, ctx: &StepCtx) -> Vec<i32> {
        vec![ctx.superstep as i32]
    }

    /// After a migration the engine remapped `levels` onto the new
    /// partition; the visited bitmap is derived state — a bit is set iff
    /// the vertex already holds a level (claims only ever accompany a
    /// `fetch_min` to a finite level, so bit ⊆ finite always holds).
    fn rebuild_scratch(&self, part: &Partition, state: &mut AlgState) {
        let mut bitmap = vec![0u64; part.nv.div_ceil(64).max(1)];
        let levels = state.arrays[LEVELS].as_i32();
        for (v, &l) in levels.iter().take(part.nv).enumerate() {
            if l != INF_I32 {
                bitmap[v / 64] |= 1 << (v % 64);
            }
        }
        state.scratch = bitmap;
    }

    fn compute_cpu(&self, part: &Partition, state: &mut AlgState, ctx: &StepCtx) -> ComputeOut {
        let cur = ctx.superstep as i32;
        let nv = part.nv;
        let (arrays, scratch) = (&mut state.arrays, &mut state.scratch);
        let levels = arrays[LEVELS].as_i32_mut();
        let cells = as_atomic_i32_cells(levels);
        // SAFETY: scratch is exclusively borrowed; AtomicU64 has the same
        // layout as u64.
        let bitmap: &[AtomicU64] = unsafe {
            std::slice::from_raw_parts(scratch.as_ptr() as *const AtomicU64, scratch.len())
        };

        let fold = |lo: usize, hi: usize, acc: (bool, u64, u64)| {
            let (mut changed, mut reads, mut writes) = acc;
            for v in lo..hi {
                if ctx.instrument {
                    reads += 1; // level[v]
                }
                if cells[v].load(Ordering::Relaxed) != cur {
                    continue;
                }
                for &t in part.targets(v as u32) {
                    let t = t as usize;
                    if t < nv {
                        // visited-bitmap fast path (Fig 11 lines 6-7)
                        if ctx.instrument {
                            reads += 1;
                        }
                        let bit = 1u64 << (t % 64);
                        if bitmap[t / 64].load(Ordering::Relaxed) & bit != 0 {
                            continue;
                        }
                        // claim the bit; the level write races benignly
                        // (all writers this superstep write cur+1).
                        let prev = bitmap[t / 64].fetch_or(bit, Ordering::Relaxed);
                        if prev & bit == 0 {
                            // might already hold a level delivered by the
                            // inbox (stale bitmap) — min keeps it correct.
                            cells[t].fetch_min(cur + 1, Ordering::Relaxed);
                            if ctx.instrument {
                                writes += 1;
                            }
                            changed = true;
                        }
                    } else {
                        // boundary edge: reduce into the ghost slot
                        let prev = cells[t].fetch_min(cur + 1, Ordering::Relaxed);
                        if ctx.instrument {
                            reads += 1;
                        }
                        if prev > cur + 1 {
                            if ctx.instrument {
                                writes += 1;
                            }
                            changed = true;
                        }
                    }
                }
            }
            (changed, reads, writes)
        };
        let (changed, reads, writes) = parallel_reduce(
            nv,
            ctx.threads,
            (false, 0u64, 0u64),
            fold,
            |a, b| (a.0 || b.0, a.1 + b.1, a.2 + b.2),
        );
        ComputeOut { changed, reads, writes }
    }
}

/// Direction-optimized BFS variant (Beamer et al. 2013; paper §10): when
/// the frontier is large, switch from top-down edge expansion to a
/// bottom-up sweep where unvisited vertices probe their *incoming*
/// neighbors. Ablation bench `bench ablation_dobfs`. CPU-only partitions:
/// the bottom-up sweep needs the reverse adjacency, so this variant keeps
/// a reversed copy and is exposed as a standalone whole-graph routine in
/// `baseline`; inside the hybrid engine the standard top-down kernel is
/// used (as in the paper's headline results, §8).
pub fn frontier_density(levels: &[i32], cur: i32) -> f64 {
    let total = levels.len().max(1);
    let in_frontier = levels.iter().filter(|&&l| l == cur).count();
    in_frontier as f64 / total as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::{self, EngineConfig};
    use crate::graph::{CsrGraph, EdgeList};
    use crate::partition::Strategy;

    fn chain(n: usize) -> CsrGraph {
        let mut el = EdgeList::new(n);
        for i in 0..n - 1 {
            el.push(i as u32, i as u32 + 1);
        }
        CsrGraph::from_edge_list(&el)
    }

    #[test]
    fn single_partition_chain() {
        let g = chain(10);
        let mut alg = Bfs::new(0);
        let r = engine::run(&g, &mut alg, &EngineConfig::host_only(1)).unwrap();
        let levels = r.output.as_i32();
        for (v, &l) in levels.iter().enumerate() {
            assert_eq!(l, v as i32);
        }
    }

    #[test]
    fn two_cpu_partitions_match() {
        let g = chain(32);
        let mut a = Bfs::new(0);
        let r1 = engine::run(&g, &mut a, &EngineConfig::host_only(1)).unwrap();
        let mut b = Bfs::new(0);
        let cfg = EngineConfig::cpu_partitions(&[0.5, 0.5], Strategy::Rand);
        let r2 = engine::run(&g, &mut b, &cfg).unwrap();
        assert_eq!(r1.output.as_i32(), r2.output.as_i32());
    }

    #[test]
    fn unreachable_stays_inf() {
        let mut el = EdgeList::new(4);
        el.push(0, 1);
        // 2, 3 disconnected
        let g = CsrGraph::from_edge_list(&el);
        let mut alg = Bfs::new(0);
        let r = engine::run(&g, &mut alg, &EngineConfig::host_only(1)).unwrap();
        assert_eq!(r.output.as_i32(), &[0, 1, INF_I32, INF_I32]);
    }

    #[test]
    fn frontier_density_counts() {
        assert!((frontier_density(&[0, 1, 1, INF_I32], 1) - 0.5).abs() < 1e-12);
    }
}
