//! Betweenness Centrality — Brandes' algorithm (paper §7.2, Figure 18) on
//! the typed vertex-program surface. Two BSP cycles:
//!
//! **Forward** (cycle 0): [`Kernel::TraversalSigma`] — a level-synchronous
//! BFS that also counts shortest paths. `dist` propagates with `min`;
//! `numsp` (σ) accumulates with `add`. The two travel as a *paired*
//! message ([`CommDecl::DistSigma`]): a σ contribution applies only when
//! the accompanying level matches the receiver's final level — exactly the
//! `dist[nbr] == level + 1` guard in Figure 18 line 11, enforced across
//! the partition boundary. The forward cycle ships only `[dist, numsp]`
//! to the accelerator (the plan's `device` narrowing).
//!
//! **Backward** (cycle 1): [`Kernel::Gather`] in decreasing level order.
//! Instead of pulling `delta` and `numsp` separately, each processed level
//! publishes `ratio[v] = (1 + δ(v)) / σ(v)` (zero everywhere else), so a
//! successor's full term `σ(v)/σ(w) · (1+δ(w))` becomes `σ(v) · ratio[w]`
//! — one pulled value per unique remote neighbor, the paper's two-way
//! communication (§4.3.2) with reduction. The driver's `skip_superstep`
//! hook guards `current_level < 1`: dependency accumulation runs over the
//! *intermediate* levels only — the source must never be credited with
//! its own shortest paths (the `max_level <= 1` no-op found by ISSUE 4's
//! differential fuzz).
//!
//! Single-source, like the paper's Table 4 measurements. TEPS counts
//! forward + backward traversals (×2, §5).

use super::program::{
    AccelSpec, Activation, CommDecl, CyclePlan, FieldId, Fields, FieldSpec, InitRow, Kernel,
    ProgramDriver, ProgramMeta, Role, VertexProgram,
};
use super::{StepCtx, INF_I32};
use crate::engine::state::{AlgState, StateArray};
use crate::graph::CsrGraph;
use crate::partition::PartitionedGraph;

/// Betweenness centrality, as a vertex program.
pub struct BcProgram {
    pub source: u32,
    /// Maximum finite BFS level, computed between cycles.
    max_level: i32,
}

const DIST: FieldId = FieldId(0);
const NUMSP: FieldId = FieldId(1);
const DELTA: FieldId = FieldId(2);
const BC: FieldId = FieldId(3);
const RATIO: FieldId = FieldId(4);

impl VertexProgram for BcProgram {
    fn meta(&self) -> ProgramMeta {
        ProgramMeta {
            name: "bc",
            needs_weights: false,
            undirected: false,
            reversed: false,
            fixed_rounds: None,
            output: BC,
        }
    }

    fn cycles(&self) -> usize {
        2
    }

    fn schema(&self) -> Vec<FieldSpec> {
        vec![
            FieldSpec::i32("dist", Role::Device, INF_I32),
            FieldSpec::f32("numsp", Role::Device, 0.0),
            FieldSpec::f32("delta", Role::Device, 0.0),
            FieldSpec::f32("bc", Role::Device, 0.0),
            FieldSpec::f32("ratio", Role::Device, 0.0),
        ]
    }

    fn plan(&self, cycle: usize) -> CyclePlan {
        if cycle == 0 {
            CyclePlan {
                kernel: Kernel::TraversalSigma { dist: DIST, sigma: NUMSP },
                comm: vec![CommDecl::DistSigma { dist: DIST, sigma: NUMSP }],
                // forward only needs the traversal pair on the device
                device: Some(vec![DIST, NUMSP]),
                accel: AccelSpec { name: "bc_fwd", n_si32: 1, n_sf32: 0 },
            }
        } else {
            CyclePlan {
                kernel: Kernel::Gather { src: RATIO, active: Activation::LevelEquals(DIST) },
                // backward pulls the final levels and the published ratios
                comm: vec![CommDecl::Pull(DIST), CommDecl::Pull(RATIO)],
                device: None,
                accel: AccelSpec { name: "bc_bwd", n_si32: 1, n_sf32: 0 },
            }
        }
    }

    fn init_vertex(&self, global_id: u32, row: &mut InitRow<'_>) {
        if global_id == self.source {
            row.set_i32(DIST, 0);
            row.set_f32(NUMSP, 1.0);
        }
    }

    fn begin_cycle(&mut self, cycle: usize, pg: &PartitionedGraph, states: &mut [AlgState]) {
        if cycle != 1 {
            return;
        }
        // max finite level across all real vertices
        let mut max_level = 0i32;
        for (p, st) in pg.parts.iter().zip(states.iter()) {
            let dist = st.arrays[DIST.0].as_i32();
            for v in 0..p.nv {
                if dist[v] != INF_I32 {
                    max_level = max_level.max(dist[v]);
                }
            }
        }
        self.max_level = max_level;
        // seed ratio for the deepest level: δ = 0 there, so
        // ratio = 1/σ. All other slots zero.
        for (p, st) in pg.parts.iter().zip(states.iter_mut()) {
            let (head, tail) = st.arrays.split_at_mut(RATIO.0);
            let dist = head[DIST.0].as_i32();
            let numsp = head[NUMSP.0].as_f32();
            let ratio = tail[0].as_f32_mut();
            ratio.fill(0.0);
            for v in 0..p.nv {
                if dist[v] == max_level && numsp[v] > 0.0 {
                    ratio[v] = 1.0 / numsp[v];
                }
            }
        }
    }

    /// Forward counts up; backward counts down over the intermediate
    /// levels `max_level-1 .. 1`.
    fn current_level(&self, ctx: &StepCtx) -> i32 {
        if ctx.cycle == 0 {
            ctx.superstep as i32
        } else {
            self.max_level - 1 - ctx.superstep as i32
        }
    }

    /// The engine mandates one superstep per cycle; when `max_level <= 1`
    /// that superstep would land on `current_level <= 0` — make it a no-op
    /// instead of crediting the source with its own shortest paths.
    fn skip_superstep(&self, ctx: &StepCtx) -> bool {
        ctx.cycle == 1 && self.current_level(ctx) < 1
    }

    /// δ and centrality for a vertex at the current level (Fig 18
    /// backwardPropagation): `δ(v) = σ(v) · Σ ratio[succ]`, `bc += δ`.
    fn gather_apply(&self, _ctx: &StepCtx, v: usize, f: &Fields<'_>, sum: f32) -> u64 {
        let delta = f.f32(NUMSP, v) * sum;
        f.set_f32(DELTA, v, delta);
        f.set_f32(BC, v, f.f32(BC, v) + delta);
        2
    }

    /// Publish this level's ratios, zero everything else so stale
    /// deeper-level ratios can't leak into the next superstep.
    fn publish(&self, ctx: &StepCtx, v: usize, f: &Fields<'_>) {
        let cur = self.current_level(ctx);
        let r = if f.i32(DIST, v) == cur && f.f32(NUMSP, v) > 0.0 {
            (1.0 + f.f32(DELTA, v)) / f.f32(NUMSP, v)
        } else {
            0.0
        };
        f.set_f32(RATIO, v, r);
    }

    fn cycle_done(&self, cycle: usize, next_superstep: usize, any_changed: bool) -> Option<bool> {
        Some(if cycle == 0 {
            !any_changed
        } else {
            // levels max_level-1 .. 1; engine always runs ≥ 1 superstep
            next_superstep as i64 >= (self.max_level as i64 - 1).max(1)
        })
    }

    fn scalars_i32(&self, ctx: &StepCtx) -> Vec<i32> {
        vec![self.current_level(ctx)]
    }

    /// 2 × Σ degree(v) over vertices with non-zero score (fwd + bwd, §5).
    fn traversed_edges(&self, output: &StateArray, g: &CsrGraph, _rounds: usize) -> u64 {
        2 * output
            .as_f32()
            .iter()
            .enumerate()
            .filter(|(_, &s)| s > 0.0)
            .map(|(v, _)| g.out_degree(v as u32))
            .sum::<u64>()
    }
}

/// The engine-facing BC algorithm.
pub type Bc = ProgramDriver<BcProgram>;

impl Bc {
    pub fn new(source: u32) -> Bc {
        ProgramDriver::build(BcProgram { source, max_level: 0 }).expect("static schema is valid")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::{self, EngineConfig};
    use crate::graph::{CsrGraph, EdgeList};
    use crate::partition::Strategy;

    /// Path 0->1->2->3: vertex 1 lies on paths 0→{2,3}, vertex 2 on 0→3.
    fn path4() -> CsrGraph {
        let mut el = EdgeList::new(4);
        el.push(0, 1);
        el.push(1, 2);
        el.push(2, 3);
        CsrGraph::from_edge_list(&el)
    }

    #[test]
    fn path_centrality_host() {
        let g = path4();
        let mut alg = Bc::new(0);
        let r = engine::run(&g, &mut alg, &EngineConfig::host_only(1)).unwrap();
        // δ(3)=0; δ(2)=σ2/σ3(1+0)=1; δ(1)=σ1/σ2(1+1)=2; bc=δ per vertex
        assert_eq!(r.output.as_f32(), &[0.0, 2.0, 1.0, 0.0]);
    }

    #[test]
    fn diamond_split_paths() {
        // 0->1->3, 0->2->3 : two shortest paths to 3, each middle carries ½.
        let mut el = EdgeList::new(4);
        el.push(0, 1);
        el.push(0, 2);
        el.push(1, 3);
        el.push(2, 3);
        let g = CsrGraph::from_edge_list(&el);
        let mut alg = Bc::new(0);
        let r = engine::run(&g, &mut alg, &EngineConfig::host_only(1)).unwrap();
        assert_eq!(r.output.as_f32(), &[0.0, 0.5, 0.5, 0.0]);
    }

    #[test]
    fn partitioned_matches_host() {
        let g = path4();
        let mut a = Bc::new(0);
        let r1 = engine::run(&g, &mut a, &EngineConfig::host_only(1)).unwrap();
        for strat in [Strategy::Rand, Strategy::High, Strategy::Low] {
            let mut b = Bc::new(0);
            let cfg = EngineConfig::cpu_partitions(&[0.5, 0.5], strat);
            let r2 = engine::run(&g, &mut b, &cfg).unwrap();
            for (x, y) in r1.output.as_f32().iter().zip(r2.output.as_f32()) {
                assert!((x - y).abs() < 1e-5, "{strat:?}: {x} vs {y}");
            }
        }
    }

    #[test]
    fn star_hub_source_keeps_zero_centrality() {
        // max_level == 1: the backward cycle's mandatory superstep lands on
        // current_level == 0 and must be a no-op — the source is not an
        // intermediate vertex of its own shortest paths. (Latent engine bug
        // found by the differential-fuzz pass of ISSUE 4: bc[hub] came out
        // as 7.0; now guarded generically by `skip_superstep`.)
        let mut el = EdgeList::new(8);
        for i in 1..8 {
            el.push(0, i);
            el.push(i, 0);
        }
        let g = CsrGraph::from_edge_list(&el);
        let mut alg = Bc::new(0);
        let r = engine::run(&g, &mut alg, &EngineConfig::host_only(1)).unwrap();
        assert_eq!(r.output.as_f32(), &[0.0; 8]);
        // and partitioned, where the backward superstep still runs per part
        let mut alg = Bc::new(0);
        let cfg = EngineConfig::cpu_partitions(&[0.5, 0.5], Strategy::Rand);
        let r = engine::run(&g, &mut alg, &cfg).unwrap();
        assert_eq!(r.output.as_f32(), &[0.0; 8]);
    }

    #[test]
    fn isolated_source() {
        let mut el = EdgeList::new(3);
        el.push(1, 2);
        let g = CsrGraph::from_edge_list(&el);
        let mut alg = Bc::new(0);
        let r = engine::run(&g, &mut alg, &EngineConfig::host_only(1)).unwrap();
        assert_eq!(r.output.as_f32(), &[0.0, 0.0, 0.0]);
    }

    #[test]
    fn forward_cycle_ships_only_the_traversal_pair() {
        use crate::alg::Algorithm;
        let alg = Bc::new(0);
        let fwd = Algorithm::program(&alg, 0);
        assert_eq!(fwd.name, "bc_fwd");
        assert_eq!(fwd.arrays, vec![0, 1], "device narrowing");
        let bwd = Algorithm::program(&alg, 1);
        assert_eq!(bwd.name, "bc_bwd");
        assert_eq!(bwd.arrays, vec![0, 1, 2, 3, 4]);
        assert!(alg.channels(0).iter().any(|op| op.order_sensitive()));
        assert!(alg.channels(1).iter().all(|op| !op.order_sensitive()));
    }
}
