//! Betweenness Centrality — Brandes' algorithm (paper §7.2, Figure 18).
//!
//! Two BSP cycles:
//!
//! **Forward** (cycle 0): a level-synchronous BFS that also counts
//! shortest paths. `dist` propagates with `min`; `numsp` (σ) accumulates
//! with `add`. The two travel as a *paired* message
//! ([`CommOp::DistSigma`]): a σ contribution applies only when the
//! accompanying level matches the receiver's final level — exactly the
//! `dist[nbr] == level + 1` guard in Figure 18 line 11, enforced across
//! the partition boundary.
//!
//! **Backward** (cycle 1): dependency accumulation in decreasing level
//! order. Instead of pulling `delta` and `numsp` separately, each
//! processed level publishes `ratio[v] = (1 + δ(v)) / σ(v)` (zero
//! everywhere else), so a successor's full term `σ(v)/σ(w) · (1+δ(w))`
//! becomes `σ(v) · ratio[w]` — one pulled value per unique remote
//! neighbor, the paper's two-way communication (§4.3.2) with reduction.
//!
//! Single-source, like the paper's Table 4 measurements. TEPS counts
//! forward + backward traversals (×2, §5).

use super::{AlgSpec, Algorithm, ComputeOut, EdgeOrientation, Pad, ProgramSpec, StepCtx, INF_I32};
use crate::engine::state::{AlgState, Channel, CommOp, StateArray};
use crate::partition::{Partition, PartitionedGraph};
use crate::util::atomic::{as_atomic_f32_cells, as_atomic_i32_cells, atomic_add_f32};
use crate::util::threadpool::parallel_reduce;
use std::sync::atomic::Ordering;

pub struct Bc {
    pub source: u32,
    /// Maximum finite BFS level, computed between cycles.
    max_level: i32,
}

impl Bc {
    pub fn new(source: u32) -> Bc {
        Bc { source, max_level: 0 }
    }
}

const DIST: usize = 0;
const NUMSP: usize = 1;
const DELTA: usize = 2;
const BC: usize = 3;
const RATIO: usize = 4;

impl Algorithm for Bc {
    fn spec(&self) -> AlgSpec {
        AlgSpec {
            name: "bc",
            needs_weights: false,
            undirected: false,
            reversed: false,
            fixed_rounds: None,
        }
    }

    fn cycles(&self) -> usize {
        2
    }

    fn init_state(&mut self, pg: &PartitionedGraph, part: &Partition) -> AlgState {
        let n = part.state_len();
        let mut dist = vec![INF_I32; n];
        let mut numsp = vec![0f32; n];
        if pg.part_of[self.source as usize] as usize == part.id {
            let l = pg.local_of[self.source as usize] as usize;
            dist[l] = 0;
            numsp[l] = 1.0;
        }
        AlgState::new(vec![
            StateArray::I32(dist),
            StateArray::F32(numsp),
            StateArray::F32(vec![0f32; n]), // delta
            StateArray::F32(vec![0f32; n]), // bc
            StateArray::F32(vec![0f32; n]), // ratio
        ])
    }

    fn begin_cycle(&mut self, cycle: usize, pg: &PartitionedGraph, states: &mut [AlgState]) {
        if cycle != 1 {
            return;
        }
        // max finite level across all real vertices
        let mut max_level = 0i32;
        for (p, st) in pg.parts.iter().zip(states.iter()) {
            let dist = st.arrays[DIST].as_i32();
            for v in 0..p.nv {
                if dist[v] != INF_I32 {
                    max_level = max_level.max(dist[v]);
                }
            }
        }
        self.max_level = max_level;
        // seed ratio for the deepest level: δ = 0 there, so
        // ratio = 1/σ. All other slots zero.
        for (p, st) in pg.parts.iter().zip(states.iter_mut()) {
            let (head, tail) = st.arrays.split_at_mut(RATIO);
            let dist = head[DIST].as_i32();
            let numsp = head[NUMSP].as_f32();
            let ratio = tail[0].as_f32_mut();
            ratio.fill(0.0);
            for v in 0..p.nv {
                if dist[v] == max_level && numsp[v] > 0.0 {
                    ratio[v] = 1.0 / numsp[v];
                }
            }
        }
    }

    fn channels(&self, cycle: usize) -> Vec<CommOp> {
        if cycle == 0 {
            vec![CommOp::DistSigma { dist: DIST, sigma: NUMSP }]
        } else {
            // backward pulls the final levels and the published ratios
            vec![
                CommOp::Single(Channel::pull_i32(DIST)),
                CommOp::Single(Channel::pull_f32(RATIO)),
            ]
        }
    }

    fn program(&self, cycle: usize) -> ProgramSpec {
        if cycle == 0 {
            ProgramSpec {
                name: "bc_fwd",
                arrays: vec![DIST, NUMSP],
                pads: vec![Pad::I32(INF_I32), Pad::F32(0.0)],
                aux: vec![],
                needs_weights: false,
                n_si32: 1,
                n_sf32: 0,
                orientation: EdgeOrientation::Forward,
            }
        } else {
            ProgramSpec {
                name: "bc_bwd",
                arrays: vec![DIST, NUMSP, DELTA, BC, RATIO],
                pads: vec![
                    Pad::I32(INF_I32),
                    Pad::F32(0.0),
                    Pad::F32(0.0),
                    Pad::F32(0.0),
                    Pad::F32(0.0),
                ],
                aux: vec![],
                needs_weights: false,
                n_si32: 1,
                n_sf32: 0,
                orientation: EdgeOrientation::Forward,
            }
        }
    }

    fn scalars_i32(&self, ctx: &StepCtx) -> Vec<i32> {
        if ctx.cycle == 0 {
            vec![ctx.superstep as i32]
        } else {
            vec![self.max_level - 1 - ctx.superstep as i32]
        }
    }

    fn cycle_done(&self, cycle: usize, next_superstep: usize, any_changed: bool) -> bool {
        if cycle == 0 {
            !any_changed
        } else {
            // levels max_level-1 .. 1; engine always runs ≥ 1 superstep
            next_superstep as i64 >= (self.max_level as i64 - 1).max(1)
        }
    }

    fn compute_cpu(&self, part: &Partition, state: &mut AlgState, ctx: &StepCtx) -> ComputeOut {
        if ctx.cycle == 0 {
            self.forward_cpu(part, state, ctx)
        } else {
            self.backward_cpu(part, state, ctx)
        }
    }

    fn output_array(&self) -> usize {
        BC
    }
}

impl Bc {
    /// Figure 18 forwardPropagation.
    fn forward_cpu(&self, part: &Partition, state: &mut AlgState, ctx: &StepCtx) -> ComputeOut {
        let cur = ctx.superstep as i32;
        let (dist_arr, rest) = state.arrays.split_at_mut(NUMSP);
        let dist_cells = as_atomic_i32_cells(dist_arr[DIST].as_i32_mut());
        let numsp_cells = as_atomic_f32_cells(rest[0].as_f32_mut());

        // Frontier scan in canonical (ascending global id) order: within a
        // superstep the σ adds write only level-(cur+1) cells and read only
        // settled level-cur values, so the scan order is observable *only*
        // through the f32 add order into each target — canonical iteration
        // makes that order placement-invariant (DESIGN.md §9).
        let canon = &part.canonical_order;
        let fold = |lo: usize, hi: usize, acc: (bool, u64, u64)| {
            let (mut changed, mut reads, mut writes) = acc;
            for i in lo..hi {
                let v = canon[i] as usize;
                if ctx.instrument {
                    reads += 1;
                }
                if dist_cells[v].load(Ordering::Relaxed) != cur {
                    continue;
                }
                let v_numsp = f32::from_bits(numsp_cells[v].load(Ordering::Relaxed));
                if ctx.instrument {
                    reads += 1;
                }
                for &t in part.targets(v as u32) {
                    let t = t as usize;
                    // discover (Fig 18 lines 7-9): settle the level
                    let prev = dist_cells[t].fetch_min(cur + 1, Ordering::Relaxed);
                    if prev > cur + 1 {
                        changed = true;
                        if ctx.instrument {
                            writes += 1;
                        }
                    }
                    if ctx.instrument {
                        reads += 1;
                    }
                    // accumulate σ (Fig 18 lines 11-12): only into
                    // vertices/slots settled exactly one level deeper.
                    // Within a superstep all writers write cur+1, so the
                    // re-read is stable.
                    if dist_cells[t].load(Ordering::Relaxed) == cur + 1 {
                        atomic_add_f32(&numsp_cells[t], v_numsp);
                        changed = true;
                        if ctx.instrument {
                            writes += 1;
                        }
                    }
                }
            }
            (changed, reads, writes)
        };
        let (changed, reads, writes) = parallel_reduce(
            part.nv,
            ctx.threads,
            (false, 0u64, 0u64),
            fold,
            |a, b| (a.0 || b.0, a.1 + b.1, a.2 + b.2),
        );
        ComputeOut { changed, reads, writes }
    }

    /// Figure 18 backwardPropagation, with the published-ratio formulation.
    fn backward_cpu(&self, part: &Partition, state: &mut AlgState, ctx: &StepCtx) -> ComputeOut {
        let cur = self.max_level - 1 - ctx.superstep as i32;
        // Dependency accumulation runs over the *intermediate* levels
        // `max_level-1 .. 1` only — Brandes sums δ over w ≠ s, so level 0
        // (the source) must never accumulate. The engine still mandates
        // one superstep per cycle, and when `max_level <= 1` (e.g. a star
        // probed from its hub, or an isolated source) that superstep would
        // land on `cur <= 0`: make it a no-op instead of crediting the
        // source with its own shortest paths.
        if cur < 1 {
            return ComputeOut { changed: true, reads: 0, writes: 0 };
        }
        let nv = part.nv;
        let mut reads = 0u64;
        let mut writes = 0u64;

        // Phase A: δ and centrality for vertices at level `cur`.
        {
            let (head, tail) = state.arrays.split_at_mut(DELTA);
            let dist = head[DIST].as_i32();
            let numsp = head[NUMSP].as_f32();
            let (delta_arr, tail2) = tail.split_at_mut(1);
            let delta = delta_arr[0].as_f32_mut();
            let (bc_arr, ratio_arr) = tail2.split_at_mut(1);
            let bc = bc_arr[0].as_f32_mut();
            let ratio = ratio_arr[0].as_f32();
            for v in 0..nv {
                if dist[v] != cur {
                    continue;
                }
                let mut sum = 0f32;
                for &t in part.targets(v as u32) {
                    sum += ratio[t as usize];
                }
                if ctx.instrument {
                    reads += 1 + part.targets(v as u32).len() as u64;
                    writes += 2;
                }
                delta[v] = numsp[v] * sum;
                bc[v] += delta[v];
            }
        }

        // Phase B: publish this level's ratios, zero everything else so
        // stale deeper-level ratios can't leak into the next superstep.
        {
            let (head, tail) = state.arrays.split_at_mut(RATIO);
            let dist = head[DIST].as_i32();
            let numsp = head[NUMSP].as_f32();
            let delta = head[DELTA].as_f32();
            let ratio = tail[0].as_f32_mut();
            for v in 0..nv {
                ratio[v] = if dist[v] == cur && numsp[v] > 0.0 {
                    (1.0 + delta[v]) / numsp[v]
                } else {
                    0.0
                };
            }
            if ctx.instrument {
                writes += nv as u64;
            }
        }
        ComputeOut { changed: true, reads, writes }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::{self, EngineConfig};
    use crate::graph::{CsrGraph, EdgeList};
    use crate::partition::Strategy;

    /// Path 0->1->2->3: vertex 1 lies on paths 0→{2,3}, vertex 2 on 0→3.
    fn path4() -> CsrGraph {
        let mut el = EdgeList::new(4);
        el.push(0, 1);
        el.push(1, 2);
        el.push(2, 3);
        CsrGraph::from_edge_list(&el)
    }

    #[test]
    fn path_centrality_host() {
        let g = path4();
        let mut alg = Bc::new(0);
        let r = engine::run(&g, &mut alg, &EngineConfig::host_only(1)).unwrap();
        // δ(3)=0; δ(2)=σ2/σ3(1+0)=1; δ(1)=σ1/σ2(1+1)=2; bc=δ per vertex
        assert_eq!(r.output.as_f32(), &[0.0, 2.0, 1.0, 0.0]);
    }

    #[test]
    fn diamond_split_paths() {
        // 0->1->3, 0->2->3 : two shortest paths to 3, each middle carries ½.
        let mut el = EdgeList::new(4);
        el.push(0, 1);
        el.push(0, 2);
        el.push(1, 3);
        el.push(2, 3);
        let g = CsrGraph::from_edge_list(&el);
        let mut alg = Bc::new(0);
        let r = engine::run(&g, &mut alg, &EngineConfig::host_only(1)).unwrap();
        assert_eq!(r.output.as_f32(), &[0.0, 0.5, 0.5, 0.0]);
    }

    #[test]
    fn partitioned_matches_host() {
        let g = path4();
        let mut a = Bc::new(0);
        let r1 = engine::run(&g, &mut a, &EngineConfig::host_only(1)).unwrap();
        for strat in [Strategy::Rand, Strategy::High, Strategy::Low] {
            let mut b = Bc::new(0);
            let cfg = EngineConfig::cpu_partitions(&[0.5, 0.5], strat);
            let r2 = engine::run(&g, &mut b, &cfg).unwrap();
            for (x, y) in r1.output.as_f32().iter().zip(r2.output.as_f32()) {
                assert!((x - y).abs() < 1e-5, "{strat:?}: {x} vs {y}");
            }
        }
    }

    #[test]
    fn star_hub_source_keeps_zero_centrality() {
        // max_level == 1: the backward cycle's mandatory superstep lands on
        // cur == 0 and must be a no-op — the source is not an intermediate
        // vertex of its own shortest paths. (Latent engine bug found by the
        // differential-fuzz pass of ISSUE 4: bc[hub] came out as 7.0.)
        let mut el = EdgeList::new(8);
        for i in 1..8 {
            el.push(0, i);
            el.push(i, 0);
        }
        let g = CsrGraph::from_edge_list(&el);
        let mut alg = Bc::new(0);
        let r = engine::run(&g, &mut alg, &EngineConfig::host_only(1)).unwrap();
        assert_eq!(r.output.as_f32(), &[0.0; 8]);
        // and partitioned, where the backward superstep still runs per part
        let mut alg = Bc::new(0);
        let cfg = EngineConfig::cpu_partitions(&[0.5, 0.5], Strategy::Rand);
        let r = engine::run(&g, &mut alg, &cfg).unwrap();
        assert_eq!(r.output.as_f32(), &[0.0; 8]);
    }

    #[test]
    fn isolated_source() {
        let mut el = EdgeList::new(3);
        el.push(1, 2);
        let g = CsrGraph::from_edge_list(&el);
        let mut alg = Bc::new(0);
        let r = engine::run(&g, &mut alg, &EngineConfig::host_only(1)).unwrap();
        assert_eq!(r.output.as_f32(), &[0.0, 0.0, 0.0]);
    }
}
