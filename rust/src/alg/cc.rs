//! Connected Components via label propagation (paper §9.4) on the typed
//! vertex-program surface.
//!
//! Operates on the undirected view (each edge doubled, Table 5 note).
//! Every vertex starts with its own global id as label; labels propagate
//! with `min` until quiescence — one of the paper's canonical
//! reduction-friendly algorithms (§3.4: "minimum label in a connected
//! components algorithm"). The program is the smallest possible
//! [`Kernel::MonotoneScatter`] instance: activation uses the same
//! monotone-shadow trick as SSSP (a vertex propagates when its label
//! dropped since it last propagated, covering inbox updates without extra
//! channels) and the per-edge rule forwards the label unchanged.

use super::program::{
    AccelSpec, CommDecl, CyclePlan, FieldId, FieldSpec, InitRow, Kernel, ProgramDriver,
    ProgramMeta, Role, Value, VertexProgram,
};
use super::{StepCtx, INF_I32};
use crate::engine::state::StateArray;
use crate::graph::CsrGraph;

/// Connected components, as a vertex program.
#[derive(Default)]
pub struct CcProgram;

const LABELS: FieldId = FieldId(0);
/// CPU-only shadow: label at the time of the last propagation.
const PROPAGATED_AT: FieldId = FieldId(1);

impl VertexProgram for CcProgram {
    fn meta(&self) -> ProgramMeta {
        ProgramMeta {
            name: "cc",
            needs_weights: false,
            undirected: true,
            reversed: false,
            fixed_rounds: None,
            output: LABELS,
        }
    }

    fn schema(&self) -> Vec<FieldSpec> {
        vec![
            FieldSpec::i32("labels", Role::Device, INF_I32),
            FieldSpec::i32("propagated_at", Role::Host, INF_I32),
        ]
    }

    fn plan(&self, _cycle: usize) -> CyclePlan {
        CyclePlan {
            kernel: Kernel::MonotoneScatter { value: LABELS, shadow: PROPAGATED_AT },
            comm: vec![CommDecl::PushMin(LABELS)],
            device: None,
            accel: AccelSpec { name: "cc", n_si32: 0, n_sf32: 0 },
        }
    }

    fn init_vertex(&self, global_id: u32, row: &mut InitRow<'_>) {
        row.set_i32(LABELS, global_id as i32);
    }

    /// Labels propagate unchanged; the channel's `min` does the rest.
    fn edge_update(&self, _ctx: &StepCtx, src: Value, _w: f32) -> Option<Value> {
        Some(src)
    }

    /// Undirected view doubles the edges (paper §5).
    fn traversed_edges(&self, _output: &StateArray, g: &CsrGraph, _rounds: usize) -> u64 {
        2 * g.edge_count() as u64
    }
}

/// The engine-facing CC algorithm.
pub type Cc = ProgramDriver<CcProgram>;

impl Cc {
    #[allow(clippy::new_without_default)]
    pub fn new() -> Cc {
        ProgramDriver::build(CcProgram).expect("static schema is valid")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::{self, EngineConfig};
    use crate::graph::{CsrGraph, EdgeList};
    use crate::partition::Strategy;

    fn two_components() -> CsrGraph {
        // component A: 0-1-2 (chain), component B: 3-4
        let mut el = EdgeList::new(5);
        el.push(0, 1);
        el.push(1, 2);
        el.push(3, 4);
        CsrGraph::from_edge_list(&el)
    }

    #[test]
    fn labels_host_only() {
        let g = two_components();
        let mut alg = Cc::new();
        let r = engine::run(&g, &mut alg, &EngineConfig::host_only(1)).unwrap();
        assert_eq!(r.output.as_i32(), &[0, 0, 0, 3, 3]);
    }

    #[test]
    fn partitioned_matches() {
        let g = two_components();
        let mut a = Cc::new();
        let r1 = engine::run(&g, &mut a, &EngineConfig::host_only(1)).unwrap();
        for shares in [[0.5, 0.5], [0.3, 0.7]] {
            let mut b = Cc::new();
            let cfg = EngineConfig::cpu_partitions(&shares, Strategy::Rand);
            let r2 = engine::run(&g, &mut b, &cfg).unwrap();
            assert_eq!(r1.output.as_i32(), r2.output.as_i32());
        }
    }

    #[test]
    fn isolated_vertices_keep_own_label() {
        let g = CsrGraph::from_edge_list(&EdgeList::new(3));
        let mut alg = Cc::new();
        let r = engine::run(&g, &mut alg, &EngineConfig::host_only(1)).unwrap();
        assert_eq!(r.output.as_i32(), &[0, 1, 2]);
    }
}
