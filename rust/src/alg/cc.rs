//! Connected Components via label propagation (paper §9.4).
//!
//! Operates on the undirected view (each edge doubled, Table 5 note).
//! Every vertex starts with its own global id as label; labels propagate
//! with `min` until quiescence. The reduction operator is `min` — one of
//! the paper's canonical reduction-friendly algorithms (§3.4: "minimum
//! label in a connected components algorithm").
//!
//! Activation uses the same monotone trick as SSSP: a vertex propagates
//! when its label dropped since it last propagated (covers inbox updates
//! without extra channels).

use super::{AlgSpec, Algorithm, ComputeOut, EdgeOrientation, Pad, ProgramSpec, StepCtx, INF_I32};
use crate::engine::state::{AlgState, Channel, CommOp, StateArray};
use crate::partition::{Partition, PartitionedGraph};
use crate::util::atomic::as_atomic_i32_cells;
use crate::util::threadpool::parallel_reduce;
use std::sync::atomic::Ordering;

#[derive(Default)]
pub struct Cc;

impl Cc {
    pub fn new() -> Cc {
        Cc
    }
}

const LABELS: usize = 0;
/// CPU-only: label at the time of the last propagation.
const PROPAGATED_AT: usize = 1;

impl Algorithm for Cc {
    fn spec(&self) -> AlgSpec {
        AlgSpec {
            name: "cc",
            needs_weights: false,
            undirected: true,
            reversed: false,
            fixed_rounds: None,
        }
    }

    fn init_state(&mut self, _pg: &PartitionedGraph, part: &Partition) -> AlgState {
        let n = part.state_len();
        let mut labels = vec![INF_I32; n];
        for (l, &g) in part.local_to_global.iter().enumerate() {
            labels[l] = g as i32;
        }
        AlgState::new(vec![
            StateArray::I32(labels),
            StateArray::I32(vec![INF_I32; n]),
        ])
    }

    fn channels(&self, _cycle: usize) -> Vec<CommOp> {
        vec![CommOp::Single(Channel::push_min_i32(LABELS))]
    }

    fn program(&self, _cycle: usize) -> ProgramSpec {
        ProgramSpec {
            name: "cc",
            arrays: vec![LABELS],
            pads: vec![Pad::I32(INF_I32)],
            aux: vec![],
            needs_weights: false,
            n_si32: 0,
            n_sf32: 0,
            orientation: EdgeOrientation::Forward,
        }
    }

    fn compute_cpu(&self, part: &Partition, state: &mut AlgState, ctx: &StepCtx) -> ComputeOut {
        let nv = part.nv;
        let (labels_arr, rest) = state.arrays.split_at_mut(PROPAGATED_AT);
        let labels = labels_arr[LABELS].as_i32_mut();
        let cells = as_atomic_i32_cells(labels);
        // per-vertex, written only by the owning chunk.
        let propagated_cells = as_atomic_i32_cells(rest[0].as_i32_mut());

        let fold = |lo: usize, hi: usize, acc: (bool, u64, u64)| {
            let (mut changed, mut reads, mut writes) = acc;
            for v in lo..hi {
                let lv = cells[v].load(Ordering::Relaxed);
                if ctx.instrument {
                    reads += 2;
                }
                if lv >= propagated_cells[v].load(Ordering::Relaxed) {
                    continue;
                }
                propagated_cells[v].store(lv, Ordering::Relaxed);
                for &t in part.targets(v as u32) {
                    let old = cells[t as usize].fetch_min(lv, Ordering::Relaxed);
                    if ctx.instrument {
                        reads += 1;
                    }
                    if lv < old {
                        changed = true;
                        if ctx.instrument {
                            writes += 1;
                        }
                    }
                }
            }
            (changed, reads, writes)
        };
        let (changed, reads, writes) = parallel_reduce(
            nv,
            ctx.threads,
            (false, 0u64, 0u64),
            fold,
            |a, b| (a.0 || b.0, a.1 + b.1, a.2 + b.2),
        );
        ComputeOut { changed, reads, writes }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::{self, EngineConfig};
    use crate::graph::{CsrGraph, EdgeList};
    use crate::partition::Strategy;

    fn two_components() -> CsrGraph {
        // component A: 0-1-2 (chain), component B: 3-4
        let mut el = EdgeList::new(5);
        el.push(0, 1);
        el.push(1, 2);
        el.push(3, 4);
        CsrGraph::from_edge_list(&el)
    }

    #[test]
    fn labels_host_only() {
        let g = two_components();
        let mut alg = Cc::new();
        let r = engine::run(&g, &mut alg, &EngineConfig::host_only(1)).unwrap();
        assert_eq!(r.output.as_i32(), &[0, 0, 0, 3, 3]);
    }

    #[test]
    fn partitioned_matches() {
        let g = two_components();
        let mut a = Cc::new();
        let r1 = engine::run(&g, &mut a, &EngineConfig::host_only(1)).unwrap();
        for shares in [[0.5, 0.5], [0.3, 0.7]] {
            let mut b = Cc::new();
            let cfg = EngineConfig::cpu_partitions(&shares, Strategy::Rand);
            let r2 = engine::run(&g, &mut b, &cfg).unwrap();
            assert_eq!(r1.output.as_i32(), r2.output.as_i32());
        }
    }

    #[test]
    fn isolated_vertices_keep_own_label() {
        let g = CsrGraph::from_edge_list(&EdgeList::new(3));
        let mut alg = Cc::new();
        let r = engine::run(&g, &mut alg, &EngineConfig::host_only(1)).unwrap();
        assert_eq!(r.output.as_i32(), &[0, 1, 2]);
    }
}
