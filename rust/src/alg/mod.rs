//! Algorithm layer — the paper's callback API (§4.2, Figure 5), now split
//! in two:
//!
//! - [`program::VertexProgram`] is the **typed, declarative surface**
//!   algorithms are written against: a named state schema (dtype, pad,
//!   role), per-cycle communication declarations, a kernel family, and a
//!   handful of small typed callbacks (`edge_update`, `gather_apply`, …).
//!   All ten algorithms (`bfs`, `pagerank`, `sssp`, `bc`, `cc`,
//!   `widest`, `triangles`, `kcore`, `labelprop`, `ppr`) live on this
//!   surface; see DESIGN.md §10 for how to add one in well under 100
//!   lines, and §15 for the edge-centric kernel family the motif
//!   workloads ride on.
//! - [`Algorithm`] is the **engine-facing execution contract** — the
//!   paper's `alg_init` / `alg_compute` / `alg_scatter` hooks plus the
//!   direction-optimization and rebalance extensions. It is implemented
//!   exactly once, by [`program::ProgramDriver`], which derives push/pull
//!   CPU kernels, channel lists, accelerator marshaling
//!   ([`ProgramSpec`]), frontier statistics, and scratch rebuilds from
//!   the program's declarations. (The trait remains public and object-
//!   friendly so harness tools and ablation benches can still wrap or
//!   hand-roll an `Algorithm` when they need to.)
//!
//! Mapping to the paper's callbacks: `init_state` ↔ `alg_init`;
//! `compute_cpu` ↔ the CPU `alg_compute` kernel; the accelerator
//! `alg_compute` is the AOT-compiled JAX/Pallas step program named by
//! [`ProgramSpec`] (see `python/compile/model.py`); `channels` ↔
//! `alg_scatter` with the engine applying the declared reduction
//! generically; `collect` is handled by the engine via `output_array`.
//! Algorithms with several BSP cycles (Betweenness Centrality's forward +
//! backward sweeps) declare `cycles() > 1` and get a `begin_cycle` hook.

pub mod bc;
pub mod bfs;
pub mod cc;
pub mod incremental;
pub mod kcore;
pub mod labelprop;
pub mod msbfs;
pub mod pagerank;
pub mod ppr;
pub mod program;
pub mod sssp;
pub mod triangles;
pub mod widest;

use crate::engine::direction::{Direction, FrontierStats};
use crate::engine::state::{AlgState, CommOp};
use crate::graph::CsrGraph;
use crate::partition::{Partition, PartitionedGraph};

/// "Infinite" distance/level marker. `1 << 30` (not `i32::MAX`) so that
/// `INF + 1` cannot overflow in kernels, matching the Pallas side.
pub const INF_I32: i32 = 1 << 30;

/// Static description of an algorithm.
#[derive(Debug, Clone, Copy)]
pub struct AlgSpec {
    pub name: &'static str,
    /// Requires edge weights (SSSP).
    pub needs_weights: bool,
    /// Operates on the undirected view (CC): each edge is doubled.
    pub undirected: bool,
    /// Operates on the reversed graph (pull-based PageRank §7.1: a vertex
    /// pulls the ranks of its in-neighbors).
    pub reversed: bool,
    /// Fixed superstep count per cycle (PageRank); `None` → run to
    /// quiescence.
    pub fixed_rounds: Option<usize>,
}

/// Per-superstep context handed to compute kernels.
#[derive(Debug, Clone, Copy)]
pub struct StepCtx {
    pub cycle: usize,
    /// 0-based superstep within the current cycle.
    pub superstep: usize,
    /// Worker threads available to the CPU element.
    pub threads: usize,
    /// Memory-access counters on?
    pub instrument: bool,
    /// Traversal direction chosen by the engine's α/β policy for this
    /// element (DESIGN.md §8). Always `Push` unless the algorithm declares
    /// `supports_pull` and the run enables `EngineConfig::direction`;
    /// accelerator elements always receive `Push`.
    pub direction: Direction,
    /// Requested intra-partition balance mode (DESIGN.md §11). Kernels may
    /// degrade it (e.g. pull and gather cap at `Edge`; order-sensitive f32
    /// kernels ignore it entirely) — eligibility is decided centrally in
    /// `ProgramDriver`, never per call site.
    pub balance: crate::util::threadpool::Balance,
}

/// Result of a CPU compute phase.
#[derive(Debug, Clone, Copy, Default)]
pub struct ComputeOut {
    pub changed: bool,
    /// Instrumented state-memory reads/writes (0 when not instrumenting).
    pub reads: u64,
    pub writes: u64,
    /// Wall time of the slowest / fastest worker chunk in this phase
    /// (0 when the kernel ran as a single chunk) — the load-imbalance
    /// signal surfaced as `StepMetrics::chunk_max` / `chunk_min`.
    pub chunk_max_secs: f64,
    pub chunk_min_secs: f64,
}

/// Edge array orientation for the accelerator COO upload.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EdgeOrientation {
    /// `(src = vertex, dst = target)` — push algorithms.
    Forward,
    /// `(src = target, dst = vertex)` — pull algorithms over in-edge lists
    /// (PageRank on the reversed graph).
    Reversed,
}

/// Pad value for the `[state_len, n_cap)` region of device arrays.
/// `U64` pads exist only for host-role fields (u64 never ships to the
/// accelerator), but every field carries one so ghost/dummy slots can be
/// initialized uniformly.
#[derive(Debug, Clone, Copy)]
pub enum Pad {
    I32(i32),
    F32(f32),
    U64(u64),
}

/// Which AOT program implements a cycle's superstep on the accelerator,
/// and how to marshal it. Input order contract with `python/compile`:
/// `(state arrays…, aux arrays…, src, dst, [weights], [si32], [sf32])`;
/// outputs `(state arrays…, changed)`.
#[derive(Debug, Clone)]
pub struct ProgramSpec {
    /// Program name in the AOT manifest (e.g. "bfs").
    pub name: &'static str,
    /// Indices into `AlgState::arrays`, in program order.
    pub arrays: Vec<usize>,
    /// Pad values, parallel to `arrays`.
    pub pads: Vec<Pad>,
    /// Indices into `AlgState::aux`, in program order.
    pub aux: Vec<usize>,
    pub needs_weights: bool,
    pub n_si32: usize,
    pub n_sf32: usize,
    pub orientation: EdgeOrientation,
}

/// The TOTEM algorithm interface. See module docs.
///
/// `Sync` is required because the pipelined executor calls `compute_cpu`
/// for different partitions from concurrent scoped threads (all kernel
/// state lives in the per-partition `AlgState`, so implementations are
/// naturally `Sync`).
pub trait Algorithm: Sync {
    fn spec(&self) -> AlgSpec;

    /// BSP cycles (1 for everything except BC's forward+backward).
    fn cycles(&self) -> usize {
        1
    }

    /// One-time hook before partitioning-independent state is built.
    /// `original` is the caller's graph, `prepared` the transformed view
    /// that was partitioned (reversed/undirected as per the spec).
    fn prepare(&mut self, _original: &CsrGraph, _prepared: &CsrGraph) {}

    /// Allocate and initialize this partition's state arrays.
    fn init_state(&mut self, pg: &PartitionedGraph, part: &Partition) -> AlgState;

    /// Hook at the start of each cycle (BC computes the max level here).
    fn begin_cycle(&mut self, _cycle: usize, _pg: &PartitionedGraph, _states: &mut [AlgState]) {}

    /// Communicated state arrays for a cycle.
    fn channels(&self, cycle: usize) -> Vec<CommOp>;

    /// Accelerator step program for a cycle.
    fn program(&self, cycle: usize) -> ProgramSpec;

    /// Scalar inputs for the accelerator program at this superstep.
    fn scalars_i32(&self, _ctx: &StepCtx) -> Vec<i32> {
        vec![]
    }
    fn scalars_f32(&self, _ctx: &StepCtx) -> Vec<f32> {
        vec![]
    }

    /// Does `compute_cpu` honor `StepCtx::direction == Pull` (a bottom-up
    /// kernel over the partition's transpose CSR)? Algorithms answering
    /// `false` (the default) always receive `Push`, even when the run
    /// enables direction optimization.
    fn supports_pull(&self) -> bool {
        false
    }

    /// Frontier-shape estimate for one partition ahead of
    /// `next_superstep`, feeding the engine's α/β direction policy
    /// (DESIGN.md §8). `None` (the default) opts the partition out of
    /// direction decisions for that superstep.
    fn frontier_stats(
        &self,
        _part: &Partition,
        _state: &AlgState,
        _next_superstep: usize,
    ) -> Option<FrontierStats> {
        None
    }

    /// The CPU element's compute phase for one partition.
    fn compute_cpu(&self, part: &Partition, state: &mut AlgState, ctx: &StepCtx) -> ComputeOut;

    /// Should the cycle stop before superstep `next_superstep`?
    /// Default: quiesce when no partition changed anything.
    fn cycle_done(&self, _cycle: usize, next_superstep: usize, any_changed: bool) -> bool {
        if let Some(r) = self.spec().fixed_rounds {
            next_superstep >= r
        } else {
            !any_changed
        }
    }

    /// Which `arrays` index carries the per-vertex result.
    fn output_array(&self) -> usize {
        0
    }

    /// Additional `arrays` indices to collect into `RunResult::extra`,
    /// in order (multi-source BFS collects one level array per lane on
    /// top of the `seen` word in `output_array`). Default: none.
    fn extra_outputs(&self) -> Vec<usize> {
        vec![]
    }

    /// Rebuild partition-local scratch (`AlgState::scratch`) after the
    /// dynamic α controller migrated vertices: the engine has rebuilt the
    /// partition and remapped the typed state arrays through the global id
    /// maps, but scratch layout is algorithm-private (e.g. the BFS visited
    /// bitmap), so algorithms that use it must override this. Default:
    /// no scratch.
    fn rebuild_scratch(&self, _part: &Partition, _state: &mut AlgState) {}

    /// Traversed-edges accounting for TEPS (paper §5 "Evaluation
    /// Metrics"). `output` is the collected global result array; `g` the
    /// original graph. Each algorithm reports its own formula (BFS counts
    /// the out-degrees of visited vertices, PageRank counts |E| per
    /// round, …) — this replaced the old stringly-typed
    /// `alg::traversed_edges(name, …)` dispatch. Default: |E| × rounds.
    fn traversed_edges(
        &self,
        _output: &crate::engine::state::StateArray,
        g: &CsrGraph,
        rounds: usize,
    ) -> u64 {
        g.edge_count() as u64 * rounds.max(1) as u64
    }
}
