//! Vertex→partition assignment strategies (paper §6).
//!
//! - `RAND`: vertices in random order, greedily filled to the target edge
//!   shares — the naïve baseline of §3.4/§5.
//! - `HIGH`: vertices sorted by degree **descending**; partition 0 (the CPU
//!   by convention) receives the highest-degree vertices until it holds its
//!   edge share, the accelerator partitions receive the low-degree tail.
//! - `LOW`: ascending — the CPU gets the low-degree vertices, the
//!   accelerators the hubs (best for state-heavy algorithms like BC, §7.2).
//!
//! All three are exactly the paper's low-cost strategies: `O(|V| log |V|)`
//! sorting (§6.2 notes partial sort achieves `O(|V|)`; full sort keeps the
//! code simple and is nowhere near the bottleneck).

use crate::graph::CsrGraph;
use crate::util::rng::Rng;

/// Partitioning strategy (paper Figure 9 notation).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Strategy {
    /// Random vertex placement, edge-share balanced.
    Rand,
    /// Highest-degree vertices on partition 0 (CPU).
    High,
    /// Lowest-degree vertices on partition 0 (CPU).
    Low,
}

impl Strategy {
    pub fn parse(s: &str) -> Result<Strategy, String> {
        match s.to_ascii_lowercase().as_str() {
            "rand" | "random" => Ok(Strategy::Rand),
            "high" => Ok(Strategy::High),
            "low" => Ok(Strategy::Low),
            _ => Err(format!("unknown strategy '{s}' (rand|high|low)")),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Strategy::Rand => "RAND",
            Strategy::High => "HIGH",
            Strategy::Low => "LOW",
        }
    }
}

/// Compute a vertex→partition assignment hitting the requested edge
/// `shares` (fractions of |E|, must sum to ~1; partition 0 = CPU).
///
/// Returns one partition id per vertex. Greedy prefix fill over the
/// strategy's vertex order: a partition keeps receiving vertices until its
/// cumulative out-degree reaches its share of the edges.
pub fn assign(g: &CsrGraph, strategy: Strategy, shares: &[f64], seed: u64) -> Vec<u8> {
    assert!(!shares.is_empty() && shares.len() <= 8, "1..=8 partitions supported");
    let total: f64 = shares.iter().sum();
    assert!(
        (total - 1.0).abs() < 1e-6,
        "shares must sum to 1 (got {total})"
    );
    assert!(shares.iter().all(|&s| s >= 0.0));

    let v = g.vertex_count;
    let mut order: Vec<u32> = (0..v as u32).collect();
    match strategy {
        Strategy::Rand => {
            let mut rng = Rng::new(seed);
            rng.shuffle(&mut order);
        }
        Strategy::High => {
            order.sort_by_key(|&x| std::cmp::Reverse(g.out_degree(x)));
        }
        Strategy::Low => {
            order.sort_by_key(|&x| g.out_degree(x));
        }
    }

    let e_total = g.edge_count() as f64;
    let mut assignment = vec![0u8; v];
    let mut part = 0usize;
    let mut cum_edges = 0f64;
    let mut cum_target: f64 = shares[0] * e_total;
    for &vtx in &order {
        // advance to the next partition once this one's edge budget is full
        while part + 1 < shares.len() && cum_edges >= cum_target - 1e-9 {
            part += 1;
            cum_target += shares[part] * e_total;
        }
        assignment[vtx as usize] = part as u8;
        cum_edges += g.out_degree(vtx) as f64;
    }
    assignment
}

/// Cut a band from the low-degree tail of a descending-degree member
/// list: take vertices from the end until their cumulative out-degree
/// reaches `target_edges`, but never more than `max_vertices`. This is
/// the runtime re-balancing counterpart of the HIGH/LOW greedy prefix
/// fill above — `engine`'s dynamic α controller migrates such bands
/// between processing elements (partitions keep `local_to_global` sorted
/// by descending degree, so the tail is exactly the low-degree band).
pub fn low_degree_band(
    g: &CsrGraph,
    members_desc: &[u32],
    target_edges: f64,
    max_vertices: usize,
) -> Vec<u32> {
    let mut band = Vec::new();
    let mut edges = 0f64;
    for &v in members_desc.iter().rev().take(max_vertices) {
        band.push(v);
        edges += g.out_degree(v) as f64;
        if edges >= target_edges {
            break;
        }
    }
    band
}

/// Realized statistics of an assignment: per-partition vertex and edge
/// counts (Figure 13's |V_cpu| plot is `vertices[0] / |V|`).
#[derive(Debug, Clone)]
pub struct AssignmentStats {
    pub vertices: Vec<usize>,
    pub edges: Vec<u64>,
}

pub fn assignment_stats(g: &CsrGraph, assignment: &[u8], parts: usize) -> AssignmentStats {
    let mut vertices = vec![0usize; parts];
    let mut edges = vec![0u64; parts];
    for v in 0..g.vertex_count {
        let p = assignment[v] as usize;
        vertices[p] += 1;
        edges[p] += g.out_degree(v as u32);
    }
    AssignmentStats { vertices, edges }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generator::{rmat, uniform, RmatParams};
    use crate::graph::CsrGraph;

    fn g_rmat() -> CsrGraph {
        CsrGraph::from_edge_list(&rmat(&RmatParams::paper(12, 42)))
    }

    #[test]
    fn shares_respected_all_strategies() {
        let g = g_rmat();
        for strat in [Strategy::Rand, Strategy::High, Strategy::Low] {
            let a = assign(&g, strat, &[0.7, 0.3], 1);
            let st = assignment_stats(&g, &a, 2);
            let frac = st.edges[0] as f64 / g.edge_count() as f64;
            // greedy fill overshoots by at most one vertex's degree
            assert!(
                (frac - 0.7).abs() < 0.05,
                "{}: frac={frac}",
                strat.name()
            );
        }
    }

    #[test]
    fn three_way_shares() {
        let g = g_rmat();
        let a = assign(&g, Strategy::Rand, &[0.5, 0.25, 0.25], 3);
        let st = assignment_stats(&g, &a, 3);
        let fr: Vec<f64> = st.edges.iter().map(|&e| e as f64 / g.edge_count() as f64).collect();
        assert!((fr[0] - 0.5).abs() < 0.05, "{fr:?}");
        assert!((fr[1] - 0.25).abs() < 0.05, "{fr:?}");
    }

    #[test]
    fn high_gives_cpu_few_vertices() {
        // The paper's key observation (Fig 13): for the same edge share,
        // HIGH puts orders of magnitude fewer vertices on the CPU than LOW.
        let g = g_rmat();
        let hi = assignment_stats(&g, &assign(&g, Strategy::High, &[0.5, 0.5], 1), 2);
        let lo = assignment_stats(&g, &assign(&g, Strategy::Low, &[0.5, 0.5], 1), 2);
        assert!(
            hi.vertices[0] * 10 < lo.vertices[0],
            "high={} low={}",
            hi.vertices[0],
            lo.vertices[0]
        );
    }

    #[test]
    fn high_low_are_degree_monotone() {
        let g = g_rmat();
        let a = assign(&g, Strategy::High, &[0.6, 0.4], 1);
        let min_p0 = (0..g.vertex_count)
            .filter(|&v| a[v] == 0)
            .map(|v| g.out_degree(v as u32))
            .min()
            .unwrap();
        let max_p1 = (0..g.vertex_count)
            .filter(|&v| a[v] == 1)
            .map(|v| g.out_degree(v as u32))
            .max()
            .unwrap();
        assert!(min_p0 >= max_p1, "min_p0={min_p0} max_p1={max_p1}");
    }

    #[test]
    fn rand_is_seed_deterministic() {
        let g = g_rmat();
        assert_eq!(
            assign(&g, Strategy::Rand, &[0.5, 0.5], 9),
            assign(&g, Strategy::Rand, &[0.5, 0.5], 9)
        );
        assert_ne!(
            assign(&g, Strategy::Rand, &[0.5, 0.5], 9),
            assign(&g, Strategy::Rand, &[0.5, 0.5], 10)
        );
    }

    #[test]
    fn single_partition_all_zero() {
        let g = CsrGraph::from_edge_list(&uniform(8, 4, 1));
        let a = assign(&g, Strategy::High, &[1.0], 0);
        assert!(a.iter().all(|&p| p == 0));
    }

    #[test]
    fn parse_names() {
        assert_eq!(Strategy::parse("HIGH").unwrap(), Strategy::High);
        assert_eq!(Strategy::parse("random").unwrap(), Strategy::Rand);
        assert!(Strategy::parse("metis").is_err());
    }

    #[test]
    fn low_degree_band_cuts_the_tail() {
        let g = g_rmat();
        let mut members: Vec<u32> = (0..g.vertex_count as u32).collect();
        members.sort_by_key(|&v| std::cmp::Reverse(g.out_degree(v)));
        let total: f64 = g.edge_count() as f64;
        let band = low_degree_band(&g, &members, 0.05 * total, members.len());
        assert!(!band.is_empty());
        // band members are exactly the list's suffix, walked tail-first
        let mut suffix: Vec<u32> = members[members.len() - band.len()..].to_vec();
        suffix.reverse();
        assert_eq!(suffix, band);
        // vertex cap is respected even when the edge target is unreachable
        let capped = low_degree_band(&g, &members, f64::INFINITY, 7);
        assert_eq!(capped.len(), 7);
        assert!(low_degree_band(&g, &members, 1.0, 0).is_empty());
    }
}
