//! Graph partitioning for hybrid platforms (paper §4.3.1, §6).
//!
//! A [`PartitionedGraph`] splits a CSR graph into per-element partitions
//! with the paper's data layout:
//!
//! - each partition renumbers its vertices into a dense local id space;
//! - boundary edges do **not** store the remote vertex id — they store an
//!   index into a *ghost slot* (the paper's outbox-buffer entry), so all
//!   local edges to the same remote vertex share one slot: this is the
//!   message **reduction** of §3.4, applied structurally;
//! - per remote partition, a [`GhostTable`] records which remote-local
//!   vertices the slots correspond to, sorted by remote id (the paper's
//!   "inbox sorted by vertex IDs" pre-fetch optimization);
//! - within a vertex's adjacency, local edges come first, boundary edges
//!   last (§4.3.4 optimization ii).
//!
//! The per-partition **state layout** shared by CPU and accelerator
//! elements (DESIGN.md §3):
//!
//! ```text
//! [0, nv)                 real local vertices
//! [nv, nv + n_ghost)      ghost slots, grouped by remote partition
//! [nv + n_ghost]          dummy sink (accelerator padding edges land here)
//! ```

pub mod assignment;

pub use assignment::{assign, assignment_stats, low_degree_band, AssignmentStats, Strategy};

use crate::graph::CsrGraph;
use std::sync::OnceLock;

/// In-edge (transpose) CSR of a partition's local CSR (DESIGN.md §8).
///
/// Rows are **state indices** `[0, state_len())` — real local vertices,
/// then ghost slots, then the dummy sink — the same layout the forward
/// `LocalCsr::targets` entries address, so pull-mode kernels read and
/// write the very same per-partition state arrays as push-mode kernels.
/// `sources[row_offsets[t]..row_offsets[t+1]]` lists the local vertices
/// that have a forward edge into state index `t`, in ascending local id
/// (stable counting sort), so iteration order is deterministic.
///
/// Every forward edge appears exactly once (edge conservation and
/// in-degree sums are property-tested in `rebalance_invariants.rs`).
/// Rows for ghost slots record which local vertices feed that outbox slot
/// — useful for boundary-aware sweeps; the dummy row is always empty.
///
/// Weights are not mirrored: the only pull-mode consumer today is BFS's
/// bottom-up sweep (unweighted); SSSP stays push-mode.
#[derive(Debug, Clone, Default)]
pub struct TransposeCsr {
    /// `state_len + 1` offsets into `sources`.
    pub row_offsets: Vec<u64>,
    /// Local source vertex of each in-edge.
    pub sources: Vec<u32>,
}

impl TransposeCsr {
    /// Build from a partition's forward CSR by counting sort —
    /// `O(|V_p| + |E_p|)`, same recipe as `CsrGraph::from_edge_list`.
    pub fn build(csr: &LocalCsr, state_len: usize) -> TransposeCsr {
        let nv = csr.local_counts.len();
        let mut deg = vec![0u64; state_len + 1];
        for &t in &csr.targets {
            deg[t as usize + 1] += 1;
        }
        for i in 0..state_len {
            deg[i + 1] += deg[i];
        }
        let row_offsets = deg.clone();
        let mut cursor = deg;
        let mut sources = vec![0u32; csr.targets.len()];
        for v in 0..nv {
            let lo = csr.row_offsets[v] as usize;
            let hi = csr.row_offsets[v + 1] as usize;
            for &t in &csr.targets[lo..hi] {
                let slot = cursor[t as usize] as usize;
                sources[slot] = v as u32;
                cursor[t as usize] += 1;
            }
        }
        TransposeCsr { row_offsets, sources }
    }

    /// Local in-neighbors of state index `t`.
    #[inline]
    pub fn sources_of(&self, t: u32) -> &[u32] {
        let lo = self.row_offsets[t as usize] as usize;
        let hi = self.row_offsets[t as usize + 1] as usize;
        &self.sources[lo..hi]
    }

    /// In-degree of state index `t` (local edges only).
    #[inline]
    pub fn in_degree(&self, t: u32) -> u64 {
        self.row_offsets[t as usize + 1] - self.row_offsets[t as usize]
    }

    pub fn edge_count(&self) -> usize {
        self.sources.len()
    }
}

/// Ghost (boundary) table towards one remote partition.
#[derive(Debug, Clone)]
pub struct GhostTable {
    /// The remote partition id.
    pub remote_part: usize,
    /// Local ids *in the remote partition* of each ghost vertex, ascending.
    pub remote_locals: Vec<u32>,
    /// First state-array slot used by this table in the owning partition.
    pub slot_base: usize,
    /// Raw boundary edges that collapsed into this table (β numerator
    /// before reduction, Figure 4).
    pub boundary_edges: u64,
}

impl GhostTable {
    pub fn len(&self) -> usize {
        self.remote_locals.len()
    }
    pub fn is_empty(&self) -> bool {
        self.remote_locals.is_empty()
    }
}

/// Local CSR of a partition. `targets` entries are **state indices**:
/// `< nv` → real local vertex; `>= nv` → ghost slot.
#[derive(Debug, Clone)]
pub struct LocalCsr {
    pub row_offsets: Vec<u64>,
    pub targets: Vec<u32>,
    pub weights: Option<Vec<f32>>,
    /// Per vertex, how many of its targets are local (local-first ordering).
    pub local_counts: Vec<u32>,
}

/// One partition of the graph plus its communication metadata.
#[derive(Debug, Clone)]
pub struct Partition {
    pub id: usize,
    /// Real local vertex count.
    pub nv: usize,
    /// local id -> global id.
    pub local_to_global: Vec<u32>,
    pub csr: LocalCsr,
    pub ghosts: Vec<GhostTable>,
    pub n_ghost: usize,
    /// Lazily built in-edge CSR for pull/bottom-up kernels (DESIGN.md §8).
    /// Migrations rebuild the whole `Partition`, so the cache can never go
    /// stale; construct with `OnceLock::new()`.
    pub transpose_cache: OnceLock<TransposeCsr>,
}

impl Partition {
    /// The in-edge (transpose) CSR, built on first use and cached. Safe to
    /// call concurrently from per-partition compute threads.
    #[inline]
    pub fn transpose(&self) -> &TransposeCsr {
        self.transpose_cache
            .get_or_init(|| TransposeCsr::build(&self.csr, self.state_len()))
    }

    /// Length of the unified state arrays (real + ghosts + dummy).
    #[inline]
    pub fn state_len(&self) -> usize {
        self.nv + self.n_ghost + 1
    }

    /// Index of the dummy sink slot.
    #[inline]
    pub fn dummy_index(&self) -> usize {
        self.nv + self.n_ghost
    }

    pub fn edge_count(&self) -> usize {
        self.csr.targets.len()
    }

    /// Neighbor state-indices of local vertex `v`.
    #[inline]
    pub fn targets(&self, v: u32) -> &[u32] {
        let lo = self.csr.row_offsets[v as usize] as usize;
        let hi = self.csr.row_offsets[v as usize + 1] as usize;
        &self.csr.targets[lo..hi]
    }

    #[inline]
    pub fn weights(&self, v: u32) -> &[f32] {
        let lo = self.csr.row_offsets[v as usize] as usize;
        let hi = self.csr.row_offsets[v as usize + 1] as usize;
        &self.csr.weights.as_ref().expect("unweighted partition")[lo..hi]
    }

    /// Spread a global per-vertex array into this partition's state layout
    /// (ghost + dummy slots take `fill`).
    pub fn map_vertex_array<T: Copy>(&self, global: &[T], fill: T) -> Vec<T> {
        let mut out = vec![fill; self.state_len()];
        for (l, &g) in self.local_to_global.iter().enumerate() {
            out[l] = global[g as usize];
        }
        out
    }

    /// Bytes of the partition graph structure (paper §4.3.3 item i).
    pub fn graph_bytes(&self) -> u64 {
        (self.csr.row_offsets.len() * 8
            + self.csr.targets.len() * 4
            + self.csr.weights.as_ref().map_or(0, |w| w.len() * 4)
            + self.local_to_global.len() * 4) as u64
    }

    /// Bytes of the ghost/communication tables, `(vid + s) × slots` with
    /// s = per-message state bytes (paper §4.3.3 items ii/iii).
    pub fn comm_bytes(&self, msg_bytes: u64) -> u64 {
        self.ghosts
            .iter()
            .map(|t| (4 + msg_bytes) * t.len() as u64)
            .sum()
    }
}

/// The partitioned graph: all partitions plus global lookup tables.
#[derive(Debug, Clone)]
pub struct PartitionedGraph {
    pub parts: Vec<Partition>,
    /// global vertex -> partition id.
    pub part_of: Vec<u8>,
    /// global vertex -> local id within its partition.
    pub local_of: Vec<u32>,
    pub global_vertex_count: usize,
    pub total_edges: usize,
}

/// Communication-volume statistics (Figure 4).
#[derive(Debug, Clone)]
pub struct BetaStats {
    /// Boundary edges (messages without reduction).
    pub boundary_edges: u64,
    /// Ghost slots (messages with reduction).
    pub reduced_messages: u64,
    pub total_edges: u64,
}

impl BetaStats {
    /// β without reduction: fraction of edges that cross partitions.
    pub fn beta_raw(&self) -> f64 {
        self.boundary_edges as f64 / self.total_edges.max(1) as f64
    }
    /// β with reduction: messages actually sent per edge.
    pub fn beta_reduced(&self) -> f64 {
        self.reduced_messages as f64 / self.total_edges.max(1) as f64
    }
}

impl PartitionedGraph {
    /// Partition `g` according to `assignment` (one partition id per
    /// vertex; ids must be `< nparts`).
    ///
    /// Within each partition, vertices are ordered by descending degree —
    /// the partition-local analogue of the paper's degree ordering, which
    /// also gives the accelerator's SIMD batches uniform work.
    pub fn build(g: &CsrGraph, assignment: &[u8], nparts: usize) -> PartitionedGraph {
        assert_eq!(assignment.len(), g.vertex_count);
        let v_total = g.vertex_count;

        // --- local id spaces -------------------------------------------------
        let mut members: Vec<Vec<u32>> = vec![Vec::new(); nparts];
        for v in 0..v_total as u32 {
            members[assignment[v as usize] as usize].push(v);
        }
        for m in members.iter_mut() {
            m.sort_by_key(|&x| std::cmp::Reverse(g.out_degree(x)));
        }
        let mut local_of = vec![0u32; v_total];
        for m in &members {
            for (l, &v) in m.iter().enumerate() {
                local_of[v as usize] = l as u32;
            }
        }

        // --- per-partition build ---------------------------------------------
        let mut parts = Vec::with_capacity(nparts);
        for (pid, mem) in members.iter().enumerate() {
            let nv = mem.len();

            // Pass 1: collect unique remote (part, remote_local) pairs and
            // raw boundary counts.
            let mut boundary: Vec<(u8, u32)> = Vec::new();
            let mut boundary_count = vec![0u64; nparts];
            for &gv in mem {
                for &gd in g.neighbors(gv) {
                    let q = assignment[gd as usize];
                    if q as usize != pid {
                        boundary.push((q, local_of[gd as usize]));
                        boundary_count[q as usize] += 1;
                    }
                }
            }
            boundary.sort_unstable();
            boundary.dedup();

            // Ghost tables grouped by remote partition, slots contiguous.
            let mut ghosts: Vec<GhostTable> = Vec::new();
            let mut slot_base = nv;
            let mut i = 0;
            while i < boundary.len() {
                let q = boundary[i].0;
                let mut remote_locals = Vec::new();
                while i < boundary.len() && boundary[i].0 == q {
                    remote_locals.push(boundary[i].1);
                    i += 1;
                }
                let len = remote_locals.len();
                ghosts.push(GhostTable {
                    remote_part: q as usize,
                    remote_locals,
                    slot_base,
                    boundary_edges: boundary_count[q as usize],
                });
                slot_base += len;
            }
            let n_ghost = slot_base - nv;

            // Pass 2: rewrite edges to state indices, local-first order.
            let mut row_offsets = Vec::with_capacity(nv + 1);
            row_offsets.push(0u64);
            let mut targets: Vec<u32> = Vec::new();
            let mut weights: Option<Vec<f32>> = g.weights.as_ref().map(|_| Vec::new());
            let mut local_counts = Vec::with_capacity(nv);
            let mut ghost_buf: Vec<(u32, f32)> = Vec::new();
            for &gv in mem {
                let glo = g.row_offsets[gv as usize] as usize;
                let nbrs = g.neighbors(gv);
                ghost_buf.clear();
                let mut n_local = 0u32;
                for (k, &gd) in nbrs.iter().enumerate() {
                    let w = g.weights.as_ref().map_or(0.0, |ws| ws[glo + k]);
                    let q = assignment[gd as usize] as usize;
                    if q == pid {
                        targets.push(local_of[gd as usize]);
                        if let Some(wv) = &mut weights {
                            wv.push(w);
                        }
                        n_local += 1;
                    } else {
                        // find the ghost table for q and the slot via
                        // binary search over its sorted remote_locals.
                        let t = ghosts
                            .iter()
                            .find(|t| t.remote_part == q)
                            .expect("ghost table must exist");
                        let idx = t
                            .remote_locals
                            .binary_search(&local_of[gd as usize])
                            .expect("ghost entry must exist");
                        ghost_buf.push(((t.slot_base + idx) as u32, w));
                    }
                }
                for &(slot, w) in &ghost_buf {
                    targets.push(slot);
                    if let Some(wv) = &mut weights {
                        wv.push(w);
                    }
                }
                local_counts.push(n_local);
                row_offsets.push(targets.len() as u64);
            }

            parts.push(Partition {
                id: pid,
                nv,
                local_to_global: mem.clone(),
                csr: LocalCsr { row_offsets, targets, weights, local_counts },
                ghosts,
                n_ghost,
                transpose_cache: OnceLock::new(),
            });
        }

        PartitionedGraph {
            parts,
            part_of: assignment.to_vec(),
            local_of,
            global_vertex_count: v_total,
            total_edges: g.edge_count(),
        }
    }

    /// Convenience: assign + build in one call.
    pub fn partition(
        g: &CsrGraph,
        strategy: Strategy,
        shares: &[f64],
        seed: u64,
    ) -> PartitionedGraph {
        let a = assign(g, strategy, shares, seed);
        PartitionedGraph::build(g, &a, shares.len())
    }

    /// Figure 4 statistics.
    pub fn beta_stats(&self) -> BetaStats {
        let mut boundary = 0u64;
        let mut reduced = 0u64;
        for p in &self.parts {
            for t in &p.ghosts {
                boundary += t.boundary_edges;
                reduced += t.len() as u64;
            }
        }
        BetaStats {
            boundary_edges: boundary,
            reduced_messages: reduced,
            total_edges: self.total_edges as u64,
        }
    }

    /// Realized edge share per partition (the effective α of partition 0).
    pub fn edge_shares(&self) -> Vec<f64> {
        self.parts
            .iter()
            .map(|p| p.edge_count() as f64 / self.total_edges.max(1) as f64)
            .collect()
    }

    /// Gather a per-partition-state array back into a global array.
    pub fn collect_to_global<T: Copy + Default>(&self, locals: &[Vec<T>]) -> Vec<T> {
        let mut out = vec![T::default(); self.global_vertex_count];
        for (p, vals) in self.parts.iter().zip(locals) {
            for (l, &g) in p.local_to_global.iter().enumerate() {
                out[g as usize] = vals[l];
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generator::{rmat, with_random_weights, RmatParams};
    use crate::graph::{CsrGraph, EdgeList};

    fn small() -> CsrGraph {
        // 0->1,0->2,1->2,2->3,3->0,3->1 ; partitions {0,1} and {2,3}
        let mut el = EdgeList::new(4);
        for &(s, d) in &[(0, 1), (0, 2), (1, 2), (2, 3), (3, 0), (3, 1)] {
            el.push(s, d);
        }
        CsrGraph::from_edge_list(&el)
    }

    #[test]
    fn two_way_structure() {
        let g = small();
        let pg = PartitionedGraph::build(&g, &[0, 0, 1, 1], 2);
        assert_eq!(pg.parts.len(), 2);
        let p0 = &pg.parts[0];
        let p1 = &pg.parts[1];
        assert_eq!(p0.nv, 2);
        assert_eq!(p1.nv, 2);
        // p0 boundary edges: 0->2 and 1->2 → both to the same remote vertex
        // → ONE ghost slot (reduction!).
        assert_eq!(p0.n_ghost, 1);
        assert_eq!(p0.ghosts[0].boundary_edges, 2);
        // p1 boundary: 3->0, 3->1 → two distinct remotes → two slots.
        assert_eq!(p1.n_ghost, 2);
        // edge counts preserved
        assert_eq!(p0.edge_count() + p1.edge_count(), g.edge_count());
    }

    #[test]
    fn beta_stats_small() {
        let g = small();
        let pg = PartitionedGraph::build(&g, &[0, 0, 1, 1], 2);
        let b = pg.beta_stats();
        assert_eq!(b.boundary_edges, 4); // 0->2,1->2,3->0,3->1
        assert_eq!(b.reduced_messages, 3);
        assert!((b.beta_raw() - 4.0 / 6.0).abs() < 1e-12);
        assert!((b.beta_reduced() - 3.0 / 6.0).abs() < 1e-12);
    }

    #[test]
    fn local_edges_first() {
        let g = small();
        let pg = PartitionedGraph::build(&g, &[0, 0, 1, 1], 2);
        for p in &pg.parts {
            for v in 0..p.nv as u32 {
                let t = p.targets(v);
                let nl = p.csr.local_counts[v as usize] as usize;
                assert!(t[..nl].iter().all(|&x| (x as usize) < p.nv));
                assert!(t[nl..].iter().all(|&x| (x as usize) >= p.nv));
            }
        }
    }

    #[test]
    fn state_indices_in_range() {
        let g = CsrGraph::from_edge_list(&rmat(&RmatParams::paper(10, 7)));
        let pg = PartitionedGraph::partition(&g, Strategy::High, &[0.6, 0.4], 1);
        for p in &pg.parts {
            let n = p.state_len() as u32;
            assert!(p.csr.targets.iter().all(|&t| t < n - 1)); // never dummy
        }
    }

    #[test]
    fn weights_preserved_across_partitioning() {
        let mut el = rmat(&RmatParams::paper(8, 3));
        with_random_weights(&mut el, 64, 5);
        let g = CsrGraph::from_edge_list(&el);
        let pg = PartitionedGraph::partition(&g, Strategy::Rand, &[0.5, 0.5], 2);
        // total weight preserved
        let total_g: f64 = g.weights.as_ref().unwrap().iter().map(|&w| w as f64).sum();
        let total_p: f64 = pg
            .parts
            .iter()
            .map(|p| {
                p.csr
                    .weights
                    .as_ref()
                    .unwrap()
                    .iter()
                    .map(|&w| w as f64)
                    .sum::<f64>()
            })
            .sum();
        assert!((total_g - total_p).abs() < 1e-6);
    }

    #[test]
    fn ghost_tables_sorted_and_consistent() {
        let g = CsrGraph::from_edge_list(&rmat(&RmatParams::paper(9, 11)));
        let pg = PartitionedGraph::partition(&g, Strategy::Rand, &[0.4, 0.3, 0.3], 3);
        for p in &pg.parts {
            let mut next_base = p.nv;
            for t in &p.ghosts {
                assert_eq!(t.slot_base, next_base);
                next_base += t.len();
                assert!(t.remote_locals.windows(2).all(|w| w[0] < w[1]));
                let rp = &pg.parts[t.remote_part];
                assert!(t.remote_locals.iter().all(|&l| (l as usize) < rp.nv));
            }
            assert_eq!(next_base, p.nv + p.n_ghost);
        }
    }

    #[test]
    fn round_trip_edges_through_ghosts() {
        // Every global edge must be recoverable from the partitioned form.
        let g = CsrGraph::from_edge_list(&rmat(&RmatParams::paper(8, 13)));
        let pg = PartitionedGraph::partition(&g, Strategy::Low, &[0.5, 0.5], 4);
        let mut rebuilt: Vec<(u32, u32)> = Vec::new();
        for p in &pg.parts {
            for v in 0..p.nv as u32 {
                let gv = p.local_to_global[v as usize];
                for &t in p.targets(v) {
                    let gd = if (t as usize) < p.nv {
                        p.local_to_global[t as usize]
                    } else {
                        // resolve ghost slot → remote partition local id
                        let tab = p
                            .ghosts
                            .iter()
                            .find(|tab| {
                                (t as usize) >= tab.slot_base
                                    && (t as usize) < tab.slot_base + tab.len()
                            })
                            .unwrap();
                        let rl = tab.remote_locals[t as usize - tab.slot_base];
                        pg.parts[tab.remote_part].local_to_global[rl as usize]
                    };
                    rebuilt.push((gv, gd));
                }
            }
        }
        let mut orig: Vec<(u32, u32)> = g.iter_edges().collect();
        orig.sort_unstable();
        rebuilt.sort_unstable();
        assert_eq!(orig, rebuilt);
    }

    #[test]
    fn transpose_inverts_local_csr() {
        let g = small();
        let pg = PartitionedGraph::build(&g, &[0, 0, 1, 1], 2);
        for p in &pg.parts {
            let tr = p.transpose();
            // edge conservation: every forward edge appears exactly once
            assert_eq!(tr.edge_count(), p.edge_count());
            assert_eq!(tr.row_offsets.len(), p.state_len() + 1);
            // forward multiset == transpose multiset
            let mut fwd: Vec<(u32, u32)> = Vec::new();
            for v in 0..p.nv as u32 {
                for &t in p.targets(v) {
                    fwd.push((v, t));
                }
            }
            let mut rev: Vec<(u32, u32)> = Vec::new();
            for t in 0..p.state_len() as u32 {
                for &u in tr.sources_of(t) {
                    rev.push((u, t));
                }
            }
            fwd.sort_unstable();
            rev.sort_unstable();
            assert_eq!(fwd, rev);
            // dummy row is empty; sources ascend within a row
            assert_eq!(tr.in_degree(p.dummy_index() as u32), 0);
            for t in 0..p.state_len() as u32 {
                let s = tr.sources_of(t);
                assert!(s.windows(2).all(|w| w[0] <= w[1]));
            }
        }
    }

    #[test]
    fn transpose_cached_and_cloned() {
        let g = CsrGraph::from_edge_list(&rmat(&RmatParams::paper(8, 21)));
        let pg = PartitionedGraph::partition(&g, Strategy::High, &[0.5, 0.5], 3);
        let p = &pg.parts[0];
        let a = p.transpose() as *const TransposeCsr;
        let b = p.transpose() as *const TransposeCsr;
        assert_eq!(a, b, "second call must hit the cache");
        // a clone carries (or rebuilds) an equivalent transpose
        let c = p.clone();
        assert_eq!(c.transpose().sources, p.transpose().sources);
        assert_eq!(c.transpose().row_offsets, p.transpose().row_offsets);
    }

    #[test]
    fn map_and_collect_roundtrip() {
        let g = CsrGraph::from_edge_list(&rmat(&RmatParams::paper(8, 17)));
        let pg = PartitionedGraph::partition(&g, Strategy::High, &[0.7, 0.3], 1);
        let global: Vec<u32> = (0..g.vertex_count as u32).map(|v| v * 3).collect();
        let locals: Vec<Vec<u32>> = pg
            .parts
            .iter()
            .map(|p| p.map_vertex_array(&global, u32::MAX))
            .collect();
        let back = pg.collect_to_global(&locals);
        assert_eq!(back, global);
    }

    #[test]
    fn reduction_shrinks_beta_on_scale_free() {
        let g = CsrGraph::from_edge_list(&rmat(&RmatParams::paper(12, 19)));
        let pg = PartitionedGraph::partition(&g, Strategy::Rand, &[0.5, 0.5], 7);
        let b = pg.beta_stats();
        // random 2-way partitioning: raw β ≈ 50%, reduced far lower (Fig 4)
        assert!((b.beta_raw() - 0.5).abs() < 0.05, "raw={}", b.beta_raw());
        assert!(
            b.beta_reduced() < 0.6 * b.beta_raw(),
            "reduced={} raw={}",
            b.beta_reduced(),
            b.beta_raw()
        );
    }
}
