//! Graph partitioning for hybrid platforms (paper §4.3.1, §6).
//!
//! A [`PartitionedGraph`] splits a CSR graph into per-element partitions
//! with the paper's data layout:
//!
//! - each partition renumbers its vertices into a dense local id space;
//! - boundary edges do **not** store the remote vertex id — they store an
//!   index into a *ghost slot* (the paper's outbox-buffer entry), so all
//!   local edges to the same remote vertex share one slot: this is the
//!   message **reduction** of §3.4, applied structurally;
//! - per remote partition, a [`GhostTable`] records which remote-local
//!   vertices the slots correspond to, sorted by remote id (the paper's
//!   "inbox sorted by vertex IDs" pre-fetch optimization);
//! - within a vertex's adjacency, local edges come first, boundary edges
//!   last (§4.3.4 optimization ii).
//!
//! The per-partition **state layout** shared by CPU and accelerator
//! elements (DESIGN.md §3):
//!
//! ```text
//! [0, nv)                 real local vertices
//! [nv, nv + n_ghost)      ghost slots, grouped by remote partition
//! [nv + n_ghost]          dummy sink (accelerator padding edges land here)
//! ```
//!
//! **Vertex placement** (DESIGN.md §9): which member occupies which local
//! id inside a partition is a free choice — the state layout contract and
//! the ghost-table invariants hold for *any* bijection — and it decides
//! the CPU kernels' memory-access locality (paper §6.3.2, Figs 12–13).
//! [`Placement`] selects that intra-partition order; global outputs are
//! bit-identical across placements (the permutation is invisible after
//! `collect_to_global`, enforced by the golden + differential-fuzz
//! suites).

pub mod assignment;

pub use assignment::{assign, assignment_stats, low_degree_band, AssignmentStats, Strategy};

use crate::graph::CsrGraph;
use std::sync::OnceLock;

/// Intra-partition vertex placement: the order in which a partition's
/// members are renumbered into its dense local id space (DESIGN.md §9).
///
/// Every placement is a bijection over the same member set, so partition
/// structure (edge/weight multisets, ghost-table sorting, transpose
/// in-degrees) and global algorithm outputs are placement-invariant; what
/// changes is the *layout* — and with it cache locality and the probe
/// order of bottom-up sweeps (measured in `benches/fig12_13_cache.rs`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Placement {
    /// Raw assignment order: local ids ascend with global ids.
    AssignmentOrder,
    /// Descending out-degree, ties in assignment order (stable sort).
    /// The historical layout — hubs first gives the accelerator's SIMD
    /// batches uniform work and keeps the hot vertices' state contiguous —
    /// and therefore the default.
    #[default]
    DegreeDesc,
    /// Ascending out-degree, ties in assignment order. The adversarial
    /// counterpart of [`Placement::DegreeDesc`], kept for measurement.
    DegreeAsc,
    /// Per-partition pseudo-BFS over the partition-induced subgraph:
    /// repeatedly seed from the highest-degree unvisited member and run a
    /// BFS over *local* edges, so traversal neighborhoods land near each
    /// other in the local id space (Sallinen et al. 2015's layout
    /// sensitivity argument).
    BfsOrder,
}

/// All placements, in measurement order.
pub const ALL_PLACEMENTS: [Placement; 4] = [
    Placement::AssignmentOrder,
    Placement::DegreeDesc,
    Placement::DegreeAsc,
    Placement::BfsOrder,
];

impl Placement {
    pub fn parse(s: &str) -> Result<Placement, String> {
        match s.to_ascii_lowercase().as_str() {
            "assign" | "assignment" => Ok(Placement::AssignmentOrder),
            "degree-desc" | "degdesc" => Ok(Placement::DegreeDesc),
            "degree-asc" | "degasc" => Ok(Placement::DegreeAsc),
            "bfs" | "bfs-order" => Ok(Placement::BfsOrder),
            _ => Err(format!(
                "unknown placement '{s}' (assign|degree-desc|degree-asc|bfs)"
            )),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Placement::AssignmentOrder => "assign",
            Placement::DegreeDesc => "degree-desc",
            Placement::DegreeAsc => "degree-asc",
            Placement::BfsOrder => "bfs",
        }
    }

    /// Order one partition's members (collected in ascending global id)
    /// into local-id order. Input `members` is the assignment-order list;
    /// the result is a permutation of it. Deterministic for every variant.
    fn order_members(&self, g: &CsrGraph, assignment: &[u8], pid: usize, members: &mut Vec<u32>) {
        match self {
            Placement::AssignmentOrder => {}
            Placement::DegreeDesc => {
                members.sort_by_key(|&x| std::cmp::Reverse(g.out_degree(x)));
            }
            Placement::DegreeAsc => {
                members.sort_by_key(|&x| g.out_degree(x));
            }
            Placement::BfsOrder => {
                *members = bfs_order(g, assignment, pid, members);
            }
        }
    }
}

/// Pseudo-BFS member order (see [`Placement::BfsOrder`]): seeds are taken
/// in descending degree (assignment-order ties); each BFS visits local
/// out-neighbors in adjacency order. Every member appears exactly once.
fn bfs_order(g: &CsrGraph, assignment: &[u8], pid: usize, members: &[u32]) -> Vec<u32> {
    let mut seeds: Vec<u32> = members.to_vec();
    seeds.sort_by_key(|&x| std::cmp::Reverse(g.out_degree(x)));
    let mut visited = vec![false; g.vertex_count];
    let mut order = Vec::with_capacity(members.len());
    let mut queue = std::collections::VecDeque::new();
    for &seed in &seeds {
        if visited[seed as usize] {
            continue;
        }
        visited[seed as usize] = true;
        queue.push_back(seed);
        while let Some(v) = queue.pop_front() {
            order.push(v);
            for &d in g.neighbors(v) {
                if assignment[d as usize] as usize == pid && !visited[d as usize] {
                    visited[d as usize] = true;
                    queue.push_back(d);
                }
            }
        }
    }
    debug_assert_eq!(order.len(), members.len());
    order
}

/// In-edge (transpose) CSR of a partition's local CSR (DESIGN.md §8).
///
/// Rows are **state indices** `[0, state_len())` — real local vertices,
/// then ghost slots, then the dummy sink — the same layout the forward
/// `LocalCsr::targets` entries address, so pull-mode kernels read and
/// write the very same per-partition state arrays as push-mode kernels.
/// `sources[row_offsets[t]..row_offsets[t+1]]` lists the local vertices
/// that have a forward edge into state index `t`, in ascending local id
/// (stable counting sort), so iteration order is deterministic.
///
/// Every forward edge appears exactly once (edge conservation and
/// in-degree sums are property-tested in `rebalance_invariants.rs`).
/// Rows for ghost slots record which local vertices feed that outbox slot
/// — useful for boundary-aware sweeps; the dummy row is always empty.
///
/// Weights are not mirrored: the only pull-mode consumer today is BFS's
/// bottom-up sweep (unweighted); SSSP stays push-mode.
#[derive(Debug, Clone, Default)]
pub struct TransposeCsr {
    /// `state_len + 1` offsets into `sources`.
    pub row_offsets: Vec<u64>,
    /// Local source vertex of each in-edge.
    pub sources: Vec<u32>,
}

impl TransposeCsr {
    /// Build from a partition's forward CSR by counting sort —
    /// `O(|V_p| + |E_p|)`, same recipe as `CsrGraph::from_edge_list`.
    pub fn build(csr: &LocalCsr, state_len: usize) -> TransposeCsr {
        let nv = csr.local_counts.len();
        let mut deg = vec![0u64; state_len + 1];
        for &t in &csr.targets {
            deg[t as usize + 1] += 1;
        }
        for i in 0..state_len {
            deg[i + 1] += deg[i];
        }
        let row_offsets = deg.clone();
        let mut cursor = deg;
        let mut sources = vec![0u32; csr.targets.len()];
        for v in 0..nv {
            let lo = csr.row_offsets[v] as usize;
            let hi = csr.row_offsets[v + 1] as usize;
            for &t in &csr.targets[lo..hi] {
                let slot = cursor[t as usize] as usize;
                sources[slot] = v as u32;
                cursor[t as usize] += 1;
            }
        }
        TransposeCsr { row_offsets, sources }
    }

    /// Local in-neighbors of state index `t`.
    #[inline]
    pub fn sources_of(&self, t: u32) -> &[u32] {
        let lo = self.row_offsets[t as usize] as usize;
        let hi = self.row_offsets[t as usize + 1] as usize;
        &self.sources[lo..hi]
    }

    /// In-degree of state index `t` (local edges only).
    #[inline]
    pub fn in_degree(&self, t: u32) -> u64 {
        self.row_offsets[t as usize + 1] - self.row_offsets[t as usize]
    }

    pub fn edge_count(&self) -> usize {
        self.sources.len()
    }
}

/// Ghost (boundary) table towards one remote partition.
#[derive(Debug, Clone)]
pub struct GhostTable {
    /// The remote partition id.
    pub remote_part: usize,
    /// Local ids *in the remote partition* of each ghost vertex, ascending.
    pub remote_locals: Vec<u32>,
    /// First state-array slot used by this table in the owning partition.
    pub slot_base: usize,
    /// Raw boundary edges that collapsed into this table (β numerator
    /// before reduction, Figure 4).
    pub boundary_edges: u64,
}

impl GhostTable {
    pub fn len(&self) -> usize {
        self.remote_locals.len()
    }
    pub fn is_empty(&self) -> bool {
        self.remote_locals.is_empty()
    }
}

/// Local CSR of a partition. `targets` entries are **state indices**:
/// `< nv` → real local vertex; `>= nv` → ghost slot.
#[derive(Debug, Clone)]
pub struct LocalCsr {
    pub row_offsets: Vec<u64>,
    pub targets: Vec<u32>,
    pub weights: Option<Vec<f32>>,
    /// Per vertex, how many of its targets are local (local-first ordering).
    pub local_counts: Vec<u32>,
}

/// One partition of the graph plus its communication metadata.
#[derive(Debug, Clone)]
pub struct Partition {
    pub id: usize,
    /// Real local vertex count.
    pub nv: usize,
    /// local id -> global id.
    pub local_to_global: Vec<u32>,
    pub csr: LocalCsr,
    pub ghosts: Vec<GhostTable>,
    pub n_ghost: usize,
    /// Local ids in **ascending global id** order — the inverse of the
    /// placement permutation (DESIGN.md §9): `canonical_order[i]` is the
    /// local id of the partition's i-th member in assignment order, so
    /// iterating it visits the same vertex sequence under every
    /// [`Placement`]. Kernels whose f32 accumulation order is observable
    /// (push-mode PageRank's scatter, BC's forward σ adds) iterate this
    /// instead of `0..nv`, which is what makes their global outputs
    /// bit-identical across placements.
    pub canonical_order: Vec<u32>,
    /// Lazily built in-edge CSR for pull/bottom-up kernels (DESIGN.md §8).
    /// Migrations rebuild the whole `Partition`, so the cache can never go
    /// stale; construct with `OnceLock::new()`.
    pub transpose_cache: OnceLock<TransposeCsr>,
}

impl Partition {
    /// The in-edge (transpose) CSR, built on first use and cached. Safe to
    /// call concurrently from per-partition compute threads.
    #[inline]
    pub fn transpose(&self) -> &TransposeCsr {
        self.transpose_cache
            .get_or_init(|| TransposeCsr::build(&self.csr, self.state_len()))
    }

    /// Length of the unified state arrays (real + ghosts + dummy).
    #[inline]
    pub fn state_len(&self) -> usize {
        self.nv + self.n_ghost + 1
    }

    /// Index of the dummy sink slot.
    #[inline]
    pub fn dummy_index(&self) -> usize {
        self.nv + self.n_ghost
    }

    pub fn edge_count(&self) -> usize {
        self.csr.targets.len()
    }

    /// Neighbor state-indices of local vertex `v`.
    #[inline]
    pub fn targets(&self, v: u32) -> &[u32] {
        let lo = self.csr.row_offsets[v as usize] as usize;
        let hi = self.csr.row_offsets[v as usize + 1] as usize;
        &self.csr.targets[lo..hi]
    }

    #[inline]
    pub fn weights(&self, v: u32) -> &[f32] {
        let lo = self.csr.row_offsets[v as usize] as usize;
        let hi = self.csr.row_offsets[v as usize + 1] as usize;
        &self.csr.weights.as_ref().expect("unweighted partition")[lo..hi]
    }

    /// Spread a global per-vertex array into this partition's state layout
    /// (ghost + dummy slots take `fill`).
    pub fn map_vertex_array<T: Copy>(&self, global: &[T], fill: T) -> Vec<T> {
        let mut out = vec![fill; self.state_len()];
        for (l, &g) in self.local_to_global.iter().enumerate() {
            out[l] = global[g as usize];
        }
        out
    }

    /// Bytes of the partition graph structure (paper §4.3.3 item i).
    pub fn graph_bytes(&self) -> u64 {
        (self.csr.row_offsets.len() * 8
            + self.csr.targets.len() * 4
            + self.csr.weights.as_ref().map_or(0, |w| w.len() * 4)
            + self.local_to_global.len() * 4
            + self.canonical_order.len() * 4) as u64
    }

    /// Bytes of the ghost/communication tables, `(vid + s) × slots` with
    /// s = per-message state bytes (paper §4.3.3 items ii/iii).
    pub fn comm_bytes(&self, msg_bytes: u64) -> u64 {
        self.ghosts
            .iter()
            .map(|t| (4 + msg_bytes) * t.len() as u64)
            .sum()
    }
}

/// The partitioned graph: all partitions plus global lookup tables.
#[derive(Debug, Clone)]
pub struct PartitionedGraph {
    pub parts: Vec<Partition>,
    /// global vertex -> partition id.
    pub part_of: Vec<u8>,
    /// global vertex -> local id within its partition.
    pub local_of: Vec<u32>,
    pub global_vertex_count: usize,
    pub total_edges: usize,
    /// Intra-partition vertex placement this graph was built with; a
    /// dynamic-α migration rebuild re-places with the same policy.
    pub placement: Placement,
}

/// Communication-volume statistics (Figure 4).
#[derive(Debug, Clone)]
pub struct BetaStats {
    /// Boundary edges (messages without reduction).
    pub boundary_edges: u64,
    /// Ghost slots (messages with reduction).
    pub reduced_messages: u64,
    pub total_edges: u64,
}

impl BetaStats {
    /// β without reduction: fraction of edges that cross partitions.
    pub fn beta_raw(&self) -> f64 {
        self.boundary_edges as f64 / self.total_edges.max(1) as f64
    }
    /// β with reduction: messages actually sent per edge.
    pub fn beta_reduced(&self) -> f64 {
        self.reduced_messages as f64 / self.total_edges.max(1) as f64
    }
}

impl PartitionedGraph {
    /// Partition `g` according to `assignment` with the default
    /// [`Placement`] (degree-descending, the historical layout).
    pub fn build(g: &CsrGraph, assignment: &[u8], nparts: usize) -> PartitionedGraph {
        Self::build_placed(g, assignment, nparts, Placement::default())
    }

    /// Partition `g` according to `assignment` (one partition id per
    /// vertex; ids must be `< nparts`), renumbering each partition's local
    /// id space in `placement` order (DESIGN.md §9).
    pub fn build_placed(
        g: &CsrGraph,
        assignment: &[u8],
        nparts: usize,
        placement: Placement,
    ) -> PartitionedGraph {
        assert_eq!(assignment.len(), g.vertex_count);
        let v_total = g.vertex_count;

        // --- local id spaces -------------------------------------------------
        // Members are collected in ascending global id (assignment order),
        // then permuted by the placement policy; local id = position in
        // the permuted list.
        let mut members: Vec<Vec<u32>> = vec![Vec::new(); nparts];
        for v in 0..v_total as u32 {
            members[assignment[v as usize] as usize].push(v);
        }
        for (pid, m) in members.iter_mut().enumerate() {
            placement.order_members(g, assignment, pid, m);
        }
        let mut local_of = vec![0u32; v_total];
        for m in &members {
            for (l, &v) in m.iter().enumerate() {
                local_of[v as usize] = l as u32;
            }
        }

        // --- per-partition build ---------------------------------------------
        let mut parts = Vec::with_capacity(nparts);
        for (pid, mem) in members.iter().enumerate() {
            let nv = mem.len();

            // Pass 1: collect unique remote (part, remote_local) pairs and
            // raw boundary counts.
            let mut boundary: Vec<(u8, u32)> = Vec::new();
            let mut boundary_count = vec![0u64; nparts];
            for &gv in mem {
                for &gd in g.neighbors(gv) {
                    let q = assignment[gd as usize];
                    if q as usize != pid {
                        boundary.push((q, local_of[gd as usize]));
                        boundary_count[q as usize] += 1;
                    }
                }
            }
            boundary.sort_unstable();
            boundary.dedup();

            // Ghost tables grouped by remote partition, slots contiguous.
            let mut ghosts: Vec<GhostTable> = Vec::new();
            let mut slot_base = nv;
            let mut i = 0;
            while i < boundary.len() {
                let q = boundary[i].0;
                let mut remote_locals = Vec::new();
                while i < boundary.len() && boundary[i].0 == q {
                    remote_locals.push(boundary[i].1);
                    i += 1;
                }
                let len = remote_locals.len();
                ghosts.push(GhostTable {
                    remote_part: q as usize,
                    remote_locals,
                    slot_base,
                    boundary_edges: boundary_count[q as usize],
                });
                slot_base += len;
            }
            let n_ghost = slot_base - nv;

            // Pass 2: rewrite edges to state indices, local-first order.
            let mut row_offsets = Vec::with_capacity(nv + 1);
            row_offsets.push(0u64);
            let mut targets: Vec<u32> = Vec::new();
            let mut weights: Option<Vec<f32>> = g.weights.as_ref().map(|_| Vec::new());
            let mut local_counts = Vec::with_capacity(nv);
            let mut ghost_buf: Vec<(u32, f32)> = Vec::new();
            for &gv in mem {
                let glo = g.row_offsets[gv as usize] as usize;
                let nbrs = g.neighbors(gv);
                ghost_buf.clear();
                let mut n_local = 0u32;
                for (k, &gd) in nbrs.iter().enumerate() {
                    let w = g.weights.as_ref().map_or(0.0, |ws| ws[glo + k]);
                    let q = assignment[gd as usize] as usize;
                    if q == pid {
                        targets.push(local_of[gd as usize]);
                        if let Some(wv) = &mut weights {
                            wv.push(w);
                        }
                        n_local += 1;
                    } else {
                        // find the ghost table for q and the slot via
                        // binary search over its sorted remote_locals.
                        let t = ghosts
                            .iter()
                            .find(|t| t.remote_part == q)
                            .expect("ghost table must exist");
                        let idx = t
                            .remote_locals
                            .binary_search(&local_of[gd as usize])
                            .expect("ghost entry must exist");
                        ghost_buf.push(((t.slot_base + idx) as u32, w));
                    }
                }
                for &(slot, w) in &ghost_buf {
                    targets.push(slot);
                    if let Some(wv) = &mut weights {
                        wv.push(w);
                    }
                }
                local_counts.push(n_local);
                row_offsets.push(targets.len() as u64);
            }

            // Inverse of the placement permutation: local ids sorted by
            // global id (members are distinct, so the key is unique).
            let mut canonical_order: Vec<u32> = (0..nv as u32).collect();
            canonical_order.sort_by_key(|&l| mem[l as usize]);

            parts.push(Partition {
                id: pid,
                nv,
                local_to_global: mem.clone(),
                csr: LocalCsr { row_offsets, targets, weights, local_counts },
                ghosts,
                n_ghost,
                canonical_order,
                transpose_cache: OnceLock::new(),
            });
        }

        PartitionedGraph {
            parts,
            part_of: assignment.to_vec(),
            local_of,
            global_vertex_count: v_total,
            total_edges: g.edge_count(),
            placement,
        }
    }

    /// Convenience: assign + build in one call, default placement.
    pub fn partition(
        g: &CsrGraph,
        strategy: Strategy,
        shares: &[f64],
        seed: u64,
    ) -> PartitionedGraph {
        Self::partition_placed(g, strategy, shares, seed, Placement::default())
    }

    /// Convenience: assign + build in one call with an explicit placement.
    pub fn partition_placed(
        g: &CsrGraph,
        strategy: Strategy,
        shares: &[f64],
        seed: u64,
        placement: Placement,
    ) -> PartitionedGraph {
        let a = assign(g, strategy, shares, seed);
        PartitionedGraph::build_placed(g, &a, shares.len(), placement)
    }

    /// Figure 4 statistics.
    pub fn beta_stats(&self) -> BetaStats {
        let mut boundary = 0u64;
        let mut reduced = 0u64;
        for p in &self.parts {
            for t in &p.ghosts {
                boundary += t.boundary_edges;
                reduced += t.len() as u64;
            }
        }
        BetaStats {
            boundary_edges: boundary,
            reduced_messages: reduced,
            total_edges: self.total_edges as u64,
        }
    }

    /// Realized edge share per partition (the effective α of partition 0).
    pub fn edge_shares(&self) -> Vec<f64> {
        self.parts
            .iter()
            .map(|p| p.edge_count() as f64 / self.total_edges.max(1) as f64)
            .collect()
    }

    /// Gather a per-partition-state array back into a global array.
    pub fn collect_to_global<T: Copy + Default>(&self, locals: &[Vec<T>]) -> Vec<T> {
        let mut out = vec![T::default(); self.global_vertex_count];
        for (p, vals) in self.parts.iter().zip(locals) {
            for (l, &g) in p.local_to_global.iter().enumerate() {
                out[g as usize] = vals[l];
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generator::{rmat, with_random_weights, RmatParams};
    use crate::graph::{CsrGraph, EdgeList};

    fn small() -> CsrGraph {
        // 0->1,0->2,1->2,2->3,3->0,3->1 ; partitions {0,1} and {2,3}
        let mut el = EdgeList::new(4);
        for &(s, d) in &[(0, 1), (0, 2), (1, 2), (2, 3), (3, 0), (3, 1)] {
            el.push(s, d);
        }
        CsrGraph::from_edge_list(&el)
    }

    #[test]
    fn two_way_structure() {
        let g = small();
        let pg = PartitionedGraph::build(&g, &[0, 0, 1, 1], 2);
        assert_eq!(pg.parts.len(), 2);
        let p0 = &pg.parts[0];
        let p1 = &pg.parts[1];
        assert_eq!(p0.nv, 2);
        assert_eq!(p1.nv, 2);
        // p0 boundary edges: 0->2 and 1->2 → both to the same remote vertex
        // → ONE ghost slot (reduction!).
        assert_eq!(p0.n_ghost, 1);
        assert_eq!(p0.ghosts[0].boundary_edges, 2);
        // p1 boundary: 3->0, 3->1 → two distinct remotes → two slots.
        assert_eq!(p1.n_ghost, 2);
        // edge counts preserved
        assert_eq!(p0.edge_count() + p1.edge_count(), g.edge_count());
    }

    #[test]
    fn beta_stats_small() {
        let g = small();
        let pg = PartitionedGraph::build(&g, &[0, 0, 1, 1], 2);
        let b = pg.beta_stats();
        assert_eq!(b.boundary_edges, 4); // 0->2,1->2,3->0,3->1
        assert_eq!(b.reduced_messages, 3);
        assert!((b.beta_raw() - 4.0 / 6.0).abs() < 1e-12);
        assert!((b.beta_reduced() - 3.0 / 6.0).abs() < 1e-12);
    }

    #[test]
    fn local_edges_first() {
        let g = small();
        let pg = PartitionedGraph::build(&g, &[0, 0, 1, 1], 2);
        for p in &pg.parts {
            for v in 0..p.nv as u32 {
                let t = p.targets(v);
                let nl = p.csr.local_counts[v as usize] as usize;
                assert!(t[..nl].iter().all(|&x| (x as usize) < p.nv));
                assert!(t[nl..].iter().all(|&x| (x as usize) >= p.nv));
            }
        }
    }

    #[test]
    fn state_indices_in_range() {
        let g = CsrGraph::from_edge_list(&rmat(&RmatParams::paper(10, 7)));
        let pg = PartitionedGraph::partition(&g, Strategy::High, &[0.6, 0.4], 1);
        for p in &pg.parts {
            let n = p.state_len() as u32;
            assert!(p.csr.targets.iter().all(|&t| t < n - 1)); // never dummy
        }
    }

    #[test]
    fn weights_preserved_across_partitioning() {
        let mut el = rmat(&RmatParams::paper(8, 3));
        with_random_weights(&mut el, 64, 5);
        let g = CsrGraph::from_edge_list(&el);
        let pg = PartitionedGraph::partition(&g, Strategy::Rand, &[0.5, 0.5], 2);
        // total weight preserved
        let total_g: f64 = g.weights.as_ref().unwrap().iter().map(|&w| w as f64).sum();
        let total_p: f64 = pg
            .parts
            .iter()
            .map(|p| {
                p.csr
                    .weights
                    .as_ref()
                    .unwrap()
                    .iter()
                    .map(|&w| w as f64)
                    .sum::<f64>()
            })
            .sum();
        assert!((total_g - total_p).abs() < 1e-6);
    }

    #[test]
    fn ghost_tables_sorted_and_consistent() {
        let g = CsrGraph::from_edge_list(&rmat(&RmatParams::paper(9, 11)));
        let pg = PartitionedGraph::partition(&g, Strategy::Rand, &[0.4, 0.3, 0.3], 3);
        for p in &pg.parts {
            let mut next_base = p.nv;
            for t in &p.ghosts {
                assert_eq!(t.slot_base, next_base);
                next_base += t.len();
                assert!(t.remote_locals.windows(2).all(|w| w[0] < w[1]));
                let rp = &pg.parts[t.remote_part];
                assert!(t.remote_locals.iter().all(|&l| (l as usize) < rp.nv));
            }
            assert_eq!(next_base, p.nv + p.n_ghost);
        }
    }

    #[test]
    fn round_trip_edges_through_ghosts() {
        // Every global edge must be recoverable from the partitioned form.
        let g = CsrGraph::from_edge_list(&rmat(&RmatParams::paper(8, 13)));
        let pg = PartitionedGraph::partition(&g, Strategy::Low, &[0.5, 0.5], 4);
        let mut rebuilt: Vec<(u32, u32)> = Vec::new();
        for p in &pg.parts {
            for v in 0..p.nv as u32 {
                let gv = p.local_to_global[v as usize];
                for &t in p.targets(v) {
                    let gd = if (t as usize) < p.nv {
                        p.local_to_global[t as usize]
                    } else {
                        // resolve ghost slot → remote partition local id
                        let tab = p
                            .ghosts
                            .iter()
                            .find(|tab| {
                                (t as usize) >= tab.slot_base
                                    && (t as usize) < tab.slot_base + tab.len()
                            })
                            .unwrap();
                        let rl = tab.remote_locals[t as usize - tab.slot_base];
                        pg.parts[tab.remote_part].local_to_global[rl as usize]
                    };
                    rebuilt.push((gv, gd));
                }
            }
        }
        let mut orig: Vec<(u32, u32)> = g.iter_edges().collect();
        orig.sort_unstable();
        rebuilt.sort_unstable();
        assert_eq!(orig, rebuilt);
    }

    #[test]
    fn transpose_inverts_local_csr() {
        let g = small();
        let pg = PartitionedGraph::build(&g, &[0, 0, 1, 1], 2);
        for p in &pg.parts {
            let tr = p.transpose();
            // edge conservation: every forward edge appears exactly once
            assert_eq!(tr.edge_count(), p.edge_count());
            assert_eq!(tr.row_offsets.len(), p.state_len() + 1);
            // forward multiset == transpose multiset
            let mut fwd: Vec<(u32, u32)> = Vec::new();
            for v in 0..p.nv as u32 {
                for &t in p.targets(v) {
                    fwd.push((v, t));
                }
            }
            let mut rev: Vec<(u32, u32)> = Vec::new();
            for t in 0..p.state_len() as u32 {
                for &u in tr.sources_of(t) {
                    rev.push((u, t));
                }
            }
            fwd.sort_unstable();
            rev.sort_unstable();
            assert_eq!(fwd, rev);
            // dummy row is empty; sources ascend within a row
            assert_eq!(tr.in_degree(p.dummy_index() as u32), 0);
            for t in 0..p.state_len() as u32 {
                let s = tr.sources_of(t);
                assert!(s.windows(2).all(|w| w[0] <= w[1]));
            }
        }
    }

    #[test]
    fn transpose_cached_and_cloned() {
        let g = CsrGraph::from_edge_list(&rmat(&RmatParams::paper(8, 21)));
        let pg = PartitionedGraph::partition(&g, Strategy::High, &[0.5, 0.5], 3);
        let p = &pg.parts[0];
        let a = p.transpose() as *const TransposeCsr;
        let b = p.transpose() as *const TransposeCsr;
        assert_eq!(a, b, "second call must hit the cache");
        // a clone carries (or rebuilds) an equivalent transpose
        let c = p.clone();
        assert_eq!(c.transpose().sources, p.transpose().sources);
        assert_eq!(c.transpose().row_offsets, p.transpose().row_offsets);
    }

    #[test]
    fn map_and_collect_roundtrip() {
        let g = CsrGraph::from_edge_list(&rmat(&RmatParams::paper(8, 17)));
        let pg = PartitionedGraph::partition(&g, Strategy::High, &[0.7, 0.3], 1);
        let global: Vec<u32> = (0..g.vertex_count as u32).map(|v| v * 3).collect();
        let locals: Vec<Vec<u32>> = pg
            .parts
            .iter()
            .map(|p| p.map_vertex_array(&global, u32::MAX))
            .collect();
        let back = pg.collect_to_global(&locals);
        assert_eq!(back, global);
    }

    #[test]
    fn placement_parse_and_names() {
        assert_eq!(Placement::parse("assign").unwrap(), Placement::AssignmentOrder);
        assert_eq!(Placement::parse("DEGREE-DESC").unwrap(), Placement::DegreeDesc);
        assert_eq!(Placement::parse("degasc").unwrap(), Placement::DegreeAsc);
        assert_eq!(Placement::parse("bfs").unwrap(), Placement::BfsOrder);
        assert!(Placement::parse("hilbert").is_err());
        assert_eq!(Placement::default(), Placement::DegreeDesc);
        for p in ALL_PLACEMENTS {
            assert_eq!(Placement::parse(p.name()).unwrap(), p);
        }
    }

    #[test]
    fn default_placement_preserves_degree_desc_layout() {
        // `build` must stay byte-compatible with the pre-placement layout:
        // members in descending degree, assignment-order ties.
        let g = CsrGraph::from_edge_list(&rmat(&RmatParams::paper(9, 4)));
        let a = assign(&g, Strategy::Rand, &[0.5, 0.5], 3);
        let pg = PartitionedGraph::build(&g, &a, 2);
        let pg2 = PartitionedGraph::build_placed(&g, &a, 2, Placement::DegreeDesc);
        for (p, q) in pg.parts.iter().zip(&pg2.parts) {
            assert_eq!(p.local_to_global, q.local_to_global);
            assert_eq!(p.csr.targets, q.csr.targets);
        }
        assert_eq!(pg.placement, Placement::DegreeDesc);
    }

    #[test]
    fn placements_are_bijections_with_expected_order() {
        let g = CsrGraph::from_edge_list(&rmat(&RmatParams::paper(9, 8)));
        let a = assign(&g, Strategy::Rand, &[0.4, 0.3, 0.3], 5);
        let base = PartitionedGraph::build_placed(&g, &a, 3, Placement::AssignmentOrder);
        for placement in ALL_PLACEMENTS {
            let pg = PartitionedGraph::build_placed(&g, &a, 3, placement);
            assert_eq!(pg.placement, placement);
            for (p, b) in pg.parts.iter().zip(&base.parts) {
                // same member set, different order: a bijection
                let mut sorted = p.local_to_global.clone();
                sorted.sort_unstable();
                assert_eq!(sorted, b.local_to_global, "{placement:?}");
                match placement {
                    Placement::AssignmentOrder => {
                        assert!(p.local_to_global.windows(2).all(|w| w[0] < w[1]));
                    }
                    Placement::DegreeDesc => assert!(p
                        .local_to_global
                        .windows(2)
                        .all(|w| g.out_degree(w[0]) >= g.out_degree(w[1]))),
                    Placement::DegreeAsc => assert!(p
                        .local_to_global
                        .windows(2)
                        .all(|w| g.out_degree(w[0]) <= g.out_degree(w[1]))),
                    Placement::BfsOrder => {
                        if p.nv > 0 {
                            // the first vertex is a maximum-degree member
                            let max = p
                                .local_to_global
                                .iter()
                                .map(|&v| g.out_degree(v))
                                .max()
                                .unwrap();
                            assert_eq!(g.out_degree(p.local_to_global[0]), max);
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn canonical_order_inverts_every_placement() {
        let g = CsrGraph::from_edge_list(&rmat(&RmatParams::paper(8, 6)));
        let a = assign(&g, Strategy::High, &[0.6, 0.4], 1);
        for placement in ALL_PLACEMENTS {
            let pg = PartitionedGraph::build_placed(&g, &a, 2, placement);
            for p in &pg.parts {
                assert_eq!(p.canonical_order.len(), p.nv);
                // canonical iteration visits members in ascending global id
                let seq: Vec<u32> = p
                    .canonical_order
                    .iter()
                    .map(|&l| p.local_to_global[l as usize])
                    .collect();
                assert!(seq.windows(2).all(|w| w[0] < w[1]), "{placement:?}");
                // and is itself a permutation of the local id space
                let mut ids = p.canonical_order.clone();
                ids.sort_unstable();
                assert_eq!(ids, (0..p.nv as u32).collect::<Vec<_>>());
            }
        }
    }

    #[test]
    fn placement_preserves_structure_invariants() {
        // Edge/weight multisets, ghost-table sorting, and β statistics are
        // placement-invariant; only the local id labels move.
        let mut el = rmat(&RmatParams::paper(9, 12));
        with_random_weights(&mut el, 32, 9);
        let g = CsrGraph::from_edge_list(&el);
        let a = assign(&g, Strategy::Rand, &[0.5, 0.5], 2);
        let base = PartitionedGraph::build_placed(&g, &a, 2, Placement::AssignmentOrder);
        for placement in ALL_PLACEMENTS {
            let pg = PartitionedGraph::build_placed(&g, &a, 2, placement);
            assert_eq!(pg.beta_stats().boundary_edges, base.beta_stats().boundary_edges);
            assert_eq!(pg.beta_stats().reduced_messages, base.beta_stats().reduced_messages);
            for (p, b) in pg.parts.iter().zip(&base.parts) {
                assert_eq!(p.edge_count(), b.edge_count(), "{placement:?}");
                assert_eq!(p.n_ghost, b.n_ghost, "{placement:?}");
                let sum = |x: &Partition| -> f64 {
                    x.csr.weights.as_ref().unwrap().iter().map(|&w| w as f64).sum()
                };
                assert!((sum(p) - sum(b)).abs() < 1e-6, "{placement:?}");
                let mut next_base = p.nv;
                for t in &p.ghosts {
                    assert_eq!(t.slot_base, next_base);
                    next_base += t.len();
                    assert!(t.remote_locals.windows(2).all(|w| w[0] < w[1]));
                }
            }
        }
    }

    #[test]
    fn bfs_order_covers_all_members_exactly_once() {
        // incl. members unreachable from the first seed (multi-seed)
        let mut el = EdgeList::new(8);
        // two local components in partition 0: {0,1,2} and {3,4}; isolated 5
        for &(s, d) in &[(0, 1), (1, 2), (3, 4)] {
            el.push(s, d);
        }
        el.push(6, 7); // partition 1
        let g = CsrGraph::from_edge_list(&el);
        let a: Vec<u8> = vec![0, 0, 0, 0, 0, 0, 1, 1];
        let pg = PartitionedGraph::build_placed(&g, &a, 2, Placement::BfsOrder);
        let mut got = pg.parts[0].local_to_global.clone();
        got.sort_unstable();
        assert_eq!(got, vec![0, 1, 2, 3, 4, 5]);
        // seeds descend by degree: 0 (deg 1) ... all degree-1 seeds tie, so
        // assignment order breaks them: 0's component first, then 3's, then 5
        assert_eq!(pg.parts[0].local_to_global, vec![0, 1, 2, 3, 4, 5]);
    }

    #[test]
    fn map_collect_roundtrip_every_placement() {
        // collect_to_global ∘ map_vertex_array = id for every placement
        let g = CsrGraph::from_edge_list(&rmat(&RmatParams::paper(8, 19)));
        let a = assign(&g, Strategy::Low, &[0.3, 0.4, 0.3], 4);
        let global: Vec<u32> = (0..g.vertex_count as u32).map(|v| v ^ 0x5a5a).collect();
        for placement in ALL_PLACEMENTS {
            let pg = PartitionedGraph::build_placed(&g, &a, 3, placement);
            let locals: Vec<Vec<u32>> = pg
                .parts
                .iter()
                .map(|p| p.map_vertex_array(&global, u32::MAX))
                .collect();
            assert_eq!(pg.collect_to_global(&locals), global, "{placement:?}");
        }
    }

    #[test]
    fn reduction_shrinks_beta_on_scale_free() {
        let g = CsrGraph::from_edge_list(&rmat(&RmatParams::paper(12, 19)));
        let pg = PartitionedGraph::partition(&g, Strategy::Rand, &[0.5, 0.5], 7);
        let b = pg.beta_stats();
        // random 2-way partitioning: raw β ≈ 50%, reduced far lower (Fig 4)
        assert!((b.beta_raw() - 0.5).abs() < 0.05, "raw={}", b.beta_raw());
        assert!(
            b.beta_reduced() < 0.6 * b.beta_raw(),
            "reduced={} raw={}",
            b.beta_reduced(),
            b.beta_raw()
        );
    }
}
