//! `totem` — the hybrid graph-processing launcher.
//!
//! Subcommands:
//!   run        execute an algorithm on a workload under a hardware config
//!   serve      answer a stream of queries concurrently over one shared
//!              partitioned graph, batching compatible BFS/reachability
//!              queries into bit-parallel multi-source traversals
//!   model      evaluate the performance model (Eqs. 1–4)
//!   calibrate  measure r_cpu / r_acc / c on this testbed
//!   generate   write a workload to disk (edge list or binary CSR)
//!   convert    stream any input (workload, .el, .tcsr) into a `.tcsr` v2
//!              container or text edge list with bounded staging memory
//!   info       degree-distribution statistics of a workload
//!   beta       boundary-edge statistics for a partitioning (Fig. 4)
//!
//! Examples:
//!   totem run --alg bfs --workload rmat14 --hw 2S1G --alpha 0.7 --strategy high
//!   totem run --alg pagerank --workload ukweb --hw 2S2G --alpha 0.6 --rounds 5
//!   totem model --beta 0.05 --rcpu 1e9 --c 3e9
//!   totem calibrate --alg bfs --workload rmat13
//!   totem beta --workload twitter --parts 2 --strategy rand

use anyhow::{anyhow, bail, Context, Result};
use totem::engine::{EngineConfig, StateArray};
use totem::graph::delta::{self, DeltaBatch};
use totem::graph::ingest;
use totem::graph::store;
use totem::graph::{io as gio, properties, GraphStore, LoadMode, Workload};
use totem::harness::{
    build_workload, incremental_rerun, measure, resolve_source, run_alg, AlgKind, FullReason,
    Recompute, RunSpec,
};
use totem::model::{self, calibrate, ModelParams};
use totem::partition::{PartitionedGraph, Strategy};
use totem::report::{fmt_secs, fmt_teps, Table};
use totem::util::args::Args;
use totem::util::{fmt_bytes, fmt_count};
use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::{Path, PathBuf};

fn main() {
    let args = match Args::from_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    };
    let cmd = args.positional.first().cloned().unwrap_or_else(|| "help".to_string());
    let code = match cmd.as_str() {
        "run" => run_cmd(&args),
        "serve" => serve_cmd(&args),
        "model" => model_cmd(&args),
        "calibrate" => calibrate_cmd(&args),
        "generate" => generate_cmd(&args),
        "convert" => convert_cmd(&args),
        "info" => info_cmd(&args),
        "beta" => beta_cmd(&args),
        "help" | "--help" | "-h" => {
            print!("{}", HELP);
            Ok(())
        }
        other => Err(anyhow!("unknown command '{other}' — try `totem help`")),
    }
    .map(|_| 0)
    .unwrap_or_else(|e| {
        eprintln!("error: {e:#}");
        1
    });
    std::process::exit(code);
}

const HELP: &str = "\
totem — hybrid (CPU + accelerator) graph processing engine

USAGE: totem <command> [--flags]

COMMANDS:
  run        --alg bfs|pagerank|sssp|bc|cc|widest|triangles|kcore|labelprop|ppr
             --workload rmatN|uniformN|twitter|ukweb|csr:PATH
             --hw xS[yG] --alpha F --strategy rand|high|low [--source N]
             [--placement assign|degree-desc|degree-asc|bfs]
             [--rounds N] [--reps N] [--seed N] [--instrument]
             [--artifacts DIR] [--threads N] [--budget-mb N]
             [--balance vertex|edge|hub-split]
             [--direction] [--dir-alpha F] [--dir-beta F]
             [--store auto|mmap|buffered] [--no-verify] [--dump-output PATH]
             [--mutations PATH] [--mutate-mode incremental|full]
             (--threads 0 or omitted = one worker per available core;
              --rounds applies to the fixed-iteration algorithms
              (pagerank, ppr, labelprop); ppr personalizes to --source;
              --balance picks how CPU kernels cut chunks, DESIGN.md §11;
              --store picks how csr:PATH containers load, DESIGN.md §12;
              --dump-output writes per-vertex results for exact diffing;
              --mutations replays `add u v [w]` / `del u v` batches
              separated by `commit` lines (DESIGN.md §14.1), re-solving
              after each batch — incrementally (warm-start / residual
              push, with full-recompute fallback) or from scratch;
              --dump-output then dumps the post-mutation result)
  serve      --workload W [--queries PATH] [--nqueries N] [--rate QPS]
             [--serve-workers N] [--max-inflight N] [--max-batch N]
             [--cache N] [--weights] [--rounds N] [--dump-dir DIR]
             [--mutations PATH] [--mutate-policy drain|reject]
             [--hw xS --alpha F --strategy S --threads N ...]
             (queries: one per line, `bfs V|reach V|sssp V|pagerank|ppr V`,
              replayed at --rate queries/s (0 = as fast as admitted);
              no --queries = --nqueries synthetic queries (seeded
              bfs/reach/ppr mix);
              --max-batch 1 --cache 0 disables batching/caching for
              sequential-baseline diffs; --dump-dir writes one
              per-vertex file per answered query for exact diffing;
              --mutations interleaves its commit batches evenly through
              the query stream — queries linearize around each commit
              per --mutate-policy, DESIGN.md §14.3)
  model      [--alphas a,b,c] [--beta F] [--rcpu F] [--racc F] [--c F] [--msg-bytes F]
  calibrate  --alg A --workload W [--alpha F] [--artifacts DIR]
  generate   --workload W --out PATH [--format el|csr] [--seed N] [--weights]
  convert    <workload|in.el|in.tcsr> <out.tcsr|out.el>
             [--weights] [--seed N] [--spill-edges N]
             [--store auto|mmap|buffered] [--no-verify]
             (streams through fixed-size spill runs: edge staging memory is
              bounded by --spill-edges regardless of graph size; .tcsr in →
              .tcsr out re-encodes, migrating v1 containers to v2)
  info       --workload W [--seed N]
  beta       --workload W --parts N [--strategy S] [--seed N]
";

/// `--store` flag → container load mode (DESIGN.md §12.3).
fn load_mode(args: &Args) -> Result<LoadMode> {
    LoadMode::parse(&args.str_or("store", "auto")).map_err(anyhow::Error::msg)
}

fn parse_workload_or_file(args: &Args, alg: Option<AlgKind>) -> Result<totem::graph::CsrGraph> {
    let w = args.str_or("workload", "rmat14");
    let seed = args.u64_or("seed", 42).map_err(anyhow::Error::msg)?;
    if let Some(path) = w.strip_prefix("csr:") {
        let st = GraphStore::open_with(
            &PathBuf::from(path),
            load_mode(args)?,
            !args.has("no-verify"),
        )?;
        if st.is_mapped() {
            eprintln!("# csr:{path} mmap-backed (0 heap bytes for CSR arrays)");
        }
        return Ok(st.into_graph());
    }
    if let Some(path) = w.strip_prefix("el:") {
        let el = gio::read_edge_list(&PathBuf::from(path))?;
        return Ok(totem::graph::CsrGraph::from_edge_list(&el));
    }
    let wl = Workload::parse(&w).map_err(anyhow::Error::msg)?;
    Ok(match alg {
        Some(a) => build_workload(wl, seed, a),
        None => wl.build(seed),
    })
}

fn engine_config(args: &Args, alg: AlgKind) -> Result<EngineConfig> {
    let hw = args.str_or("hw", "1S");
    let alpha = args.f64_or("alpha", 0.7).map_err(anyhow::Error::msg)?;
    let strategy =
        Strategy::parse(&args.str_or("strategy", "high")).map_err(anyhow::Error::msg)?;
    // --threads 0 (the default) = auto: one worker per available core,
    // clamped to the worker-pool cap — surfaced so a 512-core banner
    // never claims parallelism the pool cannot deliver.
    let threads = match args.usize_or("threads", 0).map_err(anyhow::Error::msg)? {
        0 => {
            let detected = totem::engine::detected_threads();
            let clamped = totem::engine::default_threads();
            if detected > clamped {
                eprintln!(
                    "# auto threads clamped: {detected} cores detected, worker pool capped at {clamped}"
                );
            }
            clamped
        }
        n => n,
    };
    let mut cfg = EngineConfig::from_notation(&hw, alpha, strategy, threads)
        .map_err(anyhow::Error::msg)?;
    // Intra-partition balance mode (DESIGN.md §11): how CPU kernels cut
    // their per-superstep chunks — by vertex count, by edge mass, or edge
    // mass with the dominant hub's adjacency sharded across workers.
    let bal_str = args.str_or("balance", "vertex");
    let balance = totem::engine::Balance::parse(&bal_str)
        .ok_or_else(|| anyhow!("unknown --balance '{bal_str}' (vertex|edge|hub-split)"))?;
    cfg = cfg.with_balance(balance);
    // Intra-partition vertex placement (DESIGN.md §9): a pure layout
    // knob — outputs are bit-identical across placements.
    let placement = totem::partition::Placement::parse(&args.str_or("placement", "degree-desc"))
        .map_err(anyhow::Error::msg)?;
    cfg = cfg
        .with_seed(args.u64_or("seed", 42).map_err(anyhow::Error::msg)?)
        .with_instrument(args.has("instrument"))
        .with_artifacts(args.str_or("artifacts", "artifacts"))
        .with_placement(placement);
    let mb = args.usize_or("budget-mb", 0).map_err(anyhow::Error::msg)?;
    if mb > 0 {
        cfg.accel_memory_budget = (mb as u64) << 20;
    }
    if alg.uses_rounds() {
        cfg.rounds = Some(args.usize_or("rounds", 5).map_err(anyhow::Error::msg)?);
    }
    // Direction-optimized traversal (DESIGN.md §8): Beamer α/β heuristic
    // per CPU element; accelerator partitions always stay top-down.
    if args.has("direction") {
        cfg = cfg.with_direction(totem::engine::DirectionConfig {
            alpha: args.f64_or("dir-alpha", 15.0).map_err(anyhow::Error::msg)?,
            beta: args.f64_or("dir-beta", 18.0).map_err(anyhow::Error::msg)?,
        });
    }
    Ok(cfg)
}

fn run_cmd(args: &Args) -> Result<()> {
    let alg = AlgKind::parse(&args.str_or("alg", "bfs")).map_err(anyhow::Error::msg)?;
    let g = parse_workload_or_file(args, Some(alg))?;
    let cfg = engine_config(args, alg)?;
    let spec = RunSpec::new(alg)
        .with_source(args.u64_or("source", u32::MAX as u64).map_err(anyhow::Error::msg)? as u32)
        .with_rounds(args.usize_or("rounds", 5).map_err(anyhow::Error::msg)?);
    let reps = args.usize_or("reps", 3).map_err(anyhow::Error::msg)?;

    eprintln!(
        "# {} on |V|={} |E|={} — {} partitions",
        alg.name(),
        fmt_count(g.vertex_count as u64),
        fmt_count(g.edge_count() as u64),
        cfg.num_partitions()
    );
    let m = measure(&g, spec, &cfg, reps)?;
    let r = &m.last;

    println!("algorithm        : {}", alg.name());
    println!("supersteps       : {}", r.supersteps);
    println!(
        "makespan         : {} ± {} (95% CI, {} reps)",
        fmt_secs(m.makespan_secs),
        fmt_secs(m.makespan_ci95),
        reps
    );
    println!("traversal rate   : {}", fmt_teps(m.teps));
    if cfg.direction.is_some() {
        println!(
            "direction        : {} of {} supersteps ran bottom-up",
            m.pull_steps, r.supersteps
        );
    } else {
        println!("direction        : push-only");
    }
    println!("placement        : {}", m.placement.name());
    println!("parallelism      : {} threads, {} balance", m.threads, cfg.balance.name());
    println!("bottleneck comp. : {}", fmt_secs(m.bottleneck_secs));
    println!("communication    : {}", fmt_secs(m.comm_secs));
    println!(
        "graph memory     : {} CSR, {} heap-owned{}",
        fmt_bytes(m.graph_bytes),
        fmt_bytes(m.graph_owned_bytes),
        if g.is_mapped() { " (mmap-backed)" } else { "" }
    );
    println!("partition memory : {}", fmt_bytes(m.partition_bytes));
    if let Some(rss) = m.peak_rss_bytes {
        println!("peak RSS         : {}", fmt_bytes(rss));
    }
    println!(
        "comm volume      : {} in {} messages",
        fmt_bytes(r.metrics.total_bytes()),
        fmt_count(r.metrics.total_messages())
    );
    println!(
        "beta             : raw {:.2}% -> reduced {:.2}%",
        100.0 * r.beta.beta_raw(),
        100.0 * r.beta.beta_reduced()
    );
    let mut t = Table::new(
        "Partitions",
        &["part", "element", "vertices", "edges", "share", "compute", "footprint"],
    );
    for (i, fp) in r.footprints.iter().enumerate() {
        t.row(vec![
            i.to_string(),
            format!("{:?}", cfg.elements[i]),
            fmt_count(fp.vertices as u64),
            fmt_count(fp.edges as u64),
            format!("{:.1}%", 100.0 * r.shares[i]),
            fmt_secs(r.metrics.partition_compute_secs(i)),
            fmt_bytes(fp.total()),
        ]);
    }
    print!("{}", t.markdown());
    if args.has("instrument") {
        for (i, mc) in r.metrics.mem.iter().enumerate() {
            println!(
                "mem[{}]: {} reads, {} writes",
                i,
                fmt_count(mc.reads),
                fmt_count(mc.writes)
            );
        }
    }
    let mut output = r.output.clone();
    let batches = parse_mutations(args)?;
    if !batches.is_empty() {
        output = replay_mutations(g, batches, spec, &cfg, args, output)?;
    }
    if let Some(path) = args.get("dump-output") {
        let path = PathBuf::from(path);
        dump_output(&path, &output)?;
        eprintln!("# wrote per-vertex output to {}", path.display());
    }
    Ok(())
}

/// Read and parse a `--mutations` file (empty when the flag is absent).
fn parse_mutations(args: &Args) -> Result<Vec<DeltaBatch>> {
    match args.get("mutations") {
        None => Ok(vec![]),
        Some(p) => {
            let text = std::fs::read_to_string(&p).with_context(|| format!("read {p}"))?;
            let batches = delta::parse_file(&text).map_err(|e| anyhow!("{p}: {e}"))?;
            eprintln!("# {} mutation batches from {p}", batches.len());
            Ok(batches)
        }
    }
}

/// Replay mutation batches against `g`, re-solving `spec` after each
/// commit — incrementally (warm-start for monotone programs, residual
/// push for PageRank, full-recompute fallback) or from scratch per
/// `--mutate-mode`. Returns the final per-vertex output, which
/// `--dump-output` then writes for exact diffing (the mutate-smoke CI job
/// diffs the two modes against each other).
fn replay_mutations(
    g: totem::graph::CsrGraph,
    batches: Vec<DeltaBatch>,
    spec: RunSpec,
    cfg: &EngineConfig,
    args: &Args,
    prior: StateArray,
) -> Result<StateArray> {
    let mode = args.str_or("mutate-mode", "incremental");
    if mode != "incremental" && mode != "full" {
        bail!("unknown --mutate-mode '{mode}' (incremental|full)");
    }
    // AUTO sources must be pinned against the pre-mutation graph: the
    // max-degree vertex can move when edges land, and the incremental and
    // full paths must answer the same question.
    let spec = spec.with_source(resolve_source(&g, &spec));
    let mut g_cur = g;
    let mut output = prior;
    for (bi, batch) in batches.into_iter().enumerate() {
        let t0 = std::time::Instant::now();
        let applied = delta::apply(&g_cur, &batch).map_err(|e| anyhow!("batch {bi}: {e}"))?;
        let (out, how) = if mode == "incremental" {
            let inc = incremental_rerun(&applied.graph, spec, cfg, &output, &applied)?;
            let how = match inc.recompute {
                Recompute::WarmStart => {
                    format!("warm-start ({} supersteps)", inc.supersteps)
                }
                Recompute::ResidualPush { sweeps } => {
                    format!("residual push ({sweeps} sweeps)")
                }
                Recompute::Full(FullReason::EffectiveDeletes) => {
                    format!("full recompute: deletes ({} supersteps)", inc.supersteps)
                }
                Recompute::Full(FullReason::Unsupported) => {
                    format!("full recompute: unsupported alg ({} supersteps)", inc.supersteps)
                }
            };
            (inc.output, how)
        } else {
            let (rr, _) = run_alg(&applied.graph, spec, cfg)?;
            (rr.output, format!("full recompute ({} supersteps)", rr.supersteps))
        };
        eprintln!(
            "[mutate] batch {bi}: +{} -{} edges ({} delete misses, {} new vertices), {} touched -> {how} in {}",
            applied.inserted,
            applied.deleted,
            applied.delete_misses,
            applied.new_vertices,
            applied.touched.len(),
            fmt_secs(t0.elapsed().as_secs_f64()),
        );
        output = out;
        g_cur = applied.graph;
    }
    Ok(output)
}

/// Write per-vertex results as `vertex value` lines. Floats are dumped as
/// bit patterns (`to_bits` hex) so two runs can be compared with a plain
/// `diff` — the ingest-smoke CI job diffs mmap-path vs in-memory-path runs.
fn dump_output(path: &Path, out: &StateArray) -> Result<()> {
    let f = File::create(path).with_context(|| format!("create {path:?}"))?;
    let mut w = BufWriter::new(f);
    match out {
        StateArray::I32(v) => {
            for (i, x) in v.iter().enumerate() {
                writeln!(w, "{i} {x}")?;
            }
        }
        StateArray::F32(v) => {
            for (i, x) in v.iter().enumerate() {
                writeln!(w, "{i} {:08x}", x.to_bits())?;
            }
        }
        StateArray::U64(v) => {
            for (i, x) in v.iter().enumerate() {
                writeln!(w, "{i} {x:016x}")?;
            }
        }
    }
    w.flush()?;
    Ok(())
}

/// Replay a query stream against the serving layer (DESIGN.md §13.5):
/// build + partition the graph once, submit queries at the configured
/// arrival rate, wait for every admitted ticket, then print the
/// server-level report (throughput, latency histogram, batching/cache
/// wins, typed rejections).
fn serve_cmd(args: &Args) -> Result<()> {
    use totem::serve::{
        arrival_delay_secs, parse_query_file, MutationPolicy, QueryKind, Server, ServerConfig,
    };

    // --weights attaches synthetic weights (required for sssp queries);
    // build_workload's Sssp arm is exactly that recipe.
    let weighted = args.has("weights");
    let g = parse_workload_or_file(args, weighted.then_some(AlgKind::Sssp))?;
    let engine = engine_config(args, AlgKind::Bfs)?;
    let queries: Vec<QueryKind> = match args.get("queries") {
        Some(p) => {
            let text = std::fs::read_to_string(&p).with_context(|| format!("read {p}"))?;
            parse_query_file(&text)?
        }
        None => {
            // Synthetic closed-loop load: a seeded bfs/reach/ppr mix
            // (sources repeat, exercising lane dedup and both caches).
            let n = args.usize_or("nqueries", 64).map_err(anyhow::Error::msg)?;
            let seed = args.u64_or("seed", 42).map_err(anyhow::Error::msg)?;
            totem::serve::synthetic_mix(n, seed, g.vertex_count as u32)
        }
    };
    let rate = args.f64_or("rate", 0.0).map_err(anyhow::Error::msg)?;
    let policy = match args.str_or("mutate-policy", "drain").as_str() {
        "drain" => MutationPolicy::Drain,
        "reject" => MutationPolicy::Reject,
        p => bail!("unknown --mutate-policy '{p}' (drain|reject)"),
    };
    let cfg = ServerConfig {
        workers: args.usize_or("serve-workers", 2).map_err(anyhow::Error::msg)?,
        max_in_flight: args.usize_or("max-inflight", 64).map_err(anyhow::Error::msg)?,
        max_batch: args.usize_or("max-batch", 64).map_err(anyhow::Error::msg)?,
        pagerank_rounds: args.usize_or("rounds", 5).map_err(anyhow::Error::msg)?,
        cache_capacity: args.usize_or("cache", 1024).map_err(anyhow::Error::msg)?,
        mutation_policy: policy,
        ..ServerConfig::new(engine)
    };
    let mutation_batches = parse_mutations(args)?;
    let dump_dir = args.get("dump-dir").map(PathBuf::from);
    if let Some(d) = &dump_dir {
        std::fs::create_dir_all(d).with_context(|| format!("create {d:?}"))?;
    }

    eprintln!(
        "# serving |V|={} |E|={} — {} workers, <= {} in flight, <= {} lanes/batch",
        fmt_count(g.vertex_count as u64),
        fmt_count(g.edge_count() as u64),
        cfg.workers,
        cfg.max_in_flight,
        cfg.max_batch,
    );
    let srv = Server::start(g, cfg)?;
    eprintln!("# graph fingerprint {:016x}", srv.fingerprint());

    let delay = arrival_delay_secs(rate);
    let t0 = std::time::Instant::now();
    // Interleave mutation batches evenly through the query stream: batch k
    // is enqueued after every `stride` queries, linearized in FIFO order
    // with the reads around it (DESIGN.md §14.3).
    let stride = if mutation_batches.is_empty() {
        usize::MAX
    } else {
        (queries.len() / (mutation_batches.len() + 1)).max(1)
    };
    let mut mutations = mutation_batches.into_iter();
    let mut mutation_tickets = Vec::new();
    let mut tickets = Vec::new();
    for (i, &q) in queries.iter().enumerate() {
        if i > 0 && i % stride == 0 {
            if let Some(b) = mutations.next() {
                mutation_tickets.push((i, srv.submit_mutation(b)));
            }
        }
        match srv.submit(q) {
            Ok(t) => tickets.push((i, t)),
            Err(e) => eprintln!("# query {i} rejected: {e}"),
        }
        if delay > 0.0 {
            std::thread::sleep(std::time::Duration::from_secs_f64(delay));
        }
    }
    // more batches than interleave slots: enqueue the rest at the tail
    for b in mutations {
        mutation_tickets.push((queries.len(), srv.submit_mutation(b)));
    }
    for (i, mt) in mutation_tickets {
        match mt.wait() {
            Ok(rep) => eprintln!(
                "# [mutate] at query {i}: epoch {} (+{} / -{} edges, {} new vertices{})",
                rep.epoch,
                rep.inserted,
                rep.deleted,
                rep.new_vertices,
                if rep.reassigned { ", reassigned" } else { "" },
            ),
            Err(e) => eprintln!("# [mutate] at query {i} failed: {e}"),
        }
    }
    let mut answered = 0usize;
    for (i, t) in tickets {
        match t.wait() {
            Ok(a) => {
                answered += 1;
                if let Some(d) = &dump_dir {
                    let path = d.join(format!("q{i:04}_{}.txt", queries[i].name()));
                    dump_response(&path, &a.response)?;
                }
            }
            Err(e) => eprintln!("# query {i} failed: {e}"),
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    let final_epoch = srv.epoch();
    let final_fingerprint = srv.fingerprint();
    let report = srv.shutdown();

    println!(
        "queries          : {} submitted, {answered} answered, {} rejected",
        queries.len(),
        report.rejected
    );
    if final_epoch > 0 {
        println!("graph epoch      : {final_epoch} (fingerprint {final_fingerprint:016x})");
    }
    println!(
        "throughput       : {:.1} queries/s over {}",
        answered as f64 / wall.max(1e-9),
        fmt_secs(wall)
    );
    print!("{report}");
    Ok(())
}

/// Write one query answer as `vertex value` lines — same diff-friendly
/// conventions as [`dump_output`] (floats as bit-pattern hex).
fn dump_response(path: &Path, resp: &totem::serve::QueryResponse) -> Result<()> {
    use totem::serve::QueryResponse as QR;
    let f = File::create(path).with_context(|| format!("create {path:?}"))?;
    let mut w = BufWriter::new(f);
    match resp {
        QR::Levels(v) => {
            for (i, x) in v.iter().enumerate() {
                writeln!(w, "{i} {x}")?;
            }
        }
        QR::Reachable(v) => {
            for (i, x) in v.iter().enumerate() {
                writeln!(w, "{i} {}", *x as u8)?;
            }
        }
        QR::Distances(v) => {
            for (i, x) in v.iter().enumerate() {
                writeln!(w, "{i} {:08x}", x.to_bits())?;
            }
        }
        QR::Ranks(v) => {
            for (i, x) in v.iter().enumerate() {
                writeln!(w, "{i} {:08x}", x.to_bits())?;
            }
        }
    }
    w.flush()?;
    Ok(())
}

fn model_cmd(args: &Args) -> Result<()> {
    let p = ModelParams {
        r_cpu: args.f64_or("rcpu", 1e9).map_err(anyhow::Error::msg)?,
        r_acc: args.f64_or("racc", 2e9).map_err(anyhow::Error::msg)?,
        c: model::comm_rate_for_message_bytes(
            args.f64_or("c", 3e9).map_err(anyhow::Error::msg)?,
            args.f64_or("msg-bytes", 4.0).map_err(anyhow::Error::msg)?,
        ),
    };
    let beta = args.f64_or("beta", 0.05).map_err(anyhow::Error::msg)?;
    let alphas = args
        .f64_list_or("alphas", &[0.4, 0.5, 0.6, 0.7, 0.8, 0.9, 1.0])
        .map_err(anyhow::Error::msg)?;
    let mut t = Table::new(
        &format!(
            "Predicted speedup (Eq. 4): r_cpu={:.2e} r_acc={:.2e} c={:.2e} beta={beta}",
            p.r_cpu, p.r_acc, p.c
        ),
        &["alpha", "speedup"],
    );
    for a in alphas {
        t.row(vec![format!("{a:.2}"), format!("{:.3}", model::speedup(a, beta, &p))]);
    }
    print!("{}", t.markdown());
    Ok(())
}

fn calibrate_cmd(args: &Args) -> Result<()> {
    let alg = AlgKind::parse(&args.str_or("alg", "bfs")).map_err(anyhow::Error::msg)?;
    let g = parse_workload_or_file(args, Some(alg))?;
    let artifacts = PathBuf::from(args.str_or("artifacts", "artifacts"));
    let alpha = args.f64_or("alpha", 0.6).map_err(anyhow::Error::msg)?;
    let src = totem::harness::resolve_source(&g, &RunSpec::new(alg));
    let cal = match alg {
        AlgKind::Bfs => calibrate::calibrate(
            &g,
            &mut totem::alg::bfs::Bfs::new(src),
            &mut totem::alg::bfs::Bfs::new(src),
            &artifacts,
            alpha,
        )?,
        AlgKind::Pagerank => calibrate::calibrate(
            &g,
            &mut totem::alg::pagerank::Pagerank::new(5),
            &mut totem::alg::pagerank::Pagerank::new(5),
            &artifacts,
            alpha,
        )?,
        AlgKind::Sssp => calibrate::calibrate(
            &g,
            &mut totem::alg::sssp::Sssp::new(src),
            &mut totem::alg::sssp::Sssp::new(src),
            &artifacts,
            alpha,
        )?,
        AlgKind::Bc => calibrate::calibrate(
            &g,
            &mut totem::alg::bc::Bc::new(src),
            &mut totem::alg::bc::Bc::new(src),
            &artifacts,
            alpha,
        )?,
        AlgKind::Cc => calibrate::calibrate(
            &g,
            &mut totem::alg::cc::Cc::new(),
            &mut totem::alg::cc::Cc::new(),
            &artifacts,
            alpha,
        )?,
        AlgKind::Widest => calibrate::calibrate(
            &g,
            &mut totem::alg::widest::Widest::new(src),
            &mut totem::alg::widest::Widest::new(src),
            &artifacts,
            alpha,
        )?,
        AlgKind::Triangles => calibrate::calibrate(
            &g,
            &mut totem::alg::triangles::Triangles::new(),
            &mut totem::alg::triangles::Triangles::new(),
            &artifacts,
            alpha,
        )?,
        AlgKind::Kcore => calibrate::calibrate(
            &g,
            &mut totem::alg::kcore::KCore::new(),
            &mut totem::alg::kcore::KCore::new(),
            &artifacts,
            alpha,
        )?,
        AlgKind::Labelprop => calibrate::calibrate(
            &g,
            &mut totem::alg::labelprop::LabelProp::new(5),
            &mut totem::alg::labelprop::LabelProp::new(5),
            &artifacts,
            alpha,
        )?,
        AlgKind::Ppr => calibrate::calibrate(
            &g,
            &mut totem::alg::ppr::Ppr::new(src, 5),
            &mut totem::alg::ppr::Ppr::new(src, 5),
            &artifacts,
            alpha,
        )?,
    };
    println!("r_cpu = {:.3e} edges/s", cal.params.r_cpu);
    println!("r_acc = {:.3e} edges/s", cal.params.r_acc);
    println!("c     = {:.3e} messages/s", cal.params.c);
    println!("host makespan = {}", fmt_secs(cal.host_secs));
    Ok(())
}

fn generate_cmd(args: &Args) -> Result<()> {
    let w = Workload::parse(&args.str_or("workload", "rmat14")).map_err(anyhow::Error::msg)?;
    let seed = args.u64_or("seed", 42).map_err(anyhow::Error::msg)?;
    let out = PathBuf::from(
        args.get("out")
            .ok_or_else(|| anyhow!("--out is required"))?,
    );
    let mut el = w.generate(seed);
    if args.has("weights") {
        use totem::graph::generator::{weight_seed, WEIGHT_MAX_DEFAULT};
        totem::graph::with_random_weights(&mut el, WEIGHT_MAX_DEFAULT, weight_seed(seed));
    }
    match args.str_or("format", "csr").as_str() {
        "el" => gio::write_edge_list(&el, &out)?,
        "csr" => gio::write_csr(&totem::graph::CsrGraph::from_edge_list(&el), &out)?,
        f => bail!("unknown format '{f}' (el|csr)"),
    }
    println!(
        "wrote {} (|V|={}, |E|={})",
        out.display(),
        fmt_count(el.vertex_count as u64),
        fmt_count(el.edge_count() as u64)
    );
    Ok(())
}

/// What `totem convert` reads from: a synthetic workload streamed on the
/// fly, a text edge list, or an existing binary container.
enum ConvertSrc {
    Workload(Workload),
    Text(PathBuf),
    Tcsr(PathBuf),
}

fn convert_cmd(args: &Args) -> Result<()> {
    const USAGE: &str = "usage: totem convert <workload|in.el|in.tcsr> <out.tcsr|out.el> \
                         [--weights] [--seed N] [--spill-edges N] [--store M] [--no-verify]";
    let input = args.positional.get(1).cloned().ok_or_else(|| anyhow!(USAGE))?;
    let output = args.positional.get(2).cloned().ok_or_else(|| anyhow!(USAGE))?;
    let out = PathBuf::from(&output);
    let seed = args.u64_or("seed", 42).map_err(anyhow::Error::msg)?;
    let weighted = args.has("weights");
    let spill = match args.usize_or("spill-edges", ingest::DEFAULT_SPILL_EDGES)
        .map_err(anyhow::Error::msg)?
    {
        0 => bail!("--spill-edges must be positive"),
        n => n,
    };
    let to_tcsr = out.extension().is_some_and(|e| e == "tcsr");
    // Spill runs land next to the output (same filesystem), falling back
    // to the system temp dir for bare filenames.
    let tmp_parent = match out.parent() {
        Some(p) if !p.as_os_str().is_empty() => p.to_path_buf(),
        _ => std::env::temp_dir(),
    };
    let src = if let Ok(w) = Workload::parse(&input) {
        ConvertSrc::Workload(w)
    } else {
        let p = PathBuf::from(&input);
        if !p.is_file() {
            bail!("input '{input}' is neither a workload name nor an existing file");
        }
        if store::is_tcsr(&p) {
            ConvertSrc::Tcsr(p)
        } else {
            ConvertSrc::Text(p)
        }
    };
    match (src, to_tcsr) {
        (ConvertSrc::Workload(w), true) => {
            let stats = ingest::convert_workload_to_tcsr(&w, seed, weighted, &out, spill, &tmp_parent)?;
            print_convert_stats(&out, &stats);
        }
        (ConvertSrc::Workload(w), false) => {
            let (v, e) = w.dimensions();
            let f = File::create(&out).with_context(|| format!("create {out:?}"))?;
            let mut wr = BufWriter::new(f);
            writeln!(wr, "# totem edge list")?;
            writeln!(wr, "p {v} {e}")?;
            w.stream(seed, weighted, &mut |s, d, wt| {
                match wt {
                    Some(x) => writeln!(wr, "{s} {d} {x}"),
                    None => writeln!(wr, "{s} {d}"),
                }
                .map_err(Into::into)
            })?;
            wr.flush()?;
            println!("wrote {} (|V|={}, |E|={})", out.display(), fmt_count(v as u64), fmt_count(e));
        }
        (ConvertSrc::Text(p), true) => {
            let stats = ingest::convert_edge_list_to_tcsr(&p, &out, spill, &tmp_parent)?;
            print_convert_stats(&out, &stats);
        }
        (ConvertSrc::Text(p), false) => {
            // Text → text normalizes (re-emits with a validated header).
            let summary = gio::scan_edge_list(&p)?;
            let f = File::create(&out).with_context(|| format!("create {out:?}"))?;
            let mut wr = BufWriter::new(f);
            writeln!(wr, "# totem edge list")?;
            writeln!(wr, "p {} {}", summary.vertex_count, summary.edge_count)?;
            gio::stream_edge_list(&p, &mut |s, d, wt| {
                match wt {
                    Some(x) => writeln!(wr, "{s} {d} {x}"),
                    None => writeln!(wr, "{s} {d}"),
                }
                .map_err(Into::into)
            })?;
            wr.flush()?;
            println!(
                "wrote {} (|V|={}, |E|={})",
                out.display(),
                fmt_count(summary.vertex_count as u64),
                fmt_count(summary.edge_count)
            );
        }
        (ConvertSrc::Tcsr(p), true) => {
            // Re-encode: buffered read (the source may be v1, which the
            // mmap path does not serve) → canonical v2 bytes. This is the
            // v1 → v2 migration path.
            let st = GraphStore::open_with(&p, LoadMode::Buffered, !args.has("no-verify"))?;
            let bytes = store::write_csr_v2(st.graph(), &out)?;
            println!(
                "wrote {} (|V|={}, |E|={}, {} on disk)",
                out.display(),
                fmt_count(st.graph().vertex_count as u64),
                fmt_count(st.graph().edge_count() as u64),
                fmt_bytes(bytes)
            );
        }
        (ConvertSrc::Tcsr(p), false) => {
            let st = GraphStore::open_with(&p, load_mode(args)?, !args.has("no-verify"))?;
            gio::write_edge_list_from_csr(st.graph(), &out)?;
            println!(
                "wrote {} (|V|={}, |E|={})",
                out.display(),
                fmt_count(st.graph().vertex_count as u64),
                fmt_count(st.graph().edge_count() as u64)
            );
        }
    }
    Ok(())
}

fn print_convert_stats(out: &Path, stats: &ingest::ConvertStats) {
    println!(
        "wrote {} (|V|={}, |E|={}, {}weighted, {} on disk)",
        out.display(),
        fmt_count(stats.vertices as u64),
        fmt_count(stats.edges),
        if stats.weighted { "" } else { "un" },
        fmt_bytes(stats.bytes_written)
    );
    println!(
        "spill: {} runs of <= {} edges, peak staging {}",
        stats.runs,
        fmt_count(stats.run_edges as u64),
        fmt_bytes(stats.peak_staging_bytes)
    );
}

fn info_cmd(args: &Args) -> Result<()> {
    let g = parse_workload_or_file(args, None)?;
    let s = properties::degree_stats(&g);
    println!("vertices        : {}", fmt_count(s.vertex_count as u64));
    println!("edges           : {}", fmt_count(s.edge_count as u64));
    println!("mean degree     : {:.2}", s.mean_degree);
    println!("max degree      : {}", fmt_count(s.max_degree));
    println!("top-1% edges    : {:.1}%", 100.0 * s.top1pct_edge_share);
    println!("degree Gini     : {:.3}", s.gini);
    println!("zero out-degree : {}", fmt_count(s.zero_degree as u64));
    println!(
        "50% edge cover  : {} vertices",
        fmt_count(properties::vertices_covering_edge_fraction(&g, 0.5) as u64)
    );
    let mut t = Table::new("log2 degree histogram", &["degree >=", "vertices"]);
    for (lb, c) in properties::degree_histogram_log2(&g) {
        t.row(vec![lb.to_string(), fmt_count(c as u64)]);
    }
    print!("{}", t.markdown());
    Ok(())
}

fn beta_cmd(args: &Args) -> Result<()> {
    let g = parse_workload_or_file(args, None)?;
    let parts = args.usize_or("parts", 2).map_err(anyhow::Error::msg)?;
    let strategy =
        Strategy::parse(&args.str_or("strategy", "rand")).map_err(anyhow::Error::msg)?;
    let seed = args.u64_or("seed", 42).map_err(anyhow::Error::msg)?;
    let shares = vec![1.0 / parts as f64; parts];
    let pg = PartitionedGraph::partition(&g, strategy, &shares, seed);
    let b = pg.beta_stats();
    println!(
        "{} {}-way: beta without reduction = {:.2}%, with reduction = {:.2}%  ({} boundary edges -> {} messages)",
        strategy.name(),
        parts,
        100.0 * b.beta_raw(),
        100.0 * b.beta_reduced(),
        fmt_count(b.boundary_edges),
        fmt_count(b.reduced_messages),
    );
    Ok(())
}
