//! `totem` — the hybrid graph-processing launcher.
//!
//! Subcommands:
//!   run        execute an algorithm on a workload under a hardware config
//!   model      evaluate the performance model (Eqs. 1–4)
//!   calibrate  measure r_cpu / r_acc / c on this testbed
//!   generate   write a workload to disk (edge list or binary CSR)
//!   info       degree-distribution statistics of a workload
//!   beta       boundary-edge statistics for a partitioning (Fig. 4)
//!
//! Examples:
//!   totem run --alg bfs --workload rmat14 --hw 2S1G --alpha 0.7 --strategy high
//!   totem run --alg pagerank --workload ukweb --hw 2S2G --alpha 0.6 --rounds 5
//!   totem model --beta 0.05 --rcpu 1e9 --c 3e9
//!   totem calibrate --alg bfs --workload rmat13
//!   totem beta --workload twitter --parts 2 --strategy rand

use anyhow::{anyhow, bail, Result};
use totem::engine::EngineConfig;
use totem::graph::{io as gio, properties, Workload};
use totem::harness::{build_workload, measure, AlgKind, RunSpec};
use totem::model::{self, calibrate, ModelParams};
use totem::partition::{PartitionedGraph, Strategy};
use totem::report::{fmt_secs, fmt_teps, Table};
use totem::util::args::Args;
use totem::util::{fmt_bytes, fmt_count};
use std::path::PathBuf;

fn main() {
    let args = match Args::from_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    };
    let cmd = args.positional.first().cloned().unwrap_or_else(|| "help".to_string());
    let code = match cmd.as_str() {
        "run" => run_cmd(&args),
        "model" => model_cmd(&args),
        "calibrate" => calibrate_cmd(&args),
        "generate" => generate_cmd(&args),
        "info" => info_cmd(&args),
        "beta" => beta_cmd(&args),
        "help" | "--help" | "-h" => {
            print!("{}", HELP);
            Ok(())
        }
        other => Err(anyhow!("unknown command '{other}' — try `totem help`")),
    }
    .map(|_| 0)
    .unwrap_or_else(|e| {
        eprintln!("error: {e:#}");
        1
    });
    std::process::exit(code);
}

const HELP: &str = "\
totem — hybrid (CPU + accelerator) graph processing engine

USAGE: totem <command> [--flags]

COMMANDS:
  run        --alg bfs|pagerank|sssp|bc|cc|widest --workload rmatN|uniformN|twitter|ukweb|csr:PATH
             --hw xS[yG] --alpha F --strategy rand|high|low [--source N]
             [--placement assign|degree-desc|degree-asc|bfs]
             [--rounds N] [--reps N] [--seed N] [--instrument]
             [--artifacts DIR] [--threads N] [--budget-mb N]
             [--balance vertex|edge|hub-split]
             [--direction] [--dir-alpha F] [--dir-beta F]
             (--threads 0 or omitted = one worker per available core;
              --balance picks how CPU kernels cut chunks, DESIGN.md §11)
  model      [--alphas a,b,c] [--beta F] [--rcpu F] [--racc F] [--c F] [--msg-bytes F]
  calibrate  --alg A --workload W [--alpha F] [--artifacts DIR]
  generate   --workload W --out PATH [--format el|csr] [--seed N] [--weights]
  info       --workload W [--seed N]
  beta       --workload W --parts N [--strategy S] [--seed N]
";

fn parse_workload_or_file(args: &Args, alg: Option<AlgKind>) -> Result<totem::graph::CsrGraph> {
    let w = args.str_or("workload", "rmat14");
    let seed = args.u64_or("seed", 42).map_err(anyhow::Error::msg)?;
    if let Some(path) = w.strip_prefix("csr:") {
        return gio::read_csr(&PathBuf::from(path));
    }
    if let Some(path) = w.strip_prefix("el:") {
        let el = gio::read_edge_list(&PathBuf::from(path))?;
        return Ok(totem::graph::CsrGraph::from_edge_list(&el));
    }
    let wl = Workload::parse(&w).map_err(anyhow::Error::msg)?;
    Ok(match alg {
        Some(a) => build_workload(wl, seed, a),
        None => wl.build(seed),
    })
}

fn engine_config(args: &Args, alg: AlgKind) -> Result<EngineConfig> {
    let hw = args.str_or("hw", "1S");
    let alpha = args.f64_or("alpha", 0.7).map_err(anyhow::Error::msg)?;
    let strategy =
        Strategy::parse(&args.str_or("strategy", "high")).map_err(anyhow::Error::msg)?;
    // --threads 0 (the default) = auto: one worker per available core.
    let threads = match args.usize_or("threads", 0).map_err(anyhow::Error::msg)? {
        0 => totem::engine::default_threads(),
        n => n,
    };
    let mut cfg = EngineConfig::from_notation(&hw, alpha, strategy, threads)
        .map_err(anyhow::Error::msg)?;
    // Intra-partition balance mode (DESIGN.md §11): how CPU kernels cut
    // their per-superstep chunks — by vertex count, by edge mass, or edge
    // mass with the dominant hub's adjacency sharded across workers.
    let bal_str = args.str_or("balance", "vertex");
    let balance = totem::engine::Balance::parse(&bal_str)
        .ok_or_else(|| anyhow!("unknown --balance '{bal_str}' (vertex|edge|hub-split)"))?;
    cfg = cfg.with_balance(balance);
    // Intra-partition vertex placement (DESIGN.md §9): a pure layout
    // knob — outputs are bit-identical across placements.
    let placement = totem::partition::Placement::parse(&args.str_or("placement", "degree-desc"))
        .map_err(anyhow::Error::msg)?;
    cfg = cfg
        .with_seed(args.u64_or("seed", 42).map_err(anyhow::Error::msg)?)
        .with_instrument(args.has("instrument"))
        .with_artifacts(args.str_or("artifacts", "artifacts"))
        .with_placement(placement);
    let mb = args.usize_or("budget-mb", 0).map_err(anyhow::Error::msg)?;
    if mb > 0 {
        cfg.accel_memory_budget = (mb as u64) << 20;
    }
    if alg == AlgKind::Pagerank {
        cfg.rounds = Some(args.usize_or("rounds", 5).map_err(anyhow::Error::msg)?);
    }
    // Direction-optimized traversal (DESIGN.md §8): Beamer α/β heuristic
    // per CPU element; accelerator partitions always stay top-down.
    if args.has("direction") {
        cfg = cfg.with_direction(totem::engine::DirectionConfig {
            alpha: args.f64_or("dir-alpha", 15.0).map_err(anyhow::Error::msg)?,
            beta: args.f64_or("dir-beta", 18.0).map_err(anyhow::Error::msg)?,
        });
    }
    Ok(cfg)
}

fn run_cmd(args: &Args) -> Result<()> {
    let alg = AlgKind::parse(&args.str_or("alg", "bfs")).map_err(anyhow::Error::msg)?;
    let g = parse_workload_or_file(args, Some(alg))?;
    let cfg = engine_config(args, alg)?;
    let spec = RunSpec::new(alg)
        .with_source(args.u64_or("source", u32::MAX as u64).map_err(anyhow::Error::msg)? as u32)
        .with_rounds(args.usize_or("rounds", 5).map_err(anyhow::Error::msg)?);
    let reps = args.usize_or("reps", 3).map_err(anyhow::Error::msg)?;

    eprintln!(
        "# {} on |V|={} |E|={} — {} partitions",
        alg.name(),
        fmt_count(g.vertex_count as u64),
        fmt_count(g.edge_count() as u64),
        cfg.num_partitions()
    );
    let m = measure(&g, spec, &cfg, reps)?;
    let r = &m.last;

    println!("algorithm        : {}", alg.name());
    println!("supersteps       : {}", r.supersteps);
    println!(
        "makespan         : {} ± {} (95% CI, {} reps)",
        fmt_secs(m.makespan_secs),
        fmt_secs(m.makespan_ci95),
        reps
    );
    println!("traversal rate   : {}", fmt_teps(m.teps));
    if cfg.direction.is_some() {
        println!(
            "direction        : {} of {} supersteps ran bottom-up",
            m.pull_steps, r.supersteps
        );
    } else {
        println!("direction        : push-only");
    }
    println!("placement        : {}", m.placement.name());
    println!("parallelism      : {} threads, {} balance", m.threads, cfg.balance.name());
    println!("bottleneck comp. : {}", fmt_secs(m.bottleneck_secs));
    println!("communication    : {}", fmt_secs(m.comm_secs));
    println!(
        "comm volume      : {} in {} messages",
        fmt_bytes(r.metrics.total_bytes()),
        fmt_count(r.metrics.total_messages())
    );
    println!(
        "beta             : raw {:.2}% -> reduced {:.2}%",
        100.0 * r.beta.beta_raw(),
        100.0 * r.beta.beta_reduced()
    );
    let mut t = Table::new(
        "Partitions",
        &["part", "element", "vertices", "edges", "share", "compute", "footprint"],
    );
    for (i, fp) in r.footprints.iter().enumerate() {
        t.row(vec![
            i.to_string(),
            format!("{:?}", cfg.elements[i]),
            fmt_count(fp.vertices as u64),
            fmt_count(fp.edges as u64),
            format!("{:.1}%", 100.0 * r.shares[i]),
            fmt_secs(r.metrics.partition_compute_secs(i)),
            fmt_bytes(fp.total()),
        ]);
    }
    print!("{}", t.markdown());
    if args.has("instrument") {
        for (i, mc) in r.metrics.mem.iter().enumerate() {
            println!(
                "mem[{}]: {} reads, {} writes",
                i,
                fmt_count(mc.reads),
                fmt_count(mc.writes)
            );
        }
    }
    Ok(())
}

fn model_cmd(args: &Args) -> Result<()> {
    let p = ModelParams {
        r_cpu: args.f64_or("rcpu", 1e9).map_err(anyhow::Error::msg)?,
        r_acc: args.f64_or("racc", 2e9).map_err(anyhow::Error::msg)?,
        c: model::comm_rate_for_message_bytes(
            args.f64_or("c", 3e9).map_err(anyhow::Error::msg)?,
            args.f64_or("msg-bytes", 4.0).map_err(anyhow::Error::msg)?,
        ),
    };
    let beta = args.f64_or("beta", 0.05).map_err(anyhow::Error::msg)?;
    let alphas = args
        .f64_list_or("alphas", &[0.4, 0.5, 0.6, 0.7, 0.8, 0.9, 1.0])
        .map_err(anyhow::Error::msg)?;
    let mut t = Table::new(
        &format!(
            "Predicted speedup (Eq. 4): r_cpu={:.2e} r_acc={:.2e} c={:.2e} beta={beta}",
            p.r_cpu, p.r_acc, p.c
        ),
        &["alpha", "speedup"],
    );
    for a in alphas {
        t.row(vec![format!("{a:.2}"), format!("{:.3}", model::speedup(a, beta, &p))]);
    }
    print!("{}", t.markdown());
    Ok(())
}

fn calibrate_cmd(args: &Args) -> Result<()> {
    let alg = AlgKind::parse(&args.str_or("alg", "bfs")).map_err(anyhow::Error::msg)?;
    let g = parse_workload_or_file(args, Some(alg))?;
    let artifacts = PathBuf::from(args.str_or("artifacts", "artifacts"));
    let alpha = args.f64_or("alpha", 0.6).map_err(anyhow::Error::msg)?;
    let src = totem::harness::resolve_source(&g, &RunSpec::new(alg));
    let cal = match alg {
        AlgKind::Bfs => calibrate::calibrate(
            &g,
            &mut totem::alg::bfs::Bfs::new(src),
            &mut totem::alg::bfs::Bfs::new(src),
            &artifacts,
            alpha,
        )?,
        AlgKind::Pagerank => calibrate::calibrate(
            &g,
            &mut totem::alg::pagerank::Pagerank::new(5),
            &mut totem::alg::pagerank::Pagerank::new(5),
            &artifacts,
            alpha,
        )?,
        AlgKind::Sssp => calibrate::calibrate(
            &g,
            &mut totem::alg::sssp::Sssp::new(src),
            &mut totem::alg::sssp::Sssp::new(src),
            &artifacts,
            alpha,
        )?,
        AlgKind::Bc => calibrate::calibrate(
            &g,
            &mut totem::alg::bc::Bc::new(src),
            &mut totem::alg::bc::Bc::new(src),
            &artifacts,
            alpha,
        )?,
        AlgKind::Cc => calibrate::calibrate(
            &g,
            &mut totem::alg::cc::Cc::new(),
            &mut totem::alg::cc::Cc::new(),
            &artifacts,
            alpha,
        )?,
        AlgKind::Widest => calibrate::calibrate(
            &g,
            &mut totem::alg::widest::Widest::new(src),
            &mut totem::alg::widest::Widest::new(src),
            &artifacts,
            alpha,
        )?,
    };
    println!("r_cpu = {:.3e} edges/s", cal.params.r_cpu);
    println!("r_acc = {:.3e} edges/s", cal.params.r_acc);
    println!("c     = {:.3e} messages/s", cal.params.c);
    println!("host makespan = {}", fmt_secs(cal.host_secs));
    Ok(())
}

fn generate_cmd(args: &Args) -> Result<()> {
    let w = Workload::parse(&args.str_or("workload", "rmat14")).map_err(anyhow::Error::msg)?;
    let seed = args.u64_or("seed", 42).map_err(anyhow::Error::msg)?;
    let out = PathBuf::from(
        args.get("out")
            .ok_or_else(|| anyhow!("--out is required"))?,
    );
    let mut el = w.generate(seed);
    if args.has("weights") {
        totem::graph::with_random_weights(&mut el, 64, seed ^ 0x5eed);
    }
    match args.str_or("format", "csr").as_str() {
        "el" => gio::write_edge_list(&el, &out)?,
        "csr" => gio::write_csr(&totem::graph::CsrGraph::from_edge_list(&el), &out)?,
        f => bail!("unknown format '{f}' (el|csr)"),
    }
    println!(
        "wrote {} (|V|={}, |E|={})",
        out.display(),
        fmt_count(el.vertex_count as u64),
        fmt_count(el.edge_count() as u64)
    );
    Ok(())
}

fn info_cmd(args: &Args) -> Result<()> {
    let g = parse_workload_or_file(args, None)?;
    let s = properties::degree_stats(&g);
    println!("vertices        : {}", fmt_count(s.vertex_count as u64));
    println!("edges           : {}", fmt_count(s.edge_count as u64));
    println!("mean degree     : {:.2}", s.mean_degree);
    println!("max degree      : {}", fmt_count(s.max_degree));
    println!("top-1% edges    : {:.1}%", 100.0 * s.top1pct_edge_share);
    println!("degree Gini     : {:.3}", s.gini);
    println!("zero out-degree : {}", fmt_count(s.zero_degree as u64));
    println!(
        "50% edge cover  : {} vertices",
        fmt_count(properties::vertices_covering_edge_fraction(&g, 0.5) as u64)
    );
    let mut t = Table::new("log2 degree histogram", &["degree >=", "vertices"]);
    for (lb, c) in properties::degree_histogram_log2(&g) {
        t.row(vec![lb.to_string(), fmt_count(c as u64)]);
    }
    print!("{}", t.markdown());
    Ok(())
}

fn beta_cmd(args: &Args) -> Result<()> {
    let g = parse_workload_or_file(args, None)?;
    let parts = args.usize_or("parts", 2).map_err(anyhow::Error::msg)?;
    let strategy =
        Strategy::parse(&args.str_or("strategy", "rand")).map_err(anyhow::Error::msg)?;
    let seed = args.u64_or("seed", 42).map_err(anyhow::Error::msg)?;
    let shares = vec![1.0 / parts as f64; parts];
    let pg = PartitionedGraph::partition(&g, strategy, &shares, seed);
    let b = pg.beta_stats();
    println!(
        "{} {}-way: beta without reduction = {:.2}%, with reduction = {:.2}%  ({} boundary edges -> {} messages)",
        strategy.name(),
        parts,
        100.0 * b.beta_raw(),
        100.0 * b.beta_reduced(),
        fmt_count(b.boundary_edges),
        fmt_count(b.reduced_messages),
    );
    Ok(())
}
