//! Minimal JSON support (no serde in the offline dependency set).
//!
//! Two halves:
//! - [`JsonValue`] + a recursive-descent parser, used to read the AOT
//!   `artifacts/manifest.json` written by `python/compile/aot.py`;
//! - a tiny writer ([`JsonValue::render`]) used by the
//!   bench harness to dump machine-readable results next to the markdown
//!   tables.
//!
//! Supports the JSON we actually produce: objects, arrays, strings with
//! standard escapes, f64 numbers, booleans, null. Not a general-purpose
//! validator (e.g. it accepts trailing garbage after the top value only via
//! [`parse_str`] returning the remainder implicitly consumed check).

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Clone, Debug, PartialEq)]
pub enum JsonValue {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<JsonValue>),
    Obj(BTreeMap<String, JsonValue>),
}

impl JsonValue {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            JsonValue::Num(x) => Some(*x),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            JsonValue::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[JsonValue]> {
        match self {
            JsonValue::Arr(v) => Some(v),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, JsonValue>> {
        match self {
            JsonValue::Obj(m) => Some(m),
            _ => None,
        }
    }
    pub fn get(&self, key: &str) -> Option<&JsonValue> {
        self.as_obj().and_then(|m| m.get(key))
    }

    /// Serialize compactly.
    pub fn render(&self) -> String {
        let mut out = String::new();
        self.render_into(&mut out);
        out
    }

    fn render_into(&self, out: &mut String) {
        match self {
            JsonValue::Null => out.push_str("null"),
            JsonValue::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            JsonValue::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            JsonValue::Str(s) => escape_into(s, out),
            JsonValue::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.render_into(out);
                }
                out.push(']');
            }
            JsonValue::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    escape_into(k, out);
                    out.push(':');
                    v.render_into(out);
                }
                out.push('}');
            }
        }
    }
}

fn escape_into(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse a JSON document. Errors carry a byte offset for debugging.
pub fn parse_str(input: &str) -> Result<JsonValue, String> {
    let bytes = input.as_bytes();
    let mut pos = 0usize;
    let v = parse_value(bytes, &mut pos)?;
    skip_ws(bytes, &mut pos);
    if pos != bytes.len() {
        return Err(format!("trailing data at byte {pos}"));
    }
    Ok(v)
}

fn skip_ws(b: &[u8], pos: &mut usize) {
    while *pos < b.len() && matches!(b[*pos], b' ' | b'\t' | b'\n' | b'\r') {
        *pos += 1;
    }
}

fn parse_value(b: &[u8], pos: &mut usize) -> Result<JsonValue, String> {
    skip_ws(b, pos);
    if *pos >= b.len() {
        return Err("unexpected end of input".into());
    }
    match b[*pos] {
        b'{' => parse_obj(b, pos),
        b'[' => parse_arr(b, pos),
        b'"' => Ok(JsonValue::Str(parse_string(b, pos)?)),
        b't' => parse_lit(b, pos, "true", JsonValue::Bool(true)),
        b'f' => parse_lit(b, pos, "false", JsonValue::Bool(false)),
        b'n' => parse_lit(b, pos, "null", JsonValue::Null),
        _ => parse_num(b, pos),
    }
}

fn parse_lit(b: &[u8], pos: &mut usize, lit: &str, v: JsonValue) -> Result<JsonValue, String> {
    if b[*pos..].starts_with(lit.as_bytes()) {
        *pos += lit.len();
        Ok(v)
    } else {
        Err(format!("invalid literal at byte {pos}", pos = *pos))
    }
}

fn parse_num(b: &[u8], pos: &mut usize) -> Result<JsonValue, String> {
    let start = *pos;
    while *pos < b.len()
        && matches!(b[*pos], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
    {
        *pos += 1;
    }
    let s = std::str::from_utf8(&b[start..*pos]).map_err(|e| e.to_string())?;
    s.parse::<f64>()
        .map(JsonValue::Num)
        .map_err(|e| format!("bad number '{s}' at byte {start}: {e}"))
}

fn parse_string(b: &[u8], pos: &mut usize) -> Result<String, String> {
    debug_assert_eq!(b[*pos], b'"');
    *pos += 1;
    let mut out = String::new();
    while *pos < b.len() {
        match b[*pos] {
            b'"' => {
                *pos += 1;
                return Ok(out);
            }
            b'\\' => {
                *pos += 1;
                if *pos >= b.len() {
                    return Err("dangling escape".into());
                }
                match b[*pos] {
                    b'"' => out.push('"'),
                    b'\\' => out.push('\\'),
                    b'/' => out.push('/'),
                    b'n' => out.push('\n'),
                    b't' => out.push('\t'),
                    b'r' => out.push('\r'),
                    b'b' => out.push('\u{8}'),
                    b'f' => out.push('\u{c}'),
                    b'u' => {
                        if *pos + 4 >= b.len() {
                            return Err("truncated \\u escape".into());
                        }
                        let hex = std::str::from_utf8(&b[*pos + 1..*pos + 5])
                            .map_err(|e| e.to_string())?;
                        let code =
                            u32::from_str_radix(hex, 16).map_err(|e| e.to_string())?;
                        out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        *pos += 4;
                    }
                    c => return Err(format!("bad escape \\{}", c as char)),
                }
                *pos += 1;
            }
            _ => {
                // copy a UTF-8 run
                let start = *pos;
                while *pos < b.len() && b[*pos] != b'"' && b[*pos] != b'\\' {
                    *pos += 1;
                }
                out.push_str(
                    std::str::from_utf8(&b[start..*pos]).map_err(|e| e.to_string())?,
                );
            }
        }
    }
    Err("unterminated string".into())
}

fn parse_arr(b: &[u8], pos: &mut usize) -> Result<JsonValue, String> {
    *pos += 1; // '['
    let mut v = Vec::new();
    skip_ws(b, pos);
    if *pos < b.len() && b[*pos] == b']' {
        *pos += 1;
        return Ok(JsonValue::Arr(v));
    }
    loop {
        v.push(parse_value(b, pos)?);
        skip_ws(b, pos);
        match b.get(*pos) {
            Some(b',') => *pos += 1,
            Some(b']') => {
                *pos += 1;
                return Ok(JsonValue::Arr(v));
            }
            _ => return Err(format!("expected ',' or ']' at byte {pos}", pos = *pos)),
        }
    }
}

fn parse_obj(b: &[u8], pos: &mut usize) -> Result<JsonValue, String> {
    *pos += 1; // '{'
    let mut m = BTreeMap::new();
    skip_ws(b, pos);
    if *pos < b.len() && b[*pos] == b'}' {
        *pos += 1;
        return Ok(JsonValue::Obj(m));
    }
    loop {
        skip_ws(b, pos);
        if *pos >= b.len() || b[*pos] != b'"' {
            return Err(format!("expected key at byte {pos}", pos = *pos));
        }
        let key = parse_string(b, pos)?;
        skip_ws(b, pos);
        if b.get(*pos) != Some(&b':') {
            return Err(format!("expected ':' at byte {pos}", pos = *pos));
        }
        *pos += 1;
        m.insert(key, parse_value(b, pos)?);
        skip_ws(b, pos);
        match b.get(*pos) {
            Some(b',') => *pos += 1,
            Some(b'}') => {
                *pos += 1;
                return Ok(JsonValue::Obj(m));
            }
            _ => return Err(format!("expected ',' or '}}' at byte {pos}", pos = *pos)),
        }
    }
}

/// Convenience builder for objects.
pub fn obj(pairs: Vec<(&str, JsonValue)>) -> JsonValue {
    JsonValue::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn num(x: f64) -> JsonValue {
    JsonValue::Num(x)
}

pub fn s(x: &str) -> JsonValue {
    JsonValue::Str(x.to_string())
}

pub fn arr(v: Vec<JsonValue>) -> JsonValue {
    JsonValue::Arr(v)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let v = obj(vec![
            ("name", s("bfs")),
            ("n", num(4096.0)),
            ("ok", JsonValue::Bool(true)),
            ("xs", arr(vec![num(1.0), num(2.5)])),
            ("nested", obj(vec![("k", JsonValue::Null)])),
        ]);
        let text = v.render();
        let back = parse_str(&text).unwrap();
        assert_eq!(v, back);
    }

    #[test]
    fn parse_whitespace_and_escapes() {
        let text = "  { \"a\\n\" : [ 1 , -2.5e3 , \"x\\\"y\" ] } ";
        let v = parse_str(text).unwrap();
        let a = v.get("a\n").unwrap().as_arr().unwrap();
        assert_eq!(a[0].as_f64(), Some(1.0));
        assert_eq!(a[1].as_f64(), Some(-2500.0));
        assert_eq!(a[2].as_str(), Some("x\"y"));
    }

    #[test]
    fn parse_unicode_escape() {
        let v = parse_str("\"\\u0041\\u00e9\"").unwrap();
        assert_eq!(v.as_str(), Some("Aé"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse_str("{").is_err());
        assert!(parse_str("[1,]").is_err());
        assert!(parse_str("{\"a\":1} x").is_err());
        assert!(parse_str("tru").is_err());
    }

    #[test]
    fn integers_render_without_fraction() {
        assert_eq!(num(5.0).render(), "5");
        assert_eq!(num(5.25).render(), "5.25");
    }

    #[test]
    fn empty_containers() {
        assert_eq!(parse_str("[]").unwrap(), JsonValue::Arr(vec![]));
        assert_eq!(parse_str("{}").unwrap(), JsonValue::Obj(BTreeMap::new()));
    }
}
