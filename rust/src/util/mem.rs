//! Process memory accounting (DESIGN.md §12.6).
//!
//! Linux exposes resident-set figures in `/proc/self/status`; `VmHWM` is
//! the peak RSS since process start (or the last reset), `VmRSS` the
//! current value. Both are reported in kB. On non-Linux targets there is
//! no portable equivalent without new dependencies, so the probes return
//! `None` and callers print `n/a` — accounting is advisory, never
//! load-bearing for correctness.
//!
//! **Per-measurement peaks.** Raw `VmHWM` is a process-*lifetime* high
//! water mark: in a process that measures many configurations (the
//! harness, `totem serve`, benches), every report after the biggest run
//! would repeat that run's peak. [`PeakRssProbe`] scopes the watermark to
//! one measured region by resetting it through `/proc/self/clear_refs`
//! (writing `"5"`, Linux ≥ 4.0) at region start; where the reset is
//! unavailable (non-Linux, hardened /proc) it degrades to a documented
//! baseline+delta estimate.

/// Peak resident set size of this process in bytes (`VmHWM`), if the
/// platform exposes it. Process-lifetime unless reset — use
/// [`PeakRssProbe`] for per-region accounting.
pub fn peak_rss_bytes() -> Option<u64> {
    proc_status_kb("VmHWM:").map(|kb| kb * 1024)
}

/// Reset the kernel's peak-RSS watermark (`VmHWM`) to the current RSS by
/// writing `"5"` to `/proc/self/clear_refs`. Returns whether the reset
/// took effect; `false` on non-Linux targets or when /proc is hardened.
pub fn reset_peak_rss() -> bool {
    #[cfg(target_os = "linux")]
    {
        std::fs::write("/proc/self/clear_refs", "5").is_ok()
    }
    #[cfg(not(target_os = "linux"))]
    {
        false
    }
}

/// Peak-RSS accounting scoped to one measured region.
///
/// [`PeakRssProbe::start`] resets the kernel watermark when it can;
/// [`PeakRssProbe::peak`] then reads a true per-region peak. When the
/// reset is unavailable the probe falls back to baseline+delta: if the
/// region pushed a **new** lifetime high water, that absolute peak is the
/// region's peak too; otherwise the region's real peak is unobservable
/// and the probe reports `max(baseline RSS, final RSS)` — a lower bound.
/// Residual caveat: the fallback can under-report transient spikes that
/// stayed below an *earlier* region's high water.
pub struct PeakRssProbe {
    reset_ok: bool,
    baseline_peak: Option<u64>,
    baseline_current: Option<u64>,
}

impl PeakRssProbe {
    /// Open a measured region (resets `VmHWM` when the platform allows).
    pub fn start() -> PeakRssProbe {
        let reset_ok = reset_peak_rss();
        PeakRssProbe {
            reset_ok,
            baseline_peak: peak_rss_bytes(),
            baseline_current: current_rss_bytes(),
        }
    }

    /// Did the watermark reset take effect (i.e. is [`Self::peak`] a true
    /// per-region peak rather than the fallback estimate)?
    pub fn is_exact(&self) -> bool {
        self.reset_ok
    }

    /// Peak RSS attributable to the region since [`Self::start`].
    pub fn peak(&self) -> Option<u64> {
        let peak_now = peak_rss_bytes()?;
        if self.reset_ok {
            return Some(peak_now);
        }
        let bp = self.baseline_peak?;
        if peak_now > bp {
            // the region set a new lifetime high water — that IS its peak
            return Some(peak_now);
        }
        // unobservable under an older high water: lower-bound estimate
        match (self.baseline_current, current_rss_bytes()) {
            (Some(bc), Some(cur)) => Some(bc.max(cur)),
            (Some(bc), None) => Some(bc),
            (None, cur) => cur,
        }
    }
}

/// Current resident set size of this process in bytes (`VmRSS`), if the
/// platform exposes it.
pub fn current_rss_bytes() -> Option<u64> {
    proc_status_kb("VmRSS:").map(|kb| kb * 1024)
}

#[cfg(target_os = "linux")]
fn proc_status_kb(key: &str) -> Option<u64> {
    let status = std::fs::read_to_string("/proc/self/status").ok()?;
    for line in status.lines() {
        if let Some(rest) = line.strip_prefix(key) {
            // format: "VmHWM:      12345 kB"
            let num = rest.trim().split_whitespace().next()?;
            return num.parse::<u64>().ok();
        }
    }
    None
}

#[cfg(not(target_os = "linux"))]
fn proc_status_kb(_key: &str) -> Option<u64> {
    None
}

#[cfg(all(test, target_os = "linux"))]
mod tests {
    use super::*;

    #[test]
    fn rss_probes_report_plausible_values() {
        let peak = peak_rss_bytes().expect("linux exposes VmHWM");
        let cur = current_rss_bytes().expect("linux exposes VmRSS");
        // Any live process has at least a page resident, and the peak can
        // never be below the current value.
        assert!(cur >= 4096);
        assert!(peak >= cur);
    }

    #[test]
    fn peak_tracks_large_allocations() {
        let before = peak_rss_bytes().unwrap();
        let buf = vec![1u8; 64 << 20]; // 64 MiB, touched so it's resident
        let sum: u64 = buf.iter().map(|&b| b as u64).sum();
        assert_eq!(sum, 64 << 20);
        let after = peak_rss_bytes().unwrap();
        assert!(after >= before, "peak RSS is monotone");
    }

    /// The repeated-measurement regression (ISSUE 8): without the reset,
    /// a small region measured after a large one inherits the large
    /// region's lifetime watermark. With [`PeakRssProbe`] the second,
    /// much smaller region must report a strictly smaller peak.
    #[test]
    fn probe_scopes_peak_to_the_measured_region() {
        fn touch(mb: usize) -> u64 {
            let buf = vec![1u8; mb << 20];
            buf.iter().map(|&b| b as u64).sum()
        }
        let p1 = PeakRssProbe::start();
        assert_eq!(touch(64), 64 << 20);
        let peak1 = p1.peak().unwrap();
        // 64 MiB was freed (> MMAP_THRESHOLD, so munmapped) before the
        // second region opens
        let p2 = PeakRssProbe::start();
        assert_eq!(touch(8), 8 << 20);
        let peak2 = p2.peak().unwrap();
        if p1.is_exact() && p2.is_exact() {
            assert!(
                peak2 < peak1,
                "per-region peaks must not inherit earlier watermarks \
                 (region1 {peak1} B, region2 {peak2} B)"
            );
        } else {
            // hardened /proc: the fallback still reports something sane
            assert!(peak2 > 0 && peak1 > 0);
        }
    }
}
