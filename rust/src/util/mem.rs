//! Process memory accounting (DESIGN.md §12.6).
//!
//! Linux exposes resident-set figures in `/proc/self/status`; `VmHWM` is
//! the peak RSS since process start (or the last reset), `VmRSS` the
//! current value. Both are reported in kB. On non-Linux targets there is
//! no portable equivalent without new dependencies, so the probes return
//! `None` and callers print `n/a` — accounting is advisory, never
//! load-bearing for correctness.

/// Peak resident set size of this process in bytes (`VmHWM`), if the
/// platform exposes it.
pub fn peak_rss_bytes() -> Option<u64> {
    proc_status_kb("VmHWM:").map(|kb| kb * 1024)
}

/// Current resident set size of this process in bytes (`VmRSS`), if the
/// platform exposes it.
pub fn current_rss_bytes() -> Option<u64> {
    proc_status_kb("VmRSS:").map(|kb| kb * 1024)
}

#[cfg(target_os = "linux")]
fn proc_status_kb(key: &str) -> Option<u64> {
    let status = std::fs::read_to_string("/proc/self/status").ok()?;
    for line in status.lines() {
        if let Some(rest) = line.strip_prefix(key) {
            // format: "VmHWM:      12345 kB"
            let num = rest.trim().split_whitespace().next()?;
            return num.parse::<u64>().ok();
        }
    }
    None
}

#[cfg(not(target_os = "linux"))]
fn proc_status_kb(_key: &str) -> Option<u64> {
    None
}

#[cfg(all(test, target_os = "linux"))]
mod tests {
    use super::*;

    #[test]
    fn rss_probes_report_plausible_values() {
        let peak = peak_rss_bytes().expect("linux exposes VmHWM");
        let cur = current_rss_bytes().expect("linux exposes VmRSS");
        // Any live process has at least a page resident, and the peak can
        // never be below the current value.
        assert!(cur >= 4096);
        assert!(peak >= cur);
    }

    #[test]
    fn peak_tracks_large_allocations() {
        let before = peak_rss_bytes().unwrap();
        let buf = vec![1u8; 64 << 20]; // 64 MiB, touched so it's resident
        let sum: u64 = buf.iter().map(|&b| b as u64).sum();
        assert_eq!(sum, 64 << 20);
        let after = peak_rss_bytes().unwrap();
        assert!(after >= before, "peak RSS is monotone");
    }
}
