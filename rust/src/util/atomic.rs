//! Atomic min / add helpers for the CPU compute kernels.
//!
//! The paper's CPU kernels use `atomicSet` / `atomicMin` / `atomicAdd`
//! (Figures 11, 18, 20). Rust's standard atomics cover integer min
//! (`fetch_min`) but not floating point, so f32 min/add are implemented as
//! compare-exchange loops over the bit pattern — the standard lock-free
//! recipe. All operations use `Relaxed` ordering: the BSP model inserts a
//! full barrier between the compute and communication phases, so only
//! atomicity (not ordering) is required within a phase.

use std::sync::atomic::{AtomicI32, AtomicU32, AtomicU64, Ordering};

/// Atomically `*a = min(*a, v)`; returns the previous value.
#[inline]
pub fn atomic_min_i32(a: &AtomicI32, v: i32) -> i32 {
    a.fetch_min(v, Ordering::Relaxed)
}

/// Atomically `*a = min(*a, v)` for u32; returns the previous value.
#[inline]
pub fn atomic_min_u32(a: &AtomicU32, v: u32) -> u32 {
    a.fetch_min(v, Ordering::Relaxed)
}

/// Atomically `*a = min(*a, v)` for f32 stored as bits; returns previous.
///
/// NaN-free inputs assumed (graph distances / ranks never produce NaN in
/// our kernels; debug_assert guards it).
#[inline]
pub fn atomic_min_f32(a: &AtomicU32, v: f32) -> f32 {
    debug_assert!(!v.is_nan());
    let mut cur = a.load(Ordering::Relaxed);
    loop {
        let cur_f = f32::from_bits(cur);
        if cur_f <= v {
            return cur_f;
        }
        match a.compare_exchange_weak(cur, v.to_bits(), Ordering::Relaxed, Ordering::Relaxed) {
            Ok(_) => return cur_f,
            Err(next) => cur = next,
        }
    }
}

/// Atomically `*a = max(*a, v)` for f32 stored as bits; returns previous.
///
/// The dual of [`atomic_min_f32`], used by max-reduce programs (widest
/// path's max-min relaxation). NaN-free inputs assumed.
#[inline]
pub fn atomic_max_f32(a: &AtomicU32, v: f32) -> f32 {
    debug_assert!(!v.is_nan());
    let mut cur = a.load(Ordering::Relaxed);
    loop {
        let cur_f = f32::from_bits(cur);
        if cur_f >= v {
            return cur_f;
        }
        match a.compare_exchange_weak(cur, v.to_bits(), Ordering::Relaxed, Ordering::Relaxed) {
            Ok(_) => return cur_f,
            Err(next) => cur = next,
        }
    }
}

/// Atomically `*a += v` for f32 stored as bits; returns previous.
#[inline]
pub fn atomic_add_f32(a: &AtomicU32, v: f32) -> f32 {
    let mut cur = a.load(Ordering::Relaxed);
    loop {
        let cur_f = f32::from_bits(cur);
        let new = cur_f + v;
        match a.compare_exchange_weak(cur, new.to_bits(), Ordering::Relaxed, Ordering::Relaxed) {
            Ok(_) => return cur_f,
            Err(next) => cur = next,
        }
    }
}

/// View a `&mut [f32]` as atomic u32 bit cells. Sound because `AtomicU32`
/// has the same size/alignment as `f32` and the mutable borrow guarantees
/// exclusive ownership of the region for the duration.
#[inline]
pub fn as_atomic_f32_cells(xs: &mut [f32]) -> &[AtomicU32] {
    unsafe { std::slice::from_raw_parts(xs.as_ptr() as *const AtomicU32, xs.len()) }
}

/// View a `&mut [i32]` as atomic i32 cells.
#[inline]
pub fn as_atomic_i32_cells(xs: &mut [i32]) -> &[AtomicI32] {
    unsafe { std::slice::from_raw_parts(xs.as_ptr() as *const AtomicI32, xs.len()) }
}

/// View a `&mut [u64]` as atomic u64 cells (the bit-parallel MS-BFS lane
/// words). Sound for the same reason as the 32-bit views: `AtomicU64` has
/// the same size/alignment as `u64` and the mutable borrow guarantees
/// exclusive ownership for the duration.
#[inline]
pub fn as_atomic_u64_cells(xs: &mut [u64]) -> &[AtomicU64] {
    unsafe { std::slice::from_raw_parts(xs.as_ptr() as *const AtomicU64, xs.len()) }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU32;

    #[test]
    fn f32_min_sequential() {
        let a = AtomicU32::new(10.0f32.to_bits());
        atomic_min_f32(&a, 12.0);
        assert_eq!(f32::from_bits(a.load(Ordering::Relaxed)), 10.0);
        atomic_min_f32(&a, 3.5);
        assert_eq!(f32::from_bits(a.load(Ordering::Relaxed)), 3.5);
    }

    #[test]
    fn f32_max_sequential() {
        let a = AtomicU32::new(f32::NEG_INFINITY.to_bits());
        atomic_max_f32(&a, 3.0);
        assert_eq!(f32::from_bits(a.load(Ordering::Relaxed)), 3.0);
        atomic_max_f32(&a, 1.5);
        assert_eq!(f32::from_bits(a.load(Ordering::Relaxed)), 3.0);
        atomic_max_f32(&a, f32::INFINITY);
        assert_eq!(f32::from_bits(a.load(Ordering::Relaxed)), f32::INFINITY);
    }

    #[test]
    fn f32_max_concurrent_finds_max() {
        let a = AtomicU32::new(f32::NEG_INFINITY.to_bits());
        let aref = &a;
        std::thread::scope(|scope| {
            for t in 0..4u32 {
                scope.spawn(move || {
                    for i in 0..1000u32 {
                        atomic_max_f32(aref, (t * 1000 + i) as f32);
                    }
                });
            }
        });
        assert_eq!(f32::from_bits(a.load(Ordering::Relaxed)), 3999.0);
    }

    #[test]
    fn f32_add_sequential() {
        let a = AtomicU32::new(1.0f32.to_bits());
        atomic_add_f32(&a, 2.5);
        atomic_add_f32(&a, -0.5);
        assert_eq!(f32::from_bits(a.load(Ordering::Relaxed)), 3.0);
    }

    #[test]
    fn f32_add_concurrent_sums_correctly() {
        let a = AtomicU32::new(0.0f32.to_bits());
        let aref = &a;
        std::thread::scope(|scope| {
            for _ in 0..4 {
                scope.spawn(move || {
                    for _ in 0..1000 {
                        atomic_add_f32(aref, 1.0);
                    }
                });
            }
        });
        assert_eq!(f32::from_bits(a.load(Ordering::Relaxed)), 4000.0);
    }

    #[test]
    fn f32_min_concurrent_finds_min() {
        let a = AtomicU32::new(f32::INFINITY.to_bits());
        let aref = &a;
        std::thread::scope(|scope| {
            for t in 0..4u32 {
                scope.spawn(move || {
                    for i in 0..1000u32 {
                        atomic_min_f32(aref, (t * 1000 + i) as f32);
                    }
                });
            }
        });
        assert_eq!(f32::from_bits(a.load(Ordering::Relaxed)), 0.0);
    }

    #[test]
    fn cell_views_alias_storage() {
        let mut xs = vec![1.0f32, 2.0, 3.0];
        {
            let cells = as_atomic_f32_cells(&mut xs);
            atomic_add_f32(&cells[1], 10.0);
        }
        assert_eq!(xs, vec![1.0, 12.0, 3.0]);

        let mut ys = vec![5i32, 6];
        {
            let cells = as_atomic_i32_cells(&mut ys);
            atomic_min_i32(&cells[0], 2);
        }
        assert_eq!(ys, vec![2, 6]);

        let mut zs = vec![0b1u64, 0];
        {
            let cells = as_atomic_u64_cells(&mut zs);
            cells[0].fetch_or(0b100, Ordering::Relaxed);
            cells[1].fetch_or(1 << 63, Ordering::Relaxed);
        }
        assert_eq!(zs, vec![0b101, 1 << 63]);
    }
}
