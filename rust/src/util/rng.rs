//! Deterministic pseudo-random number generation.
//!
//! The whole repository (graph generators, random partitioning, benchmark
//! workloads, property tests) must be reproducible from a single `u64` seed,
//! so we ship our own small PRNG instead of depending on `rand`.
//!
//! `SplitMix64` seeds `Xoshiro256**`, the standard recipe: SplitMix64 is a
//! good stream mixer for arbitrary user seeds (including 0), and
//! Xoshiro256** is a fast, high-quality generator for bulk use.

/// SplitMix64 step: advances `state` and returns the next output.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Xoshiro256** PRNG. Not cryptographic; plenty for simulation workloads.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create a generator from an arbitrary seed (0 is fine).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Derive an independent stream, e.g. one per thread or per partition.
    pub fn fork(&mut self, stream: u64) -> Rng {
        let mut sm = self.next_u64() ^ stream.wrapping_mul(0xA076_1D64_78BD_642F);
        Rng::new(splitmix64(&mut sm))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in `[0, 1)`, 53-bit resolution.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[0, 1)` as f32.
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Uniform integer in `[0, bound)` (Lemire's rejection-free-ish method).
    #[inline]
    pub fn below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        // 128-bit multiply-shift; small modulo bias is irrelevant here
        // (bound << 2^64 everywhere we use it).
        ((self.next_u64() as u128 * bound as u128) >> 64) as u64
    }

    /// Uniform in `[lo, hi)` for f64.
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// A random permutation of `0..n` as u32 (n must fit u32).
    pub fn permutation(&mut self, n: usize) -> Vec<u32> {
        let mut p: Vec<u32> = (0..n as u32).collect();
        self.shuffle(&mut p);
        p
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_respects_bound() {
        let mut r = Rng::new(9);
        for bound in [1u64, 2, 3, 10, 1000] {
            for _ in 0..1000 {
                assert!(r.below(bound) < bound);
            }
        }
    }

    #[test]
    fn below_covers_range() {
        let mut r = Rng::new(11);
        let mut seen = [false; 8];
        for _ in 0..10_000 {
            seen[r.below(8) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn permutation_is_permutation() {
        let mut r = Rng::new(3);
        let p = r.permutation(1000);
        let mut sorted = p.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..1000u32).collect::<Vec<_>>());
    }

    #[test]
    fn fork_streams_independent() {
        let mut base = Rng::new(5);
        let mut a = base.fork(0);
        let mut b = base.fork(1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn mean_is_roughly_half() {
        let mut r = Rng::new(123);
        let n = 100_000;
        let sum: f64 = (0..n).map(|_| r.next_f64()).sum();
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }
}
