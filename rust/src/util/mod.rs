//! Self-contained utility layer: PRNG, JSON, CLI args, atomics, the
//! worker pool, timers, file memory-mapping, and process memory probes.
//! The offline build environment vendors only the `xla` crate closure,
//! so everything here is hand-rolled (see DESIGN.md §6).

pub mod args;
pub mod atomic;
pub mod json;
pub mod mem;
pub mod mmap;
pub mod rng;
pub mod threadpool;
pub mod timer;

/// Split-borrow two *distinct* elements of a slice mutably — the shared
/// helper behind the engine's pairwise state exchanges and the vertex-
/// program driver's value/shadow (dist/σ) kernels. Panics if `a == b`
/// (callers validate distinctness up front).
pub fn split_two_mut<T>(xs: &mut [T], a: usize, b: usize) -> (&mut T, &mut T) {
    assert_ne!(a, b, "split_two_mut needs distinct indices");
    if a < b {
        let (x, y) = xs.split_at_mut(b);
        (&mut x[a], &mut y[0])
    } else {
        let (x, y) = xs.split_at_mut(a);
        let (snd, fst) = (&mut x[b], &mut y[0]);
        (fst, snd)
    }
}

/// Format a byte count human-readably (used by reports and Table 5).
pub fn fmt_bytes(bytes: u64) -> String {
    const UNITS: [&str; 5] = ["B", "KB", "MB", "GB", "TB"];
    let mut x = bytes as f64;
    let mut u = 0;
    while x >= 1024.0 && u < UNITS.len() - 1 {
        x /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{bytes}B")
    } else {
        format!("{x:.1}{}", UNITS[u])
    }
}

/// Format a count with thousands separators, e.g. 1234567 -> "1,234,567".
pub fn fmt_count(n: u64) -> String {
    let s = n.to_string();
    let mut out = String::with_capacity(s.len() + s.len() / 3);
    for (i, c) in s.chars().enumerate() {
        if i > 0 && (s.len() - i) % 3 == 0 {
            out.push(',');
        }
        out.push(c);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn split_two_mut_returns_both_orders() {
        let mut xs = vec![1, 2, 3];
        let (a, b) = split_two_mut(&mut xs, 0, 2);
        assert_eq!((*a, *b), (1, 3));
        let (a, b) = split_two_mut(&mut xs, 2, 0);
        assert_eq!((*a, *b), (3, 1));
        *a = 9;
        assert_eq!(xs, vec![1, 2, 9]);
    }

    #[test]
    #[should_panic(expected = "distinct")]
    fn split_two_mut_rejects_equal_indices() {
        let mut xs = vec![1, 2];
        let _ = split_two_mut(&mut xs, 1, 1);
    }

    #[test]
    fn bytes_formatting() {
        assert_eq!(fmt_bytes(512), "512B");
        assert_eq!(fmt_bytes(2048), "2.0KB");
        assert_eq!(fmt_bytes(3 * 1024 * 1024), "3.0MB");
    }

    #[test]
    fn count_formatting() {
        assert_eq!(fmt_count(7), "7");
        assert_eq!(fmt_count(1234), "1,234");
        assert_eq!(fmt_count(1234567), "1,234,567");
    }
}
