//! Self-contained utility layer: PRNG, JSON, CLI args, atomics, scoped
//! parallelism, timers. The offline build environment vendors only the
//! `xla` crate closure, so everything here is hand-rolled (see DESIGN.md §6).

pub mod args;
pub mod atomic;
pub mod json;
pub mod rng;
pub mod threadpool;
pub mod timer;

/// Format a byte count human-readably (used by reports and Table 5).
pub fn fmt_bytes(bytes: u64) -> String {
    const UNITS: [&str; 5] = ["B", "KB", "MB", "GB", "TB"];
    let mut x = bytes as f64;
    let mut u = 0;
    while x >= 1024.0 && u < UNITS.len() - 1 {
        x /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{bytes}B")
    } else {
        format!("{x:.1}{}", UNITS[u])
    }
}

/// Format a count with thousands separators, e.g. 1234567 -> "1,234,567".
pub fn fmt_count(n: u64) -> String {
    let s = n.to_string();
    let mut out = String::with_capacity(s.len() + s.len() / 3);
    for (i, c) in s.chars().enumerate() {
        if i > 0 && (s.len() - i) % 3 == 0 {
            out.push(',');
        }
        out.push(c);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bytes_formatting() {
        assert_eq!(fmt_bytes(512), "512B");
        assert_eq!(fmt_bytes(2048), "2.0KB");
        assert_eq!(fmt_bytes(3 * 1024 * 1024), "3.0MB");
    }

    #[test]
    fn count_formatting() {
        assert_eq!(fmt_count(7), "7");
        assert_eq!(fmt_count(1234), "1,234");
        assert_eq!(fmt_count(1234567), "1,234,567");
    }
}
