//! Scoped data-parallel helpers for the CPU processing element.
//!
//! The paper's CPU kernels are OpenMP `parallel for` loops over the
//! partition's vertices (Figure 11). We reproduce that with
//! `std::thread::scope` and static chunking — no external crate needed.
//!
//! The thread count models the paper's `xS` configurations (CPU sockets):
//! `1S` = half the configured parallelism, `2S` = full. On this container
//! (1 core) the structure is exercised but wall-clock parallel speedup is
//! not observable; see DESIGN.md §2.

/// Run `f(thread_idx, lo, hi)` over `0..n` split into `threads` contiguous
/// chunks. With `threads == 1` the call is inlined on the caller thread
/// (no spawn overhead) — the common case on this testbed.
pub fn parallel_chunks<F>(n: usize, threads: usize, f: F)
where
    F: Fn(usize, usize, usize) + Sync,
{
    let threads = threads.max(1);
    if threads == 1 || n < 2 * threads {
        f(0, 0, n);
        return;
    }
    let chunk = n.div_ceil(threads);
    std::thread::scope(|scope| {
        for t in 0..threads {
            let lo = t * chunk;
            let hi = ((t + 1) * chunk).min(n);
            if lo >= hi {
                break;
            }
            let f = &f;
            scope.spawn(move || f(t, lo, hi));
        }
    });
}

/// Map-reduce over `0..n`: each thread folds its chunk with `fold`, results
/// combined with `combine`. Used for "finished" voting and counters.
pub fn parallel_reduce<T, F, C>(n: usize, threads: usize, init: T, fold: F, combine: C) -> T
where
    T: Send + Clone,
    F: Fn(usize, usize, T) -> T + Sync,
    C: Fn(T, T) -> T,
{
    let threads = threads.max(1);
    if threads == 1 || n < 2 * threads {
        return fold(0, n, init);
    }
    let chunk = n.div_ceil(threads);
    let mut partials: Vec<Option<T>> = Vec::new();
    std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for t in 0..threads {
            let lo = t * chunk;
            let hi = ((t + 1) * chunk).min(n);
            if lo >= hi {
                break;
            }
            let fold = &fold;
            let seed = init.clone();
            handles.push(scope.spawn(move || fold(lo, hi, seed)));
        }
        for h in handles {
            partials.push(Some(h.join().expect("worker panicked")));
        }
    });
    let mut acc = init;
    for p in partials.into_iter().flatten() {
        acc = combine(acc, p);
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn chunks_cover_everything_once() {
        for threads in [1, 2, 3, 7] {
            for n in [0usize, 1, 5, 100, 101] {
                let counts: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
                parallel_chunks(n, threads, |_, lo, hi| {
                    for i in lo..hi {
                        counts[i].fetch_add(1, Ordering::Relaxed);
                    }
                });
                assert!(
                    counts.iter().all(|c| c.load(Ordering::Relaxed) == 1),
                    "n={n} threads={threads}"
                );
            }
        }
    }

    #[test]
    fn reduce_sums() {
        for threads in [1, 2, 4] {
            let total = parallel_reduce(
                1000,
                threads,
                0u64,
                |lo, hi, acc| acc + (lo..hi).map(|x| x as u64).sum::<u64>(),
                |a, b| a + b,
            );
            assert_eq!(total, 999 * 1000 / 2);
        }
    }

    #[test]
    fn reduce_all_vote() {
        // the "finished" vote: AND across chunks
        let finished = parallel_reduce(
            64,
            4,
            true,
            |lo, hi, acc| acc && (lo..hi).all(|i| i != 13),
            |a, b| a && b,
        );
        assert!(!finished);
    }
}
