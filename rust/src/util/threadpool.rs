//! Persistent data-parallel worker pool for the CPU processing element.
//!
//! The paper's CPU kernels are OpenMP `parallel for` loops over the
//! partition's vertices (Figure 11). Through PR 5 we reproduced that with
//! `std::thread::scope` — a fresh spawn per fold site per superstep per
//! partition. This module replaces that with a **long-lived pool of parked
//! workers** (DESIGN.md §11): threads are created once (`ensure_workers`,
//! called by `engine::run` and lazily by the free functions below), block
//! on a shared injector queue, and execute *chunk tasks* submitted by any
//! caller. The `parallel_chunks` / `parallel_reduce` call-site API is
//! unchanged, so kernel code migrated mechanically.
//!
//! On top of the pool sits **balance-aware chunking** (`Balance`,
//! `ChunkPlan`): contiguous vertex chunks (the historical behaviour),
//! edge-balanced chunks cut by prefix-summed out-degree, and hub-split
//! chunks that additionally shard a single dominant vertex's adjacency
//! across workers (CGgraph-style edge-level balance for R-MAT hubs).
//! Which kernels may use which mode is decided centrally in
//! `ProgramDriver` by the order-sensitivity contract (DESIGN.md §9, §11) —
//! this module only builds plans and runs them.
//!
//! **Determinism contract** (part of the repo-wide bit-identity contract):
//! chunk partials are combined strictly in ascending chunk order, whatever
//! order the workers finished in, and a worker panic is re-raised on the
//! calling thread with its original payload — never swallowed, never
//! `expect`ed inside the pool.
//!
//! **Concurrent-caller contract** (the serving layer and the pipelined
//! executor depend on this, DESIGN.md §13): any number of threads may
//! submit jobs concurrently. Submission is one queue push under a single
//! mutex; per-job state (`JobHeader`) lives on the submitting caller's
//! stack, so jobs share nothing but the queue. Every submitter
//! help-drains the queue until its own job quiesces — it may execute
//! *another* job's chunks while waiting, so a saturated pool degrades to
//! caller-executed work instead of deadlocking, and total progress is
//! guaranteed with zero pool workers. `ensure_workers` is grow-only and
//! idempotent: concurrent sizing races are benign (the pool ends at the
//! max of all requests and never shrinks mid-job). Per-job determinism
//! (ascending-order combine, panic ownership) is unaffected by
//! concurrent submitters.
//!
//! The thread count models the paper's `xS` configurations (CPU sockets).
//! On a 1-core container the structure is exercised but wall-clock speedup
//! is not observable; see DESIGN.md §2.

use std::any::Any;
use std::collections::VecDeque;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Condvar, Mutex, OnceLock};
use std::time::Instant;

/// Upper bound on pool threads; a safety valve, far above any realistic
/// `available_parallelism` on this testbed. Public because the effective
/// thread count must be clamped *consistently* everywhere: `ChunkPlan`
/// sizing, `EngineConfig::validate` (typed rejection of `--threads`
/// above the cap), and `default_threads()` all honor this one constant —
/// `ensure_workers` silently capping while plans cut more chunks was the
/// PR 8 oversubscription bug.
pub const MAX_POOL_WORKERS: usize = 256;

// ---------------------------------------------------------------------------
// Balance modes and chunk plans
// ---------------------------------------------------------------------------

/// Intra-partition load-balance mode for parallel kernels (DESIGN.md §11).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Balance {
    /// Contiguous, equal-*vertex* chunks (the pre-PR-6 behaviour). On
    /// skewed graphs one chunk inherits the hubs and the rest idle.
    #[default]
    Vertex,
    /// Chunk boundaries cut by prefix-summed out-degree (CSR row offsets):
    /// equal *edges* per worker, vertices never split.
    Edge,
    /// `Edge`, plus the single highest-degree vertex's adjacency is sharded
    /// across all workers when it alone exceeds an even share — CGgraph's
    /// edge-level balance for scale-free hubs.
    HubSplit,
}

impl Balance {
    pub const ALL: [Balance; 3] = [Balance::Vertex, Balance::Edge, Balance::HubSplit];

    pub fn name(self) -> &'static str {
        match self {
            Balance::Vertex => "vertex",
            Balance::Edge => "edge",
            Balance::HubSplit => "hub-split",
        }
    }

    /// Parse a CLI spelling (`--balance vertex|edge|hub-split`).
    pub fn parse(s: &str) -> Option<Balance> {
        match s.to_ascii_lowercase().as_str() {
            "vertex" | "v" => Some(Balance::Vertex),
            "edge" | "e" => Some(Balance::Edge),
            "hub-split" | "hubsplit" | "hub" | "h" => Some(Balance::HubSplit),
            _ => None,
        }
    }
}

/// One unit of parallel work: the vertex range `[lo, hi)`, plus optionally
/// a shard `[e_lo, e_hi)` of the plan's hub adjacency (`ChunkPlan::hub`).
/// When a plan has a hub, the hub vertex is *excluded* from every `[lo,hi)`
/// range (kernels skip it) and processed only through the shards.
#[derive(Debug, Clone, Copy)]
pub struct Chunk {
    pub lo: usize,
    pub hi: usize,
    /// `(e_lo, e_hi)` into the hub's adjacency list, if this chunk carries
    /// a shard of it.
    pub split: Option<(usize, usize)>,
}

/// Per-job worker busy-time spread — the observable load-imbalance signal
/// surfaced into `StepMetrics` (max vs min chunk wall time).
#[derive(Debug, Clone, Copy, Default)]
pub struct ChunkSpread {
    pub max_secs: f64,
    pub min_secs: f64,
}

/// A concrete partitioning of `0..n` into chunks, built once per kernel
/// invocation from the balance mode and (for edge modes) the CSR row
/// offsets. Plans with `threads == 1` or `n < 2*threads` collapse to a
/// single chunk executed inline — mirroring the historical fast path.
#[derive(Debug, Clone)]
pub struct ChunkPlan {
    pub chunks: Vec<Chunk>,
    /// The split vertex, when `HubSplit` engaged. Kernels must skip this
    /// vertex in `[lo,hi)` range loops and process `Chunk::split` shards.
    pub hub: Option<usize>,
    pub n: usize,
}

impl ChunkPlan {
    fn single(n: usize) -> ChunkPlan {
        ChunkPlan { chunks: vec![Chunk { lo: 0, hi: n, split: None }], hub: None, n }
    }

    /// Contiguous equal-vertex chunks — identical boundaries to the
    /// pre-pool scoped-spawn implementation.
    pub fn vertex(n: usize, threads: usize) -> ChunkPlan {
        let threads = threads.max(1);
        if threads == 1 || n < 2 * threads {
            return ChunkPlan::single(n);
        }
        let chunk = n.div_ceil(threads);
        let mut chunks = Vec::with_capacity(threads);
        for t in 0..threads {
            let lo = t * chunk;
            let hi = ((t + 1) * chunk).min(n);
            if lo >= hi {
                break;
            }
            chunks.push(Chunk { lo, hi, split: None });
        }
        ChunkPlan { chunks, hub: None, n }
    }

    /// Edge-balanced chunks: boundary `t` is the first vertex whose prefix
    /// edge count reaches `t/threads` of the total. `row_offsets` is the
    /// CSR row-offset array (`len == n+1`); every vertex stays whole.
    pub fn edge(row_offsets: &[u64], threads: usize) -> ChunkPlan {
        let n = row_offsets.len().saturating_sub(1);
        let threads = threads.max(1);
        if threads == 1 || n < 2 * threads {
            return ChunkPlan::single(n);
        }
        let base = row_offsets[0];
        let total = row_offsets[n] - base;
        if total == 0 {
            return ChunkPlan::vertex(n, threads);
        }
        let mut bounds = vec![0usize; threads + 1];
        bounds[threads] = n;
        for t in 1..threads {
            let target = base + ((total as u128 * t as u128) / threads as u128) as u64;
            let idx = row_offsets.partition_point(|&x| x < target).min(n);
            bounds[t] = idx.max(bounds[t - 1]);
        }
        let mut chunks = Vec::with_capacity(threads);
        for t in 0..threads {
            let (lo, hi) = (bounds[t], bounds[t + 1]);
            if lo < hi {
                chunks.push(Chunk { lo, hi, split: None });
            }
        }
        ChunkPlan { chunks, hub: None, n }
    }

    /// Hub-split: find the single highest-out-degree vertex; if its degree
    /// alone exceeds an even edge share (`deg_hub * threads > total`),
    /// shard its adjacency evenly across all chunks and balance the
    /// remaining vertices' edges around it. Otherwise degrade to `edge`.
    pub fn hub_split(row_offsets: &[u64], threads: usize) -> ChunkPlan {
        let n = row_offsets.len().saturating_sub(1);
        let threads = threads.max(1);
        if threads == 1 || n < 2 * threads {
            return ChunkPlan::single(n);
        }
        let total = row_offsets[n] - row_offsets[0];
        if total == 0 {
            return ChunkPlan::vertex(n, threads);
        }
        let deg = |v: usize| row_offsets[v + 1] - row_offsets[v];
        let (mut hub, mut deg_h) = (0usize, 0u64);
        for v in 0..n {
            if deg(v) > deg_h {
                hub = v;
                deg_h = deg(v);
            }
        }
        if (deg_h as u128) * (threads as u128) <= total as u128 {
            return ChunkPlan::edge(row_offsets, threads);
        }
        // Vertex ranges balanced on non-hub degree (the hub weighs zero —
        // it is excluded from range iteration and carried by the shards).
        let rest = total - deg_h;
        let mut bounds = vec![0usize; threads + 1];
        bounds[threads] = n;
        let mut acc: u64 = 0;
        let mut t = 1;
        for v in 0..n {
            if v != hub {
                acc += deg(v);
            }
            while t < threads && (acc as u128) * (threads as u128) >= (rest as u128) * (t as u128)
            {
                bounds[t] = v + 1;
                t += 1;
            }
        }
        let dh = deg_h as usize;
        let mut chunks = Vec::with_capacity(threads);
        for t in 0..threads {
            let (lo, hi) = (bounds[t], bounds[t + 1]);
            let (e_lo, e_hi) = (dh * t / threads, dh * (t + 1) / threads);
            let split = (e_lo < e_hi).then_some((e_lo, e_hi));
            if lo < hi || split.is_some() {
                chunks.push(Chunk { lo, hi, split });
            }
        }
        ChunkPlan { chunks, hub: Some(hub), n }
    }

    /// Build the plan for a balance mode over `row_offsets` (`len == n+1`).
    pub fn for_balance(balance: Balance, row_offsets: &[u64], threads: usize) -> ChunkPlan {
        match balance {
            Balance::Vertex => ChunkPlan::vertex(row_offsets.len().saturating_sub(1), threads),
            Balance::Edge => ChunkPlan::edge(row_offsets, threads),
            Balance::HubSplit => ChunkPlan::hub_split(row_offsets, threads),
        }
    }
}

// ---------------------------------------------------------------------------
// The persistent pool
// ---------------------------------------------------------------------------

struct PoolShared {
    queue: Mutex<VecDeque<Task>>,
    work_cv: Condvar,
}

struct Pool {
    shared: &'static PoolShared,
    /// Workers spawned so far; guarded growth in `grow_to`.
    spawned: Mutex<usize>,
}

/// A single chunk of a job, queued for any worker (or the submitting
/// caller) to execute.
struct Task {
    job: *const JobHeader,
    chunk: usize,
}

// SAFETY: the `job` pointer targets a `JobHeader` on the submitting
// caller's stack. The caller never leaves `run_job` (by return *or*
// unwind) until `remaining` hits zero, i.e. until every queued task has
// finished executing, so the pointer is live for every access.
unsafe impl Send for Task {}

/// Per-job shared state, stack-allocated by the submitting caller.
struct JobHeader {
    /// The chunk body, lifetime-erased. See `Task` safety comment.
    run: &'static (dyn Fn(usize) + Sync),
    remaining: AtomicUsize,
    panic: Mutex<Option<Box<dyn Any + Send>>>,
    done_lock: Mutex<()>,
    done_cv: Condvar,
}

fn pool() -> &'static Pool {
    static POOL: OnceLock<Pool> = OnceLock::new();
    POOL.get_or_init(|| Pool {
        shared: Box::leak(Box::new(PoolShared {
            queue: Mutex::new(VecDeque::new()),
            work_cv: Condvar::new(),
        })),
        spawned: Mutex::new(0),
    })
}

/// Ensure the global pool has at least `threads - 1` parked workers (the
/// submitting caller is the remaining worker). Called once per engine run,
/// sized from the element configuration; also called lazily by the free
/// functions so direct callers (tests, benches) get parallelism too.
/// Grow-only: workers are never torn down — they park on the queue condvar
/// and die with the process.
pub fn ensure_workers(threads: usize) {
    let want = threads.saturating_sub(1).min(MAX_POOL_WORKERS);
    let p = pool();
    let mut spawned = p.spawned.lock().unwrap();
    while *spawned < want {
        let shared: &'static PoolShared = p.shared;
        let idx = *spawned;
        let res = std::thread::Builder::new()
            .name(format!("totem-pool-{idx}"))
            .spawn(move || worker_loop(shared));
        if res.is_err() {
            // Spawn failure is non-fatal: callers help-drain their own
            // jobs, so work still completes (serially).
            break;
        }
        *spawned += 1;
    }
}

/// Current pool size (workers only, excluding callers). Test hook.
pub fn pool_workers() -> usize {
    *pool().spawned.lock().unwrap()
}

fn worker_loop(shared: &'static PoolShared) {
    loop {
        let task = {
            let mut q = shared.queue.lock().unwrap();
            loop {
                if let Some(t) = q.pop_front() {
                    break t;
                }
                q = shared.work_cv.wait(q).unwrap();
            }
        };
        run_task(task);
    }
}

/// Execute one task: run the chunk body under `catch_unwind`, stash any
/// panic payload in the job, and signal completion on the last chunk.
fn run_task(task: Task) {
    // SAFETY: see `Task`.
    let job = unsafe { &*task.job };
    let body = job.run;
    if let Err(payload) = catch_unwind(AssertUnwindSafe(|| body(task.chunk))) {
        let mut slot = job.panic.lock().unwrap();
        // first panic wins; later ones are dropped (same as rayon)
        slot.get_or_insert(payload);
    }
    if job.remaining.fetch_sub(1, Ordering::AcqRel) == 1 {
        // Lock-then-notify so the submitter cannot miss the wakeup between
        // its `remaining` check and its `wait`.
        let _g = job.done_lock.lock().unwrap();
        job.done_cv.notify_all();
    }
}

/// Submit `k` chunk tasks running `body(chunk_idx)` and wait for all of
/// them. The caller help-drains the queue (it is worker number `threads`),
/// then parks until stragglers finish. Re-raises the first worker panic on
/// the calling thread once every chunk has completed — the job's memory is
/// only released after quiescence, which is what makes the lifetime
/// erasure sound.
fn run_job(k: usize, body: &(dyn Fn(usize) + Sync)) {
    debug_assert!(k >= 1);
    ensure_workers(k);
    // SAFETY: lifetime erasure only; `job` (and thus `body`) outlives every
    // access because this function does not return until `remaining == 0`.
    let run: &'static (dyn Fn(usize) + Sync) = unsafe { std::mem::transmute(body) };
    let job = JobHeader {
        run,
        remaining: AtomicUsize::new(k),
        panic: Mutex::new(None),
        done_lock: Mutex::new(()),
        done_cv: Condvar::new(),
    };
    let shared = pool().shared;
    {
        let mut q = shared.queue.lock().unwrap();
        for chunk in 0..k {
            q.push_back(Task { job: &job as *const JobHeader, chunk });
        }
    }
    shared.work_cv.notify_all();
    // Help-drain: execute queued tasks (ours or another concurrent job's —
    // the pipelined executor submits from several partition threads) until
    // our own job has no queued work left.
    while job.remaining.load(Ordering::Acquire) != 0 {
        let task = shared.queue.lock().unwrap().pop_front();
        match task {
            Some(t) => run_task(t),
            None => break,
        }
    }
    // Park until in-flight chunks (stolen by pool workers) finish.
    {
        let mut g = job.done_lock.lock().unwrap();
        while job.remaining.load(Ordering::Acquire) != 0 {
            g = job.done_cv.wait(g).unwrap();
        }
    }
    if let Some(payload) = job.panic.lock().unwrap().take() {
        resume_unwind(payload);
    }
}

// ---------------------------------------------------------------------------
// Public call-site API (unchanged signatures from the scoped-spawn era)
// ---------------------------------------------------------------------------

/// Run `f(chunk_idx, lo, hi)` over `0..n` split into `threads` contiguous
/// vertex chunks on the persistent pool. With `threads == 1` (or tiny `n`)
/// the call is inlined on the caller thread — the common case on this
/// testbed.
pub fn parallel_chunks<F>(n: usize, threads: usize, f: F)
where
    F: Fn(usize, usize, usize) + Sync,
{
    let plan = ChunkPlan::vertex(n, threads);
    if plan.chunks.len() == 1 {
        f(0, 0, n);
        return;
    }
    let chunks = &plan.chunks;
    run_job(chunks.len(), &|ci: usize| {
        let c = chunks[ci];
        f(ci, c.lo, c.hi);
    });
}

/// Map-reduce over `0..n` with equal-vertex chunks: each chunk folds with
/// `fold`, partials combined with `combine` **in ascending chunk order**
/// (deterministic, part of the bit-identity contract). A panic inside
/// `fold` is re-raised here with its original payload after all chunks
/// quiesce.
pub fn parallel_reduce<T, F, C>(n: usize, threads: usize, init: T, fold: F, combine: C) -> T
where
    T: Send + Clone,
    F: Fn(usize, usize, T) -> T + Sync,
    C: Fn(T, T) -> T,
{
    let plan = ChunkPlan::vertex(n, threads);
    let (acc, _) = parallel_reduce_plan(&plan, init, |c, seed| fold(c.lo, c.hi, seed), combine);
    acc
}

/// Map-reduce over an explicit `ChunkPlan` (balance-aware kernels). Each
/// chunk is timed; the returned `ChunkSpread` is the max/min chunk wall
/// time — the per-partition load-imbalance signal for `StepMetrics`.
/// Partials are combined in ascending chunk order regardless of completion
/// order; single-chunk plans fold inline on the caller.
pub fn parallel_reduce_plan<T, F, C>(
    plan: &ChunkPlan,
    init: T,
    fold: F,
    combine: C,
) -> (T, ChunkSpread)
where
    T: Send + Clone,
    F: Fn(&Chunk, T) -> T + Sync,
    C: Fn(T, T) -> T,
{
    let k = plan.chunks.len();
    if k == 0 {
        return (init, ChunkSpread::default());
    }
    if k == 1 {
        let t0 = Instant::now();
        let acc = fold(&plan.chunks[0], init);
        let secs = t0.elapsed().as_secs_f64();
        return (acc, ChunkSpread { max_secs: secs, min_secs: secs });
    }
    let partials: Vec<Mutex<Option<T>>> = (0..k).map(|_| Mutex::new(None)).collect();
    // Seeds are cloned on the caller (not inside workers) so the public
    // bound stays `T: Send + Clone` — `T: Sync` is not required.
    let seeds: Vec<Mutex<Option<T>>> = (0..k).map(|_| Mutex::new(Some(init.clone()))).collect();
    let times: Vec<AtomicU64> = (0..k).map(|_| AtomicU64::new(0)).collect();
    {
        let fold = &fold;
        run_job(k, &|ci: usize| {
            let seed = seeds[ci].lock().unwrap().take().expect("seed taken once");
            let t0 = Instant::now();
            let r = fold(&plan.chunks[ci], seed);
            times[ci].store(t0.elapsed().as_secs_f64().to_bits(), Ordering::Relaxed);
            *partials[ci].lock().unwrap() = Some(r);
        });
    }
    let mut acc = init;
    let (mut max_s, mut min_s) = (0.0f64, f64::INFINITY);
    for (p, t) in partials.into_iter().zip(&times) {
        let part = p
            .into_inner()
            .unwrap()
            .expect("chunk quiesced without a result or a panic");
        acc = combine(acc, part);
        let secs = f64::from_bits(t.load(Ordering::Relaxed));
        max_s = max_s.max(secs);
        min_s = min_s.min(secs);
    }
    (acc, ChunkSpread { max_secs: max_s, min_secs: if min_s.is_finite() { min_s } else { 0.0 } })
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn chunks_cover_everything_once() {
        for threads in [1, 2, 3, 7] {
            for n in [0usize, 1, 5, 100, 101] {
                let counts: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
                parallel_chunks(n, threads, |_, lo, hi| {
                    for i in lo..hi {
                        counts[i].fetch_add(1, Ordering::Relaxed);
                    }
                });
                assert!(
                    counts.iter().all(|c| c.load(Ordering::Relaxed) == 1),
                    "n={n} threads={threads}"
                );
            }
        }
    }

    #[test]
    fn reduce_sums() {
        for threads in [1, 2, 4] {
            let total = parallel_reduce(
                1000,
                threads,
                0u64,
                |lo, hi, acc| acc + (lo..hi).map(|x| x as u64).sum::<u64>(),
                |a, b| a + b,
            );
            assert_eq!(total, 999 * 1000 / 2);
        }
    }

    #[test]
    fn reduce_all_vote() {
        // the "finished" vote: AND across chunks
        let finished = parallel_reduce(
            64,
            4,
            true,
            |lo, hi, acc| acc && (lo..hi).all(|i| i != 13),
            |a, b| a && b,
        );
        assert!(!finished);
    }

    /// Degree sequence → CSR row offsets.
    fn offsets(degs: &[u64]) -> Vec<u64> {
        let mut row = Vec::with_capacity(degs.len() + 1);
        let mut acc = 0u64;
        row.push(0);
        for &d in degs {
            acc += d;
            row.push(acc);
        }
        row
    }

    /// Check a plan covers every vertex's full adjacency exactly once:
    /// non-hub vertices appear in exactly one `[lo,hi)` range; the hub (if
    /// any) is covered exactly by the union of disjoint shards.
    fn assert_exact_cover(plan: &ChunkPlan, degs: &[u64], label: &str) {
        let n = degs.len();
        assert_eq!(plan.n, n, "{label}");
        let mut visits = vec![0u32; n];
        let mut hub_edges: Vec<u32> = Vec::new();
        if let Some(h) = plan.hub {
            hub_edges = vec![0; degs[h] as usize];
        }
        for c in &plan.chunks {
            assert!(c.lo <= c.hi && c.hi <= n, "{label}: bad range");
            for v in c.lo..c.hi {
                if plan.hub != Some(v) {
                    visits[v] += 1;
                }
            }
            if let Some((e0, e1)) = c.split {
                let h = plan.hub.expect("split without hub");
                assert!(e1 <= degs[h] as usize, "{label}: shard past degree");
                for e in e0..e1 {
                    hub_edges[e] += 1;
                }
            }
        }
        for (v, &cnt) in visits.iter().enumerate() {
            if plan.hub == Some(v) {
                continue;
            }
            assert_eq!(cnt, 1, "{label}: vertex {v} visited {cnt} times");
        }
        assert!(hub_edges.iter().all(|&c| c == 1), "{label}: hub edges not covered once");
    }

    #[test]
    fn plans_cover_every_edge_exactly_once_on_skewed_degrees() {
        // Skewed sequences: zipf-ish, star (one mega hub), uniform,
        // all-isolated, hub-at-the-end, and a seeded random mix.
        let mut rng = crate::util::rng::Rng::new(0xBA1A);
        let mut random: Vec<u64> = (0..257).map(|_| rng.below(9)).collect();
        random[200] = 5_000; // dominant hub off-center
        let zipf: Vec<u64> = (0..100).map(|v| 1 + 300 / (v as u64 + 1)).collect();
        let mut star = vec![0u64; 64];
        star[0] = 10_000;
        let tail_hub: Vec<u64> = (0..50).map(|v| if v == 49 { 999 } else { 1 }).collect();
        let cases: Vec<(&str, Vec<u64>)> = vec![
            ("zipf", zipf),
            ("star", star),
            ("uniform", vec![7; 128]),
            ("isolated", vec![0; 40]),
            ("tail-hub", tail_hub),
            ("random", random),
        ];
        for (name, degs) in &cases {
            let row = offsets(degs);
            for threads in [1usize, 2, 3, 4, 7, 8, 13] {
                for b in Balance::ALL {
                    let plan = ChunkPlan::for_balance(b, &row, threads);
                    assert_exact_cover(&plan, degs, &format!("{name}/{threads}/{b:?}"));
                }
            }
        }
    }

    #[test]
    fn edge_plan_balances_better_than_vertex_on_a_hub() {
        // 0..n-1 light vertices plus a mega hub at v=0: the vertex plan
        // gives chunk 0 nearly all edges; the edge plan caps every chunk at
        // (total/threads + max_degree) and hub-split at roughly total/threads.
        let mut degs = vec![1u64; 1024];
        degs[0] = 4096;
        let row = offsets(&degs);
        let threads = 8;
        let total: u64 = degs.iter().sum();
        let load = |plan: &ChunkPlan| -> u64 {
            plan.chunks
                .iter()
                .map(|c| {
                    let mut e: u64 = (c.lo..c.hi)
                        .filter(|&v| plan.hub != Some(v))
                        .map(|v| degs[v])
                        .sum();
                    if let Some((e0, e1)) = c.split {
                        e += (e1 - e0) as u64;
                    }
                    e
                })
                .max()
                .unwrap_or(0)
        };
        let vmax = load(&ChunkPlan::vertex(degs.len(), threads));
        let emax = load(&ChunkPlan::edge(&row, threads));
        let hmax = load(&ChunkPlan::hub_split(&row, threads));
        assert!(vmax >= degs[0], "vertex chunking inherits the hub whole");
        assert!(emax <= total / threads as u64 + degs[0], "edge bound");
        assert!(hmax < vmax, "hub-split must beat vertex chunking ({hmax} vs {vmax})");
        assert!(hmax <= total / threads as u64 + total / 100, "hub shards even out the load");
    }

    #[test]
    fn hub_split_degrades_to_edge_without_a_dominant_hub() {
        let degs = vec![5u64; 64];
        let row = offsets(&degs);
        let plan = ChunkPlan::hub_split(&row, 4);
        assert!(plan.hub.is_none(), "uniform degrees: no hub to split");
        assert!(plan.chunks.iter().all(|c| c.split.is_none()));
    }

    #[test]
    fn panic_propagates_to_caller_with_payload() {
        let caught = std::panic::catch_unwind(|| {
            parallel_reduce(
                1000,
                4,
                0u64,
                |lo, hi, acc| {
                    if (lo..hi).contains(&613) {
                        panic!("kernel died at 613");
                    }
                    acc + (hi - lo) as u64
                },
                |a, b| a + b,
            )
        });
        let payload = caught.expect_err("panic must propagate out of the pool");
        let msg = payload
            .downcast_ref::<&str>()
            .copied()
            .map(String::from)
            .or_else(|| payload.downcast_ref::<String>().cloned())
            .unwrap_or_default();
        assert!(msg.contains("kernel died at 613"), "original payload preserved: {msg}");
        // The pool must stay usable after a propagated panic.
        let total = parallel_reduce(100, 4, 0u64, |lo, hi, a| a + (hi - lo) as u64, |a, b| a + b);
        assert_eq!(total, 100);
    }

    #[test]
    fn combine_order_is_ascending_chunk_order() {
        // Fold tags each chunk; combine concatenates. Whatever order the
        // workers finish in, the combined sequence must be ascending — the
        // deterministic-combine half of the bit-identity contract.
        for _ in 0..64 {
            let order = parallel_reduce(
                1000,
                8,
                Vec::new(),
                |lo, _hi, mut acc: Vec<usize>| {
                    acc.push(lo);
                    acc
                },
                |mut a, b| {
                    a.extend(b);
                    a
                },
            );
            assert_eq!(order.len(), 8);
            assert!(order.windows(2).all(|w| w[0] < w[1]), "got {order:?}");
        }
    }

    #[test]
    fn reduce_plan_reports_spread_and_sums_over_splits() {
        let mut degs = vec![2u64; 512];
        degs[100] = 9_000;
        let row = offsets(&degs);
        let plan = ChunkPlan::hub_split(&row, 4);
        assert_eq!(plan.hub, Some(100));
        // Sum of per-chunk edge loads must equal the total edge count.
        let (sum, spread) = parallel_reduce_plan(
            &plan,
            0u64,
            |c: &Chunk, acc: u64| {
                let mut e: u64 = (c.lo..c.hi).filter(|&v| v != 100).map(|v| degs[v]).sum();
                if let Some((e0, e1)) = c.split {
                    e += (e1 - e0) as u64;
                }
                acc + e
            },
            |a, b| a + b,
        );
        assert_eq!(sum, degs.iter().sum::<u64>());
        assert!(spread.max_secs >= spread.min_secs);
        assert!(spread.min_secs >= 0.0);
    }

    #[test]
    fn concurrent_jobs_from_many_submitters() {
        // The pipelined executor submits jobs from several partition
        // threads at once; results must stay isolated per job.
        std::thread::scope(|s| {
            for base in 0..6u64 {
                s.spawn(move || {
                    for _ in 0..8 {
                        let total = parallel_reduce(
                            500,
                            3,
                            0u64,
                            |lo, hi, acc| acc + (lo..hi).map(|x| x as u64 + base).sum::<u64>(),
                            |a, b| a + b,
                        );
                        assert_eq!(total, 499 * 500 / 2 + 500 * base);
                    }
                });
            }
        });
    }

    #[test]
    fn workers_persist_across_calls() {
        ensure_workers(4);
        let before = pool_workers();
        assert!(before >= 3);
        for _ in 0..16 {
            parallel_chunks(256, 4, |_, _, _| {});
        }
        assert_eq!(pool_workers(), before, "grow-only pool: no respawn per call");
    }
}
