//! Tiny CLI argument parser (no clap in the offline dependency set).
//!
//! Supports the shapes the `totem` binary and the bench harnesses need:
//! `--key value`, `--key=value`, boolean `--flag`, and positional args.

use std::collections::BTreeMap;

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    flags: BTreeMap<String, String>,
    seen: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw arguments (without argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Result<Args, String> {
        let mut out = Args::default();
        let mut it = raw.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(body) = a.strip_prefix("--") {
                if body.is_empty() {
                    // "--" separator: rest is positional
                    out.positional.extend(it);
                    break;
                }
                let (key, val) = match body.split_once('=') {
                    Some((k, v)) => (k.to_string(), Some(v.to_string())),
                    None => (body.to_string(), None),
                };
                let val = match val {
                    Some(v) => v,
                    None => {
                        // consume the next token as the value unless it looks
                        // like another flag; then treat as boolean.
                        match it.peek() {
                            Some(nxt) if !nxt.starts_with("--") => it.next().unwrap(),
                            _ => "true".to_string(),
                        }
                    }
                };
                out.seen.push(key.clone());
                out.flags.insert(key, val);
            } else {
                out.positional.push(a);
            }
        }
        Ok(out)
    }

    pub fn from_env() -> Result<Args, String> {
        Args::parse(std::env::args().skip(1))
    }

    pub fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, key: &str, default: usize) -> Result<usize, String> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|e| format!("--{key}: expected integer, got '{v}' ({e})")),
        }
    }

    pub fn u64_or(&self, key: &str, default: u64) -> Result<u64, String> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|e| format!("--{key}: expected integer, got '{v}' ({e})")),
        }
    }

    pub fn f64_or(&self, key: &str, default: f64) -> Result<f64, String> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|e| format!("--{key}: expected number, got '{v}' ({e})")),
        }
    }

    pub fn bool_or(&self, key: &str, default: bool) -> Result<bool, String> {
        match self.get(key) {
            None => Ok(default),
            Some("true") | Some("1") | Some("yes") => Ok(true),
            Some("false") | Some("0") | Some("no") => Ok(false),
            Some(v) => Err(format!("--{key}: expected bool, got '{v}'")),
        }
    }

    /// Comma-separated list of f64, e.g. `--alphas 0.5,0.6,0.7`.
    pub fn f64_list_or(&self, key: &str, default: &[f64]) -> Result<Vec<f64>, String> {
        match self.get(key) {
            None => Ok(default.to_vec()),
            Some(v) => v
                .split(',')
                .map(|x| {
                    x.trim()
                        .parse()
                        .map_err(|e| format!("--{key}: bad element '{x}' ({e})"))
                })
                .collect(),
        }
    }

    /// Comma-separated list of strings.
    pub fn str_list_or(&self, key: &str, default: &[&str]) -> Vec<String> {
        match self.get(key) {
            None => default.iter().map(|s| s.to_string()).collect(),
            Some(v) => v.split(',').map(|x| x.trim().to_string()).collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(v: &[&str]) -> Args {
        Args::parse(v.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn key_value_styles() {
        let a = parse(&["run", "--alg", "bfs", "--alpha=0.7", "--verbose", "--n", "42"]);
        assert_eq!(a.positional, vec!["run"]);
        assert_eq!(a.get("alg"), Some("bfs"));
        assert_eq!(a.f64_or("alpha", 0.0).unwrap(), 0.7);
        assert!(a.bool_or("verbose", false).unwrap());
        assert_eq!(a.usize_or("n", 0).unwrap(), 42);
    }

    #[test]
    fn defaults() {
        let a = parse(&[]);
        assert_eq!(a.str_or("alg", "bfs"), "bfs");
        assert_eq!(a.usize_or("n", 7).unwrap(), 7);
        assert!(!a.bool_or("verbose", false).unwrap());
    }

    #[test]
    fn negative_numbers_as_values() {
        let a = parse(&["--offset=-3"]);
        assert_eq!(a.f64_or("offset", 0.0).unwrap(), -3.0);
    }

    #[test]
    fn lists() {
        let a = parse(&["--alphas", "0.5, 0.6,0.7", "--algs", "bfs,pagerank"]);
        assert_eq!(a.f64_list_or("alphas", &[]).unwrap(), vec![0.5, 0.6, 0.7]);
        assert_eq!(a.str_list_or("algs", &[]), vec!["bfs", "pagerank"]);
    }

    #[test]
    fn bad_values_error() {
        let a = parse(&["--n", "abc"]);
        assert!(a.usize_or("n", 0).is_err());
    }

    #[test]
    fn double_dash_positional() {
        let a = parse(&["--x", "1", "--", "--not-a-flag"]);
        assert_eq!(a.positional, vec!["--not-a-flag"]);
    }
}
