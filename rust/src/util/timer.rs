//! Phase timing utilities.
//!
//! The engine attributes every nanosecond of a BSP superstep to a phase
//! (per-partition compute, transfer, scatter). These are thin wrappers over
//! `std::time::Instant` that accumulate into named buckets.

use std::time::{Duration, Instant};

/// Accumulating stopwatch.
#[derive(Debug, Default, Clone, Copy)]
pub struct Stopwatch {
    total: Duration,
}

impl Stopwatch {
    pub fn new() -> Self {
        Self::default()
    }

    /// Time a closure, accumulate, and return its value.
    #[inline]
    pub fn time<T>(&mut self, f: impl FnOnce() -> T) -> T {
        let t0 = Instant::now();
        let out = f();
        self.total += t0.elapsed();
        out
    }

    pub fn add(&mut self, d: Duration) {
        self.total += d;
    }

    pub fn total(&self) -> Duration {
        self.total
    }

    pub fn secs(&self) -> f64 {
        self.total.as_secs_f64()
    }

    pub fn reset(&mut self) {
        self.total = Duration::ZERO;
    }
}

/// Measure one closure's duration in seconds along with its value.
#[inline]
pub fn timed<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed().as_secs_f64())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accumulates() {
        let mut sw = Stopwatch::new();
        let v = sw.time(|| {
            std::thread::sleep(Duration::from_millis(5));
            42
        });
        assert_eq!(v, 42);
        sw.time(|| std::thread::sleep(Duration::from_millis(5)));
        assert!(sw.secs() >= 0.009, "secs={}", sw.secs());
        sw.reset();
        assert_eq!(sw.secs(), 0.0);
    }

    #[test]
    fn timed_returns_value_and_duration() {
        let (v, dt) = timed(|| 7u32);
        assert_eq!(v, 7);
        assert!(dt >= 0.0);
    }
}
