//! Minimal read-only file memory-mapping shim (DESIGN.md §12.3).
//!
//! The offline build environment cannot add the `libc`/`memmap2` crates, so
//! the two syscalls the out-of-core ingest layer needs — `mmap` and
//! `munmap` — are declared here directly against the C runtime every Unix
//! target already links. The surface is deliberately tiny: map a whole
//! file read-only & private, expose it as `&[u8]`, unmap on drop.
//!
//! Non-Unix targets compile a stub whose `map_readonly` always fails with
//! `ErrorKind::Unsupported`; callers (`graph::store::GraphStore`) treat
//! that as "fall back to buffered reads", so the rest of the crate never
//! `cfg`s on the platform itself.

#[cfg(unix)]
mod sys {
    use std::os::raw::{c_int, c_void};

    // Prototypes per POSIX; `off_t` is pointer-width (`isize`) on every
    // LP64 Unix target this repo builds for. 32-bit targets without
    // large-file support would need `mmap64` — out of scope, documented
    // in DESIGN.md §12.3.
    extern "C" {
        pub fn mmap(
            addr: *mut c_void,
            length: usize,
            prot: c_int,
            flags: c_int,
            fd: c_int,
            offset: isize,
        ) -> *mut c_void;
        pub fn munmap(addr: *mut c_void, length: usize) -> c_int;
        #[cfg(target_os = "linux")]
        pub fn madvise(addr: *mut c_void, length: usize, advice: c_int) -> c_int;
    }

    pub const PROT_READ: c_int = 1;
    pub const MAP_PRIVATE: c_int = 2;
    #[cfg(target_os = "linux")]
    pub const MADV_SEQUENTIAL: c_int = 2;
}

/// Whether this build can memory-map files at all.
pub fn mmap_supported() -> bool {
    cfg!(unix)
}

/// A read-only, private mapping of an entire file.
///
/// The mapping stays valid for the lifetime of this value; `Drop` unmaps.
/// Contract (DESIGN.md §12.3): the underlying file must not be truncated
/// while mapped — POSIX delivers `SIGBUS` on access past a shrunken file's
/// end, which no userspace check can fully prevent. `GraphStore` validates
/// the file length against the declared layout *before* building slices,
/// so a well-formed file that stays put is always safe.
#[cfg(unix)]
pub struct Mmap {
    ptr: *mut u8,
    len: usize,
}

#[cfg(unix)]
impl Mmap {
    /// Map `f` read-only in its entirety. Fails on empty files (POSIX
    /// rejects zero-length mappings) and on any syscall error.
    pub fn map_readonly(f: &std::fs::File) -> std::io::Result<Mmap> {
        use std::os::unix::io::AsRawFd;
        let len = f.metadata()?.len();
        if len == 0 {
            return Err(std::io::Error::new(
                std::io::ErrorKind::InvalidInput,
                "cannot mmap an empty file",
            ));
        }
        let len = usize::try_from(len).map_err(|_| {
            std::io::Error::new(std::io::ErrorKind::InvalidInput, "file too large to map")
        })?;
        let ptr = unsafe {
            sys::mmap(
                std::ptr::null_mut(),
                len,
                sys::PROT_READ,
                sys::MAP_PRIVATE,
                f.as_raw_fd(),
                0,
            )
        };
        if ptr as isize == -1 {
            return Err(std::io::Error::last_os_error());
        }
        Ok(Mmap { ptr: ptr as *mut u8, len })
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// The mapped bytes. Page-aligned base pointer (mmap guarantees it).
    pub fn as_slice(&self) -> &[u8] {
        unsafe { std::slice::from_raw_parts(self.ptr, self.len) }
    }

    /// Advise the kernel we will stream the mapping front-to-back
    /// (read-ahead hint for checksum verification and partition build).
    /// Best-effort: errors are ignored, non-Linux is a no-op.
    pub fn advise_sequential(&self) {
        #[cfg(target_os = "linux")]
        unsafe {
            let _ = sys::madvise(self.ptr as *mut _, self.len, sys::MADV_SEQUENTIAL);
        }
    }
}

#[cfg(unix)]
impl Drop for Mmap {
    fn drop(&mut self) {
        unsafe {
            let _ = sys::munmap(self.ptr as *mut _, self.len);
        }
    }
}

// SAFETY: the mapping is read-only (PROT_READ) and private (MAP_PRIVATE);
// concurrent shared reads from multiple threads are data-race-free, and
// ownership transfer only moves the pointer, never the pages.
#[cfg(unix)]
unsafe impl Send for Mmap {}
#[cfg(unix)]
unsafe impl Sync for Mmap {}

#[cfg(unix)]
impl std::fmt::Debug for Mmap {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Mmap").field("len", &self.len).finish()
    }
}

/// Stub for non-Unix targets: `map_readonly` always fails, so callers take
/// the buffered-read fallback path.
#[cfg(not(unix))]
#[derive(Debug)]
pub struct Mmap {
    never: std::convert::Infallible,
}

#[cfg(not(unix))]
impl Mmap {
    pub fn map_readonly(_f: &std::fs::File) -> std::io::Result<Mmap> {
        Err(std::io::Error::new(
            std::io::ErrorKind::Unsupported,
            "mmap is not available on this platform",
        ))
    }

    pub fn len(&self) -> usize {
        match self.never {}
    }

    pub fn is_empty(&self) -> bool {
        match self.never {}
    }

    pub fn as_slice(&self) -> &[u8] {
        match self.never {}
    }

    pub fn advise_sequential(&self) {
        match self.never {}
    }
}

#[cfg(all(test, unix))]
mod tests {
    use super::*;
    use std::io::Write;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("totem_mmap_tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn maps_file_contents() {
        let p = tmp("a.bin");
        let mut f = std::fs::File::create(&p).unwrap();
        f.write_all(b"hello mapping").unwrap();
        drop(f);
        let f = std::fs::File::open(&p).unwrap();
        let m = Mmap::map_readonly(&f).unwrap();
        assert_eq!(m.as_slice(), b"hello mapping");
        assert_eq!(m.len(), 13);
        m.advise_sequential();
    }

    #[test]
    fn rejects_empty_file() {
        let p = tmp("empty.bin");
        std::fs::File::create(&p).unwrap();
        let f = std::fs::File::open(&p).unwrap();
        assert!(Mmap::map_readonly(&f).is_err());
    }

    #[test]
    fn mapping_is_page_aligned_and_shareable_across_threads() {
        let p = tmp("b.bin");
        std::fs::write(&p, vec![7u8; 4096 * 2 + 13]).unwrap();
        let f = std::fs::File::open(&p).unwrap();
        let m = std::sync::Arc::new(Mmap::map_readonly(&f).unwrap());
        assert_eq!(m.as_slice().as_ptr() as usize % 4096, 0, "page aligned");
        let m2 = m.clone();
        let h = std::thread::spawn(move || m2.as_slice().iter().map(|&b| b as u64).sum::<u64>());
        let a = m.as_slice().iter().map(|&b| b as u64).sum::<u64>();
        assert_eq!(a, h.join().unwrap());
    }
}
